"""Layer-stacking semantics: `model.stacked` must equal manually chaining
the single-layer tile forwards with ReLU between hidden layers and a
linear final layer — the exact pipeline contract the Rust `ModelSpec` /
`plan::ExecPlan` implement and the multi-layer PJRT validation drives.
"""

from __future__ import annotations

import jax
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

# square tile: stacking needs num_src == num_dst and feat_in == feat_out
TS = M.TileShape(num_src=40, num_dst=40, num_edges=120, feat_in=16,
                 feat_out=16)

STACKABLE = ["gcn", "gat", "sage", "ggnn", "rgcn"]


def _named_args(name, seed):
    spec = M.MODELS[name]
    return dict(zip(spec.arg_names, spec.example_args(TS, seed=seed)))


def _split(name, seed):
    """(graph_args, weight_args, x) for one layer at `seed`."""
    named = _named_args(name, seed)
    graph = {k: v for k, v in named.items() if k in M.GRAPH_ARG_NAMES}
    weights = {k: v for k, v in named.items()
               if k not in M.GRAPH_ARG_NAMES and k not in M.X_ARG_NAMES}
    return graph, weights, named["x_src"]


@pytest.mark.parametrize("name", STACKABLE)
@pytest.mark.parametrize("depth", [2, 3])
def test_stacked_matches_manual_chain(name, depth):
    graph, _, x = _split(name, seed=1)
    layer_weights = [_split(name, seed=10 + l)[1] for l in range(depth)]

    got = np.asarray(M.stacked(name, TS, layer_weights, graph, x))

    spec = M.MODELS[name]
    fn = spec.bind(TS)
    h = x
    for l, weights in enumerate(layer_weights):
        args = []
        for n in spec.arg_names:
            if n in M.X_ARG_NAMES:
                args.append(h)
            elif n in M.GRAPH_ARG_NAMES:
                args.append(graph[n])
            else:
                args.append(weights[n])
        h = fn(*args)
        if l + 1 < depth:
            h = ref.relu(h)  # hidden layers activated, final linear
    np.testing.assert_array_equal(got, np.asarray(h))
    assert got.shape == (TS.num_dst, TS.feat_out)
    assert np.isfinite(got).all()


def test_hidden_relu_applied_final_linear():
    # with ReLU disabled the chain must differ (hidden negatives survive)
    name = "gcn"
    graph, _, x = _split(name, seed=2)
    layer_weights = [_split(name, seed=20 + l)[1] for l in range(2)]
    relu = np.asarray(M.stacked(name, TS, layer_weights, graph, x))
    linear = np.asarray(M.stacked(name, TS, layer_weights, graph, x,
                                  activation=lambda h: h))
    assert not np.array_equal(relu, linear), \
        "fixture too weak: hidden ReLU clamped nothing"
    # the FINAL layer is linear: outputs may go negative
    assert (relu < 0).any()


def test_stacked_rejects_non_square_tiles():
    bad = M.TileShape(num_src=32, num_dst=16, num_edges=64, feat_in=8,
                      feat_out=8)
    with pytest.raises(ValueError, match="square"):
        M.stacked("gcn", bad, [], {}, None)
    bad = M.TileShape(num_src=32, num_dst=32, num_edges=64, feat_in=8,
                      feat_out=4)
    with pytest.raises(ValueError, match="square"):
        M.stacked("gcn", bad, [], {}, None)
