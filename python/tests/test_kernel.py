"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (and the int ranges of edge indices); every case
asserts allclose against `kernels.ref`. This is the core correctness
signal for the AOT artifacts the Rust runtime executes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import elw, gemm, ref, spmm

jax.config.update("jax_platform_name", "cpu")

ATOL = 2e-4
RTOL = 2e-4


def _rng(seed):
    return np.random.default_rng(seed)


def _tile(rng, s, d, e):
    src = rng.integers(0, s, size=e).astype(np.int32)
    dst = rng.integers(0, d, size=e).astype(np.int32)
    valid = (rng.random(e) < 0.8).astype(np.int32)
    # pad convention: invalid edges point at vertex 0
    src = np.where(valid == 1, src, 0).astype(np.int32)
    dst = np.where(valid == 1, dst, 0).astype(np.int32)
    return src, dst, valid


# ---------------------------------------------------------------------------
# GEMM
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 150),
    n=st.integers(1, 150),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_matches_ref(m, k, n, seed):
    rng = _rng(seed)
    x = rng.normal(size=(m, k)).astype(np.float32)
    w = rng.normal(size=(k, n)).astype(np.float32)
    got = gemm.gemm(jnp.asarray(x), jnp.asarray(w))
    want = ref.gemm(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(got, want, atol=ATOL * k, rtol=RTOL)


def test_gemm_exact_mu_shape():
    """(32, 128, 128): exactly one MU block, no padding waste."""
    rng = _rng(0)
    x = rng.normal(size=(32, 128)).astype(np.float32)
    w = rng.normal(size=(128, 128)).astype(np.float32)
    got = gemm.gemm(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(got, x @ w, atol=1e-2, rtol=1e-4)
    assert gemm.mxu_utilization(32, 128, 128) == 1.0


def test_gemm_bias():
    rng = _rng(1)
    x = rng.normal(size=(33, 60)).astype(np.float32)
    w = rng.normal(size=(60, 40)).astype(np.float32)
    b = rng.normal(size=(40,)).astype(np.float32)
    got = gemm.gemm_bias(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b))
    np.testing.assert_allclose(got, x @ w + b, atol=1e-2, rtol=1e-4)


def test_gemm_mxu_utilization_penalizes_padding():
    assert gemm.mxu_utilization(1, 1, 1) < 0.01
    assert gemm.mxu_utilization(64, 256, 256) == 1.0


def test_gemm_vmem_fits():
    """One program instance must fit comfortably in 16 MiB of VMEM."""
    assert gemm.vmem_bytes() < 16 * 2**20


# ---------------------------------------------------------------------------
# Scatter / Gather (GOP)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    s=st.integers(1, 64),
    d=st.integers(1, 64),
    e=st.integers(1, 256),
    f=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_scatter_matches_ref(s, d, e, f, seed):
    rng = _rng(seed)
    x = rng.normal(size=(s, f)).astype(np.float32)
    src, _, _ = _tile(rng, s, d, e)
    got = spmm.scatter(jnp.asarray(x), jnp.asarray(src))
    want = ref.scatter_src(jnp.asarray(x), jnp.asarray(src))
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(1, 48),
    e=st.integers(1, 200),
    f=st.integers(1, 140),
    seed=st.integers(0, 2**31 - 1),
)
def test_gather_sum_matches_ref(d, e, f, seed):
    rng = _rng(seed)
    feat = rng.normal(size=(e, f)).astype(np.float32)
    _, dst, valid = _tile(rng, 8, d, e)
    got = spmm.gather_sum(jnp.asarray(feat), jnp.asarray(dst),
                          jnp.asarray(valid), num_dst=d)
    want = ref.gather_sum(jnp.asarray(feat), jnp.asarray(dst),
                          jnp.asarray(valid), d)
    np.testing.assert_allclose(got, want, atol=ATOL * 4, rtol=RTOL)


@settings(max_examples=15, deadline=None)
@given(
    d=st.integers(1, 32),
    e=st.integers(1, 128),
    f=st.integers(1, 140),
    seed=st.integers(0, 2**31 - 1),
)
def test_gather_max_matches_ref(d, e, f, seed):
    rng = _rng(seed)
    feat = rng.normal(size=(e, f)).astype(np.float32)
    _, dst, valid = _tile(rng, 8, d, e)
    got = spmm.gather_max(jnp.asarray(feat), jnp.asarray(dst),
                          jnp.asarray(valid), num_dst=d)
    want = ref.gather_max(jnp.asarray(feat), jnp.asarray(dst),
                          jnp.asarray(valid), d)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


def test_gather_sum_all_invalid_is_zero():
    feat = np.ones((16, 8), np.float32)
    dst = np.zeros(16, np.int32)
    valid = np.zeros(16, np.int32)
    got = spmm.gather_sum(jnp.asarray(feat), jnp.asarray(dst),
                          jnp.asarray(valid), num_dst=4)
    np.testing.assert_array_equal(np.asarray(got), 0.0)


def test_gather_max_empty_segment_is_zero():
    feat = -np.ones((4, 8), np.float32)
    dst = np.zeros(4, np.int32)  # everything lands on dst 0
    valid = np.ones(4, np.int32)
    got = np.asarray(spmm.gather_max(jnp.asarray(feat), jnp.asarray(dst),
                                     jnp.asarray(valid), num_dst=3))
    np.testing.assert_array_equal(got[1:], 0.0)   # empty segments
    np.testing.assert_array_equal(got[0], -1.0)   # real max may be negative


def test_scatter_roundtrip_identity():
    """scatter with identity index returns the input."""
    x = np.arange(12, dtype=np.float32).reshape(3, 4)
    idx = np.arange(3, dtype=np.int32)
    got = spmm.scatter(jnp.asarray(x), jnp.asarray(idx))
    np.testing.assert_array_equal(np.asarray(got), x)


# ---------------------------------------------------------------------------
# ELW
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op", sorted(elw._UNARY))
@settings(max_examples=8, deadline=None)
@given(
    r=st.integers(1, 40),
    c=st.integers(1, 300),
    seed=st.integers(0, 2**31 - 1),
)
def test_unary_matches_numpy(op, r, c, seed):
    rng = _rng(seed)
    x = rng.normal(size=(r, c)).astype(np.float32)
    got = np.asarray(elw.unary(op, jnp.asarray(x)))
    want = np.asarray(elw._UNARY[op](jnp.asarray(x)))
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


@pytest.mark.parametrize("op", sorted(elw._BINARY))
@settings(max_examples=8, deadline=None)
@given(
    r=st.integers(1, 40),
    c=st.integers(1, 300),
    seed=st.integers(0, 2**31 - 1),
)
def test_binary_matches_numpy(op, r, c, seed):
    rng = _rng(seed)
    a = rng.normal(size=(r, c)).astype(np.float32)
    b = rng.normal(size=(r, c)).astype(np.float32) + 3.0  # avoid div-by-~0
    got = np.asarray(elw.binary(op, jnp.asarray(a), jnp.asarray(b)))
    want = np.asarray(elw._BINARY[op](jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


def test_gru_fuse_matches_unfused():
    rng = _rng(7)
    v, f = 40, 48
    zi = rng.normal(size=(v, f)).astype(np.float32)
    ci = rng.normal(size=(v, f)).astype(np.float32)
    x = rng.normal(size=(v, f)).astype(np.float32)
    got = np.asarray(elw.gru_fuse(jnp.asarray(zi), jnp.asarray(ci),
                                  jnp.asarray(x)))
    z = 1.0 / (1.0 + np.exp(-zi))
    want = (1.0 - z) * x + z * np.tanh(ci)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


def test_unary_preserves_shape_odd_sizes():
    for shape in [(1,), (1, 1), (7, 13), (2049,), (3, 5, 7)]:
        x = np.full(shape, -2.0, np.float32)
        got = np.asarray(elw.unary("relu", jnp.asarray(x)))
        assert got.shape == shape
        np.testing.assert_array_equal(got, 0.0)
