"""L2 correctness: tile-level model forwards vs the pure-jnp oracles.

Also checks the E2V-optimization invariant the paper's Fig 12 relies on:
the optimized and naive schedules produce identical numerics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

TS = M.TileShape(num_src=48, num_dst=40, num_edges=160, feat_in=24,
                 feat_out=36)
TS_SQ = M.TileShape(num_src=48, num_dst=40, num_edges=160, feat_in=24,
                    feat_out=24)  # GGNN needs feat_in == feat_out


def _args(name, ts):
    return M.MODELS[name].example_args(ts, seed=3)


def _run(name, ts):
    spec = M.MODELS[name]
    return np.asarray(spec.bind(ts)(*_args(name, ts)))


def test_gcn_matches_ref():
    x_src, src, dst, valid, w = _args("gcn", TS)
    got = _run("gcn", TS)
    want = np.asarray(ref.gcn_tile_e2v(x_src, src, dst, valid, w, TS.num_dst))
    np.testing.assert_allclose(got, want, atol=5e-3, rtol=1e-4)


def test_gcn_e2v_equals_naive():
    """E2V motion must be numerics-preserving (paper §6.2)."""
    got_opt = _run("gcn", TS)
    got_naive = _run("gcn_naive", TS)
    np.testing.assert_allclose(got_opt, got_naive, atol=5e-3, rtol=1e-4)


def test_gat_matches_ref():
    x_src, x_dst, src, dst, valid, w, a_src, a_dst = _args("gat", TS)
    got = _run("gat", TS)
    want = np.asarray(ref.gat_tile(x_src, x_dst, src, dst, valid, w,
                                   a_src, a_dst, TS.num_dst))
    np.testing.assert_allclose(got, want, atol=5e-3, rtol=1e-3)


def test_gat_e2v_equals_naive():
    got_opt = _run("gat", TS)
    got_naive = _run("gat_naive", TS)
    np.testing.assert_allclose(got_opt, got_naive, atol=5e-3, rtol=1e-3)


def test_sage_matches_ref():
    x_src, x_dst, src, dst, valid, w_pool, b_pool, w_self, w_neigh = \
        _args("sage", TS)
    got = _run("sage", TS)
    want = np.asarray(ref.sage_tile(x_src, x_dst, src, dst, valid, w_pool,
                                    b_pool, w_self, w_neigh, TS.num_dst))
    np.testing.assert_allclose(got, want, atol=5e-3, rtol=1e-3)


def test_sage_e2v_equals_naive():
    got_opt = _run("sage", TS)
    got_naive = _run("sage_naive", TS)
    np.testing.assert_allclose(got_opt, got_naive, atol=5e-3, rtol=1e-3)


def test_ggnn_matches_ref():
    args = _args("ggnn", TS_SQ)
    (x_src, x_dst, src, dst, valid, w_msg, w_z, u_z, w_r, u_r, w_h, u_h) = args
    got = _run("ggnn", TS_SQ)
    want = np.asarray(ref.ggnn_tile(x_src, x_dst, src, dst, valid, w_msg,
                                    w_z, u_z, w_r, u_r, w_h, u_h,
                                    TS_SQ.num_dst))
    np.testing.assert_allclose(got, want, atol=5e-3, rtol=1e-3)


def test_rgcn_matches_ref():
    x_src, src, dst, etype, valid, weights = _args("rgcn", TS)
    got = _run("rgcn", TS)
    want = np.asarray(ref.rgcn_tile(x_src, src, dst, etype, valid, weights,
                                    TS.num_dst))
    np.testing.assert_allclose(got, want, atol=5e-3, rtol=1e-3)


def test_rgcn_e2v_ref_equivalence():
    x_src, src, dst, etype, valid, weights = _args("rgcn", TS)
    a = np.asarray(ref.rgcn_tile(x_src, src, dst, etype, valid, weights,
                                 TS.num_dst))
    b = np.asarray(ref.rgcn_tile_e2v(x_src, src, dst, etype, valid, weights,
                                     TS.num_dst))
    np.testing.assert_allclose(a, b, atol=5e-3, rtol=1e-3)


@pytest.mark.parametrize("name", sorted(M.MODELS))
def test_output_shape(name):
    ts = TS_SQ if name == "ggnn" else TS
    got = _run(name, ts)
    assert got.shape == (ts.num_dst, ts.feat_out)
    assert np.isfinite(got).all()


def test_tile_shape_tag_roundtrip():
    ts = M.TileShape(1, 2, 3, 4, 5)
    assert ts.tag() == "s1_d2_e3_f4x5"
