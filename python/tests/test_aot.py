"""AOT pipeline tests: lowering produces parseable, entry-complete HLO text.

Executing the artifacts is the Rust runtime's job (rust/tests); here we
verify the text is well-formed, deterministic, and the manifest matches.
"""

from __future__ import annotations

import json

import pytest

from compile import aot
from compile import model as M

SMALL = M.TileShape(num_src=32, num_dst=32, num_edges=64, feat_in=16,
                    feat_out=16)


@pytest.mark.parametrize("name", ["gcn", "gat", "sage", "ggnn", "rgcn"])
def test_lower_model_produces_hlo_text(name):
    text, meta = aot.lower_model(name, SMALL)
    assert "ENTRY" in text and "ROOT" in text
    assert meta["model"] == name
    assert meta["output"]["shape"] == [SMALL.num_dst, SMALL.feat_out]
    # every declared arg appears as a parameter
    assert text.count("parameter(") >= len(meta["args"])


def test_lowering_is_deterministic():
    t1, m1 = aot.lower_model("gcn", SMALL)
    t2, m2 = aot.lower_model("gcn", SMALL)
    assert m1["sha256"] == m2["sha256"]
    assert t1 == t2


def test_main_writes_manifest(tmp_path):
    aot.main(["--out-dir", str(tmp_path), "--models", "gcn"])
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text"
    assert len(manifest["entries"]) == len(aot.DEFAULT_SHAPES)
    for e in manifest["entries"]:
        assert (tmp_path / e["file"]).exists()
        assert e["tile"]["feat_in"] > 0


def test_no_mosaic_custom_calls():
    """interpret=True must lower Pallas to plain HLO (CPU-executable)."""
    text, _ = aot.lower_model("gcn", SMALL)
    assert "tpu_custom_call" not in text
    assert "mosaic" not in text.lower()
