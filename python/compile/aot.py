"""AOT pipeline: lower every (model, tile-shape) to HLO *text* artifacts.

HLO text — NOT `HloModuleProto.serialize()` — is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
bundled xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids, so text round-trips cleanly
(see /opt/xla-example/README.md).

Outputs (under --out-dir, default ../artifacts):
  <model>__<shapetag>.hlo.txt   one per registry entry
  manifest.json                 shapes + argument order for the Rust runtime

`make artifacts` invokes this once at build time; Python never runs on the
request path.
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import pathlib
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(name: str, ts: M.TileShape) -> tuple[str, dict]:
    """Lower one registry model at one tile shape; returns (hlo, meta)."""
    spec = M.MODELS[name]
    fn = spec.bind(ts)
    args = spec.example_args(ts)
    lowered = jax.jit(lambda *a: (fn(*a),)).lower(*args)
    text = to_hlo_text(lowered)
    meta = {
        "model": name,
        "tile": {
            "num_src": ts.num_src,
            "num_dst": ts.num_dst,
            "num_edges": ts.num_edges,
            "feat_in": ts.feat_in,
            "feat_out": ts.feat_out,
        },
        "args": [
            {"name": n, "shape": list(a.shape), "dtype": str(a.dtype)}
            for n, a in zip(spec.arg_names, args)
        ],
        "output": {
            "shape": [ts.num_dst, ts.feat_out],
            "dtype": "float32",
        },
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
    }
    return text, meta


DEFAULT_SHAPES = [
    M.TileShape(num_src=256, num_dst=256, num_edges=1024, feat_in=128,
                feat_out=128),
    # A small shape for fast integration tests on the Rust side.
    M.TileShape(num_src=64, num_dst=64, num_edges=256, feat_in=32,
                feat_out=32),
]


def chain_shapes(base: M.TileShape, layers: int,
                 hidden: list[int]) -> list[M.TileShape]:
    """Tile shapes for every layer of a stacked pipeline at `base`.

    Mirrors the Rust ``ModelSpec`` width resolution: the chain is
    ``feat_in -> hidden... -> feat_out`` with hidden defaulting to
    ``feat_out`` repeated ``layers - 1`` times. One artifact per distinct
    (in, out) pair is enough — the Rust runtime re-executes the same
    artifact per layer with that layer's weights.
    """
    if layers <= 1:
        if hidden:
            # mirror the Rust ModelSpec rule: a depth-1 pipeline takes
            # no hidden widths (silently dropping them would desync the
            # artifact set from the runtime's validation)
            raise SystemExit(
                f"--hidden lists {len(hidden)} widths but --layers {layers} "
                f"needs exactly 0")
        return [base]
    hs = hidden or [base.feat_out] * (layers - 1)
    if len(hs) != layers - 1:
        raise SystemExit(
            f"--hidden lists {len(hs)} widths but --layers {layers} needs "
            f"exactly {layers - 1}")
    widths = [base.feat_in, *hs, base.feat_out]
    return [dataclasses.replace(base, feat_in=fi, feat_out=fo)
            for fi, fo in zip(widths, widths[1:])]


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default=None,
                   help="artifact directory (default <repo>/artifacts)")
    p.add_argument("--out", default=None,
                   help="also write the gcn/default-shape HLO to this path "
                        "(Makefile stamp file)")
    p.add_argument("--models", nargs="*", default=sorted(M.MODELS),
                   help="subset of models to lower")
    p.add_argument("--layers", type=int, default=1,
                   help="pipeline depth: also lower artifacts for every "
                        "layer's (in, out) dims of the stacked chain (the "
                        "Rust side chains one artifact execution per layer, "
                        "ReLU between hidden layers, final layer linear)")
    p.add_argument("--hidden", default="",
                   help="comma-separated hidden widths (layers-1 entries; "
                        "default: feat_out repeated)")
    args = p.parse_args(argv)
    hidden = [int(h) for h in args.hidden.split(",") if h.strip()]

    repo = pathlib.Path(__file__).resolve().parents[2]
    out_dir = pathlib.Path(args.out_dir) if args.out_dir else repo / "artifacts"
    out_dir.mkdir(parents=True, exist_ok=True)

    manifest = {"format": "hlo-text", "entries": []}
    if args.layers > 1:
        # stacking recipe for consumers: mirrors rust models::ModelSpec
        manifest["pipeline"] = {
            "layers": args.layers,
            "hidden": hidden or None,
            "activation": "relu",
            "final": "linear",
            "note": "execute one artifact per layer with that layer's "
                    "weights; layer l output (original vertex order) is "
                    "layer l+1 input, ReLU between hidden layers",
        }
    for name in args.models:
        seen: set[str] = set()
        for base in DEFAULT_SHAPES:
            for ts in chain_shapes(base, args.layers, hidden):
                if ts.tag() in seen:
                    continue  # uniform chains reuse one artifact per layer
                seen.add(ts.tag())
                text, meta = lower_model(name, ts)
                fname = f"{name}__{ts.tag()}.hlo.txt"
                (out_dir / fname).write_text(text)
                meta["file"] = fname
                manifest["entries"].append(meta)
                print(f"  {fname}: {len(text)} chars", file=sys.stderr)

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {len(manifest['entries'])} artifacts to {out_dir}",
          file=sys.stderr)

    if args.out:
        # Makefile stamp: the default-shape GCN module.
        stamp = out_dir / f"gcn__{DEFAULT_SHAPES[0].tag()}.hlo.txt"
        pathlib.Path(args.out).write_text(stamp.read_text())


if __name__ == "__main__":
    main()
