"""ZIPPER L1 Pallas kernels (build-time only; lowered AOT into HLO text).

Modules:
  gemm — MU-tiled matmul (32×128 output-stationary blocks)
  spmm — GOP scatter / gather(sum|max) over tile COO edge lists
  elw  — VU-striped element-wise ops and fused chains
  ref  — pure-jnp oracles for all of the above
"""

from . import elw, gemm, ref, spmm  # noqa: F401
