"""L1 Pallas kernel: MU-tiled GEMM.

This is the software analog of ZIPPER's Matrix Unit — a 32×128
output-stationary systolic array (paper §7.1, Table 4). The Pallas grid
iterates over (M/32, N/128, K/K_BLK) output tiles; each program instance
accumulates one 32×128 output block, mirroring the MU's output-stationary
dataflow where the partial sum stays resident while inputs stream through.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the (32, 128) block is
both the paper's MU shape and a multiple of the TPU f32 tile (8, 128), so
the same BlockSpec targets the MXU on real hardware. Here kernels run under
`interpret=True` (CPU PJRT cannot execute Mosaic custom-calls); structure,
not wallclock, is the TPU-perf claim.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The MU geometry from paper Table 4: one 32×128 systolic array.
MU_ROWS = 32
MU_COLS = 128
K_BLOCK = 128


def _gemm_kernel(x_ref, w_ref, o_ref):
    """One (32, 128) output-stationary block, accumulated over the K axis.

    The out BlockSpec maps (i, j) independent of k, so `o_ref` stays
    resident across the (fastest-varying) k grid axis — the Pallas
    expression of the MU's output-stationary dataflow.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(x: jnp.ndarray, m: int, axis: int) -> jnp.ndarray:
    rem = x.shape[axis] % m
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, m - rem)
    return jnp.pad(x, pad)


def gemm(x: jnp.ndarray, w: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """Tiled matmul `x @ w` through the MU-shaped Pallas kernel.

    Arbitrary (M, K) × (K, N) f32; inputs are zero-padded up to the MU
    block geometry and the result is sliced back.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    xp = _pad_to(_pad_to(x, MU_ROWS, 0), K_BLOCK, 1)
    wp = _pad_to(_pad_to(w, K_BLOCK, 0), MU_COLS, 1)
    mp, kp = xp.shape
    _, np_ = wp.shape
    grid = (mp // MU_ROWS, np_ // MU_COLS, kp // K_BLOCK)

    out = pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((MU_ROWS, K_BLOCK), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((K_BLOCK, MU_COLS), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((MU_ROWS, MU_COLS), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]


def gemm_bias(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
              interpret: bool = True) -> jnp.ndarray:
    """GEMM followed by a broadcast bias add (fused on the MU output side)."""
    return gemm(x, w, interpret=interpret) + b[None, :]


def vmem_bytes() -> int:
    """Static VMEM footprint estimate of one program instance (DESIGN.md §7).

    x block + w block + resident output block, f32.
    """
    return 4 * (MU_ROWS * K_BLOCK + K_BLOCK * MU_COLS + MU_ROWS * MU_COLS)


def mxu_utilization(m: int, k: int, n: int) -> float:
    """Fraction of MXU-issued MACs that are useful (non-padding) work."""
    mp = math.ceil(m / MU_ROWS) * MU_ROWS
    kp = math.ceil(k / K_BLOCK) * K_BLOCK
    np_ = math.ceil(n / MU_COLS) * MU_COLS
    return (m * k * n) / (mp * kp * np_)
