"""L1 Pallas kernels: fused element-wise (ELW) blocks.

Software analog of ZIPPER's Vector Unit running ELW instructions (paper
Table 2: ADD, SUB, MUL, DIV, EXP, RELU). The VU is 8 × SIMD32 = 256 lanes;
we block the flattened element stream into (8, 256)-element stripes so one
program instance corresponds to one VU issue group.

GNN models interleave many small ELWs (paper §2); fusing chains of them
into a single kernel is the L1-side counterpart of ZIPPER's operator-level
pipelining — one VMEM round-trip instead of one per op.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 8 SIMD32 cores × 32 lanes = 256 lanes per VU; stripe 8 rows deep.
LANES = 256
ROWS = 8
BLOCK = ROWS * LANES

_UNARY = {
    "relu": lambda x: jnp.maximum(x, 0.0),
    "exp": jnp.exp,
    "sigmoid": lambda x: 1.0 / (1.0 + jnp.exp(-x)),
    "tanh": jnp.tanh,
    "leaky_relu": lambda x: jnp.where(x >= 0.0, x, 0.2 * x),
    "neg": lambda x: -x,
}

_BINARY = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b,
    "div": lambda a, b: a / b,
    "max": jnp.maximum,
}


def _unary_kernel(x_ref, o_ref, *, op: str):
    o_ref[...] = _UNARY[op](x_ref[...])


def _binary_kernel(a_ref, b_ref, o_ref, *, op: str):
    o_ref[...] = _BINARY[op](a_ref[...], b_ref[...])


def _blocked(x: jnp.ndarray) -> tuple[jnp.ndarray, int]:
    """Flatten to (n_blocks * ROWS, LANES), zero-padded."""
    n = x.size
    nblk = -(-n // BLOCK)
    flat = jnp.pad(x.reshape(-1), (0, nblk * BLOCK - n))
    return flat.reshape(nblk * ROWS, LANES), n


def unary(op: str, x: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """Apply a unary ELW op through the VU-striped Pallas kernel."""
    xb, n = _blocked(x)
    grid = (xb.shape[0] // ROWS,)
    out = pl.pallas_call(
        functools.partial(_unary_kernel, op=op),
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xb.shape, x.dtype),
        interpret=interpret,
    )(xb)
    return out.reshape(-1)[:n].reshape(x.shape)


def binary(op: str, a: jnp.ndarray, b: jnp.ndarray,
           interpret: bool = True) -> jnp.ndarray:
    """Apply a binary ELW op (same-shape operands) through the VU kernel."""
    assert a.shape == b.shape, (a.shape, b.shape)
    ab, n = _blocked(a)
    bb, _ = _blocked(b)
    grid = (ab.shape[0] // ROWS,)
    out = pl.pallas_call(
        functools.partial(_binary_kernel, op=op),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(ab.shape, a.dtype),
        interpret=interpret,
    )(ab, bb)
    return out.reshape(-1)[:n].reshape(a.shape)


# ---------------------------------------------------------------------------
# Fused GRU tail (GGNN hot ELW chain): one kernel, one VMEM round-trip
# ---------------------------------------------------------------------------

def _gru_fuse_kernel(zi_ref, ci_ref, x_ref, o_ref):
    """Fused GRU output stage given the GEMM partial products.

    zi = aW_z + xU_z (pre-sigmoid update gate), ci = aW_h + (r⊙x)U_h
    (pre-tanh candidate; the r gate is applied upstream because it feeds a
    GEMM). out = (1−σ(zi)) ⊙ x + σ(zi) ⊙ tanh(ci). Naively this is five
    VU instructions with four intermediate VMEM round-trips; fused it is
    one (paper §6.2's operator-fusion optimization at the kernel level).
    """
    z = 1.0 / (1.0 + jnp.exp(-zi_ref[...]))
    h_t = jnp.tanh(ci_ref[...])
    x = x_ref[...]
    o_ref[...] = (1.0 - z) * x + z * h_t


def gru_fuse(zi, ci, x, interpret: bool = True):
    """Fused GRU output stage over (V, F) operands. All shapes identical."""
    assert zi.shape == ci.shape == x.shape
    v, f = zi.shape
    blocks = [_blocked(t)[0] for t in (zi, ci, x)]
    n = zi.size
    grid = (blocks[0].shape[0] // ROWS,)
    out = pl.pallas_call(
        _gru_fuse_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS, LANES), lambda i: (i, 0))] * 3,
        out_specs=pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(blocks[0].shape, zi.dtype),
        interpret=interpret,
    )(*blocks)
    return out.reshape(-1)[:n].reshape(v, f)


def vmem_bytes() -> int:
    """Static VMEM footprint of one ELW program instance."""
    return 4 * 3 * BLOCK
