"""Pure-jnp reference oracles for the ZIPPER Pallas kernels.

Every Pallas kernel in this package has an exact pure-`jax.numpy`
counterpart here. pytest asserts `assert_allclose(kernel(...), ref(...))`
over hypothesis-driven shape/dtype sweeps — this is the core L1
correctness signal (the role DGL played for the paper's simulator
validation).

Conventions (shared with the Rust functional simulator):
  * A *tile* is a (source-partition, destination-partition) rectangle of
    the adjacency matrix (paper §5.1, grid tiling).
  * Tile edges are COO `(src, dst)` index vectors, padded to a static
    length `E` with `src = dst = 0` and a `valid` 0/1 mask (static shapes
    are required for AOT lowering; the pad convention matches
    `tiling::TileData` on the Rust side).
  * Embeddings are row-major `(vertices, F)` f32.
"""

from __future__ import annotations

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# GEMM / ELW primitives (paper Table 1 "Computational")
# ---------------------------------------------------------------------------

def gemm(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Plain dense matmul — oracle for the MU-tiled Pallas GEMM."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def gemm_bias(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return gemm(x, w) + b[None, :]


def relu(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.maximum(x, 0.0)


def leaky_relu(x: jnp.ndarray, slope: float = 0.2) -> jnp.ndarray:
    return jnp.where(x >= 0.0, x, slope * x)


def elw_add(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return a + b


def elw_mul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return a * b


def sigmoid(x: jnp.ndarray) -> jnp.ndarray:
    return 1.0 / (1.0 + jnp.exp(-x))


# ---------------------------------------------------------------------------
# GOP primitives (paper Table 1 "Communicational")
# ---------------------------------------------------------------------------

def scatter_src(x_src: jnp.ndarray, src: jnp.ndarray) -> jnp.ndarray:
    """SCTR.OUTE — distribute source-vertex embeddings onto tile edges.

    x_src: (S, F) source-partition embeddings; src: (E,) int32.
    Returns (E, F) per-edge features.
    """
    return x_src[src]


def scatter_dst(x_dst: jnp.ndarray, dst: jnp.ndarray) -> jnp.ndarray:
    """SCTR.INE — distribute destination-vertex embeddings onto tile edges."""
    return x_dst[dst]


def gather_sum(
    edge_feat: jnp.ndarray, dst: jnp.ndarray, valid: jnp.ndarray, num_dst: int
) -> jnp.ndarray:
    """GTHR.DST.SUM — segment-sum per-edge features into destination rows.

    edge_feat: (E, F); dst: (E,) int32; valid: (E,) {0,1}; → (num_dst, F).
    """
    maskf = valid[:, None].astype(edge_feat.dtype)
    sel = (dst[:, None] == jnp.arange(num_dst)[None, :]).astype(edge_feat.dtype)
    sel = sel * maskf
    return sel.T @ (edge_feat * maskf)


def gather_max(
    edge_feat: jnp.ndarray, dst: jnp.ndarray, valid: jnp.ndarray, num_dst: int
) -> jnp.ndarray:
    """GTHR.DST.MAX — segment-max (SAGE maxpool). Empty segments yield 0."""
    neg = jnp.asarray(-3.0e38, edge_feat.dtype)
    # (E, D) membership mask
    member = (dst[:, None] == jnp.arange(num_dst)[None, :]) & (valid[:, None] != 0)
    # (E, D, F) via broadcasting — acceptable for an oracle.
    expanded = jnp.where(member[:, :, None], edge_feat[:, None, :], neg)
    out = jnp.max(expanded, axis=0)
    has_any = member.any(axis=0)
    return jnp.where(has_any[:, None], out, 0.0)


def segment_softmax(
    scores: jnp.ndarray, dst: jnp.ndarray, valid: jnp.ndarray, num_dst: int
) -> jnp.ndarray:
    """Per-destination softmax over edge scores (GAT attention).

    scores: (E,), returns (E,) normalized weights; invalid edges → 0.
    """
    neg = jnp.asarray(-3.0e38, scores.dtype)
    member = (dst[:, None] == jnp.arange(num_dst)[None, :]) & (valid[:, None] != 0)
    per_dst = jnp.where(member, scores[:, None], neg)  # (E, D)
    seg_max = jnp.max(per_dst, axis=0)  # (D,)
    # Clamp empty destinations to 0 so invalid edges (which may point at
    # them under the pad convention) don't produce inf·0 = NaN below.
    seg_max = jnp.where(member.any(axis=0), seg_max, 0.0)
    shifted = scores - seg_max[dst]
    expv = jnp.exp(shifted) * valid.astype(scores.dtype)
    seg_sum = gather_sum(expv[:, None], dst, valid, num_dst)[:, 0]  # (D,)
    denom = jnp.maximum(seg_sum, 1e-30)
    return expv / denom[dst]


# ---------------------------------------------------------------------------
# Whole-tile GNN layers (oracles for model.py / the Rust functional sim)
# ---------------------------------------------------------------------------

def gcn_tile(x_src, src, dst, valid, w, num_dst: int):
    """GCN layer on one tile: Scatter → Gather(sum) → GEMM (paper Fig 1a)."""
    edge = scatter_src(x_src, src)
    agg = gather_sum(edge, dst, valid, num_dst)
    return gemm(agg, w)


def gcn_tile_e2v(x_src, src, dst, valid, w, num_dst: int):
    """GCN with the E2V optimization applied: GEMM on source vertices first."""
    h = gemm(x_src, w)
    edge = scatter_src(h, src)
    return gather_sum(edge, dst, valid, num_dst)


def gat_tile(x_src, x_dst, src, dst, valid, w, a_src, a_dst, num_dst: int,
             slope: float = 0.2):
    """Single-head GAT layer on one tile (paper Fig 1b).

    z = x W; e_ij = LeakyReLU(a_srcᵀ z_i + a_dstᵀ z_j);
    α = segment-softmax(e); out_j = Σ α_ij z_i.
    """
    z_src = gemm(x_src, w)              # (S, F')
    z_dst = gemm(x_dst, w)              # (D, F')
    s_src = z_src @ a_src               # (S,)
    s_dst = z_dst @ a_dst               # (D,)
    e = leaky_relu(s_src[src] + s_dst[dst], slope)   # (E,)
    alpha = segment_softmax(e, dst, valid, num_dst)  # (E,)
    edge = scatter_src(z_src, src) * alpha[:, None]
    return gather_sum(edge, dst, valid, num_dst)


def sage_tile(x_src, x_dst, src, dst, valid, w_pool, b_pool, w_self, w_neigh,
              num_dst: int):
    """GraphSAGE-maxpool layer on one tile.

    h_N(v) = max_{u∈N(v)} ReLU(x_u W_pool + b_pool);
    out_v  = x_v W_self + h_N(v) W_neigh   (concat folded into two GEMMs).
    """
    pooled = relu(gemm_bias(x_src, w_pool, b_pool))
    edge = scatter_src(pooled, src)
    h_n = gather_max(edge, dst, valid, num_dst)
    return gemm(x_dst, w_self) + gemm(h_n, w_neigh)


def ggnn_tile(x_src, x_dst, src, dst, valid, w_msg, w_z, u_z, w_r, u_r,
              w_h, u_h, num_dst: int):
    """GGNN layer on one tile: message = gather(x W_msg); GRU(x_dst, message).

    GRU decomposed into explicit GEMM + ELW ops (paper §8.1: "We implement
    the GRU with separate ELWs and GEMMs on ZIPPER").
    """
    msg_src = gemm(x_src, w_msg)
    edge = scatter_src(msg_src, src)
    a = gather_sum(edge, dst, valid, num_dst)        # (D, F)
    z = sigmoid(gemm(a, w_z) + gemm(x_dst, u_z))
    r = sigmoid(gemm(a, w_r) + gemm(x_dst, u_r))
    h_tilde = jnp.tanh(gemm(a, w_h) + gemm(r * x_dst, u_h))
    return (1.0 - z) * x_dst + z * h_tilde


def rgcn_tile(x_src, src, dst, etype, valid, weights, num_dst: int):
    """R-GCN layer on one tile: per-edge-type weights, type-guided BMM.

    weights: (R, F, F'); etype: (E,) int32 in [0, R).
    out_j = Σ_{(i→j) of type r} x_i W_r
    """
    edge_x = scatter_src(x_src, src)                 # (E, F)
    # index-guided batched matmul (paper ISA "BMM")
    w_per_edge = weights[etype]                      # (E, F, F')
    edge = jnp.einsum("ef,efg->eg", edge_x, w_per_edge)
    return gather_sum(edge, dst, valid, num_dst)


def rgcn_tile_e2v(x_src, src, dst, etype, valid, weights, num_dst: int):
    """R-GCN with per-relation source transform hoisted (E2V variant)."""
    # (R, S, F') — transform every source vertex under every relation, then
    # pick per edge. Equivalent numerics; trades FLOPs for regular GEMMs.
    h_all = jnp.einsum("sf,rfg->rsg", x_src, weights)
    edge = h_all[etype, src]
    return gather_sum(edge, dst, valid, num_dst)
