"""L1 Pallas kernels: GOP (Scatter / Gather) over one graph tile.

These are the software analog of ZIPPER's Vector Unit executing GOP
instructions (paper §7.1): each SIMD core scatters or gathers one vertex
at a time, guided by the tile's COO edge list held in the Tile Hub.

TPU adaptation (DESIGN.md §Hardware-Adaptation): TPUs have no native
scatter-add, so Gather(sum) is expressed as a one-hot selection matmul —
`onehotᵀ(dst) @ edge_feats` — which runs on the MXU. This is exactly the
hardware insight inverted: the paper routes GOPs to SIMD lanes because its
MU is busy with GEMMs; on a TPU the MXU *is* the efficient reduction
engine, so the selection matmul is the idiomatic mapping. The F dimension
is blocked at 128 lanes so each program instance works on one (E, 128)
stripe of edge features resident in VMEM.

Edge lists are padded to a static length with a 0/1 `valid` mask
(convention shared with `ref.py` and the Rust `tiling::TileData`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VPU lane width: one stripe of the embedding dimension per program.
F_BLOCK = 128


def _pad_f(x: jnp.ndarray) -> tuple[jnp.ndarray, int]:
    f = x.shape[1]
    rem = f % F_BLOCK
    if rem:
        x = jnp.pad(x, ((0, 0), (0, F_BLOCK - rem)))
    return x, f


# ---------------------------------------------------------------------------
# Scatter: vertex → edge (SCTR.OUTE / SCTR.INE)
# ---------------------------------------------------------------------------

def _scatter_kernel(x_ref, idx_ref, o_ref):
    # One (S, F_BLOCK) stripe of vertex features; gather rows by edge index.
    o_ref[...] = x_ref[...][idx_ref[...]]


def scatter(x: jnp.ndarray, idx: jnp.ndarray, interpret: bool = True
            ) -> jnp.ndarray:
    """Distribute vertex embeddings onto edges: `out[e] = x[idx[e]]`.

    x: (V, F) f32; idx: (E,) int32 → (E, F) f32.
    """
    xp, f = _pad_f(x)
    e = idx.shape[0]
    grid = (xp.shape[1] // F_BLOCK,)
    out = pl.pallas_call(
        _scatter_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((xp.shape[0], F_BLOCK), lambda j: (0, j)),
            pl.BlockSpec((e,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((e, F_BLOCK), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((e, xp.shape[1]), jnp.float32),
        interpret=interpret,
    )(xp, idx)
    return out[:, :f]


# ---------------------------------------------------------------------------
# Gather(sum): edge → vertex (GTHR.DST.SUM) as a one-hot MXU matmul
# ---------------------------------------------------------------------------

def _gather_sum_kernel(edge_ref, dst_ref, valid_ref, o_ref, *, num_dst: int):
    edge = edge_ref[...]                      # (E, F_BLOCK)
    dst = dst_ref[...]                        # (E,)
    maskf = valid_ref[...].astype(edge.dtype)[:, None]
    sel = (dst[:, None] == jnp.arange(num_dst)[None, :]).astype(edge.dtype)
    sel = sel * maskf                         # (E, D) one-hot selection
    o_ref[...] = jax.lax.dot_general(
        sel, edge * maskf,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def gather_sum(edge_feat: jnp.ndarray, dst: jnp.ndarray, valid: jnp.ndarray,
               num_dst: int, interpret: bool = True) -> jnp.ndarray:
    """Segment-sum per-edge features into destination rows via one-hot matmul.

    edge_feat: (E, F); dst, valid: (E,) → (num_dst, F).
    """
    ep, f = _pad_f(edge_feat)
    e = ep.shape[0]
    grid = (ep.shape[1] // F_BLOCK,)
    out = pl.pallas_call(
        functools.partial(_gather_sum_kernel, num_dst=num_dst),
        grid=grid,
        in_specs=[
            pl.BlockSpec((e, F_BLOCK), lambda j: (0, j)),
            pl.BlockSpec((e,), lambda j: (0,)),
            pl.BlockSpec((e,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((num_dst, F_BLOCK), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((num_dst, ep.shape[1]), jnp.float32),
        interpret=interpret,
    )(ep, dst, valid)
    return out[:, :f]


# ---------------------------------------------------------------------------
# Gather(max): edge → vertex (GTHR.DST.MAX), SAGE maxpool
# ---------------------------------------------------------------------------

def _gather_max_kernel(edge_ref, dst_ref, valid_ref, o_ref, *, num_dst: int):
    edge = edge_ref[...]                      # (E, F_BLOCK)
    dst = dst_ref[...]
    valid = valid_ref[...]
    neg = jnp.asarray(-3.0e38, edge.dtype)

    def body(d, out):
        member = (dst == d) & (valid != 0)    # (E,)
        col = jnp.where(member[:, None], edge, neg)
        mx = jnp.max(col, axis=0)
        mx = jnp.where(member.any(), mx, 0.0)
        return out.at[d].set(mx)

    o_ref[...] = jax.lax.fori_loop(
        0, num_dst, body, jnp.zeros_like(o_ref)
    )


def gather_max(edge_feat: jnp.ndarray, dst: jnp.ndarray, valid: jnp.ndarray,
               num_dst: int, interpret: bool = True) -> jnp.ndarray:
    """Segment-max per-edge features into destination rows.

    Each loop iteration plays one VU SIMD core reducing one destination
    vertex (paper §7.1: "each core is responsible for ... one vertex in
    the tile at a time"). Empty segments yield 0.
    """
    ep, f = _pad_f(edge_feat)
    e = ep.shape[0]
    grid = (ep.shape[1] // F_BLOCK,)
    out = pl.pallas_call(
        functools.partial(_gather_max_kernel, num_dst=num_dst),
        grid=grid,
        in_specs=[
            pl.BlockSpec((e, F_BLOCK), lambda j: (0, j)),
            pl.BlockSpec((e,), lambda j: (0,)),
            pl.BlockSpec((e,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((num_dst, F_BLOCK), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((num_dst, ep.shape[1]), jnp.float32),
        interpret=interpret,
    )(ep, dst, valid)
    return out[:, :f]


def vmem_bytes(e: int, num_dst: int) -> int:
    """Static VMEM footprint of one gather program instance (DESIGN.md §7)."""
    return 4 * (e * F_BLOCK + 2 * e + e * num_dst + num_dst * F_BLOCK)
