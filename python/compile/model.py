"""L2: tile-level GNN model forward passes in JAX, calling the L1 kernels.

One function per (model, variant). Each takes a *tile context* — the
source-partition embeddings, destination-partition embeddings, the tile's
padded COO edge list, and the model weights — and returns the tile's
contribution to the destination partition, exactly the unit of work one
ZIPPER stream triple (sStream → eStream → dStream) processes.

These functions are:
  * the AOT lowering targets (`aot.py` lowers each to HLO text; the Rust
    runtime executes them via PJRT as the numerical oracle for the
    cycle-level simulator's functional mode), and
  * validated against `kernels.ref` by pytest.

All shapes are static (AOT requirement): a tile context is (S, D, E, F)
= (#source vertices, #destination vertices, padded edge count, embedding
width). Padded edges have src = dst = 0 and valid = 0.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import elw, gemm, spmm


@dataclasses.dataclass(frozen=True)
class TileShape:
    """Static tile geometry: the AOT specialization key."""

    num_src: int = 256
    num_dst: int = 256
    num_edges: int = 1024
    feat_in: int = 128
    feat_out: int = 128

    def tag(self) -> str:
        return (f"s{self.num_src}_d{self.num_dst}_e{self.num_edges}"
                f"_f{self.feat_in}x{self.feat_out}")


# Number of relations for R-GCN (paper §8.1: "We set the type number to 3").
NUM_RELATIONS = 3


# ---------------------------------------------------------------------------
# Model forward passes (per tile)
# ---------------------------------------------------------------------------

def gcn_e2v(x_src, src, dst, valid, w, *, num_dst: int):
    """GCN with E2V applied: GEMM on source vertices, then Scatter→Gather.

    The paper-Fig-1a order (Scatter→Gather→GEMM) is `gcn_naive`; both are
    lowered so the Fig 12 compiler-opt experiment can execute either
    schedule.
    """
    h = gemm.gemm(x_src, w)
    edge = spmm.scatter(h, src)
    return spmm.gather_sum(edge, dst, valid, num_dst=num_dst)


def gcn_naive(x_src, src, dst, valid, w, *, num_dst: int):
    edge = spmm.scatter(x_src, src)
    agg = spmm.gather_sum(edge, dst, valid, num_dst=num_dst)
    return gemm.gemm(agg, w)


def gat(x_src, x_dst, src, dst, valid, w, a_src, a_dst, *, num_dst: int):
    """Single-head GAT (paper Fig 1b), E2V-optimized: z = xW on vertices."""
    z_src = gemm.gemm(x_src, w)
    z_dst = gemm.gemm(x_dst, w)
    s_src = gemm.gemm(z_src, a_src[:, None])[:, 0]
    s_dst = gemm.gemm(z_dst, a_dst[:, None])[:, 0]
    e = elw.unary("leaky_relu",
                  elw.binary("add", s_src[src], s_dst[dst]))
    # segment softmax over destinations (GOP + ELW mix)
    from .kernels import ref
    alpha = ref.segment_softmax(e, dst, valid, num_dst)
    edge = spmm.scatter(z_src, src) * alpha[:, None]
    return spmm.gather_sum(edge, dst, valid, num_dst=num_dst)


def gat_naive(x_src, x_dst, src, dst, valid, w, a_src, a_dst, *,
              num_dst: int):
    """GAT without E2V: the xW GEMM is applied per *edge* after scatter.

    This is the straightforward DGL-style formulation the paper's Fig 12
    compares against — same numerics, redundant per-edge GEMMs.
    """
    from .kernels import ref
    edge_x_src = spmm.scatter(x_src, src)                 # (E, F)
    z_edge_src = gemm.gemm(edge_x_src, w)                 # redundant per-edge
    edge_x_dst = spmm.scatter(x_dst, dst)
    z_edge_dst = gemm.gemm(edge_x_dst, w)
    s_src = gemm.gemm(z_edge_src, a_src[:, None])[:, 0]
    s_dst = gemm.gemm(z_edge_dst, a_dst[:, None])[:, 0]
    e = elw.unary("leaky_relu", elw.binary("add", s_src, s_dst))
    alpha = ref.segment_softmax(e, dst, valid, num_dst)
    edge = z_edge_src * alpha[:, None]
    return spmm.gather_sum(edge, dst, valid, num_dst=num_dst)


def sage(x_src, x_dst, src, dst, valid, w_pool, b_pool, w_self, w_neigh, *,
         num_dst: int):
    """GraphSAGE-maxpool (paper §8.1), E2V-optimized: pool GEMM on vertices."""
    pooled = elw.unary("relu", gemm.gemm_bias(x_src, w_pool, b_pool))
    edge = spmm.scatter(pooled, src)
    h_n = spmm.gather_max(edge, dst, valid, num_dst=num_dst)
    return elw.binary("add", gemm.gemm(x_dst, w_self),
                      gemm.gemm(h_n, w_neigh))


def sage_naive(x_src, x_dst, src, dst, valid, w_pool, b_pool, w_self,
               w_neigh, *, num_dst: int):
    """SAGE without E2V: pool transform applied per edge after scatter."""
    edge_x = spmm.scatter(x_src, src)
    pooled = elw.unary("relu", gemm.gemm_bias(edge_x, w_pool, b_pool))
    h_n = spmm.gather_max(pooled, dst, valid, num_dst=num_dst)
    return elw.binary("add", gemm.gemm(x_dst, w_self),
                      gemm.gemm(h_n, w_neigh))


def ggnn(x_src, x_dst, src, dst, valid, w_msg, w_z, u_z, w_r, u_r, w_h, u_h,
         *, num_dst: int):
    """GGNN: message GEMM + Gather(sum) + GRU as separate GEMM/ELW ops."""
    msg = gemm.gemm(x_src, w_msg)
    edge = spmm.scatter(msg, src)
    a = spmm.gather_sum(edge, dst, valid, num_dst=num_dst)
    zi = elw.binary("add", gemm.gemm(a, w_z), gemm.gemm(x_dst, u_z))
    ri = elw.binary("add", gemm.gemm(a, w_r), gemm.gemm(x_dst, u_r))
    r = elw.unary("sigmoid", ri)
    ci = elw.binary("add", gemm.gemm(a, w_h),
                    gemm.gemm(elw.binary("mul", r, x_dst), u_h))
    return elw.gru_fuse(zi, ci, x_dst)


def rgcn(x_src, src, dst, etype, valid, weights, *, num_dst: int):
    """R-GCN with 3 relation types; per-relation GEMM + masked gather.

    The index-guided BMM (paper ISA) is realized as R dense GEMMs over the
    source partition plus relation-masked gathers — the E2V-hoisted form
    (regular MXU work instead of per-edge matmuls).
    """
    out = None
    for r in range(NUM_RELATIONS):
        h_r = gemm.gemm(x_src, weights[r])
        edge = spmm.scatter(h_r, src)
        mask_r = valid * (etype == r).astype(valid.dtype)
        part = spmm.gather_sum(edge, dst, mask_r, num_dst=num_dst)
        out = part if out is None else elw.binary("add", out, part)
    return out


# ---------------------------------------------------------------------------
# Registry: model name → (builder, weight synthesizer)
# ---------------------------------------------------------------------------

def _rng_weights(key, shapes):
    ks = jax.random.split(key, len(shapes))
    return [jax.random.normal(k, s, jnp.float32) * 0.1 for k, s in zip(ks, shapes)]


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """A lowering target: closed-over-tile-shape callable + example args."""

    name: str
    fn: Callable
    arg_names: tuple[str, ...]

    def example_args(self, ts: TileShape, seed: int = 0):
        """Concrete example arrays for `jax.jit(...).lower(...)`."""
        key = jax.random.PRNGKey(seed)
        kx, kd, kw, ke = jax.random.split(key, 4)
        fi, fo = ts.feat_in, ts.feat_out
        x_src = jax.random.normal(kx, (ts.num_src, fi), jnp.float32)
        x_dst = jax.random.normal(kd, (ts.num_dst, fi), jnp.float32)
        src = jax.random.randint(ke, (ts.num_edges,), 0, ts.num_src, jnp.int32)
        dst = jax.random.randint(kd, (ts.num_edges,), 0, ts.num_dst, jnp.int32)
        valid = (jnp.arange(ts.num_edges) < (ts.num_edges * 3) // 4).astype(jnp.int32)
        etype = jax.random.randint(kw, (ts.num_edges,), 0, NUM_RELATIONS, jnp.int32)
        pool = {
            "x_src": x_src, "x_dst": x_dst, "src": src, "dst": dst,
            "valid": valid, "etype": etype,
            "w": _rng_weights(kw, [(fi, fo)])[0],
            "a_src": jax.random.normal(kw, (fo,), jnp.float32) * 0.1,
            "a_dst": jax.random.normal(kd, (fo,), jnp.float32) * 0.1,
            "w_pool": _rng_weights(kw, [(fi, fo)])[0],
            "b_pool": jnp.zeros((fo,), jnp.float32),
            "w_self": _rng_weights(kd, [(fi, fo)])[0],
            "w_neigh": _rng_weights(ke, [(fo, fo)])[0],
            "w_msg": _rng_weights(kw, [(fi, fi)])[0],
            "w_z": _rng_weights(kw, [(fi, fi)])[0],
            "u_z": _rng_weights(kd, [(fi, fi)])[0],
            "w_r": _rng_weights(ke, [(fi, fi)])[0],
            "u_r": _rng_weights(kx, [(fi, fi)])[0],
            "w_h": _rng_weights(kw, [(fi, fi)])[0],
            "u_h": _rng_weights(kd, [(fi, fi)])[0],
            "weights": jax.random.normal(kw, (NUM_RELATIONS, fi, fo),
                                         jnp.float32) * 0.1,
        }
        return [pool[a] for a in self.arg_names]

    def bind(self, ts: TileShape) -> Callable:
        """Close the tile shape over the model fn (num_dst is static)."""
        import functools
        return functools.partial(self.fn, num_dst=ts.num_dst)


# ---------------------------------------------------------------------------
# Layer stacking (multi-layer pipelines)
# ---------------------------------------------------------------------------

# Argument-name classes shared by every registry model: the graph args
# are layer-invariant, the x args carry the chained embeddings, and
# everything else is a per-layer weight.
GRAPH_ARG_NAMES = ("src", "dst", "valid", "etype")
X_ARG_NAMES = ("x_src", "x_dst")


def stacked(name: str, ts: TileShape, layer_weights, graph_args, x,
            activation=None):
    """Chain ``len(layer_weights)`` layers of model `name` on one tile.

    Mirrors the Rust ``ModelSpec`` pipeline semantics exactly: layer
    *l*'s output becomes layer *l+1*'s ``x_src``/``x_dst``, hidden
    layers get `activation` (default ReLU), and the final layer is
    linear. The graph args (edge list, validity mask, edge types) are
    shared by every layer — the single-tiling amortization the Rust
    `plan::ExecPlan` performs per partition.

    Requires a *square* tile (``num_src == num_dst`` and ``feat_in ==
    feat_out``): only then is "feed the output back in" well-defined on
    one tile, which is the per-partition contract the Rust multi-layer
    PJRT validation drives.

    `layer_weights` is one dict per layer mapping weight arg names to
    arrays; `graph_args` maps the GRAPH_ARG_NAMES the model uses.
    """
    from .kernels import ref
    if activation is None:
        activation = ref.relu
    if ts.num_src != ts.num_dst or ts.feat_in != ts.feat_out:
        raise ValueError(
            f"stacked() needs a square tile shape (num_src == num_dst, "
            f"feat_in == feat_out), got {ts}")
    spec = MODELS[name]
    fn = spec.bind(ts)
    h = x
    depth = len(layer_weights)
    for l, weights in enumerate(layer_weights):
        args = []
        for n in spec.arg_names:
            if n in X_ARG_NAMES:
                args.append(h)
            elif n in GRAPH_ARG_NAMES:
                args.append(graph_args[n])
            else:
                args.append(weights[n])
        h = fn(*args)
        if l + 1 < depth:
            h = activation(h)
    return h


MODELS: dict[str, ModelSpec] = {
    "gcn": ModelSpec("gcn", gcn_e2v, ("x_src", "src", "dst", "valid", "w")),
    "gcn_naive": ModelSpec("gcn_naive", gcn_naive,
                           ("x_src", "src", "dst", "valid", "w")),
    "gat": ModelSpec("gat", gat, ("x_src", "x_dst", "src", "dst", "valid",
                                  "w", "a_src", "a_dst")),
    "gat_naive": ModelSpec("gat_naive", gat_naive,
                           ("x_src", "x_dst", "src", "dst", "valid",
                            "w", "a_src", "a_dst")),
    "sage": ModelSpec("sage", sage, ("x_src", "x_dst", "src", "dst", "valid",
                                     "w_pool", "b_pool", "w_self", "w_neigh")),
    "sage_naive": ModelSpec("sage_naive", sage_naive,
                            ("x_src", "x_dst", "src", "dst", "valid",
                             "w_pool", "b_pool", "w_self", "w_neigh")),
    "ggnn": ModelSpec("ggnn", ggnn, ("x_src", "x_dst", "src", "dst", "valid",
                                     "w_msg", "w_z", "u_z", "w_r", "u_r",
                                     "w_h", "u_h")),
    "rgcn": ModelSpec("rgcn", rgcn, ("x_src", "src", "dst", "etype", "valid",
                                     "weights")),
}
