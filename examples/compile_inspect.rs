//! Compiler pipeline inspector: tensor DAG → IR segments → E2V → SDE
//! functions → pipeline-optimizer passes, shown stage by stage (paper
//! Fig 8's walk-through plus the DESIGN.md §3.7 plan-level passes).
//!
//! ```bash
//! cargo run --release --example compile_inspect -- gat        # depth 2
//! cargo run --release --example compile_inspect -- gcn 3      # depth 3
//! ```
//!
//! The final section is a plan-level IR dump: the whole compiled layer
//! stack is printed before any pass, then each optimizer pass runs in
//! its fixed order (`load_elim → fuse → hoist → dbe`) with the
//! disassembly and per-pass `OptReport` shown after every rewrite.

use zipper::compiler::{compile, optimize_pipeline, OptLevel, PassSet, Program};
use zipper::ir::{self, e2v};
use zipper::models::{ModelKind, ModelSpec};

fn dump_stages(stages: &[Program]) {
    for (l, p) in stages.iter().enumerate() {
        println!("; ----- layer {l} -----");
        println!("{}", p.disassemble());
    }
}

fn main() -> Result<(), String> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "gat".into());
    let depth: u32 = std::env::args()
        .nth(2)
        .map(|d| d.parse().map_err(|_| format!("bad depth {d}")))
        .transpose()?
        .unwrap_or(2);
    let model = ModelKind::parse(&name).ok_or(format!("unknown model {name}"))?;
    let g = model.build();

    println!("== tensor-level DAG ({} nodes) ==", g.nodes.len());
    let mix = g.op_mix();
    println!("op mix: {} GEMM-class, {} ELW, {} GOP\n", mix.gemm, mix.elw, mix.gop);

    println!("== IR segments (paper §6.1 step 1) ==");
    for seg in ir::split_segments(&g) {
        println!(
            "{} [{:?}]: {} ops, sends {:?}, recvs {:?}",
            seg.label,
            seg.kind,
            seg.nodes.len(),
            seg.sends.iter().map(|p| p.role).collect::<Vec<_>>(),
            seg.recvs.iter().map(|p| p.role).collect::<Vec<_>>(),
        );
    }

    println!("\n== E2V optimization (paper §6.2) ==");
    let (opt, stats) = e2v::optimize(&g);
    println!("hoisted {} edge ops in {} rounds", stats.hoisted, stats.rounds);
    let saved = e2v::flops_saved(&g, &opt, 10_000, 200_000, 128, 128);
    println!("flops saved on a 10k-vertex / 200k-edge graph @F=128: {saved}");

    println!("\n== naive SDE functions ==");
    let naive = compile(&g, OptLevel::None).map_err(|e| e.to_string())?;
    println!("{}", naive.disassemble());

    println!("== optimized SDE functions ==");
    let optim = compile(&g, OptLevel::E2v).map_err(|e| e.to_string())?;
    println!("{}", optim.disassemble());
    println!(
        "instruction count: naive {} → optimized {}",
        naive.instruction_count(),
        optim.instruction_count()
    );

    // ---- plan-level pipeline optimizer (DESIGN.md §3.7) -----------------
    let spec = ModelSpec::new(model, 32, &[], 32, depth)?;
    let mut stages: Vec<Program> = (0..spec.depth())
        .map(|l| compile(&spec.build_layer(l), OptLevel::Pipeline(PassSet::all())))
        .collect::<Result<_, _>>()
        .map_err(|e| e.to_string())?;
    let count = |ps: &[Program]| ps.iter().map(Program::instruction_count).sum::<usize>();

    println!("\n== pipeline optimizer: {name} depth-{depth} stack, before any pass ==");
    println!("; {} instructions total\n", count(&stages));
    dump_stages(&stages);

    for (pass_name, pass) in PassSet::NAMED {
        let rep = optimize_pipeline(&mut stages, pass);
        let outcome = &rep.passes[0];
        println!(
            "== after {pass_name}: {} -> {} instructions \
             (removed {} fused {} hoisted {} freed {}) ==\n",
            rep.instructions_before,
            outcome.instructions_after,
            outcome.report.removed,
            outcome.report.fused,
            outcome.report.hoisted,
            outcome.report.freed,
        );
        dump_stages(&stages);
    }
    println!("; final pipeline: {} instructions", count(&stages));
    Ok(())
}
