//! Compiler pipeline inspector: tensor DAG → IR segments → E2V → SDE
//! functions, shown stage by stage (paper Fig 8's walk-through).
//!
//! ```bash
//! cargo run --release --example compile_inspect -- gat
//! ```

use zipper::compiler::{compile, OptLevel};
use zipper::ir::{self, e2v};
use zipper::models::ModelKind;

fn main() -> Result<(), String> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "gat".into());
    let model = ModelKind::parse(&name).ok_or(format!("unknown model {name}"))?;
    let g = model.build();

    println!("== tensor-level DAG ({} nodes) ==", g.nodes.len());
    let mix = g.op_mix();
    println!("op mix: {} GEMM-class, {} ELW, {} GOP\n", mix.gemm, mix.elw, mix.gop);

    println!("== IR segments (paper §6.1 step 1) ==");
    for seg in ir::split_segments(&g) {
        println!(
            "{} [{:?}]: {} ops, sends {:?}, recvs {:?}",
            seg.label,
            seg.kind,
            seg.nodes.len(),
            seg.sends.iter().map(|p| p.role).collect::<Vec<_>>(),
            seg.recvs.iter().map(|p| p.role).collect::<Vec<_>>(),
        );
    }

    println!("\n== E2V optimization (paper §6.2) ==");
    let (opt, stats) = e2v::optimize(&g);
    println!("hoisted {} edge ops in {} rounds", stats.hoisted, stats.rounds);
    let saved = e2v::flops_saved(&g, &opt, 10_000, 200_000, 128, 128);
    println!("flops saved on a 10k-vertex / 200k-edge graph @F=128: {saved}");

    println!("\n== naive SDE functions ==");
    let naive = compile(&g, OptLevel::None).map_err(|e| e.to_string())?;
    println!("{}", naive.disassemble());

    println!("== optimized SDE functions ==");
    let optim = compile(&g, OptLevel::E2v).map_err(|e| e.to_string())?;
    println!("{}", optim.disassemble());
    println!(
        "instruction count: naive {} → optimized {}",
        naive.instruction_count(),
        optim.instruction_count()
    );
    Ok(())
}
