//! Design-space exploration (paper §8.3 / Fig 13 style, interactive).
//!
//! Sweeps stream counts and unit counts for one model/dataset and prints
//! normalized latencies — the workflow an architect would run before
//! committing to a configuration.
//!
//! ```bash
//! cargo run --release --example design_space -- gat CP
//! ```

use zipper::config::{ArchConfig, RunConfig};
use zipper::coordinator::Session;
use zipper::area;
use zipper::metrics::Table;

fn main() -> Result<(), String> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let model = argv.first().cloned().unwrap_or_else(|| "gat".into());
    let dataset = argv.get(1).cloned().unwrap_or_else(|| "CP".into());

    let run = RunConfig {
        model: model.clone(),
        dataset: dataset.clone(),
        scale: 512,
        feat_in: 64,
        feat_out: 64,
        ..Default::default()
    };
    let session = Session::prepare(&run)?;
    println!(
        "DSE for {model} on {dataset} (1/{} scale: |V|={} |E|={})\n",
        run.scale,
        session.graph().num_vertices(),
        session.graph().num_edges()
    );

    // stream sweep at 1 MU / 2 VU
    let mut t = Table::new(&["s/e streams", "cycles", "norm", "MU busy %", "VU busy %"]);
    let mut base = None;
    for streams in [1u32, 2, 4, 8, 16] {
        let mut arch = ArchConfig::default();
        arch.s_streams = streams;
        arch.e_streams = streams;
        let res = session.simulate(&arch, false, None, 0)?;
        let b = *base.get_or_insert(res.cycles as f64);
        t.row(&[
            streams.to_string(),
            res.cycles.to_string(),
            format!("{:.3}", res.cycles as f64 / b),
            format!("{:.1}", 100.0 * res.mu_busy as f64 / res.cycles as f64),
            format!(
                "{:.1}",
                100.0 * res.vu_busy as f64 / (res.cycles as f64 * arch.vu_count as f64)
            ),
        ]);
    }
    println!("stream sweep (1 MU, 2 VU):\n{}", t.render());

    // unit sweep at 4/4 streams
    let mut t = Table::new(&["MU", "VU", "cycles", "norm", "area mm²"]);
    let mut base = None;
    for (mu, vu) in [(1u32, 1u32), (1, 2), (1, 4), (2, 2), (2, 4), (4, 4)] {
        let mut arch = ArchConfig::default();
        arch.mu_count = mu;
        arch.vu_count = vu;
        let res = session.simulate(&arch, false, None, 0)?;
        let b = *base.get_or_insert(res.cycles as f64);
        t.row(&[
            mu.to_string(),
            vu.to_string(),
            res.cycles.to_string(),
            format!("{:.3}", res.cycles as f64 / b),
            format!("{:.2}", area::area(&arch).total_mm2()),
        ]);
    }
    println!("unit sweep (4 s/eStreams):\n{}", t.render());
    Ok(())
}
