//! Quickstart: compile a GCN, tile a graph, simulate, read the numbers.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use zipper::config::{ArchConfig, RunConfig};
use zipper::coordinator::Session;
use zipper::energy::EnergyModel;
use zipper::util;

fn main() -> Result<(), String> {
    // 1. Architecture: the paper's Table 4 configuration.
    let arch = ArchConfig::default();

    // 2. A run: GCN over a scaled soc-LiveJournal1 stand-in.
    let run = RunConfig {
        model: "gcn".into(),
        dataset: "SL".into(),
        scale: 256,
        feat_in: 64,
        feat_out: 64,
        functional: true,
        ..Default::default()
    };

    // 3. Session = shared handle over a compile-once ExecPlan
    //    (graph + tiling + compiled SDE program + weights).
    let session = Session::prepare(&run)?;
    println!(
        "graph |V|={} |E|={}, {} tiles across {} partitions",
        session.graph().num_vertices(),
        session.graph().num_edges(),
        session.tiling().num_tiles(),
        session.tiling().partitions.len()
    );
    println!("{}", session.program().disassemble());

    // 4. Simulate (cycle-level + functional).
    let x = session.make_input(run.seed);
    let res = session.simulate(&arch, true, Some(&x), 0)?;
    let energy = EnergyModel::default().evaluate(&res.counters, arch.freq_hz);

    println!(
        "latency: {} cycles = {}",
        res.cycles,
        util::fmt_time_at(res.cycles, arch.freq_hz)
    );
    println!(
        "off-chip: read {}, write {}",
        util::fmt_bytes(res.dram_read_bytes),
        util::fmt_bytes(res.dram_write_bytes)
    );
    println!("energy: {:.6} J", energy.total_j());
    let out = res.output.expect("functional output");
    println!(
        "output: {} embeddings, checksum {:.6}",
        out.len() / run.feat_out as usize,
        out.iter().map(|&v| v as f64).sum::<f64>()
    );
    Ok(())
}
