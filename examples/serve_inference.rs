//! End-to-end serving driver (the DESIGN.md headline example).
//!
//! Proves the compile-once serving pipeline on a real small workload:
//!   1. (when a PJRT backend + artifacts are present) cross-validates
//!      every GNN model's simulator functional output against the PJRT
//!      oracle — skipped gracefully in dependency-free builds,
//!   2. serves a **cold** batch of inference requests (all 5 models ×
//!      citation-graph stand-ins) through the multi-threaded coordinator
//!      with functional execution on — every plan is compiled here,
//!   3. serves the **same** batch again through a coordinator sharing
//!      the plan cache — zero recompile/retile work, scratch reuse —
//!      and reports the cold vs warm throughput ratio,
//!   4. serves the batch once more with request batching + tile-parallel
//!      execution on (`max_batch = 8`, `exec_threads = 4`): same-plan
//!      requests share one timing simulation and one batched functional
//!      pass, with per-request checksums asserted bit-identical to the
//!      sequential warm pass,
//!   5. serves **3-layer pipelines** (GCN/GAT/SAGE, shared tiling per
//!      plan) and prints the per-layer cycle/DRAM/energy breakdown plus
//!      the aggregate peak-UEM footprint (Fig 2's inter-layer
//!      activation story), asserting the per-layer cycles sum to the
//!      pipeline total.
//!
//! ```bash
//! cargo run --release --example serve_inference
//! ```

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};
use zipper::config::{ArchConfig, RunConfig, ServingConfig};
use zipper::coordinator::{
    validate, Coordinator, InferenceRequest, InferenceResponse, RejectReason, ZipperService,
};
use zipper::metrics::Table;
use zipper::plan::PlanCache;
use zipper::runtime::{Runtime, TileShape};
use zipper::tiling::{Reorder, TilingConfig, TilingMode};
use zipper::util::stats::{percentile, Summary};

fn request(i: u64) -> InferenceRequest {
    let models = ["gcn", "gat", "sage", "ggnn", "rgcn"];
    let datasets = ["CR", "CS", "PB"];
    let run = RunConfig {
        model: models[i as usize % models.len()].into(),
        dataset: datasets[i as usize % datasets.len()].into(),
        scale: 4,
        feat_in: 32,
        feat_out: 32,
        tiling: TilingConfig {
            dst_part: 256,
            src_part: 256,
            mode: TilingMode::Sparse,
            reorder: Reorder::InDegree,
            threads: 1,
        },
        e2v: true,
        passes: Default::default(),
        functional: true,
        seed: 7,
        layers: 1,
        hidden: Vec::new(),
        serving: Default::default(),
        kernels: Default::default(),
    };
    InferenceRequest { id: i, run, input_seed: i }
}

fn serve_batch(
    arch: ArchConfig,
    workers: usize,
    n_requests: u64,
    cache: &Arc<PlanCache>,
) -> Result<(Vec<InferenceResponse>, f64), String> {
    let mut c = Coordinator::with_cache(arch, workers, Arc::clone(cache));
    let t0 = Instant::now();
    for i in 0..n_requests {
        c.submit(request(i));
    }
    let mut resp = c.drain();
    let wall = t0.elapsed().as_secs_f64();
    resp.sort_by_key(|r| r.id);
    for r in &resp {
        if let Some(e) = &r.error {
            return Err(format!("request {} failed: {e}", r.id));
        }
        assert!(r.output_checksum.is_some(), "functional output expected");
    }
    Ok((resp, wall))
}

fn main() -> Result<(), String> {
    let arch = ArchConfig::default();

    // ---- phase 1: PJRT oracle cross-validation (optional) ----------------
    println!("== phase 1: three-layer validation (sim vs PJRT artifacts) ==");
    let artifacts = Path::new("artifacts");
    let oracle = if artifacts.join("manifest.json").exists() {
        Runtime::new(artifacts).ok().filter(|rt| rt.available())
    } else {
        None
    };
    match oracle {
        Some(mut rt) => {
            println!("PJRT platform: {}", rt.platform());
            let shape =
                TileShape { num_src: 64, num_dst: 64, num_edges: 256, feat_in: 32, feat_out: 32 };
            let reports = validate::validate_all(&mut rt, &shape, 23)?;
            let mut t = Table::new(&["model", "max err", "pass"]);
            for r in &reports {
                if !r.pass {
                    return Err(format!("{} failed validation: {}", r.model, r.max_abs_err));
                }
                t.row(&[r.model.clone(), format!("{:.2e}", r.max_abs_err), "ok".into()]);
            }
            print!("{}", t.render());
        }
        None => {
            println!(
                "skipped: PJRT backend or artifacts/ not available in this build \
                 (run `make artifacts` with a PJRT-linked binary to enable)"
            );
        }
    }

    // ---- phase 2: cold serving (plans compiled on first use) -------------
    println!("\n== phase 2: cold serving (compile-once plans built here) ==");
    let n_requests = 30u64;
    let workers = 4usize;
    let cache = Arc::new(PlanCache::new());
    let (cold_resp, cold_wall) = serve_batch(arch, workers, n_requests, &cache)?;

    let mut table = Table::new(&["model", "dataset", "sim latency", "energy", "host wall", "plan"]);
    let mut sim_lat = Summary::new();
    let mut host_lat: Vec<f64> = Vec::new();
    for r in &cold_resp {
        sim_lat.push(r.sim_seconds);
        host_lat.push(r.wall_seconds);
        if r.id < 10 {
            table.row(&[
                r.model.clone(),
                r.dataset.clone(),
                format!("{:.3} ms", r.sim_seconds * 1e3),
                format!("{:.3} mJ", r.energy_j * 1e3),
                format!("{:.1} ms", r.wall_seconds * 1e3),
                if r.plan_cache_hit { "warm".into() } else { "cold".into() },
            ]);
        }
    }
    print!("{}", table.render());
    println!("(first 10 of {n_requests} shown)");
    let stats = cache.stats();
    println!(
        "cold pass: {:.1} req/s on {workers} workers ({n_requests} requests in {:.2}s); \
         {} plans compiled",
        n_requests as f64 / cold_wall,
        cold_wall,
        stats.entries
    );

    // ---- phase 3: warm serving off the shared plan cache -----------------
    println!("\n== phase 3: warm serving (shared plan cache, zero recompile/retile) ==");
    let (warm_resp, warm_wall) = serve_batch(arch, workers, n_requests, &cache)?;
    let all_warm = warm_resp.iter().all(|r| r.plan_cache_hit);
    let max_prepare = warm_resp.iter().map(|r| r.prepare_seconds).fold(0.0, f64::max);
    assert!(all_warm, "warm pass must hit the plan cache on every request");
    assert!(max_prepare == 0.0, "warm requests must not pay plan compilation");
    for (c, w) in cold_resp.iter().zip(&warm_resp) {
        assert_eq!(c.sim_cycles, w.sim_cycles, "warm plan must be bit-identical");
        assert_eq!(c.output_checksum, w.output_checksum, "request {}", c.id);
    }
    println!(
        "warm pass: {:.1} req/s ({n_requests} requests in {:.2}s) — {:.2}x cold throughput",
        n_requests as f64 / warm_wall,
        warm_wall,
        cold_wall / warm_wall
    );
    let stats = cache.stats();
    println!(
        "plan cache: {} entries, {} hits / {} misses ({:.0}% hit rate)",
        stats.entries,
        stats.hits,
        stats.misses,
        100.0 * stats.hit_rate()
    );

    // ---- phase 4: batched + tile-parallel serving ------------------------
    println!("\n== phase 4: batched serving (max_batch=8, exec_threads=4) ==");
    let serving = ServingConfig { exec_threads: 4, max_batch: 8, ..Default::default() };
    let mut c = Coordinator::with_serving(arch, workers, serving, Arc::clone(&cache));
    let t0 = Instant::now();
    for i in 0..n_requests {
        c.submit(request(i));
    }
    let mut batched = c.drain();
    let batched_wall = t0.elapsed().as_secs_f64();
    batched.sort_by_key(|r| r.id);
    for (b, w) in batched.iter().zip(&warm_resp) {
        if let Some(e) = &b.error {
            return Err(format!("batched request {} failed: {e}", b.id));
        }
        assert!(b.plan_cache_hit, "batched pass must reuse cached plans");
        assert_eq!(b.sim_cycles, w.sim_cycles, "request {}", b.id);
        assert_eq!(
            b.output_checksum, w.output_checksum,
            "request {}: batched output must be bit-identical to sequential",
            b.id
        );
    }
    let mean_batch = batched.iter().map(|r| r.batch_size).sum::<usize>() as f64
        / batched.len() as f64;
    println!(
        "batched pass: {:.1} req/s ({n_requests} requests in {:.2}s) — {:.2}x the \
         sequential warm pass, mean batch size {mean_batch:.1}",
        n_requests as f64 / batched_wall,
        batched_wall,
        warm_wall / batched_wall
    );
    println!("per-request outputs bit-identical to sequential serving (asserted)");

    // ---- phase 5: stacked-layer pipelines --------------------------------
    println!("\n== phase 5: 3-layer pipelines (one shared tiling per plan) ==");
    let serving = ServingConfig { exec_threads: 4, max_batch: 4, ..Default::default() };
    let mut c = Coordinator::with_serving(arch, workers, serving, Arc::clone(&cache));
    for i in 0..3u64 {
        // request(0..3) lands on gcn/gat/sage
        for k in 0..2u64 {
            let mut req = request(i);
            req.id = i * 2 + k;
            req.run.layers = 3;
            req.input_seed = k;
            c.submit(req);
        }
    }
    let mut deep = c.drain();
    deep.sort_by_key(|r| r.id);
    let mut lt = Table::new(&["model", "layer", "dims", "cycles", "dram read", "energy"]);
    for r in deep.iter() {
        if let Some(e) = &r.error {
            return Err(format!("layered request {} failed: {e}", r.id));
        }
        assert_eq!(r.layers.len(), 3, "depth-3 breakdown expected");
        assert_eq!(
            r.sim_cycles,
            r.layers.iter().map(|l| l.cycles).sum::<u64>(),
            "per-layer cycles must sum to the pipeline total"
        );
        if r.id % 2 == 0 {
            for (l, lc) in r.layers.iter().enumerate() {
                lt.row(&[
                    if l == 0 { r.model.clone() } else { String::new() },
                    l.to_string(),
                    format!("{}x{}", lc.feat_in, lc.feat_out),
                    lc.cycles.to_string(),
                    format!("{:.1} KB", lc.dram_read_bytes as f64 / 1024.0),
                    format!("{:.3} mJ", lc.energy_j * 1e3),
                ]);
            }
        }
    }
    print!("{}", lt.render());
    let peak = deep.iter().map(|r| r.peak_uem_bytes).max().unwrap_or(0);
    println!(
        "aggregate peak UEM incl. inter-layer activations: {:.1} KB \
         (depth cost is visible per layer above)",
        peak as f64 / 1024.0
    );

    // ---- phase 6: always-on service (admission, deadlines, shutdown) -----
    println!("\n== phase 6: always-on service (timer batching, deadlines, graceful stop) ==");
    let serving = ServingConfig {
        exec_threads: 2,
        max_batch: 8,
        max_wait_us: 500,
        queue_cap: 256,
        ..Default::default()
    };
    let svc = ZipperService::new(arch, workers, serving, Arc::clone(&cache))?;
    // submission overlaps execution here: early tickets resolve while
    // later requests are still being admitted, and partially filled
    // batches flush on the 500 us timer instead of waiting for a drain
    let mut tickets = Vec::new();
    for i in 0..n_requests {
        tickets.push(svc.submit(request(i)));
    }
    // a probe with an already-exhausted latency budget: admission sheds
    // it with a structured reason instead of wasting a worker on it
    let doomed = svc.submit_with_deadline(request(0), Some(Instant::now()));
    for t in tickets {
        let r = t.wait();
        if let Some(e) = &r.error {
            return Err(format!("service request {} failed: {e}", r.id));
        }
        assert!(r.wall_seconds >= r.queue_seconds, "wall must contain queue wait");
    }
    let shed = doomed.wait();
    assert_eq!(shed.reject, Some(RejectReason::DeadlineExceeded));
    println!(
        "expired-deadline probe rejected at admission: {}",
        shed.error.as_deref().unwrap_or("(no error)")
    );
    let report = svc.shutdown(Duration::from_secs(30));
    assert!(report.graceful, "drain must finish within the grace period");
    let m = svc.metrics();
    assert_eq!(
        m.completed + m.failed + m.rejected_total(),
        m.submitted,
        "every submitted request must be answered or structurally rejected"
    );
    println!(
        "served {} requests: p50/p95 latency {}/{} us, mean batch {:.1}, peak queue {}",
        m.completed,
        m.latency_p50_us,
        m.latency_p95_us,
        m.mean_batch_size(),
        m.peak_queue_depth
    );
    println!(
        "graceful shutdown in {:.3}s ({} shed)",
        report.wall_seconds, report.shed
    );

    println!(
        "\nsimulated accelerator latency: mean {:.3} ms, min {:.3} ms, max {:.3} ms",
        sim_lat.mean * 1e3,
        sim_lat.min * 1e3,
        sim_lat.max * 1e3
    );
    println!(
        "host serving latency (cold pass): p50 {:.1} ms, p95 {:.1} ms",
        percentile(&host_lat, 50.0) * 1e3,
        percentile(&host_lat, 95.0) * 1e3
    );
    println!("\ncompile-once pipeline verified: warm requests reuse immutable ExecPlans");
    Ok(())
}
