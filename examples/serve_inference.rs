//! End-to-end serving driver (the DESIGN.md headline example).
//!
//! Proves all three layers compose on a real small workload:
//!   1. loads the AOT HLO artifacts (L2 JAX models calling L1 Pallas
//!      kernels) into a PJRT CPU client,
//!   2. cross-validates every GNN model's simulator functional output
//!      against the PJRT oracle,
//!   3. serves a batched stream of inference requests (all 5 models ×
//!      citation-graph stand-ins) through the multi-threaded coordinator
//!      with functional execution on,
//!   4. reports per-request simulated latency/energy plus host-side
//!      serving latency and throughput.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_inference
//! ```
//!
//! Results recorded in EXPERIMENTS.md §End-to-end.

use std::path::Path;
use std::time::Instant;
use zipper::config::{ArchConfig, RunConfig};
use zipper::coordinator::{validate, Coordinator, InferenceRequest};
use zipper::metrics::Table;
use zipper::runtime::{Runtime, TileShape};
use zipper::tiling::{Reorder, TilingConfig, TilingMode};
use zipper::util::stats::{percentile, Summary};

fn main() -> Result<(), String> {
    let arch = ArchConfig::default();

    // ---- phase 1: PJRT oracle cross-validation --------------------------
    println!("== phase 1: three-layer validation (sim vs PJRT artifacts) ==");
    let mut rt = Runtime::new(Path::new("artifacts")).map_err(|e| e.to_string())?;
    println!("PJRT platform: {}", rt.platform());
    let shape = TileShape { num_src: 64, num_dst: 64, num_edges: 256, feat_in: 32, feat_out: 32 };
    let reports = validate::validate_all(&mut rt, &shape, 23).map_err(|e| e.to_string())?;
    let mut t = Table::new(&["model", "max err", "pass"]);
    for r in &reports {
        if !r.pass {
            return Err(format!("{} failed validation: {}", r.model, r.max_abs_err));
        }
        t.row(&[r.model.clone(), format!("{:.2e}", r.max_abs_err), "ok".into()]);
    }
    print!("{}", t.render());

    // ---- phase 2: batched serving ---------------------------------------
    println!("\n== phase 2: batched inference serving ==");
    let models = ["gcn", "gat", "sage", "ggnn", "rgcn"];
    let datasets = ["CR", "CS", "PB"];
    let n_requests = 30u64;
    let workers = 4usize;
    let mut c = Coordinator::new(arch, workers);
    let t0 = Instant::now();
    for i in 0..n_requests {
        let run = RunConfig {
            model: models[i as usize % models.len()].into(),
            dataset: datasets[i as usize % datasets.len()].into(),
            scale: 4,
            feat_in: 32,
            feat_out: 32,
            tiling: TilingConfig {
                dst_part: 256,
                src_part: 256,
                mode: TilingMode::Sparse,
                reorder: Reorder::InDegree,
            },
            e2v: true,
            functional: true,
            seed: 7,
        };
        c.submit(InferenceRequest { id: i, run, input_seed: i });
    }
    let mut resp = c.drain();
    let wall = t0.elapsed().as_secs_f64();
    resp.sort_by_key(|r| r.id);

    let mut table = Table::new(&["model", "dataset", "sim latency", "energy", "host wall"]);
    let mut sim_lat = Summary::new();
    let mut host_lat: Vec<f64> = Vec::new();
    for r in &resp {
        if let Some(e) = &r.error {
            return Err(format!("request {} failed: {e}", r.id));
        }
        assert!(r.output_checksum.is_some(), "functional output expected");
        sim_lat.push(r.sim_seconds);
        host_lat.push(r.wall_seconds);
        if r.id < 10 {
            table.row(&[
                r.model.clone(),
                r.dataset.clone(),
                format!("{:.3} ms", r.sim_seconds * 1e3),
                format!("{:.3} mJ", r.energy_j * 1e3),
                format!("{:.1} ms", r.wall_seconds * 1e3),
            ]);
        }
    }
    print!("{}", table.render());
    println!("(first 10 of {n_requests} shown)");
    println!(
        "\nthroughput: {:.1} req/s on {workers} workers ({n_requests} requests in {:.2}s)",
        n_requests as f64 / wall,
        wall
    );
    println!(
        "simulated accelerator latency: mean {:.3} ms, min {:.3} ms, max {:.3} ms",
        sim_lat.mean * 1e3,
        sim_lat.min * 1e3,
        sim_lat.max * 1e3
    );
    println!(
        "host serving latency: p50 {:.1} ms, p95 {:.1} ms",
        percentile(&host_lat, 50.0) * 1e3,
        percentile(&host_lat, 95.0) * 1e3
    );
    println!("\nall layers composed: artifacts -> PJRT oracle == simulator functional output");
    Ok(())
}
