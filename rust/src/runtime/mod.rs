//! PJRT runtime: load the AOT-compiled HLO text artifacts and execute
//! them from Rust — the oracle path for validating the simulator's
//! functional mode (the role DGL played in the paper's §8.1 validation).
//!
//! Interchange is HLO *text* (see python/compile/aot.py): jax ≥ 0.5
//! emits protos with 64-bit ids that older xla_extension builds reject;
//! the text parser reassigns ids and round-trips cleanly. Python runs
//! only at `make artifacts` time; this module is pure Rust at run time.
//!
//! **Backend gating:** the crate builds dependency-free, so the PJRT
//! FFI backend (the external `xla` crate) is not linked by default.
//! Manifest parsing, argument packing, and shape bookkeeping are fully
//! functional either way; `Runtime::execute` reports a descriptive
//! error when no backend is linked, and callers (CLI `validate`, the
//! serving example, the PJRT integration tests) degrade gracefully via
//! [`Runtime::available`].

use crate::util::json::Json;
use std::collections::HashMap;
use std::fmt;
use std::path::{Path, PathBuf};

/// Runtime-layer error (dependency-free stand-in for `anyhow::Error`).
#[derive(Debug)]
pub struct RtError(pub String);

impl fmt::Display for RtError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for RtError {}

pub type Result<T> = std::result::Result<T, RtError>;

fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(RtError(msg.into()))
}

/// Tile geometry key matching `python/compile/model.py::TileShape`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TileShape {
    pub num_src: u32,
    pub num_dst: u32,
    pub num_edges: u32,
    pub feat_in: u32,
    pub feat_out: u32,
}

impl TileShape {
    pub fn tag(&self) -> String {
        format!(
            "s{}_d{}_e{}_f{}x{}",
            self.num_src, self.num_dst, self.num_edges, self.feat_in, self.feat_out
        )
    }
}

/// One manifest entry: a lowered (model, tile-shape) module.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub model: String,
    pub tile: TileShape,
    pub file: String,
    /// Argument order: (name, shape, dtype), as lowered.
    pub args: Vec<(String, Vec<usize>, String)>,
}

/// The artifact manifest written by `python -m compile.aot`.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            RtError(format!(
                "reading {}/manifest.json (run `make artifacts`): {e}",
                dir.display()
            ))
        })?;
        let j = Json::parse(&text).map_err(|e| RtError(format!("manifest: {e}")))?;
        if j.get("format").and_then(Json::as_str) != Some("hlo-text") {
            return err("unexpected manifest format");
        }
        let mut entries = Vec::new();
        for e in j.get("entries").and_then(Json::as_arr).unwrap_or(&[]) {
            let tile = e
                .get("tile")
                .ok_or_else(|| RtError("entry missing tile".into()))?;
            let g = |k: &str| -> Result<u32> {
                tile.get(k)
                    .and_then(Json::as_u64)
                    .map(|v| v as u32)
                    .ok_or_else(|| RtError(format!("tile missing {k}")))
            };
            let mut args = Vec::new();
            for a in e.get("args").and_then(Json::as_arr).unwrap_or(&[]) {
                let name = a.get("name").and_then(Json::as_str).unwrap_or("").to_string();
                let shape: Vec<usize> = a
                    .get("shape")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|x| x.as_u64().map(|v| v as usize))
                    .collect();
                let dtype =
                    a.get("dtype").and_then(Json::as_str).unwrap_or("float32").to_string();
                args.push((name, shape, dtype));
            }
            entries.push(ArtifactMeta {
                model: e
                    .get("model")
                    .and_then(Json::as_str)
                    .ok_or_else(|| RtError("entry missing model".into()))?
                    .to_string(),
                tile: TileShape {
                    num_src: g("num_src")?,
                    num_dst: g("num_dst")?,
                    num_edges: g("num_edges")?,
                    feat_in: g("feat_in")?,
                    feat_out: g("feat_out")?,
                },
                file: e
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| RtError("entry missing file".into()))?
                    .to_string(),
                args,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    pub fn find(&self, model: &str, tile: &TileShape) -> Option<&ArtifactMeta> {
        self.entries.iter().find(|e| e.model == model && &e.tile == tile)
    }

    pub fn shapes_for(&self, model: &str) -> Vec<TileShape> {
        self.entries.iter().filter(|e| e.model == model).map(|e| e.tile).collect()
    }
}

/// Typed input to an executable: f32 matrix or i32 vector.
#[derive(Clone, Debug)]
pub enum ArgValue {
    F32 { data: Vec<f32>, shape: Vec<usize> },
    I32 { data: Vec<i32>, shape: Vec<usize> },
}

/// A PJRT client with a cache of compiled executables. Without a linked
/// PJRT backend this degrades to manifest/shape bookkeeping only (see
/// the module docs); `execute` then returns a descriptive error.
pub struct Runtime {
    manifest: Manifest,
    /// Modules validated by `prepare` (backend builds hold compiled
    /// executables here; the stub tracks preparedness for cache parity).
    prepared: HashMap<(String, TileShape), ()>,
}

impl Runtime {
    /// Whether a PJRT FFI backend is linked into this build.
    pub const BACKEND_LINKED: bool = false;

    pub fn new(artifact_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        Ok(Runtime { manifest, prepared: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        if Self::BACKEND_LINKED {
            "cpu".to_string()
        } else {
            "none (PJRT backend not linked)".to_string()
        }
    }

    /// True when `execute` can actually run modules.
    pub fn available(&self) -> bool {
        Self::BACKEND_LINKED
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Resolve (or fetch from cache) the module for (model, tile shape).
    pub fn prepare(&mut self, model: &str, tile: &TileShape) -> Result<()> {
        let key = (model.to_string(), *tile);
        if self.prepared.contains_key(&key) {
            return Ok(());
        }
        let meta = self
            .manifest
            .find(model, tile)
            .ok_or_else(|| RtError(format!("no artifact for {model} @ {}", tile.tag())))?;
        let path = self.manifest.dir.join(&meta.file);
        if !path.exists() {
            return err(format!("artifact file missing: {}", path.display()));
        }
        self.prepared.insert(key, ());
        Ok(())
    }

    /// Execute the module for (model, tile) with positional args.
    /// Returns the (num_dst × feat_out) output row-major.
    pub fn execute(
        &mut self,
        model: &str,
        tile: &TileShape,
        _args: &[ArgValue],
    ) -> Result<Vec<f32>> {
        self.prepare(model, tile)?;
        err(format!(
            "cannot execute {model} @ {}: no PJRT backend is linked into this build \
             (the crate is dependency-free; link the xla backend to enable oracle runs)",
            tile.tag()
        ))
    }
}

/// Helpers to build `ArgValue`s from a simulator-style tile context.
pub mod pack {
    use super::ArgValue;
    use crate::util::Rng;

    /// Pad/truncate a COO edge list to the artifact's static edge count.
    /// Padded entries point at vertex 0 with valid = 0 (ref.py convention).
    pub fn edges(coo: &[(u32, u32)], num_edges: usize) -> (ArgValue, ArgValue, ArgValue) {
        let mut src = vec![0i32; num_edges];
        let mut dst = vec![0i32; num_edges];
        let mut valid = vec![0i32; num_edges];
        for (i, &(s, d)) in coo.iter().take(num_edges).enumerate() {
            src[i] = s as i32;
            dst[i] = d as i32;
            valid[i] = 1;
        }
        (
            ArgValue::I32 { data: src, shape: vec![num_edges] },
            ArgValue::I32 { data: dst, shape: vec![num_edges] },
            ArgValue::I32 { data: valid, shape: vec![num_edges] },
        )
    }

    pub fn etypes(types: &[u8], num_edges: usize) -> ArgValue {
        let mut t = vec![0i32; num_edges];
        for (i, &x) in types.iter().take(num_edges).enumerate() {
            t[i] = x as i32;
        }
        ArgValue::I32 { data: t, shape: vec![num_edges] }
    }

    /// Embedding block zero-padded to `rows × cols`.
    pub fn features(x: &[f32], rows: usize, cols: usize) -> ArgValue {
        let mut data = vec![0.0f32; rows * cols];
        let n = x.len().min(rows * cols);
        data[..n].copy_from_slice(&x[..n]);
        ArgValue::F32 { data, shape: vec![rows, cols] }
    }

    /// Deterministic random weights (seeded) in artifact layout.
    pub fn random_weight(rows: usize, cols: usize, seed: u64) -> ArgValue {
        let mut rng = Rng::new(seed);
        let data = (0..rows * cols).map(|_| (rng.normal() * 0.1) as f32).collect();
        ArgValue::F32 { data, shape: vec![rows, cols] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_shape_tag_matches_python() {
        let t = TileShape {
            num_src: 256, num_dst: 256, num_edges: 1024, feat_in: 128, feat_out: 128,
        };
        assert_eq!(t.tag(), "s256_d256_e1024_f128x128");
    }

    #[test]
    fn manifest_parse_minimal() {
        let dir = std::env::temp_dir().join(format!("zipper_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format":"hlo-text","entries":[{"model":"gcn","file":"f.hlo.txt",
                "tile":{"num_src":64,"num_dst":64,"num_edges":256,"feat_in":32,"feat_out":32},
                "args":[{"name":"x_src","shape":[64,32],"dtype":"float32"}],
                "output":{"shape":[64,32],"dtype":"float32"}}]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 1);
        let t = TileShape { num_src: 64, num_dst: 64, num_edges: 256, feat_in: 32, feat_out: 32 };
        assert!(m.find("gcn", &t).is_some());
        assert_eq!(m.entries[0].args[0].0, "x_src");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stub_runtime_reports_unavailable_not_panic() {
        let dir = std::env::temp_dir().join(format!("zipper_rt_stub_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format":"hlo-text","entries":[]}"#,
        )
        .unwrap();
        let mut rt = Runtime::new(&dir).unwrap();
        assert!(!rt.available());
        let t = TileShape { num_src: 8, num_dst: 8, num_edges: 8, feat_in: 4, feat_out: 4 };
        let e = rt.execute("gcn", &t, &[]).unwrap_err();
        assert!(e.to_string().contains("no artifact for gcn"), "{e}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pack_edges_pads_with_invalid() {
        let (s, d, v) = pack::edges(&[(3, 1), (2, 0)], 4);
        let (ArgValue::I32 { data: s, .. }, ArgValue::I32 { data: d, .. },
             ArgValue::I32 { data: v, .. }) = (s, d, v) else { panic!() };
        assert_eq!(s, vec![3, 2, 0, 0]);
        assert_eq!(d, vec![1, 0, 0, 0]);
        assert_eq!(v, vec![1, 1, 0, 0]);
    }

    #[test]
    fn pack_features_pads_rows() {
        let ArgValue::F32 { data, shape } = pack::features(&[1.0, 2.0], 2, 2) else {
            panic!()
        };
        assert_eq!(shape, vec![2, 2]);
        assert_eq!(data, vec![1.0, 2.0, 0.0, 0.0]);
    }
}
