//! Persistent per-run shard worker pool (DESIGN.md §3.9).
//!
//! Both sharded executors used to respawn a fresh `std::thread::scope`
//! per layer — K thread spawns plus K joins per layer of every request.
//! [`with_shard_pool`] spawns the K workers exactly ONCE per sharded
//! execution: between layers the workers park on a condvar, the driver
//! publishes one *round* (the layer index plus one owned job input per
//! shard), and each worker hands its result back through a per-shard
//! slot before parking again. Worker panics are caught and surfaced as
//! `Err("shard worker panicked")`, matching the old per-scope join
//! behavior, and a drop guard stops the pool even if the driver
//! unwinds, so the enclosing scope can always join.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Condvar, Mutex, MutexGuard};

/// One worker job: `FnMut(layer, input) -> Result<output, error>`.
/// Boxed so each worker can capture its own shard plan and `&mut`
/// scratch; `'env` ties those borrows to the caller's stack frame.
pub(crate) type ShardWorker<'env, I, O> =
    Box<dyn FnMut(usize, I) -> Result<O, String> + Send + 'env>;

struct RoundState<I, O> {
    /// Monotone round counter; workers run one job per round.
    round: u64,
    /// Layer index published with the current round.
    layer: usize,
    stop: bool,
    /// One owned job input per shard, taken by its worker.
    inputs: Vec<Option<I>>,
    /// One result slot per shard, filled before the worker parks.
    outputs: Vec<Option<Result<O, String>>>,
    /// Workers that have completed the current round.
    done: usize,
}

/// The shared driver/worker rendezvous. Created and owned by
/// [`with_shard_pool`]; the driver closure talks to it via
/// [`ShardPool::run_round`].
pub(crate) struct ShardPool<I, O> {
    k: usize,
    state: Mutex<RoundState<I, O>>,
    /// Signaled by the driver when a new round (or stop) is published.
    work: Condvar,
    /// Signaled by the last worker to finish a round.
    idle: Condvar,
}

impl<I: Send, O: Send> ShardPool<I, O> {
    fn new(k: usize) -> Self {
        ShardPool {
            k,
            state: Mutex::new(RoundState {
                round: 0,
                layer: 0,
                stop: false,
                inputs: Vec::new(),
                outputs: Vec::new(),
                done: 0,
            }),
            work: Condvar::new(),
            idle: Condvar::new(),
        }
    }

    fn lock(&self) -> MutexGuard<'_, RoundState<I, O>> {
        // a worker can only poison the mutex by panicking between the
        // catch_unwind boundary and its unlock — the state is still a
        // plain value either way, so recover rather than cascade
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Driver side: publish one job per shard for `layer`, wake every
    /// worker, block until all K results are in, and return them in
    /// shard order.
    pub(crate) fn run_round(&self, layer: usize, inputs: Vec<I>) -> Vec<Result<O, String>> {
        assert_eq!(inputs.len(), self.k, "one job per shard per round");
        let mut st = self.lock();
        st.layer = layer;
        st.inputs.clear();
        st.inputs.extend(inputs.into_iter().map(Some));
        st.outputs.clear();
        st.outputs.resize_with(self.k, || None);
        st.done = 0;
        st.round += 1;
        self.work.notify_all();
        while st.done < self.k {
            st = self.idle.wait(st).unwrap_or_else(|p| p.into_inner());
        }
        st.outputs
            .iter_mut()
            .map(|o| o.take().expect("every worker stored a result"))
            .collect()
    }

    fn stop(&self) {
        let mut st = self.lock();
        st.stop = true;
        drop(st);
        self.work.notify_all();
    }

    fn worker_loop(&self, shard: usize, f: &mut (dyn FnMut(usize, I) -> Result<O, String> + Send)) {
        let mut seen = 0u64;
        loop {
            let (layer, job) = {
                let mut st = self.lock();
                loop {
                    if st.stop {
                        return;
                    }
                    if st.round != seen {
                        break;
                    }
                    st = self.work.wait(st).unwrap_or_else(|p| p.into_inner());
                }
                seen = st.round;
                let job = st.inputs[shard].take().expect("round carries one job per shard");
                (st.layer, job)
            };
            let out = catch_unwind(AssertUnwindSafe(|| f(layer, job)))
                .unwrap_or_else(|_| Err("shard worker panicked".into()));
            let mut st = self.lock();
            st.outputs[shard] = Some(out);
            st.done += 1;
            if st.done == self.k {
                self.idle.notify_one();
            }
        }
    }
}

/// Guarantees the workers are released even if `drive` unwinds, so the
/// enclosing `thread::scope` never deadlocks at join.
struct StopGuard<'a, I: Send, O: Send>(&'a ShardPool<I, O>);

impl<I: Send, O: Send> Drop for StopGuard<'_, I, O> {
    fn drop(&mut self) {
        self.0.stop();
    }
}

/// Spawn one persistent worker per shard, run `drive` on the calling
/// thread (it schedules layers via [`ShardPool::run_round`]), then park
/// the pool and join. Workers live for the whole execution — layer
/// boundaries cost a condvar wake, not a thread spawn.
pub(crate) fn with_shard_pool<'env, I, O, R>(
    mut workers: Vec<ShardWorker<'env, I, O>>,
    drive: impl FnOnce(&ShardPool<I, O>) -> R,
) -> R
where
    I: Send + 'env,
    O: Send + 'env,
{
    let pool = ShardPool::new(workers.len());
    std::thread::scope(|scope| {
        for (shard, mut f) in workers.drain(..).enumerate() {
            let p = &pool;
            scope.spawn(move || p.worker_loop(shard, &mut *f));
        }
        let _guard = StopGuard(&pool);
        drive(&pool)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn adders(k: usize) -> Vec<ShardWorker<'static, u64, u64>> {
        (0..k)
            .map(|s| {
                let b: ShardWorker<'static, u64, u64> =
                    Box::new(move |layer, x| Ok(x + layer as u64 * 100 + s as u64));
                b
            })
            .collect()
    }

    #[test]
    fn rounds_return_in_shard_order_and_workers_persist() {
        let sums = with_shard_pool(adders(4), |pool| {
            let mut sums = vec![0u64; 4];
            // many rounds through the SAME four workers
            for layer in 0..50 {
                let outs = pool.run_round(layer, vec![1, 2, 3, 4]);
                for (s, o) in outs.into_iter().enumerate() {
                    assert_eq!(o.unwrap(), 1 + s as u64 + layer as u64 * 100 + s as u64);
                    sums[s] += 1;
                }
            }
            sums
        });
        assert_eq!(sums, vec![50; 4]);
    }

    #[test]
    fn worker_state_is_retained_across_rounds() {
        // each worker accumulates into captured &mut state, proving the
        // same closure instance (not a respawn) serves every round
        let mut accs = vec![0u64; 3];
        {
            let workers: Vec<ShardWorker<'_, u64, u64>> = accs
                .iter_mut()
                .map(|acc| {
                    let b: ShardWorker<'_, u64, u64> = Box::new(move |_, x| {
                        *acc += x;
                        Ok(*acc)
                    });
                    b
                })
                .collect();
            let last = with_shard_pool(workers, |pool| {
                let mut last = Vec::new();
                for _ in 0..10 {
                    last = pool.run_round(0, vec![1, 2, 3]);
                }
                last
            });
            let got: Vec<u64> = last.into_iter().map(|r| r.unwrap()).collect();
            assert_eq!(got, vec![10, 20, 30]);
        }
        assert_eq!(accs, vec![10, 20, 30]);
    }

    #[test]
    fn errors_and_panics_surface_per_shard() {
        let workers: Vec<ShardWorker<'static, u64, u64>> = vec![
            Box::new(|_, x| Ok(x)),
            Box::new(|_, _| Err("boom".into())),
            Box::new(|_, _| panic!("worker dies")),
        ];
        let outs = with_shard_pool(workers, |pool| pool.run_round(0, vec![7, 7, 7]));
        assert_eq!(outs[0], Ok(7));
        assert_eq!(outs[1], Err("boom".to_string()));
        assert_eq!(outs[2], Err("shard worker panicked".to_string()));
    }

    #[test]
    fn driver_unwind_releases_workers() {
        // the StopGuard must stop the pool when drive panics, or the
        // scope would deadlock joining parked workers
        let r = catch_unwind(AssertUnwindSafe(|| {
            with_shard_pool(adders(2), |pool| {
                let _ = pool.run_round(0, vec![1, 2]);
                panic!("driver bails mid-run");
            })
        }));
        assert!(r.is_err());
    }
}
