//! Compile-once execution plans (the paper's core systems claim).
//!
//! ZIPPER's compiler fixes the expensive decisions — tiling, operator
//! scheduling, buffer assignment — *once* per (model spec, graph, arch
//! operating point); the runtime then only maps the immutable IR
//! programs onto hardware blocks per request. [`ExecPlan`] is that
//! artifact: an `Arc`-able pipeline of per-layer [`LayerStage`]s
//! (compiled [`Program`] + [`WeightStore`] each) over ONE shared
//! [`Tiling`] + derived dimensions, produced once and shared by any
//! number of concurrent simulation runs. Per-request state lives
//! entirely in the caller's [`ExecScratch`], so serving is re-entrant
//! and allocation-light — including the inter-layer activation chain of
//! multi-layer runs (DESIGN.md §3.4).
//!
//! [`PlanCache`] is the serving-side cache: a concurrent map from the
//! structured [`PlanKey`] to `Arc<ExecPlan>`, with hit/miss counters so
//! benches can prove warm requests skip recompile/retile entirely.

use crate::compiler::{compile, optimize_pipeline, OptLevel, PassSet, PipelineOptReport, Program};
use crate::config::{ArchConfig, KernelPolicy, RunConfig};
use crate::graph::partition::{partition, Partitioning};
use crate::graph::{datasets, Graph};
use crate::models::{ModelKind, ModelSpec, WeightStore, NUM_RELATIONS};
use crate::sim::parallel::{run_batch, BatchScratch, StageWl};
use crate::sim::{ExecScratch, LayerMetrics, SimOptions, SimResult, Simulator, Workload};
use crate::tiling::{tile, Reorder, Tiling, TilingConfig, TilingMode};
use crate::util::Rng;
use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

mod pool;
use pool::{with_shard_pool, ShardWorker};

/// Structured, stable cache key: every input that changes the compiled
/// artifact. (The old string key formatted `TilingConfig` with `{:?}`
/// and omitted the dataset seed — two different graphs could collide.)
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub model: String,
    pub dataset: String,
    pub scale: u64,
    /// Raw request dims (kept for key continuity with the pre-pipeline
    /// cache and the stable `Display` rendering; note GGNN's square
    /// coercion happens in `layers`, not here).
    pub feat_in: u32,
    pub feat_out: u32,
    /// Resolved per-layer (in, out) dims — the layer signature.
    /// Different depths or hidden widths never alias (one entry per
    /// layer, depth-1 = `[(feat_in, feat_out)]`), and equivalent
    /// spellings of the same hidden chain (`hidden = []` vs the
    /// explicit default widths) resolve identically.
    pub layers: Vec<(u32, u32)>,
    pub tiling: TilingConfig,
    pub e2v: bool,
    /// Pipeline-optimizer pass selection. Part of the key because the
    /// passes rewrite the compiled programs: plans built under different
    /// pass subsets must never alias in the cache.
    pub passes: PassSet,
    pub seed: u64,
    /// Kernel-variant selection (SIMD / sparsity skipping / storage
    /// dtype). Part of the key because the compiled artifact differs:
    /// weights are quantized at plan build and both executors read the
    /// policy from the plan — variants must never alias in the cache.
    pub kernels: KernelPolicy,
    /// Multi-chip shard count (1 = unsharded). Part of the key because a
    /// sharded plan carries K per-shard sub-plans plus halo maps —
    /// sharded and unsharded plans must never alias in the cache.
    pub shards: u32,
    /// Operator-level overlap (DESIGN.md §3.9): hide the boundary halo
    /// exchange behind halo-independent tile compute. Part of the key
    /// because the timing model differs — overlapped and serial plans
    /// must never alias in the cache. Normalized to `false` for
    /// unsharded runs (no boundary to overlap), so the knob cannot
    /// fragment the single-chip cache population.
    pub overlap: bool,
}

impl PlanKey {
    pub fn of(run: &RunConfig) -> PlanKey {
        PlanKey {
            model: run.model.clone(),
            dataset: run.dataset.clone(),
            scale: run.scale,
            feat_in: run.feat_in,
            feat_out: run.feat_out,
            layers: layer_signature(run),
            // normalized: `TilingConfig::threads` is a host compile-
            // latency knob that never changes the artifact, so it must
            // not fragment the cache
            tiling: run.tiling.cache_key(),
            e2v: run.e2v,
            passes: run.passes,
            seed: run.seed,
            kernels: run.kernels,
            shards: run.shards.max(1),
            overlap: run.overlap && run.shards >= 2,
        }
    }
}

/// The resolved per-layer (in, out) dims of a run — normalized through
/// [`ModelSpec`] so equivalent spellings (`hidden = []` vs an explicit
/// all-default chain) share one cache entry. Runs that cannot resolve
/// (unknown model, inconsistent chain — they fail compile anyway) fall
/// back to the raw width chain so the key still distinguishes them.
fn layer_signature(run: &RunConfig) -> Vec<(u32, u32)> {
    if let Some(kind) = ModelKind::parse(&run.model) {
        if let Ok(spec) = ModelSpec::new(kind, run.feat_in, &run.hidden, run.feat_out, run.layers)
        {
            return spec.layers.iter().map(|l| (l.feat_in, l.feat_out)).collect();
        }
    }
    let mut widths = Vec::with_capacity(run.hidden.len() + 2);
    widths.push(run.feat_in);
    widths.extend_from_slice(&run.hidden);
    widths.push(run.feat_out);
    widths.windows(2).map(|w| (w[0], w[1])).collect()
}

impl fmt::Display for PlanKey {
    /// Stable structured rendering (log lines, bench JSON).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mode = match self.tiling.mode {
            TilingMode::Regular => "regular",
            TilingMode::Sparse => "sparse",
        };
        let reorder = match self.tiling.reorder {
            Reorder::None => "none",
            Reorder::InDegree => "in_degree",
            Reorder::OutDegree => "out_degree",
        };
        let layers = self
            .layers
            .iter()
            .map(|&(i, o)| format!("{i}x{o}"))
            .collect::<Vec<_>>()
            .join(",");
        write!(
            f,
            "model={};dataset={};scale={};feat={}x{};layers={};dst_part={};src_part={};mode={};reorder={};e2v={};passes={};seed={};simd={};skip={};dtype={};shards={};overlap={}",
            self.model,
            self.dataset,
            self.scale,
            self.feat_in,
            self.feat_out,
            layers,
            self.tiling.dst_part,
            self.tiling.src_part,
            mode,
            reorder,
            self.e2v,
            self.passes,
            self.seed,
            self.kernels.simd,
            self.kernels.sparse_skip,
            self.kernels.dtype.name(),
            self.shards,
            self.overlap,
        )
    }
}

/// One inbound halo-activation copy of a sharded plan: at each layer
/// boundary, the consumer shard's local row `dst_local` is overwritten
/// with the producing (home) shard's freshly-computed row `src_local`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HaloCopy {
    pub src_shard: u32,
    pub src_local: u32,
    pub dst_local: u32,
}

/// Plan-time operator-overlap schedule of a sharded plan (DESIGN.md
/// §3.9): every tile of every shard classified as **halo-independent**
/// (its occupied source rows gather only core-local vertices, so it can
/// execute while the boundary exchange is still in flight) or
/// **halo-dependent** (it reads at least one imported halo row and must
/// wait for the exchange). The classification is sound because shard
/// tilings are compiled with `Reorder::None`: tile source ids ARE
/// shard-local ids, indexing straight into the partition's core mask,
/// and `Tile::src_occ` masks out block rows that carry no edge.
///
/// The schedule is always computed at plan build (it is cheap and
/// useful for inspection); whether the executors *bill* the overlapped
/// timing is selected by `PlanKey::overlap`.
pub struct OverlapSchedule {
    /// Per shard: one flag per tile in canonical (partition, tile)
    /// order — `true` = halo-independent.
    pub independent: Vec<Vec<bool>>,
    /// Per shard: number of halo-independent tiles.
    pub independent_tiles: Vec<u32>,
    /// Per shard: number of halo-dependent tiles.
    pub dependent_tiles: Vec<u32>,
    /// Per shard, per layer: the work-weighted fraction of the layer's
    /// compute carried by halo-independent tiles, in [0, 1]. Tile work
    /// is modeled as `rows·feat_in·feat_out + edges·feat_out` (dense
    /// transform + gather), the same first-order shape the engine's
    /// cycle model follows. Shards with no tiles report 1.0 (nothing
    /// reads a halo row).
    pub independent_work_frac: Vec<Vec<f64>>,
}

/// The sharded half of an [`ExecPlan`] (DESIGN.md §3.8): K per-shard
/// sub-plans compiled with the shared machinery, plus the vertex maps
/// that scatter inputs, exchange halos, and stitch outputs back to
/// original vertex order.
///
/// Built over the *globally relabeled* graph (the top-level tiling's
/// permutation), with shard-local ids assigned in ascending relabeled
/// order and shard tilings compiled with `Reorder::None` — so every
/// destination's gather left-fold visits sources in exactly the order
/// the unsharded plan uses, making sharded outputs bit-exact.
pub struct ShardedPlan {
    /// The K-way cut of the relabeled graph (shard graphs + halo sets).
    pub partition: Partitioning,
    /// One full sub-plan per shard (own tiling + stages; weights and
    /// programs are graph-independent, hence identical across shards).
    pub shards: Vec<ExecPlan>,
    /// Per shard: inbound halo copies applied at every layer boundary.
    pub halo_in: Vec<Vec<HaloCopy>>,
    /// Per shard: local id → ORIGINAL (pre-relabel) vertex id.
    pub local_to_orig: Vec<Vec<u32>>,
    /// Per shard: (local, original) pairs of core vertices — the
    /// output-stitch map.
    pub core_out: Vec<Vec<(u32, u32)>>,
    /// Total halo copies per layer boundary (= Σ `halo_in` lengths).
    pub halo_copies: u64,
    /// Tile-level halo-independence schedule (DESIGN.md §3.9).
    pub overlap: OverlapSchedule,
}

impl ShardedPlan {
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }
}

/// Dimensions derived at plan-compile time so consumers never recompute
/// them per request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanDims {
    pub num_vertices: u32,
    pub num_edges: u64,
    pub num_partitions: usize,
    pub num_tiles: usize,
    pub max_tile_src: u32,
    pub max_tile_edges: u32,
    /// Length of a flat input embedding vector (V × feat_in).
    pub input_len: usize,
    /// Length of a flat output embedding vector (V × feat_out).
    pub output_len: usize,
}

/// One compiled layer of a plan's pipeline: the layer's SDE program and
/// weights at its `(feat_in, feat_out)` operating point. Stages never
/// own graph-side state — the plan's single [`Tiling`] (and its E2V
/// vertex permutation) is shared by every stage.
pub struct LayerStage {
    pub program: Program,
    pub weights: WeightStore,
    pub feat_in: u32,
    pub feat_out: u32,
}

/// Immutable, shareable execution plan: everything reusable across
/// requests for one (model spec, graph, tiling, features) operating
/// point. Multi-layer models compile into a *pipeline* of
/// [`LayerStage`]s over ONE shared tiling — the expensive graph-side
/// work (sparse tiling + reorder permutation) is computed exactly once
/// per plan and amortized across every layer of every request.
pub struct ExecPlan {
    pub key: PlanKey,
    pub model: ModelKind,
    /// Resolved layer chain (depth, widths, activations).
    pub spec: ModelSpec,
    pub graph: Graph,
    /// The single tiling every stage executes over.
    pub tiling: Tiling,
    /// Per-layer compiled programs + weights, execution order.
    pub stages: Vec<LayerStage>,
    /// First layer's input embedding width.
    pub feat_in: u32,
    /// Final layer's output embedding width.
    pub feat_out: u32,
    pub dims: PlanDims,
    /// Per-pass attribution from the pipeline optimizer, when the run
    /// selected a non-empty [`PassSet`] (`None` = no optimizer run).
    pub opt_report: Option<PipelineOptReport>,
    /// Multi-chip sharding (DESIGN.md §3.8): `Some` iff `key.shards ≥ 2`.
    /// Unsharded plans carry `None` and execute exactly as before.
    pub sharding: Option<ShardedPlan>,
}

impl ExecPlan {
    /// Compile a plan from a run config (dataset registry + compiler).
    pub fn compile(run: &RunConfig) -> Result<ExecPlan, String> {
        let model = ModelKind::parse(&run.model)
            .ok_or_else(|| format!("unknown model {}", run.model))?;
        let spec = datasets::by_id(&run.dataset)
            .ok_or_else(|| format!("unknown dataset {}", run.dataset))?;
        let etypes = if model.uses_etypes() { NUM_RELATIONS } else { 0 };
        let graph = spec.instantiate_typed(run.scale, etypes, run.seed);
        Self::from_graph(model, graph, run)
    }

    /// Compile a plan around an explicit graph (tests, examples).
    pub fn from_graph(model: ModelKind, graph: Graph, run: &RunConfig) -> Result<ExecPlan, String> {
        run.kernels.validate().map_err(|e| e.to_string())?;
        if !run.passes.is_empty() && !run.e2v {
            return Err(format!(
                "pipeline passes ({}) require e2v lowering (drop --no-e2v or --passes)",
                run.passes
            ));
        }
        let spec = ModelSpec::new(model, run.feat_in, &run.hidden, run.feat_out, run.layers)?;
        // the ONE graph-side compile step, shared by every stage
        let tiling = tile(&graph, run.tiling);
        let opt = if !run.e2v {
            OptLevel::None
        } else if run.passes.is_empty() {
            OptLevel::E2v
        } else {
            OptLevel::Pipeline(run.passes)
        };
        // per-layer lowering first: the pipeline optimizer needs the
        // whole compiled layer stack before any stage is finalized
        let mut programs = Vec::with_capacity(spec.depth());
        let mut stores = Vec::with_capacity(spec.depth());
        for (l, layer) in spec.layers.iter().enumerate() {
            let dag = spec.build_layer(l);
            programs.push(compile(&dag, opt).map_err(|e| e.at_layer(l).to_string())?);
            let mut weights = WeightStore::synthesize(
                &dag,
                layer.feat_in,
                layer.feat_out,
                ModelSpec::layer_seed(run.seed, l),
            );
            // Reduced-precision storage: weights are quantized ONCE at
            // plan build (round-trip through the storage dtype), so the
            // resident f32 image is exactly what 16-bit storage plus
            // convert-at-load would produce — and every executor reads
            // the same values. F32 policy is a no-op.
            weights.quantize(run.kernels.dtype);
            stores.push(weights);
        }
        let opt_report = if run.passes.is_empty() {
            None
        } else {
            Some(optimize_pipeline(&mut programs, run.passes))
        };
        let stages: Vec<LayerStage> = programs
            .into_iter()
            .zip(stores)
            .zip(&spec.layers)
            .map(|((program, weights), layer)| LayerStage {
                program,
                weights,
                feat_in: layer.feat_in,
                feat_out: layer.feat_out,
            })
            .collect();
        let (feat_in, feat_out) = (spec.feat_in(), spec.feat_out());
        let dims = PlanDims {
            num_vertices: tiling.num_vertices,
            num_edges: tiling.num_edges,
            num_partitions: tiling.partitions.len(),
            num_tiles: tiling.num_tiles(),
            max_tile_src: tiling.max_tile_src(),
            max_tile_edges: tiling.max_tile_edges(),
            input_len: tiling.num_vertices as usize * feat_in as usize,
            output_len: tiling.num_vertices as usize * feat_out as usize,
        };
        let key = PlanKey::of(run);
        let sharding = if key.shards >= 2 {
            Some(build_sharding(model, &graph, &tiling, run, key.shards as usize)?)
        } else {
            None
        };
        Ok(ExecPlan {
            key,
            model,
            spec,
            graph,
            tiling,
            stages,
            feat_in,
            feat_out,
            dims,
            opt_report,
            sharding,
        })
    }

    /// Pipeline depth (number of compiled layer stages, ≥ 1).
    pub fn depth(&self) -> usize {
        self.stages.len()
    }

    /// Deterministic input embeddings for this plan's graph.
    pub fn make_input(&self, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..self.dims.input_len).map(|_| rng.next_f32_sym() * 0.5).collect()
    }

    /// Borrow one pipeline stage as a simulator workload (the engine
    /// executes one layer program at a time; `ExecPlan::simulate_with`
    /// chains the stages).
    pub fn stage_workload<'a>(&'a self, l: usize, x: Option<&'a [f32]>) -> Workload<'a> {
        let stage = &self.stages[l];
        Workload {
            program: &stage.program,
            tiling: &self.tiling,
            weights: &stage.weights,
            feat_in: stage.feat_in,
            feat_out: stage.feat_out,
            x,
            kernels: self.key.kernels,
        }
    }

    /// Borrow the first stage as a simulator workload (the whole model
    /// for depth-1 plans; kept for single-layer tests and tools).
    pub fn workload<'a>(&'a self, x: Option<&'a [f32]>) -> Workload<'a> {
        self.stage_workload(0, x)
    }

    /// Run the cycle-level simulation (optionally functional), allocating
    /// fresh scratch. Prefer [`ExecPlan::simulate_with`] on hot paths.
    pub fn simulate(
        &self,
        arch: &ArchConfig,
        functional: bool,
        x: Option<&[f32]>,
        trace_window: u64,
    ) -> Result<SimResult, String> {
        let mut scratch = ExecScratch::new();
        self.simulate_with(arch, functional, x, trace_window, &mut scratch)
    }

    /// Re-entrant simulation: the plan is only read, all run-local state
    /// lives in `scratch`. Any number of threads may call this on the
    /// same `Arc<ExecPlan>` concurrently, each with its own scratch.
    ///
    /// Multi-layer plans chain the engine: layer *l*'s output embeddings
    /// (ORIGINAL vertex order, stashed in the scratch's pooled chain
    /// buffer) become layer *l+1*'s `x`, timing/energy/DRAM accumulate
    /// across layers, and `SimResult::layers` carries the per-layer
    /// breakdown. Depth 1 is bit-exact with the pre-pipeline behavior.
    pub fn simulate_with(
        &self,
        arch: &ArchConfig,
        functional: bool,
        x: Option<&[f32]>,
        trace_window: u64,
        scratch: &mut ExecScratch,
    ) -> Result<SimResult, String> {
        if self.sharding.is_some() {
            return self.simulate_sharded(arch, functional, x, trace_window, scratch);
        }
        if self.stages.len() == 1 {
            // depth-1 fast path: one engine run, no chaining
            let wl = self.stage_workload(0, x);
            let opts = SimOptions { functional, trace_window, emit_output: true };
            let mut res = Simulator::new(arch, &wl, opts).run_with(scratch)?;
            res.layers = vec![layer_metrics(&self.stages[0], &res)];
            return Ok(res);
        }
        // detach the pooled chain buffer so the in-flight layer can
        // borrow it as input while the scratch stays mutably borrowed
        let mut chain = std::mem::take(&mut scratch.chain);
        let result = self.simulate_chain(arch, functional, x, trace_window, scratch, &mut chain);
        scratch.chain = chain;
        result
    }

    fn simulate_chain(
        &self,
        arch: &ArchConfig,
        functional: bool,
        x: Option<&[f32]>,
        trace_window: u64,
        scratch: &mut ExecScratch,
        chain: &mut Vec<f32>,
    ) -> Result<SimResult, String> {
        let depth = self.stages.len();
        let mut acc = SimResult::default();
        for (l, stage) in self.stages.iter().enumerate() {
            let last = l + 1 == depth;
            let input: Option<&[f32]> = if !functional {
                None
            } else if l == 0 {
                x
            } else {
                Some(chain.as_slice())
            };
            let wl = Workload {
                program: &stage.program,
                tiling: &self.tiling,
                weights: &stage.weights,
                feat_in: stage.feat_in,
                feat_out: stage.feat_out,
                x: input,
                kernels: self.key.kernels,
            };
            let opts = SimOptions {
                functional,
                // the windowed trace covers the first layer
                trace_window: if l == 0 { trace_window } else { 0 },
                emit_output: last,
            };
            let mut res = Simulator::new(arch, &wl, opts).run_with(scratch)?;
            if functional && !last {
                scratch.stash_output(&self.tiling, stage.feat_out, chain);
                // hidden-layer activations round-trip through the
                // storage dtype at exactly this chain boundary — the
                // same point `run_stage`'s sink quantizes, so the
                // engine and `run_batch` stay bit-identical under
                // f16/bf16 too (no-op for f32)
                crate::sim::tensor::quantize_slice(self.key.kernels.dtype, chain);
            }
            acc.layers.push(layer_metrics(stage, &res));
            acc.cycles += res.cycles;
            acc.instructions += res.instructions;
            acc.mu_busy += res.mu_busy;
            acc.vu_busy += res.vu_busy;
            acc.mem_busy += res.mem_busy;
            acc.dram_read_bytes += res.dram_read_bytes;
            acc.dram_write_bytes += res.dram_write_bytes;
            acc.counters += res.counters;
            if l == 0 {
                acc.trace = std::mem::take(&mut res.trace);
            }
            if last {
                acc.output = res.output.take();
            }
        }
        acc.peak_uem_bytes = self.aggregate_peak(&acc.layers);
        Ok(acc)
    }

    /// Fig 2-style footprint aggregate: a layer's tile-resident peak
    /// plus the inter-layer activation images resident across its
    /// boundaries (the previous layer's output while it is consumed, and
    /// this layer's own output image while it is produced). Depth-1
    /// plans have no inter-layer activations, so this reduces to the
    /// engine's own peak.
    fn aggregate_peak(&self, layers: &[LayerMetrics]) -> u64 {
        let v = self.dims.num_vertices as u64;
        let depth = layers.len();
        // inter-layer activation images are stored at the policy dtype
        // (2 bytes for f16/bf16), which is half the reduced-precision
        // path's footprint win; tile-resident peaks stay f32
        let act_bytes = self.key.kernels.dtype.bytes() as u64;
        layers
            .iter()
            .enumerate()
            .map(|(l, lm)| {
                let inp = if l > 0 { v * lm.feat_in as u64 * act_bytes } else { 0 };
                let out = if l + 1 < depth { v * lm.feat_out as u64 * act_bytes } else { 0 };
                lm.peak_uem_bytes + inp + out
            })
            .max()
            .unwrap_or(0)
    }

    /// Tile-parallel batched functional execution (no timing): one input
    /// embedding per request lane, each partition's tiles sharded across
    /// `exec_threads` OS threads, reductions folded in deterministic tile
    /// order. Multi-layer plans run the whole stage pipeline per lane
    /// (`sim::parallel::run_pipeline`), chaining layer outputs through
    /// the scratch's pooled buffers. Returns one output vector per lane,
    /// bit-identical for every `exec_threads` value and batch grouping —
    /// and bit-identical to a functional [`ExecPlan::simulate_with`]
    /// run: both executors share the single instruction-dispatch core
    /// (see [`sim::parallel`]). Timing for these lanes comes from a
    /// `functional: false` [`ExecPlan::simulate_with`] run, which is
    /// input-independent.
    ///
    /// [`sim::parallel`]: crate::sim::parallel
    pub fn execute_batch_with(
        &self,
        inputs: &[&[f32]],
        exec_threads: usize,
        scratch: &mut BatchScratch,
    ) -> Result<Vec<Vec<f32>>, String> {
        if self.sharding.is_some() {
            return self.execute_batch_sharded(inputs, exec_threads, scratch);
        }
        let stages: Vec<StageWl> = self
            .stages
            .iter()
            .map(|s| StageWl {
                program: &s.program,
                weights: &s.weights,
                feat_in: s.feat_in,
                feat_out: s.feat_out,
                kernels: self.key.kernels,
            })
            .collect();
        crate::sim::parallel::run_pipeline(&self.tiling, &stages, inputs, exec_threads, scratch)
    }

    /// Sharded engine path (DESIGN.md §3.8–3.9): one engine per shard
    /// per layer, run on a *persistent* per-run worker pool — K workers
    /// spawn once, park on a condvar between layers, and serve every
    /// round, so a layer boundary costs a wake instead of K thread
    /// spawns. The layer's cycle cost is the slowest shard; additive
    /// metrics (instructions, DRAM, energy events) sum over shards.
    ///
    /// Boundary exchange billing depends on `PlanKey::overlap`:
    /// - serial (default): the full exchange cost lands on the
    ///   producing layer's critical path (`exposed_cycles`);
    /// - overlap: the exchange is billed against the *consuming*
    ///   layer's halo-independent tile phase —
    ///   `max(exchange, independent) + dependent` per shard, max over
    ///   shards — and only the exposed remainder reaches the critical
    ///   path. Functional execution is unchanged either way (exchange
    ///   still completes before the next layer's folds run), so outputs
    ///   are bit-exact across both settings.
    ///
    /// At every layer boundary the halo rows of each shard's activation
    /// image are overwritten with the owning shard's freshly computed
    /// rows; the final layer's core rows are stitched back to ORIGINAL
    /// vertex order — bit-exactly equal to the unsharded plan's output,
    /// because shard-local gather folds visit sources in the same order
    /// (see [`ShardedPlan`]).
    fn simulate_sharded(
        &self,
        arch: &ArchConfig,
        functional: bool,
        x: Option<&[f32]>,
        trace_window: u64,
        scratch: &mut ExecScratch,
    ) -> Result<SimResult, String> {
        let sh = self.sharding.as_ref().expect("sharded path requires sharding");
        let k = sh.shards.len();
        let depth = self.stages.len();
        let dtype = self.key.kernels.dtype;
        let overlap = self.key.overlap;
        // scatter the global input into per-shard local images
        let mut cur: Vec<Vec<f32>> = Vec::new();
        if functional {
            let x = x.ok_or("functional sharded run needs input embeddings")?;
            if x.len() != self.dims.input_len {
                return Err(format!(
                    "input length {} != |V| * feat_in = {}",
                    x.len(),
                    self.dims.input_len
                ));
            }
            let f = self.feat_in as usize;
            for map in &sh.local_to_orig {
                let mut xi = vec![0.0f32; map.len() * f];
                for (l, &orig) in map.iter().enumerate() {
                    xi[l * f..(l + 1) * f].copy_from_slice(&x[orig as usize * f..][..f]);
                }
                cur.push(xi);
            }
        }
        let scratches = scratch.ensure_shards(k);
        let mut acc = SimResult::default();
        let mut shard_layers: Vec<Vec<LayerMetrics>> = vec![Vec::new(); k];
        let mut outs: Vec<Vec<f32>> = Vec::new();
        // one persistent worker per shard; each owns its sub-plan ref +
        // scratch and serves (layer, input) jobs for the whole run
        let workers: Vec<ShardWorker<'_, Option<Vec<f32>>, SimResult>> = sh
            .shards
            .iter()
            .zip(scratches.iter_mut())
            .enumerate()
            .map(|(s, (sp, ss))| {
                let w: ShardWorker<'_, Option<Vec<f32>>, SimResult> =
                    Box::new(move |l: usize, x: Option<Vec<f32>>| {
                        // the windowed trace covers shard 0's first layer
                        let tw = if l == 0 && s == 0 { trace_window } else { 0 };
                        let wl = sp.stage_workload(l, x.as_deref());
                        let opts = SimOptions {
                            functional,
                            trace_window: tw,
                            emit_output: functional,
                        };
                        Simulator::new(arch, &wl, opts).run_with(ss)
                    });
                w
            })
            .collect();
        let run: Result<(), String> = with_shard_pool(workers, |pool| {
            // exchange cycles staged at the previous boundary, still to
            // be billed against this layer's independent phase
            let mut pending = 0u64;
            for l in 0..depth {
                let last = l + 1 == depth;
                let stage = &self.stages[l];
                let round_inputs: Vec<Option<Vec<f32>>> = if functional {
                    std::mem::take(&mut cur).into_iter().map(Some).collect()
                } else {
                    (0..k).map(|_| None).collect()
                };
                let results = pool.run_round(l, round_inputs);
                let mut layer = LayerMetrics {
                    feat_in: stage.feat_in,
                    feat_out: stage.feat_out,
                    ..Default::default()
                };
                outs.clear();
                // raw compute: max over concurrent chips
                let mut raw_max = 0u64;
                // overlapped: max over chips of max(E, independent) + dependent
                let mut overlapped_max = 0u64;
                for (s, r) in results.into_iter().enumerate() {
                    let mut res = r.map_err(|e| format!("shard {s} layer {l}: {e}"))?;
                    raw_max = raw_max.max(res.cycles);
                    if pending > 0 {
                        let frac = sh.overlap.independent_work_frac[s][l];
                        let ind = ((res.cycles as f64 * frac) as u64).min(res.cycles);
                        let dep = res.cycles - ind;
                        overlapped_max = overlapped_max.max(pending.max(ind) + dep);
                    }
                    layer.instructions += res.instructions;
                    layer.dram_read_bytes += res.dram_read_bytes;
                    layer.dram_write_bytes += res.dram_write_bytes;
                    layer.peak_uem_bytes = layer.peak_uem_bytes.max(res.peak_uem_bytes);
                    layer.counters += res.counters;
                    acc.mu_busy += res.mu_busy;
                    acc.vu_busy += res.vu_busy;
                    acc.mem_busy += res.mem_busy;
                    if l == 0 && s == 0 {
                        acc.trace = std::mem::take(&mut res.trace);
                    }
                    shard_layers[s].push(layer_metrics(stage, &res));
                    if functional {
                        outs.push(res.output.take().ok_or_else(|| {
                            format!("shard {s} layer {l} produced no output")
                        })?);
                    }
                }
                layer.cycles = if pending > 0 { overlapped_max } else { raw_max };
                if pending > 0 {
                    // max(E, ind) + dep is ≥ the raw layer (dep + ind)
                    // and ≤ raw + E, so exposed ∈ [0, E] by construction
                    let exposed = layer.cycles - raw_max;
                    layer.counters.cycles += exposed;
                    acc.halo.exposed_cycles += exposed;
                    acc.halo.hidden_cycles += pending - exposed;
                    pending = 0;
                }
                if !last && sh.halo_copies > 0 {
                    let (bytes, cycles) =
                        halo_exchange_cost(arch, sh.halo_copies, stage.feat_out, dtype);
                    // fabric traffic always bills to the producing layer
                    layer.dram_read_bytes += bytes / 2;
                    layer.dram_write_bytes += bytes / 2;
                    layer.counters.hbm_bytes += bytes;
                    acc.halo.exchanges += 1;
                    acc.halo.vertices += sh.halo_copies;
                    acc.halo.bytes += bytes;
                    acc.halo.cycles += cycles;
                    if overlap {
                        // defer: billed against the next layer's
                        // independent phase at the top of the loop
                        pending = cycles;
                    } else {
                        layer.cycles += cycles;
                        layer.counters.cycles += cycles;
                        acc.halo.exposed_cycles += cycles;
                    }
                }
                if functional && !last {
                    // hidden activations round-trip through the storage
                    // dtype at the boundary (the same point the
                    // unsharded chain quantizes), THEN halo rows are
                    // imported; a zero-copy boundary skips the exchange
                    for o in outs.iter_mut() {
                        crate::sim::tensor::quantize_slice(dtype, o);
                    }
                    if sh.halo_copies > 0 {
                        exchange_halos(&sh.halo_in, stage.feat_out as usize, &mut outs);
                    }
                    std::mem::swap(&mut cur, &mut outs);
                }
                acc.cycles += layer.cycles;
                acc.instructions += layer.instructions;
                acc.dram_read_bytes += layer.dram_read_bytes;
                acc.dram_write_bytes += layer.dram_write_bytes;
                acc.counters += layer.counters;
                acc.layers.push(layer);
            }
            Ok(())
        });
        run?;
        if functional {
            let f = self.feat_out as usize;
            let mut out = vec![0.0f32; self.dims.output_len];
            for (s, pairs) in sh.core_out.iter().enumerate() {
                for &(local, orig) in pairs {
                    out[orig as usize * f..][..f]
                        .copy_from_slice(&outs[s][local as usize * f..][..f]);
                }
            }
            acc.output = Some(out);
        }
        // per-chip footprint: the busiest shard's aggregate peak
        acc.peak_uem_bytes = sh
            .shards
            .iter()
            .zip(&shard_layers)
            .map(|(sp, ls)| sp.aggregate_peak(ls))
            .max()
            .unwrap_or(0);
        Ok(acc)
    }

    /// Sharded tile-parallel batched path: per layer, every shard runs
    /// the full [`run_batch`] machinery concurrently on the persistent
    /// shard worker pool (the exec-thread budget is split across
    /// shards), halos are exchanged per lane at each boundary, and the
    /// final core rows are stitched back to ORIGINAL vertex order.
    /// Bit-identical to the sharded engine path and to the unsharded
    /// plan for every thread count, because `run_batch` itself is
    /// thread-count-invariant — and for every `overlap` setting,
    /// because overlap only changes the cycle model, never the
    /// functional schedule (DESIGN.md §3.9).
    fn execute_batch_sharded(
        &self,
        inputs: &[&[f32]],
        exec_threads: usize,
        scratch: &mut BatchScratch,
    ) -> Result<Vec<Vec<f32>>, String> {
        let sh = self.sharding.as_ref().expect("sharded path requires sharding");
        let k = sh.shards.len();
        let nlanes = inputs.len();
        if nlanes == 0 {
            return Ok(Vec::new());
        }
        for (i, x) in inputs.iter().enumerate() {
            if x.len() != self.dims.input_len {
                return Err(format!(
                    "lane {i}: input length {} != |V| * feat_in = {}",
                    x.len(),
                    self.dims.input_len
                ));
            }
        }
        let depth = self.stages.len();
        let dtype = self.key.kernels.dtype;
        let f_in = self.feat_in as usize;
        // per-shard, per-lane local input images
        let mut cur: Vec<Vec<Vec<f32>>> = sh
            .local_to_orig
            .iter()
            .map(|map| {
                inputs
                    .iter()
                    .map(|x| {
                        let mut xi = vec![0.0f32; map.len() * f_in];
                        for (l, &orig) in map.iter().enumerate() {
                            xi[l * f_in..(l + 1) * f_in]
                                .copy_from_slice(&x[orig as usize * f_in..][..f_in]);
                        }
                        xi
                    })
                    .collect()
            })
            .collect();
        let scratches = scratch.ensure_shards(k);
        let inner_threads = (exec_threads.max(1) / k).max(1);
        // persistent workers: jobs carry the shard's owned lane images,
        // results are the shard's per-lane outputs
        let workers: Vec<ShardWorker<'_, Vec<Vec<f32>>, Vec<Vec<f32>>>> = sh
            .shards
            .iter()
            .zip(scratches.iter_mut())
            .map(|(sp, ss)| {
                let w: ShardWorker<'_, Vec<Vec<f32>>, Vec<Vec<f32>>> =
                    Box::new(move |l: usize, lanes: Vec<Vec<f32>>| {
                        let wl = sp.stage_workload(l, None);
                        let refs: Vec<&[f32]> = lanes.iter().map(|v| v.as_slice()).collect();
                        run_batch(&wl, &refs, inner_threads, ss)
                    });
                w
            })
            .collect();
        with_shard_pool(workers, |pool| {
            for l in 0..depth {
                let last = l + 1 == depth;
                let results = pool.run_round(l, std::mem::take(&mut cur));
                let mut outs: Vec<Vec<Vec<f32>>> = Vec::with_capacity(k);
                for (s, r) in results.into_iter().enumerate() {
                    outs.push(r.map_err(|e| format!("shard {s} layer {l}: {e}"))?);
                }
                if last {
                    let f = self.feat_out as usize;
                    let mut stitched: Vec<Vec<f32>> =
                        (0..nlanes).map(|_| vec![0.0f32; self.dims.output_len]).collect();
                    for (s, pairs) in sh.core_out.iter().enumerate() {
                        for (lane, dst) in stitched.iter_mut().enumerate() {
                            for &(local, orig) in pairs {
                                dst[orig as usize * f..][..f]
                                    .copy_from_slice(&outs[s][lane][local as usize * f..][..f]);
                            }
                        }
                    }
                    return Ok(stitched);
                }
                let f = self.stages[l].feat_out as usize;
                for lane_out in outs.iter_mut().flatten() {
                    crate::sim::tensor::quantize_slice(dtype, lane_out);
                }
                // zero-copy boundaries skip the staged exchange outright
                if sh.halo_copies > 0 {
                    for lane in 0..nlanes {
                        exchange_halos_lane(&sh.halo_in, f, lane, &mut outs);
                    }
                }
                cur = outs;
            }
            unreachable!("the final stage returns from the loop")
        })
    }
}

/// Per-layer slice of an engine run for `SimResult::layers`.
fn layer_metrics(stage: &LayerStage, res: &SimResult) -> LayerMetrics {
    LayerMetrics {
        feat_in: stage.feat_in,
        feat_out: stage.feat_out,
        cycles: res.cycles,
        instructions: res.instructions,
        dram_read_bytes: res.dram_read_bytes,
        dram_write_bytes: res.dram_write_bytes,
        peak_uem_bytes: res.peak_uem_bytes,
        counters: res.counters,
    }
}

/// Build the sharded half of a plan: cut the *globally relabeled* graph
/// (the top-level tiling's permutation already applied), compile one
/// sub-plan per shard with `Reorder::None`, and derive the scatter /
/// halo / stitch maps. Shard-local ids ascend in relabeled order, so
/// every destination's gather left-fold visits sources exactly as the
/// unsharded plan does — the bit-exactness argument of DESIGN.md §3.8.
fn build_sharding(
    model: ModelKind,
    graph: &Graph,
    tiling: &Tiling,
    run: &RunConfig,
    k: usize,
) -> Result<ShardedPlan, String> {
    let relabeled = graph.relabel(&tiling.perm).map_err(|e| e.to_string())?;
    let part = partition(&relabeled, k, run.seed)?;
    let mut shard_run = run.clone();
    shard_run.shards = 1;
    // the global degree order is already baked into the relabeled ids;
    // shard tilings must NOT reorder again or the fold order would drift
    shard_run.tiling.reorder = Reorder::None;
    let mut shards = Vec::with_capacity(k);
    for sh in &part.shards {
        shards.push(ExecPlan::from_graph(model, sh.graph.clone(), &shard_run)?);
    }
    let mut halo_in: Vec<Vec<HaloCopy>> = Vec::with_capacity(k);
    let mut local_to_orig: Vec<Vec<u32>> = Vec::with_capacity(k);
    let mut core_out: Vec<Vec<(u32, u32)>> = Vec::with_capacity(k);
    let mut halo_copies = 0u64;
    for sh in &part.shards {
        let l2o: Vec<u32> = sh.locals.iter().map(|&g| tiling.inv_perm[g as usize]).collect();
        let mut copies = Vec::with_capacity(sh.halo_vertices as usize);
        let mut core = Vec::with_capacity(sh.core_vertices as usize);
        for (l, (&g, &is_core)) in sh.locals.iter().zip(&sh.is_core).enumerate() {
            if is_core {
                core.push((l as u32, l2o[l]));
            } else {
                let home = part.assign[g as usize];
                let src_local = part.shards[home as usize]
                    .local_of(g)
                    .ok_or_else(|| format!("halo vertex {g} missing from home shard {home}"))?;
                copies.push(HaloCopy { src_shard: home, src_local, dst_local: l as u32 });
            }
        }
        halo_copies += copies.len() as u64;
        halo_in.push(copies);
        local_to_orig.push(l2o);
        core_out.push(core);
    }
    let overlap = build_overlap_schedule(&part, &shards);
    Ok(ShardedPlan {
        partition: part,
        shards,
        halo_in,
        local_to_orig,
        core_out,
        halo_copies,
        overlap,
    })
}

/// Classify every tile of every shard as halo-independent vs
/// halo-dependent and derive the per-layer independent-work fractions
/// the overlap timing model bills against (DESIGN.md §3.9). Sound
/// because shard tilings use `Reorder::None`: `Tile::src_vertices` hold
/// shard-local ids that index the partition's core mask directly, and
/// `Tile::occupied_sources_within` ignores block rows that carry no
/// edge (a halo vertex inside an untouched row creates no dependence).
fn build_overlap_schedule(part: &Partitioning, shards: &[ExecPlan]) -> OverlapSchedule {
    let mut independent = Vec::with_capacity(shards.len());
    let mut independent_tiles = Vec::with_capacity(shards.len());
    let mut dependent_tiles = Vec::with_capacity(shards.len());
    let mut work_frac = Vec::with_capacity(shards.len());
    for (s, sp) in shards.iter().enumerate() {
        let is_core = &part.shards[s].is_core;
        let mut flags = Vec::with_capacity(sp.dims.num_tiles);
        // (rows, edges) per tile, for the per-layer work weighting
        let mut shape = Vec::with_capacity(sp.dims.num_tiles);
        for p in &sp.tiling.partitions {
            for t in &p.tiles {
                flags.push(t.occupied_sources_within(is_core));
                shape.push((t.num_src() as u64, t.num_edges() as u64));
            }
        }
        let n_ind = flags.iter().filter(|&&i| i).count() as u32;
        // per-layer fractions: tile work ≈ rows·fi·fo (dense transform)
        // + edges·fo (gather/reduce), the engine's first-order shape
        let per_layer: Vec<f64> = sp
            .stages
            .iter()
            .map(|stage| {
                let (fi, fo) = (stage.feat_in as u128, stage.feat_out as u128);
                let mut ind_w = 0u128;
                let mut tot_w = 0u128;
                for (&(rows, edges), &ind) in shape.iter().zip(&flags) {
                    let w = rows as u128 * fi * fo + edges as u128 * fo;
                    tot_w += w;
                    if ind {
                        ind_w += w;
                    }
                }
                if tot_w == 0 {
                    // a shard with no work reads no halo rows at all
                    1.0
                } else {
                    ind_w as f64 / tot_w as f64
                }
            })
            .collect();
        dependent_tiles.push(flags.len() as u32 - n_ind);
        independent_tiles.push(n_ind);
        independent.push(flags);
        work_frac.push(per_layer);
    }
    OverlapSchedule {
        independent,
        independent_tiles,
        dependent_tiles,
        independent_work_frac: work_frac,
    }
}

/// Cost model for one inter-shard halo exchange (DESIGN.md §3.8): every
/// halo copy moves one `feat_out` activation row at the storage dtype;
/// bytes cross the chip fabric twice (producer write + consumer read)
/// at HBM-class aggregate bandwidth, plus one link latency per boundary
/// (the per-pair transfers overlap). Returns `(bytes, cycles)`.
fn halo_exchange_cost(
    arch: &ArchConfig,
    copies: u64,
    feat_out: u32,
    dtype: crate::config::StorageDtype,
) -> (u64, u64) {
    let bytes = 2 * copies * feat_out as u64 * dtype.bytes();
    let cycles =
        (bytes as f64 / arch.hbm_bytes_per_cycle()).ceil() as u64 + arch.hbm_latency_cycles;
    (bytes, cycles)
}

/// Overwrite every shard's halo rows with the owning shard's freshly
/// computed activation rows. Reads are staged before writes; halo
/// sources are always *core* rows of their home shard and core rows are
/// never patched, so the exchange is exact regardless of shard order.
/// A shard with an empty copy list is skipped outright (no staging, no
/// writes) — a one-directional cut pays only for the direction that
/// actually moves rows.
fn exchange_halos(halo_in: &[Vec<HaloCopy>], f: usize, outs: &mut [Vec<f32>]) {
    for s in 0..outs.len() {
        if halo_in[s].is_empty() {
            continue;
        }
        let staged: Vec<f32> = halo_in[s]
            .iter()
            .flat_map(|hc| {
                outs[hc.src_shard as usize][hc.src_local as usize * f..][..f].iter().copied()
            })
            .collect();
        for (i, hc) in halo_in[s].iter().enumerate() {
            outs[s][hc.dst_local as usize * f..][..f].copy_from_slice(&staged[i * f..][..f]);
        }
    }
}

/// Per-lane variant of [`exchange_halos`] for the batched path
/// (`outs[shard][lane]` layout).
fn exchange_halos_lane(halo_in: &[Vec<HaloCopy>], f: usize, lane: usize, outs: &mut [Vec<Vec<f32>>]) {
    for s in 0..outs.len() {
        if halo_in[s].is_empty() {
            continue;
        }
        let staged: Vec<f32> = halo_in[s]
            .iter()
            .flat_map(|hc| {
                outs[hc.src_shard as usize][lane][hc.src_local as usize * f..][..f]
                    .iter()
                    .copied()
            })
            .collect();
        for (i, hc) in halo_in[s].iter().enumerate() {
            outs[s][lane][hc.dst_local as usize * f..][..f].copy_from_slice(&staged[i * f..][..f]);
        }
    }
}

/// Snapshot of cache effectiveness counters. A *hit* means the whole
/// layered artifact was reused: one [`PlanKey`] (which carries the full
/// per-layer dim signature) maps to one compiled pipeline — shared
/// tiling plus every [`LayerStage`] — so a warm request skips retiling
/// AND every layer's compile/weight synthesis. Misses count one per
/// distinct key, i.e. exactly one `tile()` invocation each, regardless
/// of depth.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Concurrent plan cache: compile once per key, share `Arc<ExecPlan>`
/// across workers. Compilation happens outside the map lock so a slow
/// compile never blocks unrelated lookups; if two threads race on the
/// same key the first insert wins and the loser's plan is dropped.
///
/// # Examples
///
/// The second lookup of an identical [`RunConfig`] is a hit and returns
/// the same shared plan:
///
/// ```
/// use zipper::config::RunConfig;
/// use zipper::plan::PlanCache;
///
/// let cache = PlanCache::new();
/// let mut run = RunConfig::default();
/// run.dataset = "CR".into(); // tiny citation-graph stand-in
/// run.scale = 64;
/// run.feat_in = 8;
/// run.feat_out = 8;
///
/// let (first, hit_first) = cache.get_or_compile(&run).unwrap();
/// let (again, hit_again) = cache.get_or_compile(&run).unwrap();
/// assert!(!hit_first && hit_again);
/// assert!(std::sync::Arc::ptr_eq(&first, &again));
/// assert_eq!(cache.stats().entries, 1);
/// ```
pub struct PlanCache {
    plans: Mutex<HashMap<PlanKey, Arc<ExecPlan>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache {
            plans: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Fetch the plan for `run`, compiling it on first use. Returns the
    /// shared plan and whether this call was a cache hit. The key is the
    /// layered [`PlanKey`]: runs differing only in depth or hidden
    /// widths compile separate pipelines (never alias), while equivalent
    /// spellings of the same hidden chain share one entry.
    pub fn get_or_compile(&self, run: &RunConfig) -> Result<(Arc<ExecPlan>, bool), String> {
        let key = PlanKey::of(run);
        if let Some(p) = self.lookup(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok((p, true));
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let fresh = Arc::new(ExecPlan::compile(run)?);
        let mut map = self.plans.lock().unwrap_or_else(|p| p.into_inner());
        let entry = map.entry(key).or_insert(fresh);
        Ok((Arc::clone(entry), false))
    }

    fn lookup(&self, key: &PlanKey) -> Option<Arc<ExecPlan>> {
        let map = self.plans.lock().unwrap_or_else(|p| p.into_inner());
        map.get(key).map(Arc::clone)
    }

    pub fn stats(&self) -> CacheStats {
        let entries = self.plans.lock().unwrap_or_else(|p| p.into_inner()).len();
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries,
        }
    }

    pub fn clear(&self) {
        self.plans.lock().unwrap_or_else(|p| p.into_inner()).clear();
    }
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tiling::{Reorder, TilingMode};

    fn run_cfg(model: &str) -> RunConfig {
        RunConfig {
            model: model.into(),
            dataset: "CR".into(),
            scale: 16,
            feat_in: 16,
            feat_out: 16,
            layers: 1,
            hidden: Vec::new(),
            tiling: TilingConfig {
                dst_part: 64,
                src_part: 64,
                mode: TilingMode::Sparse,
                reorder: Reorder::InDegree,
                threads: 1,
            },
            e2v: true,
            passes: PassSet::none(),
            functional: false,
            seed: 3,
            serving: Default::default(),
            kernels: Default::default(),
            shards: 1,
            overlap: false,
        }
    }

    #[test]
    fn plan_key_is_stable_and_seed_sensitive() {
        let a = PlanKey::of(&run_cfg("gcn"));
        let b = PlanKey::of(&run_cfg("gcn"));
        assert_eq!(a, b);
        let mut other = run_cfg("gcn");
        other.seed = 4;
        assert_ne!(a, PlanKey::of(&other));
        let s = a.to_string();
        assert!(s.contains("model=gcn") && s.contains("seed=3") && s.contains("mode=sparse"));
    }

    #[test]
    fn plan_key_ignores_tiling_threads() {
        // a threaded compile and a serial compile are the same plan
        let a = PlanKey::of(&run_cfg("gcn"));
        let mut threaded = run_cfg("gcn");
        threaded.tiling.threads = 8;
        assert_eq!(a, PlanKey::of(&threaded));
        let cache = PlanCache::new();
        cache.get_or_compile(&run_cfg("gcn")).unwrap();
        let (_, hit) = cache.get_or_compile(&threaded).unwrap();
        assert!(hit, "threads must not fragment the plan cache");
    }

    #[test]
    fn plan_compiles_and_simulates() {
        let plan = ExecPlan::compile(&run_cfg("gat")).unwrap();
        assert!(plan.dims.num_tiles > 0);
        assert_eq!(plan.dims.num_partitions, plan.tiling.partitions.len());
        let x = plan.make_input(7);
        assert_eq!(x.len(), plan.dims.input_len);
        let res = plan.simulate(&ArchConfig::default(), true, Some(&x), 0).unwrap();
        assert!(res.cycles > 0);
        assert_eq!(res.output.unwrap().len(), plan.dims.output_len);
    }

    #[test]
    fn cache_hit_returns_same_plan() {
        let cache = PlanCache::new();
        let (a, hit_a) = cache.get_or_compile(&run_cfg("gcn")).unwrap();
        let (b, hit_b) = cache.get_or_compile(&run_cfg("gcn")).unwrap();
        assert!(!hit_a && hit_b);
        assert!(Arc::ptr_eq(&a, &b));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn cache_miss_on_different_config() {
        let cache = PlanCache::new();
        cache.get_or_compile(&run_cfg("gcn")).unwrap();
        let (_, hit) = cache.get_or_compile(&run_cfg("gat")).unwrap();
        assert!(!hit);
        let mut seeded = run_cfg("gcn");
        seeded.seed = 99;
        let (_, hit) = cache.get_or_compile(&seeded).unwrap();
        assert!(!hit, "different seed must not reuse a cached graph");
        assert_eq!(cache.stats().entries, 3);
    }

    #[test]
    fn plan_key_carries_the_layer_signature() {
        let mut deep = run_cfg("gcn");
        deep.layers = 3;
        let key = PlanKey::of(&deep);
        assert_eq!(key.layers, vec![(16, 16), (16, 16), (16, 16)]);
        assert!(key.to_string().contains("layers=16x16,16x16,16x16"));
        // equivalent spellings of the default chain share one key
        let mut explicit = deep.clone();
        explicit.hidden = vec![16, 16];
        assert_eq!(key, PlanKey::of(&explicit));
        // …but real differences never alias
        let mut narrow = deep.clone();
        narrow.hidden = vec![8, 8];
        assert_ne!(key, PlanKey::of(&narrow));
        assert_ne!(key, PlanKey::of(&run_cfg("gcn")));
    }

    #[test]
    fn cache_never_aliases_depths() {
        let cache = PlanCache::new();
        cache.get_or_compile(&run_cfg("gcn")).unwrap();
        let mut deep = run_cfg("gcn");
        deep.layers = 2;
        let (plan, hit) = cache.get_or_compile(&deep).unwrap();
        assert!(!hit, "a 2-layer run must not reuse the depth-1 plan");
        assert_eq!(plan.depth(), 2);
        let mut hid = deep.clone();
        hid.hidden = vec![8];
        let (plan8, hit) = cache.get_or_compile(&hid).unwrap();
        assert!(!hit, "different hidden widths must not alias");
        assert_eq!((plan8.stages[0].feat_out, plan8.stages[1].feat_in), (8, 8));
        assert_eq!(cache.stats().entries, 3);
    }

    #[test]
    fn multi_layer_plan_shares_one_tiling_and_stacks_stages() {
        let mut run = run_cfg("gat");
        run.layers = 3;
        run.functional = true;
        let plan = ExecPlan::compile(&run).unwrap();
        assert_eq!(plan.depth(), 3);
        assert_eq!(plan.spec.depth(), 3);
        // stage weights are per-layer decorrelated
        assert_ne!(
            plan.stages[0].weights.tensors[0].data,
            plan.stages[1].weights.tensors[0].data
        );
        // hidden layers carry the activation, final is linear
        assert!(plan.spec.layers[0].activation.is_some());
        assert!(plan.spec.layers[2].activation.is_none());
        // chained simulation: per-layer breakdown sums to the total
        let x = plan.make_input(5);
        let res = plan.simulate(&ArchConfig::default(), true, Some(&x), 0).unwrap();
        assert_eq!(res.layers.len(), 3);
        assert_eq!(res.cycles, res.layers.iter().map(|l| l.cycles).sum::<u64>());
        assert_eq!(
            res.dram_read_bytes,
            res.layers.iter().map(|l| l.dram_read_bytes).sum::<u64>()
        );
        let out = res.output.unwrap();
        assert_eq!(out.len(), plan.dims.output_len);
        assert!(out.iter().all(|v| v.is_finite()));
        // aggregate peak covers at least one inter-layer activation image
        let act = plan.dims.num_vertices as u64 * 16 * 4;
        assert!(res.peak_uem_bytes >= act, "{} < {act}", res.peak_uem_bytes);
    }

    #[test]
    fn cache_never_aliases_kernel_policies() {
        let cache = PlanCache::new();
        cache.get_or_compile(&run_cfg("gcn")).unwrap();
        let mut simd_off = run_cfg("gcn");
        simd_off.kernels.simd = !simd_off.kernels.simd;
        let (_, hit) = cache.get_or_compile(&simd_off).unwrap();
        assert!(!hit, "simd policy must not alias in the plan cache");
        let mut skip = run_cfg("gcn");
        skip.kernels.sparse_skip = true;
        let (_, hit) = cache.get_or_compile(&skip).unwrap();
        assert!(!hit, "sparse_skip policy must not alias in the plan cache");
        assert_eq!(cache.stats().entries, 3);
        let key = PlanKey::of(&skip);
        assert!(key.to_string().contains("skip=true"), "{key}");
    }

    #[cfg(feature = "half")]
    #[test]
    fn reduced_precision_plan_quantizes_weights_and_keys_separately() {
        use crate::config::StorageDtype;
        use crate::sim::tensor::{f16_bits_to_f32, f32_to_f16_bits};
        let mut run = run_cfg("gcn");
        run.kernels.dtype = StorageDtype::F16;
        assert_ne!(PlanKey::of(&run), PlanKey::of(&run_cfg("gcn")));
        let plan = ExecPlan::compile(&run).unwrap();
        let f32_plan = ExecPlan::compile(&run_cfg("gcn")).unwrap();
        for (q, full) in plan.stages[0]
            .weights
            .tensors
            .iter()
            .zip(&f32_plan.stages[0].weights.tensors)
        {
            for (&qv, &fv) in q.data.iter().zip(&full.data) {
                assert_eq!(
                    qv.to_bits(),
                    f16_bits_to_f32(f32_to_f16_bits(fv)).to_bits(),
                    "weight not an f16 round-trip of the f32 weight"
                );
            }
        }
    }

    #[test]
    fn cache_never_aliases_pass_sets() {
        let cache = PlanCache::new();
        cache.get_or_compile(&run_cfg("gcn")).unwrap();
        let mut opt = run_cfg("gcn");
        opt.passes = PassSet::all();
        let (plan, hit) = cache.get_or_compile(&opt).unwrap();
        assert!(!hit, "pass sets must not alias in the plan cache");
        assert!(plan.opt_report.is_some());
        let mut partial = run_cfg("gcn");
        partial.passes = PassSet::LOAD_ELIM;
        let (_, hit) = cache.get_or_compile(&partial).unwrap();
        assert!(!hit, "pass subsets must not alias either");
        assert_eq!(cache.stats().entries, 3);
        let key = PlanKey::of(&opt);
        assert!(key.to_string().contains("passes=all"), "{key}");
    }

    #[test]
    fn passes_require_e2v_lowering() {
        let mut bad = run_cfg("gcn");
        bad.e2v = false;
        bad.passes = PassSet::all();
        let err = ExecPlan::compile(&bad).unwrap_err();
        assert!(err.contains("require e2v"), "{err}");
    }

    #[test]
    fn optimized_plan_shrinks_and_matches_baseline() {
        // the ISSUE.md acceptance shape: all passes on, depth-3 GCN —
        // fewer instructions than E2v, bit-identical functional output
        let mut base = run_cfg("gcn");
        base.layers = 3;
        let mut opt = base.clone();
        opt.passes = PassSet::all();
        let baseline = ExecPlan::compile(&base).unwrap();
        let optimized = ExecPlan::compile(&opt).unwrap();
        let count = |p: &ExecPlan| {
            p.stages.iter().map(|s| s.program.instruction_count()).sum::<usize>()
        };
        assert!(
            count(&optimized) < count(&baseline),
            "all-passes depth-3 GCN must drop instructions ({} vs {})",
            count(&optimized),
            count(&baseline)
        );
        let rep = optimized.opt_report.as_ref().unwrap();
        assert_eq!(rep.passes.len(), 4);
        assert!(rep.instructions_after() < rep.instructions_before);
        let x = baseline.make_input(11);
        let arch = ArchConfig::default();
        let a = baseline.simulate(&arch, true, Some(&x), 0).unwrap();
        let b = optimized.simulate(&arch, true, Some(&x), 0).unwrap();
        assert_eq!(a.output, b.output, "optimized plan must be bit-exact");
        assert!(b.cycles <= a.cycles, "optimizer must not cost cycles");
    }

    #[test]
    fn cache_never_aliases_shard_counts() {
        let cache = PlanCache::new();
        cache.get_or_compile(&run_cfg("gcn")).unwrap();
        let mut sharded = run_cfg("gcn");
        sharded.shards = 2;
        let (plan, hit) = cache.get_or_compile(&sharded).unwrap();
        assert!(!hit, "a sharded run must not reuse the unsharded plan");
        let sh = plan.sharding.as_ref().expect("shards=2 plan carries a ShardedPlan");
        assert_eq!(sh.num_shards(), 2);
        assert_eq!(cache.stats().entries, 2);
        let key = PlanKey::of(&sharded);
        assert!(key.to_string().contains("shards=2"), "{key}");
        // shards=1 normalizes into the unsharded key and plan
        let mut one = run_cfg("gcn");
        one.shards = 1;
        let (p1, hit) = cache.get_or_compile(&one).unwrap();
        assert!(hit);
        assert!(p1.sharding.is_none());
    }

    #[test]
    fn cache_never_aliases_overlap() {
        let cache = PlanCache::new();
        let mut serial = run_cfg("gcn");
        serial.shards = 2;
        cache.get_or_compile(&serial).unwrap();
        let mut overlapped = serial.clone();
        overlapped.overlap = true;
        let (_, hit) = cache.get_or_compile(&overlapped).unwrap();
        assert!(!hit, "overlapped and serial sharded plans must not alias");
        assert_eq!(cache.stats().entries, 2);
        let key = PlanKey::of(&overlapped);
        assert!(key.to_string().contains("overlap=true"), "{key}");
        // …but on an unsharded run the knob is inert and normalizes away
        let mut unsharded = run_cfg("gcn");
        unsharded.overlap = true;
        assert_eq!(PlanKey::of(&unsharded), PlanKey::of(&run_cfg("gcn")));
        cache.get_or_compile(&run_cfg("gcn")).unwrap();
        let (_, hit) = cache.get_or_compile(&unsharded).unwrap();
        assert!(hit, "overlap must not fragment the unsharded cache population");
    }

    #[test]
    fn overlap_schedule_matches_brute_force_classification() {
        let mut run = run_cfg("gcn");
        run.layers = 2;
        run.shards = 2;
        let plan = ExecPlan::compile(&run).unwrap();
        let sh = plan.sharding.as_ref().unwrap();
        for (s, sp) in sh.shards.iter().enumerate() {
            let is_core = &sh.partition.shards[s].is_core;
            let mut i = 0usize;
            let (mut n_ind, mut n_dep) = (0u32, 0u32);
            for p in &sp.tiling.partitions {
                for t in &p.tiles {
                    // brute force: any edge whose source is a halo row
                    // makes the tile dependent
                    let dep = t
                        .edges
                        .iter()
                        .any(|&(ls, _)| !is_core[t.src_vertices[ls as usize] as usize]);
                    assert_eq!(
                        sh.overlap.independent[s][i], !dep,
                        "shard {s} tile {i} misclassified"
                    );
                    if dep {
                        n_dep += 1;
                    } else {
                        n_ind += 1;
                    }
                    i += 1;
                }
            }
            assert_eq!(sh.overlap.independent_tiles[s], n_ind);
            assert_eq!(sh.overlap.dependent_tiles[s], n_dep);
            assert_eq!(sh.overlap.independent_work_frac[s].len(), 2);
            for &f in &sh.overlap.independent_work_frac[s] {
                assert!((0.0..=1.0).contains(&f), "work fraction {f} out of range");
            }
            // a shard that imports halo rows must have ≥1 dependent
            // tile: every halo vertex exists because some core dst
            // reads it, and that edge lives in exactly one tile
            if !sh.halo_in[s].is_empty() {
                assert!(n_dep > 0, "shard {s} imports halos but has no dependent tile");
            }
        }
    }

    #[test]
    fn sharded_plan_is_bit_exact_on_both_paths() {
        let mut base = run_cfg("gat");
        base.layers = 2;
        let unsharded = ExecPlan::compile(&base).unwrap();
        let mut sharded_run = base.clone();
        sharded_run.shards = 3;
        let sharded = ExecPlan::compile(&sharded_run).unwrap();
        let x = unsharded.make_input(9);
        let arch = ArchConfig::default();
        let a = unsharded.simulate(&arch, true, Some(&x), 0).unwrap();
        let b = sharded.simulate(&arch, true, Some(&x), 0).unwrap();
        assert_eq!(a.output, b.output, "sharded engine output must be bit-exact");
        assert_eq!(b.halo.exchanges, 1, "depth-2 run has one halo boundary");
        assert!(b.halo.bytes > 0 && b.halo.cycles > 0);
        assert_eq!(b.cycles, b.layers.iter().map(|l| l.cycles).sum::<u64>());
        // batched path agrees too
        let mut scratch = BatchScratch::new();
        let outs = sharded.execute_batch_with(&[&x], 2, &mut scratch).unwrap();
        assert_eq!(Some(&outs[0]), a.output.as_ref());
    }

    #[test]
    fn cache_propagates_compile_errors() {
        let cache = PlanCache::new();
        let mut bad = run_cfg("gcn");
        bad.model = "transformer".into();
        assert!(cache.get_or_compile(&bad).is_err());
        assert_eq!(cache.stats().entries, 0);
    }
}
