//! Compile-once execution plans (the paper's core systems claim).
//!
//! ZIPPER's compiler fixes the expensive decisions — tiling, operator
//! scheduling, buffer assignment — *once* per (model, graph, arch
//! operating point); the runtime then only maps the immutable IR program
//! onto hardware blocks per request. [`ExecPlan`] is that artifact: an
//! `Arc`-able bundle of compiled [`Program`] + [`Tiling`] +
//! [`WeightStore`] + derived dimensions, produced once and shared by any
//! number of concurrent simulation runs. Per-request state lives
//! entirely in the caller's [`ExecScratch`], so serving is re-entrant
//! and allocation-light.
//!
//! [`PlanCache`] is the serving-side cache: a concurrent map from the
//! structured [`PlanKey`] to `Arc<ExecPlan>`, with hit/miss counters so
//! benches can prove warm requests skip recompile/retile entirely.

use crate::compiler::{compile, OptLevel, Program};
use crate::config::{ArchConfig, RunConfig};
use crate::graph::{datasets, Graph};
use crate::models::{ModelKind, WeightStore, NUM_RELATIONS};
use crate::sim::parallel::BatchScratch;
use crate::sim::{ExecScratch, SimOptions, SimResult, Simulator, Workload};
use crate::tiling::{tile, Reorder, Tiling, TilingConfig, TilingMode};
use crate::util::Rng;
use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Structured, stable cache key: every input that changes the compiled
/// artifact. (The old string key formatted `TilingConfig` with `{:?}`
/// and omitted the dataset seed — two different graphs could collide.)
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub model: String,
    pub dataset: String,
    pub scale: u64,
    pub feat_in: u32,
    pub feat_out: u32,
    pub tiling: TilingConfig,
    pub e2v: bool,
    pub seed: u64,
}

impl PlanKey {
    pub fn of(run: &RunConfig) -> PlanKey {
        PlanKey {
            model: run.model.clone(),
            dataset: run.dataset.clone(),
            scale: run.scale,
            feat_in: run.feat_in,
            feat_out: run.feat_out,
            // normalized: `TilingConfig::threads` is a host compile-
            // latency knob that never changes the artifact, so it must
            // not fragment the cache
            tiling: run.tiling.cache_key(),
            e2v: run.e2v,
            seed: run.seed,
        }
    }
}

impl fmt::Display for PlanKey {
    /// Stable structured rendering (log lines, bench JSON).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mode = match self.tiling.mode {
            TilingMode::Regular => "regular",
            TilingMode::Sparse => "sparse",
        };
        let reorder = match self.tiling.reorder {
            Reorder::None => "none",
            Reorder::InDegree => "in_degree",
            Reorder::OutDegree => "out_degree",
        };
        write!(
            f,
            "model={};dataset={};scale={};feat={}x{};dst_part={};src_part={};mode={};reorder={};e2v={};seed={}",
            self.model,
            self.dataset,
            self.scale,
            self.feat_in,
            self.feat_out,
            self.tiling.dst_part,
            self.tiling.src_part,
            mode,
            reorder,
            self.e2v,
            self.seed,
        )
    }
}

/// Dimensions derived at plan-compile time so consumers never recompute
/// them per request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanDims {
    pub num_vertices: u32,
    pub num_edges: u64,
    pub num_partitions: usize,
    pub num_tiles: usize,
    pub max_tile_src: u32,
    pub max_tile_edges: u32,
    /// Length of a flat input embedding vector (V × feat_in).
    pub input_len: usize,
    /// Length of a flat output embedding vector (V × feat_out).
    pub output_len: usize,
}

/// Immutable, shareable execution plan: everything reusable across
/// requests for one (model, graph, tiling, features) operating point.
pub struct ExecPlan {
    pub key: PlanKey,
    pub model: ModelKind,
    pub graph: Graph,
    pub tiling: Tiling,
    pub program: Program,
    pub weights: WeightStore,
    pub feat_in: u32,
    pub feat_out: u32,
    pub dims: PlanDims,
}

impl ExecPlan {
    /// Compile a plan from a run config (dataset registry + compiler).
    pub fn compile(run: &RunConfig) -> Result<ExecPlan, String> {
        let model = ModelKind::parse(&run.model)
            .ok_or_else(|| format!("unknown model {}", run.model))?;
        let spec = datasets::by_id(&run.dataset)
            .ok_or_else(|| format!("unknown dataset {}", run.dataset))?;
        let etypes = if model.uses_etypes() { NUM_RELATIONS } else { 0 };
        let graph = spec.instantiate_typed(run.scale, etypes, run.seed);
        Self::from_graph(model, graph, run)
    }

    /// Compile a plan around an explicit graph (tests, examples).
    pub fn from_graph(model: ModelKind, graph: Graph, run: &RunConfig) -> Result<ExecPlan, String> {
        let feat_out = if model.requires_square() { run.feat_in } else { run.feat_out };
        let tiling = tile(&graph, run.tiling);
        let opt = if run.e2v { OptLevel::E2v } else { OptLevel::None };
        let program = compile(&model.build(), opt).map_err(|e| e.to_string())?;
        let weights = WeightStore::synthesize(&model.build(), run.feat_in, feat_out, run.seed);
        let dims = PlanDims {
            num_vertices: tiling.num_vertices,
            num_edges: tiling.num_edges,
            num_partitions: tiling.partitions.len(),
            num_tiles: tiling.num_tiles(),
            max_tile_src: tiling.max_tile_src(),
            max_tile_edges: tiling.max_tile_edges(),
            input_len: tiling.num_vertices as usize * run.feat_in as usize,
            output_len: tiling.num_vertices as usize * feat_out as usize,
        };
        Ok(ExecPlan {
            key: PlanKey::of(run),
            model,
            graph,
            tiling,
            program,
            weights,
            feat_in: run.feat_in,
            feat_out,
            dims,
        })
    }

    /// Deterministic input embeddings for this plan's graph.
    pub fn make_input(&self, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..self.dims.input_len).map(|_| rng.next_f32_sym() * 0.5).collect()
    }

    /// Borrow this plan as a simulator workload.
    pub fn workload<'a>(&'a self, x: Option<&'a [f32]>) -> Workload<'a> {
        Workload {
            program: &self.program,
            tiling: &self.tiling,
            weights: &self.weights,
            feat_in: self.feat_in,
            feat_out: self.feat_out,
            x,
        }
    }

    /// Run the cycle-level simulation (optionally functional), allocating
    /// fresh scratch. Prefer [`ExecPlan::simulate_with`] on hot paths.
    pub fn simulate(
        &self,
        arch: &ArchConfig,
        functional: bool,
        x: Option<&[f32]>,
        trace_window: u64,
    ) -> Result<SimResult, String> {
        let mut scratch = ExecScratch::new();
        self.simulate_with(arch, functional, x, trace_window, &mut scratch)
    }

    /// Re-entrant simulation: the plan is only read, all run-local state
    /// lives in `scratch`. Any number of threads may call this on the
    /// same `Arc<ExecPlan>` concurrently, each with its own scratch.
    pub fn simulate_with(
        &self,
        arch: &ArchConfig,
        functional: bool,
        x: Option<&[f32]>,
        trace_window: u64,
        scratch: &mut ExecScratch,
    ) -> Result<SimResult, String> {
        let wl = self.workload(x);
        Simulator::new(arch, &wl, SimOptions { functional, trace_window }).run_with(scratch)
    }

    /// Tile-parallel batched functional execution (no timing): one input
    /// embedding per request lane, each partition's tiles sharded across
    /// `exec_threads` OS threads, reductions folded in deterministic tile
    /// order. Returns one output vector per lane, bit-identical for every
    /// `exec_threads` value and batch grouping — and bit-identical to a
    /// functional [`ExecPlan::simulate_with`] run: both executors share
    /// the single instruction-dispatch core (see [`sim::parallel`]).
    /// Timing for these lanes comes from a `functional: false`
    /// [`ExecPlan::simulate_with`] run, which is input-independent.
    ///
    /// [`sim::parallel`]: crate::sim::parallel
    pub fn execute_batch_with(
        &self,
        inputs: &[&[f32]],
        exec_threads: usize,
        scratch: &mut BatchScratch,
    ) -> Result<Vec<Vec<f32>>, String> {
        let wl = self.workload(None);
        crate::sim::parallel::run_batch(&wl, inputs, exec_threads, scratch)
    }
}

/// Snapshot of cache effectiveness counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Concurrent plan cache: compile once per key, share `Arc<ExecPlan>`
/// across workers. Compilation happens outside the map lock so a slow
/// compile never blocks unrelated lookups; if two threads race on the
/// same key the first insert wins and the loser's plan is dropped.
///
/// # Examples
///
/// The second lookup of an identical [`RunConfig`] is a hit and returns
/// the same shared plan:
///
/// ```
/// use zipper::config::RunConfig;
/// use zipper::plan::PlanCache;
///
/// let cache = PlanCache::new();
/// let mut run = RunConfig::default();
/// run.dataset = "CR".into(); // tiny citation-graph stand-in
/// run.scale = 64;
/// run.feat_in = 8;
/// run.feat_out = 8;
///
/// let (first, hit_first) = cache.get_or_compile(&run).unwrap();
/// let (again, hit_again) = cache.get_or_compile(&run).unwrap();
/// assert!(!hit_first && hit_again);
/// assert!(std::sync::Arc::ptr_eq(&first, &again));
/// assert_eq!(cache.stats().entries, 1);
/// ```
pub struct PlanCache {
    plans: Mutex<HashMap<PlanKey, Arc<ExecPlan>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache {
            plans: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Fetch the plan for `run`, compiling it on first use. Returns the
    /// shared plan and whether this call was a cache hit.
    pub fn get_or_compile(&self, run: &RunConfig) -> Result<(Arc<ExecPlan>, bool), String> {
        let key = PlanKey::of(run);
        if let Some(p) = self.lookup(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok((p, true));
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let fresh = Arc::new(ExecPlan::compile(run)?);
        let mut map = self.plans.lock().unwrap_or_else(|p| p.into_inner());
        let entry = map.entry(key).or_insert(fresh);
        Ok((Arc::clone(entry), false))
    }

    fn lookup(&self, key: &PlanKey) -> Option<Arc<ExecPlan>> {
        let map = self.plans.lock().unwrap_or_else(|p| p.into_inner());
        map.get(key).map(Arc::clone)
    }

    pub fn stats(&self) -> CacheStats {
        let entries = self.plans.lock().unwrap_or_else(|p| p.into_inner()).len();
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries,
        }
    }

    pub fn clear(&self) {
        self.plans.lock().unwrap_or_else(|p| p.into_inner()).clear();
    }
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tiling::{Reorder, TilingMode};

    fn run_cfg(model: &str) -> RunConfig {
        RunConfig {
            model: model.into(),
            dataset: "CR".into(),
            scale: 16,
            feat_in: 16,
            feat_out: 16,
            tiling: TilingConfig {
                dst_part: 64,
                src_part: 64,
                mode: TilingMode::Sparse,
                reorder: Reorder::InDegree,
                threads: 1,
            },
            e2v: true,
            functional: false,
            seed: 3,
            serving: Default::default(),
        }
    }

    #[test]
    fn plan_key_is_stable_and_seed_sensitive() {
        let a = PlanKey::of(&run_cfg("gcn"));
        let b = PlanKey::of(&run_cfg("gcn"));
        assert_eq!(a, b);
        let mut other = run_cfg("gcn");
        other.seed = 4;
        assert_ne!(a, PlanKey::of(&other));
        let s = a.to_string();
        assert!(s.contains("model=gcn") && s.contains("seed=3") && s.contains("mode=sparse"));
    }

    #[test]
    fn plan_key_ignores_tiling_threads() {
        // a threaded compile and a serial compile are the same plan
        let a = PlanKey::of(&run_cfg("gcn"));
        let mut threaded = run_cfg("gcn");
        threaded.tiling.threads = 8;
        assert_eq!(a, PlanKey::of(&threaded));
        let cache = PlanCache::new();
        cache.get_or_compile(&run_cfg("gcn")).unwrap();
        let (_, hit) = cache.get_or_compile(&threaded).unwrap();
        assert!(hit, "threads must not fragment the plan cache");
    }

    #[test]
    fn plan_compiles_and_simulates() {
        let plan = ExecPlan::compile(&run_cfg("gat")).unwrap();
        assert!(plan.dims.num_tiles > 0);
        assert_eq!(plan.dims.num_partitions, plan.tiling.partitions.len());
        let x = plan.make_input(7);
        assert_eq!(x.len(), plan.dims.input_len);
        let res = plan.simulate(&ArchConfig::default(), true, Some(&x), 0).unwrap();
        assert!(res.cycles > 0);
        assert_eq!(res.output.unwrap().len(), plan.dims.output_len);
    }

    #[test]
    fn cache_hit_returns_same_plan() {
        let cache = PlanCache::new();
        let (a, hit_a) = cache.get_or_compile(&run_cfg("gcn")).unwrap();
        let (b, hit_b) = cache.get_or_compile(&run_cfg("gcn")).unwrap();
        assert!(!hit_a && hit_b);
        assert!(Arc::ptr_eq(&a, &b));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn cache_miss_on_different_config() {
        let cache = PlanCache::new();
        cache.get_or_compile(&run_cfg("gcn")).unwrap();
        let (_, hit) = cache.get_or_compile(&run_cfg("gat")).unwrap();
        assert!(!hit);
        let mut seeded = run_cfg("gcn");
        seeded.seed = 99;
        let (_, hit) = cache.get_or_compile(&seeded).unwrap();
        assert!(!hit, "different seed must not reuse a cached graph");
        assert_eq!(cache.stats().entries, 3);
    }

    #[test]
    fn cache_propagates_compile_errors() {
        let cache = PlanCache::new();
        let mut bad = run_cfg("gcn");
        bad.model = "transformer".into();
        assert!(cache.get_or_compile(&bad).is_err());
        assert_eq!(cache.stats().entries, 0);
    }
}
