//! Metrics: per-unit utilization counters, phase traces (Fig 3-style),
//! and tabular emitters shared by the benches.

use std::fmt::Write as _;

/// Phase label for trace samples (the paper's Fig 3 annotations).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Gemm,
    Elw,
    Gop,
    Mem,
    Idle,
}

impl Phase {
    pub fn tag(self) -> &'static str {
        match self {
            Phase::Gemm => "GEMM",
            Phase::Elw => "ELW",
            Phase::Gop => "GOP",
            Phase::Mem => "MEM",
            Phase::Idle => "idle",
        }
    }
}

/// One windowed sample of the execution trace.
#[derive(Clone, Copy, Debug)]
pub struct TraceSample {
    pub cycle: u64,
    /// FLOP efficiency in the window: useful FLOPs / peak FLOPs.
    pub flop_eff: f64,
    /// DRAM bandwidth utilization in the window.
    pub dram_util: f64,
    /// Dominant primitive in the window.
    pub phase: Phase,
}

/// Windowed trace recorder. The simulator adds (cycle, flops, bytes,
/// phase-weight) events; samples are aggregated per window.
#[derive(Clone, Debug)]
pub struct Trace {
    window: u64,
    peak_flops_per_cycle: f64,
    peak_bytes_per_cycle: f64,
    // accumulation for the open window
    cur_start: u64,
    cur_flops: f64,
    cur_bytes: f64,
    cur_phase_w: [f64; 4], // Gemm, Elw, Gop, Mem
    pub samples: Vec<TraceSample>,
}

impl Trace {
    pub fn new(window: u64, peak_flops_per_cycle: f64, peak_bytes_per_cycle: f64) -> Self {
        Trace {
            window: window.max(1),
            peak_flops_per_cycle,
            peak_bytes_per_cycle,
            cur_start: 0,
            cur_flops: 0.0,
            cur_bytes: 0.0,
            cur_phase_w: [0.0; 4],
            samples: Vec::new(),
        }
    }

    /// Record `flops` and `bytes` of work occupying [start, end) cycles.
    pub fn record(&mut self, start: u64, end: u64, flops: u64, bytes: u64, phase: Phase) {
        // flush completed windows
        while start >= self.cur_start + self.window {
            self.flush();
        }
        let dur = (end - start).max(1) as f64;
        self.cur_flops += flops as f64;
        self.cur_bytes += bytes as f64;
        let idx = match phase {
            Phase::Gemm => 0,
            Phase::Elw => 1,
            Phase::Gop => 2,
            Phase::Mem => 3,
            Phase::Idle => return,
        };
        self.cur_phase_w[idx] += dur;
    }

    fn flush(&mut self) {
        let w = self.window as f64;
        let dominant = {
            let m = self
                .cur_phase_w
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            if *m.1 == 0.0 {
                Phase::Idle
            } else {
                [Phase::Gemm, Phase::Elw, Phase::Gop, Phase::Mem][m.0]
            }
        };
        self.samples.push(TraceSample {
            cycle: self.cur_start,
            flop_eff: (self.cur_flops / (w * self.peak_flops_per_cycle)).min(1.0),
            dram_util: (self.cur_bytes / (w * self.peak_bytes_per_cycle)).min(1.0),
            phase: dominant,
        });
        self.cur_start += self.window;
        self.cur_flops = 0.0;
        self.cur_bytes = 0.0;
        self.cur_phase_w = [0.0; 4];
    }

    /// Flush the trailing window and return the samples.
    pub fn finish(mut self) -> Vec<TraceSample> {
        self.flush();
        self.samples
    }
}

/// Fixed-width table printer used by every bench (stable, diffable rows).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "{:<w$}  ", c, w = widths[i]);
            }
            out.push('\n');
        };
        fmt_row(&mut out, &self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            fmt_row(&mut out, r);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_windows_and_dominance() {
        let mut t = Trace::new(100, 10.0, 8.0);
        t.record(0, 50, 500, 0, Phase::Gemm); // window 0: 50% flop eff
        t.record(50, 90, 10, 100, Phase::Gop);
        t.record(150, 200, 0, 400, Phase::Mem); // window 1: 50% dram util
        let s = t.finish();
        assert_eq!(s.len(), 2);
        assert!((s[0].flop_eff - 0.51).abs() < 0.01);
        assert_eq!(s[0].phase, Phase::Gemm);
        assert_eq!(s[1].phase, Phase::Mem);
        assert!((s[1].dram_util - 0.5).abs() < 1e-9);
    }

    #[test]
    fn trace_clamps_to_one() {
        let mut t = Trace::new(10, 1.0, 1.0);
        t.record(0, 10, 1_000, 1_000, Phase::Gemm);
        let s = t.finish();
        assert_eq!(s[0].flop_eff, 1.0);
        assert_eq!(s[0].dram_util, 1.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["model", "speedup"]);
        t.row(&["gcn".into(), "93.6x".into()]);
        t.row(&["gat".into(), "1.2x".into()]);
        let r = t.render();
        assert!(r.contains("model"));
        assert!(r.lines().count() == 4);
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["x".into()]);
    }
}
