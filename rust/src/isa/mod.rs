//! ZIPPER ISA (paper Table 2): computational, data-transfer, and
//! synchronization instructions.
//!
//! Instructions are *coarse-grained* — one instruction operates on all
//! edges or vertices of a tile (paper §6.1) — and live in SDE functions
//! shared by every tile. Tile-dependent operand sizes are therefore
//! symbolic (`Dim`): a stream binds a concrete tile at `FCH.TILE` and the
//! dims resolve against that tile's metadata, exactly how the hardware's
//! tile-id operand works.
//!
//! Buffer operands (`BufId`) name slots in the unified embedding memory;
//! the compiler performs the (static) slot assignment per function.

use std::fmt;

/// Embedding-memory buffer slot (compiler-assigned, frame-local).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BufId(pub u16);

/// Model-weight table index. Weights live in the UEM for the whole run
/// (paper §7.1); the per-tile `LD.W` instructions emitted by the compiler
/// model the on-chip UEM → MU weight-buffer fill before each use. The
/// pipeline optimizer's hoist pass restores whole-partition residency by
/// lifting those fills into the dFunction (see `compiler::optimize`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct WeightId(pub u16);

/// Symbolic dimension, resolved against the bound tile / partition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dim {
    Const(u32),
    /// Source vertices of the bound tile.
    TileSrc,
    /// Edges of the bound tile.
    TileEdges,
    /// Destination vertices of the bound partition.
    PartDst,
    FeatIn,
    FeatOut,
}

/// Concrete tile geometry a stream binds at FCH.TILE (plus model feats).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DimCtx {
    pub tile_src: u32,
    pub tile_edges: u32,
    pub part_dst: u32,
    pub feat_in: u32,
    pub feat_out: u32,
}

impl Dim {
    pub fn resolve(self, ctx: &DimCtx) -> u32 {
        match self {
            Dim::Const(c) => c,
            Dim::TileSrc => ctx.tile_src,
            Dim::TileEdges => ctx.tile_edges,
            Dim::PartDst => ctx.part_dst,
            Dim::FeatIn => ctx.feat_in,
            Dim::FeatOut => ctx.feat_out,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ElwUnary {
    Exp,
    Relu,
    LeakyRelu,
    Sigmoid,
    Tanh,
    Neg,
    /// 1 − x (GRU update-gate complement; counts as one VU op).
    OneMinus,
    /// 1 / x.
    Recip,
    /// 1 / x with a zero guard: 0 → 0. The VU's divider returns the
    /// additive identity for empty-gather denominators (destinations
    /// with no in-edges), matching the Gather unit's empty-segment
    /// convention.
    Recip0,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ElwBinary {
    Add,
    Sub,
    Mul,
    Div,
    Max,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Reduce {
    Sum,
    Max,
}

/// Scatter direction (paper: SCTR.OUTE distributes source-vertex data to
/// out-edges; SCTR.INE distributes destination-vertex data to in-edges).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SctrDir {
    OutEdge,
    InEdge,
}

/// LD target (paper: LD.DST / LD.SRC / LD.EDGE).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LdTarget {
    /// Destination-partition embeddings (one per partition).
    Dst,
    /// Tile source-vertex embeddings (per tile; sparse-tiling sensitive).
    Src,
    /// Tile edge list into the Tile Hub (per tile).
    Edge,
    /// Weight slice from the UEM into the MU weight buffer (on-chip
    /// fill, no DRAM traffic; `dst` encodes the *weight-table index*,
    /// not an embedding buffer — see `WeightId`).
    Weight,
}

/// Which stream class a SIGNAL wakes (the paper's SIGNAL.E generalized:
/// our protocol needs d→s, s→e, and e→d wakeups; see compiler docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum StreamClass {
    S,
    E,
    D,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Instr {
    // ---- computational: ELW (VU) ------------------------------------
    ElwU {
        op: ElwUnary,
        src: BufId,
        dst: BufId,
        rows: Dim,
        cols: Dim,
    },
    ElwB {
        op: ElwBinary,
        a: BufId,
        b: BufId,
        dst: BufId,
        rows: Dim,
        cols: Dim,
    },
    /// Broadcast a column vector (rows×1) over a (rows×cols) operand.
    ElwBcast {
        op: ElwBinary,
        a: BufId,
        vec: BufId,
        dst: BufId,
        rows: Dim,
        cols: Dim,
    },
    /// Matrix-vector product: (rows×cols) @ weight(cols×1) → (rows×1).
    Gemv {
        src: BufId,
        weight: WeightId,
        dst: BufId,
        rows: Dim,
        cols: Dim,
    },
    // ---- computational: GEMM (MU) -----------------------------------
    Gemm {
        src: BufId,
        weight: WeightId,
        dst: BufId,
        m: Dim,
        k: Dim,
        n: Dim,
        /// Accumulate into dst instead of overwrite (partition acc).
        accumulate: bool,
        /// Fused activation applied on the MU's output path as results
        /// stream to `dst` (pipeline-optimizer fusion; `None` when the
        /// activation is a separate ELW instruction).
        act: Option<ElwUnary>,
    },
    /// Index-guided batched matmul (R-GCN): per-edge weight selected by
    /// the tile's edge-type array; src is per-edge features.
    Bmm {
        src: BufId,
        weights: WeightId,
        dst: BufId,
        m: Dim,
        k: Dim,
        n: Dim,
    },
    // ---- computational: GOP (VU, edge-list guided) ------------------
    Sctr {
        dir: SctrDir,
        src: BufId,
        dst: BufId,
        cols: Dim,
    },
    Gthr {
        reduce: Reduce,
        src: BufId,
        dst: BufId,
        cols: Dim,
        /// Accumulate into the partition accumulator across tiles.
        accumulate: bool,
    },
    // ---- data transfer ----------------------------------------------
    Ld {
        target: LdTarget,
        dst: BufId,
        rows: Dim,
        cols: Dim,
    },
    St {
        src: BufId,
        rows: Dim,
        cols: Dim,
    },
    // ---- synchronization ---------------------------------------------
    /// Wake one idle stream of the class (paper SIGNAL.E).
    Signal { class: StreamClass },
    /// Block until `count` signals addressed to this stream arrive.
    Wait { count: Dim },
    /// Bind the next tile of the current partition; None left → branch
    /// to `on_empty` offset (relative jump within the function).
    FchTile { on_empty: i32 },
    /// Bind the next partition; none left → halt the stream.
    FchPtt,
    /// Publish partition results / advance partition bookkeeping.
    UpdPtt,
    /// Check whether all tiles of the bound partition completed; if so,
    /// signal the dStream (paper CHK.PTT).
    ChkPtt,
    /// Unconditional relative jump (loop closing; implicit in the
    /// paper's stream semantics, explicit in our encoding).
    Jump(i32),
    Halt,
}

/// Execution resource an instruction occupies (dispatcher routing).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum UnitClass {
    Mu,
    Vu,
    Mem,
    Sync,
}

impl Instr {
    pub fn unit(&self) -> UnitClass {
        match self {
            Instr::Gemm { .. } | Instr::Bmm { .. } => UnitClass::Mu,
            Instr::ElwU { .. }
            | Instr::ElwB { .. }
            | Instr::ElwBcast { .. }
            | Instr::Gemv { .. }
            | Instr::Sctr { .. }
            | Instr::Gthr { .. } => UnitClass::Vu,
            Instr::Ld { .. } | Instr::St { .. } => UnitClass::Mem,
            _ => UnitClass::Sync,
        }
    }

    /// Useful FLOPs of this instruction under `ctx` (energy + baselines).
    pub fn flops(&self, ctx: &DimCtx) -> u64 {
        let r = |d: Dim| d.resolve(ctx) as u64;
        match self {
            Instr::ElwU { rows, cols, .. } => r(*rows) * r(*cols),
            Instr::ElwB { rows, cols, .. } | Instr::ElwBcast { rows, cols, .. } => {
                r(*rows) * r(*cols)
            }
            Instr::Gemv { rows, cols, .. } => 2 * r(*rows) * r(*cols),
            Instr::Gemm { m, k, n, .. } | Instr::Bmm { m, k, n, .. } => {
                2 * r(*m) * r(*k) * r(*n)
            }
            Instr::Sctr { cols, .. } => r(Dim::TileEdges) * r(*cols),
            Instr::Gthr { cols, .. } => r(Dim::TileEdges) * r(*cols),
            _ => 0,
        }
    }

    /// Off-chip bytes moved (data-transfer instructions only).
    pub fn dram_bytes(&self, ctx: &DimCtx) -> u64 {
        let r = |d: Dim| d.resolve(ctx) as u64;
        match self {
            Instr::Ld { target: LdTarget::Edge, .. } => {
                // COO pair per edge (paper stores tiles in COO/CSC)
                r(Dim::TileEdges) * 8
            }
            // weights are UEM-resident (paper §7.1): LD.W is an on-chip
            // fill, never an HBM transfer
            Instr::Ld { target: LdTarget::Weight, .. } => 0,
            Instr::Ld { rows, cols, .. } | Instr::St { rows, cols, .. } => {
                r(*rows) * r(*cols) * 4
            }
            _ => 0,
        }
    }
}

impl fmt::Display for Instr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fn d(x: Dim) -> String {
            match x {
                Dim::Const(c) => c.to_string(),
                Dim::TileSrc => "S".into(),
                Dim::TileEdges => "E".into(),
                Dim::PartDst => "D".into(),
                Dim::FeatIn => "Fi".into(),
                Dim::FeatOut => "Fo".into(),
            }
        }
        match self {
            Instr::ElwU { op, src, dst, rows, cols } => write!(
                f,
                "ELW.{op:?} b{} -> b{} [{}x{}]",
                src.0, dst.0, d(*rows), d(*cols)
            ),
            Instr::ElwB { op, a, b, dst, rows, cols } => write!(
                f,
                "ELW.{op:?} b{} b{} -> b{} [{}x{}]",
                a.0, b.0, dst.0, d(*rows), d(*cols)
            ),
            Instr::ElwBcast { op, a, vec, dst, rows, cols } => write!(
                f,
                "ELW.{op:?}.BCAST b{} v:b{} -> b{} [{}x{}]",
                a.0, vec.0, dst.0, d(*rows), d(*cols)
            ),
            Instr::Gemv { src, weight, dst, rows, cols } => write!(
                f,
                "GEMV b{} w{} -> b{} [{}x{}]",
                src.0, weight.0, dst.0, d(*rows), d(*cols)
            ),
            Instr::Gemm { src, weight, dst, m, k, n, accumulate, act } => write!(
                f,
                "GEMM{}{} b{} w{} -> b{} [{}x{}x{}]",
                if *accumulate { ".ACC" } else { "" },
                act.map(|a| format!(".{a:?}")).unwrap_or_default(),
                src.0, weight.0, dst.0, d(*m), d(*k), d(*n)
            ),
            Instr::Bmm { src, weights, dst, m, k, n } => write!(
                f,
                "BMM b{} w{} -> b{} [{}x{}x{}]",
                src.0, weights.0, dst.0, d(*m), d(*k), d(*n)
            ),
            Instr::Sctr { dir, src, dst, cols } => write!(
                f,
                "SCTR.{} b{} -> b{} [Ex{}]",
                match dir { SctrDir::OutEdge => "OUTE", SctrDir::InEdge => "INE" },
                src.0, dst.0, d(*cols)
            ),
            Instr::Gthr { reduce, src, dst, cols, accumulate } => write!(
                f,
                "GTHR.DST.{}{} b{} -> b{} [Dx{}]",
                match reduce { Reduce::Sum => "SUM", Reduce::Max => "MAX" },
                if *accumulate { ".ACC" } else { "" },
                src.0, dst.0, d(*cols)
            ),
            Instr::Ld { target: LdTarget::Weight, dst, rows, cols } => {
                write!(f, "LD.WGT w{} [{}x{}]", dst.0, d(*rows), d(*cols))
            }
            Instr::Ld { target, dst, rows, cols } => write!(
                f,
                "LD.{} -> b{} [{}x{}]",
                match target {
                    LdTarget::Dst => "DST",
                    LdTarget::Src => "SRC",
                    LdTarget::Edge => "EDGE",
                    LdTarget::Weight => unreachable!(),
                },
                dst.0, d(*rows), d(*cols)
            ),
            Instr::St { src, rows, cols } => {
                write!(f, "ST.DST b{} [{}x{}]", src.0, d(*rows), d(*cols))
            }
            Instr::Signal { class } => write!(f, "SIGNAL.{class:?}"),
            Instr::Wait { count } => write!(f, "WAIT [{}]", d(*count)),
            Instr::FchTile { on_empty } => write!(f, "FCH.TILE (empty->{on_empty:+})"),
            Instr::FchPtt => write!(f, "FCH.PTT"),
            Instr::UpdPtt => write!(f, "UPD.PTT"),
            Instr::ChkPtt => write!(f, "CHK.PTT"),
            Instr::Jump(off) => write!(f, "JUMP {off:+}"),
            Instr::Halt => write!(f, "HALT"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> DimCtx {
        DimCtx { tile_src: 100, tile_edges: 400, part_dst: 64, feat_in: 128, feat_out: 32 }
    }

    #[test]
    fn dims_resolve() {
        let c = ctx();
        assert_eq!(Dim::Const(7).resolve(&c), 7);
        assert_eq!(Dim::TileSrc.resolve(&c), 100);
        assert_eq!(Dim::TileEdges.resolve(&c), 400);
        assert_eq!(Dim::PartDst.resolve(&c), 64);
        assert_eq!(Dim::FeatIn.resolve(&c), 128);
        assert_eq!(Dim::FeatOut.resolve(&c), 32);
    }

    #[test]
    fn unit_routing_matches_table2() {
        // GEMM class → MU; ELW + GOP → VU (paper §7.1 routes GOPs to VU);
        // LD/ST → memory controller; sync → scheduler.
        let gemm = Instr::Gemm {
            src: BufId(0), weight: WeightId(0), dst: BufId(1),
            m: Dim::TileSrc, k: Dim::FeatIn, n: Dim::FeatOut, accumulate: false,
            act: None,
        };
        assert_eq!(gemm.unit(), UnitClass::Mu);
        let gthr = Instr::Gthr {
            reduce: Reduce::Sum, src: BufId(0), dst: BufId(1),
            cols: Dim::FeatOut, accumulate: true,
        };
        assert_eq!(gthr.unit(), UnitClass::Vu);
        let ld = Instr::Ld {
            target: LdTarget::Src, dst: BufId(0),
            rows: Dim::TileSrc, cols: Dim::FeatIn,
        };
        assert_eq!(ld.unit(), UnitClass::Mem);
        assert_eq!(Instr::FchPtt.unit(), UnitClass::Sync);
    }

    #[test]
    fn gemm_flops() {
        let c = ctx();
        let gemm = Instr::Gemm {
            src: BufId(0), weight: WeightId(0), dst: BufId(1),
            m: Dim::TileSrc, k: Dim::FeatIn, n: Dim::FeatOut, accumulate: false,
            act: None,
        };
        assert_eq!(gemm.flops(&c), 2 * 100 * 128 * 32);
    }

    #[test]
    fn ld_bytes() {
        let c = ctx();
        let ld = Instr::Ld {
            target: LdTarget::Src, dst: BufId(0),
            rows: Dim::TileSrc, cols: Dim::FeatIn,
        };
        assert_eq!(ld.dram_bytes(&c), 100 * 128 * 4);
        let lde = Instr::Ld {
            target: LdTarget::Edge, dst: BufId(0),
            rows: Dim::TileEdges, cols: Dim::Const(1),
        };
        assert_eq!(lde.dram_bytes(&c), 400 * 8);
        // LD.W is an on-chip UEM -> MU fill: zero DRAM traffic
        let ldw = Instr::Ld {
            target: LdTarget::Weight, dst: BufId(0),
            rows: Dim::FeatIn, cols: Dim::FeatOut,
        };
        assert_eq!(ldw.dram_bytes(&c), 0);
    }

    #[test]
    fn display_is_readable() {
        let i = Instr::Sctr {
            dir: SctrDir::OutEdge, src: BufId(2), dst: BufId(3), cols: Dim::FeatOut,
        };
        assert_eq!(format!("{i}"), "SCTR.OUTE b2 -> b3 [ExFo]");
        let fused = Instr::Gemm {
            src: BufId(0), weight: WeightId(1), dst: BufId(2),
            m: Dim::PartDst, k: Dim::FeatIn, n: Dim::FeatOut, accumulate: false,
            act: Some(ElwUnary::Relu),
        };
        assert_eq!(format!("{fused}"), "GEMM.Relu b0 w1 -> b2 [DxFixFo]");
        let ldw = Instr::Ld {
            target: LdTarget::Weight, dst: BufId(3),
            rows: Dim::FeatIn, cols: Dim::FeatOut,
        };
        assert_eq!(format!("{ldw}"), "LD.WGT w3 [FixFo]");
    }
}
