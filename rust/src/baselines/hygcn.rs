//! HyGCN baseline (paper §8.4, Fig 14): a fixed two-stage pipeline
//! accelerator specialized for GCN-shaped models.
//!
//! HyGCN couples an *Aggregation* engine (SIMD cores walking edges) to a
//! *Combination* engine (systolic arrays for the dense transform) through
//! a one-directional pipeline. Per the published configuration: 32 SIMD16
//! cores (aggregation), 8 systolic modules of 16×16 (combination),
//! 128 GB/s HBM @ 1 GHz, 22 MB on-chip buffers.
//!
//! The model: a GCN layer is processed in vertex chunks; chunk i's
//! combination overlaps chunk i+1's aggregation (two-stage pipelining),
//! so layer time ≈ max(T_agg, T_comb) + min-stage startup. Because the
//! pipeline is *fixed*, non-GCN interleavings (GAT's edge ELWs between
//! GOPs) cannot be mapped — which is the flexibility argument ZIPPER
//! makes. We only evaluate it on GCN, as the paper does.

/// HyGCN published configuration.
#[derive(Clone, Copy, Debug)]
pub struct HygcnConfig {
    pub freq_hz: f64,
    /// Aggregation SIMD lanes total (32 cores × 16 lanes).
    pub agg_lanes: u64,
    /// Combination MACs/cycle (8 × 16×16 systolic).
    pub comb_macs: u64,
    pub mem_bw: f64,
    pub power_w: f64,
}

impl Default for HygcnConfig {
    fn default() -> Self {
        HygcnConfig {
            freq_hz: 1.0e9,
            agg_lanes: 32 * 16,
            comb_macs: 8 * 16 * 16,
            mem_bw: 128.0e9,
            // Platform power under OUR §8.1 energy methodology (same
            // eDRAM/refresh/HBM-device constants as ZIPPER's model, for
            // 24 MB of buffers + wider aggregation SIMD) — NOT the 6.7 W
            // core-only figure HyGCN published. Consistent accounting is
            // what makes the Fig 14 cross-accelerator energy ratio
            // meaningful.
            power_w: 120.0,
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct HygcnResult {
    pub seconds: f64,
    pub energy_j: f64,
}

/// Run a `layers`-deep GCN on the HyGCN model.
///
/// Per layer: aggregation touches every edge once per feature element
/// (edge-centric sliding window, ~85% window efficiency published);
/// combination is a dense (V × F × F') matmul at ~92% systolic
/// utilization. Off-chip traffic: features once in + once out per layer
/// (their shard cache keeps reuse high on citation graphs).
pub fn run_gcn(
    cfg: &HygcnConfig,
    num_vertices: u64,
    num_edges: u64,
    feats: &[u64], // per-layer widths, len = layers + 1
) -> HygcnResult {
    let mut total = 0.0f64;
    for l in 0..feats.len() - 1 {
        let (f_in, _f_out) = (feats[l] as f64, feats[l + 1] as f64);
        let agg_ops = num_edges as f64 * f_in;
        let t_agg_compute = agg_ops / (cfg.agg_lanes as f64 * 0.85) / cfg.freq_hz;
        let agg_bytes = num_edges as f64 * (4.0 * f_in + 8.0);
        let t_agg_mem = agg_bytes / cfg.mem_bw;
        let t_agg = t_agg_compute.max(t_agg_mem);

        let comb_macs = num_vertices as f64 * f_in * _f_out;
        let t_comb = comb_macs / (cfg.comb_macs as f64 * 0.92) / cfg.freq_hz;

        // two-stage pipeline over chunks: bounded by the slower stage
        let t_layer = t_agg.max(t_comb) + t_agg.min(t_comb) * 0.05;
        total += t_layer;
    }
    HygcnResult { seconds: total, energy_j: total * cfg.power_w }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_layer_gcn_runs() {
        let r = run_gcn(&HygcnConfig::default(), 2_708, 10_556, &[1433, 16, 7]);
        assert!(r.seconds > 0.0 && r.seconds < 1.0);
        assert!(r.energy_j > 0.0);
    }

    #[test]
    fn pipeline_bounded_by_slower_stage() {
        let cfg = HygcnConfig::default();
        // agg-dominated graph (many edges, tiny combination)
        let dense = run_gcn(&cfg, 1_000, 10_000_000, &[64, 64]);
        let sparse = run_gcn(&cfg, 1_000, 1_000, &[64, 64]);
        assert!(dense.seconds > 10.0 * sparse.seconds);
    }

    #[test]
    fn energy_tracks_time() {
        let cfg = HygcnConfig::default();
        let a = run_gcn(&cfg, 10_000, 100_000, &[128, 128]);
        let b = run_gcn(&cfg, 20_000, 200_000, &[128, 128]);
        assert!((b.energy_j / a.energy_j - b.seconds / a.seconds).abs() < 1e-9);
    }
}
