//! Analytic baseline models (DESIGN.md §5 substitutions).
//!
//! The paper benchmarks DGL 0.5 on a 2× Xeon E5-2630 v4 box and an
//! NVIDIA V100, plus the HyGCN accelerator. None of that hardware exists
//! here, so each baseline is an analytic roofline model over the same
//! whole-graph operator list the real frameworks execute: per operator,
//! time = max(flops / (peak·eff_c), bytes / (bw·eff_b)) + launch overhead,
//! with per-class efficiency derates taken from the paper's own Fig 3
//! measurements (GEMM runs near peak, GOPs crawl at a few percent).
//! Energy = active power × time. The *ratios* ZIPPER reports against
//! these baselines (Fig 9/10) are then driven by operator counts — the
//! quantity we reproduce — not by absolute silicon behaviour.

pub mod hygcn;

use crate::ir::{FDim, ModelGraph, Op, Span};
use crate::metrics::Phase;

/// One whole-graph operator: class + work volume.
#[derive(Clone, Copy, Debug)]
pub struct OpCost {
    pub phase: Phase,
    pub flops: f64,
    /// Bytes moved to/from device memory.
    pub bytes: f64,
    /// Bytes of the operator's output (workspace accounting).
    pub out_bytes: f64,
}

/// Expand a model DAG into whole-graph operator costs (classic DGL
/// execution: every op runs over the entire vertex/edge set, §3.2).
pub fn whole_graph_ops(
    model: &ModelGraph,
    num_vertices: u64,
    num_edges: u64,
    feat_in: u64,
    feat_out: u64,
) -> Vec<OpCost> {
    let spans = model.spans().expect("well-typed model");
    let fdims = model.fdims();
    let live = model.live_set();
    let width = |d: FDim| -> f64 {
        match d {
            FDim::In => feat_in as f64,
            FDim::Out => feat_out as f64,
            FDim::One => 1.0,
        }
    };
    let mut ops = Vec::new();
    for n in &model.nodes {
        let i = n.id.0 as usize;
        if !live[i] {
            continue;
        }
        let items = match spans[i] {
            Span::Vertex => num_vertices as f64,
            Span::Edge => num_edges as f64,
            Span::Param => continue,
        };
        let f_out = width(fdims[i]);
        let cost = match &n.op {
            Op::Gemm { x, .. } => {
                let k = width(fdims[x.0 as usize]);
                OpCost {
                    phase: Phase::Gemm,
                    flops: items * 2.0 * k * f_out,
                    bytes: items * 4.0 * (k + f_out),
                    out_bytes: items * 4.0 * f_out,
                }
            }
            Op::Gemv { x, .. } => {
                let k = width(fdims[x.0 as usize]);
                OpCost {
                    phase: Phase::Gemm,
                    flops: items * 2.0 * k,
                    bytes: items * 4.0 * (k + 1.0),
                    out_bytes: items * 4.0,
                }
            }
            Op::BmmByType { e, .. } => {
                let k = width(fdims[e.0 as usize]);
                OpCost {
                    phase: Phase::Gemm,
                    flops: items * 2.0 * k * f_out,
                    // per-edge weight selection makes BMM traffic-heavy
                    bytes: items * 4.0 * (k + f_out + k * f_out / 8.0),
                    out_bytes: items * 4.0 * f_out,
                }
            }
            Op::ElwU { .. } | Op::ElwB { .. } | Op::ElwBcast { .. } => OpCost {
                phase: Phase::Elw,
                flops: items * f_out,
                bytes: items * 4.0 * 2.0 * f_out,
                out_bytes: items * 4.0 * f_out,
            },
            Op::ScatterOut { v } | Op::ScatterIn { v } => {
                let f = width(fdims[v.0 as usize]);
                OpCost {
                    phase: Phase::Gop,
                    flops: num_edges as f64 * f,
                    // random-access vertex reads + edge writes + index reads
                    bytes: num_edges as f64 * (4.0 * 2.0 * f + 8.0),
                    out_bytes: num_edges as f64 * 4.0 * f,
                }
            }
            Op::GatherSum { e } | Op::GatherMax { e } => {
                let f = width(fdims[e.0 as usize]);
                OpCost {
                    phase: Phase::Gop,
                    flops: num_edges as f64 * f,
                    bytes: num_edges as f64 * (4.0 * 2.0 * f + 8.0)
                        + num_vertices as f64 * 4.0 * f,
                    out_bytes: num_vertices as f64 * 4.0 * f,
                }
            }
            Op::InputV { .. } | Op::Weight { .. } | Op::OutputV { .. } => continue,
        };
        ops.push(cost);
    }
    ops
}

/// Per-class execution efficiency (fractions of peak compute / bandwidth).
#[derive(Clone, Copy, Debug)]
pub struct ClassEff {
    pub compute: f64,
    pub bandwidth: f64,
}

/// Analytic device model.
#[derive(Clone, Copy, Debug)]
pub struct DeviceModel {
    pub name: &'static str,
    pub peak_flops: f64,
    pub mem_bw: f64,
    /// Per-operator dispatch overhead (framework + kernel launch).
    pub launch_overhead_s: f64,
    /// Active power draw in watts (energy = power × time).
    pub power_w: f64,
    /// Device memory capacity (OOM modeling); None = host-sized.
    pub mem_cap_bytes: Option<u64>,
    pub gemm: ClassEff,
    pub elw: ClassEff,
    pub gop: ClassEff,
}

impl DeviceModel {
    /// 2× Intel Xeon E5-2630 v4 (paper Table 4): 20 cores @ 2.2 GHz,
    /// AVX2 FMA → ~1.4 TFLOP/s peak, 136 GB/s DDR4.
    pub fn cpu_dgl() -> Self {
        DeviceModel {
            name: "DGL-CPU",
            peak_flops: 1.41e12,
            mem_bw: 136.0e9,
            launch_overhead_s: 20.0e-6,
            power_w: 170.0,
            mem_cap_bytes: None,
            // Fig 3-derived derates: CPU GEMM decent, GOP terrible
            gemm: ClassEff { compute: 0.45, bandwidth: 0.60 },
            elw: ClassEff { compute: 0.08, bandwidth: 0.35 },
            gop: ClassEff { compute: 0.01, bandwidth: 0.04 },
        }
    }

    /// NVIDIA V100 (paper Table 4): 14 TFLOP/s fp32, 900 GB/s HBM2, 32 GB.
    /// Efficiency derates calibrated so the Fig 9 GPU gap lands in the
    /// paper's regime (ZIPPER ≈ 1.5× faster on average): cuSPARSE-class
    /// SpMM kernels reach a healthy fraction of HBM2 bandwidth even
    /// though their FLOP efficiency is low.
    pub fn gpu_dgl() -> Self {
        DeviceModel {
            name: "DGL-GPU",
            peak_flops: 14.0e12,
            mem_bw: 900.0e9,
            launch_overhead_s: 4.0e-6,
            power_w: 250.0,
            mem_cap_bytes: Some(32 * 1024 * 1024 * 1024),
            gemm: ClassEff { compute: 0.65, bandwidth: 0.80 },
            elw: ClassEff { compute: 0.15, bandwidth: 0.80 },
            // F=128 gathers read 512 B rows — largely coalesced on HBM2
            gop: ClassEff { compute: 0.05, bandwidth: 0.65 },
        }
    }

    fn eff(&self, phase: Phase) -> ClassEff {
        match phase {
            Phase::Gemm => self.gemm,
            Phase::Elw => self.elw,
            _ => self.gop,
        }
    }

    /// Execute an operator list; returns timing/energy/footprint.
    pub fn run(&self, ops: &[OpCost], static_bytes: u64) -> DeviceResult {
        let mut seconds = 0.0;
        let mut workspace = 0.0f64;
        let mut segments = Vec::with_capacity(ops.len());
        for op in ops {
            let e = self.eff(op.phase);
            let t_c = op.flops / (self.peak_flops * e.compute);
            let t_b = op.bytes / (self.mem_bw * e.bandwidth);
            let t = t_c.max(t_b) + self.launch_overhead_s;
            segments.push(DeviceSegment {
                phase: op.phase,
                seconds: t,
                flop_eff: (op.flops / t) / self.peak_flops,
                bw_util: (op.bytes / t) / self.mem_bw,
            });
            seconds += t;
            workspace += op.out_bytes;
        }
        let total_bytes = static_bytes + workspace as u64;
        let oom = self.mem_cap_bytes.is_some_and(|cap| total_bytes > cap);
        DeviceResult {
            seconds,
            energy_j: seconds * self.power_w,
            mem_bytes: total_bytes,
            workspace_bytes: workspace as u64,
            oom,
            segments,
        }
    }
}

/// Per-operator segment (drives the Fig 3-style baseline traces).
#[derive(Clone, Copy, Debug)]
pub struct DeviceSegment {
    pub phase: Phase,
    pub seconds: f64,
    pub flop_eff: f64,
    pub bw_util: f64,
}

#[derive(Clone, Debug)]
pub struct DeviceResult {
    pub seconds: f64,
    pub energy_j: f64,
    pub mem_bytes: u64,
    pub workspace_bytes: u64,
    pub oom: bool,
    pub segments: Vec<DeviceSegment>,
}

/// Memory footprint breakdown (Fig 2): classic whole-graph execution.
#[derive(Clone, Copy, Debug, Default)]
pub struct MemBreakdown {
    pub graph_bytes: u64,
    pub weight_bytes: u64,
    pub feature_bytes: u64,
    pub workspace_bytes: u64,
}

impl MemBreakdown {
    pub fn total(&self) -> u64 {
        self.graph_bytes + self.weight_bytes + self.feature_bytes + self.workspace_bytes
    }
}

/// Workspace model matching what DGL/PyG actually materialize:
///   * vertex-span intermediates are kept (autograd graph), full width;
///   * edge-span intermediates materialize only at scalar width (E, 1) —
///     attention scores etc.; *wide* (E, F) tensors are never allocated
///     because the frameworks' fused SpMM/SDDMM kernels (u_mul_e_sum,
///     copy_u_max, edge_softmax) stream them. We therefore account the
///     E2V-optimized graph, whose schedule coincides with the fused
///     kernels DGL dispatches to.
pub fn memory_footprint(
    model: &ModelGraph,
    num_vertices: u64,
    num_edges: u64,
    feat_in: u64,
    feat_out: u64,
) -> MemBreakdown {
    let (model, _) = crate::ir::e2v::optimize(model);
    let model = &model;
    let spans = model.spans().expect("well-typed");
    let fdims = model.fdims();
    let live = model.live_set();
    let mut workspace = 0.0f64;
    for n in &model.nodes {
        let i = n.id.0 as usize;
        if !live[i] {
            continue;
        }
        let is_compute = matches!(
            n.op,
            Op::Gemm { .. }
                | Op::Gemv { .. }
                | Op::BmmByType { .. }
                | Op::ElwU { .. }
                | Op::ElwB { .. }
                | Op::ElwBcast { .. }
                | Op::GatherSum { .. }
                | Op::GatherMax { .. }
        );
        if !is_compute {
            continue;
        }
        let width = match fdims[i] {
            FDim::In => feat_in as f64,
            FDim::Out => feat_out as f64,
            FDim::One => 1.0,
        };
        workspace += match spans[i] {
            Span::Vertex => num_vertices as f64 * 4.0 * width,
            // wide edge tensors are fused away; scalars materialize
            Span::Edge if width <= 1.0 => num_edges as f64 * 4.0,
            Span::Edge => 0.0,
            Span::Param => 0.0,
        };
    }
    let weight_bytes: u64 = model
        .nodes
        .iter()
        .filter_map(|n| match n.op {
            Op::Weight { rows, cols, count, .. } => {
                let w = |d: FDim| match d {
                    FDim::In => feat_in,
                    FDim::Out => feat_out,
                    FDim::One => 1,
                };
                Some(count as u64 * w(rows) * w(cols) * 4)
            }
            _ => None,
        })
        .sum();
    MemBreakdown {
        graph_bytes: num_edges * 8 + num_vertices * 8,
        weight_bytes,
        feature_bytes: num_vertices * 4 * (feat_in + feat_out),
        workspace_bytes: workspace as u64,
    }
}

/// Reference workloads for Fig 2/3 that aren't GNNs: encoded as operator
/// lists with published aggregate characteristics.
pub mod refworkloads {
    use super::OpCost;
    use crate::metrics::Phase;

    /// One PageRank iteration: pure GOP over the edge set (F = 1).
    pub fn pagerank(num_vertices: u64, num_edges: u64) -> Vec<OpCost> {
        let e = num_edges as f64;
        let v = num_vertices as f64;
        vec![
            // scatter ranks to edges
            OpCost { phase: Phase::Gop, flops: e, bytes: e * (8.0 + 8.0), out_bytes: e * 4.0 },
            // gather-sum per destination
            OpCost { phase: Phase::Gop, flops: e, bytes: e * 16.0 + v * 4.0, out_bytes: v * 4.0 },
            // rank update (damping): elementwise over vertices
            OpCost { phase: Phase::Elw, flops: v * 3.0, bytes: v * 12.0, out_bytes: v * 4.0 },
        ]
    }

    /// VGG16 forward, batch 256 @224²: ~15.5 GFLOP/image of conv+FC GEMM
    /// with interleaved ReLU/pool ELW. Encoded as 16 GEMM+ELW pairs.
    pub fn vgg16(batch: u64) -> Vec<OpCost> {
        let total_flops = 15.5e9 * batch as f64 * 2.0;
        let act_bytes = 110.0e6 * 4.0 * batch as f64; // activation traffic
        let norm: f64 = (0..16).map(|j| 2.0 / (j as f64 + 2.0)).sum();
        let mut ops = Vec::new();
        for i in 0..16 {
            // front layers are bigger: harmonic-ish decay
            let share = (2.0 / (i as f64 + 2.0)) / norm;
            let f = total_flops * share;
            let b = act_bytes * share;
            // out_bytes reflects *peak-live* activations (inference frees
            // layer inputs): published V100 footprint ≈ 6.9 GB total.
            ops.push(OpCost { phase: Phase::Gemm, flops: f, bytes: b, out_bytes: b / 20.0 });
            ops.push(OpCost { phase: Phase::Elw, flops: b / 8.0, bytes: b / 2.0, out_bytes: b / 40.0 });
        }
        ops
    }

    /// ResNet-50 forward, batch 256: ~4.1 GFLOP/image, more ELW mixing.
    pub fn resnet50(batch: u64) -> Vec<OpCost> {
        let total_flops = 4.1e9 * batch as f64 * 2.0;
        let act_bytes = 90.0e6 * 4.0 * batch as f64;
        let mut ops = Vec::new();
        for i in 0..50 {
            let share = 1.0 / 50.0;
            ops.push(OpCost {
                phase: Phase::Gemm,
                flops: total_flops * share,
                bytes: act_bytes * share,
                out_bytes: act_bytes * share / 20.0,
            });
            if i % 3 == 0 {
                ops.push(OpCost {
                    phase: Phase::Elw,
                    flops: act_bytes * share / 16.0,
                    bytes: act_bytes * share / 2.0,
                    out_bytes: act_bytes * share / 40.0,
                });
            }
        }
        ops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn gcn_whole_graph_ops() {
        let ops = whole_graph_ops(&models::gcn(), 1_000, 10_000, 128, 128);
        // scatter + gather + gemm
        assert_eq!(ops.len(), 3);
        let gemm: Vec<_> = ops.iter().filter(|o| o.phase == Phase::Gemm).collect();
        assert_eq!(gemm.len(), 1);
        assert!((gemm[0].flops - 1_000.0 * 2.0 * 128.0 * 128.0).abs() < 1.0);
    }

    #[test]
    fn gpu_beats_cpu_on_gemm_heavy() {
        let ops = whole_graph_ops(&models::gcn(), 100_000, 1_000_000, 128, 128);
        let cpu = DeviceModel::cpu_dgl().run(&ops, 0);
        let gpu = DeviceModel::gpu_dgl().run(&ops, 0);
        assert!(gpu.seconds < cpu.seconds);
        assert!(cpu.seconds > 0.0 && gpu.energy_j > 0.0);
    }

    #[test]
    fn gop_bound_ops_run_far_below_peak() {
        let ops = refworkloads::pagerank(1_000_000, 10_000_000);
        let gpu = DeviceModel::gpu_dgl().run(&ops, 0);
        for seg in &gpu.segments {
            if seg.phase == Phase::Gop {
                assert!(seg.flop_eff < 0.05, "GOP flop eff {}", seg.flop_eff);
            }
        }
    }

    #[test]
    fn memory_footprint_matches_fig2_shape() {
        // the paper's Observation 1: GNN footprint dwarfs PageRank's on
        // the same graph, dominated by workspace + wide features; yet
        // CP/SL still fit a 32 GB V100 (the paper ran them there).
        const GB: u64 = 1024 * 1024 * 1024;
        let mb = memory_footprint(&models::sage(), 4_847_571, 43_369_619, 128, 128);
        assert!(mb.workspace_bytes > mb.graph_bytes);
        assert!(mb.total() > 8 * GB, "SAGE/SL in the paper's ~16 GB regime");
        assert!(mb.total() < 32 * GB, "SAGE/SL must fit the V100");
        let pr_bytes = 4_847_571u64 * 16 + 43_369_619 * 8;
        assert!(mb.total() > 5 * pr_bytes, "GNN >> PageRank");
    }

    #[test]
    fn gnn_ooms_on_eo_but_pagerank_does_not() {
        // Fig 2: GAT/SAGE OOM on europe-osm (32 GB cap); PageRank fits
        const GB: u64 = 1024 * 1024 * 1024;
        let (v, e) = (50_912_018u64, 54_054_660u64);
        for m in [models::gat(), models::sage()] {
            assert!(memory_footprint(&m, v, e, 128, 128).total() > 32 * GB);
        }
        let gpu = DeviceModel::gpu_dgl();
        let pr = gpu.run(&refworkloads::pagerank(v, e), v * 8 + e * 8);
        assert!(!pr.oom, "PageRank on EO must fit");
    }

    #[test]
    fn vgg_is_gemm_dominated() {
        let ops = refworkloads::vgg16(256);
        let gemm_t: f64 = ops.iter().filter(|o| o.phase == Phase::Gemm).map(|o| o.flops).sum();
        let elw_t: f64 = ops.iter().filter(|o| o.phase == Phase::Elw).map(|o| o.flops).sum();
        assert!(gemm_t > 10.0 * elw_t);
    }
}
