//! `zipper` CLI — leader entrypoint for the ZIPPER reproduction.
//!
//! Subcommands:
//!   config    show the effective architecture/run configuration
//!   compile   compile a model to SDE functions and print the listing
//!   run       tile + simulate one (model, dataset) and print metrics
//!   serve     serve a batch of inference requests via the coordinator
//!   validate  cross-validate simulator vs PJRT artifacts (all models)
//!   datasets  list the dataset registry
//!
//! Arguments are `--key value` pairs (dependency-free parser; see
//! `Args`). `--config FILE` loads an INI/TOML-lite document first; CLI
//! flags override it.

use std::collections::HashMap;
use std::path::Path;
use std::process::ExitCode;

use zipper::compiler::{compile, optimize_pipeline, OptLevel, PassSet};
use zipper::config::{self, ArchConfig, OverflowPolicy, RunConfig, StorageDtype};
use zipper::coordinator::{validate, Coordinator, InferenceRequest, Session};
use zipper::energy::EnergyModel;
use zipper::graph::datasets;
use zipper::metrics::Table;
use zipper::models::ModelKind;
use zipper::runtime::{Runtime, TileShape};
use zipper::util;

/// Minimal `--key value` / `--flag` argument parser.
struct Args {
    positional: Vec<String>,
    named: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut named = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let next_is_value =
                    argv.get(i + 1).map(|v| !v.starts_with("--")).unwrap_or(false);
                if next_is_value {
                    named.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    named.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, named }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.named.get(key).map(|s| s.as_str())
    }

    fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

fn build_configs(args: &Args) -> Result<(ArchConfig, RunConfig), String> {
    let mut arch = ArchConfig::default();
    let mut run = RunConfig::default();
    if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        config::apply(&text, &mut arch, &mut run).map_err(|e| e.to_string())?;
    }
    if let Some(v) = args.get("model") {
        run.model = v.to_string();
    }
    if let Some(v) = args.get("dataset") {
        run.dataset = v.to_string();
    }
    if let Some(v) = args.get("scale") {
        run.scale = v.parse().map_err(|_| "bad --scale")?;
    }
    if let Some(v) = args.get("feat") {
        let f: u32 = v.parse().map_err(|_| "bad --feat")?;
        run.feat_in = f;
        run.feat_out = f;
    }
    if let Some(v) = args.get("layers") {
        run.layers = v.parse().map_err(|_| "bad --layers")?;
    }
    if let Some(v) = args.get("hidden") {
        run.hidden = v
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(|s| s.parse::<u32>().map_err(|_| "bad --hidden"))
            .collect::<Result<Vec<u32>, _>>()?;
    }
    if let Some(v) = args.get("threads") {
        run.tiling.threads = v.parse().map_err(|_| "bad --threads")?;
    }
    if let Some(v) = args.get("shards") {
        run.shards = v.parse().map_err(|_| "bad --shards")?;
        if run.shards == 0 {
            return Err("bad --shards (must be >= 1)".into());
        }
    }
    if args.flag("overlap") {
        run.overlap = true;
    }
    if args.flag("no-overlap") {
        run.overlap = false;
    }
    if let Some(v) = args.get("exec-threads") {
        run.serving.exec_threads = v.parse().map_err(|_| "bad --exec-threads")?;
    }
    if let Some(v) = args.get("max-batch") {
        run.serving.max_batch = v.parse().map_err(|_| "bad --max-batch")?;
    }
    if let Some(v) = args.get("max-wait-us") {
        run.serving.max_wait_us = v.parse().map_err(|_| "bad --max-wait-us")?;
    }
    if let Some(v) = args.get("queue-cap") {
        run.serving.queue_cap = v.parse().map_err(|_| "bad --queue-cap")?;
    }
    if let Some(v) = args.get("overflow") {
        run.serving.overflow =
            OverflowPolicy::parse(v).ok_or("bad --overflow (reject | block)")?;
    }
    if let Some(v) = args.get("deadline-us") {
        run.serving.default_deadline_us = v.parse().map_err(|_| "bad --deadline-us")?;
    }
    if let Some(v) = args.get("s-streams") {
        arch.s_streams = v.parse().map_err(|_| "bad --s-streams")?;
    }
    if let Some(v) = args.get("e-streams") {
        arch.e_streams = v.parse().map_err(|_| "bad --e-streams")?;
    }
    if let Some(v) = args.get("mu") {
        arch.mu_count = v.parse().map_err(|_| "bad --mu")?;
    }
    if let Some(v) = args.get("vu") {
        arch.vu_count = v.parse().map_err(|_| "bad --vu")?;
    }
    if let Some(v) = args.get("dtype") {
        run.kernels.dtype = StorageDtype::parse(v).ok_or("bad --dtype (f32 | f16 | bf16)")?;
    }
    if args.flag("simd") {
        run.kernels.simd = true;
    }
    if args.flag("no-simd") {
        run.kernels.simd = false;
    }
    if args.flag("sparse-skip") {
        run.kernels.sparse_skip = true;
    }
    if args.flag("no-e2v") {
        run.e2v = false;
    }
    if let Some(v) = args.get("passes") {
        run.passes = PassSet::parse(v)
            .ok_or("bad --passes (all | none | comma list of load_elim,fuse,hoist,dbe)")?;
    }
    if args.flag("functional") {
        run.functional = true;
    }
    run.kernels.validate().map_err(|e| e.to_string())?;
    Ok((arch, run))
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match real_main(&argv) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn real_main(argv: &[String]) -> Result<(), String> {
    let args = Args::parse(argv);
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "config" => {
            let (arch, run) = build_configs(&args)?;
            print!("{}", config::show(&arch, &run));
            Ok(())
        }
        "datasets" => {
            let mut t = Table::new(&["id", "name", "|V|", "|E|", "type"]);
            for d in datasets::TABLE3.iter().chain(datasets::HYGCN_SETS.iter()) {
                t.row(&[
                    d.id.into(),
                    d.name.into(),
                    d.vertices.to_string(),
                    d.edges.to_string(),
                    d.kind.into(),
                ]);
            }
            print!("{}", t.render());
            Ok(())
        }
        "compile" => {
            let (_, run) = build_configs(&args)?;
            let model = ModelKind::parse(&run.model)
                .ok_or_else(|| format!("unknown model {}", run.model))?;
            if !run.passes.is_empty() && !run.e2v {
                return Err("--passes requires e2v lowering (drop --no-e2v)".into());
            }
            let spec = zipper::models::ModelSpec::new(
                model,
                run.feat_in,
                &run.hidden,
                run.feat_out,
                run.layers,
            )?;
            let opt = if !run.e2v {
                OptLevel::None
            } else if run.passes.is_empty() {
                OptLevel::E2v
            } else {
                OptLevel::Pipeline(run.passes)
            };
            let mut programs = Vec::with_capacity(spec.depth());
            for l in 0..spec.depth() {
                programs
                    .push(compile(&spec.build_layer(l), opt).map_err(|e| e.to_string())?);
            }
            let report = (!run.passes.is_empty())
                .then(|| optimize_pipeline(&mut programs, run.passes));
            for (l, p) in programs.iter().enumerate() {
                if programs.len() > 1 {
                    let lay = &spec.layers[l];
                    println!("; ===== layer {l}: {}x{} =====", lay.feat_in, lay.feat_out);
                }
                println!("{}", p.disassemble());
                if let Some(stats) = p.e2v {
                    println!(
                        "; e2v: hoisted {} ops in {} rounds",
                        stats.hoisted, stats.rounds
                    );
                }
            }
            if let Some(rep) = report {
                println!(
                    "; pipeline optimizer ({}): {} -> {} instructions",
                    run.passes,
                    rep.instructions_before,
                    rep.instructions_after()
                );
                print!("{rep}");
            }
            Ok(())
        }
        "run" => {
            let (arch, run) = build_configs(&args)?;
            let session = Session::prepare(&run)?;
            let x;
            let input = if run.functional {
                x = session.make_input(run.seed);
                Some(x.as_slice())
            } else {
                None
            };
            let t0 = std::time::Instant::now();
            let res = session.simulate(&arch, run.functional, input, 0)?;
            let wall = t0.elapsed().as_secs_f64();
            let e = EnergyModel::default().evaluate(&res.counters, arch.freq_hz);
            println!("model={} dataset={} scale=1/{}", run.model, run.dataset, run.scale);
            println!(
                "graph: |V|={} |E|={}  tiles={} (mode {:?}, reorder {:?})",
                session.graph().num_vertices(),
                session.graph().num_edges(),
                session.tiling().num_tiles(),
                run.tiling.mode,
                run.tiling.reorder,
            );
            println!(
                "cycles={} ({})  instructions={}",
                res.cycles,
                util::fmt_time_at(res.cycles, arch.freq_hz),
                res.instructions
            );
            // sharded runs sum busy counters over K chips
            let chips = run.shards.max(1) as f64;
            println!(
                "busy: MU {:.1}%  VU {:.1}%  MEM {:.1}%",
                100.0 * res.mu_busy as f64
                    / (res.cycles.max(1) as f64 * arch.mu_count as f64 * chips),
                100.0 * res.vu_busy as f64
                    / (res.cycles.max(1) as f64 * arch.vu_count as f64 * chips),
                100.0 * res.mem_busy as f64 / (res.cycles.max(1) as f64 * chips),
            );
            println!(
                "dram: read {} write {}",
                util::fmt_bytes(res.dram_read_bytes),
                util::fmt_bytes(res.dram_write_bytes)
            );
            if res.halo.exchanges > 0 {
                println!(
                    "halo: {} shards  {} exchanges  {} vertex-copies  {} chip-to-chip  \
                     ({} cycles: {} exposed, {} hidden)",
                    run.shards,
                    res.halo.exchanges,
                    res.halo.vertices,
                    util::fmt_bytes(res.halo.bytes),
                    res.halo.cycles,
                    res.halo.exposed_cycles,
                    res.halo.hidden_cycles,
                );
            }
            println!(
                "energy: {:.6} J (hbm {:.1}%)",
                e.total_j(),
                100.0 * e.hbm_j / e.total_j()
            );
            if res.layers.len() > 1 {
                println!(
                    "layer pipeline: depth {} (peak UEM incl. inter-layer activations: {})",
                    res.layers.len(),
                    util::fmt_bytes(res.peak_uem_bytes)
                );
                for (l, lm) in res.layers.iter().enumerate() {
                    println!(
                        "  layer {l}: {}x{}  cycles={}  dram r/w {} / {}",
                        lm.feat_in,
                        lm.feat_out,
                        lm.cycles,
                        util::fmt_bytes(lm.dram_read_bytes),
                        util::fmt_bytes(lm.dram_write_bytes),
                    );
                }
            }
            if let Some(out) = res.output {
                let sum: f64 = out.iter().map(|&v| v as f64).sum();
                println!("output checksum: {sum:.6}");
            }
            println!("host wall time: {wall:.3}s");
            Ok(())
        }
        "serve" => {
            let (arch, run) = build_configs(&args)?;
            let n: u64 = args
                .get("requests")
                .unwrap_or("16")
                .parse()
                .map_err(|_| "bad --requests")?;
            let workers: usize = args
                .get("workers")
                .unwrap_or("4")
                .parse()
                .map_err(|_| "bad --workers")?;
            let models = ["gcn", "gat", "sage", "ggnn", "rgcn"];
            let mut c = Coordinator::with_serving(
                arch,
                workers,
                run.serving,
                std::sync::Arc::new(zipper::plan::PlanCache::new()),
            );
            let t0 = std::time::Instant::now();
            for i in 0..n {
                let mut r = run.clone();
                r.model = models[i as usize % models.len()].to_string();
                c.submit(InferenceRequest { id: i, run: r, input_seed: i });
            }
            let mut resp = c.drain();
            let wall = t0.elapsed().as_secs_f64();
            resp.sort_by_key(|r| r.id);
            let mut t = Table::new(&[
                "id", "model", "sim cycles", "sim time", "energy", "wall", "queue", "batch",
            ]);
            for r in &resp {
                t.row(&[
                    r.id.to_string(),
                    r.model.clone(),
                    r.sim_cycles.to_string(),
                    format!("{:.3} ms", r.sim_seconds * 1e3),
                    format!("{:.3} mJ", r.energy_j * 1e3),
                    format!("{:.1} ms", r.wall_seconds * 1e3),
                    format!("{:.1} ms", r.queue_seconds * 1e3),
                    r.batch_size.to_string(),
                ]);
            }
            print!("{}", t.render());
            let errors = resp.iter().filter(|r| r.error.is_some()).count();
            println!(
                "served {n} requests on {workers} workers in {wall:.3}s \
                 ({:.1} req/s), {errors} errors",
                n as f64 / wall
            );
            println!(
                "batching: max_batch={} exec_threads={} max_wait_us={} \
                 queue_cap={} overflow={} deadline_us={}",
                run.serving.max_batch,
                run.serving.exec_threads,
                run.serving.max_wait_us,
                run.serving.queue_cap,
                run.serving.overflow.name(),
                run.serving.default_deadline_us
            );
            if let Some(m) = c.last_metrics() {
                println!(
                    "service: p50/p95/p99 latency {}/{}/{} us, peak queue {}, \
                     mean batch {:.2}, shed {} ({:.1}%)",
                    m.latency_p50_us,
                    m.latency_p95_us,
                    m.latency_p99_us,
                    m.peak_queue_depth,
                    m.mean_batch_size(),
                    m.rejected_total(),
                    100.0 * m.shed_rate()
                );
            }
            if run.layers > 1 {
                if let Some(r) = resp.iter().find(|r| r.error.is_none()) {
                    let per: Vec<String> =
                        r.layers.iter().map(|l| l.cycles.to_string()).collect();
                    println!(
                        "layer pipeline: depth {} — per-layer cycles [{}], peak UEM {}",
                        run.layers,
                        per.join(", "),
                        util::fmt_bytes(r.peak_uem_bytes)
                    );
                }
            }
            let stats = c.cache_stats();
            println!(
                "plan cache: {} plans compiled once, {} warm hits ({:.0}% hit rate)",
                stats.entries,
                stats.hits,
                100.0 * stats.hit_rate()
            );
            Ok(())
        }
        "validate" => {
            let dir = args.get("artifacts").unwrap_or("artifacts");
            let mut rt = Runtime::new(Path::new(dir)).map_err(|e| e.to_string())?;
            println!("PJRT platform: {}", rt.platform());
            if !rt.available() {
                return Err(
                    "PJRT backend not linked into this build; `validate` needs the \
                     oracle runtime (see rust/src/runtime docs)"
                        .into(),
                );
            }
            let shape = TileShape {
                num_src: 64,
                num_dst: 64,
                num_edges: 256,
                feat_in: 32,
                feat_out: 32,
            };
            let reports =
                validate::validate_all(&mut rt, &shape, 17).map_err(|e| e.to_string())?;
            let mut t =
                Table::new(&["model", "partitions", "rows", "max err", "mean err", "pass"]);
            let mut all_pass = true;
            for r in &reports {
                all_pass &= r.pass;
                t.row(&[
                    r.model.clone(),
                    r.partitions.to_string(),
                    r.rows_compared.to_string(),
                    format!("{:.2e}", r.max_abs_err),
                    format!("{:.2e}", r.mean_abs_err),
                    if r.pass { "ok".into() } else { "FAIL".into() },
                ]);
            }
            print!("{}", t.render());
            if all_pass {
                println!("all models match the PJRT oracle");
                Ok(())
            } else {
                Err("validation failed".into())
            }
        }
        _ => {
            println!(
                "zipper — tile- and operator-level parallel GNN acceleration\n\n\
                 usage: zipper <command> [--key value ...]\n\n\
                 commands:\n  \
                 config    show effective configuration (--config FILE to load)\n  \
                 datasets  list the dataset registry (paper Table 3 + HyGCN sets)\n  \
                 compile   print SDE functions (--model gat [--no-e2v])\n  \
                 run       simulate one (model, dataset) and print metrics\n  \
                 serve     serve a request batch through the coordinator pool\n  \
                 validate  cross-validate simulator vs PJRT artifacts\n            \
                 (--artifacts DIR, default artifacts/)\n\n\
                 common flags (config file section in brackets):\n  \
                 --config FILE        load an INI/TOML-lite config first; flags override\n  \
                 --model M            gcn | gat | sage | ggnn | rgcn       [run]\n  \
                 --dataset D          registry id, see `zipper datasets`   [run]\n  \
                 --scale N            dataset scale divisor (1/N size)     [run]\n  \
                 --feat F             feature width (sets feat_in=feat_out) [run]\n  \
                 --layers N           stacked GNN layers compiled into one plan\n                       \
                 sharing a single tiling; hidden layers are\n                       \
                 ReLU-activated, the final layer linear\n                       \
                 (default 1)                          [run]\n  \
                 --hidden d1,d2,...   hidden widths between layers (exactly\n                       \
                 layers-1 entries; default: feat_out) [run]\n  \
                 --no-e2v             disable the E2V compiler optimization\n  \
                 --passes P           pipeline-optimizer passes run over the whole\n                       \
                 compiled layer stack: all | none | comma\n                       \
                 list of load_elim,fuse,hoist,dbe\n                       \
                 (requires e2v; default none)         [run]\n  \
                 --shards K           multi-chip sharded execution: partition the\n                       \
                 graph across K chips with per-layer halo\n                       \
                 exchange; outputs stay bit-exact\n                       \
                 (default 1 = unsharded)              [run]\n  \
                 --overlap            hide the halo exchange behind the next\n                       \
                 layer's halo-independent tiles (K >= 2;\n                       \
                 timing only, outputs stay bit-exact)  [run]\n  \
                 --functional         also execute on f32 embeddings (checksums)\n  \
                 --simd / --no-simd   force the SIMD kernel variants on or off\n                       \
                 (default: on when built with the `simd`\n                       \
                 feature; bit-exact either way)     [kernels]\n  \
                 --sparse-skip        skip empty 8-row source blocks inside\n                       \
                 partially occupied tiles (timing and\n                       \
                 DRAM credit; outputs unchanged)    [kernels]\n  \
                 --dtype D            f32 | f16 | bf16 storage for weights and\n                       \
                 hidden activations (16-bit needs the\n                       \
                 `half` feature; f32 accumulate)    [kernels]\n  \
                 --mu N / --vu N      matrix / vector unit counts          [arch]\n  \
                 --s-streams N / --e-streams N   stream counts             [arch]\n\n\
                 serving flags (serve; all host-side, never change outputs):\n  \
                 --requests N         number of inference requests (default 16)\n  \
                 --workers N          coordinator worker threads (default 4)\n  \
                 --max-batch N        group up to N queued requests sharing one\n                       \
                 compiled plan into a single batched pass\n                       \
                 (default 1 = no batching)            [serving]\n  \
                 --exec-threads N     tile-parallel functional execution threads\n                       \
                 per batch; outputs are bit-identical for\n                       \
                 every value (default 1)              [serving]\n  \
                 --max-wait-us N      flush a partially filled batch after N us\n                       \
                 (default 0 = hold until fill/drain)  [serving]\n  \
                 --queue-cap N        bounded admission queue depth\n                       \
                 (default 1024)                       [serving]\n  \
                 --overflow P         reject | block when the queue is full\n                       \
                 (default reject)                     [serving]\n  \
                 --deadline-us N      per-request latency budget; expired\n                       \
                 requests are shed with a structured\n                       \
                 reject reason (default 0 = none)     [serving]\n  \
                 --threads N          OS threads for parallel tiling when a plan\n                       \
                 is compiled (cold-start latency knob) [tiling]"
            );
            Ok(())
        }
    }
}
