//! Synthetic graph generators matched to the paper's dataset families.
//!
//! Three degree shapes cover Table 3 (DESIGN.md §5 substitution table):
//!   * `power_law`  — RMAT-flavoured preferential attachment for the
//!     social/collaboration/citation graphs (SL, HW, CP, AD, plus the
//!     HyGCN citation sets). Heavy-tailed in- and out-degrees.
//!   * `street_mesh` — near-uniform degree ≈ 1–3 lattice with local
//!     shortcuts for europe-osm (EO): huge V, E ≈ V, almost no skew.
//!   * `uniform`    — Erdős–Rényi-style for small control graphs (AK).
//!
//! All generators are deterministic in (shape parameters, seed).

use super::{Graph, GraphBuilder};
use crate::util::Rng;

/// RMAT-style power-law digraph: vertices get Zipf-ranked endpoint
/// probabilities on both sides, with a skew knob per side.
///
/// `alpha_in` / `alpha_out` ≈ 1.0–1.4 give social-network-like tails.
pub fn power_law(
    num_vertices: u32,
    num_edges: u64,
    alpha_in: f64,
    alpha_out: f64,
    num_etypes: u8,
    seed: u64,
) -> Graph {
    assert!(num_vertices > 0);
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::with_capacity(num_vertices, num_edges as usize);
    if num_etypes > 0 {
        b.with_etypes();
    }
    // Random rank→vertex maps so the heavy hitters aren't ids 0..k —
    // vertex ids carry no degree information until reordering (§5.3),
    // exactly the situation the paper's Degree Sorting exploits.
    let mut rank_to_v_in: Vec<u32> = (0..num_vertices).collect();
    let mut rank_to_v_out: Vec<u32> = (0..num_vertices).collect();
    rng.shuffle(&mut rank_to_v_in);
    rng.shuffle(&mut rank_to_v_out);
    for _ in 0..num_edges {
        let s = rank_to_v_out[rng.zipf(num_vertices as u64, alpha_out) as usize];
        let d = rank_to_v_in[rng.zipf(num_vertices as u64, alpha_in) as usize];
        let t = if num_etypes > 0 {
            rng.below(num_etypes as u64) as u8
        } else {
            0
        };
        b.add_edge_typed(s, d, t).expect("zipf ranks stay in range");
    }
    b.build()
}

/// R-MAT recursive-quadrant power-law digraph (Chakrabarti et al.),
/// scaled for the sharding benches: 2^scale_log2 vertices, built through
/// the streaming two-pass constructor so the 1M-vertex × 8M-edge graph
/// never materializes an unsorted edge list (saves ~9 bytes/edge peak).
///
/// Quadrant probabilities (a,b,c,d) = (0.57, 0.19, 0.19, 0.05) — the
/// canonical social-network setting. Deterministic in `seed`: the RNG is
/// recreated inside the stream closure, so both passes see the identical
/// edge sequence.
pub fn rmat(scale_log2: u32, num_edges: u64, seed: u64) -> Graph {
    rmat_typed(scale_log2, num_edges, 0, seed)
}

pub fn rmat_typed(scale_log2: u32, num_edges: u64, num_etypes: u8, seed: u64) -> Graph {
    assert!((1..=31).contains(&scale_log2), "scale_log2 must be in 1..=31");
    let n = 1u32 << scale_log2;
    Graph::from_edge_stream(n, num_etypes > 0, |emit| {
        let mut rng = Rng::new(seed);
        for _ in 0..num_edges {
            let (mut s, mut d) = (0u32, 0u32);
            for _ in 0..scale_log2 {
                let r = rng.below(100);
                let (bs, bd) = if r < 57 {
                    (0, 0)
                } else if r < 76 {
                    (0, 1)
                } else if r < 95 {
                    (1, 0)
                } else {
                    (1, 1)
                };
                s = (s << 1) | bs;
                d = (d << 1) | bd;
            }
            let t = if num_etypes > 0 {
                rng.below(num_etypes as u64) as u8
            } else {
                0
            };
            emit(s, d, t);
        }
    })
    .expect("rmat quadrant descent stays in 0..2^scale")
}

/// Street-network-like mesh: a ring + nearest-neighbour lattice with a
/// small fraction of short-range chords. Degree is nearly uniform and
/// tiny (europe-osm has mean degree ≈ 1.06).
pub fn street_mesh(num_vertices: u32, num_edges: u64, seed: u64) -> Graph {
    street_mesh_typed(num_vertices, num_edges, 0, seed)
}

pub fn street_mesh_typed(
    num_vertices: u32,
    num_edges: u64,
    num_etypes: u8,
    seed: u64,
) -> Graph {
    assert!(num_vertices > 1);
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::with_capacity(num_vertices, num_edges as usize);
    if num_etypes > 0 {
        b.with_etypes();
    }
    let etype = |rng: &mut Rng| {
        if num_etypes > 0 {
            rng.below(num_etypes as u64) as u8
        } else {
            0
        }
    };
    let n = num_vertices as u64;
    let mut added = 0u64;
    // ring backbone first (up to num_edges)
    let backbone = n.min(num_edges);
    for v in 0..backbone {
        let t = etype(&mut rng);
        b.add_edge_typed(v as u32, ((v + 1) % n) as u32, t)
            .expect("ring endpoints wrap in range");
        added += 1;
    }
    // local chords: distance ≤ 8 hops, uniform endpoints
    while added < num_edges {
        let v = rng.below(n);
        let hop = 2 + rng.below(7);
        let t = etype(&mut rng);
        b.add_edge_typed(v as u32, ((v + hop) % n) as u32, t)
            .expect("chord endpoints wrap in range");
        added += 1;
    }
    b.build()
}

/// Erdős–Rényi-style uniform digraph (fixed edge count).
pub fn uniform(num_vertices: u32, num_edges: u64, seed: u64) -> Graph {
    uniform_typed(num_vertices, num_edges, 0, seed)
}

pub fn uniform_typed(
    num_vertices: u32,
    num_edges: u64,
    num_etypes: u8,
    seed: u64,
) -> Graph {
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::with_capacity(num_vertices, num_edges as usize);
    if num_etypes > 0 {
        b.with_etypes();
    }
    for _ in 0..num_edges {
        let s = rng.below(num_vertices as u64) as u32;
        let d = rng.below(num_vertices as u64) as u32;
        let t = if num_etypes > 0 { rng.below(num_etypes as u64) as u8 } else { 0 };
        b.add_edge_typed(s, d, t).expect("uniform draws stay below |V|");
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_law_counts_and_skew() {
        let g = power_law(2_000, 20_000, 1.2, 1.2, 0, 1);
        assert_eq!(g.num_vertices(), 2_000);
        assert_eq!(g.num_edges(), 20_000);
        let s = g.degree_stats();
        assert!(s.in_degree_gini > 0.45, "gini {}", s.in_degree_gini);
        assert!(s.max_in_degree > 100, "max {}", s.max_in_degree);
    }

    #[test]
    fn street_mesh_is_flat() {
        let g = street_mesh(5_000, 5_300, 2);
        assert_eq!(g.num_edges(), 5_300);
        let s = g.degree_stats();
        assert!(s.in_degree_gini < 0.25, "gini {}", s.in_degree_gini);
        assert!(s.max_in_degree <= 6, "max {}", s.max_in_degree);
    }

    #[test]
    fn uniform_is_between() {
        let g = uniform(2_000, 20_000, 3);
        let s = g.degree_stats();
        assert!(s.in_degree_gini < 0.45, "gini {}", s.in_degree_gini);
    }

    #[test]
    fn power_law_deterministic() {
        let a = power_law(500, 2_000, 1.1, 1.1, 3, 42);
        let b = power_law(500, 2_000, 1.1, 1.1, 3, 42);
        assert_eq!(a.in_degrees(), b.in_degrees());
        assert_eq!(a.etypes().unwrap(), b.etypes().unwrap());
    }

    #[test]
    fn power_law_seeds_differ() {
        let a = power_law(500, 2_000, 1.1, 1.1, 0, 1);
        let b = power_law(500, 2_000, 1.1, 1.1, 0, 2);
        assert_ne!(a.in_degrees(), b.in_degrees());
    }

    #[test]
    fn etypes_within_bound() {
        let g = power_law(200, 1_000, 1.0, 1.0, 3, 5);
        assert!(g.etypes().unwrap().iter().all(|&t| t < 3));
    }

    #[test]
    fn rmat_counts_and_skew() {
        let g = rmat(12, 40_000, 17); // 4096 vertices
        assert_eq!(g.num_vertices(), 4096);
        assert_eq!(g.num_edges(), 40_000);
        let s = g.degree_stats();
        // recursive quadrant bias concentrates edges on low ids
        assert!(s.in_degree_gini > 0.45, "gini {}", s.in_degree_gini);
        assert!(s.max_in_degree > 100, "max {}", s.max_in_degree);
    }

    #[test]
    fn rmat_deterministic_in_seed() {
        let a = rmat_typed(8, 2_000, 4, 99);
        let b = rmat_typed(8, 2_000, 4, 99);
        assert_eq!(a.in_degrees(), b.in_degrees());
        assert_eq!(a.etypes().unwrap(), b.etypes().unwrap());
        let c = rmat(8, 2_000, 100);
        assert_ne!(a.in_degrees(), c.in_degrees());
    }
}
