//! Compressed sparse graph storage.
//!
//! ZIPPER's tiling iterates *destination partitions* and, inside them,
//! source partitions (paper §5.1), so the primary index is CSC: for each
//! destination vertex, its in-edges (source ids), sorted. Edge types
//! (R-GCN) ride along as a parallel array in edge order.

/// Immutable directed graph in CSC (by destination) order.
#[derive(Clone, Debug)]
pub struct Graph {
    num_vertices: u32,
    /// col_ptr[d]..col_ptr[d+1] indexes `srcs` with the in-edges of d.
    col_ptr: Vec<u64>,
    /// Source vertex of each edge, grouped by destination, sorted within.
    srcs: Vec<u32>,
    /// Optional per-edge relation type (R-GCN), same order as `srcs`.
    etypes: Option<Vec<u8>>,
}

impl Graph {
    pub fn num_vertices(&self) -> u32 {
        self.num_vertices
    }

    pub fn num_edges(&self) -> u64 {
        self.srcs.len() as u64
    }

    pub fn in_degree(&self, v: u32) -> u32 {
        (self.col_ptr[v as usize + 1] - self.col_ptr[v as usize]) as u32
    }

    /// In-neighbors (edge sources) of `v`, ascending.
    pub fn in_neighbors(&self, v: u32) -> &[u32] {
        let lo = self.col_ptr[v as usize] as usize;
        let hi = self.col_ptr[v as usize + 1] as usize;
        &self.srcs[lo..hi]
    }

    /// Edge-order index range of v's in-edges (for etype lookups).
    pub fn in_edge_range(&self, v: u32) -> std::ops::Range<usize> {
        self.col_ptr[v as usize] as usize..self.col_ptr[v as usize + 1] as usize
    }

    pub fn etypes(&self) -> Option<&[u8]> {
        self.etypes.as_deref()
    }

    pub fn has_etypes(&self) -> bool {
        self.etypes.is_some()
    }

    /// Out-degrees (costs an O(E) pass; cached by callers that need it).
    pub fn out_degrees(&self) -> Vec<u32> {
        let mut deg = vec![0u32; self.num_vertices as usize];
        for &s in &self.srcs {
            deg[s as usize] += 1;
        }
        deg
    }

    /// In-degrees as a vector.
    pub fn in_degrees(&self) -> Vec<u32> {
        (0..self.num_vertices).map(|v| self.in_degree(v)).collect()
    }

    /// Relabel vertices: `perm[old] = new`. Preserves edge multiplicity
    /// and per-edge types. Used by the Degree-Sort reordering (§5.3).
    pub fn relabel(&self, perm: &[u32]) -> Graph {
        assert_eq!(perm.len(), self.num_vertices as usize);
        debug_assert!({
            let mut seen = vec![false; perm.len()];
            perm.iter().all(|&p| {
                let fresh = !seen[p as usize];
                seen[p as usize] = true;
                fresh
            })
        }, "perm must be a permutation");
        let mut b = GraphBuilder::new(self.num_vertices);
        for d in 0..self.num_vertices {
            let range = self.in_edge_range(d);
            for (k, &s) in self.srcs[range.clone()].iter().enumerate() {
                let et = self.etypes.as_ref().map(|t| t[range.start + k]);
                b.add_edge_typed(perm[s as usize], perm[d as usize], et.unwrap_or(0));
            }
        }
        if self.etypes.is_some() {
            b.with_etypes();
        }
        b.build()
    }

    /// Total bytes of the graph structure itself (for the Fig 2 memory
    /// model): CSC pointers + source ids (+ edge types).
    pub fn structure_bytes(&self) -> u64 {
        (self.col_ptr.len() * 8 + self.srcs.len() * 4) as u64
            + self.etypes.as_ref().map_or(0, |t| t.len() as u64)
    }
}

/// Mutable edge accumulator; `build()` sorts into CSC.
#[derive(Clone, Debug)]
pub struct GraphBuilder {
    num_vertices: u32,
    edges: Vec<(u32, u32, u8)>, // (src, dst, etype)
    keep_etypes: bool,
}

impl GraphBuilder {
    pub fn new(num_vertices: u32) -> Self {
        GraphBuilder { num_vertices, edges: Vec::new(), keep_etypes: false }
    }

    pub fn with_capacity(num_vertices: u32, edges: usize) -> Self {
        GraphBuilder {
            num_vertices,
            edges: Vec::with_capacity(edges),
            keep_etypes: false,
        }
    }

    pub fn add_edge(&mut self, src: u32, dst: u32) {
        self.add_edge_typed(src, dst, 0);
    }

    pub fn add_edge_typed(&mut self, src: u32, dst: u32, etype: u8) {
        debug_assert!(src < self.num_vertices && dst < self.num_vertices);
        self.edges.push((src, dst, etype));
    }

    /// Keep per-edge relation types in the built graph (R-GCN).
    pub fn with_etypes(&mut self) -> &mut Self {
        self.keep_etypes = true;
        self
    }

    pub fn num_pending_edges(&self) -> usize {
        self.edges.len()
    }

    pub fn build(self) -> Graph {
        // counting sort by destination (O(E + V)), then sort each
        // destination's in-neighbour slice by source — O(E + Σ dᵢ log dᵢ)
        // total, ~2× faster than a comparison sort over all edges on the
        // generator/relabel hot path (see perf benches).
        let n = self.num_vertices as usize;
        let m = self.edges.len();
        let mut col_ptr = vec![0u64; n + 1];
        for &(_, d, _) in &self.edges {
            col_ptr[d as usize + 1] += 1;
        }
        for i in 0..n {
            col_ptr[i + 1] += col_ptr[i];
        }
        let mut srcs = vec![0u32; m];
        let mut types = if self.keep_etypes { vec![0u8; m] } else { Vec::new() };
        let mut cursor: Vec<u64> = col_ptr[..n].to_vec();
        for &(s, d, t) in &self.edges {
            let at = cursor[d as usize] as usize;
            cursor[d as usize] += 1;
            srcs[at] = s;
            if self.keep_etypes {
                types[at] = t;
            }
        }
        // per-destination source ordering
        for d in 0..n {
            let lo = col_ptr[d] as usize;
            let hi = col_ptr[d + 1] as usize;
            if hi - lo > 1 {
                if self.keep_etypes {
                    let mut pairs: Vec<(u32, u8)> = srcs[lo..hi]
                        .iter()
                        .copied()
                        .zip(types[lo..hi].iter().copied())
                        .collect();
                    pairs.sort_unstable_by_key(|&(s, _)| s);
                    for (i, (s, t)) in pairs.into_iter().enumerate() {
                        srcs[lo + i] = s;
                        types[lo + i] = t;
                    }
                } else {
                    srcs[lo..hi].sort_unstable();
                }
            }
        }
        let etypes = self.keep_etypes.then_some(types);
        Graph { num_vertices: self.num_vertices, col_ptr, srcs, etypes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Graph {
        // 0→1, 0→2, 1→3, 2→3
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1);
        b.add_edge(0, 2);
        b.add_edge(1, 3);
        b.add_edge(2, 3);
        b.build()
    }

    #[test]
    fn csc_layout() {
        let g = diamond();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.in_neighbors(0), &[] as &[u32]);
        assert_eq!(g.in_neighbors(1), &[0]);
        assert_eq!(g.in_neighbors(3), &[1, 2]);
        assert_eq!(g.in_degree(3), 2);
    }

    #[test]
    fn out_degrees_match() {
        let g = diamond();
        assert_eq!(g.out_degrees(), vec![2, 1, 1, 0]);
    }

    #[test]
    fn relabel_preserves_structure() {
        let g = diamond();
        // reverse permutation
        let perm: Vec<u32> = vec![3, 2, 1, 0];
        let r = g.relabel(&perm);
        assert_eq!(r.num_edges(), 4);
        // old 3 (in-deg 2) is now vertex 0
        assert_eq!(r.in_degree(0), 2);
        assert_eq!(r.in_neighbors(0), &[1, 2]); // old 1,2 → new 2,1 sorted
    }

    #[test]
    fn etypes_sorted_with_edges() {
        let mut b = GraphBuilder::new(3);
        b.add_edge_typed(2, 0, 7);
        b.add_edge_typed(1, 0, 5);
        b.with_etypes();
        let g = b.build();
        assert_eq!(g.in_neighbors(0), &[1, 2]);
        assert_eq!(g.etypes().unwrap(), &[5, 7]); // follows (dst,src) sort
    }

    #[test]
    fn structure_bytes_counts() {
        let g = diamond();
        assert_eq!(g.structure_bytes(), (5 * 8 + 4 * 4) as u64);
    }

    #[test]
    fn parallel_edges_kept() {
        let mut b = GraphBuilder::new(2);
        b.add_edge(0, 1);
        b.add_edge(0, 1);
        let g = b.build();
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.in_neighbors(1), &[0, 0]);
    }
}
