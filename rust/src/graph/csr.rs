//! Compressed sparse graph storage.
//!
//! ZIPPER's tiling iterates *destination partitions* and, inside them,
//! source partitions (paper §5.1), so the primary index is CSC: for each
//! destination vertex, its in-edges (source ids), sorted. Edge types
//! (R-GCN) ride along as a parallel array in edge order.

use std::fmt;

/// Structural errors from graph construction and relabeling.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum GraphError {
    /// An edge endpoint is outside `0..num_vertices`.
    EdgeOutOfRange { src: u32, dst: u32, num_vertices: u32 },
    /// A relabel permutation has the wrong length.
    PermLength { len: usize, num_vertices: u32 },
    /// A relabel permutation repeats or exceeds a target id, so it is not
    /// a bijection on `0..num_vertices`. `value` is the first offender.
    PermNotBijective { value: u32, num_vertices: u32 },
    /// A streaming edge source emitted different edge counts on its two
    /// passes (the closure must be deterministic and re-runnable).
    StreamNondeterministic { pass1: u64, pass2: u64 },
}

impl fmt::Display for GraphError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            GraphError::EdgeOutOfRange { src, dst, num_vertices } => write!(
                f,
                "edge ({src} -> {dst}) out of range for graph with {num_vertices} vertices"
            ),
            GraphError::PermLength { len, num_vertices } => write!(
                f,
                "permutation has {len} entries but the graph has {num_vertices} vertices"
            ),
            GraphError::PermNotBijective { value, num_vertices } => write!(
                f,
                "permutation is not a bijection on 0..{num_vertices}: \
                 target id {value} is repeated or out of range"
            ),
            GraphError::StreamNondeterministic { pass1, pass2 } => write!(
                f,
                "edge stream emitted {pass1} edges on the counting pass \
                 but {pass2} on the placement pass"
            ),
        }
    }
}

impl std::error::Error for GraphError {}

/// Immutable directed graph in CSC (by destination) order.
#[derive(Clone, Debug)]
pub struct Graph {
    num_vertices: u32,
    /// col_ptr[d]..col_ptr[d+1] indexes `srcs` with the in-edges of d.
    col_ptr: Vec<u64>,
    /// Source vertex of each edge, grouped by destination, sorted within.
    srcs: Vec<u32>,
    /// Optional per-edge relation type (R-GCN), same order as `srcs`.
    etypes: Option<Vec<u8>>,
}

impl Graph {
    pub fn num_vertices(&self) -> u32 {
        self.num_vertices
    }

    pub fn num_edges(&self) -> u64 {
        self.srcs.len() as u64
    }

    pub fn in_degree(&self, v: u32) -> u32 {
        (self.col_ptr[v as usize + 1] - self.col_ptr[v as usize]) as u32
    }

    /// In-neighbors (edge sources) of `v`, ascending.
    pub fn in_neighbors(&self, v: u32) -> &[u32] {
        let lo = self.col_ptr[v as usize] as usize;
        let hi = self.col_ptr[v as usize + 1] as usize;
        &self.srcs[lo..hi]
    }

    /// Edge-order index range of v's in-edges (for etype lookups).
    pub fn in_edge_range(&self, v: u32) -> std::ops::Range<usize> {
        self.col_ptr[v as usize] as usize..self.col_ptr[v as usize + 1] as usize
    }

    pub fn etypes(&self) -> Option<&[u8]> {
        self.etypes.as_deref()
    }

    pub fn has_etypes(&self) -> bool {
        self.etypes.is_some()
    }

    /// Out-degrees (costs an O(E) pass; cached by callers that need it).
    pub fn out_degrees(&self) -> Vec<u32> {
        let mut deg = vec![0u32; self.num_vertices as usize];
        for &s in &self.srcs {
            deg[s as usize] += 1;
        }
        deg
    }

    /// In-degrees as a vector.
    pub fn in_degrees(&self) -> Vec<u32> {
        (0..self.num_vertices).map(|v| self.in_degree(v)).collect()
    }

    /// Relabel vertices: `perm[old] = new`. Preserves edge multiplicity
    /// and per-edge types. Used by the Degree-Sort reordering (§5.3) and
    /// by sharding, which partitions the *relabeled* graph so shard-local
    /// ids can stay order-preserving (DESIGN.md §3.8).
    ///
    /// Rejects non-permutation input: wrong length, a repeated target id,
    /// or a target id ≥ |V| all return a structured [`GraphError`].
    pub fn relabel(&self, perm: &[u32]) -> Result<Graph, GraphError> {
        let n = self.num_vertices;
        if perm.len() != n as usize {
            return Err(GraphError::PermLength { len: perm.len(), num_vertices: n });
        }
        let mut seen = vec![false; n as usize];
        for &p in perm {
            if p >= n || seen[p as usize] {
                return Err(GraphError::PermNotBijective { value: p, num_vertices: n });
            }
            seen[p as usize] = true;
        }
        let mut b = GraphBuilder::with_capacity(n, self.srcs.len());
        for d in 0..n {
            let range = self.in_edge_range(d);
            for (k, &s) in self.srcs[range.clone()].iter().enumerate() {
                let et = self.etypes.as_ref().map(|t| t[range.start + k]);
                b.add_edge_typed(perm[s as usize], perm[d as usize], et.unwrap_or(0))?;
            }
        }
        if self.etypes.is_some() {
            b.with_etypes();
        }
        Ok(b.build())
    }

    /// Build a CSC graph from a re-runnable edge stream without ever
    /// materializing the unsorted edge list. The closure is invoked
    /// twice with an `emit(src, dst, etype)` sink and must produce the
    /// identical edge sequence both times (recreate your RNG from its
    /// seed inside the closure). Pass 1 counts in-degrees to size the
    /// column pointers; pass 2 places each edge directly into its final
    /// destination slice — peak memory is the finished CSC arrays plus
    /// one cursor vector, instead of `build()`'s extra 9 bytes/edge.
    pub fn from_edge_stream<F>(
        num_vertices: u32,
        keep_etypes: bool,
        mut stream: F,
    ) -> Result<Graph, GraphError>
    where
        F: FnMut(&mut dyn FnMut(u32, u32, u8)),
    {
        let n = num_vertices as usize;
        // pass 1: per-destination counts + eager range validation
        let mut col_ptr = vec![0u64; n + 1];
        let mut bad: Option<GraphError> = None;
        let mut pass1 = 0u64;
        stream(&mut |s, d, _t| {
            pass1 += 1;
            if s >= num_vertices || d >= num_vertices {
                if bad.is_none() {
                    bad = Some(GraphError::EdgeOutOfRange { src: s, dst: d, num_vertices });
                }
                return;
            }
            col_ptr[d as usize + 1] += 1;
        });
        if let Some(e) = bad {
            return Err(e);
        }
        for i in 0..n {
            col_ptr[i + 1] += col_ptr[i];
        }
        let m = col_ptr[n] as usize;
        // pass 2: place edges at their cursor positions
        let mut srcs = vec![0u32; m];
        let mut types = if keep_etypes { vec![0u8; m] } else { Vec::new() };
        let mut cursor: Vec<u64> = col_ptr[..n].to_vec();
        let mut pass2 = 0u64;
        let mut overflow = false;
        stream(&mut |s, d, t| {
            pass2 += 1;
            let di = d as usize;
            if s >= num_vertices || di >= n || cursor[di] >= col_ptr[di + 1] {
                overflow = true;
                return;
            }
            let at = cursor[di] as usize;
            cursor[di] += 1;
            srcs[at] = s;
            if keep_etypes {
                types[at] = t;
            }
        });
        if overflow || pass2 != pass1 {
            return Err(GraphError::StreamNondeterministic { pass1, pass2 });
        }
        sort_within_dst(&col_ptr, &mut srcs, &mut types, keep_etypes);
        let etypes = keep_etypes.then_some(types);
        Ok(Graph { num_vertices, col_ptr, srcs, etypes })
    }

    /// Total bytes of the graph structure itself (for the Fig 2 memory
    /// model): CSC pointers + source ids (+ edge types).
    pub fn structure_bytes(&self) -> u64 {
        (self.col_ptr.len() * 8 + self.srcs.len() * 4) as u64
            + self.etypes.as_ref().map_or(0, |t| t.len() as u64)
    }
}

/// Sort each destination's in-neighbour slice by source id, carrying
/// edge types along. Shared by `GraphBuilder::build` and the streaming
/// constructor so both produce the identical canonical edge order.
fn sort_within_dst(col_ptr: &[u64], srcs: &mut [u32], types: &mut [u8], keep_etypes: bool) {
    let n = col_ptr.len() - 1;
    for d in 0..n {
        let lo = col_ptr[d] as usize;
        let hi = col_ptr[d + 1] as usize;
        if hi - lo > 1 {
            if keep_etypes {
                let mut pairs: Vec<(u32, u8)> = srcs[lo..hi]
                    .iter()
                    .copied()
                    .zip(types[lo..hi].iter().copied())
                    .collect();
                pairs.sort_unstable_by_key(|&(s, _)| s);
                for (i, (s, t)) in pairs.into_iter().enumerate() {
                    srcs[lo + i] = s;
                    types[lo + i] = t;
                }
            } else {
                srcs[lo..hi].sort_unstable();
            }
        }
    }
}

/// Mutable edge accumulator; `build()` sorts into CSC.
#[derive(Clone, Debug)]
pub struct GraphBuilder {
    num_vertices: u32,
    edges: Vec<(u32, u32, u8)>, // (src, dst, etype)
    keep_etypes: bool,
}

impl GraphBuilder {
    pub fn new(num_vertices: u32) -> Self {
        GraphBuilder { num_vertices, edges: Vec::new(), keep_etypes: false }
    }

    pub fn with_capacity(num_vertices: u32, edges: usize) -> Self {
        GraphBuilder {
            num_vertices,
            edges: Vec::with_capacity(edges),
            keep_etypes: false,
        }
    }

    /// Add an untyped edge. Endpoints are validated eagerly: an
    /// out-of-range id fails here with the offending edge, not later
    /// inside `build()`'s counting sort.
    pub fn add_edge(&mut self, src: u32, dst: u32) -> Result<(), GraphError> {
        self.add_edge_typed(src, dst, 0)
    }

    pub fn add_edge_typed(&mut self, src: u32, dst: u32, etype: u8) -> Result<(), GraphError> {
        if src >= self.num_vertices || dst >= self.num_vertices {
            return Err(GraphError::EdgeOutOfRange {
                src,
                dst,
                num_vertices: self.num_vertices,
            });
        }
        self.edges.push((src, dst, etype));
        Ok(())
    }

    /// Keep per-edge relation types in the built graph (R-GCN).
    pub fn with_etypes(&mut self) -> &mut Self {
        self.keep_etypes = true;
        self
    }

    pub fn num_pending_edges(&self) -> usize {
        self.edges.len()
    }

    pub fn build(self) -> Graph {
        // counting sort by destination (O(E + V)), then sort each
        // destination's in-neighbour slice by source — O(E + Σ dᵢ log dᵢ)
        // total, ~2× faster than a comparison sort over all edges on the
        // generator/relabel hot path (see perf benches).
        let n = self.num_vertices as usize;
        let m = self.edges.len();
        let mut col_ptr = vec![0u64; n + 1];
        for &(_, d, _) in &self.edges {
            col_ptr[d as usize + 1] += 1;
        }
        for i in 0..n {
            col_ptr[i + 1] += col_ptr[i];
        }
        let mut srcs = vec![0u32; m];
        let mut types = if self.keep_etypes { vec![0u8; m] } else { Vec::new() };
        let mut cursor: Vec<u64> = col_ptr[..n].to_vec();
        for &(s, d, t) in &self.edges {
            let at = cursor[d as usize] as usize;
            cursor[d as usize] += 1;
            srcs[at] = s;
            if self.keep_etypes {
                types[at] = t;
            }
        }
        sort_within_dst(&col_ptr, &mut srcs, &mut types, self.keep_etypes);
        let etypes = self.keep_etypes.then_some(types);
        Graph { num_vertices: self.num_vertices, col_ptr, srcs, etypes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Graph {
        // 0→1, 0→2, 1→3, 2→3
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1).unwrap();
        b.add_edge(0, 2).unwrap();
        b.add_edge(1, 3).unwrap();
        b.add_edge(2, 3).unwrap();
        b.build()
    }

    #[test]
    fn csc_layout() {
        let g = diamond();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.in_neighbors(0), &[] as &[u32]);
        assert_eq!(g.in_neighbors(1), &[0]);
        assert_eq!(g.in_neighbors(3), &[1, 2]);
        assert_eq!(g.in_degree(3), 2);
    }

    #[test]
    fn out_degrees_match() {
        let g = diamond();
        assert_eq!(g.out_degrees(), vec![2, 1, 1, 0]);
    }

    #[test]
    fn add_edge_rejects_out_of_range() {
        let mut b = GraphBuilder::new(4);
        assert_eq!(
            b.add_edge(0, 4),
            Err(GraphError::EdgeOutOfRange { src: 0, dst: 4, num_vertices: 4 })
        );
        assert_eq!(
            b.add_edge_typed(7, 1, 3),
            Err(GraphError::EdgeOutOfRange { src: 7, dst: 1, num_vertices: 4 })
        );
        // the rejected edges left no residue
        assert_eq!(b.num_pending_edges(), 0);
        b.add_edge(3, 0).unwrap();
        assert_eq!(b.build().num_edges(), 1);
    }

    #[test]
    fn relabel_preserves_structure() {
        let g = diamond();
        // reverse permutation
        let perm: Vec<u32> = vec![3, 2, 1, 0];
        let r = g.relabel(&perm).unwrap();
        assert_eq!(r.num_edges(), 4);
        // old 3 (in-deg 2) is now vertex 0
        assert_eq!(r.in_degree(0), 2);
        assert_eq!(r.in_neighbors(0), &[1, 2]); // old 1,2 → new 2,1 sorted
    }

    #[test]
    fn relabel_rejects_non_permutations() {
        let g = diamond();
        assert_eq!(
            g.relabel(&[0, 1, 2]).unwrap_err(),
            GraphError::PermLength { len: 3, num_vertices: 4 }
        );
        assert_eq!(
            g.relabel(&[0, 1, 2, 2]).unwrap_err(),
            GraphError::PermNotBijective { value: 2, num_vertices: 4 }
        );
        assert_eq!(
            g.relabel(&[0, 1, 2, 9]).unwrap_err(),
            GraphError::PermNotBijective { value: 9, num_vertices: 4 }
        );
    }

    #[test]
    fn relabel_inverse_round_trips() {
        // property: relabel(perm) then relabel(inverse) is the identity,
        // for seeded random permutations over a skewed graph
        let g = super::super::generators::power_law(64, 400, 1.2, 1.2, 3, 9);
        for seed in 0..5u64 {
            let mut perm: Vec<u32> = (0..64).collect();
            crate::util::Rng::new(seed).shuffle(&mut perm);
            let mut inv = vec![0u32; 64];
            for (old, &new) in perm.iter().enumerate() {
                inv[new as usize] = old as u32;
            }
            let back = g.relabel(&perm).unwrap().relabel(&inv).unwrap();
            for v in 0..64u32 {
                assert_eq!(g.in_neighbors(v), back.in_neighbors(v), "seed {seed} vertex {v}");
                assert_eq!(
                    &g.etypes().unwrap()[g.in_edge_range(v)],
                    &back.etypes().unwrap()[back.in_edge_range(v)],
                );
            }
        }
    }

    #[test]
    fn etypes_sorted_with_edges() {
        let mut b = GraphBuilder::new(3);
        b.add_edge_typed(2, 0, 7).unwrap();
        b.add_edge_typed(1, 0, 5).unwrap();
        b.with_etypes();
        let g = b.build();
        assert_eq!(g.in_neighbors(0), &[1, 2]);
        assert_eq!(g.etypes().unwrap(), &[5, 7]); // follows (dst,src) sort
    }

    #[test]
    fn structure_bytes_counts() {
        let g = diamond();
        assert_eq!(g.structure_bytes(), (5 * 8 + 4 * 4) as u64);
    }

    #[test]
    fn parallel_edges_kept() {
        let mut b = GraphBuilder::new(2);
        b.add_edge(0, 1).unwrap();
        b.add_edge(0, 1).unwrap();
        let g = b.build();
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.in_neighbors(1), &[0, 0]);
    }

    #[test]
    fn edge_stream_matches_builder() {
        // same edges through both constructors → identical CSC layout
        let edges: &[(u32, u32, u8)] = &[(2, 0, 7), (1, 0, 5), (0, 1, 1), (2, 1, 2), (2, 1, 0)];
        let mut b = GraphBuilder::new(3);
        for &(s, d, t) in edges {
            b.add_edge_typed(s, d, t).unwrap();
        }
        b.with_etypes();
        let a = b.build();
        let g = Graph::from_edge_stream(3, true, |emit| {
            for &(s, d, t) in edges {
                emit(s, d, t);
            }
        })
        .unwrap();
        assert_eq!(a.num_edges(), g.num_edges());
        for v in 0..3u32 {
            assert_eq!(a.in_neighbors(v), g.in_neighbors(v));
            assert_eq!(
                &a.etypes().unwrap()[a.in_edge_range(v)],
                &g.etypes().unwrap()[g.in_edge_range(v)]
            );
        }
    }

    #[test]
    fn edge_stream_rejects_out_of_range() {
        let r = Graph::from_edge_stream(2, false, |emit| {
            emit(0, 1, 0);
            emit(5, 1, 0);
        });
        assert_eq!(
            r.unwrap_err(),
            GraphError::EdgeOutOfRange { src: 5, dst: 1, num_vertices: 2 }
        );
    }

    #[test]
    fn edge_stream_rejects_nondeterminism() {
        let mut calls = 0u32;
        let r = Graph::from_edge_stream(4, false, |emit| {
            calls += 1;
            // second pass emits one extra edge
            for _ in 0..calls {
                emit(0, 1, 0);
            }
        });
        assert_eq!(
            r.unwrap_err(),
            GraphError::StreamNondeterministic { pass1: 1, pass2: 2 }
        );
    }
}
