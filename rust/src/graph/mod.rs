//! Graph substrate: storage, builders, generators, and the dataset registry.
//!
//! The paper evaluates on six Gunrock graphs (Table 3) plus four citation
//! graphs for the HyGCN comparison. Dataset files aren't available in this
//! environment, so `datasets` provides synthetic generators matched to
//! each graph's vertex/edge counts and degree *shape* (DESIGN.md §5 —
//! tiling/pipelining behaviour depends on |V|, |E| and degree skew, which
//! we match; absolute cycle counts scale with graph size, ratios don't).

mod csr;
pub mod datasets;
pub mod generators;
pub mod partition;

pub use csr::{Graph, GraphBuilder, GraphError};

/// Degree-distribution summary used to sanity-check generated graphs.
#[derive(Clone, Debug, PartialEq)]
pub struct DegreeStats {
    pub num_vertices: u64,
    pub num_edges: u64,
    pub max_in_degree: u64,
    pub mean_in_degree: f64,
    /// Gini coefficient of the in-degree distribution: 0 = uniform,
    /// → 1 = maximally skewed. Power-law graphs land well above street
    /// meshes; the generators are tested against expected bands.
    pub in_degree_gini: f64,
}

impl Graph {
    pub fn degree_stats(&self) -> DegreeStats {
        let n = self.num_vertices() as usize;
        let mut degs: Vec<u64> = (0..n)
            .map(|v| self.in_degree(v as u32) as u64)
            .collect();
        degs.sort_unstable();
        let total: u64 = degs.iter().sum();
        let max = degs.last().copied().unwrap_or(0);
        // Gini over sorted degrees: (2 Σ i·x_i)/(n Σ x_i) − (n+1)/n
        let gini = if total == 0 || n == 0 {
            0.0
        } else {
            let weighted: f64 = degs
                .iter()
                .enumerate()
                .map(|(i, &d)| (i as f64 + 1.0) * d as f64)
                .sum();
            (2.0 * weighted) / (n as f64 * total as f64) - (n as f64 + 1.0) / n as f64
        };
        DegreeStats {
            num_vertices: self.num_vertices() as u64,
            num_edges: self.num_edges(),
            max_in_degree: max,
            mean_in_degree: total as f64 / n.max(1) as f64,
            in_degree_gini: gini,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degree_stats_triangle() {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1).unwrap();
        b.add_edge(1, 2).unwrap();
        b.add_edge(2, 0).unwrap();
        let g = b.build();
        let s = g.degree_stats();
        assert_eq!(s.num_edges, 3);
        assert_eq!(s.max_in_degree, 1);
        assert!((s.mean_in_degree - 1.0).abs() < 1e-12);
        assert!(s.in_degree_gini.abs() < 1e-9); // perfectly uniform
    }

    #[test]
    fn gini_detects_skew() {
        let mut b = GraphBuilder::new(10);
        for s in 0..9u32 {
            b.add_edge(s, 9).unwrap(); // star: everything points at vertex 9
        }
        let g = b.build();
        assert!(g.degree_stats().in_degree_gini > 0.8);
    }
}
