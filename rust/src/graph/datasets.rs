//! Dataset registry: paper Table 3 plus the HyGCN comparison sets.
//!
//! Each entry records the *published* vertex/edge counts and the
//! generator family that matches its degree shape. `instantiate(scale)`
//! builds a synthetic stand-in at `1/scale` of the published size
//! (DESIGN.md §5: speedup ratios survive scaling; absolute cycles don't,
//! and we only claim ratios). `scale = 1` gives the full published size.

use super::{generators, Graph};

/// Degree-shape family for the generator (see `generators`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Family {
    /// Heavy-tailed: social/collaboration/citation networks.
    PowerLaw { alpha_in: f64, alpha_out: f64 },
    /// Near-uniform tiny degree: street networks.
    StreetMesh,
    /// Uniform random.
    Uniform,
}

#[derive(Clone, Copy, Debug)]
pub struct DatasetSpec {
    /// Short id used in benches and the paper's figures ("AK", "SL", ...).
    pub id: &'static str,
    pub name: &'static str,
    pub vertices: u64,
    pub edges: u64,
    pub family: Family,
    /// Paper Table 3 "Type" column.
    pub kind: &'static str,
}

impl DatasetSpec {
    /// Build the synthetic stand-in at 1/scale of the published size.
    /// Vertex and edge counts are divided together so mean degree — and
    /// with a Zipf family, the degree *shape* — is preserved.
    pub fn instantiate(&self, scale: u64, seed: u64) -> Graph {
        self.instantiate_typed(scale, 0, seed)
    }

    /// Same, with `num_etypes` random relation types (R-GCN; paper §8.1
    /// "randomly generate the edge type for each benchmark graph").
    pub fn instantiate_typed(&self, scale: u64, num_etypes: u8, seed: u64) -> Graph {
        assert!(scale >= 1);
        let v = (self.vertices / scale).max(64) as u32;
        let e = (self.edges / scale).max(128);
        match self.family {
            Family::PowerLaw { alpha_in, alpha_out } => {
                generators::power_law(v, e, alpha_in, alpha_out, num_etypes, seed)
            }
            Family::StreetMesh => generators::street_mesh_typed(v, e, num_etypes, seed),
            Family::Uniform => generators::uniform_typed(v, e, num_etypes, seed),
        }
    }

    /// Published mean degree (drives the analytic baseline models even
    /// when the instantiated graph is scaled).
    pub fn mean_degree(&self) -> f64 {
        self.edges as f64 / self.vertices as f64
    }
}

/// Paper Table 3.
pub const TABLE3: [DatasetSpec; 6] = [
    DatasetSpec {
        id: "AK",
        name: "ak2010",
        vertices: 45_293,
        edges: 108_549,
        family: Family::Uniform,
        kind: "Redistrict Set",
    },
    DatasetSpec {
        id: "AD",
        name: "coAuthorsDBLP",
        vertices: 299_068,
        edges: 977_676,
        family: Family::PowerLaw { alpha_in: 0.9, alpha_out: 0.9 },
        kind: "Citation Networks",
    },
    DatasetSpec {
        id: "HW",
        name: "hollywood-2009",
        vertices: 1_139_905,
        edges: 57_515_616,
        family: Family::PowerLaw { alpha_in: 1.1, alpha_out: 1.1 },
        kind: "Collaboration Networks",
    },
    DatasetSpec {
        id: "CP",
        name: "cit-Patents",
        vertices: 3_774_768,
        edges: 16_518_948,
        family: Family::PowerLaw { alpha_in: 0.8, alpha_out: 0.8 },
        kind: "Patent Networks",
    },
    DatasetSpec {
        id: "SL",
        name: "soc-LiveJournal1",
        vertices: 4_847_571,
        edges: 43_369_619,
        family: Family::PowerLaw { alpha_in: 1.1, alpha_out: 1.1 },
        kind: "Social Networks",
    },
    DatasetSpec {
        id: "EO",
        name: "europe-osm",
        vertices: 50_912_018,
        edges: 54_054_660,
        family: Family::StreetMesh,
        kind: "Street Networks",
    },
];

/// HyGCN-comparison citation graphs (paper §8.4).
pub const HYGCN_SETS: [DatasetSpec; 4] = [
    DatasetSpec {
        id: "CR",
        name: "Cora",
        vertices: 2_708,
        edges: 10_556,
        family: Family::PowerLaw { alpha_in: 0.7, alpha_out: 0.7 },
        kind: "Citation",
    },
    DatasetSpec {
        id: "CS",
        name: "Citeseer",
        vertices: 3_327,
        edges: 9_104,
        family: Family::PowerLaw { alpha_in: 0.7, alpha_out: 0.7 },
        kind: "Citation",
    },
    DatasetSpec {
        id: "PB",
        name: "Pubmed",
        vertices: 19_717,
        edges: 88_648,
        family: Family::PowerLaw { alpha_in: 0.8, alpha_out: 0.8 },
        kind: "Citation",
    },
    DatasetSpec {
        id: "RD",
        name: "Reddit",
        vertices: 232_965,
        edges: 114_615_892,
        family: Family::PowerLaw { alpha_in: 1.2, alpha_out: 1.2 },
        kind: "Social",
    },
];

pub fn by_id(id: &str) -> Option<&'static DatasetSpec> {
    TABLE3
        .iter()
        .chain(HYGCN_SETS.iter())
        .find(|d| d.id.eq_ignore_ascii_case(id) || d.name.eq_ignore_ascii_case(id))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_lookup() {
        assert_eq!(by_id("SL").unwrap().name, "soc-LiveJournal1");
        assert_eq!(by_id("cora").unwrap().id, "CR");
        assert!(by_id("nope").is_none());
    }

    #[test]
    fn instantiate_scales_counts() {
        let spec = by_id("AD").unwrap();
        let g = spec.instantiate(64, 1);
        let v = g.num_vertices() as u64;
        let e = g.num_edges();
        assert!((v as i64 - (spec.vertices / 64) as i64).abs() <= 1);
        assert!((e as i64 - (spec.edges / 64) as i64).abs() <= 1);
        // mean degree preserved within 5%
        let md = e as f64 / v as f64;
        assert!((md - spec.mean_degree()).abs() / spec.mean_degree() < 0.05);
    }

    #[test]
    fn street_vs_social_shape() {
        let eo = by_id("EO").unwrap().instantiate(4096, 7);
        let sl = by_id("SL").unwrap().instantiate(4096, 7);
        assert!(sl.degree_stats().in_degree_gini > eo.degree_stats().in_degree_gini + 0.2);
    }

    #[test]
    fn typed_instantiation() {
        let g = by_id("AK").unwrap().instantiate_typed(16, 3, 9);
        assert!(g.has_etypes());
        assert!(g.etypes().unwrap().iter().all(|&t| t < 3));
    }

    #[test]
    fn tiny_floor_respected() {
        // extreme scale still yields a usable graph
        let g = by_id("CR").unwrap().instantiate(1_000_000, 1);
        assert!(g.num_vertices() >= 64);
        assert!(g.num_edges() >= 128);
    }
}
