//! K-way vertex sharding for multi-chip execution (DESIGN.md §3.8).
//!
//! A shard *owns* (is "core" for) a disjoint set of destination vertices
//! and carries **every** in-edge of those destinations. Sources that live
//! on another shard appear locally as *halo* vertices: present in the
//! shard's vertex list, but with zero local in-edges — their activations
//! are imported from the owning shard at each layer boundary. Because a
//! core destination sees its complete in-neighbourhood locally, per-layer
//! shard outputs for core rows equal the unsharded computation exactly;
//! halo rows are imports and their locally-computed values are discarded.
//!
//! The partitioner is a degree-balanced greedy (LPT over in-degree
//! weights) followed by a seeded local-refinement sweep that moves a
//! vertex to the shard holding the plurality of its neighbours when that
//! strictly reduces the edge cut and keeps loads within a slack band.
//! Everything is deterministic in (graph, num_shards, seed).

use super::Graph;
use crate::util::Rng;

/// Load-balance slack for refinement moves: a vertex may move into a
/// shard only while that shard's weight stays ≤ (1 + slack) × average.
const BALANCE_SLACK: f64 = 0.10;
/// Refinement sweeps over all vertices (each in a fresh seeded order).
const REFINE_PASSES: usize = 2;

/// One shard of a [`Partitioning`]: an induced subgraph plus the maps
/// back to the input graph's vertex ids.
#[derive(Clone, Debug)]
pub struct Shard {
    pub id: u32,
    /// Induced subgraph over `locals`: every in-edge of every core
    /// vertex, endpoints renumbered to shard-local ids. Halo vertices
    /// have zero in-edges here by construction.
    pub graph: Graph,
    /// Shard-local id → input-graph vertex id, **strictly ascending** —
    /// shard-local order preserves input order, which is what makes
    /// sharded reductions bit-exact with the unsharded plan (§3.8).
    pub locals: Vec<u32>,
    /// `is_core[local]`: owned vertex (true) vs imported halo (false).
    pub is_core: Vec<bool>,
    pub core_vertices: u64,
    pub halo_vertices: u64,
    pub edges: u64,
}

impl Shard {
    /// Shard-local id of input-graph vertex `v`, if present here.
    pub fn local_of(&self, v: u32) -> Option<u32> {
        self.locals.binary_search(&v).ok().map(|i| i as u32)
    }
}

/// Result of [`partition`]: shard list plus the global assignment map.
#[derive(Clone, Debug)]
pub struct Partitioning {
    pub num_shards: usize,
    /// Input-graph vertex → owning shard id.
    pub assign: Vec<u32>,
    pub shards: Vec<Shard>,
    /// Edges whose source and destination live on different shards.
    pub cut_edges: u64,
    pub num_edges: u64,
}

impl Partitioning {
    /// Total halo slots across shards (= per-boundary activation copies).
    pub fn halo_total(&self) -> u64 {
        self.shards.iter().map(|s| s.halo_vertices).sum()
    }

    pub fn cut_fraction(&self) -> f64 {
        if self.num_edges == 0 {
            0.0
        } else {
            self.cut_edges as f64 / self.num_edges as f64
        }
    }
}

/// Split `graph` into `num_shards` disjoint-core shards with explicit
/// halo sets. Deterministic in all three arguments.
pub fn partition(graph: &Graph, num_shards: usize, seed: u64) -> Result<Partitioning, String> {
    let n = graph.num_vertices() as usize;
    if num_shards == 0 {
        return Err("num_shards must be >= 1".into());
    }
    if num_shards > n {
        return Err(format!(
            "cannot cut a {n}-vertex graph into {num_shards} shards"
        ));
    }
    let k = num_shards;

    // ---- greedy LPT assignment on weight = 1 + in_degree -------------
    // The +1 keeps vertex counts balanced on near-edgeless graphs (EO).
    let weight = |v: u32| 1u64 + graph.in_degree(v) as u64;
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_by_key(|&v| (std::cmp::Reverse(weight(v)), v));
    let mut assign = vec![0u32; n];
    let mut load = vec![0u64; k];
    let mut core_count = vec![0u64; k];
    for &v in &order {
        let mut best = 0usize;
        for s in 1..k {
            if load[s] < load[best] {
                best = s;
            }
        }
        assign[v as usize] = best as u32;
        load[best] += weight(v);
        core_count[best] += 1;
    }

    // ---- seeded refinement: plurality-neighbour moves ----------------
    if k > 1 && graph.num_edges() > 0 {
        // out-adjacency (CSR by source) so a vertex sees both edge
        // directions when counting neighbour shards
        let out_deg = graph.out_degrees();
        let mut out_ptr = vec![0u64; n + 1];
        for v in 0..n {
            out_ptr[v + 1] = out_ptr[v] + out_deg[v] as u64;
        }
        let mut out_dst = vec![0u32; graph.num_edges() as usize];
        let mut cursor: Vec<u64> = out_ptr[..n].to_vec();
        for d in 0..n as u32 {
            for &s in graph.in_neighbors(d) {
                let at = cursor[s as usize] as usize;
                cursor[s as usize] += 1;
                out_dst[at] = d;
            }
        }

        let total_w: u64 = load.iter().sum();
        let cap = ((total_w as f64 / k as f64) * (1.0 + BALANCE_SLACK)).ceil() as u64;
        let mut rng = Rng::new(seed);
        let mut counts = vec![0u64; k];
        let mut touched: Vec<usize> = Vec::new();
        for _ in 0..REFINE_PASSES {
            let mut visit: Vec<u32> = (0..n as u32).collect();
            rng.shuffle(&mut visit);
            for &v in &visit {
                let cur = assign[v as usize] as usize;
                if core_count[cur] <= 1 {
                    continue; // never drain a shard empty
                }
                for &s in graph.in_neighbors(v) {
                    let sh = assign[s as usize] as usize;
                    if counts[sh] == 0 {
                        touched.push(sh);
                    }
                    counts[sh] += 1;
                }
                let lo = out_ptr[v as usize] as usize;
                let hi = out_ptr[v as usize + 1] as usize;
                for &d in &out_dst[lo..hi] {
                    let sh = assign[d as usize] as usize;
                    if counts[sh] == 0 {
                        touched.push(sh);
                    }
                    counts[sh] += 1;
                }
                let mut best = cur;
                for &sh in &touched {
                    let better = counts[sh] > counts[best];
                    let tie_lower = counts[sh] == counts[best] && best != cur && sh < best;
                    if better || tie_lower {
                        best = sh;
                    }
                }
                let w = weight(v);
                if best != cur && counts[best] > counts[cur] && load[best] + w <= cap {
                    assign[v as usize] = best as u32;
                    load[cur] -= w;
                    load[best] += w;
                    core_count[cur] -= 1;
                    core_count[best] += 1;
                }
                for sh in touched.drain(..) {
                    counts[sh] = 0;
                }
            }
        }
    }

    build_shards(graph, k, assign)
}

/// Materialize per-shard induced subgraphs + maps from an assignment.
fn build_shards(graph: &Graph, k: usize, assign: Vec<u32>) -> Result<Partitioning, String> {
    let n = graph.num_vertices() as usize;
    let keep_etypes = graph.has_etypes();

    // halo candidates: sources of cross-shard edges, recorded per
    // destination shard — dedup by sort below. Also count the cut.
    let mut halos: Vec<Vec<u32>> = vec![Vec::new(); k];
    let mut cut_edges = 0u64;
    for d in 0..n as u32 {
        let ds = assign[d as usize] as usize;
        for &s in graph.in_neighbors(d) {
            if assign[s as usize] as usize != ds {
                cut_edges += 1;
                halos[ds].push(s);
            }
        }
    }

    let mut shards = Vec::with_capacity(k);
    // scratch global→local map, reset after each shard via its locals
    let mut to_local = vec![u32::MAX; n];
    for sid in 0..k {
        let mut halo = std::mem::take(&mut halos[sid]);
        halo.sort_unstable();
        halo.dedup();
        // merge ascending core ids with ascending halo ids
        let mut locals: Vec<u32> = Vec::new();
        let mut is_core: Vec<bool> = Vec::new();
        let mut hi = 0usize;
        for v in 0..n as u32 {
            let core_here = assign[v as usize] as usize == sid;
            let halo_here = hi < halo.len() && halo[hi] == v;
            if halo_here {
                hi += 1;
            }
            if core_here || halo_here {
                locals.push(v);
                is_core.push(core_here);
            }
        }
        for (l, &v) in locals.iter().enumerate() {
            to_local[v as usize] = l as u32;
        }
        let mut edges = 0u64;
        for (&v, &core) in locals.iter().zip(&is_core) {
            if core {
                edges += graph.in_degree(v) as u64;
            }
        }
        let sg = Graph::from_edge_stream(locals.len() as u32, keep_etypes, |emit| {
            for (&v, &core) in locals.iter().zip(&is_core) {
                if !core {
                    continue;
                }
                let range = graph.in_edge_range(v);
                let et = graph.etypes();
                for (i, &s) in graph.in_neighbors(v).iter().enumerate() {
                    let t = et.map_or(0, |ts| ts[range.start + i]);
                    emit(to_local[s as usize], to_local[v as usize], t);
                }
            }
        })
        .map_err(|e| format!("shard {sid} subgraph: {e}"))?;
        for &v in &locals {
            to_local[v as usize] = u32::MAX;
        }
        let core_vertices = is_core.iter().filter(|&&c| c).count() as u64;
        let halo_vertices = locals.len() as u64 - core_vertices;
        shards.push(Shard {
            id: sid as u32,
            graph: sg,
            locals,
            is_core,
            core_vertices,
            halo_vertices,
            edges,
        });
    }

    Ok(Partitioning {
        num_shards: k,
        assign,
        shards,
        cut_edges,
        num_edges: graph.num_edges(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn check_invariants(g: &Graph, p: &Partitioning) {
        let n = g.num_vertices() as usize;
        // every vertex is core in exactly one shard — its assigned one
        assert_eq!(p.assign.len(), n);
        let total_core: u64 = p.shards.iter().map(|s| s.core_vertices).sum();
        assert_eq!(total_core, n as u64);
        for sh in &p.shards {
            assert!(sh.core_vertices >= 1, "shard {} drained empty", sh.id);
            // locals strictly ascending (order preservation)
            assert!(sh.locals.windows(2).all(|w| w[0] < w[1]));
            for (l, (&v, &core)) in sh.locals.iter().zip(&sh.is_core).enumerate() {
                assert_eq!(core, p.assign[v as usize] == sh.id);
                // halo vertices have zero local in-edges; core vertices
                // carry their full input-graph in-neighbourhood
                let local_deg = sh.graph.in_degree(l as u32);
                if core {
                    assert_eq!(local_deg, g.in_degree(v));
                } else {
                    assert_eq!(local_deg, 0);
                    // halo minimality: ≥1 cross-shard in-edge from v to a
                    // core destination of this shard
                    let feeds = sh
                        .locals
                        .iter()
                        .zip(&sh.is_core)
                        .filter(|&(_, &c)| c)
                        .any(|(&d, _)| g.in_neighbors(d).contains(&v));
                    assert!(feeds, "halo {} never feeds shard {}", v, sh.id);
                }
            }
        }
        // every edge covered exactly once (by its destination's shard)
        let total_edges: u64 = p.shards.iter().map(|s| s.edges).sum();
        assert_eq!(total_edges, g.num_edges());
        let placed: u64 = p.shards.iter().map(|s| s.graph.num_edges()).sum();
        assert_eq!(placed, g.num_edges());
    }

    #[test]
    fn invariants_power_law() {
        let g = generators::power_law(500, 4_000, 1.2, 1.2, 0, 3);
        for k in [1usize, 2, 3, 8] {
            let p = partition(&g, k, 7).unwrap();
            check_invariants(&g, &p);
            if k == 1 {
                assert_eq!(p.cut_edges, 0);
                assert_eq!(p.halo_total(), 0);
            }
        }
    }

    #[test]
    fn invariants_rmat_with_etypes() {
        let g = generators::rmat_typed(9, 3_000, 4, 11);
        let p = partition(&g, 4, 5).unwrap();
        check_invariants(&g, &p);
        // shard subgraphs keep edge types
        assert!(p.shards.iter().all(|s| s.graph.has_etypes()));
    }

    #[test]
    fn deterministic_in_seed() {
        let g = generators::power_law(300, 2_000, 1.1, 1.1, 0, 1);
        let a = partition(&g, 4, 42).unwrap();
        let b = partition(&g, 4, 42).unwrap();
        assert_eq!(a.assign, b.assign);
        assert_eq!(a.cut_edges, b.cut_edges);
    }

    #[test]
    fn loads_balanced() {
        let g = generators::power_law(1_000, 8_000, 1.2, 1.2, 0, 9);
        let p = partition(&g, 4, 3).unwrap();
        let loads: Vec<u64> = p
            .shards
            .iter()
            .map(|s| {
                s.locals
                    .iter()
                    .zip(&s.is_core)
                    .filter(|&(_, &c)| c)
                    .map(|(&v, _)| 1 + g.in_degree(v) as u64)
                    .sum()
            })
            .collect();
        let avg = loads.iter().sum::<u64>() as f64 / 4.0;
        for &l in &loads {
            assert!((l as f64) < avg * 1.25, "loads {loads:?} vs avg {avg}");
        }
    }

    #[test]
    fn rejects_bad_shard_counts() {
        let g = generators::uniform(10, 20, 1);
        assert!(partition(&g, 0, 1).is_err());
        assert!(partition(&g, 11, 1).is_err());
        assert!(partition(&g, 10, 1).is_ok());
    }
}
