//! Graph tiling (paper §5.1, §5.3): grid partitioning of the adjacency
//! matrix into (source-partition × destination-partition) tiles, with the
//! two paper optimizations:
//!
//!   * **sparse tiling** — keep only source vertices that actually have
//!     an edge in the tile (skips useless LD.SRC traffic + compute);
//!   * **degree-sort reordering** — relabel vertices by descending
//!     in-degree before partitioning, concentrating edges into few tiles
//!     so sparse tiling removes more blank rows.
//!
//! The output `Tiling` is the unit of work the compiler's SDE functions
//! and the simulator's streams consume: each tile carries a local COO
//! edge list (`tile-hub` content) plus the list of global source vertices
//! it needs resident in UEM.

use crate::graph::Graph;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TilingMode {
    /// Grid tiling: every vertex of the source partition is loaded.
    Regular,
    /// Sparse tiling: only sources with ≥1 edge in the tile are loaded.
    Sparse,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Reorder {
    None,
    /// Descending in-degree relabel (paper Fig 7c "Degree Sorting").
    InDegree,
    /// Descending out-degree relabel (ablation).
    OutDegree,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TilingConfig {
    /// Destination vertices per partition (dStream granularity).
    pub dst_part: u32,
    /// Source vertices per tile row-block (sStream granularity).
    pub src_part: u32,
    pub mode: TilingMode,
    pub reorder: Reorder,
    /// Host OS threads used to *build* the tiling (per-partition fan-out
    /// at plan-compile time). Purely a cold-start latency knob: the
    /// produced tiling is identical for every value. 0 or 1 = serial.
    pub threads: u32,
}

impl Default for TilingConfig {
    fn default() -> Self {
        // Sized so a partition's worth of f32[*,128] embeddings fits the
        // paper's 21 MB UEM with room for several in-flight tiles.
        TilingConfig {
            dst_part: 2048,
            src_part: 2048,
            mode: TilingMode::Sparse,
            reorder: Reorder::InDegree,
            threads: 1,
        }
    }
}

impl TilingConfig {
    /// The plan-identity view of this config: `threads` is a host-side
    /// compile-latency knob that never changes the produced tiling, so
    /// cache keys normalize it away (see `plan::PlanKey`).
    pub fn cache_key(self) -> TilingConfig {
        TilingConfig { threads: 0, ..self }
    }
}

/// Row-block granularity of sparse skipping: occupancy is credited in
/// blocks of this many source rows (a hardware skip unit works on burst
/// or systolic-row granularity, not single rows). Used by the engine's
/// `KernelPolicy::sparse_skip` timing model.
pub const SKIP_BLOCK: u32 = 8;

/// One tile: the edges between one source block and one destination
/// partition, in local coordinates.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Tile {
    pub partition_id: u32,
    pub tile_id: u32,
    /// Global ids of the source vertices this tile loads (sparse mode:
    /// only those with edges; regular mode: the whole source block).
    pub src_vertices: Vec<u32>,
    /// COO edge list in local coordinates: (index into `src_vertices`,
    /// destination offset within the partition). Tile-hub content.
    pub edges: Vec<(u32, u32)>,
    /// Per-edge relation types if the graph has them (R-GCN), COO order.
    pub etypes: Option<Vec<u8>>,
    /// Touched-source-row bitmap: bit r (word r/64, bit r%64) is set iff
    /// local source row r appears as an edge source. Sparse-mode tiles
    /// are fully occupied by construction (rows are compacted); regular
    /// tiles record which rows of the full block carry edges, feeding
    /// `KernelPolicy::sparse_skip` (see `Tile::new`).
    pub src_occ: Vec<u64>,
    /// Number of set bits in `src_occ`.
    pub occ_rows: u32,
}

impl Tile {
    /// Build a tile, deriving the source-row occupancy from the local
    /// COO edge list. All construction sites go through here so the
    /// occupancy can never drift out of sync with the edges.
    pub fn new(
        partition_id: u32,
        tile_id: u32,
        src_vertices: Vec<u32>,
        edges: Vec<(u32, u32)>,
        etypes: Option<Vec<u8>>,
    ) -> Tile {
        let words = src_vertices.len().div_ceil(64);
        let mut src_occ = vec![0u64; words];
        for &(ls, _) in &edges {
            src_occ[ls as usize / 64] |= 1 << (ls % 64);
        }
        let occ_rows = src_occ.iter().map(|w| w.count_ones()).sum();
        Tile { partition_id, tile_id, src_vertices, edges, etypes, src_occ, occ_rows }
    }

    pub fn num_src(&self) -> u32 {
        self.src_vertices.len() as u32
    }

    pub fn num_edges(&self) -> u32 {
        self.edges.len() as u32
    }

    /// True iff every source row carries at least one edge (always the
    /// case for sparse-mode tiles). Fully occupied tiles take the
    /// unmasked kernel path even under `sparse_skip`.
    pub fn fully_occupied(&self) -> bool {
        self.occ_rows as usize == self.src_vertices.len()
    }

    /// Source rows counted at `block`-row skip granularity: every block
    /// containing ≥1 touched row contributes its full `block` rows
    /// (capped at the tile's row count). This is what the sparse-skip
    /// timing model charges for TileSrc-row compute and LD.SRC traffic.
    pub fn occupied_block_rows(&self, block: u32) -> u32 {
        let n = self.src_vertices.len() as u32;
        if block == 0 || n == 0 {
            return n;
        }
        let mut rows = 0u32;
        let mut blk_start = 0u32;
        while blk_start < n {
            let blk_end = (blk_start + block).min(n);
            let touched = (blk_start..blk_end)
                .any(|r| self.src_occ[r as usize / 64] >> (r % 64) & 1 == 1);
            if touched {
                rows += blk_end - blk_start;
            }
            blk_start = blk_end;
        }
        rows
    }

    /// True iff every *occupied* source row (see `src_occ`) maps to a
    /// vertex flagged `true` in `ok`, indexed by the tile's source
    /// vertex ids. The sharded overlap scheduler (DESIGN.md §3.9) calls
    /// this with a shard's core mask to classify tiles as
    /// halo-independent: such a tile's gathers never read an imported
    /// halo row, so it can execute while the boundary exchange is still
    /// in flight. Unoccupied rows are ignored — a halo vertex that
    /// merely falls inside a regular-mode block without contributing an
    /// edge creates no dependence.
    pub fn occupied_sources_within(&self, ok: &[bool]) -> bool {
        self.src_vertices.iter().enumerate().all(|(r, &v)| {
            self.src_occ[r / 64] >> (r % 64) & 1 == 0 || ok[v as usize]
        })
    }

    /// Bytes of tile metadata held in the Tile Hub: COO pairs (+types).
    pub fn hub_bytes(&self) -> u64 {
        self.edges.len() as u64 * 8 + self.etypes.as_ref().map_or(0, |t| t.len() as u64)
    }
}

/// One destination partition and its tiles.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Partition {
    pub partition_id: u32,
    /// Global destination vertex range [start, end).
    pub dst_start: u32,
    pub dst_end: u32,
    pub tiles: Vec<Tile>,
}

impl Partition {
    pub fn num_dst(&self) -> u32 {
        self.dst_end - self.dst_start
    }
}

/// The tiled graph plus the vertex relabeling applied (if any).
#[derive(Clone, Debug)]
pub struct Tiling {
    pub config: TilingConfig,
    pub partitions: Vec<Partition>,
    /// perm[original_vertex] = tiled_vertex (identity when Reorder::None).
    pub perm: Vec<u32>,
    /// Inverse: tiled_vertex → original_vertex.
    pub inv_perm: Vec<u32>,
    pub num_vertices: u32,
    pub num_edges: u64,
}

/// Artifact equality: the config is compared through
/// [`TilingConfig::cache_key`], so the host-side `threads` knob never
/// makes byte-identical tilings compare unequal.
impl PartialEq for Tiling {
    fn eq(&self, other: &Self) -> bool {
        self.config.cache_key() == other.config.cache_key()
            && self.num_vertices == other.num_vertices
            && self.num_edges == other.num_edges
            && self.perm == other.perm
            && self.inv_perm == other.inv_perm
            && self.partitions == other.partitions
    }
}

impl Eq for Tiling {}

impl Tiling {
    pub fn num_tiles(&self) -> usize {
        self.partitions.iter().map(|p| p.tiles.len()).sum()
    }

    /// Total source-vertex loads across all tiles — the quantity sparse
    /// tiling + reordering reduce (paper Fig 11 left axis is the
    /// off-chip read traffic, dominated by this × embedding bytes).
    pub fn total_src_loads(&self) -> u64 {
        self.partitions
            .iter()
            .flat_map(|p| p.tiles.iter())
            .map(|t| t.src_vertices.len() as u64)
            .sum()
    }

    /// Max source vertices in any single tile (UEM sizing).
    pub fn max_tile_src(&self) -> u32 {
        self.partitions
            .iter()
            .flat_map(|p| p.tiles.iter())
            .map(|t| t.num_src())
            .max()
            .unwrap_or(0)
    }

    pub fn max_tile_edges(&self) -> u32 {
        self.partitions
            .iter()
            .flat_map(|p| p.tiles.iter())
            .map(|t| t.num_edges())
            .max()
            .unwrap_or(0)
    }
}

/// Compute the degree-sort permutation: perm[old] = new, descending key.
fn degree_perm(degrees: &[u32]) -> Vec<u32> {
    let mut order: Vec<u32> = (0..degrees.len() as u32).collect();
    // stable sort: ties keep original id order (deterministic)
    order.sort_by_key(|&v| std::cmp::Reverse(degrees[v as usize]));
    let mut perm = vec![0u32; degrees.len()];
    for (new_id, &old_id) in order.iter().enumerate() {
        perm[old_id as usize] = new_id as u32;
    }
    perm
}

/// Reusable per-thread scratch for partition construction.
#[derive(Default)]
struct TileScratch {
    /// global→local source-id map (sparse tiling hot path).
    local: Vec<u32>,
    /// Per-source-block edge buckets, recycled across partitions.
    buckets: Vec<Vec<(u32, u32, u8)>>,
}

/// Build one destination partition's tiles. Pure function of (graph,
/// cfg, p) — `scratch` only recycles allocations — so partitions can be
/// constructed in any order or concurrently with identical results.
fn build_partition(
    g: &Graph,
    cfg: TilingConfig,
    n: u32,
    blocks_per_part: u32,
    p: u32,
    scratch: &mut TileScratch,
) -> Partition {
    let dst_start = p * cfg.dst_part;
    let dst_end = ((p + 1) * cfg.dst_part).min(n);
    // bucket edges of this partition by source block
    if scratch.buckets.len() < blocks_per_part as usize {
        scratch.buckets.resize_with(blocks_per_part as usize, Vec::new);
    }
    for b in &mut scratch.buckets {
        b.clear();
    }
    for d in dst_start..dst_end {
        let range = g.in_edge_range(d);
        let nbrs = g.in_neighbors(d);
        for (k, &s) in nbrs.iter().enumerate() {
            let et = g.etypes().map_or(0, |t| t[range.start + k]);
            scratch.buckets[(s / cfg.src_part) as usize].push((s, d - dst_start, et));
        }
    }
    let mut tiles = Vec::new();
    for (b, edges) in scratch
        .buckets
        .iter()
        .enumerate()
        .take(blocks_per_part as usize)
    {
        let blk_start = b as u32 * cfg.src_part;
        let blk_end = ((b as u32 + 1) * cfg.src_part).min(n);
        match cfg.mode {
            TilingMode::Regular => {
                if edges.is_empty() && cfg.dst_part < n {
                    // Regular tiling still skips entirely-empty tiles
                    // (no metadata exists for them in any scheme);
                    // the cost difference vs sparse is the blank rows
                    // *within* non-empty tiles.
                    continue;
                }
                let src_vertices: Vec<u32> = (blk_start..blk_end).collect();
                let has_types = g.has_etypes();
                let mut coo = Vec::with_capacity(edges.len());
                let mut types = Vec::new();
                for &(s, dl, et) in edges {
                    coo.push((s - blk_start, dl));
                    if has_types {
                        types.push(et);
                    }
                }
                tiles.push(Tile::new(
                    p,
                    tiles.len() as u32,
                    src_vertices,
                    coo,
                    has_types.then_some(types),
                ));
            }
            TilingMode::Sparse => {
                if edges.is_empty() {
                    continue;
                }
                // compact source ids via a reusable block-local
                // scratch map (O(E) instead of sort+binary-search)
                let blk_len = (blk_end - blk_start) as usize;
                if scratch.local.len() < blk_len {
                    scratch.local.resize(blk_len, u32::MAX);
                }
                let mut uniq: Vec<u32> = Vec::new();
                for &(s, _, _) in edges {
                    let off = (s - blk_start) as usize;
                    if scratch.local[off] == u32::MAX {
                        scratch.local[off] = 0; // present marker
                        uniq.push(s);
                    }
                }
                uniq.sort_unstable(); // keep ascending global order
                for (i, &s) in uniq.iter().enumerate() {
                    scratch.local[(s - blk_start) as usize] = i as u32;
                }
                let has_types = g.has_etypes();
                let mut coo = Vec::with_capacity(edges.len());
                let mut types = Vec::new();
                for &(s, dl, et) in edges {
                    coo.push((scratch.local[(s - blk_start) as usize], dl));
                    if has_types {
                        types.push(et);
                    }
                }
                // reset only the touched entries
                for &s in &uniq {
                    scratch.local[(s - blk_start) as usize] = u32::MAX;
                }
                tiles.push(Tile::new(
                    p,
                    tiles.len() as u32,
                    uniq,
                    coo,
                    has_types.then_some(types),
                ));
            }
        }
    }
    Partition { partition_id: p, dst_start, dst_end, tiles }
}

/// Build every destination partition, fanning out across
/// `cfg.threads` OS threads when asked. Each partition is independent,
/// so the result is identical to the serial order for any thread count
/// (`threads` is a cold-start latency knob, not a semantic one). The
/// crate stays dependency-free: plain `std::thread::scope` workers pull
/// partition ids off an atomic counter (degree-sorted graphs put most
/// edges in the first partitions, so static chunking would imbalance).
fn build_partitions(
    g: &Graph,
    cfg: TilingConfig,
    n: u32,
    num_parts: u32,
    blocks_per_part: u32,
) -> Vec<Partition> {
    let threads = (cfg.threads as usize).min(num_parts as usize);
    if threads <= 1 {
        let mut scratch = TileScratch::default();
        return (0..num_parts)
            .map(|p| build_partition(g, cfg, n, blocks_per_part, p, &mut scratch))
            .collect();
    }
    let next = std::sync::atomic::AtomicU32::new(0);
    let collected = std::sync::Mutex::new(Vec::with_capacity(num_parts as usize));
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                let mut scratch = TileScratch::default();
                let mut built: Vec<Partition> = Vec::new();
                loop {
                    let p = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if p >= num_parts {
                        break;
                    }
                    built.push(build_partition(g, cfg, n, blocks_per_part, p, &mut scratch));
                }
                let mut guard = collected.lock().unwrap_or_else(|e| e.into_inner());
                guard.extend(built);
            });
        }
    });
    let mut partitions = collected.into_inner().unwrap_or_else(|e| e.into_inner());
    partitions.sort_unstable_by_key(|p| p.partition_id);
    partitions
}

/// Process-wide count of [`tile`] invocations. Tiling is the expensive
/// graph-side compile step a multi-layer `plan::ExecPlan` amortizes
/// across every layer, so single-process drivers (benches, the CI
/// `perf_layers --smoke` step) assert this moves by exactly one per
/// compiled plan and not at all on warm requests. Monotonic and global:
/// don't assert exact deltas from concurrently-running tests.
pub fn tile_invocations() -> u64 {
    TILE_INVOCATIONS.load(std::sync::atomic::Ordering::Relaxed)
}

static TILE_INVOCATIONS: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// Tile a graph under `cfg`. The graph is relabeled first if reordering
/// is requested; `Tiling::perm` records the mapping so embeddings can be
/// permuted consistently (the coordinator does this once at load time).
pub fn tile(graph: &Graph, cfg: TilingConfig) -> Tiling {
    TILE_INVOCATIONS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let n = graph.num_vertices();
    let perm: Vec<u32> = match cfg.reorder {
        Reorder::None => (0..n).collect(),
        Reorder::InDegree => degree_perm(&graph.in_degrees()),
        Reorder::OutDegree => degree_perm(&graph.out_degrees()),
    };
    let owned;
    let g: &Graph = if matches!(cfg.reorder, Reorder::None) {
        graph
    } else {
        owned = graph.relabel(&perm).expect("degree_perm builds a valid permutation");
        &owned
    };

    let mut inv_perm = vec![0u32; n as usize];
    for (old, &new) in perm.iter().enumerate() {
        inv_perm[new as usize] = old as u32;
    }

    let num_parts = crate::util::ceil_div(n as u64, cfg.dst_part as u64) as u32;
    let blocks_per_part = crate::util::ceil_div(n as u64, cfg.src_part as u64) as u32;
    let partitions = build_partitions(g, cfg, n, num_parts, blocks_per_part);

    Tiling {
        config: cfg,
        partitions,
        perm,
        inv_perm,
        num_vertices: n,
        num_edges: graph.num_edges(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, GraphBuilder};

    fn small() -> Graph {
        // 8 vertices; edges concentrate on dsts 0,1
        let mut b = GraphBuilder::new(8);
        for s in 0..6u32 {
            b.add_edge(s, 0).unwrap();
        }
        b.add_edge(6, 1).unwrap();
        b.add_edge(7, 5).unwrap();
        b.build()
    }

    fn cfg(mode: TilingMode, reorder: Reorder) -> TilingConfig {
        TilingConfig { dst_part: 4, src_part: 4, mode, reorder, threads: 1 }
    }

    #[test]
    fn edge_conservation_regular() {
        let g = small();
        let t = tile(&g, cfg(TilingMode::Regular, Reorder::None));
        let total: u64 = t
            .partitions
            .iter()
            .flat_map(|p| p.tiles.iter())
            .map(|x| x.num_edges() as u64)
            .sum();
        assert_eq!(total, g.num_edges());
    }

    #[test]
    fn edge_conservation_sparse_reordered() {
        let g = generators::power_law(300, 2_000, 1.1, 1.1, 0, 4);
        for reorder in [Reorder::None, Reorder::InDegree, Reorder::OutDegree] {
            let t = tile(
                &g,
                TilingConfig {
                    dst_part: 64,
                    src_part: 64,
                    mode: TilingMode::Sparse,
                    reorder,
                    threads: 1,
                },
            );
            let total: u64 = t
                .partitions
                .iter()
                .flat_map(|p| p.tiles.iter())
                .map(|x| x.num_edges() as u64)
                .sum();
            assert_eq!(total, g.num_edges());
        }
    }

    #[test]
    fn sparse_loads_fewer_sources() {
        let g = generators::power_law(512, 1_024, 1.2, 1.2, 0, 9);
        let reg = tile(&g, TilingConfig { dst_part: 64, src_part: 64,
            mode: TilingMode::Regular, reorder: Reorder::None, threads: 1 });
        let sp = tile(&g, TilingConfig { dst_part: 64, src_part: 64,
            mode: TilingMode::Sparse, reorder: Reorder::None, threads: 1 });
        assert!(sp.total_src_loads() < reg.total_src_loads());
    }

    #[test]
    fn reordering_reduces_sparse_loads_on_power_law() {
        // the paper's Fig 11 effect: sparse+reorder < sparse < regular
        let g = generators::power_law(2_000, 16_000, 1.2, 1.2, 0, 11);
        let mk = |mode, reorder| {
            tile(&g, TilingConfig { dst_part: 128, src_part: 128, mode, reorder, threads: 1 })
                .total_src_loads()
        };
        let regular = mk(TilingMode::Regular, Reorder::None);
        let sparse = mk(TilingMode::Sparse, Reorder::None);
        let sorted = mk(TilingMode::Sparse, Reorder::InDegree);
        assert!(sparse < regular, "sparse {sparse} !< regular {regular}");
        assert!(sorted < sparse, "sorted {sorted} !< sparse {sparse}");
    }

    #[test]
    fn local_indices_in_bounds() {
        let g = generators::power_law(500, 3_000, 1.0, 1.0, 3, 13);
        let t = tile(&g, cfg(TilingMode::Sparse, Reorder::InDegree));
        for p in &t.partitions {
            for tl in &p.tiles {
                for &(ls, ld) in &tl.edges {
                    assert!(ls < tl.num_src());
                    assert!(ld < p.num_dst());
                }
                assert_eq!(
                    tl.etypes.as_ref().map(|x| x.len()),
                    Some(tl.edges.len())
                );
            }
        }
    }

    #[test]
    fn perm_is_consistent() {
        let g = generators::power_law(200, 900, 1.1, 1.1, 0, 17);
        let t = tile(&g, cfg(TilingMode::Sparse, Reorder::InDegree));
        for old in 0..200u32 {
            assert_eq!(t.inv_perm[t.perm[old as usize] as usize], old);
        }
        // highest in-degree vertex maps to id 0
        let degs = g.in_degrees();
        let max_v = (0..200u32).max_by_key(|&v| degs[v as usize]).unwrap();
        assert_eq!(t.perm[max_v as usize], 0);
    }

    #[test]
    fn sparse_edges_map_to_correct_sources() {
        // functional round-trip: reconstruct global edges from tiles
        let g = small();
        let t = tile(&g, cfg(TilingMode::Sparse, Reorder::None));
        let mut rebuilt: Vec<(u32, u32)> = Vec::new();
        for p in &t.partitions {
            for tl in &p.tiles {
                for &(ls, ld) in &tl.edges {
                    rebuilt.push((tl.src_vertices[ls as usize], p.dst_start + ld));
                }
            }
        }
        rebuilt.sort_unstable();
        let mut expected: Vec<(u32, u32)> = Vec::new();
        for d in 0..8u32 {
            for &s in g.in_neighbors(d) {
                expected.push((s, d));
            }
        }
        expected.sort_unstable();
        assert_eq!(rebuilt, expected);
    }

    #[test]
    fn parallel_tiling_matches_serial() {
        // threads is a latency knob only: identical partitions/tiles for
        // every thread count, including more threads than partitions
        let g = generators::power_law(3_000, 24_000, 1.2, 1.2, 2, 5);
        for (mode, reorder) in [
            (TilingMode::Sparse, Reorder::InDegree),
            (TilingMode::Regular, Reorder::None),
        ] {
            let base_cfg = TilingConfig {
                dst_part: 128,
                src_part: 128,
                mode,
                reorder,
                threads: 1,
            };
            let base = tile(&g, base_cfg);
            for threads in [0u32, 2, 4, 7, 64] {
                let par = tile(&g, TilingConfig { threads, ..base_cfg });
                assert_eq!(base.partitions, par.partitions, "threads={threads}");
                assert_eq!(base.perm, par.perm, "threads={threads}");
                assert_eq!(base.inv_perm, par.inv_perm, "threads={threads}");
                // whole-artifact equality ignores the threads knob
                assert_eq!(base, par, "threads={threads}");
            }
        }
    }

    #[test]
    fn cache_key_normalizes_threads() {
        let a = TilingConfig { threads: 1, ..TilingConfig::default() };
        let b = TilingConfig { threads: 8, ..TilingConfig::default() };
        assert_ne!(a, b);
        assert_eq!(a.cache_key(), b.cache_key());
    }

    #[test]
    fn occupancy_tracks_edge_sources() {
        let g = generators::power_law(600, 2_400, 1.2, 1.2, 0, 7);
        for mode in [TilingMode::Regular, TilingMode::Sparse] {
            let t = tile(&g, TilingConfig { dst_part: 64, src_part: 64,
                mode, reorder: Reorder::None, threads: 1 });
            for p in &t.partitions {
                for tl in &p.tiles {
                    let mut touched = vec![false; tl.src_vertices.len()];
                    for &(ls, _) in &tl.edges {
                        touched[ls as usize] = true;
                    }
                    let expect = touched.iter().filter(|&&b| b).count() as u32;
                    assert_eq!(tl.occ_rows, expect);
                    for (r, &b) in touched.iter().enumerate() {
                        assert_eq!(tl.src_occ[r / 64] >> (r % 64) & 1 == 1, b);
                    }
                    // block-granular count is between exact and full
                    let blk = tl.occupied_block_rows(SKIP_BLOCK);
                    assert!(blk >= tl.occ_rows && blk <= tl.num_src());
                    if mode == TilingMode::Sparse {
                        // sparse compaction ⇒ every row has an edge
                        assert!(tl.fully_occupied());
                        assert_eq!(blk, tl.num_src());
                    }
                }
            }
        }
    }

    #[test]
    fn occupied_block_rows_rounds_to_blocks() {
        // 20 src rows, edges touch rows 0 and 17 only
        let t = Tile::new(0, 0, (0..20).collect(), vec![(0, 0), (17, 1)], None);
        assert_eq!(t.occ_rows, 2);
        assert!(!t.fully_occupied());
        // blocks of 8: [0..8) touched, [8..16) empty, [16..20) touched
        assert_eq!(t.occupied_block_rows(8), 8 + 4);
        assert_eq!(t.occupied_block_rows(1), 2);
        assert_eq!(t.occupied_block_rows(0), 20, "0 disables skipping");
        assert_eq!(t.occupied_block_rows(64), 20);
    }

    #[test]
    fn occupied_sources_within_ignores_untouched_rows() {
        // 20 src rows (vertices 0..20), edges touch rows 0 and 17 only
        let t = Tile::new(0, 0, (0..20).collect(), vec![(0, 0), (17, 1)], None);
        let mut ok = vec![true; 20];
        assert!(t.occupied_sources_within(&ok));
        ok[5] = false; // untouched row: no dependence
        assert!(t.occupied_sources_within(&ok));
        ok[17] = false; // touched row outside the mask: dependent
        assert!(!t.occupied_sources_within(&ok));
        // sparse-style tile: every row occupied, so every source counts
        let s = Tile::new(0, 1, vec![3, 9], vec![(0, 0), (1, 0)], None);
        assert!(s.fully_occupied());
        let mut ok = vec![true; 10];
        assert!(s.occupied_sources_within(&ok));
        ok[9] = false;
        assert!(!s.occupied_sources_within(&ok));
    }

    #[test]
    fn single_partition_degenerate() {
        let g = small();
        let t = tile(&g, TilingConfig { dst_part: 1_000, src_part: 1_000,
            mode: TilingMode::Regular, reorder: Reorder::None, threads: 1 });
        assert_eq!(t.partitions.len(), 1);
        assert_eq!(t.num_tiles(), 1);
        assert_eq!(t.partitions[0].tiles[0].num_src(), 8);
    }
}
