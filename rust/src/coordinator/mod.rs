//! L3 coordinator: the serving front-end over the ZIPPER stack.
//!
//! Responsibilities:
//!   * **Plans** — compile-once bundles (`plan::ExecPlan`): dataset →
//!     graph → tiling → compiled SDE program → weights, cached per
//!     structured `PlanKey` and shared across workers as `Arc`s.
//!   * **Serving** — a worker pool consuming inference requests from a
//!     queue; each worker reuses one `ExecScratch`, so a warm request
//!     does zero recompile/retile work and almost no allocation.
//!   * **Validation** — the three-layer glue: execute the same tiles
//!     through the PJRT-loaded JAX artifacts and compare against the
//!     simulator's functional output (paper §8.1: "validate ... the
//!     functionality of each operation and the tiling-based execution
//!     against DGL" — our DGL is the L2 JAX model).

pub mod validate;

use crate::compiler::Program;
use crate::config::{ArchConfig, RunConfig};
use crate::energy::EnergyModel;
use crate::graph::Graph;
use crate::models::{ModelKind, WeightStore};
use crate::plan::{CacheStats, ExecPlan, PlanCache};
use crate::sim::{ExecScratch, SimResult};
use crate::tiling::Tiling;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// A prepared inference session: a thin handle over a shared, immutable
/// [`ExecPlan`]. Cheap to clone; all per-run state lives in the caller's
/// scratch. Kept as the stable front-door API for benches and examples.
#[derive(Clone)]
pub struct Session {
    plan: Arc<ExecPlan>,
}

impl Session {
    /// Compile a session from a run config (dataset registry + compiler).
    pub fn prepare(run: &RunConfig) -> Result<Session, String> {
        Ok(Session { plan: Arc::new(ExecPlan::compile(run)?) })
    }

    /// Build a session around an explicit graph (tests, examples).
    pub fn from_graph(model: ModelKind, graph: Graph, run: &RunConfig) -> Result<Session, String> {
        Ok(Session { plan: Arc::new(ExecPlan::from_graph(model, graph, run)?) })
    }

    /// Wrap an already-compiled shared plan (plan-cache hit path).
    pub fn from_plan(plan: Arc<ExecPlan>) -> Session {
        Session { plan }
    }

    pub fn plan(&self) -> &Arc<ExecPlan> {
        &self.plan
    }

    pub fn model(&self) -> ModelKind {
        self.plan.model
    }

    pub fn graph(&self) -> &Graph {
        &self.plan.graph
    }

    pub fn tiling(&self) -> &Tiling {
        &self.plan.tiling
    }

    pub fn program(&self) -> &Program {
        &self.plan.program
    }

    pub fn weights(&self) -> &WeightStore {
        &self.plan.weights
    }

    pub fn feat_in(&self) -> u32 {
        self.plan.feat_in
    }

    pub fn feat_out(&self) -> u32 {
        self.plan.feat_out
    }

    /// Deterministic input embeddings for this session's graph.
    pub fn make_input(&self, seed: u64) -> Vec<f32> {
        self.plan.make_input(seed)
    }

    /// Run the cycle-level simulation (optionally functional).
    pub fn simulate(
        &self,
        arch: &ArchConfig,
        functional: bool,
        x: Option<&[f32]>,
        trace_window: u64,
    ) -> Result<SimResult, String> {
        self.plan.simulate(arch, functional, x, trace_window)
    }

    /// Re-entrant variant reusing a caller-owned scratch (hot path).
    pub fn simulate_with(
        &self,
        arch: &ArchConfig,
        functional: bool,
        x: Option<&[f32]>,
        trace_window: u64,
        scratch: &mut ExecScratch,
    ) -> Result<SimResult, String> {
        self.plan.simulate_with(arch, functional, x, trace_window, scratch)
    }
}

/// One inference request handled by the serving loop.
#[derive(Clone, Debug)]
pub struct InferenceRequest {
    pub id: u64,
    pub run: RunConfig,
    /// Seed for the request's input embeddings.
    pub input_seed: u64,
}

/// The response: simulated device time + host-side serving latency.
#[derive(Clone, Debug)]
pub struct InferenceResponse {
    pub id: u64,
    pub model: String,
    pub dataset: String,
    /// Simulated accelerator latency (cycles / seconds @ arch clock).
    pub sim_cycles: u64,
    pub sim_seconds: f64,
    pub energy_j: f64,
    /// Wall-clock serving latency (queue + prepare + simulate).
    pub wall_seconds: f64,
    /// Whether the execution plan came from the cache (warm request).
    pub plan_cache_hit: bool,
    /// Host seconds spent compiling the plan (0 on a warm request).
    pub prepare_seconds: f64,
    /// Checksum of the output embeddings (functional runs).
    pub output_checksum: Option<f64>,
    pub error: Option<String>,
}

impl InferenceResponse {
    fn empty(id: u64, model: &str, dataset: &str) -> InferenceResponse {
        InferenceResponse {
            id,
            model: model.to_string(),
            dataset: dataset.to_string(),
            sim_cycles: 0,
            sim_seconds: 0.0,
            energy_j: 0.0,
            wall_seconds: 0.0,
            plan_cache_hit: false,
            prepare_seconds: 0.0,
            output_checksum: None,
            error: None,
        }
    }

    fn failed(id: u64, model: &str, dataset: &str, error: String) -> InferenceResponse {
        InferenceResponse { error: Some(error), ..Self::empty(id, model, dataset) }
    }
}

/// Multi-threaded serving coordinator over a shared [`PlanCache`].
pub struct Coordinator {
    tx: Option<mpsc::Sender<InferenceRequest>>,
    rx_resp: mpsc::Receiver<InferenceResponse>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// (id, model, dataset) per submitted request, so drain can report
    /// losses instead of silently truncating.
    submitted: Vec<(u64, String, String)>,
    /// Responses synthesized locally (e.g. when the queue is gone).
    local: Vec<InferenceResponse>,
    cache: Arc<PlanCache>,
}

impl Coordinator {
    pub fn new(arch: ArchConfig, num_workers: usize) -> Coordinator {
        Self::with_cache(arch, num_workers, Arc::new(PlanCache::new()))
    }

    /// Share an existing plan cache (warm restarts, cold/warm benches).
    pub fn with_cache(arch: ArchConfig, num_workers: usize, cache: Arc<PlanCache>) -> Coordinator {
        let (tx, rx) = mpsc::channel::<InferenceRequest>();
        let (tx_resp, rx_resp) = mpsc::channel::<InferenceResponse>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::new();
        for _ in 0..num_workers.max(1) {
            let rx = Arc::clone(&rx);
            let tx_resp = tx_resp.clone();
            let cache = Arc::clone(&cache);
            workers.push(std::thread::spawn(move || {
                // per-worker scratch: reused across every request this
                // worker serves (the allocation-light hot path)
                let mut scratch = ExecScratch::new();
                loop {
                    let req = {
                        let guard = match rx.lock() {
                            Ok(g) => g,
                            // a peer panicked while holding the queue
                            // lock; the queue itself is still sound
                            Err(poisoned) => poisoned.into_inner(),
                        };
                        guard.recv()
                    };
                    let Ok(req) = req else { break };
                    let t0 = Instant::now();
                    let resp = catch_unwind(AssertUnwindSafe(|| {
                        handle(&arch, &cache, &req, t0, &mut scratch)
                    }))
                    .unwrap_or_else(|panic| {
                        InferenceResponse::failed(
                            req.id,
                            &req.run.model,
                            &req.run.dataset,
                            format!("worker panicked: {}", panic_message(panic.as_ref())),
                        )
                    });
                    if tx_resp.send(resp).is_err() {
                        break;
                    }
                }
            }));
        }
        Coordinator {
            tx: Some(tx),
            rx_resp,
            workers,
            submitted: Vec::new(),
            local: Vec::new(),
            cache,
        }
    }

    pub fn cache(&self) -> &Arc<PlanCache> {
        &self.cache
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Enqueue a request. Never panics: if the worker pool is gone (all
    /// workers exited) the failure is reported as an error response.
    pub fn submit(&mut self, req: InferenceRequest) {
        self.submitted.push((req.id, req.run.model.clone(), req.run.dataset.clone()));
        let sent = match &self.tx {
            Some(tx) => tx.send(req).map_err(|e| e.0),
            None => Err(req),
        };
        if let Err(req) = sent {
            self.local.push(InferenceResponse::failed(
                req.id,
                &req.run.model,
                &req.run.dataset,
                "worker pool unavailable (already drained or all workers exited)".into(),
            ));
        }
    }

    /// Close the queue and collect all responses (arrival order). Every
    /// submitted request yields exactly one response: requests lost to a
    /// worker failure come back as error responses instead of being
    /// silently dropped.
    pub fn drain(&mut self) -> Vec<InferenceResponse> {
        drop(self.tx.take());
        let expected = self.submitted.len();
        let mut out = std::mem::take(&mut self.local);
        while out.len() < expected {
            match self.rx_resp.recv() {
                Ok(r) => out.push(r),
                Err(_) => break, // all workers gone; report losses below
            }
        }
        let mut panics = Vec::new();
        for w in self.workers.drain(..) {
            if let Err(p) = w.join() {
                panics.push(panic_message(p.as_ref()).to_string());
            }
        }
        if out.len() < expected {
            let detail = if panics.is_empty() {
                "worker exited early".to_string()
            } else {
                format!("worker panicked: {}", panics.join("; "))
            };
            // per-id multiset accounting: ids are caller-chosen and may
            // repeat, so count received responses per id instead of
            // testing mere presence
            let mut received: std::collections::HashMap<u64, usize> = std::collections::HashMap::new();
            for r in &out {
                *received.entry(r.id).or_insert(0) += 1;
            }
            let submitted = std::mem::take(&mut self.submitted);
            for (id, model, dataset) in submitted {
                match received.get_mut(&id) {
                    Some(n) if *n > 0 => *n -= 1,
                    _ => out.push(InferenceResponse::failed(id, &model, &dataset, detail.clone())),
                }
            }
        } else {
            self.submitted.clear();
        }
        out
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn panic_message(panic: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = panic.downcast_ref::<&str>() {
        s
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

fn handle(
    arch: &ArchConfig,
    cache: &PlanCache,
    req: &InferenceRequest,
    t0: Instant,
    scratch: &mut ExecScratch,
) -> InferenceResponse {
    let base = InferenceResponse::empty(req.id, &req.run.model, &req.run.dataset);
    let (plan, hit) = match cache.get_or_compile(&req.run) {
        Ok(p) => p,
        Err(e) => {
            return InferenceResponse {
                error: Some(e),
                wall_seconds: t0.elapsed().as_secs_f64(),
                ..base
            }
        }
    };
    let prepare_seconds = if hit { 0.0 } else { t0.elapsed().as_secs_f64() };
    let x;
    let input = if req.run.functional {
        x = plan.make_input(req.input_seed);
        Some(x.as_slice())
    } else {
        None
    };
    match plan.simulate_with(arch, req.run.functional, input, 0, scratch) {
        Ok(res) => {
            let energy = EnergyModel::default()
                .evaluate(&res.counters, arch.freq_hz)
                .total_j();
            InferenceResponse {
                sim_cycles: res.cycles,
                sim_seconds: res.seconds(arch),
                energy_j: energy,
                wall_seconds: t0.elapsed().as_secs_f64(),
                plan_cache_hit: hit,
                prepare_seconds,
                output_checksum: res.output.map(|o| o.iter().map(|&v| v as f64).sum::<f64>()),
                ..base
            }
        }
        Err(e) => InferenceResponse {
            error: Some(e),
            wall_seconds: t0.elapsed().as_secs_f64(),
            plan_cache_hit: hit,
            prepare_seconds,
            ..base
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tiling::{Reorder, TilingConfig, TilingMode};

    fn small_run(model: &str, functional: bool) -> RunConfig {
        RunConfig {
            model: model.into(),
            dataset: "CR".into(),
            scale: 16,
            feat_in: 16,
            feat_out: 16,
            tiling: TilingConfig {
                dst_part: 64,
                src_part: 64,
                mode: TilingMode::Sparse,
                reorder: Reorder::InDegree,
                threads: 1,
            },
            e2v: true,
            functional,
            seed: 3,
        }
    }

    #[test]
    fn session_prepare_and_simulate() {
        let run = small_run("gcn", true);
        let s = Session::prepare(&run).unwrap();
        let x = s.make_input(1);
        let res = s.simulate(&ArchConfig::default(), true, Some(&x), 0).unwrap();
        assert!(res.cycles > 0);
        assert!(res.output.unwrap().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn coordinator_serves_batch() {
        let mut c = Coordinator::new(ArchConfig::default(), 2);
        for (i, m) in ["gcn", "gat", "sage"].iter().enumerate() {
            c.submit(InferenceRequest {
                id: i as u64,
                run: small_run(m, false),
                input_seed: i as u64,
            });
        }
        let mut resp = c.drain();
        assert_eq!(resp.len(), 3);
        resp.sort_by_key(|r| r.id);
        for r in &resp {
            assert!(r.error.is_none(), "{:?}", r.error);
            assert!(r.sim_cycles > 0);
            assert!(r.energy_j > 0.0);
        }
    }

    #[test]
    fn session_cache_reused_across_requests() {
        // identical keys → one compiled plan → identical cycles, and the
        // repeats must be recorded as cache hits
        let mut c = Coordinator::new(ArchConfig::default(), 2);
        for i in 0..4 {
            c.submit(InferenceRequest { id: i, run: small_run("gcn", false), input_seed: i });
        }
        let resp = c.drain();
        let cycles: Vec<u64> = resp.iter().map(|r| r.sim_cycles).collect();
        assert!(cycles.windows(2).all(|w| w[0] == w[1]), "{cycles:?}");
        // with 2 workers the first two requests may race to compile, but
        // at least the trailing requests must be warm
        let hits = resp.iter().filter(|r| r.plan_cache_hit).count();
        assert!(hits >= 2, "expected ≥2 warm responses, got {hits}");
        assert_eq!(c.cache_stats().entries, 1);
    }

    #[test]
    fn bad_model_reports_error() {
        let mut c = Coordinator::new(ArchConfig::default(), 1);
        let mut run = small_run("gcn", false);
        run.model = "transformer".into();
        c.submit(InferenceRequest { id: 0, run, input_seed: 0 });
        let resp = c.drain();
        assert!(resp[0].error.is_some());
    }

    #[test]
    fn submit_after_drain_reports_error_instead_of_panicking() {
        let mut c = Coordinator::new(ArchConfig::default(), 1);
        c.submit(InferenceRequest { id: 0, run: small_run("gcn", false), input_seed: 0 });
        let first = c.drain();
        assert_eq!(first.len(), 1);
        c.submit(InferenceRequest { id: 1, run: small_run("gcn", false), input_seed: 1 });
        let second = c.drain();
        assert_eq!(second.len(), 1);
        assert!(second[0].error.as_deref().unwrap().contains("worker pool unavailable"));
    }
}
