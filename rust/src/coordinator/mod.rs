//! L3 coordinator: the serving front-end over the ZIPPER stack.
//!
//! Responsibilities:
//!   * **Plans** — compile-once bundles (`plan::ExecPlan`): dataset →
//!     graph → tiling → compiled SDE program → weights, cached per
//!     structured `PlanKey` and shared across workers as `Arc`s.
//!   * **Serving** — the always-on [`service::ZipperService`] runtime:
//!     bounded admission, dual-trigger batching (fill or `max_wait_us`
//!     timer), per-request deadlines with structured load shedding, and
//!     graceful shutdown. A worker serves a plan-compatible batch with
//!     a single input-independent timing simulation plus one
//!     tile-parallel batched functional pass (`sim::parallel`),
//!     amortizing plan lookup, LD.SRC/LD.DST tile traversal, and the
//!     cycle-level simulation across the batch while keeping
//!     per-request responses and latency accounting. The closed-loop
//!     [`Coordinator`] (submit a burst, block in `drain`) is kept as a
//!     thin compatibility wrapper over the service.
//!   * **Validation** — the three-layer glue: execute the same tiles
//!     through the PJRT-loaded JAX artifacts and compare against the
//!     simulator's functional output (paper §8.1: "validate ... the
//!     functionality of each operation and the tiling-based execution
//!     against DGL" — our DGL is the L2 JAX model).

pub mod service;
pub mod validate;

pub use service::{ServiceMetrics, ShutdownReport, Ticket, ZipperService};

use crate::compiler::Program;
use crate::config::{ArchConfig, RunConfig, ServingConfig};
use crate::graph::Graph;
use crate::models::{ModelKind, ModelSpec, WeightStore};
use crate::plan::{CacheStats, ExecPlan, PlanCache, PlanKey};
use crate::sim::{ExecScratch, SimResult};
use crate::tiling::Tiling;
use std::collections::HashMap;
use std::fmt;
use std::sync::Arc;
use std::time::Duration;

/// A prepared inference session: a thin handle over a shared, immutable
/// [`ExecPlan`]. Cheap to clone; all per-run state lives in the caller's
/// scratch. Kept as the stable front-door API for benches and examples.
///
/// # Examples
///
/// Compile once, then simulate functionally and read the embeddings:
///
/// ```
/// use zipper::config::{ArchConfig, RunConfig};
/// use zipper::coordinator::Session;
///
/// let mut run = RunConfig::default();
/// run.dataset = "CR".into(); // tiny citation-graph stand-in
/// run.scale = 64;
/// run.feat_in = 8;
/// run.feat_out = 8;
/// run.functional = true;
///
/// let session = Session::prepare(&run).unwrap();
/// let x = session.make_input(1);
/// let res = session
///     .simulate(&ArchConfig::default(), true, Some(&x), 0)
///     .unwrap();
/// assert!(res.cycles > 0);
/// assert_eq!(
///     res.output.unwrap().len(),
///     session.plan().dims.output_len
/// );
/// ```
#[derive(Clone)]
pub struct Session {
    plan: Arc<ExecPlan>,
}

impl Session {
    /// Compile a session from a run config (dataset registry + compiler).
    pub fn prepare(run: &RunConfig) -> Result<Session, String> {
        Ok(Session { plan: Arc::new(ExecPlan::compile(run)?) })
    }

    /// Build a session around an explicit graph (tests, examples).
    pub fn from_graph(model: ModelKind, graph: Graph, run: &RunConfig) -> Result<Session, String> {
        Ok(Session { plan: Arc::new(ExecPlan::from_graph(model, graph, run)?) })
    }

    /// Wrap an already-compiled shared plan (plan-cache hit path).
    pub fn from_plan(plan: Arc<ExecPlan>) -> Session {
        Session { plan }
    }

    pub fn plan(&self) -> &Arc<ExecPlan> {
        &self.plan
    }

    pub fn model(&self) -> ModelKind {
        self.plan.model
    }

    /// Resolved layer chain (depth, per-layer widths, activations).
    pub fn spec(&self) -> &ModelSpec {
        &self.plan.spec
    }

    /// Pipeline depth (≥ 1).
    pub fn depth(&self) -> usize {
        self.plan.depth()
    }

    pub fn graph(&self) -> &Graph {
        &self.plan.graph
    }

    pub fn tiling(&self) -> &Tiling {
        &self.plan.tiling
    }

    /// The first layer stage's compiled program (the whole model for
    /// depth-1 sessions; see [`crate::plan::ExecPlan::stages`] for the
    /// full pipeline).
    pub fn program(&self) -> &Program {
        &self.plan.stages[0].program
    }

    /// The first layer stage's weights (see
    /// [`crate::plan::ExecPlan::stages`] for deeper layers).
    pub fn weights(&self) -> &WeightStore {
        &self.plan.stages[0].weights
    }

    /// First layer's input embedding width.
    pub fn feat_in(&self) -> u32 {
        self.plan.feat_in
    }

    /// Final layer's output embedding width.
    pub fn feat_out(&self) -> u32 {
        self.plan.feat_out
    }

    /// Deterministic input embeddings for this session's graph.
    pub fn make_input(&self, seed: u64) -> Vec<f32> {
        self.plan.make_input(seed)
    }

    /// Run the cycle-level simulation (optionally functional).
    pub fn simulate(
        &self,
        arch: &ArchConfig,
        functional: bool,
        x: Option<&[f32]>,
        trace_window: u64,
    ) -> Result<SimResult, String> {
        self.plan.simulate(arch, functional, x, trace_window)
    }

    /// Re-entrant variant reusing a caller-owned scratch (hot path).
    pub fn simulate_with(
        &self,
        arch: &ArchConfig,
        functional: bool,
        x: Option<&[f32]>,
        trace_window: u64,
        scratch: &mut ExecScratch,
    ) -> Result<SimResult, String> {
        self.plan.simulate_with(arch, functional, x, trace_window, scratch)
    }
}

/// One inference request handled by the serving loop.
#[derive(Clone, Debug)]
pub struct InferenceRequest {
    pub id: u64,
    pub run: RunConfig,
    /// Seed for the request's input embeddings.
    pub input_seed: u64,
}

/// Why the serving runtime shed a request instead of executing it.
/// Carried structurally on [`InferenceResponse::reject`] so callers can
/// branch on overload vs. deadline vs. shutdown without parsing the
/// human-readable `error` string.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The admission queue was at `queue_cap` under
    /// [`crate::config::OverflowPolicy::Reject`].
    QueueFull,
    /// The request's deadline expired — at admission, or shed at
    /// dispatch after the queue wait consumed the budget.
    DeadlineExceeded,
    /// The service stopped admission (shutdown), or the request was
    /// still queued when the shutdown grace period ran out.
    ShuttingDown,
}

impl RejectReason {
    pub fn as_str(self) -> &'static str {
        match self {
            RejectReason::QueueFull => "queue_full",
            RejectReason::DeadlineExceeded => "deadline_exceeded",
            RejectReason::ShuttingDown => "shutting_down",
        }
    }
}

impl fmt::Display for RejectReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One layer's slice of a response's cost (Fig 2-style depth
/// breakdown): cycles/DRAM/energy are additive across a pipeline's
/// layers, so `sum(layers[i].cycles) == sim_cycles`.
#[derive(Clone, Debug)]
pub struct LayerCost {
    pub feat_in: u32,
    pub feat_out: u32,
    pub cycles: u64,
    pub dram_read_bytes: u64,
    pub dram_write_bytes: u64,
    pub energy_j: f64,
}

/// The response: simulated device time + host-side serving latency.
#[derive(Clone, Debug)]
pub struct InferenceResponse {
    pub id: u64,
    pub model: String,
    pub dataset: String,
    /// Simulated accelerator latency (cycles / seconds @ arch clock),
    /// summed over the pipeline's layers.
    pub sim_cycles: u64,
    pub sim_seconds: f64,
    pub energy_j: f64,
    /// Per-layer cost breakdown (one entry per layer, depth-1 included).
    pub layers: Vec<LayerCost>,
    /// Peak UEM residency across the whole pipeline, inter-layer
    /// activation images included (Fig 2's footprint story).
    pub peak_uem_bytes: u64,
    /// End-to-end wall-clock serving latency, submit → response
    /// (queue wait + prepare + simulate).
    pub wall_seconds: f64,
    /// Time spent queued between admission and worker pickup (part of
    /// `wall_seconds`).
    pub queue_seconds: f64,
    /// Whether the execution plan came from the cache (warm request).
    pub plan_cache_hit: bool,
    /// Host seconds spent compiling the plan (0 on a warm request).
    pub prepare_seconds: f64,
    /// How many requests shared this request's batched pass (≥ 1).
    pub batch_size: usize,
    /// Chip-to-chip halo-exchange bytes billed to this request's timing
    /// run (sharded plans only, 0 otherwise — DESIGN.md §3.8).
    pub halo_bytes: u64,
    /// Halo-exchange cycles hidden behind halo-independent compute by
    /// the operator-level overlap schedule (DESIGN.md §3.9; 0 unless
    /// the plan was compiled with `overlap`).
    pub halo_hidden_cycles: u64,
    /// Halo-exchange cycles left on the simulated critical path
    /// (equals the full exchange cost for overlap-off sharded plans).
    pub halo_exposed_cycles: u64,
    /// Checksum of the output embeddings (functional runs).
    pub output_checksum: Option<f64>,
    /// Structured shed reason, if the runtime rejected this request
    /// instead of executing it (`error` then carries the human string).
    pub reject: Option<RejectReason>,
    pub error: Option<String>,
}

impl InferenceResponse {
    pub(crate) fn empty(id: u64, model: &str, dataset: &str) -> InferenceResponse {
        InferenceResponse {
            id,
            model: model.to_string(),
            dataset: dataset.to_string(),
            sim_cycles: 0,
            sim_seconds: 0.0,
            energy_j: 0.0,
            layers: Vec::new(),
            peak_uem_bytes: 0,
            wall_seconds: 0.0,
            queue_seconds: 0.0,
            plan_cache_hit: false,
            prepare_seconds: 0.0,
            batch_size: 1,
            halo_bytes: 0,
            halo_hidden_cycles: 0,
            halo_exposed_cycles: 0,
            output_checksum: None,
            reject: None,
            error: None,
        }
    }

    pub(crate) fn failed(id: u64, model: &str, dataset: &str, error: String) -> InferenceResponse {
        InferenceResponse { error: Some(error), ..Self::empty(id, model, dataset) }
    }
}

/// Groups queued requests into executable batches: requests sharing one
/// execution plan (same [`PlanKey`]) *and* the same functional flag may
/// ride one batched pass, capped at `max_batch` per batch. Grouping
/// preserves first-arrival order of groups and request order within a
/// group, so serving stays deterministic.
pub struct BatchPlanner {
    max_batch: usize,
}

impl BatchPlanner {
    /// `max_batch` is clamped to ≥ 1 (1 = no batching, the default).
    pub fn new(max_batch: usize) -> BatchPlanner {
        BatchPlanner { max_batch: max_batch.max(1) }
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Partition `reqs` into batches of plan-compatible requests.
    pub fn plan(&self, reqs: Vec<InferenceRequest>) -> Vec<Vec<InferenceRequest>> {
        let mut order: Vec<(PlanKey, bool)> = Vec::new();
        let mut groups: HashMap<(PlanKey, bool), Vec<InferenceRequest>> = HashMap::new();
        for r in reqs {
            let key = (PlanKey::of(&r.run), r.run.functional);
            match groups.get_mut(&key) {
                Some(g) => g.push(r),
                None => {
                    order.push(key.clone());
                    groups.insert(key, vec![r]);
                }
            }
        }
        let mut out = Vec::new();
        for key in order {
            let group = groups.remove(&key).expect("group recorded in order");
            let mut group = group.into_iter();
            loop {
                let chunk: Vec<InferenceRequest> =
                    group.by_ref().take(self.max_batch).collect();
                if chunk.is_empty() {
                    break;
                }
                out.push(chunk);
            }
        }
        out
    }
}

/// Closed-loop serving harness: submit a burst, block in
/// [`Coordinator::drain`]. Kept as a thin compatibility wrapper over the
/// always-on [`ZipperService`] (same worker pool, same batched
/// execution core) for benches, examples, and tests that want the
/// simple submit/drain shape.
///
/// Semantics are unchanged from the pre-service coordinator: with the
/// default [`ServingConfig`] (`max_batch = 1`, `max_wait_us = 0`) every
/// submit dispatches immediately; with batching enabled a plan group is
/// dispatched when it reaches `max_batch`, and partially filled groups
/// flush at `drain` (the wrapper's `max_wait_us` default of 0 disables
/// the service's timer trigger, so partial groups wait exactly as they
/// used to).
///
/// # Examples
///
/// ```
/// use zipper::config::{ArchConfig, RunConfig};
/// use zipper::coordinator::{Coordinator, InferenceRequest};
///
/// let mut run = RunConfig::default();
/// run.dataset = "CR".into(); // tiny citation-graph stand-in
/// run.scale = 64;
/// run.feat_in = 8;
/// run.feat_out = 8;
///
/// let mut c = Coordinator::new(ArchConfig::default(), 2);
/// for id in 0..3 {
///     c.submit(InferenceRequest { id, run: run.clone(), input_seed: id });
/// }
/// let responses = c.drain();
/// assert_eq!(responses.len(), 3);
/// assert!(responses.iter().all(|r| r.error.is_none()));
/// // identical configs share one compiled plan
/// assert_eq!(c.cache_stats().entries, 1);
/// ```
pub struct Coordinator {
    service: Option<ZipperService>,
    /// One ticket per submitted request, in submit order.
    tickets: Vec<Ticket>,
    /// Responses synthesized locally (e.g. when the service is gone).
    local: Vec<InferenceResponse>,
    /// Set when the serving config failed validation at construction:
    /// every submit then fails with this message instead of panicking.
    init_error: Option<String>,
    /// Metrics snapshot taken at the last `drain`.
    last_metrics: Option<ServiceMetrics>,
    cache: Arc<PlanCache>,
}

impl Coordinator {
    pub fn new(arch: ArchConfig, num_workers: usize) -> Coordinator {
        Self::with_cache(arch, num_workers, Arc::new(PlanCache::new()))
    }

    /// Share an existing plan cache (warm restarts, cold/warm benches).
    pub fn with_cache(arch: ArchConfig, num_workers: usize, cache: Arc<PlanCache>) -> Coordinator {
        Self::with_serving(arch, num_workers, ServingConfig::default(), cache)
    }

    /// Full constructor: worker count plus the serving knobs
    /// (`exec_threads` for the tile-parallel functional pass,
    /// `max_batch` for the batch planner; the always-on knobs keep
    /// their defaults unless set). Never panics: an invalid serving
    /// config turns every subsequent submit into an error response.
    pub fn with_serving(
        arch: ArchConfig,
        num_workers: usize,
        serving: ServingConfig,
        cache: Arc<PlanCache>,
    ) -> Coordinator {
        let (service, init_error) =
            match ZipperService::new(arch, num_workers, serving, Arc::clone(&cache)) {
                Ok(s) => (Some(s), None),
                Err(e) => (None, Some(e)),
            };
        Coordinator {
            service,
            tickets: Vec::new(),
            local: Vec::new(),
            init_error,
            last_metrics: None,
            cache,
        }
    }

    pub fn cache(&self) -> &Arc<PlanCache> {
        &self.cache
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Service metrics captured by the last [`Coordinator::drain`]
    /// (latency percentiles, batch-size histogram, shed counters).
    pub fn last_metrics(&self) -> Option<&ServiceMetrics> {
        self.last_metrics.as_ref()
    }

    /// Enqueue a request. Never panics: if the worker pool is gone (all
    /// workers exited or already drained) the failure is reported as an
    /// error response from `drain`.
    ///
    /// Dispatch is eager: as soon as a plan group reaches `max_batch`
    /// pending requests it is handed to the worker pool, so serving
    /// overlaps with the caller still producing requests (with the
    /// default `max_batch = 1` every submit dispatches immediately).
    /// Partially filled groups ride along at the next [`Coordinator::drain`].
    pub fn submit(&mut self, req: InferenceRequest) {
        let Some(service) = &self.service else {
            let msg = match &self.init_error {
                Some(e) => format!("worker pool unavailable (invalid serving config: {e})"),
                None => "worker pool unavailable (already drained or all workers exited)".into(),
            };
            self.local.push(InferenceResponse::failed(
                req.id,
                &req.run.model,
                &req.run.dataset,
                msg,
            ));
            return;
        };
        self.tickets.push(service.submit(req));
    }

    /// Close the queue and collect all responses (submit order). Every
    /// submitted request yields exactly one response: requests lost to
    /// a worker failure come back as error responses instead of being
    /// silently dropped.
    pub fn drain(&mut self) -> Vec<InferenceResponse> {
        let mut out = std::mem::take(&mut self.local);
        if let Some(service) = self.service.take() {
            // long grace: the closed-loop contract is "wait for all"
            service.shutdown(Duration::from_secs(600));
            self.last_metrics = Some(service.metrics());
        }
        out.extend(std::mem::take(&mut self.tickets).into_iter().map(Ticket::wait));
        out
    }
}

pub(crate) fn panic_message(panic: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = panic.downcast_ref::<&str>() {
        s
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tiling::{Reorder, TilingConfig, TilingMode};

    fn small_run(model: &str, functional: bool) -> RunConfig {
        RunConfig {
            model: model.into(),
            dataset: "CR".into(),
            scale: 16,
            feat_in: 16,
            feat_out: 16,
            layers: 1,
            hidden: Vec::new(),
            tiling: TilingConfig {
                dst_part: 64,
                src_part: 64,
                mode: TilingMode::Sparse,
                reorder: Reorder::InDegree,
                threads: 1,
            },
            e2v: true,
            passes: Default::default(),
            functional,
            seed: 3,
            serving: Default::default(),
            kernels: Default::default(),
            shards: 1,
            overlap: false,
        }
    }

    #[test]
    fn session_prepare_and_simulate() {
        let run = small_run("gcn", true);
        let s = Session::prepare(&run).unwrap();
        let x = s.make_input(1);
        let res = s.simulate(&ArchConfig::default(), true, Some(&x), 0).unwrap();
        assert!(res.cycles > 0);
        assert!(res.output.unwrap().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn coordinator_serves_batch() {
        let mut c = Coordinator::new(ArchConfig::default(), 2);
        for (i, m) in ["gcn", "gat", "sage"].iter().enumerate() {
            c.submit(InferenceRequest {
                id: i as u64,
                run: small_run(m, false),
                input_seed: i as u64,
            });
        }
        let mut resp = c.drain();
        assert_eq!(resp.len(), 3);
        resp.sort_by_key(|r| r.id);
        for r in &resp {
            assert!(r.error.is_none(), "{:?}", r.error);
            assert!(r.sim_cycles > 0);
            assert!(r.energy_j > 0.0);
            assert_eq!(r.batch_size, 1);
            assert!(r.wall_seconds >= r.queue_seconds);
        }
    }

    #[test]
    fn session_cache_reused_across_requests() {
        // identical keys → one compiled plan → identical cycles, and the
        // repeats must be recorded as cache hits
        let mut c = Coordinator::new(ArchConfig::default(), 2);
        for i in 0..4 {
            c.submit(InferenceRequest { id: i, run: small_run("gcn", false), input_seed: i });
        }
        let resp = c.drain();
        let cycles: Vec<u64> = resp.iter().map(|r| r.sim_cycles).collect();
        assert!(cycles.windows(2).all(|w| w[0] == w[1]), "{cycles:?}");
        // with 2 workers the first two requests may race to compile, but
        // at least the trailing requests must be warm
        let hits = resp.iter().filter(|r| r.plan_cache_hit).count();
        assert!(hits >= 2, "expected ≥2 warm responses, got {hits}");
        assert_eq!(c.cache_stats().entries, 1);
    }

    #[test]
    fn bad_model_reports_error() {
        let mut c = Coordinator::new(ArchConfig::default(), 1);
        let mut run = small_run("gcn", false);
        run.model = "transformer".into();
        c.submit(InferenceRequest { id: 0, run, input_seed: 0 });
        let resp = c.drain();
        assert!(resp[0].error.as_deref().unwrap().contains("unknown model"));
    }

    #[test]
    fn inconsistent_layer_chain_fails_fast_at_submit() {
        let mut c = Coordinator::new(ArchConfig::default(), 1);
        let mut run = small_run("gcn", false);
        run.layers = 3;
        run.hidden = vec![8]; // needs 2 widths
        c.submit(InferenceRequest { id: 0, run, input_seed: 0 });
        let mut run = small_run("ggnn", false);
        run.layers = 2;
        run.hidden = vec![32]; // GGNN needs square layers
        c.submit(InferenceRequest { id: 1, run, input_seed: 0 });
        let mut resp = c.drain();
        resp.sort_by_key(|r| r.id);
        let gcn_err = resp[0].error.as_deref().unwrap();
        assert!(gcn_err.contains("3-layer") && gcn_err.contains("16"), "{gcn_err}");
        let ggnn_err = resp[1].error.as_deref().unwrap();
        assert!(ggnn_err.contains("square") && ggnn_err.contains("32"), "{ggnn_err}");
    }

    #[test]
    fn responses_carry_per_layer_breakdown() {
        let mut c = Coordinator::new(ArchConfig::default(), 1);
        let mut run = small_run("gcn", true);
        run.layers = 3;
        c.submit(InferenceRequest { id: 0, run, input_seed: 0 });
        let resp = c.drain();
        let r = &resp[0];
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.layers.len(), 3);
        assert_eq!(r.sim_cycles, r.layers.iter().map(|l| l.cycles).sum::<u64>());
        let layer_j: f64 = r.layers.iter().map(|l| l.energy_j).sum();
        assert!((layer_j - r.energy_j).abs() / r.energy_j < 0.2, "{layer_j} vs {}", r.energy_j);
        assert!(r.peak_uem_bytes > 0);
        // depth-1 responses still carry a one-entry breakdown
        let mut c = Coordinator::new(ArchConfig::default(), 1);
        c.submit(InferenceRequest { id: 0, run: small_run("gcn", false), input_seed: 0 });
        let resp = c.drain();
        assert_eq!(resp[0].layers.len(), 1);
        assert_eq!(resp[0].layers[0].cycles, resp[0].sim_cycles);
    }

    #[test]
    fn submit_after_drain_reports_error_instead_of_panicking() {
        let mut c = Coordinator::new(ArchConfig::default(), 1);
        c.submit(InferenceRequest { id: 0, run: small_run("gcn", false), input_seed: 0 });
        let first = c.drain();
        assert_eq!(first.len(), 1);
        c.submit(InferenceRequest { id: 1, run: small_run("gcn", false), input_seed: 1 });
        let second = c.drain();
        assert_eq!(second.len(), 1);
        assert!(second[0].error.as_deref().unwrap().contains("worker pool unavailable"));
    }

    #[test]
    fn invalid_serving_config_degrades_to_error_responses() {
        // zero queue_cap is rejected by check_serving; the wrapper keeps
        // the no-panic contract and reports it per request
        let serving = ServingConfig { queue_cap: 0, ..Default::default() };
        let mut c = Coordinator::with_serving(
            ArchConfig::default(),
            1,
            serving,
            Arc::new(PlanCache::new()),
        );
        c.submit(InferenceRequest { id: 7, run: small_run("gcn", false), input_seed: 0 });
        let resp = c.drain();
        assert_eq!(resp.len(), 1);
        let err = resp[0].error.as_deref().unwrap();
        assert!(err.contains("invalid serving config"), "{err}");
        assert!(err.contains("queue_cap"), "{err}");
    }

    #[test]
    fn batch_planner_groups_by_plan_and_caps_size() {
        let planner = BatchPlanner::new(3);
        let reqs: Vec<InferenceRequest> = (0..7)
            .map(|i| {
                let m = if i % 2 == 0 { "gcn" } else { "gat" };
                InferenceRequest { id: i, run: small_run(m, true), input_seed: i }
            })
            .collect();
        let batches = planner.plan(reqs);
        // 4 gcn → [3, 1]; 3 gat → [3]
        assert_eq!(batches.len(), 3);
        for b in &batches {
            assert!(!b.is_empty() && b.len() <= 3);
            assert!(b.iter().all(|r| r.run.model == b[0].run.model));
        }
        // request order preserved within each plan group
        let gcn_ids: Vec<u64> = batches
            .iter()
            .flatten()
            .filter(|r| r.run.model == "gcn")
            .map(|r| r.id)
            .collect();
        assert_eq!(gcn_ids, vec![0, 2, 4, 6]);
    }

    #[test]
    fn batch_planner_splits_mixed_functional_flags() {
        // same plan key, different functional flag → separate batches
        let planner = BatchPlanner::new(8);
        let reqs: Vec<InferenceRequest> = (0..4)
            .map(|i| InferenceRequest {
                id: i,
                run: small_run("gcn", i % 2 == 0),
                input_seed: i,
            })
            .collect();
        let batches = planner.plan(reqs);
        assert_eq!(batches.len(), 2);
        for b in &batches {
            assert!(b.iter().all(|r| r.run.functional == b[0].run.functional));
        }
    }

    #[test]
    fn batched_compile_error_fails_every_member() {
        let serving = ServingConfig { exec_threads: 2, max_batch: 4, ..Default::default() };
        let mut c = Coordinator::with_serving(
            ArchConfig::default(),
            1,
            serving,
            Arc::new(PlanCache::new()),
        );
        let mut bad = small_run("gcn", true);
        bad.model = "transformer".into();
        for i in 0..3 {
            c.submit(InferenceRequest { id: i, run: bad.clone(), input_seed: i });
        }
        let resp = c.drain();
        assert_eq!(resp.len(), 3);
        assert!(resp.iter().all(|r| r.error.is_some()));
    }

    #[test]
    fn batched_responses_report_batch_size_and_shared_timing() {
        let serving = ServingConfig { exec_threads: 2, max_batch: 8, ..Default::default() };
        let mut c = Coordinator::with_serving(
            ArchConfig::default(),
            1,
            serving,
            Arc::new(PlanCache::new()),
        );
        for i in 0..5 {
            c.submit(InferenceRequest { id: i, run: small_run("gat", true), input_seed: i });
        }
        let mut resp = c.drain();
        resp.sort_by_key(|r| r.id);
        assert_eq!(resp.len(), 5);
        let expect = Session::prepare(&small_run("gat", true))
            .unwrap()
            .simulate(&ArchConfig::default(), false, None, 0)
            .unwrap()
            .cycles;
        for r in &resp {
            assert!(r.error.is_none(), "{:?}", r.error);
            assert_eq!(r.batch_size, 5);
            assert_eq!(r.sim_cycles, expect, "batched timing must match the engine");
            assert!(r.output_checksum.is_some());
        }
        // different seeds → different embeddings → different checksums
        assert_ne!(resp[0].output_checksum, resp[1].output_checksum);
        // the compat wrapper surfaces the service metrics after drain
        let m = c.last_metrics().expect("metrics after drain");
        assert_eq!(m.submitted, 5);
        assert_eq!(m.completed, 5);
        assert_eq!(m.batch_size_hist[5], 1);
    }
}
