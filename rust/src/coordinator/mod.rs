//! L3 coordinator: the serving front-end over the ZIPPER stack.
//!
//! Responsibilities:
//!   * **Plans** — compile-once bundles (`plan::ExecPlan`): dataset →
//!     graph → tiling → compiled SDE program → weights, cached per
//!     structured `PlanKey` and shared across workers as `Arc`s.
//!   * **Serving** — a worker pool consuming *batches* of inference
//!     requests from a queue. [`BatchPlanner`] groups queued requests
//!     that share one execution plan; a worker serves a batch with a
//!     single input-independent timing simulation plus one tile-parallel
//!     batched functional pass (`sim::parallel`), amortizing plan
//!     lookup, LD.SRC/LD.DST tile traversal, and the cycle-level
//!     simulation across the batch while keeping per-request responses
//!     and latency accounting.
//!   * **Validation** — the three-layer glue: execute the same tiles
//!     through the PJRT-loaded JAX artifacts and compare against the
//!     simulator's functional output (paper §8.1: "validate ... the
//!     functionality of each operation and the tiling-based execution
//!     against DGL" — our DGL is the L2 JAX model).

pub mod validate;

use crate::compiler::Program;
use crate::config::{ArchConfig, RunConfig, ServingConfig};
use crate::energy::EnergyModel;
use crate::graph::Graph;
use crate::models::{ModelKind, ModelSpec, WeightStore};
use crate::plan::{CacheStats, ExecPlan, PlanCache, PlanKey};
use crate::sim::parallel::BatchScratch;
use crate::sim::{ExecScratch, SimResult};
use crate::tiling::Tiling;
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// A prepared inference session: a thin handle over a shared, immutable
/// [`ExecPlan`]. Cheap to clone; all per-run state lives in the caller's
/// scratch. Kept as the stable front-door API for benches and examples.
///
/// # Examples
///
/// Compile once, then simulate functionally and read the embeddings:
///
/// ```
/// use zipper::config::{ArchConfig, RunConfig};
/// use zipper::coordinator::Session;
///
/// let mut run = RunConfig::default();
/// run.dataset = "CR".into(); // tiny citation-graph stand-in
/// run.scale = 64;
/// run.feat_in = 8;
/// run.feat_out = 8;
/// run.functional = true;
///
/// let session = Session::prepare(&run).unwrap();
/// let x = session.make_input(1);
/// let res = session
///     .simulate(&ArchConfig::default(), true, Some(&x), 0)
///     .unwrap();
/// assert!(res.cycles > 0);
/// assert_eq!(
///     res.output.unwrap().len(),
///     session.plan().dims.output_len
/// );
/// ```
#[derive(Clone)]
pub struct Session {
    plan: Arc<ExecPlan>,
}

impl Session {
    /// Compile a session from a run config (dataset registry + compiler).
    pub fn prepare(run: &RunConfig) -> Result<Session, String> {
        Ok(Session { plan: Arc::new(ExecPlan::compile(run)?) })
    }

    /// Build a session around an explicit graph (tests, examples).
    pub fn from_graph(model: ModelKind, graph: Graph, run: &RunConfig) -> Result<Session, String> {
        Ok(Session { plan: Arc::new(ExecPlan::from_graph(model, graph, run)?) })
    }

    /// Wrap an already-compiled shared plan (plan-cache hit path).
    pub fn from_plan(plan: Arc<ExecPlan>) -> Session {
        Session { plan }
    }

    pub fn plan(&self) -> &Arc<ExecPlan> {
        &self.plan
    }

    pub fn model(&self) -> ModelKind {
        self.plan.model
    }

    /// Resolved layer chain (depth, per-layer widths, activations).
    pub fn spec(&self) -> &ModelSpec {
        &self.plan.spec
    }

    /// Pipeline depth (≥ 1).
    pub fn depth(&self) -> usize {
        self.plan.depth()
    }

    pub fn graph(&self) -> &Graph {
        &self.plan.graph
    }

    pub fn tiling(&self) -> &Tiling {
        &self.plan.tiling
    }

    /// The first layer stage's compiled program (the whole model for
    /// depth-1 sessions; see [`crate::plan::ExecPlan::stages`] for the
    /// full pipeline).
    pub fn program(&self) -> &Program {
        &self.plan.stages[0].program
    }

    /// The first layer stage's weights (see
    /// [`crate::plan::ExecPlan::stages`] for deeper layers).
    pub fn weights(&self) -> &WeightStore {
        &self.plan.stages[0].weights
    }

    /// First layer's input embedding width.
    pub fn feat_in(&self) -> u32 {
        self.plan.feat_in
    }

    /// Final layer's output embedding width.
    pub fn feat_out(&self) -> u32 {
        self.plan.feat_out
    }

    /// Deterministic input embeddings for this session's graph.
    pub fn make_input(&self, seed: u64) -> Vec<f32> {
        self.plan.make_input(seed)
    }

    /// Run the cycle-level simulation (optionally functional).
    pub fn simulate(
        &self,
        arch: &ArchConfig,
        functional: bool,
        x: Option<&[f32]>,
        trace_window: u64,
    ) -> Result<SimResult, String> {
        self.plan.simulate(arch, functional, x, trace_window)
    }

    /// Re-entrant variant reusing a caller-owned scratch (hot path).
    pub fn simulate_with(
        &self,
        arch: &ArchConfig,
        functional: bool,
        x: Option<&[f32]>,
        trace_window: u64,
        scratch: &mut ExecScratch,
    ) -> Result<SimResult, String> {
        self.plan.simulate_with(arch, functional, x, trace_window, scratch)
    }
}

/// One inference request handled by the serving loop.
#[derive(Clone, Debug)]
pub struct InferenceRequest {
    pub id: u64,
    pub run: RunConfig,
    /// Seed for the request's input embeddings.
    pub input_seed: u64,
}

/// One layer's slice of a response's cost (Fig 2-style depth
/// breakdown): cycles/DRAM/energy are additive across a pipeline's
/// layers, so `sum(layers[i].cycles) == sim_cycles`.
#[derive(Clone, Debug)]
pub struct LayerCost {
    pub feat_in: u32,
    pub feat_out: u32,
    pub cycles: u64,
    pub dram_read_bytes: u64,
    pub dram_write_bytes: u64,
    pub energy_j: f64,
}

/// The response: simulated device time + host-side serving latency.
#[derive(Clone, Debug)]
pub struct InferenceResponse {
    pub id: u64,
    pub model: String,
    pub dataset: String,
    /// Simulated accelerator latency (cycles / seconds @ arch clock),
    /// summed over the pipeline's layers.
    pub sim_cycles: u64,
    pub sim_seconds: f64,
    pub energy_j: f64,
    /// Per-layer cost breakdown (one entry per layer, depth-1 included).
    pub layers: Vec<LayerCost>,
    /// Peak UEM residency across the whole pipeline, inter-layer
    /// activation images included (Fig 2's footprint story).
    pub peak_uem_bytes: u64,
    /// Wall-clock serving latency (queue + prepare + simulate).
    pub wall_seconds: f64,
    /// Whether the execution plan came from the cache (warm request).
    pub plan_cache_hit: bool,
    /// Host seconds spent compiling the plan (0 on a warm request).
    pub prepare_seconds: f64,
    /// How many requests shared this request's batched pass (≥ 1).
    pub batch_size: usize,
    /// Checksum of the output embeddings (functional runs).
    pub output_checksum: Option<f64>,
    pub error: Option<String>,
}

impl InferenceResponse {
    fn empty(id: u64, model: &str, dataset: &str) -> InferenceResponse {
        InferenceResponse {
            id,
            model: model.to_string(),
            dataset: dataset.to_string(),
            sim_cycles: 0,
            sim_seconds: 0.0,
            energy_j: 0.0,
            layers: Vec::new(),
            peak_uem_bytes: 0,
            wall_seconds: 0.0,
            plan_cache_hit: false,
            prepare_seconds: 0.0,
            batch_size: 1,
            output_checksum: None,
            error: None,
        }
    }

    fn failed(id: u64, model: &str, dataset: &str, error: String) -> InferenceResponse {
        InferenceResponse { error: Some(error), ..Self::empty(id, model, dataset) }
    }
}

/// Groups queued requests into executable batches: requests sharing one
/// execution plan (same [`PlanKey`]) *and* the same functional flag may
/// ride one batched pass, capped at `max_batch` per batch. Grouping
/// preserves first-arrival order of groups and request order within a
/// group, so serving stays deterministic.
pub struct BatchPlanner {
    max_batch: usize,
}

impl BatchPlanner {
    /// `max_batch` is clamped to ≥ 1 (1 = no batching, the default).
    pub fn new(max_batch: usize) -> BatchPlanner {
        BatchPlanner { max_batch: max_batch.max(1) }
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Partition `reqs` into batches of plan-compatible requests.
    pub fn plan(&self, reqs: Vec<InferenceRequest>) -> Vec<Vec<InferenceRequest>> {
        let mut order: Vec<(PlanKey, bool)> = Vec::new();
        let mut groups: HashMap<(PlanKey, bool), Vec<InferenceRequest>> = HashMap::new();
        for r in reqs {
            let key = (PlanKey::of(&r.run), r.run.functional);
            match groups.get_mut(&key) {
                Some(g) => g.push(r),
                None => {
                    order.push(key.clone());
                    groups.insert(key, vec![r]);
                }
            }
        }
        let mut out = Vec::new();
        for key in order {
            let group = groups.remove(&key).expect("group recorded in order");
            let mut group = group.into_iter();
            loop {
                let chunk: Vec<InferenceRequest> =
                    group.by_ref().take(self.max_batch).collect();
                if chunk.is_empty() {
                    break;
                }
                out.push(chunk);
            }
        }
        out
    }
}

/// Multi-threaded serving coordinator over a shared [`PlanCache`].
///
/// Requests are grouped into plan-compatible batches: a group is
/// dispatched to the worker pool as soon as it reaches `max_batch`
/// pending requests (immediately, with the default `max_batch = 1`),
/// and partially filled groups are flushed through the [`BatchPlanner`]
/// at [`Coordinator::drain`]. Workers execute batch-at-a-time: one
/// timing simulation plus one tile-parallel batched functional pass per
/// batch (see the module docs). With the default [`ServingConfig`]
/// (`max_batch = 1`, `exec_threads = 1`) behavior degenerates to
/// classic one-request-per-worker serving.
///
/// # Examples
///
/// ```
/// use zipper::config::{ArchConfig, RunConfig};
/// use zipper::coordinator::{Coordinator, InferenceRequest};
///
/// let mut run = RunConfig::default();
/// run.dataset = "CR".into(); // tiny citation-graph stand-in
/// run.scale = 64;
/// run.feat_in = 8;
/// run.feat_out = 8;
///
/// let mut c = Coordinator::new(ArchConfig::default(), 2);
/// for id in 0..3 {
///     c.submit(InferenceRequest { id, run: run.clone(), input_seed: id });
/// }
/// let responses = c.drain();
/// assert_eq!(responses.len(), 3);
/// assert!(responses.iter().all(|r| r.error.is_none()));
/// // identical configs share one compiled plan
/// assert_eq!(c.cache_stats().entries, 1);
/// ```
pub struct Coordinator {
    tx: Option<mpsc::Sender<Vec<InferenceRequest>>>,
    rx_resp: mpsc::Receiver<InferenceResponse>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// (id, model, dataset) per submitted request, so drain can report
    /// losses instead of silently truncating.
    submitted: Vec<(u64, String, String)>,
    /// Requests buffered until their plan group fills or the queue is
    /// flushed at drain.
    pending: Vec<InferenceRequest>,
    /// Pending-request count per batch key, for eager dispatch.
    pending_counts: HashMap<(PlanKey, bool), usize>,
    /// Responses synthesized locally (e.g. when the queue is gone).
    local: Vec<InferenceResponse>,
    planner: BatchPlanner,
    cache: Arc<PlanCache>,
}

/// Per-worker pooled state: the timing-simulation scratch plus the
/// batched functional executor's scratch, both reused for every batch
/// this worker serves.
struct WorkerState {
    timing: ExecScratch,
    batch: BatchScratch,
}

impl Coordinator {
    pub fn new(arch: ArchConfig, num_workers: usize) -> Coordinator {
        Self::with_cache(arch, num_workers, Arc::new(PlanCache::new()))
    }

    /// Share an existing plan cache (warm restarts, cold/warm benches).
    pub fn with_cache(arch: ArchConfig, num_workers: usize, cache: Arc<PlanCache>) -> Coordinator {
        Self::with_serving(arch, num_workers, ServingConfig::default(), cache)
    }

    /// Full constructor: worker count plus the serving knobs
    /// (`exec_threads` for the tile-parallel functional pass,
    /// `max_batch` for the batch planner).
    pub fn with_serving(
        arch: ArchConfig,
        num_workers: usize,
        serving: ServingConfig,
        cache: Arc<PlanCache>,
    ) -> Coordinator {
        let (tx, rx) = mpsc::channel::<Vec<InferenceRequest>>();
        let (tx_resp, rx_resp) = mpsc::channel::<InferenceResponse>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::new();
        for _ in 0..num_workers.max(1) {
            let rx = Arc::clone(&rx);
            let tx_resp = tx_resp.clone();
            let cache = Arc::clone(&cache);
            workers.push(std::thread::spawn(move || {
                // per-worker pooled scratches: reused across every batch
                // this worker serves (the allocation-light hot path)
                let mut state =
                    WorkerState { timing: ExecScratch::new(), batch: BatchScratch::new() };
                'serve: loop {
                    let batch = {
                        let guard = match rx.lock() {
                            Ok(g) => g,
                            // a peer panicked while holding the queue
                            // lock; the queue itself is still sound
                            Err(poisoned) => poisoned.into_inner(),
                        };
                        guard.recv()
                    };
                    let Ok(batch) = batch else { break };
                    let t0 = Instant::now();
                    let responses = catch_unwind(AssertUnwindSafe(|| {
                        handle_batch(&arch, &cache, serving, &batch, t0, &mut state)
                    }))
                    .unwrap_or_else(|panic| {
                        let msg = format!(
                            "worker panicked: {}",
                            panic_message(panic.as_ref())
                        );
                        batch
                            .iter()
                            .map(|r| {
                                InferenceResponse::failed(
                                    r.id,
                                    &r.run.model,
                                    &r.run.dataset,
                                    msg.clone(),
                                )
                            })
                            .collect::<Vec<_>>()
                    });
                    for resp in responses {
                        if tx_resp.send(resp).is_err() {
                            break 'serve;
                        }
                    }
                }
            }));
        }
        Coordinator {
            tx: Some(tx),
            rx_resp,
            workers,
            submitted: Vec::new(),
            pending: Vec::new(),
            pending_counts: HashMap::new(),
            local: Vec::new(),
            planner: BatchPlanner::new(serving.max_batch as usize),
            cache,
        }
    }

    pub fn cache(&self) -> &Arc<PlanCache> {
        &self.cache
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Enqueue a request. Never panics: if the worker pool is gone (all
    /// workers exited or already drained) the failure is reported as an
    /// error response from `drain`.
    ///
    /// Dispatch is eager: as soon as a plan group reaches `max_batch`
    /// pending requests it is handed to the worker pool, so serving
    /// overlaps with the caller still producing requests (with the
    /// default `max_batch = 1` every submit dispatches immediately).
    /// Partially filled groups ride along at the next [`Coordinator::drain`].
    pub fn submit(&mut self, req: InferenceRequest) {
        self.submitted.push((req.id, req.run.model.clone(), req.run.dataset.clone()));
        // structured front-door validation: inconsistent layer chains
        // (wrong hidden-width count, non-square GGNN widths) fail here
        // with shape-carrying errors instead of deep in a worker compile
        if let Err(e) = validate::check_layer_chain(&req.run) {
            self.local.push(InferenceResponse::failed(
                req.id,
                &req.run.model,
                &req.run.dataset,
                e,
            ));
            return;
        }
        if self.tx.is_none() {
            self.local.push(InferenceResponse::failed(
                req.id,
                &req.run.model,
                &req.run.dataset,
                "worker pool unavailable (already drained or all workers exited)".into(),
            ));
            return;
        }
        let key = (PlanKey::of(&req.run), req.run.functional);
        let count = self.pending_counts.entry(key.clone()).or_insert(0);
        *count += 1;
        let group_full = *count >= self.planner.max_batch();
        self.pending.push(req);
        if group_full {
            self.pending_counts.remove(&key);
            let mut batch = Vec::with_capacity(self.planner.max_batch());
            let mut rest = Vec::with_capacity(self.pending.len());
            for r in std::mem::take(&mut self.pending) {
                if (PlanKey::of(&r.run), r.run.functional) == key {
                    batch.push(r);
                } else {
                    rest.push(r);
                }
            }
            self.pending = rest;
            self.dispatch(batch);
        }
    }

    /// Send one batch to the worker pool, degrading to local error
    /// responses if every worker is gone.
    fn dispatch(&mut self, batch: Vec<InferenceRequest>) {
        let sent = match &self.tx {
            Some(tx) => tx.send(batch).map_err(|e| e.0),
            None => Err(batch),
        };
        if let Err(batch) = sent {
            for req in batch {
                self.local.push(InferenceResponse::failed(
                    req.id,
                    &req.run.model,
                    &req.run.dataset,
                    "worker pool unavailable (already drained or all workers exited)".into(),
                ));
            }
        }
    }

    /// Group the remaining (partially filled) buffered requests into
    /// batches and hand them to the worker pool.
    fn flush(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        self.pending_counts.clear();
        let pending = std::mem::take(&mut self.pending);
        for batch in self.planner.plan(pending) {
            self.dispatch(batch);
        }
    }

    /// Close the queue and collect all responses (arrival order). Every
    /// submitted request yields exactly one response: requests lost to a
    /// worker failure come back as error responses instead of being
    /// silently dropped.
    pub fn drain(&mut self) -> Vec<InferenceResponse> {
        self.flush();
        drop(self.tx.take());
        let expected = self.submitted.len();
        let mut out = std::mem::take(&mut self.local);
        while out.len() < expected {
            match self.rx_resp.recv() {
                Ok(r) => out.push(r),
                Err(_) => break, // all workers gone; report losses below
            }
        }
        let mut panics = Vec::new();
        for w in self.workers.drain(..) {
            if let Err(p) = w.join() {
                panics.push(panic_message(p.as_ref()).to_string());
            }
        }
        if out.len() < expected {
            let detail = if panics.is_empty() {
                "worker exited early".to_string()
            } else {
                format!("worker panicked: {}", panics.join("; "))
            };
            // per-id multiset accounting: ids are caller-chosen and may
            // repeat, so count received responses per id instead of
            // testing mere presence
            let mut received: HashMap<u64, usize> = HashMap::new();
            for r in &out {
                *received.entry(r.id).or_insert(0) += 1;
            }
            let submitted = std::mem::take(&mut self.submitted);
            for (id, model, dataset) in submitted {
                match received.get_mut(&id) {
                    Some(n) if *n > 0 => *n -= 1,
                    _ => out.push(InferenceResponse::failed(id, &model, &dataset, detail.clone())),
                }
            }
        } else {
            self.submitted.clear();
        }
        out
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn panic_message(panic: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = panic.downcast_ref::<&str>() {
        s
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

/// Fail every member of a batch with the same error.
fn fail_batch(batch: &[InferenceRequest], error: &str, t0: Instant) -> Vec<InferenceResponse> {
    batch
        .iter()
        .map(|r| InferenceResponse {
            wall_seconds: t0.elapsed().as_secs_f64(),
            ..InferenceResponse::failed(r.id, &r.run.model, &r.run.dataset, error.to_string())
        })
        .collect()
}

/// Serve one plan-compatible batch: a single plan lookup, a single
/// input-independent timing simulation, and (for functional requests)
/// one tile-parallel batched functional pass covering every lane. The
/// per-request accounting (wall clock, cache hit, prepare time, output
/// checksum) is preserved in each response.
fn handle_batch(
    arch: &ArchConfig,
    cache: &PlanCache,
    serving: ServingConfig,
    batch: &[InferenceRequest],
    t0: Instant,
    state: &mut WorkerState,
) -> Vec<InferenceResponse> {
    let first = &batch[0];
    let (plan, hit) = match cache.get_or_compile(&first.run) {
        Ok(p) => p,
        Err(e) => return fail_batch(batch, &e, t0),
    };
    let prepare_seconds = if hit { 0.0 } else { t0.elapsed().as_secs_f64() };

    // Timing is a pure function of (arch, plan) — input embeddings never
    // reach the cycle-level model — so one simulation covers the batch
    // (all layers of the pipeline, summed).
    let timing = match plan.simulate_with(arch, false, None, 0, &mut state.timing) {
        Ok(t) => t,
        Err(e) => return fail_batch(batch, &e, t0),
    };
    let energy = EnergyModel::default();
    let energy_j = energy.evaluate(&timing.counters, arch.freq_hz).total_j();
    let layer_costs: Vec<LayerCost> = timing
        .layers
        .iter()
        .map(|lm| LayerCost {
            feat_in: lm.feat_in,
            feat_out: lm.feat_out,
            cycles: lm.cycles,
            dram_read_bytes: lm.dram_read_bytes,
            dram_write_bytes: lm.dram_write_bytes,
            energy_j: energy.evaluate(&lm.counters, arch.freq_hz).total_j(),
        })
        .collect();

    // Functional lanes: one scratch-resident batched pass for all
    // requests, tiles sharded across `serving.exec_threads`.
    let mut checksums: Vec<Option<f64>> = vec![None; batch.len()];
    if first.run.functional {
        let inputs: Vec<Vec<f32>> =
            batch.iter().map(|r| plan.make_input(r.input_seed)).collect();
        let lanes: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let outs = match plan.execute_batch_with(
            &lanes,
            serving.exec_threads.max(1) as usize,
            &mut state.batch,
        ) {
            Ok(o) => o,
            Err(e) => return fail_batch(batch, &e, t0),
        };
        for (slot, out) in checksums.iter_mut().zip(&outs) {
            *slot = Some(out.iter().map(|&v| v as f64).sum::<f64>());
        }
    }

    batch
        .iter()
        .zip(checksums)
        .map(|(req, output_checksum)| InferenceResponse {
            sim_cycles: timing.cycles,
            sim_seconds: timing.seconds(arch),
            energy_j,
            layers: layer_costs.clone(),
            peak_uem_bytes: timing.peak_uem_bytes,
            wall_seconds: t0.elapsed().as_secs_f64(),
            plan_cache_hit: hit,
            prepare_seconds,
            batch_size: batch.len(),
            output_checksum,
            ..InferenceResponse::empty(req.id, &req.run.model, &req.run.dataset)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tiling::{Reorder, TilingConfig, TilingMode};

    fn small_run(model: &str, functional: bool) -> RunConfig {
        RunConfig {
            model: model.into(),
            dataset: "CR".into(),
            scale: 16,
            feat_in: 16,
            feat_out: 16,
            layers: 1,
            hidden: Vec::new(),
            tiling: TilingConfig {
                dst_part: 64,
                src_part: 64,
                mode: TilingMode::Sparse,
                reorder: Reorder::InDegree,
                threads: 1,
            },
            e2v: true,
            functional,
            seed: 3,
            serving: Default::default(),
            kernels: Default::default(),
        }
    }

    #[test]
    fn session_prepare_and_simulate() {
        let run = small_run("gcn", true);
        let s = Session::prepare(&run).unwrap();
        let x = s.make_input(1);
        let res = s.simulate(&ArchConfig::default(), true, Some(&x), 0).unwrap();
        assert!(res.cycles > 0);
        assert!(res.output.unwrap().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn coordinator_serves_batch() {
        let mut c = Coordinator::new(ArchConfig::default(), 2);
        for (i, m) in ["gcn", "gat", "sage"].iter().enumerate() {
            c.submit(InferenceRequest {
                id: i as u64,
                run: small_run(m, false),
                input_seed: i as u64,
            });
        }
        let mut resp = c.drain();
        assert_eq!(resp.len(), 3);
        resp.sort_by_key(|r| r.id);
        for r in &resp {
            assert!(r.error.is_none(), "{:?}", r.error);
            assert!(r.sim_cycles > 0);
            assert!(r.energy_j > 0.0);
            assert_eq!(r.batch_size, 1);
        }
    }

    #[test]
    fn session_cache_reused_across_requests() {
        // identical keys → one compiled plan → identical cycles, and the
        // repeats must be recorded as cache hits
        let mut c = Coordinator::new(ArchConfig::default(), 2);
        for i in 0..4 {
            c.submit(InferenceRequest { id: i, run: small_run("gcn", false), input_seed: i });
        }
        let resp = c.drain();
        let cycles: Vec<u64> = resp.iter().map(|r| r.sim_cycles).collect();
        assert!(cycles.windows(2).all(|w| w[0] == w[1]), "{cycles:?}");
        // with 2 workers the first two requests may race to compile, but
        // at least the trailing requests must be warm
        let hits = resp.iter().filter(|r| r.plan_cache_hit).count();
        assert!(hits >= 2, "expected ≥2 warm responses, got {hits}");
        assert_eq!(c.cache_stats().entries, 1);
    }

    #[test]
    fn bad_model_reports_error() {
        let mut c = Coordinator::new(ArchConfig::default(), 1);
        let mut run = small_run("gcn", false);
        run.model = "transformer".into();
        c.submit(InferenceRequest { id: 0, run, input_seed: 0 });
        let resp = c.drain();
        assert!(resp[0].error.as_deref().unwrap().contains("unknown model"));
    }

    #[test]
    fn inconsistent_layer_chain_fails_fast_at_submit() {
        let mut c = Coordinator::new(ArchConfig::default(), 1);
        let mut run = small_run("gcn", false);
        run.layers = 3;
        run.hidden = vec![8]; // needs 2 widths
        c.submit(InferenceRequest { id: 0, run, input_seed: 0 });
        let mut run = small_run("ggnn", false);
        run.layers = 2;
        run.hidden = vec![32]; // GGNN needs square layers
        c.submit(InferenceRequest { id: 1, run, input_seed: 0 });
        let mut resp = c.drain();
        resp.sort_by_key(|r| r.id);
        let gcn_err = resp[0].error.as_deref().unwrap();
        assert!(gcn_err.contains("3-layer") && gcn_err.contains("16"), "{gcn_err}");
        let ggnn_err = resp[1].error.as_deref().unwrap();
        assert!(ggnn_err.contains("square") && ggnn_err.contains("32"), "{ggnn_err}");
    }

    #[test]
    fn responses_carry_per_layer_breakdown() {
        let mut c = Coordinator::new(ArchConfig::default(), 1);
        let mut run = small_run("gcn", true);
        run.layers = 3;
        c.submit(InferenceRequest { id: 0, run, input_seed: 0 });
        let resp = c.drain();
        let r = &resp[0];
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.layers.len(), 3);
        assert_eq!(r.sim_cycles, r.layers.iter().map(|l| l.cycles).sum::<u64>());
        let layer_j: f64 = r.layers.iter().map(|l| l.energy_j).sum();
        assert!((layer_j - r.energy_j).abs() / r.energy_j < 0.2, "{layer_j} vs {}", r.energy_j);
        assert!(r.peak_uem_bytes > 0);
        // depth-1 responses still carry a one-entry breakdown
        let mut c = Coordinator::new(ArchConfig::default(), 1);
        c.submit(InferenceRequest { id: 0, run: small_run("gcn", false), input_seed: 0 });
        let resp = c.drain();
        assert_eq!(resp[0].layers.len(), 1);
        assert_eq!(resp[0].layers[0].cycles, resp[0].sim_cycles);
    }

    #[test]
    fn submit_after_drain_reports_error_instead_of_panicking() {
        let mut c = Coordinator::new(ArchConfig::default(), 1);
        c.submit(InferenceRequest { id: 0, run: small_run("gcn", false), input_seed: 0 });
        let first = c.drain();
        assert_eq!(first.len(), 1);
        c.submit(InferenceRequest { id: 1, run: small_run("gcn", false), input_seed: 1 });
        let second = c.drain();
        assert_eq!(second.len(), 1);
        assert!(second[0].error.as_deref().unwrap().contains("worker pool unavailable"));
    }

    #[test]
    fn batch_planner_groups_by_plan_and_caps_size() {
        let planner = BatchPlanner::new(3);
        let reqs: Vec<InferenceRequest> = (0..7)
            .map(|i| {
                let m = if i % 2 == 0 { "gcn" } else { "gat" };
                InferenceRequest { id: i, run: small_run(m, true), input_seed: i }
            })
            .collect();
        let batches = planner.plan(reqs);
        // 4 gcn → [3, 1]; 3 gat → [3]
        assert_eq!(batches.len(), 3);
        for b in &batches {
            assert!(!b.is_empty() && b.len() <= 3);
            assert!(b.iter().all(|r| r.run.model == b[0].run.model));
        }
        // request order preserved within each plan group
        let gcn_ids: Vec<u64> = batches
            .iter()
            .flatten()
            .filter(|r| r.run.model == "gcn")
            .map(|r| r.id)
            .collect();
        assert_eq!(gcn_ids, vec![0, 2, 4, 6]);
    }

    #[test]
    fn batch_planner_splits_mixed_functional_flags() {
        // same plan key, different functional flag → separate batches
        let planner = BatchPlanner::new(8);
        let reqs: Vec<InferenceRequest> = (0..4)
            .map(|i| InferenceRequest {
                id: i,
                run: small_run("gcn", i % 2 == 0),
                input_seed: i,
            })
            .collect();
        let batches = planner.plan(reqs);
        assert_eq!(batches.len(), 2);
        for b in &batches {
            assert!(b.iter().all(|r| r.run.functional == b[0].run.functional));
        }
    }

    #[test]
    fn batched_compile_error_fails_every_member() {
        let serving = ServingConfig { exec_threads: 2, max_batch: 4 };
        let mut c = Coordinator::with_serving(
            ArchConfig::default(),
            1,
            serving,
            Arc::new(PlanCache::new()),
        );
        let mut bad = small_run("gcn", true);
        bad.model = "transformer".into();
        for i in 0..3 {
            c.submit(InferenceRequest { id: i, run: bad.clone(), input_seed: i });
        }
        let resp = c.drain();
        assert_eq!(resp.len(), 3);
        assert!(resp.iter().all(|r| r.error.is_some()));
    }

    #[test]
    fn batched_responses_report_batch_size_and_shared_timing() {
        let serving = ServingConfig { exec_threads: 2, max_batch: 8 };
        let mut c = Coordinator::with_serving(
            ArchConfig::default(),
            1,
            serving,
            Arc::new(PlanCache::new()),
        );
        for i in 0..5 {
            c.submit(InferenceRequest { id: i, run: small_run("gat", true), input_seed: i });
        }
        let mut resp = c.drain();
        resp.sort_by_key(|r| r.id);
        assert_eq!(resp.len(), 5);
        let expect = Session::prepare(&small_run("gat", true))
            .unwrap()
            .simulate(&ArchConfig::default(), false, None, 0)
            .unwrap()
            .cycles;
        for r in &resp {
            assert!(r.error.is_none(), "{:?}", r.error);
            assert_eq!(r.batch_size, 5);
            assert_eq!(r.sim_cycles, expect, "batched timing must match the engine");
            assert!(r.output_checksum.is_some());
        }
        // different seeds → different embeddings → different checksums
        assert_ne!(resp[0].output_checksum, resp[1].output_checksum);
    }
}
