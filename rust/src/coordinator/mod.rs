//! L3 coordinator: the serving front-end over the ZIPPER stack.
//!
//! Responsibilities:
//!   * **Sessions** — prepare-once bundles: dataset → graph → tiling →
//!     compiled SDE program → weights, cached per request key.
//!   * **Serving** — a worker pool consuming inference requests from a
//!     queue; each request runs the cycle-level simulator (timing +
//!     energy) and optionally functional execution.
//!   * **Validation** — the three-layer glue: execute the same tiles
//!     through the PJRT-loaded JAX artifacts and compare against the
//!     simulator's functional output (paper §8.1: "validate ... the
//!     functionality of each operation and the tiling-based execution
//!     against DGL" — our DGL is the L2 JAX model).

pub mod validate;

use crate::compiler::{compile, OptLevel, Program};
use crate::config::{ArchConfig, RunConfig};
use crate::energy::{EnergyCounters, EnergyModel};
use crate::graph::{datasets, Graph};
use crate::models::{ModelKind, WeightStore, NUM_RELATIONS};
use crate::sim::{SimOptions, SimResult, Simulator, Workload};
use crate::tiling::{tile, Tiling};
use crate::util::Rng;
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// A prepared inference session: everything reusable across requests.
pub struct Session {
    pub model: ModelKind,
    pub graph: Graph,
    pub tiling: Tiling,
    pub program: Program,
    pub weights: WeightStore,
    pub feat_in: u32,
    pub feat_out: u32,
}

impl Session {
    /// Build a session from a run config (dataset registry + compiler).
    pub fn prepare(run: &RunConfig) -> Result<Session, String> {
        let model = ModelKind::parse(&run.model)
            .ok_or_else(|| format!("unknown model {}", run.model))?;
        let spec = datasets::by_id(&run.dataset)
            .ok_or_else(|| format!("unknown dataset {}", run.dataset))?;
        let etypes = if model.uses_etypes() { NUM_RELATIONS } else { 0 };
        let graph = spec.instantiate_typed(run.scale, etypes, run.seed);
        Self::from_graph(model, graph, run)
    }

    /// Build a session around an explicit graph (tests, examples).
    pub fn from_graph(
        model: ModelKind,
        graph: Graph,
        run: &RunConfig,
    ) -> Result<Session, String> {
        let feat_out = if model.requires_square() { run.feat_in } else { run.feat_out };
        let tiling = tile(&graph, run.tiling);
        let opt = if run.e2v { OptLevel::E2v } else { OptLevel::None };
        let program = compile(&model.build(), opt).map_err(|e| e.to_string())?;
        let weights = WeightStore::synthesize(&model.build(), run.feat_in, feat_out, run.seed);
        Ok(Session { model, graph, tiling, program, weights, feat_in: run.feat_in, feat_out })
    }

    /// Deterministic input embeddings for this session's graph.
    pub fn make_input(&self, seed: u64) -> Vec<f32> {
        let n = self.graph.num_vertices() as usize * self.feat_in as usize;
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.next_f32_sym() * 0.5).collect()
    }

    /// Run the cycle-level simulation (optionally functional).
    pub fn simulate(
        &self,
        arch: &ArchConfig,
        functional: bool,
        x: Option<&[f32]>,
        trace_window: u64,
    ) -> Result<SimResult, String> {
        let wl = Workload {
            program: &self.program,
            tiling: &self.tiling,
            weights: &self.weights,
            feat_in: self.feat_in,
            feat_out: self.feat_out,
            x,
        };
        Simulator::new(arch, &wl, SimOptions { functional, trace_window }).run()
    }
}

/// One inference request handled by the serving loop.
#[derive(Clone, Debug)]
pub struct InferenceRequest {
    pub id: u64,
    pub run: RunConfig,
    /// Seed for the request's input embeddings.
    pub input_seed: u64,
}

/// The response: simulated device time + host-side serving latency.
#[derive(Clone, Debug)]
pub struct InferenceResponse {
    pub id: u64,
    pub model: String,
    pub dataset: String,
    /// Simulated accelerator latency (cycles / seconds @ arch clock).
    pub sim_cycles: u64,
    pub sim_seconds: f64,
    pub energy_j: f64,
    /// Wall-clock serving latency (queue + prepare + simulate).
    pub wall_seconds: f64,
    /// Checksum of the output embeddings (functional runs).
    pub output_checksum: Option<f64>,
    pub error: Option<String>,
}

/// Session cache key.
fn session_key(run: &RunConfig) -> String {
    format!(
        "{}|{}|{}|{}x{}|{:?}|{}",
        run.model, run.dataset, run.scale, run.feat_in, run.feat_out, run.tiling, run.e2v
    )
}

/// Multi-threaded serving coordinator.
pub struct Coordinator {
    tx: Option<mpsc::Sender<InferenceRequest>>,
    rx_resp: mpsc::Receiver<InferenceResponse>,
    workers: Vec<std::thread::JoinHandle<()>>,
    submitted: u64,
}

impl Coordinator {
    pub fn new(arch: ArchConfig, num_workers: usize) -> Coordinator {
        let (tx, rx) = mpsc::channel::<InferenceRequest>();
        let (tx_resp, rx_resp) = mpsc::channel::<InferenceResponse>();
        let rx = Arc::new(Mutex::new(rx));
        let sessions: Arc<Mutex<HashMap<String, Arc<Session>>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let mut workers = Vec::new();
        for _ in 0..num_workers.max(1) {
            let rx = Arc::clone(&rx);
            let tx_resp = tx_resp.clone();
            let sessions = Arc::clone(&sessions);
            workers.push(std::thread::spawn(move || loop {
                let req = {
                    let guard = rx.lock().expect("queue lock");
                    guard.recv()
                };
                let Ok(req) = req else { break };
                let t0 = Instant::now();
                let resp = handle(&arch, &sessions, &req, t0);
                if tx_resp.send(resp).is_err() {
                    break;
                }
            }));
        }
        Coordinator { tx: Some(tx), rx_resp, workers, submitted: 0 }
    }

    pub fn submit(&mut self, req: InferenceRequest) {
        self.submitted += 1;
        self.tx
            .as_ref()
            .expect("coordinator already drained")
            .send(req)
            .expect("worker pool alive");
    }

    /// Close the queue and collect all responses (arrival order).
    pub fn drain(mut self) -> Vec<InferenceResponse> {
        drop(self.tx.take());
        let mut out = Vec::with_capacity(self.submitted as usize);
        for _ in 0..self.submitted {
            match self.rx_resp.recv() {
                Ok(r) => out.push(r),
                Err(_) => break,
            }
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        out
    }
}

fn handle(
    arch: &ArchConfig,
    sessions: &Mutex<HashMap<String, Arc<Session>>>,
    req: &InferenceRequest,
    t0: Instant,
) -> InferenceResponse {
    let key = session_key(&req.run);
    let session = {
        let mut cache = sessions.lock().expect("session lock");
        match cache.get(&key) {
            Some(s) => Ok(Arc::clone(s)),
            None => match Session::prepare(&req.run) {
                Ok(s) => {
                    let s = Arc::new(s);
                    cache.insert(key.clone(), Arc::clone(&s));
                    Ok(s)
                }
                Err(e) => Err(e),
            },
        }
    };
    let base = InferenceResponse {
        id: req.id,
        model: req.run.model.clone(),
        dataset: req.run.dataset.clone(),
        sim_cycles: 0,
        sim_seconds: 0.0,
        energy_j: 0.0,
        wall_seconds: 0.0,
        output_checksum: None,
        error: None,
    };
    let session = match session {
        Ok(s) => s,
        Err(e) => {
            return InferenceResponse {
                error: Some(e),
                wall_seconds: t0.elapsed().as_secs_f64(),
                ..base
            }
        }
    };
    let x;
    let input = if req.run.functional {
        x = session.make_input(req.input_seed);
        Some(x)
    } else {
        None
    };
    match session.simulate(arch, req.run.functional, input.as_deref(), 0) {
        Ok(res) => {
            let energy = EnergyModel::default()
                .evaluate(&counters_of(&res), arch.freq_hz)
                .total_j();
            InferenceResponse {
                sim_cycles: res.cycles,
                sim_seconds: res.seconds(arch),
                energy_j: energy,
                wall_seconds: t0.elapsed().as_secs_f64(),
                output_checksum: res.output.map(|o| o.iter().map(|&v| v as f64).sum::<f64>()),
                ..base
            }
        }
        Err(e) => InferenceResponse {
            error: Some(e),
            wall_seconds: t0.elapsed().as_secs_f64(),
            ..base
        },
    }
}

fn counters_of(res: &SimResult) -> EnergyCounters {
    res.counters
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tiling::{Reorder, TilingConfig, TilingMode};

    fn small_run(model: &str, functional: bool) -> RunConfig {
        RunConfig {
            model: model.into(),
            dataset: "CR".into(),
            scale: 16,
            feat_in: 16,
            feat_out: 16,
            tiling: TilingConfig {
                dst_part: 64,
                src_part: 64,
                mode: TilingMode::Sparse,
                reorder: Reorder::InDegree,
            },
            e2v: true,
            functional,
            seed: 3,
        }
    }

    #[test]
    fn session_prepare_and_simulate() {
        let run = small_run("gcn", true);
        let s = Session::prepare(&run).unwrap();
        let x = s.make_input(1);
        let res = s.simulate(&ArchConfig::default(), true, Some(&x), 0).unwrap();
        assert!(res.cycles > 0);
        assert!(res.output.unwrap().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn coordinator_serves_batch() {
        let mut c = Coordinator::new(ArchConfig::default(), 2);
        for (i, m) in ["gcn", "gat", "sage"].iter().enumerate() {
            c.submit(InferenceRequest {
                id: i as u64,
                run: small_run(m, false),
                input_seed: i as u64,
            });
        }
        let mut resp = c.drain();
        assert_eq!(resp.len(), 3);
        resp.sort_by_key(|r| r.id);
        for r in &resp {
            assert!(r.error.is_none(), "{:?}", r.error);
            assert!(r.sim_cycles > 0);
            assert!(r.energy_j > 0.0);
        }
    }

    #[test]
    fn session_cache_reused_across_requests() {
        // identical keys → same dataset instantiation → same cycles
        let mut c = Coordinator::new(ArchConfig::default(), 2);
        for i in 0..4 {
            c.submit(InferenceRequest { id: i, run: small_run("gcn", false), input_seed: i });
        }
        let resp = c.drain();
        let cycles: Vec<u64> = resp.iter().map(|r| r.sim_cycles).collect();
        assert!(cycles.windows(2).all(|w| w[0] == w[1]), "{cycles:?}");
    }

    #[test]
    fn bad_model_reports_error() {
        let mut c = Coordinator::new(ArchConfig::default(), 1);
        let mut run = small_run("gcn", false);
        run.model = "transformer".into();
        c.submit(InferenceRequest { id: 0, run, input_seed: 0 });
        let resp = c.drain();
        assert!(resp[0].error.is_some());
    }
}
