//! Always-on serving runtime: bounded admission, dual-trigger batching,
//! per-request deadlines, load shedding, graceful shutdown.
//!
//! [`ZipperService`] is the long-lived front-end the ROADMAP's serving
//! item calls for: unlike the closed-loop [`super::Coordinator`]
//! (submit a burst, block in `drain`), the service accepts requests
//! *while previous batches execute* and answers each one through its
//! own [`Ticket`]. The request life cycle is a four-stage state
//! machine (DESIGN.md §3.6):
//!
//! ```text
//! submit ──► ADMIT ──► ACCUMULATE ──► DISPATCH ──► respond
//!              │            │             │
//!              │ queue full │ timer/fill  │ deadline expired
//!              ▼            ▼             ▼
//!         QueueFull      (flush)     DeadlineExceeded
//! ```
//!
//! * **Bounded admission** — at most `queue_cap` requests may be
//!   admitted-but-not-picked-up. Overflow either sheds the submit with
//!   a structured [`RejectReason::QueueFull`]
//!   ([`crate::config::OverflowPolicy::Reject`], the default) or parks
//!   the submitting thread until capacity frees
//!   ([`crate::config::OverflowPolicy::Block`]).
//! * **Dual-trigger batching** — requests accumulate per
//!   `(PlanKey, functional)` group. A group flushes to the worker pool
//!   when it reaches `max_batch` (fill trigger, checked at submit) *or*
//!   when its oldest member has waited `max_wait_us` (timer trigger,
//!   driven by a dedicated dispatcher thread waiting on a condvar with
//!   timeout — no busy-wait).
//! * **Deadlines** — a request past its deadline is rejected at
//!   admission and shed again at dispatch (the queue wait may have
//!   consumed the budget), always with
//!   [`RejectReason::DeadlineExceeded`].
//! * **Graceful shutdown** — [`ZipperService::shutdown`] stops
//!   admission, flushes every partial batch, waits up to the grace
//!   period for the backlog to drain, then deterministically fails
//!   whatever is still queued with [`RejectReason::ShuttingDown`].
//! * **Metrics** — [`ZipperService::metrics`] snapshots p50/p95/p99
//!   end-to-end latency (fixed-bucket [`LogHistogram`]), current/peak
//!   queue depth, the batch-size histogram, per-reason shed counters,
//!   and the plan-cache hit rate.
//!
//! Every submitted request yields **exactly one** outcome — completed,
//! failed (validation/compile/panic error), or rejected with a
//! structured reason. Nothing hangs, nothing is dropped silently:
//! `submitted == completed + failed + rejected` holds at every
//! quiescent point (asserted by the sustained-load `perf_serving`
//! scenario and `rust/tests/service.rs`).

use super::{
    panic_message, validate, InferenceRequest, InferenceResponse, LayerCost, RejectReason,
};
use crate::config::{ArchConfig, OverflowPolicy, ServingConfig};
use crate::energy::EnergyModel;
use crate::plan::{CacheStats, PlanCache, PlanKey};
use crate::sim::parallel::BatchScratch;
use crate::sim::ExecScratch;
use crate::util::stats::LogHistogram;
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Test-only panic injection: a request whose `run.seed` equals this
/// sentinel panics inside the worker's guarded execution region, after
/// admission and batching. Integration tests use it to prove the
/// exactly-once response accounting under worker failure (poisoned
/// batches fail with a structured error, the worker survives, queued
/// and later requests are unaffected) without a special build. The
/// seed participates in the plan key, so poisoned requests never share
/// a batch with healthy ones.
#[doc(hidden)]
pub const INJECT_PANIC_SEED: u64 = 0x7a69_7070_6572_2121; // "zipper!!"

/// One admitted request: the public request plus the service-side
/// accounting state (enqueue instant for queue/wall latency, resolved
/// absolute deadline, and the response channel backing its [`Ticket`]).
struct Pending {
    req: InferenceRequest,
    enqueued: Instant,
    deadline: Option<Instant>,
    tx: mpsc::Sender<InferenceResponse>,
}

impl Pending {
    fn failed(&self, error: &str, picked_up: Instant) -> InferenceResponse {
        InferenceResponse {
            wall_seconds: self.enqueued.elapsed().as_secs_f64(),
            queue_seconds: picked_up.duration_since(self.enqueued).as_secs_f64(),
            ..InferenceResponse::failed(
                self.req.id,
                &self.req.run.model,
                &self.req.run.dataset,
                error.to_string(),
            )
        }
    }

    /// A structured rejection: the whole lifetime was queue time.
    fn rejected(&self, reason: RejectReason) -> InferenceResponse {
        let waited = self.enqueued.elapsed().as_secs_f64();
        InferenceResponse {
            wall_seconds: waited,
            queue_seconds: waited,
            reject: Some(reason),
            ..InferenceResponse::failed(
                self.req.id,
                &self.req.run.model,
                &self.req.run.dataset,
                format!("rejected: {reason}"),
            )
        }
    }
}

/// A per-`(PlanKey, functional)` accumulator group (always < max_batch
/// members — fill-triggered groups move to the ready queue at submit).
struct Accum {
    reqs: Vec<Pending>,
    /// Enqueue instant of the oldest member — the timer trigger's base.
    oldest: Instant,
}

/// Counters owned by the state mutex (no atomics: every writer already
/// holds the lock).
struct Counters {
    submitted: u64,
    completed: u64,
    failed: u64,
    rejected_queue_full: u64,
    rejected_deadline: u64,
    shed_deadline: u64,
    rejected_shutdown: u64,
    peak_queue_depth: usize,
    batches: u64,
    /// Dispatched-batch size histogram, index = size (0 unused).
    batch_sizes: Vec<u64>,
    /// End-to-end (submit → response) latency of served requests, µs.
    latency: LogHistogram,
}

impl Counters {
    fn new(max_batch: usize) -> Counters {
        Counters {
            submitted: 0,
            completed: 0,
            failed: 0,
            rejected_queue_full: 0,
            rejected_deadline: 0,
            shed_deadline: 0,
            rejected_shutdown: 0,
            peak_queue_depth: 0,
            batches: 0,
            batch_sizes: vec![0; max_batch + 1],
            latency: LogHistogram::new(),
        }
    }

    fn count_reject(&mut self, reason: RejectReason) {
        match reason {
            RejectReason::QueueFull => self.rejected_queue_full += 1,
            RejectReason::DeadlineExceeded => self.rejected_deadline += 1,
            RejectReason::ShuttingDown => self.rejected_shutdown += 1,
        }
    }
}

struct State {
    accum: HashMap<(PlanKey, bool), Accum>,
    ready: VecDeque<Vec<Pending>>,
    /// Requests admitted but not yet picked up (accum + ready).
    queued: usize,
    /// Requests picked up by a worker, response not yet recorded.
    in_flight: usize,
    /// Admission stopped (shutdown started).
    stop_admission: bool,
    /// Workers and dispatcher exit (ready queue is empty by then).
    halt: bool,
    metrics: Counters,
}

struct Inner {
    state: Mutex<State>,
    /// Workers wait here for ready batches.
    work: Condvar,
    /// The dispatcher waits here (with timeout) for the next flush.
    timer: Condvar,
    /// Blocked submitters (`OverflowPolicy::Block`) wait here for space.
    space: Condvar,
    /// `shutdown` waits here for `queued == 0 && in_flight == 0`.
    done: Condvar,
}

impl Inner {
    fn lock(&self) -> MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }
}

/// The receipt for one submitted request: resolves to **exactly one**
/// [`InferenceResponse`] — completed, failed, or rejected with a
/// structured [`RejectReason`]. Waiting never hangs: if the serving
/// side is torn down without answering (a bug, not a code path), a
/// synthesized error response is returned instead.
pub struct Ticket {
    id: u64,
    model: String,
    dataset: String,
    rx: mpsc::Receiver<InferenceResponse>,
}

impl Ticket {
    /// Block until the response arrives (or synthesize an error if the
    /// serving side vanished without answering).
    pub fn wait(self) -> InferenceResponse {
        self.rx.recv().unwrap_or_else(|_| {
            InferenceResponse::failed(
                self.id,
                &self.model,
                &self.dataset,
                "response channel closed: worker lost without answering".into(),
            )
        })
    }

    /// Non-blocking poll: `Some(response)` once resolved.
    pub fn poll(&self) -> Option<InferenceResponse> {
        self.rx.try_recv().ok()
    }

    pub fn id(&self) -> u64 {
        self.id
    }
}

/// Point-in-time service metrics (all counters monotone except
/// `queue_depth`/`in_flight`). The accounting identity
/// `submitted == completed + failed + rejected_total() + queue_depth +
/// in_flight` holds at every snapshot.
#[derive(Clone, Debug)]
pub struct ServiceMetrics {
    pub submitted: u64,
    /// Served without error.
    pub completed: u64,
    /// Answered with an error (validation, compile, worker panic).
    pub failed: u64,
    pub rejected_queue_full: u64,
    /// Deadline rejections at admission.
    pub rejected_deadline: u64,
    /// Deadline sheds at dispatch (queue wait consumed the budget).
    pub shed_deadline: u64,
    pub rejected_shutdown: u64,
    pub queue_depth: usize,
    pub peak_queue_depth: usize,
    pub in_flight: usize,
    /// Batches dispatched to workers (post-shed sizes).
    pub batches: u64,
    /// Dispatched-batch size histogram, index = batch size (0 unused).
    pub batch_size_hist: Vec<u64>,
    /// End-to-end latency percentiles of served requests, µs
    /// (fixed-bucket log₂ histogram — see [`LogHistogram`]).
    pub latency_p50_us: u64,
    pub latency_p95_us: u64,
    pub latency_p99_us: u64,
    pub latency_max_us: u64,
    pub latency_count: u64,
    pub plan_cache: CacheStats,
}

impl ServiceMetrics {
    /// All structured rejections (admission + dispatch sheds).
    pub fn rejected_total(&self) -> u64 {
        self.rejected_queue_full
            + self.rejected_deadline
            + self.shed_deadline
            + self.rejected_shutdown
    }

    /// Fraction of submitted requests shed with a structured reason.
    pub fn shed_rate(&self) -> f64 {
        if self.submitted == 0 {
            0.0
        } else {
            self.rejected_total() as f64 / self.submitted as f64
        }
    }

    /// Mean dispatched batch size (0 when nothing was dispatched).
    pub fn mean_batch_size(&self) -> f64 {
        let (mut n, mut sum) = (0u64, 0u64);
        for (size, &count) in self.batch_size_hist.iter().enumerate() {
            n += count;
            sum += size as u64 * count;
        }
        if n == 0 {
            0.0
        } else {
            sum as f64 / n as f64
        }
    }
}

/// What [`ZipperService::shutdown`] observed.
#[derive(Clone, Copy, Debug)]
pub struct ShutdownReport {
    /// The backlog drained within the grace period.
    pub graceful: bool,
    /// Requests still queued past grace, failed with `ShuttingDown`.
    pub shed: u64,
    pub wall_seconds: f64,
}

/// Per-worker pooled scratches, reused across every batch the worker
/// serves (the allocation-light hot path).
struct WorkerState {
    timing: ExecScratch,
    batch: BatchScratch,
}

/// The always-on serving runtime. See the [module docs](self) for the
/// state machine and guarantees.
///
/// # Examples
///
/// Submit while serving, then shut down gracefully:
///
/// ```
/// use std::sync::Arc;
/// use std::time::Duration;
/// use zipper::config::{ArchConfig, RunConfig, ServingConfig};
/// use zipper::coordinator::service::ZipperService;
/// use zipper::coordinator::InferenceRequest;
/// use zipper::plan::PlanCache;
///
/// let mut run = RunConfig::default();
/// run.dataset = "CR".into(); // tiny citation-graph stand-in
/// run.scale = 64;
/// run.feat_in = 8;
/// run.feat_out = 8;
///
/// // batch up to 4 requests, flush partial batches after 500 µs
/// let serving = ServingConfig { max_batch: 4, max_wait_us: 500, ..Default::default() };
/// let svc =
///     ZipperService::new(ArchConfig::default(), 2, serving, Arc::new(PlanCache::new())).unwrap();
/// let tickets: Vec<_> = (0..3)
///     .map(|id| svc.submit(InferenceRequest { id, run: run.clone(), input_seed: id }))
///     .collect();
/// let report = svc.shutdown(Duration::from_secs(60));
/// assert!(report.graceful);
/// for t in tickets {
///     let resp = t.wait();
///     assert!(resp.error.is_none() && resp.reject.is_none());
/// }
/// let m = svc.metrics();
/// assert_eq!((m.submitted, m.completed), (3, 3));
/// assert_eq!(m.queue_depth, 0);
/// ```
pub struct ZipperService {
    inner: Arc<Inner>,
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
    serving: ServingConfig,
    cache: Arc<PlanCache>,
}

impl ZipperService {
    /// Spawn the worker pool (`num_workers`, clamped to ≥ 1) and the
    /// batching dispatcher. Fails fast on self-contradictory serving
    /// knobs (see [`validate::check_serving`]).
    pub fn new(
        arch: ArchConfig,
        num_workers: usize,
        serving: ServingConfig,
        cache: Arc<PlanCache>,
    ) -> Result<ZipperService, String> {
        validate::check_serving(&serving)?;
        let max_batch = serving.max_batch.max(1) as usize;
        let inner = Arc::new(Inner {
            state: Mutex::new(State {
                accum: HashMap::new(),
                ready: VecDeque::new(),
                queued: 0,
                in_flight: 0,
                stop_admission: false,
                halt: false,
                metrics: Counters::new(max_batch),
            }),
            work: Condvar::new(),
            timer: Condvar::new(),
            space: Condvar::new(),
            done: Condvar::new(),
        });
        let mut threads = Vec::new();
        for i in 0..num_workers.max(1) {
            let inner = Arc::clone(&inner);
            let cache = Arc::clone(&cache);
            let handle = std::thread::Builder::new()
                .name(format!("zipper-worker-{i}"))
                .spawn(move || worker_loop(&inner, arch, serving, &cache))
                .map_err(|e| format!("spawn worker: {e}"))?;
            threads.push(handle);
        }
        {
            let inner = Arc::clone(&inner);
            let max_wait = Duration::from_micros(serving.max_wait_us);
            let handle = std::thread::Builder::new()
                .name("zipper-dispatch".into())
                .spawn(move || dispatcher_loop(&inner, max_wait))
                .map_err(|e| format!("spawn dispatcher: {e}"))?;
            threads.push(handle);
        }
        Ok(ZipperService { inner, threads: Mutex::new(threads), serving, cache })
    }

    pub fn cache(&self) -> &Arc<PlanCache> {
        &self.cache
    }

    pub fn serving(&self) -> ServingConfig {
        self.serving
    }

    /// Admit a request under the service's `default_deadline_us`.
    pub fn submit(&self, req: InferenceRequest) -> Ticket {
        self.submit_with_deadline(req, None)
    }

    /// Admit a request with an explicit absolute deadline (`None` =
    /// fall back to the service default; a default of 0 means no
    /// deadline). Always returns a [`Ticket`] that resolves to exactly
    /// one response; admission rejections resolve it immediately.
    ///
    /// Under `OverflowPolicy::Block` this call parks until queue
    /// capacity frees, the deadline expires, or shutdown begins.
    pub fn submit_with_deadline(
        &self,
        req: InferenceRequest,
        deadline: Option<Instant>,
    ) -> Ticket {
        let (tx, rx) = mpsc::channel();
        let ticket = Ticket {
            id: req.id,
            model: req.run.model.clone(),
            dataset: req.run.dataset.clone(),
            rx,
        };
        let enqueued = Instant::now();
        let deadline = deadline.or_else(|| match self.serving.default_deadline_us {
            0 => None,
            us => Some(enqueued + Duration::from_micros(us)),
        });
        // structured front-door validation: malformed layer chains and
        // unknown models never reach the worker pool
        if let Err(e) = validate::check_layer_chain(&req.run) {
            let mut st = self.inner.lock();
            st.metrics.submitted += 1;
            st.metrics.failed += 1;
            drop(st);
            let _ = tx.send(InferenceResponse::failed(req.id, &req.run.model, &req.run.dataset, e));
            return ticket;
        }
        let p = Pending { req, enqueued, deadline, tx };
        let mut st = self.inner.lock();
        st.metrics.submitted += 1;
        if st.stop_admission {
            Self::reject(&mut st, p, RejectReason::ShuttingDown);
            return ticket;
        }
        if p.deadline.is_some_and(|d| d <= Instant::now()) {
            Self::reject(&mut st, p, RejectReason::DeadlineExceeded);
            return ticket;
        }
        let cap = self.serving.queue_cap.max(1) as usize;
        if st.queued >= cap {
            match self.serving.overflow {
                OverflowPolicy::Reject => {
                    Self::reject(&mut st, p, RejectReason::QueueFull);
                    return ticket;
                }
                OverflowPolicy::Block => {
                    // backpressure: park until space frees or shutdown
                    while st.queued >= cap && !st.stop_admission {
                        st = self.inner.space.wait(st).unwrap_or_else(|e| e.into_inner());
                    }
                    if st.stop_admission {
                        Self::reject(&mut st, p, RejectReason::ShuttingDown);
                        return ticket;
                    }
                    if p.deadline.is_some_and(|d| d <= Instant::now()) {
                        Self::reject(&mut st, p, RejectReason::DeadlineExceeded);
                        return ticket;
                    }
                }
            }
        }
        // admit into the request's accumulator group
        st.queued += 1;
        st.metrics.peak_queue_depth = st.metrics.peak_queue_depth.max(st.queued);
        let key = (PlanKey::of(&p.req.run), p.req.run.functional);
        let max_batch = self.serving.max_batch.max(1) as usize;
        let full = {
            let acc = st.accum.entry(key.clone()).or_insert_with(|| Accum {
                reqs: Vec::with_capacity(max_batch),
                oldest: enqueued,
            });
            acc.reqs.push(p);
            acc.reqs.len() >= max_batch
        };
        if full {
            // fill trigger: hand the whole group to the worker pool now
            if let Some(acc) = st.accum.remove(&key) {
                st.ready.push_back(acc.reqs);
            }
            self.inner.work.notify_all();
        } else {
            // timer trigger: let the dispatcher re-arm for this group
            self.inner.timer.notify_all();
        }
        ticket
    }

    fn reject(st: &mut State, p: Pending, reason: RejectReason) {
        st.metrics.count_reject(reason);
        let resp = p.rejected(reason);
        let _ = p.tx.send(resp);
    }

    /// Snapshot the service counters (callable at any time, including
    /// after shutdown).
    pub fn metrics(&self) -> ServiceMetrics {
        let st = self.inner.lock();
        let m = &st.metrics;
        ServiceMetrics {
            submitted: m.submitted,
            completed: m.completed,
            failed: m.failed,
            rejected_queue_full: m.rejected_queue_full,
            rejected_deadline: m.rejected_deadline,
            shed_deadline: m.shed_deadline,
            rejected_shutdown: m.rejected_shutdown,
            queue_depth: st.queued,
            peak_queue_depth: m.peak_queue_depth,
            in_flight: st.in_flight,
            batches: m.batches,
            batch_size_hist: m.batch_sizes.clone(),
            latency_p50_us: m.latency.percentile(50.0),
            latency_p95_us: m.latency.percentile(95.0),
            latency_p99_us: m.latency.percentile(99.0),
            latency_max_us: m.latency.max(),
            latency_count: m.latency.count(),
            plan_cache: self.cache.stats(),
        }
    }

    /// Graceful shutdown: stop admission, flush every partial batch,
    /// wait up to `grace` for the backlog to drain, then
    /// deterministically fail whatever is still queued with
    /// [`RejectReason::ShuttingDown`] and join the threads. In-flight
    /// batches always finish and answer their requests (a worker is
    /// never killed mid-batch); the grace period bounds only the wait
    /// for *queued* work. Idempotent — later calls return immediately.
    pub fn shutdown(&self, grace: Duration) -> ShutdownReport {
        let t0 = Instant::now();
        {
            let mut st = self.inner.lock();
            if st.halt {
                return ShutdownReport { graceful: true, shed: 0, wall_seconds: 0.0 };
            }
            st.stop_admission = true;
            // flush partial batches so the drain below can finish them
            let groups: Vec<Vec<Pending>> = st.accum.drain().map(|(_, acc)| acc.reqs).collect();
            for g in groups {
                st.ready.push_back(g);
            }
        }
        self.inner.work.notify_all();
        self.inner.timer.notify_all();
        self.inner.space.notify_all();

        let st = self.inner.lock();
        let (mut st, _) = self
            .inner
            .done
            .wait_timeout_while(st, grace, |s| s.queued > 0 || s.in_flight > 0)
            .unwrap_or_else(|e| e.into_inner());
        let graceful = st.queued == 0 && st.in_flight == 0;
        // past grace: fail the remaining backlog deterministically
        let mut shed = 0u64;
        let leftovers: Vec<Vec<Pending>> = st.ready.drain(..).collect();
        for batch in leftovers {
            for p in batch {
                shed += 1;
                Self::reject(&mut st, p, RejectReason::ShuttingDown);
            }
        }
        st.queued = 0;
        st.halt = true;
        drop(st);
        self.inner.work.notify_all();
        self.inner.timer.notify_all();
        self.inner.space.notify_all();
        for h in self.threads.lock().unwrap_or_else(|e| e.into_inner()).drain(..) {
            let _ = h.join();
        }
        ShutdownReport { graceful, shed, wall_seconds: t0.elapsed().as_secs_f64() }
    }
}

impl Drop for ZipperService {
    fn drop(&mut self) {
        self.shutdown(Duration::from_millis(100));
    }
}

/// The dispatcher thread: drives the `max_wait_us` timer trigger with a
/// condvar-with-timeout — it sleeps until the oldest accumulated
/// request's flush deadline (or indefinitely when nothing is pending /
/// the timer is disabled) and is re-armed by `submit`. It never blocks
/// on workers and never holds the lock while sleeping, so it cannot
/// deadlock with them (DESIGN.md §3.6).
fn dispatcher_loop(inner: &Inner, max_wait: Duration) {
    let timer_on = max_wait > Duration::ZERO;
    let mut st = inner.lock();
    loop {
        if st.halt {
            return;
        }
        let mut next: Option<Instant> = None;
        if timer_on {
            let now = Instant::now();
            let expired: Vec<(PlanKey, bool)> = st
                .accum
                .iter()
                .filter(|(_, acc)| now.duration_since(acc.oldest) >= max_wait)
                .map(|(k, _)| k.clone())
                .collect();
            let flushed = !expired.is_empty();
            for key in expired {
                if let Some(acc) = st.accum.remove(&key) {
                    st.ready.push_back(acc.reqs);
                }
            }
            if flushed {
                inner.work.notify_all();
            }
            next = st.accum.values().map(|acc| acc.oldest + max_wait).min();
        }
        st = match next {
            Some(deadline) => {
                let wait = deadline.saturating_duration_since(Instant::now());
                let (g, _) = inner.timer.wait_timeout(st, wait).unwrap_or_else(|e| e.into_inner());
                g
            }
            None => inner.timer.wait(st).unwrap_or_else(|e| e.into_inner()),
        };
    }
}

/// A worker thread: pop a ready batch, shed expired members, execute
/// the rest in one batched pass, answer every member, record metrics.
/// Panics inside execution are caught per batch — the members fail
/// with a structured error and the worker keeps serving.
fn worker_loop(inner: &Inner, arch: ArchConfig, serving: ServingConfig, cache: &Arc<PlanCache>) {
    let mut ws = WorkerState { timing: ExecScratch::new(), batch: BatchScratch::new() };
    loop {
        let batch = {
            let mut st = inner.lock();
            loop {
                if let Some(b) = st.ready.pop_front() {
                    st.queued = st.queued.saturating_sub(b.len());
                    st.in_flight += b.len();
                    break Some(b);
                }
                if st.halt {
                    break None;
                }
                st = inner.work.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        let Some(batch) = batch else { return };
        // queue capacity freed — wake blocked submitters
        inner.space.notify_all();
        let picked_up = Instant::now();
        let total = batch.len();

        // shed members whose deadline expired while queued
        let mut live: Vec<Pending> = Vec::with_capacity(total);
        let mut shed_resps: Vec<(Pending, InferenceResponse)> = Vec::new();
        for p in batch {
            if p.deadline.is_some_and(|d| d <= picked_up) {
                let resp = p.rejected(RejectReason::DeadlineExceeded);
                shed_resps.push((p, resp));
            } else {
                live.push(p);
            }
        }
        let shed = shed_resps.len() as u64;
        for (p, resp) in shed_resps {
            let _ = p.tx.send(resp);
        }

        let responses = if live.is_empty() {
            Vec::new()
        } else {
            catch_unwind(AssertUnwindSafe(|| {
                execute_batch(&arch, cache, serving, &live, picked_up, &mut ws)
            }))
            .unwrap_or_else(|panic| {
                let msg = format!("worker panicked: {}", panic_message(panic.as_ref()));
                live.iter().map(|p| p.failed(&msg, picked_up)).collect()
            })
        };

        let mut ok = 0u64;
        let mut failed = 0u64;
        let mut lat_us: Vec<u64> = Vec::with_capacity(live.len());
        let live_len = live.len();
        for (p, resp) in live.iter().zip(responses) {
            if resp.error.is_none() {
                ok += 1;
            } else {
                failed += 1;
            }
            lat_us.push((resp.wall_seconds * 1e6) as u64);
            let _ = p.tx.send(resp);
        }

        let mut st = inner.lock();
        st.in_flight = st.in_flight.saturating_sub(total);
        st.metrics.shed_deadline += shed;
        st.metrics.completed += ok;
        st.metrics.failed += failed;
        for us in lat_us {
            st.metrics.latency.record(us);
        }
        if live_len > 0 {
            st.metrics.batches += 1;
            let idx = live_len.min(st.metrics.batch_sizes.len() - 1);
            st.metrics.batch_sizes[idx] += 1;
        }
        if st.queued == 0 && st.in_flight == 0 {
            inner.done.notify_all();
        }
    }
}

/// Serve one plan-compatible batch: a single plan lookup, a single
/// input-independent timing simulation, and (for functional requests)
/// one tile-parallel batched functional pass covering every lane.
/// Per-request accounting: `wall_seconds` spans submit → response
/// (queue wait included), `queue_seconds` is the admission-to-pickup
/// slice, `prepare_seconds` is the cold plan-compile cost.
fn execute_batch(
    arch: &ArchConfig,
    cache: &PlanCache,
    serving: ServingConfig,
    batch: &[Pending],
    picked_up: Instant,
    state: &mut WorkerState,
) -> Vec<InferenceResponse> {
    for p in batch {
        assert_ne!(
            p.req.run.seed,
            INJECT_PANIC_SEED,
            "injected worker panic (INJECT_PANIC_SEED test hook)"
        );
    }
    let first = &batch[0];
    let (plan, hit) = match cache.get_or_compile(&first.req.run) {
        Ok(p) => p,
        Err(e) => return batch.iter().map(|p| p.failed(&e, picked_up)).collect(),
    };
    let prepare_seconds = if hit { 0.0 } else { picked_up.elapsed().as_secs_f64() };

    // Timing is a pure function of (arch, plan) — input embeddings never
    // reach the cycle-level model — so one simulation covers the batch
    // (all layers of the pipeline, summed).
    let timing = match plan.simulate_with(arch, false, None, 0, &mut state.timing) {
        Ok(t) => t,
        Err(e) => return batch.iter().map(|p| p.failed(&e, picked_up)).collect(),
    };
    let energy = EnergyModel::default();
    let energy_j = energy.evaluate(&timing.counters, arch.freq_hz).total_j();
    let layer_costs: Vec<LayerCost> = timing
        .layers
        .iter()
        .map(|lm| LayerCost {
            feat_in: lm.feat_in,
            feat_out: lm.feat_out,
            cycles: lm.cycles,
            dram_read_bytes: lm.dram_read_bytes,
            dram_write_bytes: lm.dram_write_bytes,
            energy_j: energy.evaluate(&lm.counters, arch.freq_hz).total_j(),
        })
        .collect();

    // Functional lanes: one scratch-resident batched pass for all
    // requests, tiles sharded across `serving.exec_threads`.
    let mut checksums: Vec<Option<f64>> = vec![None; batch.len()];
    if first.req.run.functional {
        let inputs: Vec<Vec<f32>> =
            batch.iter().map(|p| plan.make_input(p.req.input_seed)).collect();
        let lanes: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let outs = match plan.execute_batch_with(
            &lanes,
            serving.exec_threads.max(1) as usize,
            &mut state.batch,
        ) {
            Ok(o) => o,
            Err(e) => return batch.iter().map(|p| p.failed(&e, picked_up)).collect(),
        };
        for (slot, out) in checksums.iter_mut().zip(&outs) {
            *slot = Some(out.iter().map(|&v| v as f64).sum::<f64>());
        }
    }

    batch
        .iter()
        .zip(checksums)
        .map(|(p, output_checksum)| InferenceResponse {
            sim_cycles: timing.cycles,
            sim_seconds: timing.seconds(arch),
            energy_j,
            layers: layer_costs.clone(),
            peak_uem_bytes: timing.peak_uem_bytes,
            wall_seconds: p.enqueued.elapsed().as_secs_f64(),
            queue_seconds: picked_up.duration_since(p.enqueued).as_secs_f64(),
            plan_cache_hit: hit,
            prepare_seconds,
            batch_size: batch.len(),
            halo_bytes: timing.halo.bytes,
            halo_hidden_cycles: timing.halo.hidden_cycles,
            halo_exposed_cycles: timing.halo.exposed_cycles,
            output_checksum,
            ..InferenceResponse::empty(p.req.id, &p.req.run.model, &p.req.run.dataset)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::tiling::{Reorder, TilingConfig, TilingMode};

    fn small_run(model: &str, functional: bool) -> RunConfig {
        RunConfig {
            model: model.into(),
            dataset: "CR".into(),
            scale: 16,
            feat_in: 16,
            feat_out: 16,
            layers: 1,
            hidden: Vec::new(),
            tiling: TilingConfig {
                dst_part: 64,
                src_part: 64,
                mode: TilingMode::Sparse,
                reorder: Reorder::InDegree,
                threads: 1,
            },
            e2v: true,
            passes: Default::default(),
            functional,
            seed: 3,
            serving: Default::default(),
            kernels: Default::default(),
            shards: 1,
            overlap: false,
        }
    }

    fn req(id: u64, run: RunConfig) -> InferenceRequest {
        InferenceRequest { id, run, input_seed: id }
    }

    fn service(workers: usize, serving: ServingConfig) -> ZipperService {
        ZipperService::new(
            ArchConfig::default(),
            workers,
            serving,
            Arc::new(PlanCache::new()),
        )
        .unwrap()
    }

    #[test]
    fn serves_and_accounts_exactly_once() {
        let svc = service(2, ServingConfig::default());
        let tickets: Vec<Ticket> =
            (0..4).map(|i| svc.submit(req(i, small_run("gcn", true)))).collect();
        let resps: Vec<InferenceResponse> = tickets.into_iter().map(Ticket::wait).collect();
        for r in &resps {
            assert!(r.error.is_none(), "{:?}", r.error);
            assert!(r.reject.is_none());
            assert!(r.output_checksum.is_some());
            assert!(r.wall_seconds >= r.queue_seconds);
        }
        let report = svc.shutdown(Duration::from_secs(30));
        assert!(report.graceful);
        assert_eq!(report.shed, 0);
        let m = svc.metrics();
        assert_eq!((m.submitted, m.completed, m.failed), (4, 4, 0));
        assert_eq!(m.rejected_total(), 0);
        assert_eq!((m.queue_depth, m.in_flight), (0, 0));
        assert_eq!(m.latency_count, 4);
        assert!(m.latency_p99_us >= m.latency_p50_us);
        assert_eq!(m.batch_size_hist.iter().sum::<u64>(), m.batches);
    }

    #[test]
    fn queue_full_rejects_deterministically() {
        // max_batch 8 with a far timer: the first request accumulates
        // and is NOT picked up, so the depth-1 queue is provably full
        // when the second arrives — no racing against workers.
        let serving = ServingConfig {
            max_batch: 8,
            max_wait_us: 60_000_000,
            queue_cap: 1,
            ..Default::default()
        };
        let svc = service(1, serving);
        let t0 = svc.submit(req(0, small_run("gcn", false)));
        let t1 = svc.submit(req(1, small_run("gcn", false)));
        let r1 = t1.wait(); // resolved immediately at admission
        assert_eq!(r1.reject, Some(RejectReason::QueueFull));
        assert!(r1.error.as_deref().unwrap().contains("queue_full"), "{:?}", r1.error);
        let report = svc.shutdown(Duration::from_secs(30));
        assert!(report.graceful);
        let r0 = t0.wait(); // flushed and served by the shutdown drain
        assert!(r0.error.is_none(), "{:?}", r0.error);
        let m = svc.metrics();
        assert_eq!(m.rejected_queue_full, 1);
        assert_eq!(m.completed, 1);
        assert_eq!(m.peak_queue_depth, 1);
    }

    #[test]
    fn expired_deadline_rejected_at_admission() {
        let svc = service(1, ServingConfig::default());
        let t = svc.submit_with_deadline(req(0, small_run("gcn", false)), Some(Instant::now()));
        let r = t.wait();
        assert_eq!(r.reject, Some(RejectReason::DeadlineExceeded));
        svc.shutdown(Duration::from_secs(5));
        assert_eq!(svc.metrics().rejected_deadline, 1);
    }

    #[test]
    fn submit_after_shutdown_is_rejected_structurally() {
        let svc = service(1, ServingConfig::default());
        svc.shutdown(Duration::from_secs(5));
        let t = svc.submit(req(0, small_run("gcn", false)));
        let r = t.wait();
        assert_eq!(r.reject, Some(RejectReason::ShuttingDown));
        assert_eq!(svc.metrics().rejected_shutdown, 1);
    }

    #[test]
    fn malformed_request_fails_fast_with_shape_error() {
        let svc = service(1, ServingConfig::default());
        let mut bad = small_run("gcn", false);
        bad.layers = 3;
        bad.hidden = vec![8]; // needs 2 widths
        let r = svc.submit(req(0, bad)).wait();
        assert!(r.error.as_deref().unwrap().contains("3-layer"), "{:?}", r.error);
        assert!(r.reject.is_none(), "validation failures are errors, not sheds");
        svc.shutdown(Duration::from_secs(5));
        let m = svc.metrics();
        assert_eq!((m.submitted, m.failed), (1, 1));
    }
}
