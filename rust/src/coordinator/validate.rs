//! Three-layer cross-validation: simulator functional output vs the
//! PJRT-executed JAX/Pallas artifacts (paper §8.1's DGL validation).
//!
//! Setup: a small graph tiled so each destination partition has exactly
//! one tile (src_part ≥ |V|), padded to the artifact's static tile shape.
//! For every partition we pack the tile's COO edges + embeddings into the
//! artifact's argument layout, execute via PJRT, and compare against the
//! simulator's functional output row-by-row.
//!
//! Requires a PJRT-backed `Runtime` (see `runtime` module docs); with
//! the dependency-free stub, `Runtime::execute` returns an error and
//! callers should gate on `Runtime::available`.
//!
//! Numerics note: GAT's per-destination softmax is max-stabilized in the
//! JAX oracle but algebraically unstabilized in the ISA program
//! (DESIGN.md §6); with the test-scale weights the difference is ≪ 1e-3.

use super::Session;
use crate::config::{ArchConfig, RunConfig};
use crate::graph::generators;
use crate::models::ModelKind;
use crate::runtime::{pack, ArgValue, Runtime, TileShape};
use crate::tiling::{Reorder, TilingConfig, TilingMode};

#[derive(Clone, Debug)]
pub struct ValidationReport {
    pub model: String,
    pub partitions: usize,
    pub rows_compared: usize,
    pub max_abs_err: f32,
    pub mean_abs_err: f32,
    pub tol: f32,
    pub pass: bool,
}

/// Validate one model end-to-end against the artifact at `shape`.
pub fn validate_model(
    rt: &mut Runtime,
    model: ModelKind,
    shape: &TileShape,
    seed: u64,
) -> Result<ValidationReport, String> {
    // graph sized to fit the artifact: one tile per partition
    let v = shape.num_src.min(200);
    let e = (shape.num_edges / 2).min(600) as u64;
    let etypes = if model.uses_etypes() { crate::models::NUM_RELATIONS } else { 0 };
    let graph = generators::power_law(v, e, 0.9, 0.9, etypes, seed);
    let dst_part = shape.num_dst.min(64);
    let run = RunConfig {
        model: model.name().into(),
        dataset: "synthetic".into(),
        scale: 1,
        feat_in: shape.feat_in,
        feat_out: shape.feat_out,
        tiling: TilingConfig {
            dst_part,
            src_part: v, // one source block ⇒ one tile per partition
            mode: TilingMode::Sparse,
            reorder: Reorder::None,
            threads: 1,
        },
        e2v: true,
        functional: true,
        seed,
        serving: Default::default(),
    };
    let session = Session::from_graph(model, graph, &run).map_err(|e| format!("session: {e}"))?;
    let x = session.make_input(seed ^ 0x5eed);
    let sim = session
        .simulate(&ArchConfig::default(), true, Some(&x), 0)
        .map_err(|e| format!("simulate: {e}"))?;
    let sim_out = sim.output.ok_or("no functional output")?;

    // Oracle path: per-partition PJRT execution.
    let fi = shape.feat_in as usize;
    let fo = shape.feat_out as usize;
    let n = session.graph().num_vertices() as usize;
    let tiling = session.tiling();
    // permuted input (tiling may relabel; Reorder::None ⇒ identity, but
    // keep the general path)
    let mut x_tiled = vec![0.0f32; n * fi];
    for old in 0..n {
        let new = tiling.perm[old] as usize;
        x_tiled[new * fi..(new + 1) * fi].copy_from_slice(&x[old * fi..(old + 1) * fi]);
    }
    let mut oracle_tiled = vec![0.0f32; n * fo];
    for part in &tiling.partitions {
        if part.tiles.is_empty() {
            continue;
        }
        if part.tiles.len() != 1 {
            return Err("validation tiling must give one tile per partition".into());
        }
        let tile = &part.tiles[0];
        if tile.num_src() > shape.num_src || tile.num_edges() > shape.num_edges {
            return Err(format!(
                "tile exceeds artifact shape: src {} edges {}",
                tile.num_src(),
                tile.num_edges()
            ));
        }
        // pack x_src rows (tile source vertices, tiled ids)
        let mut xs = vec![0.0f32; tile.num_src() as usize * fi];
        for (i, &gv) in tile.src_vertices.iter().enumerate() {
            xs[i * fi..(i + 1) * fi]
                .copy_from_slice(&x_tiled[gv as usize * fi..(gv as usize + 1) * fi]);
        }
        let x_src = pack::features(&xs, shape.num_src as usize, fi);
        // pack x_dst rows (partition destinations)
        let mut xd = vec![0.0f32; part.num_dst() as usize * fi];
        for (i, gv) in (part.dst_start..part.dst_end).enumerate() {
            xd[i * fi..(i + 1) * fi]
                .copy_from_slice(&x_tiled[gv as usize * fi..(gv as usize + 1) * fi]);
        }
        let x_dst = pack::features(&xd, shape.num_dst as usize, fi);
        let (src, dst, valid) = pack::edges(&tile.edges, shape.num_edges as usize);
        let et = pack::etypes(
            tile.etypes.as_deref().unwrap_or(&[]),
            shape.num_edges as usize,
        );

        // weights in the artifact's argument order
        let w = |name: &str| -> Result<ArgValue, String> {
            let t = session
                .weights()
                .tensors
                .iter()
                .find(|t| t.name == name)
                .ok_or_else(|| format!("weight {name} missing"))?;
            let shape_v = if t.count > 1 {
                vec![t.count as usize, t.rows as usize, t.cols as usize]
            } else if t.cols == 1 {
                vec![t.rows as usize]
            } else {
                vec![t.rows as usize, t.cols as usize]
            };
            Ok(ArgValue::F32 { data: t.data.clone(), shape: shape_v })
        };
        let zeros_bias = ArgValue::F32 { data: vec![0.0; fo], shape: vec![fo] };

        let args: Vec<ArgValue> = match model {
            ModelKind::Gcn => vec![x_src, src, dst, valid, w("w")?],
            ModelKind::Gat => vec![
                x_src, x_dst, src, dst, valid, w("w")?, w("a_src")?, w("a_dst")?,
            ],
            ModelKind::Sage => vec![
                x_src, x_dst, src, dst, valid, w("w_pool")?, zeros_bias,
                w("w_self")?, w("w_neigh")?,
            ],
            ModelKind::Ggnn => vec![
                x_src, x_dst, src, dst, valid, w("w_msg")?, w("w_z")?, w("u_z")?,
                w("w_r")?, w("u_r")?, w("w_h")?, w("u_h")?,
            ],
            ModelKind::Rgcn => vec![x_src, src, dst, et, valid, w("w_rel")?],
        };
        let out = rt
            .execute(model.name(), shape, &args)
            .map_err(|e| e.to_string())?;
        // rows 0..num_dst are the real partition rows
        for (i, gv) in (part.dst_start..part.dst_end).enumerate() {
            oracle_tiled[gv as usize * fo..(gv as usize + 1) * fo]
                .copy_from_slice(&out[i * fo..(i + 1) * fo]);
        }
    }
    // un-permute the oracle output
    let mut oracle = vec![0.0f32; n * fo];
    for new in 0..n {
        let old = tiling.inv_perm[new] as usize;
        oracle[old * fo..(old + 1) * fo]
            .copy_from_slice(&oracle_tiled[new * fo..(new + 1) * fo]);
    }

    let mut max_err = 0.0f32;
    let mut sum_err = 0.0f64;
    for (a, b) in sim_out.iter().zip(&oracle) {
        let e = (a - b).abs();
        max_err = max_err.max(e);
        sum_err += e as f64;
    }
    let tol = 2e-3;
    Ok(ValidationReport {
        model: model.name().into(),
        partitions: tiling.partitions.len(),
        rows_compared: n,
        max_abs_err: max_err,
        mean_abs_err: (sum_err / sim_out.len() as f64) as f32,
        tol,
        pass: max_err < tol,
    })
}

/// Validate every model that has an artifact at `shape`.
pub fn validate_all(
    rt: &mut Runtime,
    shape: &TileShape,
    seed: u64,
) -> Result<Vec<ValidationReport>, String> {
    let mut reports = Vec::new();
    for m in ModelKind::ALL {
        reports.push(validate_model(rt, m, shape, seed)?);
    }
    Ok(reports)
}
