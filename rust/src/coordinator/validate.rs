//! Request validation + three-layer cross-validation.
//!
//! Two jobs live here:
//!
//! 1. **Structured front-door validation** ([`check_layer_chain`]): a
//!    request's model name and layer chain (depth + hidden widths) are
//!    resolved into a [`ModelSpec`] *before* any compile work happens,
//!    so inconsistent chains (wrong hidden-width count, non-square GGNN
//!    widths) fail at submit with shape-carrying messages instead of
//!    deep inside a worker's plan compile.
//! 2. **PJRT cross-validation** ([`validate_model_depth`]): simulator
//!    functional output vs the PJRT-executed JAX/Pallas artifacts (paper
//!    §8.1's DGL validation), now depth-aware — the oracle stacks the
//!    same per-layer tile executions the Rust pipeline runs, chaining
//!    layer *l*'s whole-graph output into layer *l+1* with the hidden
//!    layers' ReLU applied between (mirroring `LayerSpec::activation`).
//!
//! Oracle setup: a small graph tiled so each destination partition has
//! exactly one tile (src_part ≥ |V|), padded to the artifact's static
//! tile shape. For every partition we pack the tile's COO edges +
//! embeddings into the artifact's argument layout, execute via PJRT, and
//! compare against the simulator's functional output row-by-row.
//! Multi-layer chains reuse the same square artifact (feat_in ==
//! feat_out) per layer with that layer's weights.
//!
//! Requires a PJRT-backed `Runtime` (see `runtime` module docs); with
//! the dependency-free stub, `Runtime::execute` returns an error and
//! callers should gate on `Runtime::available`.
//!
//! Numerics note: GAT's per-destination softmax is max-stabilized in the
//! JAX oracle but algebraically unstabilized in the ISA program
//! (DESIGN.md §6); with the test-scale weights the difference is ≪ 1e-3.

use super::Session;
use crate::config::{ArchConfig, KernelPolicy, OverflowPolicy, RunConfig, ServingConfig};
use crate::graph::generators;
use crate::models::{ModelKind, ModelSpec, WeightStore};
use crate::runtime::{pack, ArgValue, Runtime, TileShape};
use crate::tiling::{Reorder, Tiling, TilingConfig, TilingMode};

/// Resolve a request's layer chain into a [`ModelSpec`], carrying the
/// offending shapes in the error. The coordinator calls this at submit
/// so malformed pipelines never reach the worker pool.
pub fn check_layer_chain(run: &RunConfig) -> Result<ModelSpec, String> {
    let kind = ModelKind::parse(&run.model)
        .ok_or_else(|| format!("unknown model {}", run.model))?;
    ModelSpec::new(kind, run.feat_in, &run.hidden, run.feat_out, run.layers)
}

/// Lower bound on a cold request's host-side latency: even the tiniest
/// plan (CR @ scale 16) costs on the order of a millisecond to compile
/// (dataset → graph → tiling → SDE program → weights), so a default
/// deadline below this floor would shed every cold request before its
/// plan exists. [`check_serving`] rejects such configs at construction.
pub const COLD_COMPILE_FLOOR_US: u64 = 1_000;

/// Fast-fail validation of the always-on serving knobs, mirroring
/// [`check_layer_chain`]: self-contradictory configs are rejected at
/// service construction with the offending values carried in the
/// message, instead of surfacing later as a hung dispatcher, a queue
/// that can never admit, or a deadline that sheds 100% of cold traffic.
pub fn check_serving(serving: &ServingConfig) -> Result<(), String> {
    if serving.queue_cap == 0 {
        return Err(
            "serving.queue_cap = 0 can never admit a request; use queue_cap >= 1 \
             (default 1024)"
                .into(),
        );
    }
    if serving.max_wait_us > 0 && serving.max_batch <= 1 {
        return Err(format!(
            "serving.max_wait_us = {} with max_batch = {} is pure added latency: a \
             1-request batch is already full on arrival, so the timer can never \
             merge anything; set max_batch >= 2 or max_wait_us = 0",
            serving.max_wait_us, serving.max_batch
        ));
    }
    if serving.overflow == OverflowPolicy::Block
        && serving.max_wait_us == 0
        && serving.max_batch > serving.queue_cap
    {
        return Err(format!(
            "serving.overflow = block with max_batch = {} > queue_cap = {} and no \
             flush timer (max_wait_us = 0) deadlocks: the accumulator can never \
             fill before admission blocks; raise queue_cap, lower max_batch, or \
             enable max_wait_us",
            serving.max_batch, serving.queue_cap
        ));
    }
    if serving.default_deadline_us > 0 && serving.default_deadline_us < COLD_COMPILE_FLOOR_US {
        return Err(format!(
            "serving.default_deadline_us = {} is below the cold plan-compile floor \
             (~{COLD_COMPILE_FLOOR_US} us): every cold request would be shed before \
             its plan exists; use 0 (no deadline) or >= {COLD_COMPILE_FLOOR_US}",
            serving.default_deadline_us
        ));
    }
    Ok(())
}

#[derive(Clone, Debug)]
pub struct ValidationReport {
    pub model: String,
    /// Pipeline depth the report covers.
    pub layers: u32,
    pub partitions: usize,
    pub rows_compared: usize,
    pub max_abs_err: f32,
    pub mean_abs_err: f32,
    pub tol: f32,
    pub pass: bool,
}

/// Validate one model end-to-end against the artifact at `shape`
/// (depth 1 — the classic single-layer check).
pub fn validate_model(
    rt: &mut Runtime,
    model: ModelKind,
    shape: &TileShape,
    seed: u64,
) -> Result<ValidationReport, String> {
    validate_model_depth(rt, model, shape, seed, 1)
}

/// Validate a `depth`-layer pipeline end-to-end against the artifact at
/// `shape`: the simulator runs the stacked-layer `ExecPlan`, the oracle
/// chains per-layer PJRT executions with the same per-layer weights and
/// the hidden layers' ReLU in between. Multi-layer chains need a square
/// artifact shape (uniform widths).
pub fn validate_model_depth(
    rt: &mut Runtime,
    model: ModelKind,
    shape: &TileShape,
    seed: u64,
    depth: u32,
) -> Result<ValidationReport, String> {
    validate_model_depth_with(rt, model, shape, seed, depth, KernelPolicy::default())
}

/// [`validate_model_depth`] under an explicit kernel policy. The f32
/// policies (any `simd`/`sparse_skip` combination) are bit-exact with
/// each other, so they share the baseline tolerance; reduced-precision
/// storage widens it by the documented bound: per layer, quantizing
/// weights and the incoming activation perturbs each GEMM output by at
/// most `(2u + u²)·Σ_k|x_k||w_kj|` (u = the dtype's unit roundoff,
/// 2⁻¹¹ for f16 / 2⁻⁸ for bf16 — derivation in DESIGN.md "Kernel
/// policies"), which the `64·u` per-layer term over-approximates at the
/// validation scale (|Σ|x||w|| ≲ 64 with the 0.1-scaled test weights).
pub fn validate_model_depth_with(
    rt: &mut Runtime,
    model: ModelKind,
    shape: &TileShape,
    seed: u64,
    depth: u32,
    kernels: KernelPolicy,
) -> Result<ValidationReport, String> {
    let depth = depth.max(1);
    if depth > 1 && shape.feat_in != shape.feat_out {
        return Err(format!(
            "multi-layer validation needs a square artifact shape (uniform width chain), \
             got feat {}x{}",
            shape.feat_in, shape.feat_out
        ));
    }
    // graph sized to fit the artifact: one tile per partition
    let v = shape.num_src.min(200);
    let e = (shape.num_edges / 2).min(600) as u64;
    let etypes = if model.uses_etypes() { crate::models::NUM_RELATIONS } else { 0 };
    let graph = generators::power_law(v, e, 0.9, 0.9, etypes, seed);
    let dst_part = shape.num_dst.min(64);
    let run = RunConfig {
        model: model.name().into(),
        dataset: "synthetic".into(),
        scale: 1,
        feat_in: shape.feat_in,
        feat_out: shape.feat_out,
        layers: depth,
        hidden: Vec::new(),
        tiling: TilingConfig {
            dst_part,
            src_part: v, // one source block ⇒ one tile per partition
            mode: TilingMode::Sparse,
            reorder: Reorder::None,
            threads: 1,
        },
        e2v: true,
        passes: Default::default(),
        functional: true,
        seed,
        serving: Default::default(),
        kernels,
        shards: 1,
        overlap: false,
    };
    let session = Session::from_graph(model, graph, &run).map_err(|e| format!("session: {e}"))?;
    let x = session.make_input(seed ^ 0x5eed);
    let sim = session
        .simulate(&ArchConfig::default(), true, Some(&x), 0)
        .map_err(|e| format!("simulate: {e}"))?;
    let sim_out = sim.output.ok_or("no functional output")?;

    // Oracle path: chain per-layer PJRT executions. Layer l's
    // whole-graph output (original vertex order) feeds layer l+1; the
    // hidden layers' trailing ReLU matches `LayerSpec::activation`.
    let mut cur = x;
    for l in 0..depth as usize {
        let stage = &session.plan().stages[l];
        let mut out = pjrt_layer(rt, model, shape, &session, &stage.weights, &cur)?;
        if l + 1 < depth as usize {
            for h in &mut out {
                *h = h.max(0.0);
            }
        }
        cur = out;
    }
    let oracle = cur;

    let mut max_err = 0.0f32;
    let mut sum_err = 0.0f64;
    for (a, b) in sim_out.iter().zip(&oracle) {
        let e = (a - b).abs();
        max_err = max_err.max(e);
        sum_err += e as f64;
    }
    // the existing single-layer tolerance, widened per extra layer
    // (hidden-layer error propagates through the next layer's GEMMs) and
    // per the storage dtype's unit roundoff (0 for f32 — see the
    // `validate_model_depth_with` docs for the bound)
    let tol = (2e-3 + 64.0 * kernels.dtype.unit_roundoff()) * depth as f32;
    Ok(ValidationReport {
        model: model.name().into(),
        layers: depth,
        partitions: session.tiling().partitions.len(),
        rows_compared: session.graph().num_vertices() as usize,
        max_abs_err: max_err,
        mean_abs_err: (sum_err / sim_out.len() as f64) as f32,
        tol,
        pass: max_err < tol,
    })
}

/// Execute ONE layer through the PJRT artifact, partition by partition:
/// permute `x` into the shared tiling's vertex order, pack each
/// partition's single tile into the artifact's argument layout with this
/// layer's `weights`, execute, and un-permute the stitched output back
/// to original vertex order.
fn pjrt_layer(
    rt: &mut Runtime,
    model: ModelKind,
    shape: &TileShape,
    session: &Session,
    weights: &WeightStore,
    x: &[f32],
) -> Result<Vec<f32>, String> {
    let fi = shape.feat_in as usize;
    let fo = shape.feat_out as usize;
    let n = session.graph().num_vertices() as usize;
    let tiling: &Tiling = session.tiling();
    // permuted input (tiling may relabel; Reorder::None ⇒ identity, but
    // keep the general path)
    let mut x_tiled = vec![0.0f32; n * fi];
    for old in 0..n {
        let new = tiling.perm[old] as usize;
        x_tiled[new * fi..(new + 1) * fi].copy_from_slice(&x[old * fi..(old + 1) * fi]);
    }
    let mut oracle_tiled = vec![0.0f32; n * fo];
    for part in &tiling.partitions {
        if part.tiles.is_empty() {
            continue;
        }
        if part.tiles.len() != 1 {
            return Err("validation tiling must give one tile per partition".into());
        }
        let tile = &part.tiles[0];
        if tile.num_src() > shape.num_src || tile.num_edges() > shape.num_edges {
            return Err(format!(
                "tile exceeds artifact shape: src {} edges {}",
                tile.num_src(),
                tile.num_edges()
            ));
        }
        // pack x_src rows (tile source vertices, tiled ids)
        let mut xs = vec![0.0f32; tile.num_src() as usize * fi];
        for (i, &gv) in tile.src_vertices.iter().enumerate() {
            xs[i * fi..(i + 1) * fi]
                .copy_from_slice(&x_tiled[gv as usize * fi..(gv as usize + 1) * fi]);
        }
        let x_src = pack::features(&xs, shape.num_src as usize, fi);
        // pack x_dst rows (partition destinations)
        let mut xd = vec![0.0f32; part.num_dst() as usize * fi];
        for (i, gv) in (part.dst_start..part.dst_end).enumerate() {
            xd[i * fi..(i + 1) * fi]
                .copy_from_slice(&x_tiled[gv as usize * fi..(gv as usize + 1) * fi]);
        }
        let x_dst = pack::features(&xd, shape.num_dst as usize, fi);
        let (src, dst, valid) = pack::edges(&tile.edges, shape.num_edges as usize);
        let et = pack::etypes(
            tile.etypes.as_deref().unwrap_or(&[]),
            shape.num_edges as usize,
        );

        // weights in the artifact's argument order
        let w = |name: &str| -> Result<ArgValue, String> {
            let t = weights
                .tensors
                .iter()
                .find(|t| t.name == name)
                .ok_or_else(|| format!("weight {name} missing"))?;
            let shape_v = if t.count > 1 {
                vec![t.count as usize, t.rows as usize, t.cols as usize]
            } else if t.cols == 1 {
                vec![t.rows as usize]
            } else {
                vec![t.rows as usize, t.cols as usize]
            };
            Ok(ArgValue::F32 { data: t.data.clone(), shape: shape_v })
        };
        let zeros_bias = ArgValue::F32 { data: vec![0.0; fo], shape: vec![fo] };

        let args: Vec<ArgValue> = match model {
            ModelKind::Gcn => vec![x_src, src, dst, valid, w("w")?],
            ModelKind::Gat => vec![
                x_src, x_dst, src, dst, valid, w("w")?, w("a_src")?, w("a_dst")?,
            ],
            ModelKind::Sage => vec![
                x_src, x_dst, src, dst, valid, w("w_pool")?, zeros_bias,
                w("w_self")?, w("w_neigh")?,
            ],
            ModelKind::Ggnn => vec![
                x_src, x_dst, src, dst, valid, w("w_msg")?, w("w_z")?, w("u_z")?,
                w("w_r")?, w("u_r")?, w("w_h")?, w("u_h")?,
            ],
            ModelKind::Rgcn => vec![x_src, src, dst, et, valid, w("w_rel")?],
        };
        let out = rt
            .execute(model.name(), shape, &args)
            .map_err(|e| e.to_string())?;
        // rows 0..num_dst are the real partition rows
        for (i, gv) in (part.dst_start..part.dst_end).enumerate() {
            oracle_tiled[gv as usize * fo..(gv as usize + 1) * fo]
                .copy_from_slice(&out[i * fo..(i + 1) * fo]);
        }
    }
    // un-permute the oracle output
    let mut oracle = vec![0.0f32; n * fo];
    for new in 0..n {
        let old = tiling.inv_perm[new] as usize;
        oracle[old * fo..(old + 1) * fo]
            .copy_from_slice(&oracle_tiled[new * fo..(new + 1) * fo]);
    }
    Ok(oracle)
}

/// Validate every model that has an artifact at `shape` (depth 1).
pub fn validate_all(
    rt: &mut Runtime,
    shape: &TileShape,
    seed: u64,
) -> Result<Vec<ValidationReport>, String> {
    let mut reports = Vec::new();
    for m in ModelKind::ALL {
        reports.push(validate_model(rt, m, shape, seed)?);
    }
    Ok(reports)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(model: &str, feat_in: u32, hidden: Vec<u32>, feat_out: u32, layers: u32) -> RunConfig {
        RunConfig {
            model: model.into(),
            feat_in,
            feat_out,
            layers,
            hidden,
            ..RunConfig::default()
        }
    }

    #[test]
    fn valid_chains_resolve() {
        let spec = check_layer_chain(&run("gcn", 64, vec![32, 8], 16, 3)).unwrap();
        let dims: Vec<(u32, u32)> =
            spec.layers.iter().map(|l| (l.feat_in, l.feat_out)).collect();
        assert_eq!(dims, vec![(64, 32), (32, 8), (8, 16)]);
        // depth-1 and default hidden chains always resolve
        assert_eq!(check_layer_chain(&run("gat", 32, vec![], 16, 1)).unwrap().depth(), 1);
        assert_eq!(check_layer_chain(&run("sage", 32, vec![], 16, 4)).unwrap().depth(), 4);
    }

    #[test]
    fn wrong_hidden_count_is_a_shape_carrying_error() {
        let err = check_layer_chain(&run("gcn", 64, vec![32], 16, 3)).unwrap_err();
        assert!(err.contains("3-layer") && err.contains("64") && err.contains("16"), "{err}");
        let err = check_layer_chain(&run("gat", 8, vec![4, 4], 8, 2)).unwrap_err();
        assert!(err.contains("2") && err.contains("exactly 1"), "{err}");
    }

    #[test]
    fn ggnn_square_rule_enforced_per_layer() {
        let err = check_layer_chain(&run("ggnn", 16, vec![16, 32], 16, 3)).unwrap_err();
        assert!(err.contains("square") && err.contains("hidden[1]") && err.contains("32"), "{err}");
        // all-square chains pass, feat_out is coerced like depth 1
        let spec = check_layer_chain(&run("ggnn", 16, vec![16], 64, 2)).unwrap();
        assert!(spec.layers.iter().all(|l| (l.feat_in, l.feat_out) == (16, 16)));
    }

    #[test]
    fn unknown_model_is_rejected() {
        let err = check_layer_chain(&run("transformer", 16, vec![], 16, 1)).unwrap_err();
        assert!(err.contains("unknown model transformer"), "{err}");
    }

    #[test]
    fn serving_defaults_and_sane_configs_pass() {
        check_serving(&ServingConfig::default()).unwrap();
        check_serving(&ServingConfig {
            exec_threads: 4,
            max_batch: 8,
            max_wait_us: 200,
            queue_cap: 64,
            overflow: OverflowPolicy::Block,
            default_deadline_us: 50_000,
        })
        .unwrap();
        // block + small queue is fine when the timer can flush partials
        check_serving(&ServingConfig {
            max_batch: 8,
            max_wait_us: 100,
            queue_cap: 2,
            overflow: OverflowPolicy::Block,
            ..Default::default()
        })
        .unwrap();
    }

    #[test]
    fn zero_queue_cap_is_rejected() {
        let err =
            check_serving(&ServingConfig { queue_cap: 0, ..Default::default() }).unwrap_err();
        assert!(err.contains("queue_cap = 0"), "{err}");
    }

    #[test]
    fn timer_without_batching_is_rejected_with_values() {
        let serving = ServingConfig { max_wait_us: 500, max_batch: 1, ..Default::default() };
        let err = check_serving(&serving).unwrap_err();
        assert!(err.contains("500") && err.contains("max_batch = 1"), "{err}");
    }

    #[test]
    fn blocking_overflow_deadlock_shape_is_rejected() {
        // cap 2 < batch 8, no timer, block: the group can never fill
        let serving = ServingConfig {
            max_batch: 8,
            queue_cap: 2,
            overflow: OverflowPolicy::Block,
            ..Default::default()
        };
        let err = check_serving(&serving).unwrap_err();
        assert!(err.contains("max_batch = 8") && err.contains("queue_cap = 2"), "{err}");
        assert!(err.contains("deadlock"), "{err}");
    }

    #[test]
    fn sub_floor_default_deadline_is_rejected() {
        let serving = ServingConfig { default_deadline_us: 10, ..Default::default() };
        let err = check_serving(&serving).unwrap_err();
        assert!(err.contains("10") && err.contains("cold"), "{err}");
        // at/above the floor passes
        check_serving(&ServingConfig {
            default_deadline_us: COLD_COMPILE_FLOOR_US,
            ..Default::default()
        })
        .unwrap();
    }
}
