//! GNN model zoo (paper §8.1): GCN, GAT, SAGE-maxpool, GGNN, R-GCN.
//!
//! Models are defined in their *naive* tensor-level form — the direct
//! transcription of the DGL/PyG code a user writes (paper Fig 5), with
//! per-edge operations where the textbook formulation puts them. The
//! compiler's E2V pass then hoists what can be hoisted; Fig 12 measures
//! exactly that delta (naive vs compiler-optimized schedules).
//!
//! GAT softmax note: under tiled execution a per-destination softmax
//! needs all tiles of a partition before normalizing. We use the exact
//! algebraic rewrite out_j = (Σ exp(e_ij)·z_i) / (Σ exp(e_ij)) — both
//! sums are tile-accumulable gathers, and the division happens once per
//! partition in the dStream (DESIGN.md §6). Numerics match the
//! unstabilized softmax; the AOT oracle uses the max-stabilized form and
//! the integration tests compare under a small-magnitude tolerance.

use crate::ir::{FDim, ModelGraph, NodeId};
use crate::isa::{ElwBinary, ElwUnary};
use crate::util::Rng;

/// Number of R-GCN relation types (paper §8.1 sets 3).
pub const NUM_RELATIONS: u8 = 3;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelKind {
    Gcn,
    Gat,
    Sage,
    Ggnn,
    Rgcn,
}

impl ModelKind {
    pub const ALL: [ModelKind; 5] = [
        ModelKind::Gcn,
        ModelKind::Gat,
        ModelKind::Sage,
        ModelKind::Ggnn,
        ModelKind::Rgcn,
    ];

    pub fn name(self) -> &'static str {
        match self {
            ModelKind::Gcn => "gcn",
            ModelKind::Gat => "gat",
            ModelKind::Sage => "sage",
            ModelKind::Ggnn => "ggnn",
            ModelKind::Rgcn => "rgcn",
        }
    }

    /// Case-insensitive name lookup. Allocation-free: this sits on the
    /// serving hot parse path (`PlanKey` construction per submit).
    pub fn parse(s: &str) -> Option<ModelKind> {
        Self::ALL.iter().copied().find(|m| m.name().eq_ignore_ascii_case(s))
    }

    /// Whether the model reads destination-vertex embeddings (GAT's
    /// attention, SAGE's self path, GGNN's GRU state). Models that don't
    /// skip LD.DST entirely — the Fig 11 note about GAT/SAGE/GGNN
    /// accessing destination embeddings "which cannot be reduced".
    pub fn uses_dst_input(self) -> bool {
        matches!(self, ModelKind::Gat | ModelKind::Sage | ModelKind::Ggnn)
    }

    /// GGNN's GRU needs feat_in == feat_out.
    pub fn requires_square(self) -> bool {
        matches!(self, ModelKind::Ggnn)
    }

    /// Whether tiles must carry per-edge relation types.
    pub fn uses_etypes(self) -> bool {
        matches!(self, ModelKind::Rgcn)
    }

    /// Build the naive tensor-level DAG — the depth-1, linear-output
    /// special case of [`ModelKind::build_layer`].
    pub fn build(self) -> ModelGraph {
        self.build_layer(None)
    }

    /// Build one pipeline layer's tensor-level DAG: the model body plus
    /// an optional trailing activation. Hidden layers of a multi-layer
    /// [`ModelSpec`] are activated (ReLU), the final layer is linear —
    /// with `None` this is byte-identical to the pre-pipeline
    /// [`ModelKind::build`] DAG.
    pub fn build_layer(self, activation: Option<ElwUnary>) -> ModelGraph {
        let mut g = ModelGraph::new(self.name());
        let h = match self {
            ModelKind::Gcn => gcn_body(&mut g),
            ModelKind::Gat => gat_body(&mut g),
            ModelKind::Sage => sage_body(&mut g),
            ModelKind::Ggnn => ggnn_body(&mut g),
            ModelKind::Rgcn => rgcn_body(&mut g),
        };
        let h = match activation {
            Some(op) => g.unary(op, h),
            None => h,
        };
        g.output_v(h, "h");
        g
    }
}

/// One layer of a stacked GNN pipeline: the feature dims the compiler
/// resolves `FeatIn`/`FeatOut` against for this layer's program, plus
/// the trailing activation (`None` = linear).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerSpec {
    pub feat_in: u32,
    pub feat_out: u32,
    /// Appended to the layer body by [`ModelKind::build_layer`]; hidden
    /// layers get `Some(Relu)`, the final layer `None`.
    pub activation: Option<ElwUnary>,
}

/// A multi-layer GNN model: one [`ModelKind`] body stacked `depth`
/// times, layer *l*'s output embedding feeding layer *l+1*'s input.
/// This is the unit of compilation (paper Fig 5 loops `for each layer`):
/// every layer shares one graph tiling, only the per-layer programs and
/// weights differ.
///
/// # Examples
///
/// ```
/// use zipper::models::{ModelKind, ModelSpec};
///
/// // 3-layer GCN: 64 → 32 → 32 → 16, ReLU between layers, final linear
/// let spec = ModelSpec::new(ModelKind::Gcn, 64, &[32, 32], 16, 3).unwrap();
/// assert_eq!(spec.depth(), 3);
/// assert_eq!((spec.feat_in(), spec.feat_out()), (64, 16));
/// assert!(spec.layers[0].activation.is_some());
/// assert!(spec.layers[2].activation.is_none());
///
/// // inconsistent hidden chains are shape-carrying errors
/// let err = ModelSpec::new(ModelKind::Gcn, 64, &[32], 16, 3).unwrap_err();
/// assert!(err.contains("3-layer"));
/// ```
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelSpec {
    pub kind: ModelKind,
    pub layers: Vec<LayerSpec>,
}

impl ModelSpec {
    /// Resolve a depth + hidden-width chain into per-layer specs.
    ///
    /// * `depth` is clamped to ≥ 1; `hidden` must list exactly
    ///   `depth − 1` widths, or be empty (every hidden width defaults to
    ///   `feat_out`).
    /// * Models with [`ModelKind::requires_square`] (GGNN's GRU) keep
    ///   every layer at `feat_in × feat_in` — `feat_out` is coerced as
    ///   in the single-layer path, but an explicit conflicting hidden
    ///   width is rejected with the offending shapes.
    pub fn new(
        kind: ModelKind,
        feat_in: u32,
        hidden: &[u32],
        feat_out: u32,
        depth: u32,
    ) -> Result<ModelSpec, String> {
        let depth = depth.max(1) as usize;
        if let Some((i, &h)) = hidden.iter().enumerate().find(|&(_, &h)| h == 0) {
            return Err(format!("{}: hidden[{i}] = {h}, widths must be ≥ 1", kind.name()));
        }
        if !hidden.is_empty() && hidden.len() != depth - 1 {
            return Err(format!(
                "{}: {} hidden width(s) given, but a {depth}-layer pipeline \
                 {feat_in} → … → {feat_out} needs exactly {}",
                kind.name(),
                hidden.len(),
                depth - 1,
            ));
        }
        let widths: Vec<u32> = if kind.requires_square() {
            if let Some((i, &h)) = hidden.iter().enumerate().find(|&(_, &h)| h != feat_in) {
                return Err(format!(
                    "{}: hidden[{i}] = {h} conflicts with feat_in = {feat_in}; the GRU \
                     update needs square layers, so every width of a {}-layer {} \
                     pipeline must equal feat_in",
                    kind.name(),
                    depth,
                    kind.name(),
                ));
            }
            vec![feat_in; depth + 1]
        } else if hidden.is_empty() {
            let mut w = vec![feat_in];
            w.resize(depth, feat_out);
            w.push(feat_out);
            w
        } else {
            // hidden.len() == depth - 1, checked above
            let mut w = Vec::with_capacity(depth + 1);
            w.push(feat_in);
            w.extend_from_slice(hidden);
            w.push(feat_out);
            w
        };
        let layers = (0..depth)
            .map(|l| LayerSpec {
                feat_in: widths[l],
                feat_out: widths[l + 1],
                activation: if l + 1 < depth { Some(ElwUnary::Relu) } else { None },
            })
            .collect();
        Ok(ModelSpec { kind, layers })
    }

    /// The depth-1 special case (always valid; no hidden widths).
    pub fn single(kind: ModelKind, feat_in: u32, feat_out: u32) -> ModelSpec {
        Self::new(kind, feat_in, &[], feat_out, 1).expect("depth-1 specs are always valid")
    }

    pub fn depth(&self) -> usize {
        self.layers.len()
    }

    /// First layer's input embedding width.
    pub fn feat_in(&self) -> u32 {
        self.layers[0].feat_in
    }

    /// Final layer's output embedding width.
    pub fn feat_out(&self) -> u32 {
        self.layers[self.layers.len() - 1].feat_out
    }

    /// Build layer `l`'s tensor-level DAG (body + activation).
    pub fn build_layer(&self, l: usize) -> ModelGraph {
        self.kind.build_layer(self.layers[l].activation)
    }

    /// Per-layer weight seed: layer 0 uses the run seed verbatim (the
    /// depth-1 path is bit-exact with the pre-pipeline behavior), deeper
    /// layers decorrelate so stacked layers don't share weights.
    pub fn layer_seed(seed: u64, layer: usize) -> u64 {
        seed ^ (layer as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)
    }
}

/// GCN (paper Fig 1a): SpMM (Scatter+Gather) then GEMM.
pub fn gcn() -> ModelGraph {
    ModelKind::Gcn.build()
}

fn gcn_body(g: &mut ModelGraph) -> NodeId {
    let x = g.input_v("x");
    let w = g.weight("w", FDim::In, FDim::Out);
    let ex = g.scatter_out(x);
    let agg = g.gather_sum(ex);
    g.gemm(agg, w)
}

/// GAT single head (paper Fig 1b), naive: per-edge GEMMs before E2V.
pub fn gat() -> ModelGraph {
    ModelKind::Gat.build()
}

fn gat_body(g: &mut ModelGraph) -> NodeId {
    let x = g.input_v("x");
    let w = g.weight("w", FDim::In, FDim::Out);
    let a_s = g.weight("a_src", FDim::Out, FDim::One);
    let a_d = g.weight("a_dst", FDim::Out, FDim::One);
    let ex_s = g.scatter_out(x);
    let ex_d = g.scatter_in(x);
    let z_es = g.gemm(ex_s, w); // per-edge transform (E2V hoists)
    let z_ed = g.gemm(ex_d, w);
    let s_s = g.gemv(z_es, a_s);
    let s_d = g.gemv(z_ed, a_d);
    let e = g.binary(ElwBinary::Add, s_s, s_d);
    let e = g.unary(ElwUnary::LeakyRelu, e);
    let e = g.unary(ElwUnary::Exp, e);
    let num_e = g.bcast(ElwBinary::Mul, z_es, e);
    let num = g.gather_sum(num_e);
    let den = g.gather_sum(e);
    // zero-guarded normalize: empty destinations yield 0, not 0/0
    let den_r = g.unary(ElwUnary::Recip0, den);
    g.bcast(ElwBinary::Mul, num, den_r)
}

/// GraphSAGE-maxpool (paper §8.1), naive: pool transform on edges.
pub fn sage() -> ModelGraph {
    ModelKind::Sage.build()
}

fn sage_body(g: &mut ModelGraph) -> NodeId {
    let x = g.input_v("x");
    let w_pool = g.weight("w_pool", FDim::In, FDim::Out);
    let w_self = g.weight("w_self", FDim::In, FDim::Out);
    let w_neigh = g.weight("w_neigh", FDim::Out, FDim::Out);
    let ex = g.scatter_out(x);
    let pe = g.gemm(ex, w_pool); // per-edge transform (E2V hoists)
    let pe = g.unary(ElwUnary::Relu, pe);
    let h_n = g.gather_max(pe);
    let hn_t = g.gemm(h_n, w_neigh);
    let self_t = g.gemm(x, w_self);
    g.binary(ElwBinary::Add, self_t, hn_t)
}

/// GGNN (paper §8.1): gathered message + GRU in explicit GEMM/ELW ops.
pub fn ggnn() -> ModelGraph {
    ModelKind::Ggnn.build()
}

fn ggnn_body(g: &mut ModelGraph) -> NodeId {
    let x = g.input_v("x");
    let w_msg = g.weight("w_msg", FDim::In, FDim::In);
    let w_z = g.weight("w_z", FDim::In, FDim::In);
    let u_z = g.weight("u_z", FDim::In, FDim::In);
    let w_r = g.weight("w_r", FDim::In, FDim::In);
    let u_r = g.weight("u_r", FDim::In, FDim::In);
    let w_h = g.weight("w_h", FDim::In, FDim::In);
    let u_h = g.weight("u_h", FDim::In, FDim::In);
    let ex = g.scatter_out(x);
    let me = g.gemm(ex, w_msg); // per-edge message transform (E2V hoists)
    let a = g.gather_sum(me);
    // GRU: z = σ(aW_z + xU_z); r = σ(aW_r + xU_r);
    //      h̃ = tanh(aW_h + (r⊙x)U_h); h' = (1−z)⊙x + z⊙h̃
    let az = g.gemm(a, w_z);
    let xz = g.gemm(x, u_z);
    let zi = g.binary(ElwBinary::Add, az, xz);
    let z = g.unary(ElwUnary::Sigmoid, zi);
    let ar = g.gemm(a, w_r);
    let xr = g.gemm(x, u_r);
    let ri = g.binary(ElwBinary::Add, ar, xr);
    let r = g.unary(ElwUnary::Sigmoid, ri);
    let rx = g.binary(ElwBinary::Mul, r, x);
    let ah = g.gemm(a, w_h);
    let rxh = g.gemm(rx, u_h);
    let ci = g.binary(ElwBinary::Add, ah, rxh);
    let h_t = g.unary(ElwUnary::Tanh, ci);
    let zc = g.unary(ElwUnary::OneMinus, z);
    let keep = g.binary(ElwBinary::Mul, zc, x);
    let new = g.binary(ElwBinary::Mul, z, h_t);
    g.binary(ElwBinary::Add, keep, new)
}

/// R-GCN with NUM_RELATIONS edge types: index-guided BMM stays per-edge.
pub fn rgcn() -> ModelGraph {
    ModelKind::Rgcn.build()
}

fn rgcn_body(g: &mut ModelGraph) -> NodeId {
    let x = g.input_v("x");
    let wset = g.weight_set("w_rel", FDim::In, FDim::Out, NUM_RELATIONS);
    let ex = g.scatter_out(x);
    let te = g.bmm_by_type(ex, wset); // genuinely per-edge; E2V leaves it
    g.gather_sum(te)
}

/// Deterministic weight synthesis for functional execution: one f32
/// matrix per `Weight` node, 0.1-scaled normal entries, keyed by the
/// model name + weight name so Rust and bench runs agree.
pub struct WeightStore {
    /// (rows, cols, data) per WeightId in declaration order; stacked
    /// weight sets hold `count` matrices back-to-back.
    pub tensors: Vec<WeightTensor>,
}

pub struct WeightTensor {
    pub name: &'static str,
    pub rows: u32,
    pub cols: u32,
    pub count: u8,
    /// count × rows × cols, row-major per matrix.
    pub data: Vec<f32>,
}

impl WeightStore {
    pub fn synthesize(model: &ModelGraph, feat_in: u32, feat_out: u32, seed: u64) -> Self {
        let mut tensors = Vec::new();
        for n in &model.nodes {
            if let crate::ir::Op::Weight { name, rows, cols, count } = n.op {
                let r = dim(rows, feat_in, feat_out);
                let c = dim(cols, feat_in, feat_out);
                let mut rng = Rng::new(seed ^ fxhash(name));
                let len = count as usize * r as usize * c as usize;
                let data = (0..len).map(|_| (rng.normal() * 0.1) as f32).collect();
                tensors.push(WeightTensor { name, rows: r, cols: c, count, data });
            }
        }
        WeightStore { tensors }
    }

    /// Round-trip every weight through the storage dtype in place
    /// (`sim::tensor::quantize_slice`): the resident f32 image becomes
    /// exactly what 16-bit storage plus convert-at-load would yield.
    /// No-op for [`crate::config::StorageDtype::F32`]. Called once at
    /// plan build (`plan::ExecPlan::from_graph`).
    pub fn quantize(&mut self, dtype: crate::config::StorageDtype) {
        for t in &mut self.tensors {
            crate::sim::tensor::quantize_slice(dtype, &mut t.data);
        }
    }
}

fn dim(d: FDim, feat_in: u32, feat_out: u32) -> u32 {
    match d {
        FDim::In => feat_in,
        FDim::Out => feat_out,
        FDim::One => 1,
    }
}

fn fxhash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::e2v;

    #[test]
    fn all_models_are_well_typed() {
        for m in ModelKind::ALL {
            let g = m.build();
            g.spans().unwrap_or_else(|e| panic!("{}: {e}", m.name()));
        }
    }

    #[test]
    fn op_mix_matches_paper_taxonomy() {
        // GCN: 1 GEMM, 2 GOPs, 0 ELW (paper Fig 1a)
        let mix = gcn().op_mix();
        assert_eq!((mix.gemm, mix.gop, mix.elw), (1, 2, 0));
        // GAT mixes all three classes heavily (paper Fig 1b)
        let mix = gat().op_mix();
        assert!(mix.gemm >= 2 && mix.gop >= 4 && mix.elw >= 4);
    }

    #[test]
    fn e2v_improves_gat_and_sage_not_gcn_rgcn() {
        for (m, expect_hoist) in [
            (ModelKind::Gcn, false),
            (ModelKind::Gat, true),
            (ModelKind::Sage, true),
            (ModelKind::Ggnn, true),
            (ModelKind::Rgcn, false),
        ] {
            let (_, stats) = e2v::optimize(&m.build());
            assert_eq!(stats.hoisted > 0, expect_hoist, "{}", m.name());
        }
    }

    #[test]
    fn weight_store_shapes() {
        let ws = WeightStore::synthesize(&rgcn(), 64, 32, 1);
        assert_eq!(ws.tensors.len(), 1);
        let t = &ws.tensors[0];
        assert_eq!((t.rows, t.cols, t.count), (64, 32, NUM_RELATIONS));
        assert_eq!(t.data.len(), 3 * 64 * 32);
    }

    #[test]
    fn weight_store_deterministic_and_name_keyed() {
        let a = WeightStore::synthesize(&gat(), 16, 16, 7);
        let b = WeightStore::synthesize(&gat(), 16, 16, 7);
        assert_eq!(a.tensors[0].data, b.tensors[0].data);
        // different weights differ
        assert_ne!(a.tensors[0].data, a.tensors[1].data[..a.tensors[0].data.len().min(a.tensors[1].data.len())].to_vec());
    }

    #[test]
    fn model_kind_parse() {
        assert_eq!(ModelKind::parse("GAT"), Some(ModelKind::Gat));
        assert_eq!(ModelKind::parse("Gcn"), Some(ModelKind::Gcn));
        assert_eq!(ModelKind::parse("nope"), None);
    }

    #[test]
    fn build_layer_none_is_the_classic_dag() {
        for m in ModelKind::ALL {
            assert_eq!(m.build().nodes, m.build_layer(None).nodes, "{}", m.name());
        }
    }

    #[test]
    fn build_layer_appends_exactly_one_activation() {
        for m in ModelKind::ALL {
            let base = m.build();
            let act = m.build_layer(Some(ElwUnary::Relu));
            assert_eq!(act.nodes.len(), base.nodes.len() + 1, "{}", m.name());
            act.spans().unwrap_or_else(|e| panic!("{}: {e}", m.name()));
            // the activation feeds the output
            let relu_id = act
                .nodes
                .iter()
                .find_map(|n| match n.op {
                    crate::ir::Op::ElwU { op: ElwUnary::Relu, .. } => Some(n.id),
                    _ => None,
                })
                .expect("activated layer has a ReLU");
            assert!(act
                .nodes
                .iter()
                .any(|n| matches!(n.op, crate::ir::Op::OutputV { x, .. } if x == relu_id)));
        }
    }

    #[test]
    fn model_spec_resolves_width_chains() {
        let s = ModelSpec::new(ModelKind::Gcn, 64, &[], 16, 3).unwrap();
        let dims: Vec<(u32, u32)> = s.layers.iter().map(|l| (l.feat_in, l.feat_out)).collect();
        assert_eq!(dims, vec![(64, 16), (16, 16), (16, 16)]);
        let s = ModelSpec::new(ModelKind::Gat, 64, &[32, 8], 16, 3).unwrap();
        let dims: Vec<(u32, u32)> = s.layers.iter().map(|l| (l.feat_in, l.feat_out)).collect();
        assert_eq!(dims, vec![(64, 32), (32, 8), (8, 16)]);
        assert_eq!(s.layers[0].activation, Some(ElwUnary::Relu));
        assert_eq!(s.layers[2].activation, None);
        assert_eq!(ModelSpec::single(ModelKind::Gcn, 8, 4).depth(), 1);
    }

    #[test]
    fn model_spec_rejects_bad_chains_with_shapes() {
        let err = ModelSpec::new(ModelKind::Gcn, 64, &[32], 16, 3).unwrap_err();
        assert!(err.contains("3-layer") && err.contains("64") && err.contains("16"), "{err}");
        // GGNN: a wrong-COUNT chain is rejected like any other model…
        let err = ModelSpec::new(ModelKind::Ggnn, 16, &[32], 16, 3).unwrap_err();
        assert!(err.contains("3-layer") && err.contains("exactly 2"), "{err}");
        // …and a right-count chain still enforces the square rule
        let err = ModelSpec::new(ModelKind::Ggnn, 16, &[32, 16], 16, 3).unwrap_err();
        assert!(err.contains("square") && err.contains("32") && err.contains("16"), "{err}");
        let err = ModelSpec::new(ModelKind::Gcn, 8, &[0], 8, 2).unwrap_err();
        assert!(err.contains("≥ 1"), "{err}");
        // GGNN feat_out is coerced (single-layer compatibility), not an error
        let s = ModelSpec::new(ModelKind::Ggnn, 16, &[], 32, 2).unwrap();
        assert!(s.layers.iter().all(|l| (l.feat_in, l.feat_out) == (16, 16)));
    }

    #[test]
    fn quantize_roundtrips_weights_in_place() {
        use crate::config::StorageDtype;
        let mut ws = WeightStore::synthesize(&gcn(), 16, 16, 7);
        let full = ws.tensors[0].data.clone();
        ws.quantize(StorageDtype::F32);
        assert_eq!(ws.tensors[0].data, full, "f32 quantize must be a no-op");
        ws.quantize(StorageDtype::Bf16);
        let q = &ws.tensors[0].data;
        assert_ne!(q, &full, "bf16 quantize must actually reduce precision");
        for (&qv, &fv) in q.iter().zip(&full) {
            // bf16 keeps 8 mantissa bits: relative error ≤ 2^-8
            assert!((qv - fv).abs() <= fv.abs() / 256.0 + 1e-30, "{qv} vs {fv}");
        }
        // idempotent: already-quantized values are fixed points
        let once = ws.tensors[0].data.clone();
        ws.quantize(StorageDtype::Bf16);
        assert_eq!(ws.tensors[0].data, once);
    }

    #[test]
    fn layer_seeds_distinct_and_layer0_is_the_run_seed() {
        assert_eq!(ModelSpec::layer_seed(42, 0), 42);
        assert_ne!(ModelSpec::layer_seed(42, 1), 42);
        assert_ne!(ModelSpec::layer_seed(42, 1), ModelSpec::layer_seed(42, 2));
        // distinct weights per layer
        let g = gcn();
        let a = WeightStore::synthesize(&g, 16, 16, ModelSpec::layer_seed(7, 0));
        let b = WeightStore::synthesize(&g, 16, 16, ModelSpec::layer_seed(7, 1));
        assert_ne!(a.tensors[0].data, b.tensors[0].data);
    }
}
