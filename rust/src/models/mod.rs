//! GNN model zoo (paper §8.1): GCN, GAT, SAGE-maxpool, GGNN, R-GCN.
//!
//! Models are defined in their *naive* tensor-level form — the direct
//! transcription of the DGL/PyG code a user writes (paper Fig 5), with
//! per-edge operations where the textbook formulation puts them. The
//! compiler's E2V pass then hoists what can be hoisted; Fig 12 measures
//! exactly that delta (naive vs compiler-optimized schedules).
//!
//! GAT softmax note: under tiled execution a per-destination softmax
//! needs all tiles of a partition before normalizing. We use the exact
//! algebraic rewrite out_j = (Σ exp(e_ij)·z_i) / (Σ exp(e_ij)) — both
//! sums are tile-accumulable gathers, and the division happens once per
//! partition in the dStream (DESIGN.md §6). Numerics match the
//! unstabilized softmax; the AOT oracle uses the max-stabilized form and
//! the integration tests compare under a small-magnitude tolerance.

use crate::ir::{FDim, ModelGraph};
use crate::isa::{ElwBinary, ElwUnary};
use crate::util::Rng;

/// Number of R-GCN relation types (paper §8.1 sets 3).
pub const NUM_RELATIONS: u8 = 3;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelKind {
    Gcn,
    Gat,
    Sage,
    Ggnn,
    Rgcn,
}

impl ModelKind {
    pub const ALL: [ModelKind; 5] = [
        ModelKind::Gcn,
        ModelKind::Gat,
        ModelKind::Sage,
        ModelKind::Ggnn,
        ModelKind::Rgcn,
    ];

    pub fn name(self) -> &'static str {
        match self {
            ModelKind::Gcn => "gcn",
            ModelKind::Gat => "gat",
            ModelKind::Sage => "sage",
            ModelKind::Ggnn => "ggnn",
            ModelKind::Rgcn => "rgcn",
        }
    }

    pub fn parse(s: &str) -> Option<ModelKind> {
        Self::ALL.iter().copied().find(|m| m.name() == s.to_ascii_lowercase())
    }

    /// Whether the model reads destination-vertex embeddings (GAT's
    /// attention, SAGE's self path, GGNN's GRU state). Models that don't
    /// skip LD.DST entirely — the Fig 11 note about GAT/SAGE/GGNN
    /// accessing destination embeddings "which cannot be reduced".
    pub fn uses_dst_input(self) -> bool {
        matches!(self, ModelKind::Gat | ModelKind::Sage | ModelKind::Ggnn)
    }

    /// GGNN's GRU needs feat_in == feat_out.
    pub fn requires_square(self) -> bool {
        matches!(self, ModelKind::Ggnn)
    }

    /// Whether tiles must carry per-edge relation types.
    pub fn uses_etypes(self) -> bool {
        matches!(self, ModelKind::Rgcn)
    }

    /// Build the naive tensor-level DAG.
    pub fn build(self) -> ModelGraph {
        match self {
            ModelKind::Gcn => gcn(),
            ModelKind::Gat => gat(),
            ModelKind::Sage => sage(),
            ModelKind::Ggnn => ggnn(),
            ModelKind::Rgcn => rgcn(),
        }
    }
}

/// GCN (paper Fig 1a): SpMM (Scatter+Gather) then GEMM.
pub fn gcn() -> ModelGraph {
    let mut g = ModelGraph::new("gcn");
    let x = g.input_v("x");
    let w = g.weight("w", FDim::In, FDim::Out);
    let ex = g.scatter_out(x);
    let agg = g.gather_sum(ex);
    let h = g.gemm(agg, w);
    g.output_v(h, "h");
    g
}

/// GAT single head (paper Fig 1b), naive: per-edge GEMMs before E2V.
pub fn gat() -> ModelGraph {
    let mut g = ModelGraph::new("gat");
    let x = g.input_v("x");
    let w = g.weight("w", FDim::In, FDim::Out);
    let a_s = g.weight("a_src", FDim::Out, FDim::One);
    let a_d = g.weight("a_dst", FDim::Out, FDim::One);
    let ex_s = g.scatter_out(x);
    let ex_d = g.scatter_in(x);
    let z_es = g.gemm(ex_s, w); // per-edge transform (E2V hoists)
    let z_ed = g.gemm(ex_d, w);
    let s_s = g.gemv(z_es, a_s);
    let s_d = g.gemv(z_ed, a_d);
    let e = g.binary(ElwBinary::Add, s_s, s_d);
    let e = g.unary(ElwUnary::LeakyRelu, e);
    let e = g.unary(ElwUnary::Exp, e);
    let num_e = g.bcast(ElwBinary::Mul, z_es, e);
    let num = g.gather_sum(num_e);
    let den = g.gather_sum(e);
    // zero-guarded normalize: empty destinations yield 0, not 0/0
    let den_r = g.unary(ElwUnary::Recip0, den);
    let out = g.bcast(ElwBinary::Mul, num, den_r);
    g.output_v(out, "h");
    g
}

/// GraphSAGE-maxpool (paper §8.1), naive: pool transform on edges.
pub fn sage() -> ModelGraph {
    let mut g = ModelGraph::new("sage");
    let x = g.input_v("x");
    let w_pool = g.weight("w_pool", FDim::In, FDim::Out);
    let w_self = g.weight("w_self", FDim::In, FDim::Out);
    let w_neigh = g.weight("w_neigh", FDim::Out, FDim::Out);
    let ex = g.scatter_out(x);
    let pe = g.gemm(ex, w_pool); // per-edge transform (E2V hoists)
    let pe = g.unary(ElwUnary::Relu, pe);
    let h_n = g.gather_max(pe);
    let hn_t = g.gemm(h_n, w_neigh);
    let self_t = g.gemm(x, w_self);
    let out = g.binary(ElwBinary::Add, self_t, hn_t);
    g.output_v(out, "h");
    g
}

/// GGNN (paper §8.1): gathered message + GRU in explicit GEMM/ELW ops.
pub fn ggnn() -> ModelGraph {
    let mut g = ModelGraph::new("ggnn");
    let x = g.input_v("x");
    let w_msg = g.weight("w_msg", FDim::In, FDim::In);
    let w_z = g.weight("w_z", FDim::In, FDim::In);
    let u_z = g.weight("u_z", FDim::In, FDim::In);
    let w_r = g.weight("w_r", FDim::In, FDim::In);
    let u_r = g.weight("u_r", FDim::In, FDim::In);
    let w_h = g.weight("w_h", FDim::In, FDim::In);
    let u_h = g.weight("u_h", FDim::In, FDim::In);
    let ex = g.scatter_out(x);
    let me = g.gemm(ex, w_msg); // per-edge message transform (E2V hoists)
    let a = g.gather_sum(me);
    // GRU: z = σ(aW_z + xU_z); r = σ(aW_r + xU_r);
    //      h̃ = tanh(aW_h + (r⊙x)U_h); h' = (1−z)⊙x + z⊙h̃
    let az = g.gemm(a, w_z);
    let xz = g.gemm(x, u_z);
    let zi = g.binary(ElwBinary::Add, az, xz);
    let z = g.unary(ElwUnary::Sigmoid, zi);
    let ar = g.gemm(a, w_r);
    let xr = g.gemm(x, u_r);
    let ri = g.binary(ElwBinary::Add, ar, xr);
    let r = g.unary(ElwUnary::Sigmoid, ri);
    let rx = g.binary(ElwBinary::Mul, r, x);
    let ah = g.gemm(a, w_h);
    let rxh = g.gemm(rx, u_h);
    let ci = g.binary(ElwBinary::Add, ah, rxh);
    let h_t = g.unary(ElwUnary::Tanh, ci);
    let zc = g.unary(ElwUnary::OneMinus, z);
    let keep = g.binary(ElwBinary::Mul, zc, x);
    let new = g.binary(ElwBinary::Mul, z, h_t);
    let out = g.binary(ElwBinary::Add, keep, new);
    g.output_v(out, "h");
    g
}

/// R-GCN with NUM_RELATIONS edge types: index-guided BMM stays per-edge.
pub fn rgcn() -> ModelGraph {
    let mut g = ModelGraph::new("rgcn");
    let x = g.input_v("x");
    let wset = g.weight_set("w_rel", FDim::In, FDim::Out, NUM_RELATIONS);
    let ex = g.scatter_out(x);
    let te = g.bmm_by_type(ex, wset); // genuinely per-edge; E2V leaves it
    let agg = g.gather_sum(te);
    g.output_v(agg, "h");
    g
}

/// Deterministic weight synthesis for functional execution: one f32
/// matrix per `Weight` node, 0.1-scaled normal entries, keyed by the
/// model name + weight name so Rust and bench runs agree.
pub struct WeightStore {
    /// (rows, cols, data) per WeightId in declaration order; stacked
    /// weight sets hold `count` matrices back-to-back.
    pub tensors: Vec<WeightTensor>,
}

pub struct WeightTensor {
    pub name: &'static str,
    pub rows: u32,
    pub cols: u32,
    pub count: u8,
    /// count × rows × cols, row-major per matrix.
    pub data: Vec<f32>,
}

impl WeightStore {
    pub fn synthesize(model: &ModelGraph, feat_in: u32, feat_out: u32, seed: u64) -> Self {
        let mut tensors = Vec::new();
        for n in &model.nodes {
            if let crate::ir::Op::Weight { name, rows, cols, count } = n.op {
                let r = dim(rows, feat_in, feat_out);
                let c = dim(cols, feat_in, feat_out);
                let mut rng = Rng::new(seed ^ fxhash(name));
                let len = count as usize * r as usize * c as usize;
                let data = (0..len).map(|_| (rng.normal() * 0.1) as f32).collect();
                tensors.push(WeightTensor { name, rows: r, cols: c, count, data });
            }
        }
        WeightStore { tensors }
    }
}

fn dim(d: FDim, feat_in: u32, feat_out: u32) -> u32 {
    match d {
        FDim::In => feat_in,
        FDim::Out => feat_out,
        FDim::One => 1,
    }
}

fn fxhash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::e2v;

    #[test]
    fn all_models_are_well_typed() {
        for m in ModelKind::ALL {
            let g = m.build();
            g.spans().unwrap_or_else(|e| panic!("{}: {e}", m.name()));
        }
    }

    #[test]
    fn op_mix_matches_paper_taxonomy() {
        // GCN: 1 GEMM, 2 GOPs, 0 ELW (paper Fig 1a)
        let mix = gcn().op_mix();
        assert_eq!((mix.gemm, mix.gop, mix.elw), (1, 2, 0));
        // GAT mixes all three classes heavily (paper Fig 1b)
        let mix = gat().op_mix();
        assert!(mix.gemm >= 2 && mix.gop >= 4 && mix.elw >= 4);
    }

    #[test]
    fn e2v_improves_gat_and_sage_not_gcn_rgcn() {
        for (m, expect_hoist) in [
            (ModelKind::Gcn, false),
            (ModelKind::Gat, true),
            (ModelKind::Sage, true),
            (ModelKind::Ggnn, true),
            (ModelKind::Rgcn, false),
        ] {
            let (_, stats) = e2v::optimize(&m.build());
            assert_eq!(stats.hoisted > 0, expect_hoist, "{}", m.name());
        }
    }

    #[test]
    fn weight_store_shapes() {
        let ws = WeightStore::synthesize(&rgcn(), 64, 32, 1);
        assert_eq!(ws.tensors.len(), 1);
        let t = &ws.tensors[0];
        assert_eq!((t.rows, t.cols, t.count), (64, 32, NUM_RELATIONS));
        assert_eq!(t.data.len(), 3 * 64 * 32);
    }

    #[test]
    fn weight_store_deterministic_and_name_keyed() {
        let a = WeightStore::synthesize(&gat(), 16, 16, 7);
        let b = WeightStore::synthesize(&gat(), 16, 16, 7);
        assert_eq!(a.tensors[0].data, b.tensors[0].data);
        // different weights differ
        assert_ne!(a.tensors[0].data, a.tensors[1].data[..a.tensors[0].data.len().min(a.tensors[1].data.len())].to_vec());
    }

    #[test]
    fn model_kind_parse() {
        assert_eq!(ModelKind::parse("GAT"), Some(ModelKind::Gat));
        assert_eq!(ModelKind::parse("nope"), None);
    }
}
