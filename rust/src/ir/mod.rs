//! Graph-native GNN IR (paper §6, Table 1).
//!
//! A GNN model enters as a *tensor-level DAG* — the shape a user writes in
//! DGL/PyG, where vertex and edge sets are whole tensors and GOPs
//! (scatter/gather) move data between them. The IR machinery:
//!
//!   * type-checks the DAG (vertex/edge span consistency — the "tensor
//!     types are changed only by the GOPs" invariant of paper §6.1),
//!   * runs the **E2V (edge-to-vertex) optimization** (§6.2): operations
//!     on edges whose inputs derive from a single scatter are commuted
//!     before the scatter, eliminating per-edge recomputation,
//!   * eliminates dead operations,
//!   * splits the DAG at GOPs into **segments** labeled `IR.v.x` /
//!     `IR.e.x` (§6.1 step 1) for inspection and codegen.
//!
//! The compiler (`crate::compiler`) lowers the optimized DAG into SDE
//! functions of ZIPPER ISA instructions.

pub mod e2v;
pub mod graph;
pub mod segment;

pub use graph::{FDim, ModelGraph, Node, NodeId, Op, Span};
pub use segment::{split_segments, Segment, SegmentKind};
