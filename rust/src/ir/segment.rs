//! Segment split (paper §6.1 step 1): cut the DAG at GOPs into
//! disconnected vertex/edge segments, each a DAG of single-item
//! operations with send/recv markers at the cut points.
//!
//! Segments are the unit the paper's Fig 8b shows (`IR.v.x` / `IR.e.x`)
//! and what the codegen walks. A GOP `ScatterOut{v}` becomes a
//! `sendOutEdge` exit in v's (vertex) segment and a `recvSrc` entry in
//! the consuming (edge) segment; gathers analogously.

use super::graph::{ModelGraph, NodeId, Op, Span};
use std::collections::BTreeMap;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SegmentKind {
    Vertex,
    Edge,
}

/// Communication port created by splitting a GOP.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Port {
    /// The GOP node in the original DAG this port came from.
    pub gop: NodeId,
    /// e.g. "sendOutEdge", "recvSrc", "sendDstSum", "recvInEdge".
    pub role: &'static str,
    /// The data node flowing through the port (producer side) or the
    /// GOP node standing in for received data (consumer side).
    pub data: NodeId,
}

#[derive(Clone, Debug)]
pub struct Segment {
    /// Label like "IR.v.0" / "IR.e.1" (paper notation).
    pub label: String,
    pub kind: SegmentKind,
    /// Member (non-GOP) nodes, in original id order.
    pub nodes: Vec<NodeId>,
    pub sends: Vec<Port>,
    pub recvs: Vec<Port>,
}

/// Split a (well-typed) model DAG into segments.
pub fn split_segments(g: &ModelGraph) -> Vec<Segment> {
    let spans = g.spans().expect("split_segments requires a well-typed DAG");
    let live = g.live_set();

    // union-find over live non-GOP, non-param nodes; edges of the DAG
    // that don't cross a GOP keep nodes in the same segment
    let n = g.nodes.len();
    let mut parent: Vec<u32> = (0..n as u32).collect();
    fn find(parent: &mut [u32], x: u32) -> u32 {
        let mut r = x;
        while parent[r as usize] != r {
            r = parent[r as usize];
        }
        let mut c = x;
        while parent[c as usize] != r {
            let next = parent[c as usize];
            parent[c as usize] = r;
            c = next;
        }
        r
    }
    let is_gop = |id: NodeId| {
        matches!(
            g.node(id).op,
            Op::ScatterOut { .. }
                | Op::ScatterIn { .. }
                | Op::GatherSum { .. }
                | Op::GatherMax { .. }
        )
    };
    let is_member = |id: NodeId| {
        live[id.0 as usize]
            && !is_gop(id)
            && spans[id.0 as usize] != Span::Param
            && !matches!(g.node(id).op, Op::Weight { .. })
    };

    for node in &g.nodes {
        if !is_member(node.id) {
            continue;
        }
        for inp in g.inputs_of(node.id) {
            if is_member(inp) && spans[inp.0 as usize] == spans[node.id.0 as usize] {
                let (a, b) = (find(&mut parent, node.id.0), find(&mut parent, inp.0));
                parent[a as usize] = b;
            }
        }
    }

    // group members by root
    let mut groups: BTreeMap<u32, Vec<NodeId>> = BTreeMap::new();
    for node in &g.nodes {
        if is_member(node.id) {
            let r = find(&mut parent, node.id.0);
            groups.entry(r).or_default().push(node.id);
        }
    }

    // attach send/recv ports from GOPs
    let mut segments: Vec<Segment> = Vec::new();
    let mut v_count = 0;
    let mut e_count = 0;
    for (_, nodes) in groups {
        let kind = match spans[nodes[0].0 as usize] {
            Span::Vertex => SegmentKind::Vertex,
            Span::Edge => SegmentKind::Edge,
            Span::Param => unreachable!("params excluded"),
        };
        let label = match kind {
            SegmentKind::Vertex => {
                v_count += 1;
                format!("IR.v.{}", v_count - 1)
            }
            SegmentKind::Edge => {
                e_count += 1;
                format!("IR.e.{}", e_count - 1)
            }
        };
        let mut sends = Vec::new();
        let mut recvs = Vec::new();
        let in_seg =
            |id: NodeId| nodes.binary_search(&id).is_ok();
        for gop in g.nodes.iter().filter(|x| live[x.id.0 as usize] && is_gop(x.id)) {
            let (producer, send_role, recv_role) = match gop.op {
                Op::ScatterOut { v } => (v, "sendOutEdge", "recvSrc"),
                Op::ScatterIn { v } => (v, "sendInEdge", "recvDst"),
                Op::GatherSum { e } => (e, "sendDstSum", "recvInEdge"),
                Op::GatherMax { e } => (e, "sendDstMax", "recvInEdge"),
                _ => unreachable!(),
            };
            // producer side: the feeding node lives in this segment
            if in_seg(producer) {
                sends.push(Port { gop: gop.id, role: send_role, data: producer });
            }
            // consumer side: some member consumes the GOP node
            let consumed_here = nodes.iter().any(|&m| {
                g.inputs_of(m).contains(&gop.id)
            });
            if consumed_here {
                recvs.push(Port { gop: gop.id, role: recv_role, data: gop.id });
            }
        }
        segments.push(Segment { label, kind, nodes, sends, recvs });
    }
    segments
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::graph::FDim;
    use crate::isa::ElwBinary;

    fn gcn() -> ModelGraph {
        let mut g = ModelGraph::new("gcn");
        let x = g.input_v("x");
        let e = g.scatter_out(x);
        let agg = g.gather_sum(e);
        let w = g.weight("w", FDim::In, FDim::Out);
        let h = g.gemm(agg, w);
        g.output_v(h, "h");
        g
    }

    #[test]
    fn gcn_splits_into_three_segments() {
        // vertex(x) | edge(identity pass-through has no member ops!) |
        // vertex(gemm+output). The edge segment vanishes because GCN
        // applies no edge computation — gather consumes scatter directly.
        let segs = split_segments(&gcn());
        let v: Vec<_> = segs.iter().filter(|s| s.kind == SegmentKind::Vertex).collect();
        assert_eq!(v.len(), 2);
        // producer vertex segment sends out-edge data
        assert!(v[0].sends.iter().any(|p| p.role == "sendOutEdge"));
        // consumer vertex segment receives gathered data
        assert!(v[1].recvs.iter().any(|p| p.role == "recvInEdge"));
    }

    #[test]
    fn edge_segment_appears_with_edge_ops() {
        let mut g = ModelGraph::new("m");
        let x = g.input_v("x");
        let a = g.scatter_out(x);
        let b = g.scatter_in(x);
        let e = g.binary(ElwBinary::Add, a, b); // real edge op
        let out = g.gather_sum(e);
        g.output_v(out, "h");
        let segs = split_segments(&g);
        let edges: Vec<_> = segs.iter().filter(|s| s.kind == SegmentKind::Edge).collect();
        assert_eq!(edges.len(), 1);
        assert_eq!(edges[0].label, "IR.e.0");
        let roles: Vec<_> = edges[0].recvs.iter().map(|p| p.role).collect();
        assert!(roles.contains(&"recvSrc"));
        assert!(roles.contains(&"recvDst"));
        assert!(edges[0].sends.iter().any(|p| p.role == "sendDstSum"));
    }

    #[test]
    fn labels_are_stable() {
        let segs = split_segments(&gcn());
        let labels: Vec<_> = segs.iter().map(|s| s.label.as_str()).collect();
        assert_eq!(labels, vec!["IR.v.0", "IR.v.1"]);
    }

    #[test]
    fn dead_branches_excluded() {
        let mut g = gcn();
        let dead = g.input_v("dead");
        let _dead2 = g.scatter_out(dead);
        let segs = split_segments(&g);
        assert_eq!(segs.len(), 2); // unchanged
    }
}
