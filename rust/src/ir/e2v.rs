//! E2V (edge-to-vertex) optimization — paper §6.2.
//!
//! An edge operation whose inputs derive from a *single* scatter carries
//! out the same computation once per edge that could be done once per
//! vertex: `op(scatter(v))` ≡ `scatter(op(v))` because scatter replicates
//! vertex rows onto edges. Since |E| ≫ |V| (and sparse tiles still carry
//! every edge), hoisting eliminates the redundancy — this is what makes
//! the paper's Fig 12 GAT speedup (1.87× on ZIPPER, 2.36× on the GPU).
//!
//! The pass rewrites the tensor-level DAG to fixpoint:
//!   * `Gemm/Gemv(ScatterX(v), w)`      → `ScatterX(Gemm/Gemv(v, w))`
//!   * `ElwU(ScatterX(v))`              → `ScatterX(ElwU(v))`
//!   * `ElwB(ScatterX(v), ScatterX(u))` → `ScatterX(ElwB(v, u))`
//!     (both operands through the *same scatter direction* only — mixing
//!     OutEdge and InEdge data is a genuine per-edge computation)
//!   * same for `ElwBcast`.
//!
//! `BmmByType` is never hoisted: its weight choice depends on the edge.

use super::graph::{ModelGraph, NodeId, Op};

/// Statistics from one optimization run (asserted on by Fig 12's bench).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct E2vStats {
    pub hoisted: u32,
    pub rounds: u32,
}

enum ScatterKind {
    Out,
    In,
}

fn scatter_kind(g: &ModelGraph, id: NodeId) -> Option<(ScatterKind, NodeId)> {
    match g.node(id).op {
        Op::ScatterOut { v } => Some((ScatterKind::Out, v)),
        Op::ScatterIn { v } => Some((ScatterKind::In, v)),
        _ => None,
    }
}

/// Apply E2V to fixpoint. Returns the rewritten graph and statistics.
/// The rewrite appends hoisted nodes and re-points consumers; dead
/// original nodes are left for `dead-op elimination` (live_set) to drop.
pub fn optimize(g: &ModelGraph) -> (ModelGraph, E2vStats) {
    let mut g = g.clone();
    let mut stats = E2vStats::default();
    loop {
        stats.rounds += 1;
        let mut changed = false;
        // snapshot: iterate ids present before this round
        let n_before = g.nodes.len();
        for idx in 0..n_before {
            let id = NodeId(idx as u32);
            let rewritten: Option<Op> = match g.node(id).op.clone() {
                Op::Gemm { x, w } => scatter_kind(&g, x).map(|(k, v)| {
                    let hoisted = g.push(Op::Gemm { x: v, w });
                    wrap(k, hoisted)
                }),
                Op::Gemv { x, w } => scatter_kind(&g, x).map(|(k, v)| {
                    let hoisted = g.push(Op::Gemv { x: v, w });
                    wrap(k, hoisted)
                }),
                Op::ElwU { op, x } => scatter_kind(&g, x).map(|(k, v)| {
                    let hoisted = g.push(Op::ElwU { op, x: v });
                    wrap(k, hoisted)
                }),
                Op::ElwB { op, a, b } => match (scatter_kind(&g, a), scatter_kind(&g, b)) {
                    (Some((ScatterKind::Out, va)), Some((ScatterKind::Out, vb))) => {
                        let hoisted = g.push(Op::ElwB { op, a: va, b: vb });
                        Some(Op::ScatterOut { v: hoisted })
                    }
                    (Some((ScatterKind::In, va)), Some((ScatterKind::In, vb))) => {
                        let hoisted = g.push(Op::ElwB { op, a: va, b: vb });
                        Some(Op::ScatterIn { v: hoisted })
                    }
                    _ => None,
                },
                Op::ElwBcast { op, a, vec } => {
                    match (scatter_kind(&g, a), scatter_kind(&g, vec)) {
                        (Some((ScatterKind::Out, va)), Some((ScatterKind::Out, vv))) => {
                            let hoisted = g.push(Op::ElwBcast { op, a: va, vec: vv });
                            Some(Op::ScatterOut { v: hoisted })
                        }
                        (Some((ScatterKind::In, va)), Some((ScatterKind::In, vv))) => {
                            let hoisted = g.push(Op::ElwBcast { op, a: va, vec: vv });
                            Some(Op::ScatterIn { v: hoisted })
                        }
                        _ => None,
                    }
                }
                _ => None,
            };
            if let Some(op) = rewritten {
                g.nodes[idx].op = op;
                stats.hoisted += 1;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    (g, stats)
}

fn wrap(k: ScatterKind, v: NodeId) -> Op {
    match k {
        ScatterKind::Out => Op::ScatterOut { v },
        ScatterKind::In => Op::ScatterIn { v },
    }
}

/// Per-edge FLOPs saved by E2V for a given graph instance — the analytic
/// quantity behind Fig 12 (hoisted work runs |V_tile| times, not |E|).
pub fn flops_saved(
    before: &ModelGraph,
    after: &ModelGraph,
    num_vertices: u64,
    num_edges: u64,
    feat_in: u64,
    feat_out: u64,
) -> i128 {
    let cost = |g: &ModelGraph| -> i128 {
        let spans = g.spans().expect("well-typed");
        let fdims = g.fdims();
        let live = g.live_set();
        let mut total: i128 = 0;
        for n in &g.nodes {
            if !live[n.id.0 as usize] {
                continue;
            }
            let items = match spans[n.id.0 as usize] {
                super::graph::Span::Edge => num_edges,
                super::graph::Span::Vertex => num_vertices,
                super::graph::Span::Param => 0,
            } as i128;
            let width = |d: super::graph::FDim| -> i128 {
                match d {
                    super::graph::FDim::In => feat_in as i128,
                    super::graph::FDim::Out => feat_out as i128,
                    super::graph::FDim::One => 1,
                }
            };
            let f = fdims[n.id.0 as usize];
            total += match &n.op {
                Op::Gemm { x, .. } => {
                    items * 2 * width(fdims[x.0 as usize]) * width(f)
                }
                Op::Gemv { x, .. } => items * 2 * width(fdims[x.0 as usize]),
                Op::ElwU { .. } | Op::ElwB { .. } | Op::ElwBcast { .. } => {
                    items * width(f)
                }
                Op::BmmByType { e, .. } => {
                    items * 2 * width(fdims[e.0 as usize]) * width(f)
                }
                _ => 0,
            };
        }
        total
    };
    cost(before) - cost(after)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::graph::FDim;
    use crate::isa::{ElwBinary, ElwUnary};

    /// GAT-naive edge segment: gemm + gemv on scattered vertex data.
    fn gat_naive() -> ModelGraph {
        let mut g = ModelGraph::new("gat_naive");
        let x = g.input_v("x");
        let w = g.weight("w", FDim::In, FDim::Out);
        let a_s = g.weight("a_src", FDim::Out, FDim::One);
        let a_d = g.weight("a_dst", FDim::Out, FDim::One);
        let ex_s = g.scatter_out(x);
        let ex_d = g.scatter_in(x);
        let z_es = g.gemm(ex_s, w); // per-edge GEMM (redundant)
        let z_ed = g.gemm(ex_d, w);
        let s_s = g.gemv(z_es, a_s);
        let s_d = g.gemv(z_ed, a_d);
        let e = g.binary(ElwBinary::Add, s_s, s_d);
        let e = g.unary(ElwUnary::LeakyRelu, e);
        let e = g.unary(ElwUnary::Exp, e);
        let num = g.bcast(ElwBinary::Mul, z_es, e);
        let msg = g.gather_sum(num);
        let den = g.gather_sum(e);
        let out = g.bcast(ElwBinary::Div, msg, den);
        g.output_v(out, "h");
        g
    }

    #[test]
    fn hoists_per_edge_gemms() {
        let g = gat_naive();
        let (opt, stats) = optimize(&g);
        assert!(stats.hoisted >= 4, "hoisted {}", stats.hoisted);
        opt.spans().expect("rewrite stays well-typed");
        // after E2V no live GEMM/GEMV remains on the edge span
        let spans = opt.spans().unwrap();
        let live = opt.live_set();
        for n in &opt.nodes {
            if !live[n.id.0 as usize] {
                continue;
            }
            if matches!(n.op, Op::Gemm { .. } | Op::Gemv { .. }) {
                assert_ne!(
                    spans[n.id.0 as usize],
                    crate::ir::Span::Edge,
                    "edge-span GEMM survived E2V: {:?}",
                    n
                );
            }
        }
    }

    #[test]
    fn saved_flops_positive_and_scales_with_edges() {
        let g = gat_naive();
        let (opt, _) = optimize(&g);
        let sparse = flops_saved(&g, &opt, 1_000, 10_000, 128, 128);
        let denser = flops_saved(&g, &opt, 1_000, 100_000, 128, 128);
        assert!(sparse > 0);
        assert!(denser > sparse * 5);
    }

    #[test]
    fn mixed_direction_binary_not_hoisted() {
        // add(scatter_out(x), scatter_in(x)) is a real per-edge op
        let mut g = ModelGraph::new("mixed");
        let x = g.input_v("x");
        let a = g.scatter_out(x);
        let b = g.scatter_in(x);
        let e = g.binary(ElwBinary::Add, a, b);
        let out = g.gather_sum(e);
        g.output_v(out, "h");
        let (opt, stats) = optimize(&g);
        assert_eq!(stats.hoisted, 0);
        assert_eq!(opt.op_mix(), g.op_mix());
    }

    #[test]
    fn idempotent() {
        let (once, s1) = optimize(&gat_naive());
        let (twice, s2) = optimize(&once);
        assert!(s1.hoisted > 0);
        assert_eq!(s2.hoisted, 0);
        assert_eq!(once.op_mix(), twice.op_mix());
    }

    #[test]
    fn gcn_untouched() {
        // GCN's GEMM follows the gather: no hoisting opportunity
        let mut g = ModelGraph::new("gcn");
        let x = g.input_v("x");
        let e = g.scatter_out(x);
        let agg = g.gather_sum(e);
        let w = g.weight("w", FDim::In, FDim::Out);
        let h = g.gemm(agg, w);
        g.output_v(h, "h");
        let (_, stats) = optimize(&g);
        assert_eq!(stats.hoisted, 0);
    }
}
