//! The tensor-level model DAG and its builder / validator.

use crate::isa::{ElwBinary, ElwUnary};
use std::fmt;

/// Symbolic feature dimension of a tensor's second axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FDim {
    /// Model input embedding width (F).
    In,
    /// Model output embedding width (F').
    Out,
    /// Scalar column (attention scores, softmax denominators).
    One,
}

/// What a tensor spans: all vertices, all edges, or parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Span {
    Vertex,
    Edge,
    Param,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

/// Tensor-level operations — the vocabulary of the classic GNN
/// programming model (paper Fig 5) plus explicit GOPs.
#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    /// Vertex-spanning input embedding matrix (V, F).
    InputV { name: &'static str },
    /// Learned parameter. `rows`/`cols` are symbolic feature dims;
    /// `count` > 1 is a stacked weight set (R-GCN relations).
    Weight { name: &'static str, rows: FDim, cols: FDim, count: u8 },
    /// Per-item matmul: (*, rows) @ (rows, cols).
    Gemm { x: NodeId, w: NodeId },
    /// Per-item matrix-vector: (*, rows) @ (rows, 1) → (*, 1).
    Gemv { x: NodeId, w: NodeId },
    ElwU { op: ElwUnary, x: NodeId },
    ElwB { op: ElwBinary, a: NodeId, b: NodeId },
    /// Broadcast a (*, 1) column over a (*, F) operand.
    ElwBcast { op: ElwBinary, a: NodeId, vec: NodeId },
    /// GOP: distribute source-vertex data onto out-edges (sendOutEdge-recvSrc).
    ScatterOut { v: NodeId },
    /// GOP: distribute destination-vertex data onto in-edges (sendInEdge-recvDst).
    ScatterIn { v: NodeId },
    /// GOP: reduce in-edge data per destination vertex (sendDstSum-recvInEdge).
    GatherSum { e: NodeId },
    GatherMax { e: NodeId },
    /// Index-guided batched matmul over edges: per-edge weight from a
    /// stacked set, selected by the edge's relation type (R-GCN).
    BmmByType { e: NodeId, wset: NodeId },
    /// Model output (vertex-spanning).
    OutputV { x: NodeId, name: &'static str },
}

#[derive(Clone, Debug, PartialEq)]
pub struct Node {
    pub id: NodeId,
    pub op: Op,
}

/// The model DAG. Nodes are append-only; `NodeId` indexes `nodes`.
#[derive(Clone, Debug, Default)]
pub struct ModelGraph {
    pub nodes: Vec<Node>,
    pub name: String,
}

#[derive(Debug)]
pub struct IrError(pub String);

impl fmt::Display for IrError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "IR error: {}", self.0)
    }
}

impl std::error::Error for IrError {}

impl ModelGraph {
    pub fn new(name: &str) -> Self {
        ModelGraph { nodes: Vec::new(), name: name.to_string() }
    }

    pub fn push(&mut self, op: Op) -> NodeId {
        let id = NodeId(self.nodes.len() as u32);
        self.nodes.push(Node { id, op });
        id
    }

    // -- builder sugar -----------------------------------------------------

    pub fn input_v(&mut self, name: &'static str) -> NodeId {
        self.push(Op::InputV { name })
    }

    pub fn weight(&mut self, name: &'static str, rows: FDim, cols: FDim) -> NodeId {
        self.push(Op::Weight { name, rows, cols, count: 1 })
    }

    pub fn weight_set(
        &mut self,
        name: &'static str,
        rows: FDim,
        cols: FDim,
        count: u8,
    ) -> NodeId {
        self.push(Op::Weight { name, rows, cols, count })
    }

    pub fn gemm(&mut self, x: NodeId, w: NodeId) -> NodeId {
        self.push(Op::Gemm { x, w })
    }

    pub fn gemv(&mut self, x: NodeId, w: NodeId) -> NodeId {
        self.push(Op::Gemv { x, w })
    }

    pub fn unary(&mut self, op: ElwUnary, x: NodeId) -> NodeId {
        self.push(Op::ElwU { op, x })
    }

    pub fn binary(&mut self, op: ElwBinary, a: NodeId, b: NodeId) -> NodeId {
        self.push(Op::ElwB { op, a, b })
    }

    pub fn bcast(&mut self, op: ElwBinary, a: NodeId, vec: NodeId) -> NodeId {
        self.push(Op::ElwBcast { op, a, vec })
    }

    pub fn scatter_out(&mut self, v: NodeId) -> NodeId {
        self.push(Op::ScatterOut { v })
    }

    pub fn scatter_in(&mut self, v: NodeId) -> NodeId {
        self.push(Op::ScatterIn { v })
    }

    pub fn gather_sum(&mut self, e: NodeId) -> NodeId {
        self.push(Op::GatherSum { e })
    }

    pub fn gather_max(&mut self, e: NodeId) -> NodeId {
        self.push(Op::GatherMax { e })
    }

    pub fn bmm_by_type(&mut self, e: NodeId, wset: NodeId) -> NodeId {
        self.push(Op::BmmByType { e, wset })
    }

    pub fn output_v(&mut self, x: NodeId, name: &'static str) -> NodeId {
        self.push(Op::OutputV { x, name })
    }

    // -- structure ----------------------------------------------------------

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0 as usize]
    }

    pub fn inputs_of(&self, id: NodeId) -> Vec<NodeId> {
        match self.node(id).op {
            Op::InputV { .. } | Op::Weight { .. } => vec![],
            Op::Gemm { x, w } | Op::Gemv { x, w } => vec![x, w],
            Op::ElwU { x, .. } => vec![x],
            Op::ElwB { a, b, .. } => vec![a, b],
            Op::ElwBcast { a, vec, .. } => vec![a, vec],
            Op::ScatterOut { v } | Op::ScatterIn { v } => vec![v],
            Op::GatherSum { e } | Op::GatherMax { e } => vec![e],
            Op::BmmByType { e, wset } => vec![e, wset],
            Op::OutputV { x, .. } => vec![x],
        }
    }

    pub fn outputs(&self) -> Vec<NodeId> {
        self.nodes
            .iter()
            .filter(|n| matches!(n.op, Op::OutputV { .. }))
            .map(|n| n.id)
            .collect()
    }

    /// Span (vertex / edge / param) of every node, or a type error.
    /// Enforces the §6.1 invariant: only GOPs change the span.
    /// Handles forward references (E2V appends hoisted nodes at the end).
    pub fn spans(&self) -> Result<Vec<Span>, IrError> {
        let mut spans: Vec<Option<Span>> = vec![None; self.nodes.len()];
        // resolve in dependency order via an explicit worklist
        let mut order: Vec<NodeId> = Vec::with_capacity(self.nodes.len());
        {
            let mut state = vec![0u8; self.nodes.len()]; // 0=unseen 1=open 2=done
            for start in 0..self.nodes.len() as u32 {
                let mut stack = vec![(NodeId(start), false)];
                while let Some((id, expanded)) = stack.pop() {
                    let i = id.0 as usize;
                    if state[i] == 2 {
                        continue;
                    }
                    if expanded {
                        state[i] = 2;
                        order.push(id);
                        continue;
                    }
                    if state[i] == 1 {
                        return Err(IrError(format!("cycle through node {:?}", id)));
                    }
                    state[i] = 1;
                    stack.push((id, true));
                    for inp in self.inputs_of(id) {
                        if state[inp.0 as usize] != 2 {
                            stack.push((inp, false));
                        }
                    }
                }
            }
        }
        for id in order {
            let n = &self.nodes[id.0 as usize];
            let get = |x: NodeId| -> Span { spans[x.0 as usize].expect("topo order") };
            let s = match &n.op {
                Op::InputV { .. } => Span::Vertex,
                Op::Weight { .. } => Span::Param,
                Op::Gemm { x, w } | Op::Gemv { x, w } => {
                    if get(*w) != Span::Param {
                        return Err(IrError(format!(
                            "node {:?}: GEMM weight operand must be a parameter",
                            n.id
                        )));
                    }
                    get(*x)
                }
                Op::ElwU { x, .. } => get(*x),
                Op::ElwB { a, b, .. } => {
                    if get(*a) != get(*b) {
                        return Err(IrError(format!(
                            "node {:?}: ELW operands span {:?} vs {:?}",
                            n.id, get(*a), get(*b)
                        )));
                    }
                    get(*a)
                }
                Op::ElwBcast { a, vec, .. } => {
                    if get(*a) != get(*vec) {
                        return Err(IrError(format!(
                            "node {:?}: broadcast operands span {:?} vs {:?}",
                            n.id, get(*a), get(*vec)
                        )));
                    }
                    get(*a)
                }
                Op::ScatterOut { v } | Op::ScatterIn { v } => {
                    if get(*v) != Span::Vertex {
                        return Err(IrError(format!(
                            "node {:?}: scatter input must span vertices",
                            n.id
                        )));
                    }
                    Span::Edge
                }
                Op::GatherSum { e } | Op::GatherMax { e } => {
                    if get(*e) != Span::Edge {
                        return Err(IrError(format!(
                            "node {:?}: gather input must span edges",
                            n.id
                        )));
                    }
                    Span::Vertex
                }
                Op::BmmByType { e, wset } => {
                    if get(*e) != Span::Edge || get(*wset) != Span::Param {
                        return Err(IrError(format!(
                            "node {:?}: BMM needs edge data and a weight set",
                            n.id
                        )));
                    }
                    Span::Edge
                }
                Op::OutputV { x, .. } => {
                    if get(*x) != Span::Vertex {
                        return Err(IrError(format!(
                            "node {:?}: output must span vertices",
                            n.id
                        )));
                    }
                    Span::Vertex
                }
            };
            spans[id.0 as usize] = Some(s);
        }
        Ok(spans.into_iter().map(|s| s.expect("all nodes visited")).collect())
    }

    /// Feature width (symbolic) of every node's second axis.
    /// Handles forward references like `spans()`.
    pub fn fdims(&self) -> Vec<FDim> {
        let mut out: Vec<Option<FDim>> = vec![None; self.nodes.len()];
        fn resolve(g: &ModelGraph, id: NodeId, out: &mut Vec<Option<FDim>>) -> FDim {
            if let Some(d) = out[id.0 as usize] {
                return d;
            }
            let d = match &g.nodes[id.0 as usize].op {
                Op::InputV { .. } => FDim::In,
                Op::Weight { cols, .. } => *cols,
                Op::Gemm { w, .. } => resolve(g, *w, out),
                Op::Gemv { .. } => FDim::One,
                Op::ElwU { x, .. } => resolve(g, *x, out),
                Op::ElwB { a, .. } => resolve(g, *a, out),
                Op::ElwBcast { a, .. } => resolve(g, *a, out),
                Op::ScatterOut { v } | Op::ScatterIn { v } => resolve(g, *v, out),
                Op::GatherSum { e } | Op::GatherMax { e } => resolve(g, *e, out),
                Op::BmmByType { wset, .. } => resolve(g, *wset, out),
                Op::OutputV { x, .. } => resolve(g, *x, out),
            };
            out[id.0 as usize] = Some(d);
            d
        }
        for i in 0..self.nodes.len() as u32 {
            resolve(self, NodeId(i), &mut out);
        }
        out.into_iter().map(|d| d.expect("resolved")).collect()
    }

    /// Nodes reachable (backwards) from any output — the live set.
    pub fn live_set(&self) -> Vec<bool> {
        let mut live = vec![false; self.nodes.len()];
        let mut stack = self.outputs();
        while let Some(id) = stack.pop() {
            if live[id.0 as usize] {
                continue;
            }
            live[id.0 as usize] = true;
            stack.extend(self.inputs_of(id));
        }
        live
    }

    /// Count of live operations by coarse class (GOP / GEMM / ELW) — the
    /// paper's §2 primitive-op taxonomy, used by workload characterization.
    pub fn op_mix(&self) -> OpMix {
        let live = self.live_set();
        let mut mix = OpMix::default();
        for n in &self.nodes {
            if !live[n.id.0 as usize] {
                continue;
            }
            match n.op {
                Op::Gemm { .. } | Op::Gemv { .. } | Op::BmmByType { .. } => {
                    mix.gemm += 1
                }
                Op::ElwU { .. } | Op::ElwB { .. } | Op::ElwBcast { .. } => {
                    mix.elw += 1
                }
                Op::ScatterOut { .. }
                | Op::ScatterIn { .. }
                | Op::GatherSum { .. }
                | Op::GatherMax { .. } => mix.gop += 1,
                _ => {}
            }
        }
        mix
    }
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpMix {
    pub gemm: u32,
    pub elw: u32,
    pub gop: u32,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gcn() -> ModelGraph {
        let mut g = ModelGraph::new("gcn");
        let x = g.input_v("x");
        let e = g.scatter_out(x);
        let agg = g.gather_sum(e);
        let w = g.weight("w", FDim::In, FDim::Out);
        let h = g.gemm(agg, w);
        g.output_v(h, "h");
        g
    }

    #[test]
    fn gcn_spans() {
        let g = gcn();
        let spans = g.spans().unwrap();
        assert_eq!(spans[0], Span::Vertex); // x
        assert_eq!(spans[1], Span::Edge); // scatter
        assert_eq!(spans[2], Span::Vertex); // gather
        assert_eq!(spans[3], Span::Param); // w
        assert_eq!(spans[4], Span::Vertex); // gemm
    }

    #[test]
    fn gcn_op_mix() {
        let mix = gcn().op_mix();
        assert_eq!(mix, OpMix { gemm: 1, elw: 0, gop: 2 });
    }

    #[test]
    fn span_mismatch_rejected() {
        let mut g = ModelGraph::new("bad");
        let x = g.input_v("x");
        let e = g.scatter_out(x);
        // ELW between a vertex tensor and an edge tensor is ill-typed
        g.binary(ElwBinary::Add, x, e);
        assert!(g.spans().is_err());
    }

    #[test]
    fn gather_of_vertex_rejected() {
        let mut g = ModelGraph::new("bad2");
        let x = g.input_v("x");
        g.push(Op::GatherSum { e: x });
        assert!(g.spans().is_err());
    }

    #[test]
    fn dead_nodes_detected() {
        let mut g = gcn();
        let dead = g.input_v("unused");
        let live = g.live_set();
        assert!(!live[dead.0 as usize]);
        assert!(live[0]);
    }

    #[test]
    fn fdims_track_weights() {
        let g = gcn();
        let d = g.fdims();
        assert_eq!(d[0], FDim::In);
        assert_eq!(d[4], FDim::Out); // gemm output takes weight cols
    }
}
