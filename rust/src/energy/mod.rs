//! Energy model (paper §8.1 "Energy Estimation").
//!
//! The paper's accounting is linear in event counts × per-event constants
//! from synthesis (MAC @ TSMC 16 nm), Cacti 6.5 (on-chip memories, 32 nm
//! scaled to 16 nm), and 7 pJ/bit for HBM. We reproduce the accounting
//! with constants back-derived to land in the paper's regime (DESIGN.md
//! §5): the *ratios* (ZIPPER vs CPU/GPU; Fig 10) come from the event
//! counts the simulator + baselines produce, not from the constants.

/// Per-event energy constants in picojoules.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EnergyModel {
    /// One f32 MAC in a 16 nm systolic array (incl. local register moves).
    pub mac_pj: f64,
    /// One f32 VU lane-op (ELW/GOP ALU work).
    pub vu_op_pj: f64,
    /// eDRAM (UEM) access per byte: dynamic read/write.
    pub uem_pj_per_byte: f64,
    /// Tile-hub SRAM access per byte.
    pub th_pj_per_byte: f64,
    /// Off-chip HBM per *bit* (paper: 7 pJ/bit [38]).
    pub hbm_pj_per_bit: f64,
    /// Static leakage power in watts (UEM-dominated; Cacti leakage).
    pub leakage_w: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        // Calibrated so ZIPPER's effective power lands near the ~100 W
        // the paper's Fig 10 ratios imply (147× vs a 170 W CPU running
        // 93.6× slower ⇒ ZIPPER ≈ 106 W): the eDRAM macro + its refresh
        // and the HBM PHY dominate, matching Table 5's 97.9%-memory die.
        EnergyModel {
            mac_pj: 2.0,            // 16 nm f32 MAC incl. array overheads
            vu_op_pj: 1.5,
            uem_pj_per_byte: 20.0,  // 21 MB eDRAM dynamic (Cacti-derived)
            th_pj_per_byte: 4.0,    // small SRAM
            hbm_pj_per_bit: 7.0,    // paper's constant [38]
            // platform power: eDRAM refresh, clock tree, HBM device +
            // PHY standby — calibrated to the ~100 W the paper's Fig 10
            // ratios imply for the whole ZIPPER platform
            leakage_w: 85.0,
        }
    }
}

/// Event counters filled by the simulator (and the baseline models,
/// reinterpreted with their own constants).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyCounters {
    pub macs: u64,
    pub vu_ops: u64,
    pub uem_bytes: u64,
    pub th_bytes: u64,
    pub hbm_bytes: u64,
    pub cycles: u64,
}

/// Event counts are additive: summing the per-layer counters of a
/// multi-layer pipeline yields the whole run's counters (the layers
/// execute back-to-back on the same hardware).
impl std::ops::AddAssign for EnergyCounters {
    fn add_assign(&mut self, rhs: EnergyCounters) {
        self.macs += rhs.macs;
        self.vu_ops += rhs.vu_ops;
        self.uem_bytes += rhs.uem_bytes;
        self.th_bytes += rhs.th_bytes;
        self.hbm_bytes += rhs.hbm_bytes;
        self.cycles += rhs.cycles;
    }
}

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub mac_j: f64,
    pub vu_j: f64,
    pub uem_j: f64,
    pub th_j: f64,
    pub hbm_j: f64,
    pub leakage_j: f64,
}

impl EnergyBreakdown {
    pub fn total_j(&self) -> f64 {
        self.mac_j + self.vu_j + self.uem_j + self.th_j + self.hbm_j + self.leakage_j
    }
}

impl EnergyModel {
    pub fn evaluate(&self, c: &EnergyCounters, freq_hz: f64) -> EnergyBreakdown {
        const PJ: f64 = 1e-12;
        EnergyBreakdown {
            mac_j: c.macs as f64 * self.mac_pj * PJ,
            vu_j: c.vu_ops as f64 * self.vu_op_pj * PJ,
            uem_j: c.uem_bytes as f64 * self.uem_pj_per_byte * PJ,
            th_j: c.th_bytes as f64 * self.th_pj_per_byte * PJ,
            hbm_j: c.hbm_bytes as f64 * 8.0 * self.hbm_pj_per_bit * PJ,
            leakage_j: self.leakage_w * c.cycles as f64 / freq_hz,
        }
    }
}

impl std::ops::AddAssign for EnergyCounters {
    fn add_assign(&mut self, o: Self) {
        self.macs += o.macs;
        self.vu_ops += o.vu_ops;
        self.uem_bytes += o.uem_bytes;
        self.th_bytes += o.th_bytes;
        self.hbm_bytes += o.hbm_bytes;
        self.cycles = self.cycles.max(o.cycles);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hbm_constant_is_paper_value() {
        assert_eq!(EnergyModel::default().hbm_pj_per_bit, 7.0);
    }

    #[test]
    fn accounting_is_linear() {
        let m = EnergyModel::default();
        let c1 = EnergyCounters { macs: 1_000, hbm_bytes: 64, ..Default::default() };
        let c2 = EnergyCounters { macs: 2_000, hbm_bytes: 128, ..Default::default() };
        let e1 = m.evaluate(&c1, 1e9);
        let e2 = m.evaluate(&c2, 1e9);
        assert!((e2.mac_j - 2.0 * e1.mac_j).abs() < 1e-18);
        assert!((e2.hbm_j - 2.0 * e1.hbm_j).abs() < 1e-18);
    }

    #[test]
    fn hbm_dominates_onchip_per_byte() {
        // off-chip access must cost more than on-chip (sanity of the
        // constants: this ordering is what makes sparse tiling pay off)
        let m = EnergyModel::default();
        assert!(m.hbm_pj_per_bit * 8.0 > 2.0 * m.uem_pj_per_byte);
        assert!(m.uem_pj_per_byte > m.th_pj_per_byte);
    }

    #[test]
    fn leakage_scales_with_time() {
        let m = EnergyModel::default();
        let c = EnergyCounters { cycles: 1_000_000_000, ..Default::default() };
        let e = m.evaluate(&c, 1e9); // 1 second
        assert!((e.leakage_j - m.leakage_w).abs() < 1e-12);
    }
}
