//! Compiler (paper §6.1 step 3): lower the optimized tensor DAG into the
//! three SDE functions of ZIPPER ISA instructions.
//!
//! Node → function assignment (the paper's "replicate the vertex
//! segments, then prune"):
//!   * vertex nodes in the backward closure of a `ScatterOut` input run
//!     in the **sFunction**, once per tile, over the tile's source
//!     vertices (rows = TileSrc);
//!   * vertex nodes in the backward closure of a `ScatterIn` input, plus
//!     everything downstream of a Gather, run in the **dFunction**, once
//!     per partition (rows = PartDst) — split into a *pre* phase
//!     (feeds ScatterIn; runs before the tiles) and a *post* phase
//!     (consumes gathered accumulators; runs after all tiles complete);
//!   * edge nodes and the GOPs themselves run in the **eFunction**, once
//!     per tile (rows = TileEdges); Gathers accumulate into partition
//!     buffers across tiles.
//!
//! Stream protocol encoded in the functions (DESIGN.md §6; adapted from
//! paper §5.2 — here the sStream fetches tiles and the dStream waits on a
//! single completion signal raised by the eStream's CHK.PTT when the
//! partition's last tile retires):
//!
//! ```text
//! dFunction: FCH.PTT; [LD.DST]; <pre ops>; SIGNAL.S; WAIT 1;
//!            <post ops>; ST.DST; UPD.PTT; JUMP ^
//! sFunction: WAIT 1; FCH.TILE(empty -> ^wait); LD.SRC; <src ops>;
//!            SIGNAL.E; JUMP ^fch
//! eFunction: WAIT 1; LD.EDGE; <edge ops>; CHK.PTT; JUMP ^wait
//! ```
//!
//! A vertex node needed on both sides (GAT's `z = xW`) is *replicated*:
//! computed per tile source block in the sFunction and per destination
//! partition in the dFunction — exactly the paper's replica-and-prune.

use crate::ir::{self, FDim, ModelGraph, NodeId, Op, Span};
use crate::isa::{
    BufId, Dim, Instr, LdTarget, Reduce, SctrDir, StreamClass, WeightId,
};
use std::collections::BTreeMap;

pub mod optimize;

pub use optimize::{optimize_pipeline, OptReport, PassOutcome, PassSet, PipelineOptReport};

/// Partition-frame buffers start here; below is the tile frame.
pub const PART_FRAME_BASE: u16 = 0x100;

impl BufId {
    pub fn is_partition_frame(self) -> bool {
        self.0 >= PART_FRAME_BASE
    }
}

/// Weight-table entry (order defines `WeightId`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WeightMeta {
    pub name: &'static str,
    pub rows: FDim,
    pub cols: FDim,
    pub count: u8,
}

/// Reduction kind of each partition accumulator (functional init/fixup).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccKind {
    Sum,
    Max,
}

/// A compiled GNN program: the three SDE functions + metadata.
#[derive(Clone, Debug)]
pub struct Program {
    pub model_name: String,
    pub s_func: Vec<Instr>,
    pub e_func: Vec<Instr>,
    pub d_func: Vec<Instr>,
    pub weights: Vec<WeightMeta>,
    /// Number of tile-frame buffer slots.
    pub tile_bufs: u16,
    /// Number of partition-frame buffer slots.
    pub part_bufs: u16,
    /// Partition accumulators: (buffer, reduction, column dim) —
    /// zero/−inf-initialized at FCH.PTT, max-fixed-up at the dStream
    /// wait boundary. The column dim is recorded here so the executor
    /// never rescans the eFunction for the writing Gthr.
    pub accumulators: Vec<(BufId, AccKind, Dim)>,
    /// Partition-frame buffer holding the model output (ST.DST source).
    pub output_buf: BufId,
    /// Whether the model loads destination embeddings (LD.DST emitted).
    pub uses_dst_input: bool,
    /// E2V statistics if the optimizer ran.
    pub e2v: Option<ir::e2v::E2vStats>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptLevel {
    /// Straight lowering of the model as written (Fig 12 "naive").
    None,
    /// E2V + dead-op elimination (Fig 12 "optimized", the default).
    E2v,
    /// E2V lowering plus the plan-level pipeline passes in `PassSet`.
    /// Per-layer lowering is identical to `E2v`; the pipeline passes run
    /// over the whole compiled layer stack in `plan::ExecPlan` (see
    /// [`optimize::optimize_pipeline`]) because cross-layer facts are
    /// invisible to a single-program compile.
    Pipeline(PassSet),
}

/// Structured compile failure: the message plus, when known, which model
/// and which pipeline layer was being lowered.
#[derive(Clone, Debug)]
pub struct CompileError {
    pub model: Option<String>,
    pub layer: Option<usize>,
    pub message: String,
}

impl CompileError {
    pub fn new(message: impl Into<String>) -> CompileError {
        CompileError { model: None, layer: None, message: message.into() }
    }

    /// Attach the model name (kept if already set by a deeper frame).
    pub fn with_model(mut self, model: &str) -> CompileError {
        if self.model.is_none() {
            self.model = Some(model.to_string());
        }
        self
    }

    /// Attach the pipeline layer index the failure occurred in.
    pub fn at_layer(mut self, layer: usize) -> CompileError {
        self.layer = Some(layer);
        self
    }
}

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "compile error")?;
        if let Some(m) = &self.model {
            write!(f, " [model {m}")?;
            if let Some(l) = self.layer {
                write!(f, ", layer {l}")?;
            }
            write!(f, "]")?;
        } else if let Some(l) = self.layer {
            write!(f, " [layer {l}]")?;
        }
        write!(f, ": {}", self.message)
    }
}

impl std::error::Error for CompileError {}

/// Compile a model DAG into a `Program`.
pub fn compile(model: &ModelGraph, opt: OptLevel) -> Result<Program, CompileError> {
    compile_inner(model, opt).map_err(|e| e.with_model(&model.name))
}

fn compile_inner(model: &ModelGraph, opt: OptLevel) -> Result<Program, CompileError> {
    let (g, e2v_stats) = match opt {
        OptLevel::None => (model.clone(), None),
        OptLevel::E2v | OptLevel::Pipeline(_) => {
            let (g, stats) = ir::e2v::optimize(model);
            (g, Some(stats))
        }
    };
    let spans = g.spans().map_err(|e| CompileError::new(e.to_string()))?;
    let fdims = g.fdims();
    let live = g.live_set();

    // ---- weight table ----------------------------------------------------
    let mut weights = Vec::new();
    let mut weight_ids: BTreeMap<NodeId, WeightId> = BTreeMap::new();
    for n in &g.nodes {
        if let Op::Weight { name, rows, cols, count } = n.op {
            if live[n.id.0 as usize] {
                weight_ids.insert(n.id, WeightId(weights.len() as u16));
                weights.push(WeightMeta { name, rows, cols, count });
            }
        }
    }

    // ---- closures ----------------------------------------------------------
    let n = g.nodes.len();
    let is_gather =
        |id: NodeId| matches!(g.node(id).op, Op::GatherSum { .. } | Op::GatherMax { .. });
    // Backward closure; when `stop_at_gather`, gathers are included (they
    // are materialized partition accumulators) but not traversed through.
    let backward_closure = |roots: &[NodeId], stop_at_gather: bool| -> Vec<bool> {
        let mut seen = vec![false; n];
        let mut stack: Vec<NodeId> = roots.to_vec();
        while let Some(id) = stack.pop() {
            if seen[id.0 as usize] {
                continue;
            }
            seen[id.0 as usize] = true;
            if stop_at_gather && is_gather(id) {
                continue;
            }
            stack.extend(g.inputs_of(id));
        }
        seen
    };

    let scatter_out_roots: Vec<NodeId> = g
        .nodes
        .iter()
        .filter(|x| live[x.id.0 as usize])
        .filter_map(|x| match x.op {
            Op::ScatterOut { v } => Some(v),
            _ => None,
        })
        .collect();
    let scatter_in_roots: Vec<NodeId> = g
        .nodes
        .iter()
        .filter(|x| live[x.id.0 as usize])
        .filter_map(|x| match x.op {
            Op::ScatterIn { v } => Some(v),
            _ => None,
        })
        .collect();

    // single-round constraint: scatter inputs must not depend on gathers
    let full_scatter_closure = {
        let mut roots = scatter_out_roots.clone();
        roots.extend(&scatter_in_roots);
        backward_closure(&roots, false)
    };
    for (i, node) in g.nodes.iter().enumerate() {
        if full_scatter_closure[i]
            && matches!(node.op, Op::GatherSum { .. } | Op::GatherMax { .. })
        {
            return Err(CompileError::new(format!(
                "{}: scatter input depends on a gather — multi-round \
                 models must be compiled layer-by-layer",
                g.name
            )));
        }
    }

    // src side: everything a ScatterOut needs (computed per tile)
    let src_side = backward_closure(&scatter_out_roots, true);

    // dst side: everything a ScatterIn or the output needs, with gathers
    // acting as materialized frontier (computed per partition)
    let d_needed = {
        let mut roots = scatter_in_roots.clone();
        for out in g.outputs() {
            if let Op::OutputV { x, .. } = g.node(out).op {
                roots.push(x);
            }
        }
        backward_closure(&roots, true)
    };

    // depends-on-gather (forward from gathers): the dFunction post phase
    let mut after_gather = vec![false; n];
    for node in &g.nodes {
        if !live[node.id.0 as usize] {
            continue;
        }
        let i = node.id.0 as usize;
        if is_gather(node.id) {
            after_gather[i] = true;
            continue;
        }
        if g.inputs_of(node.id).iter().any(|x| after_gather[x.0 as usize]) {
            after_gather[i] = true;
        }
    }

    let dst_side =
        |i: usize| -> bool { spans[i] == Span::Vertex && live[i] && d_needed[i] };

    // ---- buffer allocation -------------------------------------------------
    let mut tile_buf_of: BTreeMap<NodeId, BufId> = BTreeMap::new();
    let mut part_buf_of: BTreeMap<NodeId, BufId> = BTreeMap::new();
    let mut next_tile: u16 = 0;
    let mut next_part: u16 = PART_FRAME_BASE;
    let mut alloc_tile = |id: NodeId, m: &mut BTreeMap<NodeId, BufId>| -> BufId {
        *m.entry(id).or_insert_with(|| {
            let b = BufId(next_tile);
            next_tile += 1;
            b
        })
    };
    let mut alloc_part = |id: NodeId, m: &mut BTreeMap<NodeId, BufId>| -> BufId {
        *m.entry(id).or_insert_with(|| {
            let b = BufId(next_part);
            next_part += 1;
            b
        })
    };

    let col_dim = |id: NodeId| -> Dim {
        match fdims[id.0 as usize] {
            FDim::In => Dim::FeatIn,
            FDim::Out => Dim::FeatOut,
            FDim::One => Dim::Const(1),
        }
    };

    // topological order over live nodes (Kahn; E2V breaks id-order)
    let topo = topo_order(&g, &live);

    // ---- sFunction body: src-side vertex ops --------------------------------
    let mut s_body: Vec<Instr> = Vec::new();
    for &id in &topo {
        let i = id.0 as usize;
        if !(src_side[i] && spans[i] == Span::Vertex) {
            continue;
        }
        match &g.node(id).op {
            Op::InputV { .. } => {
                let dst = alloc_tile(id, &mut tile_buf_of);
                s_body.push(Instr::Ld {
                    target: LdTarget::Src,
                    dst,
                    rows: Dim::TileSrc,
                    cols: Dim::FeatIn,
                });
            }
            op => {
                let dst = alloc_tile(id, &mut tile_buf_of);
                s_body.push(lower_compute(
                    op,
                    dst,
                    Dim::TileSrc,
                    &tile_buf_of,
                    &weight_ids,
                    &col_dim,
                    &fdims,
                )?);
            }
        }
    }

    // ---- dFunction bodies ----------------------------------------------------
    let mut d_pre: Vec<Instr> = Vec::new();
    let mut d_post: Vec<Instr> = Vec::new();
    let mut accumulators: Vec<(BufId, AccKind, Dim)> = Vec::new();
    let mut uses_dst_input = false;
    // gathers allocate partition accumulators first (written by eFunc)
    for &id in &topo {
        let i = id.0 as usize;
        if !live[i] {
            continue;
        }
        if let Op::GatherSum { e } | Op::GatherMax { e } = &g.node(id).op {
            let buf = alloc_part(id, &mut part_buf_of);
            let kind = match g.node(id).op {
                Op::GatherMax { .. } => AccKind::Max,
                _ => AccKind::Sum,
            };
            accumulators.push((buf, kind, col_dim(*e)));
        }
    }
    for &id in &topo {
        let i = id.0 as usize;
        if !dst_side(i) {
            continue;
        }
        match &g.node(id).op {
            Op::InputV { .. } => {
                let dst = alloc_part(id, &mut part_buf_of);
                uses_dst_input = true;
                d_pre.push(Instr::Ld {
                    target: LdTarget::Dst,
                    dst,
                    rows: Dim::PartDst,
                    cols: Dim::FeatIn,
                });
            }
            Op::GatherSum { .. } | Op::GatherMax { .. } => {} // accumulator
            op => {
                let dst = alloc_part(id, &mut part_buf_of);
                let instr = lower_compute(
                    op,
                    dst,
                    Dim::PartDst,
                    &part_buf_of,
                    &weight_ids,
                    &col_dim,
                    &fdims,
                )?;
                if after_gather[i] {
                    d_post.push(instr);
                } else {
                    d_pre.push(instr);
                }
            }
        }
    }

    // output store
    let out_node = *g
        .outputs()
        .first()
        .ok_or_else(|| CompileError::new("model has no output"))?;
    let out_src = match g.node(out_node).op {
        Op::OutputV { x, .. } => x,
        _ => unreachable!(),
    };
    let output_buf = *part_buf_of.get(&out_src).ok_or_else(|| {
        CompileError::new("output source not materialized in partition frame")
    })?;
    d_post.push(Instr::St {
        src: output_buf,
        rows: Dim::PartDst,
        cols: col_dim(out_src),
    });

    // ---- eFunction body: edge ops + GOPs ------------------------------------
    let mut e_body: Vec<Instr> = Vec::new();
    for &id in &topo {
        let i = id.0 as usize;
        if !live[i] {
            continue;
        }
        match &g.node(id).op {
            Op::ScatterOut { v } => {
                let src = *tile_buf_of.get(v).ok_or_else(|| {
                    CompileError::new(format!("scatter-out source {v:?} not in tile frame"))
                })?;
                let dst = alloc_tile(id, &mut tile_buf_of);
                e_body.push(Instr::Sctr {
                    dir: SctrDir::OutEdge,
                    src,
                    dst,
                    cols: col_dim(*v),
                });
            }
            Op::ScatterIn { v } => {
                let src = *part_buf_of.get(v).ok_or_else(|| {
                    CompileError::new(format!("scatter-in source {v:?} not in partition frame"))
                })?;
                let dst = alloc_tile(id, &mut tile_buf_of);
                e_body.push(Instr::Sctr {
                    dir: SctrDir::InEdge,
                    src,
                    dst,
                    cols: col_dim(*v),
                });
            }
            Op::GatherSum { e } | Op::GatherMax { e } => {
                let src = *tile_buf_of.get(e).ok_or_else(|| {
                    CompileError::new(format!("gather source {e:?} not in tile frame"))
                })?;
                let dst = part_buf_of[&id];
                let reduce = match g.node(id).op {
                    Op::GatherMax { .. } => Reduce::Max,
                    _ => Reduce::Sum,
                };
                e_body.push(Instr::Gthr {
                    reduce,
                    src,
                    dst,
                    cols: col_dim(*e),
                    accumulate: true,
                });
            }
            op if spans[i] == Span::Edge => {
                let dst = alloc_tile(id, &mut tile_buf_of);
                e_body.push(lower_compute(
                    op,
                    dst,
                    Dim::TileEdges,
                    &tile_buf_of,
                    &weight_ids,
                    &col_dim,
                    &fdims,
                )?);
            }
            _ => {}
        }
    }

    // ---- assemble with the stream protocol -----------------------------------
    // dFunction
    let mut d_func = vec![Instr::FchPtt];
    d_func.extend(d_pre);
    d_func.push(Instr::Signal { class: StreamClass::S });
    d_func.push(Instr::Wait { count: Dim::Const(1) });
    d_func.extend(d_post);
    d_func.push(Instr::UpdPtt);
    d_func.push(Instr::Jump(-(d_func.len() as i32)));

    // sFunction: WAIT; FCH.TILE(empty->back to WAIT); LD.W*; LD.SRC; ops;
    // SIGNAL.E; JUMP ->FCH
    let mut s_func = vec![
        Instr::Wait { count: Dim::Const(1) },
        Instr::FchTile { on_empty: -1 },
    ];
    s_func.extend(weight_loads(&s_body, &weights));
    s_func.extend(s_body);
    s_func.push(Instr::Signal { class: StreamClass::E });
    let back_to_fch = 1i32 - s_func.len() as i32;
    s_func.push(Instr::Jump(back_to_fch));

    // eFunction
    let mut e_func = vec![
        Instr::Wait { count: Dim::Const(1) },
        Instr::Ld {
            target: LdTarget::Edge,
            dst: BufId(u16::MAX), // tile hub, not an embedding buffer
            rows: Dim::TileEdges,
            cols: Dim::Const(1),
        },
    ];
    e_func.extend(weight_loads(&e_body, &weights));
    e_func.extend(e_body);
    e_func.push(Instr::ChkPtt);
    let back_to_wait = -(e_func.len() as i32);
    e_func.push(Instr::Jump(back_to_wait));

    Ok(Program {
        model_name: g.name.clone(),
        s_func,
        e_func,
        d_func,
        weights,
        tile_bufs: next_tile,
        part_bufs: next_part - PART_FRAME_BASE,
        accumulators,
        output_buf,
        uses_dst_input,
        e2v: e2v_stats,
    })
}

/// Lower a compute op given its frame's row dim and the frame buffer map.
#[allow(clippy::too_many_arguments)]
fn lower_compute(
    op: &Op,
    dst: BufId,
    rows: Dim,
    bufs: &BTreeMap<NodeId, BufId>,
    weight_ids: &BTreeMap<NodeId, WeightId>,
    col_dim: &dyn Fn(NodeId) -> Dim,
    fdims: &[FDim],
) -> Result<Instr, CompileError> {
    let buf = |id: &NodeId| -> Result<BufId, CompileError> {
        bufs.get(id)
            .copied()
            .ok_or_else(|| CompileError::new(format!("operand {id:?} not materialized")))
    };
    Ok(match op {
        Op::Gemm { x, w } => Instr::Gemm {
            src: buf(x)?,
            weight: weight_ids[w],
            dst,
            m: rows,
            k: col_dim(*x),
            n: fdim_to_dim(fdims[w.0 as usize]),
            accumulate: false,
            act: None,
        },
        Op::Gemv { x, w } => Instr::Gemv {
            src: buf(x)?,
            weight: weight_ids[w],
            dst,
            rows,
            cols: col_dim(*x),
        },
        Op::ElwU { op, x } => Instr::ElwU {
            op: *op,
            src: buf(x)?,
            dst,
            rows,
            cols: col_dim(*x),
        },
        Op::ElwB { op, a, b } => Instr::ElwB {
            op: *op,
            a: buf(a)?,
            b: buf(b)?,
            dst,
            rows,
            cols: col_dim(*a),
        },
        Op::ElwBcast { op, a, vec } => Instr::ElwBcast {
            op: *op,
            a: buf(a)?,
            vec: buf(vec)?,
            dst,
            rows,
            cols: col_dim(*a),
        },
        Op::BmmByType { e, wset } => Instr::Bmm {
            src: buf(e)?,
            weights: weight_ids[wset],
            dst,
            m: rows,
            k: col_dim(*e),
            n: fdim_to_dim(fdims[wset.0 as usize]),
        },
        other => {
            return Err(CompileError::new(format!(
                "unexpected op in compute lowering: {other:?}"
            )))
        }
    })
}

fn fdim_to_dim(f: FDim) -> Dim {
    match f {
        FDim::In => Dim::FeatIn,
        FDim::Out => Dim::FeatOut,
        FDim::One => Dim::Const(1),
    }
}

/// Per-tile weight fills for a tile-loop body: one `LD.W` per distinct
/// weight slice the body's MU/VU instructions consume, in first-use
/// order (a `count > 1` table entry — R-GCN's per-relation set — fills
/// one slice per relation). The `dst` field encodes the *weight-table
/// index*, not an embedding buffer (see `LdTarget::Weight`). dFunction
/// bodies run once per partition, so their fill is amortized and not
/// modeled; the pipeline optimizer's hoist pass lifts these per-tile
/// fills to the same per-partition residency.
fn weight_loads(body: &[Instr], weights: &[WeightMeta]) -> Vec<Instr> {
    let mut seen: Vec<WeightId> = Vec::new();
    let mut out = Vec::new();
    for instr in body {
        let w = match instr {
            Instr::Gemm { weight, .. } | Instr::Gemv { weight, .. } => *weight,
            Instr::Bmm { weights, .. } => *weights,
            _ => continue,
        };
        if seen.contains(&w) {
            continue;
        }
        seen.push(w);
        let meta = &weights[w.0 as usize];
        for _ in 0..meta.count {
            out.push(Instr::Ld {
                target: LdTarget::Weight,
                dst: BufId(w.0),
                rows: fdim_to_dim(meta.rows),
                cols: fdim_to_dim(meta.cols),
            });
        }
    }
    out
}

fn topo_order(g: &ModelGraph, live: &[bool]) -> Vec<NodeId> {
    let n = g.nodes.len();
    let mut indeg = vec![0usize; n];
    let mut consumers: Vec<Vec<NodeId>> = vec![Vec::new(); n];
    for node in &g.nodes {
        if !live[node.id.0 as usize] {
            continue;
        }
        for inp in g.inputs_of(node.id) {
            indeg[node.id.0 as usize] += 1;
            consumers[inp.0 as usize].push(node.id);
        }
    }
    // `ready` kept sorted descending so pop() yields the smallest id —
    // deterministic instruction order across runs.
    let mut ready: Vec<NodeId> = (0..n as u32)
        .map(NodeId)
        .filter(|id| live[id.0 as usize] && indeg[id.0 as usize] == 0)
        .collect();
    ready.sort_unstable_by(|a, b| b.cmp(a));
    let mut out = Vec::with_capacity(n);
    while let Some(id) = ready.pop() {
        out.push(id);
        for &c in &consumers[id.0 as usize] {
            indeg[c.0 as usize] -= 1;
            if indeg[c.0 as usize] == 0 {
                ready.push(c);
            }
        }
        ready.sort_unstable_by(|a, b| b.cmp(a));
    }
    out
}

impl Program {
    /// Human-readable listing of all three functions.
    ///
    /// The output is deterministic for a given program: instructions
    /// print in function order, the weight table in `WeightId` order,
    /// and the accumulator/output footer in sorted buffer-id order —
    /// golden-IR snapshot tests diff this text verbatim.
    pub fn disassemble(&self) -> String {
        let mut s = format!("; program {}\n", self.model_name);
        for (name, f) in [
            ("dFunction", &self.d_func),
            ("sFunction", &self.s_func),
            ("eFunction", &self.e_func),
        ] {
            s.push_str(&format!("\n{name}:\n"));
            for (i, instr) in f.iter().enumerate() {
                s.push_str(&format!("  {i:3}: {instr}\n"));
            }
        }
        s.push_str(&format!(
            "\n; weights: {:?}\n; tile bufs: {} part bufs: {}\n",
            self.weights.iter().map(|w| w.name).collect::<Vec<_>>(),
            self.tile_bufs,
            self.part_bufs
        ));
        let mut accs: Vec<String> = self
            .accumulators
            .iter()
            .map(|(b, k, _)| format!("b{}:{k:?}", b.0))
            .collect();
        accs.sort();
        s.push_str(&format!(
            "; accumulators: [{}] output: b{}\n",
            accs.join(" "),
            self.output_buf.0
        ));
        s
    }

    pub fn instruction_count(&self) -> usize {
        self.s_func.len() + self.e_func.len() + self.d_func.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelKind;

    fn compiled(m: ModelKind, opt: OptLevel) -> Program {
        compile(&m.build(), opt).unwrap_or_else(|e| panic!("{}: {e}", m.name()))
    }

    #[test]
    fn all_models_compile_both_levels() {
        for m in ModelKind::ALL {
            for opt in [OptLevel::None, OptLevel::E2v] {
                let p = compiled(m, opt);
                assert!(!p.e_func.is_empty());
                assert!(!p.d_func.is_empty());
                assert!(!p.accumulators.is_empty(), "{} has gathers", m.name());
            }
        }
    }

    #[test]
    fn gcn_program_shape() {
        let p = compiled(ModelKind::Gcn, OptLevel::E2v);
        // GCN: sFunc loads x only (no src-side compute beyond input)
        assert!(matches!(p.s_func[2], Instr::Ld { target: LdTarget::Src, .. }));
        // eFunc: scatter + gather
        assert!(p.e_func.iter().any(|i| matches!(i, Instr::Sctr { .. })));
        assert!(p
            .e_func
            .iter()
            .any(|i| matches!(i, Instr::Gthr { accumulate: true, .. })));
        // dFunc: GEMM after the wait (post phase)
        let wait_at = p
            .d_func
            .iter()
            .position(|i| matches!(i, Instr::Wait { .. }))
            .unwrap();
        let gemm_at = p
            .d_func
            .iter()
            .position(|i| matches!(i, Instr::Gemm { .. }))
            .unwrap();
        assert!(gemm_at > wait_at);
        assert!(!p.uses_dst_input);
    }

    #[test]
    fn gat_e2v_moves_gemm_to_sfunc() {
        let naive = compiled(ModelKind::Gat, OptLevel::None);
        let opt = compiled(ModelKind::Gat, OptLevel::E2v);
        let count = |f: &[Instr], pred: fn(&Instr) -> bool| f.iter().filter(|i| pred(i)).count();
        let is_mu = |i: &Instr| matches!(i, Instr::Gemm { .. } | Instr::Bmm { .. });
        // naive: per-edge GEMMs live in the eFunction
        assert!(count(&naive.e_func, is_mu) >= 2);
        // optimized: no MU work on edges; GEMM runs per-vertex in s/d funcs
        assert_eq!(count(&opt.e_func, is_mu), 0);
        assert!(count(&opt.s_func, is_mu) >= 1);
        assert!(count(&opt.d_func, is_mu) >= 1);
        assert!(opt.uses_dst_input);
        assert!(opt.e2v.unwrap().hoisted > 0);
    }

    #[test]
    fn rgcn_keeps_bmm_on_edges() {
        let p = compiled(ModelKind::Rgcn, OptLevel::E2v);
        assert!(p.e_func.iter().any(|i| matches!(i, Instr::Bmm { .. })));
    }

    #[test]
    fn sage_has_max_accumulator() {
        let p = compiled(ModelKind::Sage, OptLevel::E2v);
        assert!(p.accumulators.iter().any(|&(_, k, _)| k == AccKind::Max));
    }

    #[test]
    fn buffer_frames_disjoint() {
        for m in ModelKind::ALL {
            let p = compiled(m, OptLevel::E2v);
            assert!(p.tile_bufs < PART_FRAME_BASE);
            assert!(p.output_buf.is_partition_frame());
            // every Gthr writes a partition buffer; every Sctr writes tile
            for i in &p.e_func {
                match i {
                    Instr::Gthr { dst, .. } => assert!(dst.is_partition_frame()),
                    Instr::Sctr { dst, .. } => assert!(!dst.is_partition_frame()),
                    _ => {}
                }
            }
        }
    }

    #[test]
    fn control_flow_offsets_in_bounds() {
        for m in ModelKind::ALL {
            let p = compiled(m, OptLevel::E2v);
            for (f, name) in [(&p.s_func, "s"), (&p.e_func, "e"), (&p.d_func, "d")] {
                for (pc, i) in f.iter().enumerate() {
                    let tgt = match i {
                        Instr::Jump(off) => Some(pc as i64 + *off as i64),
                        Instr::FchTile { on_empty } => Some(pc as i64 + *on_empty as i64),
                        _ => None,
                    };
                    if let Some(t) = tgt {
                        assert!(
                            t >= 0 && (t as usize) < f.len(),
                            "{}:{name}[{pc}] jumps to {t}",
                            m.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn ggnn_post_phase_runs_gru_gemms_per_partition() {
        let p = compiled(ModelKind::Ggnn, OptLevel::E2v);
        let wait_at = p.d_func.iter().position(|i| matches!(i, Instr::Wait { .. })).unwrap();
        // az/ar/ah/rxh depend on the gathered message → post phase;
        // xz/xr depend only on x_dst → pre phase. 6 GEMMs total.
        let post_gemms = p.d_func[wait_at..]
            .iter()
            .filter(|i| matches!(i, Instr::Gemm { .. }))
            .count();
        let all_gemms = p
            .d_func
            .iter()
            .filter(|i| matches!(i, Instr::Gemm { .. }))
            .count();
        assert!(post_gemms >= 4, "gather-dependent GEMMs, found {post_gemms}");
        assert!(all_gemms >= 6, "GRU has 6 GEMMs, found {all_gemms}");
    }

    #[test]
    fn per_tile_weight_loads_emitted() {
        let ldw = |f: &[Instr]| {
            f.iter()
                .filter(|i| matches!(i, Instr::Ld { target: LdTarget::Weight, .. }))
                .count()
        };
        // GAT replicates z = xW per tile: its sFunction fills weights
        let gat = compiled(ModelKind::Gat, OptLevel::E2v);
        assert!(ldw(&gat.s_func) >= 1, "GAT sFunction uses weights per tile");
        // GCN's only GEMM runs per partition in the dFunction: no LD.W
        let gcn = compiled(ModelKind::Gcn, OptLevel::E2v);
        assert_eq!(ldw(&gcn.s_func) + ldw(&gcn.e_func) + ldw(&gcn.d_func), 0);
        // R-GCN's per-relation weight set fills one slice per relation
        let rgcn = compiled(ModelKind::Rgcn, OptLevel::E2v);
        assert!(ldw(&rgcn.e_func) >= 2, "one LD.W per relation slice");
    }

    #[test]
    fn compile_error_carries_model_context() {
        let mut g = ModelGraph::new("two_hop");
        let x = g.input_v("x");
        let e1 = g.scatter_out(x);
        let h1 = g.gather_sum(e1);
        let e2 = g.scatter_out(h1);
        let h2 = g.gather_sum(e2);
        g.output_v(h2, "h");
        let err = compile(&g, OptLevel::None).unwrap_err();
        assert_eq!(err.model.as_deref(), Some("two_hop"));
        let msg = err.at_layer(1).to_string();
        assert!(msg.contains("two_hop") && msg.contains("layer 1"), "{msg}");
    }

    #[test]
    fn disassembly_mentions_all_functions() {
        let p = compiled(ModelKind::Gat, OptLevel::E2v);
        let d = p.disassemble();
        assert!(d.contains("sFunction") && d.contains("eFunction") && d.contains("dFunction"));
        assert!(d.contains("GTHR.DST.SUM"));
    }

    #[test]
    fn multi_round_model_rejected() {
        // gather feeding a scatter (two-hop single program) must error
        let mut g = ModelGraph::new("two_hop");
        let x = g.input_v("x");
        let e1 = g.scatter_out(x);
        let h1 = g.gather_sum(e1);
        let e2 = g.scatter_out(h1);
        let h2 = g.gather_sum(e2);
        g.output_v(h2, "h");
        assert!(compile(&g, OptLevel::None).is_err());
    }
}
