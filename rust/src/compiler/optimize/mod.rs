//! Pipeline-level optimizer (DESIGN.md §3.7): passes that run over the
//! *whole compiled layer stack* of an `ExecPlan`, after per-layer
//! lowering and before the programs are zipped into `LayerStage`s.
//!
//! Per-layer compilation cannot see cross-layer facts: every stage of a
//! multi-layer plan shares one `Tiling`, so work that is invariant
//! across the layer loop — the tile edge lists, the per-tile weight
//! fills — recurs N times when each layer is lowered in isolation. The
//! four passes here close that gap:
//!
//! 1. **`load_elim`** — cross-layer invariant-load elimination. A load
//!    whose source region is provably unchanged since the previous layer
//!    over the same shared tiling is dropped. Of the load targets, only
//!    `LD.EDGE` qualifies: the edge lists are a function of the tiling
//!    alone, while `LD.SRC`/`LD.DST` read the layer's input activations
//!    (rewritten by the previous layer) and `GTHR` reduces per-layer
//!    edge values. Stage 0 keeps its loads; they stay resident in the
//!    Tile Hub for every later stage.
//! 2. **`fuse`** — elementwise fusion. A trailing `ELW` whose only input
//!    is the immediately preceding GEMM's output (the hidden-layer ReLU)
//!    folds into that GEMM's store as a fused-activation variant,
//!    applied on the MU output path by the single dispatch core.
//! 3. **`hoist`** — loop-invariant weight-load hoisting. Per-tile `LD.W`
//!    fills in the s/eFunction tile loops are weight-table reads that
//!    never change within a partition; they move to the dFunction
//!    (once per partition), restoring whole-partition MU residency.
//! 4. **`dbe`** — dead-buffer elimination. A liveness pass over `BufId`s
//!    removes pure instructions whose destination is never read (fusion
//!    orphans the old GEMM destination, for example) and shrinks the
//!    frame high-water marks, freeing UEM slots.
//!
//! Pass ordering is fixed (`load_elim → fuse → hoist → dbe`): fusion
//! creates the dead buffers that `dbe` sweeps, and `dbe` runs last so no
//! pass ever observes — or resurrects — a buffer another pass killed.
//! Every pass preserves the stream-protocol layout
//! (`FCH.PTT; …; SIGNAL.S < WAIT < UPD.PTT` in the dFunction) and
//! re-targets relative branches across every edit; the pass-invariant
//! tests below pin both.
//!
//! All passes are semantics-preserving at the bit level: eliminated
//! `LD.EDGE`/`LD.W` instructions are functional no-ops in dispatch, the
//! fused activation runs the exact kernel the removed `ELW` would have,
//! and `dbe` only deletes writes nothing reads. The differential fuzz
//! test (`rust/tests/optimizer_diff.rs`) asserts bit-exact outputs
//! against `OptLevel::E2v` on both executors for every pass subset.

use super::{Program, PART_FRAME_BASE};
use crate::isa::{BufId, Instr, LdTarget, StreamClass};
use std::collections::BTreeSet;
use std::fmt;

/// A set of pipeline-optimizer passes (`OptLevel::Pipeline` payload).
///
/// Passes are individually toggleable; the set is a bitmask so plans
/// compiled under different subsets never alias in the `PlanCache`
/// (`PassSet` is part of `PlanKey`'s `Eq`/`Hash`).
///
/// ```
/// use zipper::compiler::PassSet;
///
/// let p = PassSet::parse("load_elim,dbe").unwrap();
/// assert!(p.contains(PassSet::LOAD_ELIM) && p.contains(PassSet::DBE));
/// assert!(!p.contains(PassSet::FUSE));
/// assert_eq!(p.to_string(), "load_elim,dbe");
/// assert_eq!(PassSet::parse("all"), Some(PassSet::all()));
/// assert_eq!(PassSet::parse("none"), Some(PassSet::none()));
/// assert!(PassSet::parse("warp_drive").is_none());
/// ```
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct PassSet(u8);

impl PassSet {
    /// Cross-layer redundant-load elimination.
    pub const LOAD_ELIM: PassSet = PassSet(1 << 0);
    /// Elementwise-activation fusion into the preceding GEMM.
    pub const FUSE: PassSet = PassSet(1 << 1);
    /// Loop-invariant weight-load hoisting out of per-tile bodies.
    pub const HOIST: PassSet = PassSet(1 << 2);
    /// Dead-buffer elimination (liveness over `BufId`s).
    pub const DBE: PassSet = PassSet(1 << 3);

    /// Every pass with its config/CLI name, in execution order.
    pub const NAMED: [(&'static str, PassSet); 4] = [
        ("load_elim", PassSet::LOAD_ELIM),
        ("fuse", PassSet::FUSE),
        ("hoist", PassSet::HOIST),
        ("dbe", PassSet::DBE),
    ];

    pub const fn none() -> PassSet {
        PassSet(0)
    }

    pub const fn all() -> PassSet {
        PassSet(0b1111)
    }

    pub const fn is_empty(self) -> bool {
        self.0 == 0
    }

    pub const fn contains(self, other: PassSet) -> bool {
        self.0 & other.0 == other.0
    }

    pub const fn with(self, other: PassSet) -> PassSet {
        PassSet(self.0 | other.0)
    }

    /// All 2⁴ subsets (differential-fuzz sweep order).
    pub fn every_subset() -> impl Iterator<Item = PassSet> {
        (0u8..16).map(PassSet)
    }

    /// Parse `"all"`, `"none"`, or a `,`/`+`-separated pass-name list.
    pub fn parse(s: &str) -> Option<PassSet> {
        match s.trim() {
            "all" => return Some(PassSet::all()),
            "" | "none" => return Some(PassSet::none()),
            _ => {}
        }
        let mut out = PassSet::none();
        for part in s.split([',', '+']) {
            let name = part.trim();
            let (_, p) = PassSet::NAMED.iter().find(|(n, _)| *n == name)?;
            out = out.with(*p);
        }
        Some(out)
    }
}

impl fmt::Display for PassSet {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_empty() {
            return write!(f, "none");
        }
        if *self == PassSet::all() {
            return write!(f, "all");
        }
        let names: Vec<&str> = PassSet::NAMED
            .iter()
            .filter(|(_, p)| self.contains(*p))
            .map(|(n, _)| *n)
            .collect();
        write!(f, "{}", names.join(","))
    }
}

/// What one pass did to the pipeline.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OptReport {
    /// Instructions removed (invariant loads, dead writes).
    pub removed: usize,
    /// ELW instructions folded into a preceding GEMM.
    pub fused: usize,
    /// Per-tile weight fills lifted into the dFunction.
    pub hoisted: usize,
    /// Buffer slots no surviving instruction references.
    pub freed: usize,
}

/// One executed pass with its per-pass attribution.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PassOutcome {
    pub pass: &'static str,
    pub report: OptReport,
    /// Total pipeline instruction count after this pass ran.
    pub instructions_after: usize,
}

/// Full attribution for one `optimize_pipeline` run.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PipelineOptReport {
    /// Total pipeline instruction count before any pass ran.
    pub instructions_before: usize,
    /// Executed passes in execution order.
    pub passes: Vec<PassOutcome>,
}

impl PipelineOptReport {
    pub fn instructions_after(&self) -> usize {
        self.passes.last().map_or(self.instructions_before, |p| p.instructions_after)
    }
}

impl fmt::Display for PipelineOptReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut prev = self.instructions_before;
        for p in &self.passes {
            let r = p.report;
            writeln!(
                f,
                "{:>9}: insns {prev} -> {} (removed {} fused {} hoisted {} freed {})",
                p.pass, p.instructions_after, r.removed, r.fused, r.hoisted, r.freed
            )?;
            prev = p.instructions_after;
        }
        Ok(())
    }
}

/// Run the selected passes, in fixed order, over the compiled per-layer
/// programs of one plan (`programs[l]` is layer `l`). Mutates the
/// programs in place and returns per-pass attribution.
pub fn optimize_pipeline(programs: &mut [Program], passes: PassSet) -> PipelineOptReport {
    let count =
        |ps: &[Program]| ps.iter().map(|p| p.instruction_count()).sum::<usize>();
    let mut rep =
        PipelineOptReport { instructions_before: count(programs), passes: Vec::new() };
    for (name, pass) in PassSet::NAMED {
        if !passes.contains(pass) {
            continue;
        }
        let report = match pass {
            PassSet::LOAD_ELIM => eliminate_invariant_loads(programs),
            PassSet::FUSE => fuse_activations(programs),
            PassSet::HOIST => hoist_weight_loads(programs),
            _ => eliminate_dead_buffers(programs),
        };
        rep.passes.push(PassOutcome {
            pass: name,
            report,
            instructions_after: count(programs),
        });
    }
    rep
}

// ---- function-edit helpers (branch-safe) --------------------------------

const D_IDX: usize = 0;
const S_IDX: usize = 1;
const E_IDX: usize = 2;

fn func_of(prog: &Program, idx: usize) -> &Vec<Instr> {
    match idx {
        D_IDX => &prog.d_func,
        S_IDX => &prog.s_func,
        _ => &prog.e_func,
    }
}

fn func_of_mut(prog: &mut Program, idx: usize) -> &mut Vec<Instr> {
    match idx {
        D_IDX => &mut prog.d_func,
        S_IDX => &mut prog.s_func,
        _ => &mut prog.e_func,
    }
}

/// Remove the instructions at `remove` (ascending, no duplicates),
/// re-targeting every relative branch whose (pc → target) span straddles
/// an edit. The passes only ever delete straight-line body instructions;
/// deleting a branch target is a bug, caught here.
fn remove_at(func: &mut Vec<Instr>, remove: &[usize]) {
    if remove.is_empty() {
        return;
    }
    let mut removed = vec![false; func.len()];
    for &r in remove {
        removed[r] = true;
    }
    // new index of every surviving old pc
    let mut new_idx = vec![0usize; func.len()];
    let mut k = 0usize;
    for i in 0..func.len() {
        new_idx[i] = k;
        if !removed[i] {
            k += 1;
        }
    }
    for pc in 0..func.len() {
        if removed[pc] {
            continue;
        }
        let off = match &func[pc] {
            Instr::Jump(off) => *off,
            Instr::FchTile { on_empty } => *on_empty,
            _ => continue,
        };
        let tgt = (pc as i64 + off as i64) as usize;
        assert!(!removed[tgt], "optimizer removed a branch target (pc {pc} -> {tgt})");
        let new_off = new_idx[tgt] as i32 - new_idx[pc] as i32;
        match &mut func[pc] {
            Instr::Jump(o) => *o = new_off,
            Instr::FchTile { on_empty } => *on_empty = new_off,
            _ => unreachable!(),
        }
    }
    let mut i = 0;
    func.retain(|_| {
        let keep = !removed[i];
        i += 1;
        keep
    });
}

/// Insert `items` before old index `at`, re-targeting relative branches
/// that straddle the insertion point.
fn insert_at(func: &mut Vec<Instr>, at: usize, items: Vec<Instr>) {
    if items.is_empty() {
        return;
    }
    let n = items.len() as i64;
    for pc in 0..func.len() {
        let off = match &func[pc] {
            Instr::Jump(off) => *off,
            Instr::FchTile { on_empty } => *on_empty,
            _ => continue,
        };
        let tgt = pc as i64 + off as i64;
        let pc_new = if pc >= at { pc as i64 + n } else { pc as i64 };
        let tgt_new = if tgt >= at as i64 { tgt + n } else { tgt };
        let new_off = (tgt_new - pc_new) as i32;
        match &mut func[pc] {
            Instr::Jump(o) => *o = new_off,
            Instr::FchTile { on_empty } => *on_empty = new_off,
            _ => unreachable!(),
        }
    }
    func.splice(at..at, items);
}

// ---- dataflow facts ------------------------------------------------------

/// Embedding buffers an instruction reads. `LD.EDGE`/`LD.W` destinations
/// are sentinels (tile hub / weight-table index), not buffers.
fn reads(ins: &Instr) -> Vec<BufId> {
    match ins {
        Instr::ElwU { src, .. } => vec![*src],
        Instr::ElwB { a, b, .. } => vec![*a, *b],
        Instr::ElwBcast { a, vec, .. } => vec![*a, *vec],
        Instr::Gemv { src, .. }
        | Instr::Gemm { src, .. }
        | Instr::Bmm { src, .. }
        | Instr::Sctr { src, .. }
        | Instr::Gthr { src, .. }
        | Instr::St { src, .. } => vec![*src],
        _ => Vec::new(),
    }
}

/// The embedding buffer an instruction writes, if any.
fn writes(ins: &Instr) -> Option<BufId> {
    match ins {
        Instr::ElwU { dst, .. }
        | Instr::ElwB { dst, .. }
        | Instr::ElwBcast { dst, .. }
        | Instr::Gemv { dst, .. }
        | Instr::Gemm { dst, .. }
        | Instr::Bmm { dst, .. }
        | Instr::Sctr { dst, .. }
        | Instr::Gthr { dst, .. } => Some(*dst),
        Instr::Ld { target: LdTarget::Src | LdTarget::Dst, dst, .. } => Some(*dst),
        _ => None,
    }
}

fn read_count(prog: &Program, b: BufId) -> usize {
    [&prog.d_func, &prog.s_func, &prog.e_func]
        .iter()
        .flat_map(|f| f.iter())
        .map(|i| reads(i).iter().filter(|&&r| r == b).count())
        .sum()
}

fn write_count(prog: &Program, b: BufId) -> usize {
    [&prog.d_func, &prog.s_func, &prog.e_func]
        .iter()
        .flat_map(|f| f.iter())
        .filter(|i| writes(i) == Some(b))
        .count()
}

/// Every buffer slot the program still touches, plus the liveness roots
/// the executors require regardless of instruction dataflow (the output
/// buffer and the partition accumulators).
fn referenced_bufs(prog: &Program) -> BTreeSet<BufId> {
    let mut s = BTreeSet::new();
    for f in [&prog.d_func, &prog.s_func, &prog.e_func] {
        for ins in f.iter() {
            s.extend(reads(ins));
            s.extend(writes(ins));
        }
    }
    s.insert(prog.output_buf);
    s.extend(prog.accumulators.iter().map(|&(b, _, _)| b));
    s
}

// ---- pass 1: cross-layer invariant-load elimination ----------------------

/// Drop loads whose source is provably unchanged since the previous
/// layer over the shared tiling. The invariance analysis is per load
/// target: `LD.EDGE` streams the tile edge lists, which are a function
/// of the `Tiling` alone — byte-identical for every stage — so once a
/// stage has filled the Tile Hub, later stages reuse it. `LD.SRC` and
/// `LD.DST` read the stage's input activations (the previous stage's
/// output: *not* invariant), and `GTHR` reduces per-stage edge values,
/// so neither is ever eligible.
fn eliminate_invariant_loads(programs: &mut [Program]) -> OptReport {
    let mut removed = 0;
    let mut edge_resident = false;
    for prog in programs.iter_mut() {
        let has_edge_load = prog
            .e_func
            .iter()
            .any(|i| matches!(i, Instr::Ld { target: LdTarget::Edge, .. }));
        if edge_resident {
            let drops: Vec<usize> = prog
                .e_func
                .iter()
                .enumerate()
                .filter(|(_, i)| matches!(i, Instr::Ld { target: LdTarget::Edge, .. }))
                .map(|(pc, _)| pc)
                .collect();
            removed += drops.len();
            remove_at(&mut prog.e_func, &drops);
        }
        edge_resident |= has_edge_load;
    }
    OptReport { removed, ..OptReport::default() }
}

// ---- pass 2: elementwise fusion into GEMM --------------------------------

/// Fold `GEMM b → g; ELW.op g → e` pairs into `GEMM.op b → e` when the
/// rewrite is invisible: the GEMM overwrites (no accumulate, no prior
/// fusion), the ELW is its immediate successor and `g`'s only reader,
/// `g` has no other writer and is neither the model output nor an
/// accumulator, and `e` aliases nothing the GEMM reads. The fused
/// activation runs the exact ELW kernel on the MU output path (single
/// dispatch site), so outputs are bit-identical; the orphaned `g` is
/// swept by `dbe`.
fn fuse_activations(programs: &mut [Program]) -> OptReport {
    let mut fused = 0;
    for prog in programs.iter_mut() {
        for fidx in [D_IDX, S_IDX, E_IDX] {
            let mut i = 0;
            loop {
                let func = func_of(prog, fidx);
                if i + 1 >= func.len() {
                    break;
                }
                let candidate = match (&func[i], &func[i + 1]) {
                    (
                        Instr::Gemm {
                            src: gs, dst: g, m, n, accumulate: false, act: None, ..
                        },
                        Instr::ElwU { op, src, dst: e, rows, cols },
                    ) if src == g && rows == m && cols == n && e != g && e != gs => {
                        Some((*g, *e, *op))
                    }
                    _ => None,
                };
                if let Some((g, e, op)) = candidate {
                    let sound = read_count(prog, g) == 1
                        && write_count(prog, g) == 1
                        && write_count(prog, e) == 1
                        && g != prog.output_buf
                        && !prog.accumulators.iter().any(|&(b, _, _)| b == g);
                    if sound {
                        let func = func_of_mut(prog, fidx);
                        if let Instr::Gemm { dst, act, .. } = &mut func[i] {
                            *dst = e;
                            *act = Some(op);
                        }
                        remove_at(func, &[i + 1]);
                        fused += 1;
                        continue; // new successor at i + 1: re-check
                    }
                }
                i += 1;
            }
        }
    }
    OptReport { fused, ..OptReport::default() }
}

// ---- pass 3: loop-invariant weight-load hoisting -------------------------

/// Lift per-tile `LD.W` fills out of the s/eFunction tile loops into the
/// dFunction pre region (right after `FCH.PTT`): the weight table never
/// changes within a partition, so one fill per partition replaces one
/// per tile. A slice filled by both tile loops is inserted once (with
/// its full multi-slice multiplicity for `count > 1` weight sets).
fn hoist_weight_loads(programs: &mut [Program]) -> OptReport {
    let mut hoisted = 0;
    for prog in programs.iter_mut() {
        // distinct fill instruction → max copies needed in one function
        let mut lifted: Vec<(Instr, usize)> = Vec::new();
        for fidx in [S_IDX, E_IDX] {
            let func = func_of(prog, fidx);
            let pcs: Vec<usize> = func
                .iter()
                .enumerate()
                .filter(|(_, i)| matches!(i, Instr::Ld { target: LdTarget::Weight, .. }))
                .map(|(pc, _)| pc)
                .collect();
            for &pc in &pcs {
                let ins = func[pc].clone();
                let copies = pcs.iter().filter(|&&p| func[p] == ins).count();
                match lifted.iter_mut().find(|(l, _)| *l == ins) {
                    Some((_, c)) => *c = (*c).max(copies),
                    None => lifted.push((ins, copies)),
                }
            }
            hoisted += pcs.len();
            remove_at(func_of_mut(prog, fidx), &pcs);
        }
        let fills: Vec<Instr> = lifted
            .into_iter()
            .flat_map(|(ins, copies)| vec![ins; copies])
            .collect();
        insert_at(&mut prog.d_func, 1, fills);
    }
    OptReport { hoisted, ..OptReport::default() }
}

// ---- pass 4: dead-buffer elimination -------------------------------------

/// Liveness over `BufId`s: iteratively remove pure compute/load
/// instructions whose destination no surviving instruction reads (and
/// which is neither the model output nor an accumulator — both are
/// executor roots), then shrink the frame high-water marks. `GTHR`,
/// `ST`, `LD.EDGE`, `LD.W`, and sync instructions are never removed.
fn eliminate_dead_buffers(programs: &mut [Program]) -> OptReport {
    let removable = |ins: &Instr| {
        matches!(
            ins,
            Instr::ElwU { .. }
                | Instr::ElwB { .. }
                | Instr::ElwBcast { .. }
                | Instr::Gemv { .. }
                | Instr::Gemm { .. }
                | Instr::Bmm { .. }
                | Instr::Sctr { .. }
                | Instr::Ld { target: LdTarget::Src | LdTarget::Dst, .. }
        )
    };
    let mut removed = 0;
    let mut freed = 0;
    for prog in programs.iter_mut() {
        let before = referenced_bufs(prog);
        loop {
            let mut live: BTreeSet<BufId> = BTreeSet::new();
            for f in [&prog.d_func, &prog.s_func, &prog.e_func] {
                for ins in f.iter() {
                    live.extend(reads(ins));
                }
            }
            live.insert(prog.output_buf);
            live.extend(prog.accumulators.iter().map(|&(b, _, _)| b));
            let mut any = false;
            for fidx in [D_IDX, S_IDX, E_IDX] {
                let dead: Vec<usize> = func_of(prog, fidx)
                    .iter()
                    .enumerate()
                    .filter(|(_, ins)| {
                        removable(ins)
                            && writes(ins).is_some_and(|b| !live.contains(&b))
                    })
                    .map(|(pc, _)| pc)
                    .collect();
                if !dead.is_empty() {
                    removed += dead.len();
                    remove_at(func_of_mut(prog, fidx), &dead);
                    any = true;
                }
            }
            if !any {
                break;
            }
        }
        let after = referenced_bufs(prog);
        freed += before.difference(&after).count();
        let tile_max = after.iter().filter(|b| !b.is_partition_frame()).map(|b| b.0).max();
        prog.tile_bufs = tile_max.map_or(0, |m| m + 1);
        let part_max = after.iter().filter(|b| b.is_partition_frame()).map(|b| b.0).max();
        prog.part_bufs = part_max.map_or(0, |m| m - PART_FRAME_BASE + 1);
    }
    OptReport { removed, freed, ..OptReport::default() }
}

// ---- pass-invariant checks (shared by tests) -----------------------------

/// The dFunction stream-protocol layout every pass must preserve:
/// `FCH.PTT` first, then `SIGNAL.S < WAIT < UPD.PTT`.
#[cfg(test)]
fn d_layout_ok(prog: &Program) -> bool {
    let d = &prog.d_func;
    let sig = d
        .iter()
        .position(|i| matches!(i, Instr::Signal { class: StreamClass::S }));
    let wait = d.iter().position(|i| matches!(i, Instr::Wait { .. }));
    let upd = d.iter().position(|i| matches!(i, Instr::UpdPtt));
    matches!(d.first(), Some(Instr::FchPtt))
        && matches!((sig, wait, upd), (Some(s), Some(w), Some(u)) if s < w && w < u)
}

#[cfg(test)]
fn offsets_ok(prog: &Program) -> bool {
    [&prog.d_func, &prog.s_func, &prog.e_func].iter().all(|f| {
        f.iter().enumerate().all(|(pc, i)| {
            let tgt = match i {
                Instr::Jump(off) => Some(pc as i64 + *off as i64),
                Instr::FchTile { on_empty } => Some(pc as i64 + *on_empty as i64),
                _ => None,
            };
            tgt.map_or(true, |t| t >= 0 && (t as usize) < f.len())
        })
    })
}

#[cfg(test)]
mod tests {
    use super::super::{compile, OptLevel};
    use super::*;
    use crate::isa::{Dim, ElwUnary};
    use crate::models::{ModelKind, ModelSpec, NUM_RELATIONS};

    fn pipeline(kind: ModelKind, depth: u32) -> Vec<Program> {
        let spec = ModelSpec::new(kind, 8, &[], 8, depth).unwrap();
        (0..spec.depth())
            .map(|l| compile(&spec.build_layer(l), OptLevel::E2v).unwrap())
            .collect()
    }

    fn count_matching(f: &[Instr], pred: fn(&Instr) -> bool) -> usize {
        f.iter().filter(|i| pred(i)).count()
    }

    fn is_edge_load(i: &Instr) -> bool {
        matches!(i, Instr::Ld { target: LdTarget::Edge, .. })
    }

    fn is_weight_load(i: &Instr) -> bool {
        matches!(i, Instr::Ld { target: LdTarget::Weight, .. })
    }

    #[test]
    fn passset_parse_and_display() {
        assert_eq!(PassSet::parse("load_elim+hoist").unwrap().to_string(), "load_elim,hoist");
        assert_eq!(PassSet::all().to_string(), "all");
        assert_eq!(PassSet::none().to_string(), "none");
        assert_eq!(PassSet::parse("dbe, fuse").unwrap().to_string(), "fuse,dbe");
        assert!(PassSet::parse("fuse,bogus").is_none());
        assert_eq!(PassSet::every_subset().count(), 16);
        for s in PassSet::every_subset() {
            assert_eq!(PassSet::parse(&s.to_string()), Some(s), "{s} must round-trip");
        }
    }

    #[test]
    fn load_elim_drops_edge_loads_after_first_stage() {
        let mut progs = pipeline(ModelKind::Gcn, 3);
        let rep = optimize_pipeline(&mut progs, PassSet::LOAD_ELIM);
        assert_eq!(rep.passes[0].report.removed, 2);
        assert_eq!(count_matching(&progs[0].e_func, is_edge_load), 1, "stage 0 fills the hub");
        assert_eq!(count_matching(&progs[1].e_func, is_edge_load), 0);
        assert_eq!(count_matching(&progs[2].e_func, is_edge_load), 0);
        for p in &progs {
            assert!(d_layout_ok(p) && offsets_ok(p));
        }
        // idempotent: the hub is already resident
        let again = optimize_pipeline(&mut progs, PassSet::LOAD_ELIM);
        assert_eq!(again.passes[0].report.removed, 0);
    }

    #[test]
    fn load_elim_is_noop_on_single_stage() {
        let mut progs = pipeline(ModelKind::Gat, 1);
        let rep = optimize_pipeline(&mut progs, PassSet::LOAD_ELIM);
        assert_eq!(rep.passes[0].report.removed, 0);
        assert_eq!(rep.instructions_before, rep.instructions_after());
    }

    #[test]
    fn fuse_folds_hidden_relu_into_gemm() {
        let mut progs = pipeline(ModelKind::Gcn, 2);
        let relus = |p: &Program| {
            count_matching(&p.d_func, |i| {
                matches!(i, Instr::ElwU { op: ElwUnary::Relu, .. })
            })
        };
        assert_eq!(relus(&progs[0]), 1, "hidden layer carries a trailing ReLU");
        let rep = optimize_pipeline(&mut progs, PassSet::FUSE);
        assert!(rep.passes[0].report.fused >= 1);
        assert_eq!(relus(&progs[0]), 0);
        let fused_gemm = progs[0].d_func.iter().find_map(|i| match i {
            Instr::Gemm { dst, act: Some(op), .. } => Some((*dst, *op)),
            _ => None,
        });
        let (dst, op) = fused_gemm.expect("hidden-layer GEMM carries the fused ReLU");
        assert_eq!(op, ElwUnary::Relu);
        assert_eq!(dst, progs[0].output_buf, "fused GEMM writes the old ELW destination");
        // the final (linear) layer has nothing to fuse
        assert!(!progs[1].d_func.iter().any(|i| matches!(i, Instr::Gemm { act: Some(_), .. })));
        for p in &progs {
            assert!(d_layout_ok(p) && offsets_ok(p));
        }
    }

    #[test]
    fn fuse_requires_sole_reader() {
        // GGNN's GRU GEMMs all feed ELW.Add chains, never a sole-reader
        // unary successor in the d_func — nothing may fuse there
        let mut progs = pipeline(ModelKind::Ggnn, 1);
        let rep = optimize_pipeline(&mut progs, PassSet::FUSE);
        assert_eq!(
            count_matching(&progs[0].d_func, |i| matches!(i, Instr::Gemm { act: Some(_), .. })),
            0
        );
        let _ = rep;
    }

    #[test]
    fn hoist_moves_weight_fills_to_dfunction() {
        let mut progs = pipeline(ModelKind::Gat, 1);
        let s_fills = count_matching(&progs[0].s_func, is_weight_load);
        assert!(s_fills >= 1, "GAT fills weights per tile before hoisting");
        let rep = optimize_pipeline(&mut progs, PassSet::HOIST);
        assert_eq!(rep.passes[0].report.hoisted, s_fills);
        assert_eq!(count_matching(&progs[0].s_func, is_weight_load), 0);
        assert_eq!(count_matching(&progs[0].d_func, is_weight_load), s_fills);
        // fills sit in the pre region: after FCH.PTT, before SIGNAL.S
        assert!(is_weight_load(&progs[0].d_func[1]));
        assert!(d_layout_ok(&progs[0]) && offsets_ok(&progs[0]));
        // R-GCN keeps one fill per relation slice
        let mut progs = pipeline(ModelKind::Rgcn, 1);
        assert_eq!(count_matching(&progs[0].e_func, is_weight_load), NUM_RELATIONS as usize);
        optimize_pipeline(&mut progs, PassSet::HOIST);
        assert_eq!(count_matching(&progs[0].d_func, is_weight_load), NUM_RELATIONS as usize);
        assert!(d_layout_ok(&progs[0]) && offsets_ok(&progs[0]));
    }

    #[test]
    fn dbe_sweeps_fusion_orphans_and_never_resurrects() {
        let mut progs = pipeline(ModelKind::Gcn, 2);
        let rep = optimize_pipeline(&mut progs, PassSet::FUSE.with(PassSet::DBE));
        let dbe = rep.passes.iter().find(|p| p.pass == "dbe").unwrap();
        assert!(dbe.report.freed >= 1, "fusion orphans the old GEMM destination");
        let after: Vec<BTreeSet<BufId>> = progs.iter().map(referenced_bufs).collect();
        // a freed buffer stays dead: no instruction in any surviving
        // program references a buffer outside its referenced set
        for (p, bufs) in progs.iter().zip(&after) {
            for f in [&p.d_func, &p.s_func, &p.e_func] {
                for ins in f.iter() {
                    for b in reads(ins).into_iter().chain(writes(ins)) {
                        assert!(bufs.contains(&b));
                    }
                }
            }
            assert!(usize::from(p.part_bufs) >= 1);
        }
        // idempotent: a second sweep finds nothing
        let again = optimize_pipeline(&mut progs, PassSet::DBE);
        assert_eq!(again.passes[0].report.freed, 0);
        assert_eq!(again.passes[0].report.removed, 0);
    }

    #[test]
    fn dbe_removes_synthetic_dead_writes() {
        let mut progs = pipeline(ModelKind::Gcn, 1);
        let dead_buf = BufId(progs[0].tile_bufs);
        progs[0].tile_bufs += 1;
        insert_at(
            &mut progs[0].s_func,
            2,
            vec![Instr::ElwU {
                op: ElwUnary::Relu,
                src: BufId(0),
                dst: dead_buf,
                rows: Dim::TileSrc,
                cols: Dim::FeatIn,
            }],
        );
        assert!(offsets_ok(&progs[0]), "insert_at re-targets branches");
        let before = progs[0].instruction_count();
        let rep = optimize_pipeline(&mut progs, PassSet::DBE);
        assert_eq!(rep.passes[0].report.removed, 1);
        assert_eq!(rep.passes[0].report.freed, 1);
        assert_eq!(progs[0].instruction_count(), before - 1);
        assert!(d_layout_ok(&progs[0]) && offsets_ok(&progs[0]));
        assert_eq!(progs[0].tile_bufs, dead_buf.0, "high-water mark shrinks");
    }

    #[test]
    fn every_subset_preserves_protocol_and_monotone_counts() {
        for kind in ModelKind::ALL {
            for depth in [1u32, 3] {
                for passes in PassSet::every_subset() {
                    let mut progs = pipeline(kind, depth);
                    let gthr_before: usize = progs
                        .iter()
                        .map(|p| {
                            count_matching(&p.e_func, |i| matches!(i, Instr::Gthr { .. }))
                        })
                        .sum();
                    let rep = optimize_pipeline(&mut progs, passes);
                    let tag = format!("{} depth {depth} passes {passes}", kind.name());
                    // instruction counts monotonically non-increasing
                    let mut prev = rep.instructions_before;
                    for p in &rep.passes {
                        assert!(p.instructions_after <= prev, "{tag}: {} grew", p.pass);
                        prev = p.instructions_after;
                    }
                    for p in &progs {
                        assert!(d_layout_ok(p), "{tag}: dFunction layout broken");
                        assert!(offsets_ok(p), "{tag}: branch out of bounds");
                        // one ST.DST, gathers and accumulators untouched
                        assert_eq!(
                            count_matching(&p.d_func, |i| matches!(i, Instr::St { .. })),
                            1,
                            "{tag}"
                        );
                        assert!(!p.accumulators.is_empty(), "{tag}");
                    }
                    let gthr_after: usize = progs
                        .iter()
                        .map(|p| {
                            count_matching(&p.e_func, |i| matches!(i, Instr::Gthr { .. }))
                        })
                        .sum();
                    assert_eq!(gthr_before, gthr_after, "{tag}: a pass removed a GTHR");
                }
            }
        }
    }
}
