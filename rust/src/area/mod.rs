//! Area model (paper Table 5): per-component mm² at 16 nm.
//!
//! The paper reports synthesized/Cacti areas for its fixed configuration;
//! we keep those as the calibration point and scale linearly with unit
//! counts and memory capacities so the Fig 13 design-space exploration
//! can report area alongside latency.

use crate::config::ArchConfig;

/// Calibration constants: paper Table 5 at the Table 4 configuration.
const MU_MM2_AT_32X128: f64 = 1.00;
const VU_MM2_AT_8X32: f64 = 0.06;
const UEM_MM2_AT_21MB: f64 = 52.31;
const TH_MM2_AT_256KB: f64 = 0.15;

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AreaBreakdown {
    pub mu_mm2: f64,
    pub vu_mm2: f64,
    pub uem_mm2: f64,
    pub tile_hub_mm2: f64,
}

impl AreaBreakdown {
    pub fn total_mm2(&self) -> f64 {
        self.mu_mm2 + self.vu_mm2 + self.uem_mm2 + self.tile_hub_mm2
    }

    /// Memory share of total area (the paper highlights 97.91%).
    pub fn memory_fraction(&self) -> f64 {
        (self.uem_mm2 + self.tile_hub_mm2) / self.total_mm2()
    }
}

pub fn area(arch: &ArchConfig) -> AreaBreakdown {
    let mu_scale = (arch.mu_rows * arch.mu_cols) as f64 / (32.0 * 128.0);
    let vu_scale = (arch.vu_cores * arch.vu_lanes) as f64 / 256.0;
    AreaBreakdown {
        mu_mm2: arch.mu_count as f64 * MU_MM2_AT_32X128 * mu_scale,
        vu_mm2: arch.vu_count as f64 * VU_MM2_AT_8X32 * vu_scale,
        uem_mm2: UEM_MM2_AT_21MB * arch.uem_bytes as f64 / (21.0 * 1024.0 * 1024.0),
        tile_hub_mm2: TH_MM2_AT_256KB * arch.tile_hub_bytes as f64 / (256.0 * 1024.0),
    }
}

/// V100 die size (mm²) — the paper's "6.57% of the baseline GPU die".
pub const V100_DIE_MM2: f64 = 815.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_table5() {
        let a = area(&ArchConfig::default());
        assert!((a.mu_mm2 - 1.00).abs() < 1e-9);
        assert!((a.vu_mm2 - 0.12).abs() < 1e-9); // 2 VUs × 0.06
        assert!((a.uem_mm2 - 52.31).abs() < 1e-9);
        assert!((a.tile_hub_mm2 - 0.15).abs() < 1e-9);
        assert!((a.total_mm2() - 53.58).abs() < 0.01);
        // paper: on-chip memory ≈ 97.9% of area
        assert!((a.memory_fraction() - 0.979).abs() < 0.002);
        // paper: 6.57% of the GPU die
        assert!((a.total_mm2() / V100_DIE_MM2 - 0.0657).abs() < 0.001);
    }

    #[test]
    fn scales_with_units() {
        let mut arch = ArchConfig::default();
        arch.mu_count = 2;
        arch.vu_count = 4;
        let a = area(&arch);
        assert!((a.mu_mm2 - 2.0).abs() < 1e-9);
        assert!((a.vu_mm2 - 0.24).abs() < 1e-9);
    }
}
