//! # ZIPPER — tile- and operator-level parallel GNN acceleration
//!
//! A production-quality reproduction of *ZIPPER: Exploiting Tile- and
//! Operator-level Parallelism for General and Scalable Graph Neural
//! Network Acceleration* (Zhang et al., 2021) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's full system: graph substrate,
//!   tiling engine, graph-native GNN IR + compiler, ZIPPER ISA,
//!   cycle-level accelerator simulator with functional execution, energy
//!   and area models, analytic CPU/GPU/HyGCN baselines, and a serving
//!   coordinator.
//! * **L2 (python/compile)** — the five GNN models in JAX, AOT-lowered
//!   once to HLO text artifacts executed via PJRT (`runtime`) as the
//!   numerical oracle.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the compute
//!   hot-spots (MU-tiled GEMM, GOP scatter/gather, fused ELW).
//!
//! The serving pipeline is *compile-once*: `plan::ExecPlan` bundles the
//! immutable artifacts (tiling + compiled program + weights) produced
//! once per operating point, and every consumer — simulator, serving
//! coordinator, benches — runs off a shared `Arc<ExecPlan>` with
//! per-request state confined to a reusable `sim::ExecScratch`.
//!
//! See DESIGN.md for the layer and module map (including the split
//! simulator engine and the ExecPlan pipeline).

pub mod area;
pub mod baselines;
pub mod compiler;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod graph;
pub mod ir;
pub mod isa;
pub mod metrics;
pub mod models;
pub mod plan;
pub mod runtime;
pub mod sim;
pub mod tiling;
pub mod util;
