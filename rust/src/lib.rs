//! # ZIPPER — tile- and operator-level parallel GNN acceleration
//!
//! A production-quality reproduction of *ZIPPER: Exploiting Tile- and
//! Operator-level Parallelism for General and Scalable Graph Neural
//! Network Acceleration* (Zhang et al., 2021) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's full system: graph substrate,
//!   tiling engine, graph-native GNN IR + compiler, ZIPPER ISA,
//!   cycle-level accelerator simulator with functional execution, energy
//!   and area models, analytic CPU/GPU/HyGCN baselines, and a serving
//!   coordinator.
//! * **L2 (python/compile)** — the five GNN models in JAX, AOT-lowered
//!   once to HLO text artifacts executed via PJRT (`runtime`) as the
//!   numerical oracle.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the compute
//!   hot-spots (MU-tiled GEMM, GOP scatter/gather, fused ELW).
//!
//! The serving pipeline is *compile-once* and *batch-parallel*:
//! [`plan::ExecPlan`] bundles the immutable artifacts — ONE shared
//! tiling plus a pipeline of per-layer compiled programs + weights
//! (multi-layer models via [`models::ModelSpec`]) — produced once per
//! operating point, and every
//! consumer — simulator, serving coordinator, benches — runs off a
//! shared `Arc<ExecPlan>` with per-request state confined to reusable
//! scratches ([`sim::ExecScratch`] for the discrete-event engine,
//! [`sim::parallel::BatchScratch`] for the tile-parallel batched
//! functional executor). The coordinator's [`coordinator::BatchPlanner`]
//! groups queued requests sharing one plan so a batch costs one timing
//! simulation plus one batched functional pass, with outputs
//! bit-identical to sequential serving for any thread count.
//!
//! Quickstart (see README.md for the full tour):
//!
//! ```
//! use zipper::config::{ArchConfig, RunConfig};
//! use zipper::coordinator::Session;
//!
//! let mut run = RunConfig::default();
//! run.dataset = "CR".into();
//! run.scale = 64;
//! run.feat_in = 8;
//! run.feat_out = 8;
//! let session = Session::prepare(&run).unwrap();
//! let res = session.simulate(&ArchConfig::default(), false, None, 0).unwrap();
//! assert!(res.cycles > 0);
//! ```
//!
//! See DESIGN.md for the layer and module map (including the split
//! simulator engine, the ExecPlan pipeline, and the §3.3 tile-parallel
//! execution + request batching design).

pub mod area;
pub mod baselines;
pub mod compiler;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod graph;
pub mod ir;
pub mod isa;
pub mod metrics;
pub mod models;
pub mod plan;
pub mod runtime;
pub mod sim;
pub mod tiling;
pub mod util;
