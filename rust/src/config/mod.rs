//! Configuration system: architecture + run parameters (paper Table 4),
//! loadable from an INI/TOML-lite file and overridable from the CLI.

use std::fmt;

/// ZIPPER architecture parameters (defaults = paper Table 4).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ArchConfig {
    /// Clock frequency in Hz (1 GHz).
    pub freq_hz: f64,
    /// Matrix Units: 32×128 output-stationary systolic arrays.
    pub mu_count: u32,
    pub mu_rows: u32,
    pub mu_cols: u32,
    /// Vector Units: each 8 SIMD cores × 32 lanes.
    pub vu_count: u32,
    pub vu_cores: u32,
    pub vu_lanes: u32,
    /// Unified embedding memory (eDRAM), bytes. Paper: 21 MB.
    pub uem_bytes: u64,
    /// eDRAM banks (multi-banked so units can stream concurrently).
    pub uem_banks: u32,
    /// Tile hub (SRAM) bytes. Paper: 256 KB.
    pub tile_hub_bytes: u64,
    /// Off-chip bandwidth, bytes/s. Paper: HBM-1.0, 256 GB/s.
    pub hbm_bytes_per_sec: f64,
    /// Average HBM access latency in cycles (row activation + burst).
    pub hbm_latency_cycles: u64,
    /// Stream counts (paper: 1 dStream, 4 sStreams, 4 eStreams).
    pub s_streams: u32,
    pub e_streams: u32,
}

impl Default for ArchConfig {
    fn default() -> Self {
        ArchConfig {
            freq_hz: 1.0e9,
            mu_count: 1,
            mu_rows: 32,
            mu_cols: 128,
            vu_count: 2,
            vu_cores: 8,
            vu_lanes: 32,
            uem_bytes: 21 * 1024 * 1024,
            uem_banks: 16,
            tile_hub_bytes: 256 * 1024,
            hbm_bytes_per_sec: 256.0e9,
            hbm_latency_cycles: 64,
            s_streams: 4,
            e_streams: 4,
        }
    }
}

impl ArchConfig {
    /// Peak MACs/cycle of one MU.
    pub fn mu_macs_per_cycle(&self) -> u64 {
        (self.mu_rows * self.mu_cols) as u64
    }

    /// SIMD lanes of one VU.
    pub fn vu_width(&self) -> u64 {
        (self.vu_cores * self.vu_lanes) as u64
    }

    /// Off-chip bytes per cycle.
    pub fn hbm_bytes_per_cycle(&self) -> f64 {
        self.hbm_bytes_per_sec / self.freq_hz
    }

    /// Peak FLOP/s (MACs count as 2 FLOPs) across MUs + VUs.
    pub fn peak_flops(&self) -> f64 {
        let mu = self.mu_count as f64 * self.mu_macs_per_cycle() as f64 * 2.0;
        let vu = self.vu_count as f64 * self.vu_width() as f64;
        (mu + vu) * self.freq_hz
    }
}

/// What a full admission queue does to the next submit
/// (`[serving] overflow`). `Reject` sheds it immediately with a
/// structured `QueueFull` reason; `Block` parks the submitting thread
/// until capacity frees or the service shuts down.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OverflowPolicy {
    Reject,
    Block,
}

impl OverflowPolicy {
    pub fn name(self) -> &'static str {
        match self {
            OverflowPolicy::Reject => "reject",
            OverflowPolicy::Block => "block",
        }
    }

    pub fn parse(s: &str) -> Option<OverflowPolicy> {
        match s {
            "reject" => Some(OverflowPolicy::Reject),
            "block" => Some(OverflowPolicy::Block),
            _ => None,
        }
    }
}

/// Serving-layer knobs (`[serving]` section / `--exec-threads`,
/// `--max-batch`, `--max-wait-us`, `--queue-cap`, `--overflow`,
/// `--deadline-us`). Host-side only: like `TilingConfig::threads`, these
/// never change compiled artifacts or outputs — they shape *when* work
/// runs and what gets shed under load, not what it computes.
///
/// * `exec_threads` / `max_batch` — the batched functional pass (PR 3):
///   tile-parallel execution width and the per-plan batch cap.
/// * `max_wait_us` — the second batching trigger: a partial batch
///   flushes once its oldest request has waited this long (dispatcher
///   timer in `coordinator::service`). 0 disables the timer: partial
///   batches flush only when full or at drain/shutdown, the classic
///   closed-loop `Coordinator` behavior.
/// * `queue_cap` — bounded admission: max requests admitted but not yet
///   picked up by a worker (accumulating + ready batches).
/// * `overflow` — what a full queue does to the next submit.
/// * `default_deadline_us` — deadline applied to requests that don't
///   carry their own. 0 = no default deadline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServingConfig {
    /// OS threads for tile-parallel functional execution per batch.
    pub exec_threads: u32,
    /// Max requests sharing one `ExecPlan` grouped into one batch.
    pub max_batch: u32,
    /// Partial-batch flush timer in microseconds (0 = disabled).
    pub max_wait_us: u64,
    /// Bounded admission-queue capacity (requests, not batches).
    pub queue_cap: u32,
    /// Full-queue policy: shed (`Reject`) or backpressure (`Block`).
    pub overflow: OverflowPolicy,
    /// Default per-request deadline in microseconds (0 = none).
    pub default_deadline_us: u64,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            exec_threads: 1,
            max_batch: 1,
            max_wait_us: 0,
            queue_cap: 1024,
            overflow: OverflowPolicy::Reject,
            default_deadline_us: 0,
        }
    }
}

/// Storage precision for weights and inter-layer activations
/// (`[kernels] dtype`). Accumulation is always f32; a non-f32 dtype only
/// narrows what is *stored* across the load boundary, with
/// round-to-nearest-even conversion (see `sim::tensor` and DESIGN.md
/// "Kernel policies" for the error bound).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum StorageDtype {
    F32,
    F16,
    Bf16,
}

impl StorageDtype {
    pub fn name(self) -> &'static str {
        match self {
            StorageDtype::F32 => "f32",
            StorageDtype::F16 => "f16",
            StorageDtype::Bf16 => "bf16",
        }
    }

    pub fn parse(s: &str) -> Option<StorageDtype> {
        match s {
            "f32" => Some(StorageDtype::F32),
            "f16" => Some(StorageDtype::F16),
            "bf16" => Some(StorageDtype::Bf16),
            _ => None,
        }
    }

    /// Bytes per stored element.
    pub fn bytes(self) -> u64 {
        match self {
            StorageDtype::F32 => 4,
            StorageDtype::F16 | StorageDtype::Bf16 => 2,
        }
    }

    /// Unit roundoff u of the storage format: |q(v) − v| ≤ u·|v| for
    /// finite v in range (f16: 2⁻¹¹, bf16: 2⁻⁸, f32: 0 — identity).
    pub fn unit_roundoff(self) -> f32 {
        match self {
            StorageDtype::F32 => 0.0,
            StorageDtype::F16 => 1.0 / 2048.0,
            StorageDtype::Bf16 => 1.0 / 256.0,
        }
    }
}

/// Per-plan kernel policy (`[kernels]` section / `--simd` / `--dtype` /
/// `--sparse-skip`). Unlike the serving knobs this IS part of the plan
/// identity (`plan::PlanKey`): variants never alias in the plan cache.
///
/// * `simd` — use the lane-array kernels in `sim::tensor`; bit-exact
///   with the scalar reference on identical inputs (asserted in tests
///   and `perf_hotpath`).
/// * `sparse_skip` — skip untouched source-row blocks of partially
///   occupied tiles in tile-phase GEMM compute and LD.SRC traffic
///   (final outputs are invariant; see `tiling::Tile::occupancy`).
/// * `dtype` — storage precision for weights + inter-layer activations.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct KernelPolicy {
    pub simd: bool,
    pub sparse_skip: bool,
    pub dtype: StorageDtype,
}

impl Default for KernelPolicy {
    fn default() -> Self {
        KernelPolicy {
            // The `simd` cargo feature (on by default) selects the
            // vectorized kernels by default; scalar stays available as
            // the reference oracle either way.
            simd: cfg!(feature = "simd"),
            // Off by default: keeps the paper-faithful regular-mode
            // cycle numbers unless a run opts in.
            sparse_skip: false,
            dtype: StorageDtype::F32,
        }
    }
}

impl KernelPolicy {
    /// Reject dtypes whose config surface is not compiled in. The
    /// conversion routines are always built (dependency-free); the
    /// `half` feature only gates *selecting* them, so CI's feature
    /// matrix keeps every combination building.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.dtype != StorageDtype::F32 && !cfg!(feature = "half") {
            return Err(ConfigError(format!(
                "dtype {} requires a build with --features half",
                self.dtype.name()
            )));
        }
        Ok(())
    }
}

/// Run parameters: model, dataset, tiling, optimization toggles.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    pub model: String,
    pub dataset: String,
    /// Dataset scale divisor (DESIGN.md §5): 1 = published size.
    pub scale: u64,
    pub feat_in: u32,
    pub feat_out: u32,
    /// Pipeline depth: number of stacked GNN layers (0 is treated as 1).
    /// Layer *l*'s output embedding feeds layer *l+1*; hidden layers are
    /// ReLU-activated, the final layer is linear (`models::ModelSpec`).
    pub layers: u32,
    /// Hidden embedding widths between layers: exactly `layers − 1`
    /// entries, or empty (every hidden width defaults to `feat_out`).
    pub hidden: Vec<u32>,
    pub tiling: crate::tiling::TilingConfig,
    /// Compiler optimization level.
    pub e2v: bool,
    /// Pipeline-optimizer passes (`[run] passes`, `--passes`): run over
    /// the whole compiled layer stack after per-layer lowering. Requires
    /// `e2v` (the pipeline passes assume e2v-lowered programs). Part of
    /// the plan identity — see `plan::PlanKey`. Empty = per-layer
    /// lowering only (the pre-optimizer behavior).
    pub passes: crate::compiler::PassSet,
    /// Execute functionally (compute embeddings) as well as timing.
    pub functional: bool,
    pub seed: u64,
    /// Multi-chip shard count (DESIGN.md §3.8): 1 = single-chip (the
    /// default, no partitioning); K ≥ 2 splits the graph into K shards
    /// that execute concurrently with per-layer halo exchange. Part of
    /// the plan identity — see `plan::PlanKey`.
    pub shards: u32,
    /// Operator-level overlap (DESIGN.md §3.9): when true and `shards`
    /// ≥ 2, each layer boundary fires the halo exchange concurrently
    /// with the next layer's halo-independent tiles, billing
    /// `max(exchange, independent) + dependent` instead of the serial
    /// sum. Functional outputs are bit-exact either way; only the
    /// timing model changes. Part of the plan identity — see
    /// `plan::PlanKey`. No effect on unsharded plans.
    pub overlap: bool,
    /// Coordinator serving knobs (never part of the plan identity).
    pub serving: ServingConfig,
    /// Kernel policy (part of the plan identity — see `plan::PlanKey`).
    pub kernels: KernelPolicy,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "gcn".into(),
            dataset: "AK".into(),
            scale: 64,
            feat_in: 128,
            feat_out: 128,
            layers: 1,
            hidden: Vec::new(),
            tiling: crate::tiling::TilingConfig::default(),
            e2v: true,
            passes: crate::compiler::PassSet::none(),
            functional: false,
            seed: 42,
            shards: 1,
            overlap: false,
            serving: ServingConfig::default(),
            kernels: KernelPolicy::default(),
        }
    }
}

#[derive(Debug)]
pub struct ConfigError(pub String);

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

/// Parse an INI/TOML-lite document: `[section]` headers and
/// `key = value` lines; `#`/`;` comments. Returns (section, key, value)
/// triples in file order.
pub fn parse_ini(text: &str) -> Result<Vec<(String, String, String)>, ConfigError> {
    let mut out = Vec::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with(';') {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or_else(|| {
                ConfigError(format!("line {}: unterminated section", lineno + 1))
            })?;
            section = name.trim().to_string();
            continue;
        }
        let (k, v) = line.split_once('=').ok_or_else(|| {
            ConfigError(format!("line {}: expected key = value", lineno + 1))
        })?;
        let v = v.trim().trim_matches('"');
        out.push((section.clone(), k.trim().to_string(), v.to_string()));
    }
    Ok(out)
}

/// Apply a config document to (arch, run). Unknown keys error loudly.
pub fn apply(
    text: &str,
    arch: &mut ArchConfig,
    run: &mut RunConfig,
) -> Result<(), ConfigError> {
    use crate::tiling::{Reorder, TilingMode};
    for (section, key, value) in parse_ini(text)? {
        let num = || -> Result<f64, ConfigError> {
            value
                .parse::<f64>()
                .map_err(|_| ConfigError(format!("{section}.{key}: not a number: {value}")))
        };
        let boolean = || -> Result<bool, ConfigError> {
            match value.as_str() {
                "true" | "1" | "yes" => Ok(true),
                "false" | "0" | "no" => Ok(false),
                _ => Err(ConfigError(format!("{section}.{key}: not a bool: {value}"))),
            }
        };
        match (section.as_str(), key.as_str()) {
            ("arch", "freq_hz") => arch.freq_hz = num()?,
            ("arch", "mu_count") => arch.mu_count = num()? as u32,
            ("arch", "mu_rows") => arch.mu_rows = num()? as u32,
            ("arch", "mu_cols") => arch.mu_cols = num()? as u32,
            ("arch", "vu_count") => arch.vu_count = num()? as u32,
            ("arch", "vu_cores") => arch.vu_cores = num()? as u32,
            ("arch", "vu_lanes") => arch.vu_lanes = num()? as u32,
            ("arch", "uem_mb") => arch.uem_bytes = (num()? * 1024.0 * 1024.0) as u64,
            ("arch", "uem_banks") => arch.uem_banks = num()? as u32,
            ("arch", "tile_hub_kb") => arch.tile_hub_bytes = (num()? * 1024.0) as u64,
            ("arch", "hbm_gbps") => arch.hbm_bytes_per_sec = num()? * 1.0e9,
            ("arch", "hbm_latency_cycles") => arch.hbm_latency_cycles = num()? as u64,
            ("arch", "s_streams") => arch.s_streams = num()? as u32,
            ("arch", "e_streams") => arch.e_streams = num()? as u32,
            ("run", "model") => run.model = value.clone(),
            ("run", "dataset") => run.dataset = value.clone(),
            ("run", "scale") => run.scale = num()? as u64,
            ("run", "feat_in") => run.feat_in = num()? as u32,
            ("run", "feat_out") => run.feat_out = num()? as u32,
            ("run", "layers") => run.layers = num()? as u32,
            ("run", "hidden") => {
                run.hidden = value
                    .split(',')
                    .map(str::trim)
                    .filter(|s| !s.is_empty())
                    .map(|s| {
                        s.parse::<u32>().map_err(|_| {
                            ConfigError(format!("{section}.{key}: not a width: {s}"))
                        })
                    })
                    .collect::<Result<Vec<u32>, ConfigError>>()?;
            }
            ("run", "e2v") => run.e2v = boolean()?,
            ("run", "passes") => {
                run.passes =
                    crate::compiler::PassSet::parse(&value).ok_or_else(|| {
                        ConfigError(format!(
                            "unknown pass set {value} (all | none | load_elim,fuse,hoist,dbe)"
                        ))
                    })?;
            }
            ("run", "functional") => run.functional = boolean()?,
            ("run", "seed") => run.seed = num()? as u64,
            ("run", "shards") => {
                run.shards = num()? as u32;
                if run.shards == 0 {
                    return Err(ConfigError("shards must be >= 1".into()));
                }
            }
            ("run", "overlap") => run.overlap = boolean()?,
            ("serving", "exec_threads") => run.serving.exec_threads = num()? as u32,
            ("serving", "max_batch") => run.serving.max_batch = num()? as u32,
            ("serving", "max_wait_us") => run.serving.max_wait_us = num()? as u64,
            ("serving", "queue_cap") => run.serving.queue_cap = num()? as u32,
            ("serving", "overflow") => {
                run.serving.overflow = OverflowPolicy::parse(&value).ok_or_else(|| {
                    ConfigError(format!("unknown overflow policy {value} (reject | block)"))
                })?;
            }
            ("serving", "default_deadline_us") => {
                run.serving.default_deadline_us = num()? as u64;
            }
            ("kernels", "simd") => run.kernels.simd = boolean()?,
            ("kernels", "sparse_skip") => run.kernels.sparse_skip = boolean()?,
            ("kernels", "dtype") => {
                run.kernels.dtype = StorageDtype::parse(&value).ok_or_else(|| {
                    ConfigError(format!("unknown dtype {value} (f32 | f16 | bf16)"))
                })?;
                run.kernels.validate()?;
            }
            ("tiling", "dst_part") => run.tiling.dst_part = num()? as u32,
            ("tiling", "src_part") => run.tiling.src_part = num()? as u32,
            ("tiling", "threads") => run.tiling.threads = num()? as u32,
            ("tiling", "mode") => {
                run.tiling.mode = match value.as_str() {
                    "regular" => TilingMode::Regular,
                    "sparse" => TilingMode::Sparse,
                    _ => return Err(ConfigError(format!("unknown tiling mode {value}"))),
                }
            }
            ("tiling", "reorder") => {
                run.tiling.reorder = match value.as_str() {
                    "none" => Reorder::None,
                    "in_degree" => Reorder::InDegree,
                    "out_degree" => Reorder::OutDegree,
                    _ => return Err(ConfigError(format!("unknown reorder {value}"))),
                }
            }
            _ => {
                return Err(ConfigError(format!(
                    "unknown config key [{section}] {key}"
                )))
            }
        }
    }
    Ok(())
}

/// Render the effective configuration (for `zipper config --show`).
pub fn show(arch: &ArchConfig, run: &RunConfig) -> String {
    let hidden = if run.hidden.is_empty() {
        "(default)".to_string()
    } else {
        run.hidden
            .iter()
            .map(|h| h.to_string())
            .collect::<Vec<_>>()
            .join(",")
    };
    format!(
        "[arch]\nfreq_hz = {}\nmu_count = {} ({}x{})\nvu_count = {} ({}x{} lanes)\n\
         uem = {} ({} banks)\ntile_hub = {}\nhbm = {:.0} GB/s (latency {} cyc)\n\
         streams = 1d/{}s/{}e\npeak = {:.2} TFLOP/s\n\n\
         [run]\nmodel = {}\ndataset = {}\nscale = 1/{}\nfeat = {}x{}\n\
         layers = {}\nhidden = {}\n\
         e2v = {}\npasses = {}\nfunctional = {}\nseed = {}\nshards = {}\noverlap = {}\n\n\
         [serving]\nexec_threads = {}\nmax_batch = {}\nmax_wait_us = {}\n\
         queue_cap = {}\noverflow = {}\ndefault_deadline_us = {}\n\n\
         [kernels]\nsimd = {}\nsparse_skip = {}\ndtype = {}\n\n\
         [tiling]\ndst_part = {}\nsrc_part = {}\nmode = {:?}\nreorder = {:?}\nthreads = {}\n",
        arch.freq_hz,
        arch.mu_count,
        arch.mu_rows,
        arch.mu_cols,
        arch.vu_count,
        arch.vu_cores,
        arch.vu_lanes,
        crate::util::fmt_bytes(arch.uem_bytes),
        arch.uem_banks,
        crate::util::fmt_bytes(arch.tile_hub_bytes),
        arch.hbm_bytes_per_sec / 1.0e9,
        arch.hbm_latency_cycles,
        arch.s_streams,
        arch.e_streams,
        arch.peak_flops() / 1.0e12,
        run.model,
        run.dataset,
        run.scale,
        run.feat_in,
        run.feat_out,
        run.layers,
        hidden,
        run.e2v,
        run.passes,
        run.functional,
        run.seed,
        run.shards,
        run.overlap,
        run.serving.exec_threads,
        run.serving.max_batch,
        run.serving.max_wait_us,
        run.serving.queue_cap,
        run.serving.overflow.name(),
        run.serving.default_deadline_us,
        run.kernels.simd,
        run.kernels.sparse_skip,
        run.kernels.dtype.name(),
        run.tiling.dst_part,
        run.tiling.src_part,
        run.tiling.mode,
        run.tiling.reorder,
        run.tiling.threads,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_table4() {
        let a = ArchConfig::default();
        assert_eq!(a.mu_rows * a.mu_cols, 32 * 128);
        assert_eq!(a.vu_count, 2);
        assert_eq!(a.vu_cores * a.vu_lanes, 256);
        assert_eq!(a.uem_bytes, 21 * 1024 * 1024);
        assert_eq!(a.tile_hub_bytes, 256 * 1024);
        assert_eq!(a.s_streams, 4);
        assert_eq!(a.e_streams, 4);
        // 1 MU × 4096 MACs × 2 × 1 GHz + 2 VU × 256 × 1 GHz ≈ 8.7 TFLOPs
        assert!((a.peak_flops() - 8.704e12).abs() / 8.704e12 < 1e-9);
    }

    #[test]
    fn ini_parse_and_apply() {
        let doc = r#"
            # comment
            [arch]
            mu_count = 2
            hbm_gbps = 512
            [run]
            model = "gat"
            scale = 16
            layers = 3
            hidden = "64, 32"
            shards = 2
            overlap = true
            [serving]
            exec_threads = 4
            max_batch = 8
            max_wait_us = 250
            queue_cap = 64
            overflow = block
            default_deadline_us = 20000
            [kernels]
            simd = false
            sparse_skip = true
            [tiling]
            mode = regular
            reorder = none
            threads = 4
        "#;
        let mut arch = ArchConfig::default();
        let mut run = RunConfig::default();
        apply(doc, &mut arch, &mut run).unwrap();
        assert_eq!(arch.mu_count, 2);
        assert_eq!(arch.hbm_bytes_per_sec, 512.0e9);
        assert_eq!(run.model, "gat");
        assert_eq!(run.scale, 16);
        assert_eq!(run.layers, 3);
        assert_eq!(run.hidden, vec![64, 32]);
        assert_eq!(run.shards, 2);
        assert!(run.overlap);
        assert_eq!(
            run.serving,
            ServingConfig {
                exec_threads: 4,
                max_batch: 8,
                max_wait_us: 250,
                queue_cap: 64,
                overflow: OverflowPolicy::Block,
                default_deadline_us: 20_000,
            }
        );
        assert!(!run.kernels.simd);
        assert!(run.kernels.sparse_skip);
        assert_eq!(run.kernels.dtype, StorageDtype::F32);
        assert_eq!(run.tiling.mode, crate::tiling::TilingMode::Regular);
        assert_eq!(run.tiling.threads, 4);
    }

    #[test]
    fn kernels_dtype_parses_or_reports_missing_feature() {
        let mut arch = ArchConfig::default();
        let mut run = RunConfig::default();
        let res = apply("[kernels]\ndtype = f16\n", &mut arch, &mut run);
        if cfg!(feature = "half") {
            res.unwrap();
            assert_eq!(run.kernels.dtype, StorageDtype::F16);
        } else {
            assert!(res.unwrap_err().to_string().contains("--features half"));
        }
        assert!(apply("[kernels]\ndtype = f8\n", &mut arch, &mut run).is_err());
    }

    #[test]
    fn dtype_facts() {
        assert_eq!(StorageDtype::parse("bf16"), Some(StorageDtype::Bf16));
        assert_eq!(StorageDtype::F16.bytes(), 2);
        assert_eq!(StorageDtype::F32.bytes(), 4);
        assert_eq!(StorageDtype::F16.unit_roundoff(), 2f32.powi(-11));
        assert_eq!(StorageDtype::Bf16.unit_roundoff(), 2f32.powi(-8));
        assert_eq!(StorageDtype::F32.unit_roundoff(), 0.0);
    }

    #[test]
    fn overflow_policy_parses_or_rejects() {
        let mut arch = ArchConfig::default();
        let mut run = RunConfig::default();
        apply("[serving]\noverflow = block\n", &mut arch, &mut run).unwrap();
        assert_eq!(run.serving.overflow, OverflowPolicy::Block);
        let err = apply("[serving]\noverflow = drop\n", &mut arch, &mut run).unwrap_err();
        assert!(err.to_string().contains("reject | block"), "{err}");
        assert_eq!(OverflowPolicy::parse("reject"), Some(OverflowPolicy::Reject));
        assert_eq!(OverflowPolicy::Reject.name(), "reject");
    }

    #[test]
    fn passes_parse_or_reject() {
        use crate::compiler::PassSet;
        let mut arch = ArchConfig::default();
        let mut run = RunConfig::default();
        assert_eq!(run.passes, PassSet::none());
        apply("[run]\npasses = all\n", &mut arch, &mut run).unwrap();
        assert_eq!(run.passes, PassSet::all());
        apply("[run]\npasses = load_elim,dbe\n", &mut arch, &mut run).unwrap();
        assert!(run.passes.contains(PassSet::LOAD_ELIM));
        assert!(run.passes.contains(PassSet::DBE));
        assert!(!run.passes.contains(PassSet::FUSE));
        let err = apply("[run]\npasses = warp\n", &mut arch, &mut run).unwrap_err();
        assert!(err.to_string().contains("unknown pass set"), "{err}");
    }

    #[test]
    fn shards_parse_or_reject() {
        let mut arch = ArchConfig::default();
        let mut run = RunConfig::default();
        assert_eq!(run.shards, 1);
        apply("[run]\nshards = 4\n", &mut arch, &mut run).unwrap();
        assert_eq!(run.shards, 4);
        let err = apply("[run]\nshards = 0\n", &mut arch, &mut run).unwrap_err();
        assert!(err.to_string().contains("shards must be >= 1"), "{err}");
    }

    #[test]
    fn unknown_key_rejected() {
        let mut arch = ArchConfig::default();
        let mut run = RunConfig::default();
        assert!(apply("[arch]\nwarp_size = 32\n", &mut arch, &mut run).is_err());
        assert!(apply("[arch\nx=1", &mut arch, &mut run).is_err());
        assert!(apply("[arch]\nmu_count three\n", &mut arch, &mut run).is_err());
    }

    #[test]
    fn show_roundtrips_key_facts() {
        let s = show(&ArchConfig::default(), &RunConfig::default());
        assert!(s.contains("mu_count = 1 (32x128)"));
        assert!(s.contains("21.00 MB"));
        assert!(s.contains("[serving]") && s.contains("max_batch = 1"));
        assert!(s.contains("queue_cap = 1024") && s.contains("overflow = reject"));
        assert!(s.contains("max_wait_us = 0") && s.contains("default_deadline_us = 0"));
        assert!(s.contains("[kernels]") && s.contains("dtype = f32"));
        assert!(s.contains("layers = 1") && s.contains("hidden = (default)"));
        assert!(s.contains("passes = none"));
        assert!(s.contains("shards = 1"));
        assert!(s.contains("overlap = false"));
        let run = RunConfig { layers: 3, hidden: vec![64, 32], ..RunConfig::default() };
        let s = show(&ArchConfig::default(), &run);
        assert!(s.contains("layers = 3") && s.contains("hidden = 64,32"));
    }
}
