//! Public data types of the simulator facade: the workload borrow
//! bundle, run options, and the result record.

use crate::config::ArchConfig;
use crate::energy::EnergyCounters;
use crate::metrics::TraceSample;

/// Everything a simulation run needs. Usually built from an
/// `plan::ExecPlan` via `ExecPlan::workload`, but the loose-reference
/// form is kept for tests and ad-hoc callers.
pub struct Workload<'a> {
    pub program: &'a crate::compiler::Program,
    pub tiling: &'a crate::tiling::Tiling,
    pub weights: &'a crate::models::WeightStore,
    pub feat_in: u32,
    pub feat_out: u32,
    /// Input embeddings in ORIGINAL vertex order, (V × feat_in) row-major.
    /// Required when `SimOptions::functional` is set.
    pub x: Option<&'a [f32]>,
    /// Kernel-variant selection (SIMD / sparsity skipping / storage
    /// dtype). Part of the plan identity — see `plan::PlanKey`.
    pub kernels: crate::config::KernelPolicy,
}

#[derive(Clone, Copy, Debug)]
pub struct SimOptions {
    pub functional: bool,
    /// Trace window in cycles (0 = no trace).
    pub trace_window: u64,
    /// Materialize `SimResult::output` as a fresh caller-owned vector
    /// (functional runs). Hidden layers of a multi-layer pipeline set
    /// this to `false`: the still-tiled output image stays pooled in the
    /// scratch and is chained into the next layer without allocating.
    pub emit_output: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { functional: false, trace_window: 0, emit_output: true }
    }
}

/// Per-layer slice of a multi-layer pipeline run (`SimResult::layers`):
/// the Fig 2-style depth-cost breakdown. Cycles/DRAM/energy counters are
/// additive across layers; `peak_uem_bytes` is this layer's tile-resident
/// peak (the plan-level aggregate adds inter-layer activation footprint).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LayerMetrics {
    pub feat_in: u32,
    pub feat_out: u32,
    pub cycles: u64,
    pub instructions: u64,
    pub dram_read_bytes: u64,
    pub dram_write_bytes: u64,
    pub peak_uem_bytes: u64,
    pub counters: EnergyCounters,
}

/// Inter-shard halo-exchange accounting for sharded (multi-chip) runs
/// (DESIGN.md §3.8). All-zero for unsharded plans.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HaloMetrics {
    /// Layer boundaries at which an exchange happened (K>1 runs only).
    pub exchanges: u64,
    /// Halo vertex activations copied across shards, summed over
    /// boundaries (one copy = one vertex row into one consumer shard).
    pub vertices: u64,
    /// Bytes moved chip-to-chip, counting both the producer write and
    /// the consumer read (2× the activation payload).
    pub bytes: u64,
    /// Total modeled exchange cycles across all boundaries, hidden or
    /// not: `cycles == hidden_cycles + exposed_cycles`.
    pub cycles: u64,
    /// Exchange cycles hidden behind halo-independent tile compute by
    /// the operator-level overlap schedule (DESIGN.md §3.9). Always 0
    /// for overlap-off plans.
    pub hidden_cycles: u64,
    /// Exchange cycles left on the critical path, folded into
    /// `SimResult::cycles` and the layer breakdown. Equals `cycles`
    /// for overlap-off plans.
    pub exposed_cycles: u64,
}

/// Simulation result: timing, utilization, energy events, output.
#[derive(Clone, Debug, Default)]
pub struct SimResult {
    pub cycles: u64,
    pub instructions: u64,
    pub counters: EnergyCounters,
    pub mu_busy: u64,
    pub vu_busy: u64,
    pub mem_busy: u64,
    /// Off-chip reads only (Fig 11's reduction metric).
    pub dram_read_bytes: u64,
    pub dram_write_bytes: u64,
    pub trace: Vec<TraceSample>,
    /// Output embeddings in ORIGINAL vertex order (functional runs).
    pub output: Option<Vec<f32>>,
    /// Peak resident UEM bytes observed (Fig 2-style footprint). For
    /// multi-layer pipeline runs this includes the inter-layer
    /// activation images resident across layer boundaries.
    pub peak_uem_bytes: u64,
    /// Per-layer breakdown for pipeline runs driven through
    /// `plan::ExecPlan` (one entry per layer, depth-1 included). Empty
    /// when the engine is driven directly with a single `Workload`.
    pub layers: Vec<LayerMetrics>,
    /// Inter-shard boundary-exchange totals (sharded plans only).
    pub halo: HaloMetrics,
}

impl SimResult {
    pub fn seconds(&self, arch: &ArchConfig) -> f64 {
        self.cycles as f64 / arch.freq_hz
    }
}
