//! Public data types of the simulator facade: the workload borrow
//! bundle, run options, and the result record.

use crate::config::ArchConfig;
use crate::energy::EnergyCounters;
use crate::metrics::TraceSample;

/// Everything a simulation run needs. Usually built from an
/// `plan::ExecPlan` via `ExecPlan::workload`, but the loose-reference
/// form is kept for tests and ad-hoc callers.
pub struct Workload<'a> {
    pub program: &'a crate::compiler::Program,
    pub tiling: &'a crate::tiling::Tiling,
    pub weights: &'a crate::models::WeightStore,
    pub feat_in: u32,
    pub feat_out: u32,
    /// Input embeddings in ORIGINAL vertex order, (V × feat_in) row-major.
    /// Required when `SimOptions::functional` is set.
    pub x: Option<&'a [f32]>,
}

#[derive(Clone, Copy, Debug)]
pub struct SimOptions {
    pub functional: bool,
    /// Trace window in cycles (0 = no trace).
    pub trace_window: u64,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { functional: false, trace_window: 0 }
    }
}

/// Simulation result: timing, utilization, energy events, output.
#[derive(Clone, Debug, Default)]
pub struct SimResult {
    pub cycles: u64,
    pub instructions: u64,
    pub counters: EnergyCounters,
    pub mu_busy: u64,
    pub vu_busy: u64,
    pub mem_busy: u64,
    /// Off-chip reads only (Fig 11's reduction metric).
    pub dram_read_bytes: u64,
    pub dram_write_bytes: u64,
    pub trace: Vec<TraceSample>,
    /// Output embeddings in ORIGINAL vertex order (functional runs).
    pub output: Option<Vec<f32>>,
    /// Peak resident UEM bytes observed (Fig 2-style footprint).
    pub peak_uem_bytes: u64,
}

impl SimResult {
    pub fn seconds(&self, arch: &ArchConfig) -> f64 {
        self.cycles as f64 / arch.freq_hz
    }
}
