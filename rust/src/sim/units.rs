//! Hardware-unit timing models (the dispatcher's bottom level): per-MU
//! and per-VU busy-until scoreboards plus the banked HBM controller.
//!
//! A compute instruction is routed to the free unit instance of its
//! class that becomes available first; memory instructions go through
//! the `Hbm` model (row-buffer state + bus backlog). Per-instruction
//! cycle counts come from `sim::timing`.

use super::hbm::{Hbm, HbmConfig};
use super::scheduler::TileCtx;
use crate::config::ArchConfig;
use crate::isa::{DimCtx, Instr, LdTarget};
use crate::tiling::Tiling;

pub(crate) struct Units {
    /// busy-until per unit instance.
    mu_free: Vec<u64>,
    vu_free: Vec<u64>,
    /// Banked HBM controller (Ramulator stand-in): row-buffer state,
    /// channel occupancy. Sparse tile loads issue one run per
    /// consecutive-vertex span, so scattered sources pay activations.
    pub hbm: Hbm,
}

impl Units {
    pub fn new(arch: &ArchConfig) -> Units {
        Units {
            mu_free: vec![0; arch.mu_count as usize],
            vu_free: vec![0; arch.vu_count as usize],
            hbm: Hbm::new(HbmConfig {
                channels: ((arch.hbm_bytes_per_cycle() / 32.0).round() as u32).max(1),
                ctrl_latency: arch.hbm_latency_cycles / 2,
                ..Default::default()
            }),
        }
    }

    /// Occupy the earliest-free MU for `dur` cycles starting no earlier
    /// than `t0`; returns (start, end).
    pub fn issue_mu(&mut self, t0: u64, dur: u64) -> (u64, u64) {
        issue(&mut self.mu_free, t0, dur)
    }

    /// Occupy the earliest-free VU for `dur` cycles.
    pub fn issue_vu(&mut self, t0: u64, dur: u64) -> (u64, u64) {
        issue(&mut self.vu_free, t0, dur)
    }

    /// Latest busy-until across all compute units (end-of-run cycles).
    pub fn max_busy(&self) -> u64 {
        self.mu_free
            .iter()
            .chain(self.vu_free.iter())
            .copied()
            .max()
            .unwrap_or(0)
    }

    /// Route a data-transfer instruction through the banked HBM model.
    /// LD.SRC decomposes into one run per span of consecutive source
    /// vertices — regular tiles stream one contiguous block (row hits),
    /// sparse tiles pay scattered activations (the §5.3 trade-off the
    /// paper argues is worth it at embedding granularity).
    #[allow(clippy::too_many_arguments)]
    pub fn issue_transfer(
        &mut self,
        tiling: &Tiling,
        tile: Option<&TileCtx>,
        cur_part: Option<usize>,
        feat_in: u32,
        feat_out: u32,
        instr: &Instr,
        start: u64,
        bytes: u64,
    ) -> Result<u64, String> {
        const OUT_BASE: u64 = 1 << 41;
        const EDGE_BASE: u64 = 1 << 42;
        let fi = feat_in as u64 * 4;
        let fo = feat_out as u64 * 4;
        match instr {
            Instr::Ld { target: LdTarget::Src, .. } => {
                let tc = tile.ok_or("LD.SRC w/o tile")?;
                let part = &tiling.partitions[tc.part_idx];
                let t_meta = &part.tiles[tc.tile_idx];
                let mut end = start;
                let vs = &t_meta.src_vertices;
                let mut i = 0;
                while i < vs.len() {
                    // coalesce consecutive vertex ids into one run
                    let run_start = i;
                    while i + 1 < vs.len() && vs[i + 1] == vs[i] + 1 {
                        i += 1;
                    }
                    i += 1;
                    let addr = vs[run_start] as u64 * fi;
                    let run_bytes = (i - run_start) as u64 * fi;
                    end = end.max(self.hbm.access(start, addr, run_bytes));
                }
                Ok(end)
            }
            Instr::Ld { target: LdTarget::Dst, .. } => {
                let p = cur_part.ok_or("LD.DST w/o partition")?;
                let addr = tiling.partitions[p].dst_start as u64 * fi;
                Ok(self.hbm.access(start, addr, bytes))
            }
            Instr::Ld { target: LdTarget::Edge, .. } => {
                // edge lists stream from their own region (tile hub fill)
                let tc = tile.ok_or("LD.EDGE w/o tile")?;
                let addr =
                    EDGE_BASE + ((tc.part_idx as u64) << 28) + ((tc.tile_idx as u64) << 14);
                Ok(self.hbm.access(start, addr, bytes))
            }
            Instr::Ld { target: LdTarget::Weight, rows, cols, .. } => {
                // on-chip UEM -> MU weight-buffer fill: never touches HBM
                // (weights are UEM-resident, paper §7.1). Streamed at the
                // UEM port width, plus a fixed issue overhead. Weight dims
                // only ever resolve against the feature widths.
                const UEM_PORT_BYTES: u64 = 64;
                const ISSUE_CYCLES: u64 = 4;
                let ctx = DimCtx { feat_in, feat_out, ..Default::default() };
                let fill = rows.resolve(&ctx) as u64 * cols.resolve(&ctx) as u64 * 4;
                Ok(start + ISSUE_CYCLES + fill.div_ceil(UEM_PORT_BYTES))
            }
            Instr::St { .. } => {
                let p = cur_part.ok_or("ST w/o partition")?;
                let addr = OUT_BASE + tiling.partitions[p].dst_start as u64 * fo;
                Ok(self.hbm.access(start, addr, bytes))
            }
            other => Err(format!("issue_transfer on non-mem instr {other}")),
        }
    }
}

fn issue(slots: &mut [u64], t0: u64, dur: u64) -> (u64, u64) {
    let (idx, free) = slots
        .iter()
        .copied()
        .enumerate()
        .min_by_key(|&(_, t)| t)
        .expect("at least one unit instance");
    let start = t0.max(free);
    slots[idx] = start + dur;
    (start, start + dur)
}
