//! The single instruction-semantics core: ONE `match instr` owning the
//! functional semantics of every compute and load instruction, shared by
//! every execution path in the simulator.
//!
//! Zipper's central claim is that the graph-native IR captures GNN
//! primitive semantics *once* and every backend consumes it (paper §4).
//! The reproduction used to violate that: the discrete-event engine
//! (`sim::exec`) and the tile-parallel batched executor (`sim::parallel`)
//! each carried their own near-identical `match instr` block, so an
//! `Instr` or kernel change could silently diverge the engine from the
//! serving fast path. This module is the fix: [`exec_instr`] is the only
//! per-instruction functional-semantics `match` under `rust/src/sim/`
//! (CI greps for exactly that), parameterized over the small
//! [`BufAccess`] trait. The paths differ only in *where buffers live and
//! what they are allowed to write* — that policy lives in three thin
//! adapters:
//!
//! * `exec::EngineAccess` — the engine's tile/partition frames (tile
//!   buffers resolve through the stream's bound tile frame);
//! * `parallel::TileAccess` — a parallel worker's private tile frame
//!   plus a *read-only* view of the lane's partition frame (writing a
//!   partition buffer from the tile phase is that adapter's hard error);
//! * `parallel::PartAccess` — the dFunction partition-only view (tile
//!   buffer access is its hard error).
//!
//! **Aliased operands.** All paths detach the destination slot before
//! borrowing sources, so `src == dst` (e.g. `ELW.Relu b1 -> b1`)
//! historically failed with a spurious "buffer b1 unset" — in every
//! copy. The shared core fixes it once: when an elementwise operand
//! aliases the destination, the op computes in place on the detached
//! tensor (bit-identical math, zero allocation). Structural ops whose
//! output shape differs from the aliased input (GEMM/BMM/GEMV/SCTR)
//! cannot run in place and report a descriptive error instead.
//!
//! **GTHR is not dispatched here.** The cross-tile gather reduction is
//! the one op whose float association depends on execution order, so
//! both paths defer it and call [`fold_tile_gathers`] in ascending tile
//! order at the partition's wait boundary — which is exactly why the
//! engine's functional output and `run_batch` are now bit-identical
//! (asserted in `rust/tests/parallel_batch.rs`).

use super::exec::{part_slot, Frame};
use super::tensor::{self, Tensor};
use crate::config::KernelPolicy;
use crate::isa::{BufId, Dim, DimCtx, Instr, LdTarget};
use crate::models::WeightStore;
use crate::tiling::{Partition, Tile};

/// Buffer-access policy of one execution path: where operands are read
/// from, where destinations may be written, and how pool growth is
/// accounted. The dispatch core is generic over this — adding an `Instr`
/// arm to only one path is no longer expressible.
pub(crate) trait BufAccess {
    /// Borrow operand `buf` for reading.
    fn read(&self, buf: BufId) -> Result<&Tensor, String>;
    /// Detach destination `buf`'s pooled tensor so the op can compute
    /// into it while operands stay borrowed. Returns (tensor, was_set).
    fn take_dst(&mut self, buf: BufId) -> Result<(Tensor, bool), String>;
    /// Re-attach the computed tensor; `grew` (from the in-place kernel)
    /// feeds the path's allocation counter.
    fn put_back(&mut self, buf: BufId, t: Tensor, grew: bool) -> Result<(), String>;
    /// The lane's permuted input image (LD.SRC / LD.DST source).
    fn input(&self) -> Result<&[f32], String>;
}

fn ctx(instr: &Instr, e: String) -> String {
    format!("{instr}: {e}")
}

fn alias_err(instr: &Instr, buf: BufId) -> String {
    format!(
        "{instr}: operand b{} aliases the destination; this op cannot run in place",
        buf.0
    )
}

fn require_set(was_set: bool, buf: BufId, instr: &Instr) -> Result<(), String> {
    if was_set {
        Ok(())
    } else {
        Err(format!("{instr}: aliased operand b{} unset", buf.0))
    }
}

/// Gather the rows of `vs` (global tiled-order vertex ids) out of the
/// permuted input image. Contiguous blocks (regular tiles, dense sparse
/// tiles) collapse to one memcpy.
fn copy_vertex_rows(x_tiled: &[f32], vs: &[u32], f: usize, t: &mut Tensor) {
    if let (Some(&first), Some(&last)) = (vs.first(), vs.last()) {
        if (last - first) as usize + 1 == vs.len() {
            let base = first as usize * f;
            t.data.copy_from_slice(&x_tiled[base..base + vs.len() * f]);
        } else if f > 0 {
            for (row, &v) in t.data.chunks_exact_mut(f).zip(vs) {
                row.copy_from_slice(&x_tiled[v as usize * f..(v as usize + 1) * f]);
            }
        }
    }
}

/// Functional semantics of one load or compute instruction: detach the
/// destination's pooled tensor through `a`, compute into it in place,
/// re-attach. `part` / `t_meta` are the bound partition / tile (callers
/// resolve them; instructions that need a missing binding error out).
///
/// `policy` selects the kernel variants (DESIGN.md "Kernel policies"):
/// `simd` flips every compute arm to the lane-array kernels (bit-exact
/// with scalar by construction), and `sparse_skip` routes TileSrc-row
/// GEMMs on partially occupied tiles through the masked kernel, which
/// computes only edge-touched source rows. Untouched rows only ever
/// leave the tile frame through edge-indexed GTHR/SCTR, so skipping
/// them is invisible in the final output (soundness argument in
/// DESIGN.md).
///
/// This is THE per-instruction semantics site. Do not re-implement any
/// arm elsewhere — extend the [`BufAccess`] adapters instead.
#[allow(clippy::too_many_arguments)]
pub(crate) fn exec_instr<A: BufAccess>(
    a: &mut A,
    weights: &WeightStore,
    feat_in: u32,
    part: Option<&Partition>,
    t_meta: Option<&Tile>,
    dims: &DimCtx,
    policy: KernelPolicy,
    instr: &Instr,
) -> Result<(), String> {
    let rd = |d: Dim| d.resolve(dims);
    match instr {
        // the edge list already lives in the Tile struct; LD.EDGE is
        // timing-only
        Instr::Ld { target: LdTarget::Edge, .. } => Ok(()),
        // weights are read straight out of the WeightStore by the compute
        // arms; LD.W models the UEM -> MU weight-buffer fill (timing-only)
        Instr::Ld { target: LdTarget::Weight, .. } => Ok(()),
        Instr::Ld { target: LdTarget::Src, dst, .. } => {
            let tm = t_meta.ok_or("LD.SRC w/o tile")?;
            let (mut t, _) = a.take_dst(*dst)?;
            let grew = t.reshape(tm.num_src(), feat_in);
            copy_vertex_rows(a.input()?, &tm.src_vertices, feat_in as usize, &mut t);
            a.put_back(*dst, t, grew)
        }
        Instr::Ld { target: LdTarget::Dst, dst, .. } => {
            let p = part.ok_or("LD.DST w/o partition")?;
            let (mut t, _) = a.take_dst(*dst)?;
            let grew = t.reshape(p.num_dst(), feat_in);
            let x = a.input()?;
            let base = p.dst_start as usize * feat_in as usize;
            t.data.copy_from_slice(&x[base..base + t.data.len()]);
            a.put_back(*dst, t, grew)
        }
        // the functional store happens at the UPD.PTT partition commit
        Instr::St { .. } => Ok(()),
        Instr::ElwU { op, src, dst, .. } => {
            let (mut out, was_set) = a.take_dst(*dst)?;
            if src == dst {
                require_set(was_set, *src, instr)?;
                tensor::apply_unary_inplace_with(policy.simd, *op, &mut out);
                a.put_back(*dst, out, false)
            } else {
                let x = a.read(*src)?;
                let grew = tensor::apply_unary_with(policy.simd, *op, x, &mut out);
                a.put_back(*dst, out, grew)
            }
        }
        Instr::ElwB { op, a: lhs, b: rhs, dst, .. } => {
            let (mut out, was_set) = a.take_dst(*dst)?;
            match (lhs == dst, rhs == dst) {
                (false, false) => {
                    let at = a.read(*lhs)?;
                    let bt = a.read(*rhs)?;
                    let grew = tensor::apply_binary_with(policy.simd, *op, at, bt, &mut out)
                        .map_err(|e| ctx(instr, e))?;
                    a.put_back(*dst, out, grew)
                }
                (true, false) => {
                    require_set(was_set, *lhs, instr)?;
                    let bt = a.read(*rhs)?;
                    tensor::apply_binary_lhs_inplace_with(policy.simd, *op, &mut out, bt)
                        .map_err(|e| ctx(instr, e))?;
                    a.put_back(*dst, out, false)
                }
                (false, true) => {
                    require_set(was_set, *rhs, instr)?;
                    let at = a.read(*lhs)?;
                    tensor::apply_binary_rhs_inplace_with(policy.simd, *op, at, &mut out)
                        .map_err(|e| ctx(instr, e))?;
                    a.put_back(*dst, out, false)
                }
                (true, true) => {
                    require_set(was_set, *lhs, instr)?;
                    tensor::apply_binary_self_inplace_with(policy.simd, *op, &mut out);
                    a.put_back(*dst, out, false)
                }
            }
        }
        Instr::ElwBcast { op, a: lhs, vec, dst, .. } => {
            if vec == dst {
                return Err(alias_err(instr, *vec));
            }
            let (mut out, was_set) = a.take_dst(*dst)?;
            if lhs == dst {
                require_set(was_set, *lhs, instr)?;
                let vt = a.read(*vec)?;
                tensor::apply_bcast_inplace_with(policy.simd, *op, &mut out, vt)
                    .map_err(|e| ctx(instr, e))?;
                a.put_back(*dst, out, false)
            } else {
                let at = a.read(*lhs)?;
                let vt = a.read(*vec)?;
                let grew = tensor::apply_bcast_with(policy.simd, *op, at, vt, &mut out)
                    .map_err(|e| ctx(instr, e))?;
                a.put_back(*dst, out, grew)
            }
        }
        Instr::Gemv { src, weight: w, dst, .. } => {
            if src == dst {
                return Err(alias_err(instr, *src));
            }
            let (mut out, _) = a.take_dst(*dst)?;
            let x = a.read(*src)?;
            let grew =
                tensor::gemv_with(x, &weights.tensors[w.0 as usize].data, &mut out, policy.simd)
                    .map_err(|e| ctx(instr, e))?;
            a.put_back(*dst, out, grew)
        }
        Instr::Gemm { src, weight: w, dst, m, k, n, accumulate, act } => {
            if src == dst {
                return Err(alias_err(instr, *src));
            }
            let (mut out, was_set) = a.take_dst(*dst)?;
            if *accumulate && !was_set {
                return Err(format!("{instr}: accumulate into unset buffer b{}", dst.0));
            }
            let x = a.read(*src)?;
            let wd = &weights.tensors[w.0 as usize].data;
            // Sparsity skipping: a TileSrc-row GEMM on a partially
            // occupied tile only computes edge-touched source rows
            // (untouched rows are zeroed on overwrite, left alone on
            // accumulate — either way they are never consumed, because
            // tile values reach the partition only via edge-indexed
            // GTHR). Sparse-mode tiles are fully occupied by
            // construction, so this triggers only in Regular mode.
            let masked = policy.sparse_skip
                && matches!(m, Dim::TileSrc)
                && t_meta.is_some_and(|t| !t.fully_occupied());
            let grew = if masked {
                let tm = t_meta.expect("masked implies tile bound");
                tensor::matmul_masked(
                    x,
                    wd,
                    rd(*k),
                    rd(*n),
                    &mut out,
                    *accumulate,
                    policy.simd,
                    &tm.src_occ,
                )
            } else {
                tensor::matmul_with(x, wd, rd(*k), rd(*n), &mut out, *accumulate, policy.simd)
            }
            .map_err(|e| ctx(instr, e))?;
            // Fused activation (pipeline-optimizer fusion): applied on the
            // detached output before re-attach — bit-exact with the
            // separate ELW instruction it replaced, on every path, because
            // this is the same kernel the ELW arm would have called.
            if let Some(op) = act {
                tensor::apply_unary_inplace_with(policy.simd, *op, &mut out);
            }
            a.put_back(*dst, out, grew)
        }
        Instr::Bmm { src, weights: w, dst, k, n, .. } => {
            if src == dst {
                return Err(alias_err(instr, *src));
            }
            let tm = t_meta.ok_or("BMM w/o tile")?;
            let (mut out, _) = a.take_dst(*dst)?;
            let x = a.read(*src)?;
            let grew = tensor::bmm_by_type_with(
                x,
                &weights.tensors[w.0 as usize].data,
                rd(*k),
                rd(*n),
                tm.etypes.as_deref(),
                &mut out,
                policy.simd,
            )
            .map_err(|e| ctx(instr, e))?;
            a.put_back(*dst, out, grew)
        }
        Instr::Sctr { dir, src, dst, cols } => {
            if src == dst {
                return Err(alias_err(instr, *src));
            }
            let tm = t_meta.ok_or("SCTR w/o tile")?;
            let (mut out, _) = a.take_dst(*dst)?;
            let v = a.read(*src)?;
            let grew = tensor::scatter_rows(v, &tm.edges, *dir, rd(*cols), &mut out)
                .map_err(|e| ctx(instr, e))?;
            a.put_back(*dst, out, grew)
        }
        Instr::Gthr { .. } => Err(format!(
            "{instr}: GTHR is a cross-tile reduction; it goes through fold_tile_gathers"
        )),
        other => Err(format!("unexpected instr in functional dispatch: {other}")),
    }
}

/// Fold one tile's GTHR reductions into a partition frame, in program
/// (eFunction) order. Both paths call this per tile in **ascending tile
/// order** at the partition's wait boundary — the gather fold order, and
/// hence the float association, is fixed by the plan rather than by
/// stream scheduling or worker completion, which is what makes the
/// engine and `run_batch` outputs bit-identical.
pub(crate) fn fold_tile_gathers(
    e_func: &[Instr],
    frame: &Frame,
    t_meta: &Tile,
    part_frame: &mut Frame,
) -> Result<(), String> {
    for instr in e_func {
        if let Instr::Gthr { reduce, src, dst, .. } = instr {
            let e = frame
                .get(src.0 as usize)
                .ok_or_else(|| format!("{instr}: gather source b{} unset", src.0))?;
            let acc = part_frame
                .get_mut(part_slot(*dst))
                .ok_or_else(|| format!("{instr}: accumulator b{} unset", dst.0))?;
            tensor::gather_rows(*reduce, e, &t_meta.edges, acc).map_err(|e| ctx(instr, e))?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::exec::EngineAccess;
    use super::super::parallel::{PartAccess, TileAccess};
    use super::*;
    use crate::compiler::PART_FRAME_BASE;
    use crate::isa::{ElwBinary, ElwUnary, Reduce, SctrDir, WeightId};
    use crate::models::{WeightStore, WeightTensor};
    use crate::util::Rng;

    const FI: u32 = 4;
    const FO: u32 = 4;
    const P0: BufId = BufId(PART_FRAME_BASE);
    const P1: BufId = BufId(PART_FRAME_BASE + 1);
    // Scalar f32 policy: the adapter-agreement tests pin functional
    // semantics, so they run the reference kernels regardless of which
    // cargo features (and hence which KernelPolicy defaults) are on.
    const POL: KernelPolicy =
        KernelPolicy { simd: false, sparse_skip: false, dtype: crate::config::StorageDtype::F32 };

    fn fixture() -> (WeightStore, Partition, Tile, DimCtx, Vec<f32>) {
        let mut rng = Rng::new(42);
        let mut mk = |len: usize| -> Vec<f32> { (0..len).map(|_| rng.next_f32_sym()).collect() };
        let weights = WeightStore {
            tensors: vec![
                WeightTensor { name: "w", rows: FI, cols: FO, count: 1, data: mk(16) },
                WeightTensor { name: "a", rows: FI, cols: 1, count: 1, data: mk(4) },
                WeightTensor { name: "rel", rows: FI, cols: FO, count: 2, data: mk(32) },
            ],
        };
        let tile = Tile::new(
            0,
            0,
            vec![0, 1, 2],
            vec![(0, 0), (1, 1), (2, 0), (1, 0)],
            Some(vec![0, 1, 0, 1]),
        );
        let part = Partition { partition_id: 0, dst_start: 0, dst_end: 2, tiles: Vec::new() };
        let dims = DimCtx { tile_src: 3, tile_edges: 4, part_dst: 2, feat_in: FI, feat_out: FO };
        let x_tiled = mk(4 * FI as usize);
        (weights, part, tile, dims, x_tiled)
    }

    /// Every tile-phase compute variant, exercising plain, aliased
    /// (src == dst), partition-frame reads, and accumulate flavors.
    fn tile_phase_program() -> Vec<Instr> {
        let (r, c, e) = (Dim::TileSrc, Dim::FeatIn, Dim::TileEdges);
        vec![
            Instr::Ld { target: LdTarget::Src, dst: BufId(0), rows: r, cols: c },
            Instr::ElwU { op: ElwUnary::Tanh, src: BufId(0), dst: BufId(1), rows: r, cols: c },
            // aliased in-place unary (the historical "buffer unset" bug)
            Instr::ElwU { op: ElwUnary::Relu, src: BufId(1), dst: BufId(1), rows: r, cols: c },
            Instr::ElwB {
                op: ElwBinary::Add, a: BufId(0), b: BufId(1), dst: BufId(2), rows: r, cols: c,
            },
            // aliased lhs / rhs / both
            Instr::ElwB {
                op: ElwBinary::Mul, a: BufId(2), b: BufId(0), dst: BufId(2), rows: r, cols: c,
            },
            Instr::ElwB {
                op: ElwBinary::Sub, a: BufId(0), b: BufId(2), dst: BufId(2), rows: r, cols: c,
            },
            Instr::ElwB {
                op: ElwBinary::Max, a: BufId(2), b: BufId(2), dst: BufId(2), rows: r, cols: c,
            },
            Instr::Gemv { src: BufId(0), weight: WeightId(1), dst: BufId(3), rows: r, cols: c },
            Instr::ElwBcast {
                op: ElwBinary::Mul, a: BufId(2), vec: BufId(3), dst: BufId(4), rows: r, cols: c,
            },
            Instr::ElwBcast {
                op: ElwBinary::Add, a: BufId(4), vec: BufId(3), dst: BufId(4), rows: r, cols: c,
            },
            Instr::Gemm {
                src: BufId(0), weight: WeightId(0), dst: BufId(5),
                m: r, k: Dim::FeatIn, n: Dim::FeatOut, accumulate: false, act: None,
            },
            Instr::Gemm {
                src: BufId(4), weight: WeightId(0), dst: BufId(5),
                m: r, k: Dim::FeatIn, n: Dim::FeatOut, accumulate: true, act: None,
            },
            Instr::Sctr { dir: SctrDir::OutEdge, src: BufId(5), dst: BufId(6), cols: Dim::FeatOut },
            // partition-frame read from the tile phase (LD.DST-style data)
            Instr::Sctr { dir: SctrDir::InEdge, src: P0, dst: BufId(7), cols: Dim::FeatIn },
            Instr::Bmm {
                src: BufId(6), weights: WeightId(2), dst: BufId(8),
                m: e, k: Dim::FeatOut, n: Dim::FeatOut,
            },
        ]
    }

    fn e_func() -> Vec<Instr> {
        vec![Instr::Gthr {
            reduce: Reduce::Sum,
            src: BufId(8),
            dst: P1,
            cols: Dim::FeatOut,
            accumulate: true,
        }]
    }

    fn init_part_frame(frame: &mut Frame, x_tiled: &[f32]) {
        // P0: "LD.DST" rows (2 x FI) straight from the input image;
        // P1: zeroed Sum accumulator (2 x FO)
        let t = frame.slot_mut(part_slot(P0));
        t.reshape(2, FI);
        t.data.copy_from_slice(&x_tiled[..2 * FI as usize]);
        frame.slot_mut(part_slot(P1)).reset_filled(2, FO, 0.0);
    }

    /// The same instruction stream driven through the engine adapter and
    /// the parallel tile adapter over equivalently pooled frames must
    /// produce bit-identical buffers, fold results, and alloc counts.
    #[test]
    fn engine_and_tile_adapters_agree_on_every_compute_variant() {
        let (weights, part, tile, dims, x_tiled) = fixture();

        // engine path: FuncState-style frames
        let mut eng_part = Frame::default();
        init_part_frame(&mut eng_part, &x_tiled);
        let mut eng_tiles = vec![Frame::default()];
        let mut eng_allocs = 0u64;
        {
            let mut a = EngineAccess {
                part_frame: &mut eng_part,
                tile_frames: &mut eng_tiles,
                frame: Some(0),
                x_tiled: &x_tiled,
                has_input: true,
                allocs: &mut eng_allocs,
            };
            for instr in &tile_phase_program() {
                exec_instr(&mut a, &weights, FI, Some(&part), Some(&tile), &dims, POL, instr)
                    .unwrap_or_else(|e| panic!("engine adapter: {e}"));
            }
        }
        fold_tile_gathers(&e_func(), &eng_tiles[0], &tile, &mut eng_part).unwrap();

        // parallel path: worker frame + read-only lane partition frame
        let mut lane_part = Frame::default();
        init_part_frame(&mut lane_part, &x_tiled);
        let mut worker_frame = Frame::default();
        let tile_allocs;
        {
            let mut a = TileAccess {
                lane_part: &lane_part,
                x_tiled: &x_tiled,
                frame: &mut worker_frame,
                allocs: 0,
            };
            for instr in &tile_phase_program() {
                exec_instr(&mut a, &weights, FI, Some(&part), Some(&tile), &dims, POL, instr)
                    .unwrap_or_else(|e| panic!("tile adapter: {e}"));
            }
            tile_allocs = a.allocs;
        }
        fold_tile_gathers(&e_func(), &worker_frame, &tile, &mut lane_part).unwrap();

        for slot in 0..9usize {
            assert_eq!(
                eng_tiles[0].get(slot),
                worker_frame.get(slot),
                "tile buffer b{slot} diverged between adapters"
            );
        }
        assert_eq!(
            eng_part.get(part_slot(P1)),
            lane_part.get(part_slot(P1)),
            "folded accumulator diverged between adapters"
        );
        assert_eq!(
            eng_allocs + eng_part.allocs + eng_tiles[0].allocs,
            tile_allocs + worker_frame.allocs + lane_part.allocs,
            "alloc-event counts diverged between adapters"
        );
    }

    /// Partition-phase instructions through the engine adapter (no bound
    /// tile) vs the dFunction partition-only adapter.
    #[test]
    fn engine_and_dfunction_adapters_agree_on_partition_phase() {
        let (weights, part, _tile, dims, x_tiled) = fixture();
        let prog = vec![
            Instr::Ld { target: LdTarget::Dst, dst: P0, rows: Dim::PartDst, cols: Dim::FeatIn },
            Instr::Gemm {
                src: P0, weight: WeightId(0), dst: P1,
                m: Dim::PartDst, k: Dim::FeatIn, n: Dim::FeatOut, accumulate: false, act: None,
            },
            // aliased in-place unary on a partition buffer
            Instr::ElwU {
                op: ElwUnary::Sigmoid, src: P1, dst: P1, rows: Dim::PartDst, cols: Dim::FeatOut,
            },
            Instr::St { src: P1, rows: Dim::PartDst, cols: Dim::FeatOut },
        ];

        let mut eng_part = Frame::default();
        let mut eng_tiles = Vec::new();
        let mut eng_allocs = 0u64;
        {
            let mut a = EngineAccess {
                part_frame: &mut eng_part,
                tile_frames: &mut eng_tiles,
                frame: None,
                x_tiled: &x_tiled,
                has_input: true,
                allocs: &mut eng_allocs,
            };
            for instr in &prog {
                exec_instr(&mut a, &weights, FI, Some(&part), None, &dims, POL, instr)
                    .unwrap_or_else(|e| panic!("engine adapter: {e}"));
            }
        }

        let mut d_part = Frame::default();
        let mut d_allocs = 0u64;
        {
            let mut a = PartAccess {
                part_frame: &mut d_part,
                x_tiled: &x_tiled,
                allocs: &mut d_allocs,
            };
            for instr in &prog {
                exec_instr(&mut a, &weights, FI, Some(&part), None, &dims, POL, instr)
                    .unwrap_or_else(|e| panic!("dFunction adapter: {e}"));
            }
        }

        for slot in [part_slot(P0), part_slot(P1)] {
            assert_eq!(eng_part.get(slot), d_part.get(slot), "partition slot {slot} diverged");
        }
        assert_eq!(
            eng_allocs + eng_part.allocs,
            d_allocs + d_part.allocs,
            "alloc-event counts diverged between adapters"
        );
    }

    /// Write restrictions are adapter policy, not core policy: the same
    /// instruction is legal or illegal depending on the path.
    #[test]
    fn adapter_write_restrictions_hold() {
        let (weights, part, tile, dims, x_tiled) = fixture();
        let to_part = Instr::ElwU {
            op: ElwUnary::Relu, src: BufId(0), dst: P0, rows: Dim::TileSrc, cols: Dim::FeatIn,
        };
        let mut frame = Frame::default();
        frame.slot_mut(0).reset_filled(3, FI, 1.0);
        let lane_part = Frame::default();
        let mut a = TileAccess { lane_part: &lane_part, x_tiled: &x_tiled, frame: &mut frame, allocs: 0 };
        let err = exec_instr(&mut a, &weights, FI, Some(&part), Some(&tile), &dims, POL, &to_part)
            .unwrap_err();
        assert!(err.contains("tile phase cannot write partition buffer"), "{err}");

        let to_tile = Instr::ElwU {
            op: ElwUnary::Relu, src: P0, dst: BufId(0), rows: Dim::PartDst, cols: Dim::FeatIn,
        };
        let mut part_frame = Frame::default();
        part_frame.slot_mut(part_slot(P0)).reset_filled(2, FI, 1.0);
        let mut allocs = 0u64;
        let mut a = PartAccess { part_frame: &mut part_frame, x_tiled: &x_tiled, allocs: &mut allocs };
        let err =
            exec_instr(&mut a, &weights, FI, Some(&part), None, &dims, POL, &to_tile).unwrap_err();
        assert!(err.contains("dFunction write to tile buffer"), "{err}");
    }

    /// Aliasing a structural op (shape-changing) is a descriptive error,
    /// not a spurious "unset"; aliasing an unset elementwise operand is
    /// a genuine unset error.
    #[test]
    fn structural_aliasing_and_unset_aliasing_report_clearly() {
        let (weights, part, tile, dims, x_tiled) = fixture();
        let mut frame = Frame::default();
        frame.slot_mut(0).reset_filled(3, FI, 1.0);
        let lane_part = Frame::default();
        let mut a = TileAccess { lane_part: &lane_part, x_tiled: &x_tiled, frame: &mut frame, allocs: 0 };
        let gemm = Instr::Gemm {
            src: BufId(0), weight: WeightId(0), dst: BufId(0),
            m: Dim::TileSrc, k: Dim::FeatIn, n: Dim::FeatOut, accumulate: false, act: None,
        };
        let err = exec_instr(&mut a, &weights, FI, Some(&part), Some(&tile), &dims, POL, &gemm)
            .unwrap_err();
        assert!(err.contains("cannot run in place"), "{err}");

        // fusion never relaxes the structural aliasing rule (the PR 4
        // case): a fused-activation GEMM aliasing src == dst is the same
        // descriptive error, not a spurious "unset" or a silent in-place
        let fused_aliased = Instr::Gemm {
            src: BufId(0), weight: WeightId(0), dst: BufId(0),
            m: Dim::TileSrc, k: Dim::FeatIn, n: Dim::FeatOut, accumulate: false,
            act: Some(ElwUnary::Relu),
        };
        let err =
            exec_instr(&mut a, &weights, FI, Some(&part), Some(&tile), &dims, POL, &fused_aliased)
                .unwrap_err();
        assert!(err.contains("cannot run in place"), "{err}");

        let relu_unset = Instr::ElwU {
            op: ElwUnary::Relu, src: BufId(2), dst: BufId(2), rows: Dim::TileSrc, cols: Dim::FeatIn,
        };
        let err =
            exec_instr(&mut a, &weights, FI, Some(&part), Some(&tile), &dims, POL, &relu_unset)
                .unwrap_err();
        assert!(err.contains("unset"), "{err}");
    }

    /// A fused-activation GEMM is bit-exact with the unfused
    /// GEMM-then-ELW sequence it replaces (the optimizer's fusion pass
    /// relies on this), and LD.W is a functional no-op on every adapter.
    #[test]
    fn fused_activation_gemm_matches_unfused_sequence() {
        let (weights, part, tile, dims, x_tiled) = fixture();
        let ld = Instr::Ld {
            target: LdTarget::Src, dst: BufId(0), rows: Dim::TileSrc, cols: Dim::FeatIn,
        };
        let ldw = Instr::Ld {
            target: LdTarget::Weight, dst: BufId(0), rows: Dim::FeatIn, cols: Dim::FeatOut,
        };
        let run = |prog: &[Instr], out_buf: BufId| -> Vec<f32> {
            let lane_part = Frame::default();
            let mut frame = Frame::default();
            let mut a = TileAccess {
                lane_part: &lane_part,
                x_tiled: &x_tiled,
                frame: &mut frame,
                allocs: 0,
            };
            for instr in prog {
                exec_instr(&mut a, &weights, FI, Some(&part), Some(&tile), &dims, POL, instr)
                    .unwrap_or_else(|e| panic!("{e}"));
            }
            frame.get(out_buf.0 as usize).expect("output").data.clone()
        };
        let unfused = run(
            &[
                ld.clone(),
                ldw.clone(),
                Instr::Gemm {
                    src: BufId(0), weight: WeightId(0), dst: BufId(1),
                    m: Dim::TileSrc, k: Dim::FeatIn, n: Dim::FeatOut, accumulate: false,
                    act: None,
                },
                Instr::ElwU {
                    op: ElwUnary::Relu, src: BufId(1), dst: BufId(2),
                    rows: Dim::TileSrc, cols: Dim::FeatOut,
                },
            ],
            BufId(2),
        );
        let fused = run(
            &[
                ld,
                ldw,
                Instr::Gemm {
                    src: BufId(0), weight: WeightId(0), dst: BufId(2),
                    m: Dim::TileSrc, k: Dim::FeatIn, n: Dim::FeatOut, accumulate: false,
                    act: Some(ElwUnary::Relu),
                },
            ],
            BufId(2),
        );
        assert_eq!(unfused, fused, "fused activation diverged from unfused sequence");
    }

    /// `sparse_skip` routes TileSrc-row GEMMs on a partially occupied
    /// tile through the masked kernel: edge-touched rows are bit-exact
    /// with the dense kernel, untouched rows come out zeroed (they are
    /// never consumed downstream — GTHR/SCTR egress is edge-indexed).
    #[test]
    fn sparse_skip_gemm_matches_dense_on_touched_rows() {
        let (weights, part, _tile, _dims, _x) = fixture();
        // 5 source rows, edges touching only rows 0 and 3
        let tile = Tile::new(0, 0, vec![0, 1, 2, 3, 4], vec![(0, 1), (3, 0)], None);
        assert!(!tile.fully_occupied());
        let dims = DimCtx { tile_src: 5, tile_edges: 2, part_dst: 2, feat_in: FI, feat_out: FO };
        let mut rng = Rng::new(7);
        let x_tiled: Vec<f32> = (0..5 * FI as usize).map(|_| rng.next_f32_sym()).collect();
        let prog = vec![
            Instr::Ld {
                target: LdTarget::Src, dst: BufId(0), rows: Dim::TileSrc, cols: Dim::FeatIn,
            },
            Instr::Gemm {
                src: BufId(0), weight: WeightId(0), dst: BufId(1),
                m: Dim::TileSrc, k: Dim::FeatIn, n: Dim::FeatOut, accumulate: false, act: None,
            },
        ];
        let run = |policy: KernelPolicy| -> Vec<f32> {
            let lane_part = Frame::default();
            let mut frame = Frame::default();
            let mut a = TileAccess {
                lane_part: &lane_part,
                x_tiled: &x_tiled,
                frame: &mut frame,
                allocs: 0,
            };
            for instr in &prog {
                exec_instr(&mut a, &weights, FI, Some(&part), Some(&tile), &dims, policy, instr)
                    .unwrap_or_else(|e| panic!("{e}"));
            }
            frame.get(1).expect("gemm output").data.clone()
        };
        let dense = run(POL);
        let skipped = run(KernelPolicy { sparse_skip: true, ..POL });
        let f = FO as usize;
        for r in 0..5usize {
            let (d, s) = (&dense[r * f..(r + 1) * f], &skipped[r * f..(r + 1) * f]);
            if r == 0 || r == 3 {
                assert_eq!(d, s, "touched row {r} diverged");
            } else {
                assert!(s.iter().all(|&v| v == 0.0), "untouched row {r} not zeroed: {s:?}");
            }
        }
    }
}
