//! Banked HBM memory-controller model — the Ramulator stand-in
//! (DESIGN.md §5).
//!
//! Each `access` is one contiguous run (the engine coalesces consecutive
//! vertex rows into runs, so regular tiles issue a few large runs and
//! sparse tiles many embedding-sized ones). Timing is analytic per run —
//! O(1) instead of per-burst, which keeps the simulator fast — but
//! preserves the two behaviours that matter to ZIPPER:
//!
//!   * **row-buffer locality**: one activation per (channel, row) of the
//!     run; hit/miss counters feed the energy model and the §5.3
//!     sparse-vs-regular analysis;
//!   * **bandwidth & pipelining**: the data bus is the shared resource —
//!     queued runs stream back-to-back with activations hidden under
//!     previous transfers (`bus_free` chaining), while an un-queued run
//!     pays its leading activation latency. Embedding-sized (≥512 B)
//!     random runs therefore sustain near-sequential bandwidth, exactly
//!     the property the paper's sparse tiling relies on.

use crate::util::ceil_div;

/// HBM-1.0-ish geometry and timing (cycles at the accelerator clock).
#[derive(Clone, Copy, Debug)]
pub struct HbmConfig {
    pub channels: u32,
    pub banks_per_channel: u32,
    /// Row (page) size in bytes.
    pub row_bytes: u32,
    /// Burst granularity in bytes (one transaction on one channel).
    pub burst_bytes: u32,
    /// Cycles one burst occupies its channel (8 ch × 32 B / cyc ≈
    /// 256 GB/s @ 1 GHz).
    pub burst_cycles: u64,
    /// Row activation (tRCD) and precharge (tRP) penalties.
    pub act_cycles: u64,
    pub pre_cycles: u64,
    /// Controller pipeline latency added to every access.
    pub ctrl_latency: u64,
}

impl Default for HbmConfig {
    fn default() -> Self {
        HbmConfig {
            channels: 8,
            banks_per_channel: 16,
            row_bytes: 2048,
            burst_bytes: 32,
            burst_cycles: 1,
            act_cycles: 14,
            pre_cycles: 14,
            ctrl_latency: 20,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Hbm {
    cfg: HbmConfig,
    /// Completion time of the last queued transfer (bus backlog).
    bus_free: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub bursts: u64,
}

impl Hbm {
    pub fn new(cfg: HbmConfig) -> Self {
        Hbm { cfg, bus_free: 0, row_hits: 0, row_misses: 0, bursts: 0 }
    }

    /// Issue a contiguous transfer of `bytes` at `addr`, no earlier than
    /// `now`; returns the completion cycle.
    pub fn access(&mut self, now: u64, addr: u64, bytes: u64) -> u64 {
        let cfg = &self.cfg;
        if bytes == 0 {
            return now + cfg.ctrl_latency;
        }
        let bursts = ceil_div(bytes, cfg.burst_bytes as u64);
        let first_row = addr / cfg.row_bytes as u64;
        let last_row = (addr + bytes - 1) / cfg.row_bytes as u64;
        let rows = last_row - first_row + 1;
        // one activation per channel that touches each row
        let bursts_per_row = (cfg.row_bytes / cfg.burst_bytes) as u64;
        let act_per_row = (cfg.channels as u64).min(bursts.min(bursts_per_row));
        let misses = (rows * act_per_row).min(bursts);
        self.row_misses += misses;
        self.row_hits += bursts - misses;
        self.bursts += bursts;

        let xfer = ceil_div(bursts, cfg.channels as u64) * cfg.burst_cycles;
        // idle bus: pay the leading activation; backlogged bus: the
        // activation is hidden under the in-flight transfer
        let done = (now + cfg.act_cycles + xfer).max(self.bus_free.max(now) + xfer);
        self.bus_free = done;
        done + cfg.ctrl_latency
    }

    /// Observed row-hit rate so far.
    pub fn hit_rate(&self) -> f64 {
        let total = self.row_hits + self.row_misses;
        if total == 0 {
            0.0
        } else {
            self.row_hits as f64 / total as f64
        }
    }

    /// Bytes/cycle ceiling of the configuration.
    pub fn peak_bytes_per_cycle(&self) -> f64 {
        self.cfg.channels as f64 * self.cfg.burst_bytes as f64
            / self.cfg.burst_cycles as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_stream_mostly_hits() {
        // one activation per (channel, row); 64 bursts/row on 8 channels
        // → 7/8 of bursts hit the open row
        let mut h = Hbm::new(HbmConfig::default());
        h.access(0, 0, 64 * 1024);
        assert!(h.hit_rate() > 0.8, "hit rate {}", h.hit_rate());
    }

    #[test]
    fn random_small_reads_mostly_miss() {
        let mut h = Hbm::new(HbmConfig::default());
        let mut t = 0;
        for i in 0..512u64 {
            t = h.access(t, i * 1_000_003, 32);
        }
        assert!(h.hit_rate() < 0.2, "hit rate {}", h.hit_rate());
    }

    #[test]
    fn bandwidth_cap_respected() {
        let mut h = Hbm::new(HbmConfig::default());
        let bytes = 1_000_000u64;
        let done = h.access(0, 0, bytes);
        let min_cycles = bytes as f64 / h.peak_bytes_per_cycle();
        assert!((done as f64) >= min_cycles, "done {done} < cap {min_cycles:.0}");
        assert!((done as f64) < 1.2 * min_cycles + 100.0, "done {done}");
    }

    #[test]
    fn embedding_sized_random_runs_sustain_bandwidth() {
        // the §5.3 claim: 512 B random runs ≈ sequential bandwidth when
        // the bus is backlogged (activations hidden)
        let mut h = Hbm::new(HbmConfig::default());
        let mut done = 0;
        let runs = 2_000u64;
        for i in 0..runs {
            done = done.max(h.access(0, i * 1_000_003, 512));
        }
        let eff = (runs * 512) as f64 / done as f64 / h.peak_bytes_per_cycle();
        assert!(eff > 0.8, "efficiency {eff}");
    }

    #[test]
    fn unqueued_access_pays_activation_latency() {
        let mut h = Hbm::new(HbmConfig::default());
        let cfg = HbmConfig::default();
        let done = h.access(1_000, 0, 32);
        assert_eq!(done, 1_000 + cfg.act_cycles + 1 + cfg.ctrl_latency);
    }

    #[test]
    fn zero_byte_access_is_latency_only() {
        let mut h = Hbm::new(HbmConfig::default());
        assert_eq!(h.access(10, 0, 0), 10 + HbmConfig::default().ctrl_latency);
        assert_eq!(h.bursts, 0);
    }

    #[test]
    fn contention_serializes_on_the_bus() {
        let mut h = Hbm::new(HbmConfig { channels: 1, ..Default::default() });
        let a = h.access(0, 0, 1024);
        let b = h.access(0, 1 << 20, 1024);
        assert!(b > a, "second transfer must queue behind the first");
    }
}
