//! Instruction timing model (DESIGN.md §6).
//!
//! Cycle counts per instruction class, parameterized by `ArchConfig`:
//!
//! * **MU GEMM** — output-stationary systolic array: each (mu_rows ×
//!   mu_cols) output block streams K operand columns through the array
//!   (K cycles), plus a pipeline fill of (mu_rows + mu_cols) per call.
//! * **BMM** — same dataflow but the weight is re-fetched per edge group,
//!   modeled as a constant slowdown (`BMM_PENALTY`; paper §8.3: "suffers
//!   from a longer latency of on-chip memory access").
//! * **VU ELW/GEMV** — elems / (cores × lanes) cycles.
//! * **VU GOP** — each core walks one vertex/edge at a time guided by the
//!   tile-hub edge list: ceil(E / cores) × ceil(F / lanes) cycles.
//! * **LD/ST** — HBM latency + bytes / (bytes per cycle), serialized on
//!   the memory controller (bandwidth sharing emerges from the queue).

use crate::config::ArchConfig;
use crate::isa::{DimCtx, Instr};
use crate::util::ceil_div;

/// Extra factor for index-guided BMM weight traffic.
pub const BMM_PENALTY_NUM: u64 = 3;
pub const BMM_PENALTY_DEN: u64 = 2;

/// Cycles a compute instruction occupies its unit.
pub fn compute_cycles(arch: &ArchConfig, instr: &Instr, ctx: &DimCtx) -> u64 {
    let r = |d: crate::isa::Dim| d.resolve(ctx) as u64;
    match instr {
        Instr::Gemm { m, k, n, .. } => {
            let blocks = ceil_div(r(*m), arch.mu_rows as u64)
                * ceil_div(r(*n), arch.mu_cols as u64);
            let fill = (arch.mu_rows + arch.mu_cols) as u64;
            fill + blocks * r(*k).max(1)
        }
        Instr::Bmm { m, k, n, .. } => {
            let blocks = ceil_div(r(*m), arch.mu_rows as u64)
                * ceil_div(r(*n), arch.mu_cols as u64);
            let fill = (arch.mu_rows + arch.mu_cols) as u64;
            (fill + blocks * r(*k).max(1)) * BMM_PENALTY_NUM / BMM_PENALTY_DEN
        }
        Instr::Gemv { rows, cols, .. } => {
            ceil_div(r(*rows) * r(*cols), arch.vu_width()).max(1)
        }
        Instr::ElwU { rows, cols, .. }
        | Instr::ElwB { rows, cols, .. }
        | Instr::ElwBcast { rows, cols, .. } => {
            ceil_div(r(*rows) * r(*cols), arch.vu_width()).max(1)
        }
        Instr::Sctr { cols, .. } | Instr::Gthr { cols, .. } => {
            let per_core_items = ceil_div(r(crate::isa::Dim::TileEdges), arch.vu_cores as u64);
            per_core_items.max(1) * ceil_div(r(*cols), arch.vu_lanes as u64).max(1)
        }
        _ => 1,
    }
}

/// Cycles a data-transfer instruction occupies the memory controller.
pub fn mem_cycles(arch: &ArchConfig, bytes: u64) -> u64 {
    arch.hbm_latency_cycles + (bytes as f64 / arch.hbm_bytes_per_cycle()).ceil() as u64
}

/// MAC count of MU instructions (energy accounting).
pub fn macs(instr: &Instr, ctx: &DimCtx) -> u64 {
    let r = |d: crate::isa::Dim| d.resolve(ctx) as u64;
    match instr {
        Instr::Gemm { m, k, n, .. } | Instr::Bmm { m, k, n, .. } => r(*m) * r(*k) * r(*n),
        _ => 0,
    }
}

/// VU lane-op count (energy accounting).
pub fn vu_ops(instr: &Instr, ctx: &DimCtx) -> u64 {
    let r = |d: crate::isa::Dim| d.resolve(ctx) as u64;
    match instr {
        Instr::Gemv { rows, cols, .. } => r(*rows) * r(*cols),
        Instr::ElwU { rows, cols, .. }
        | Instr::ElwB { rows, cols, .. }
        | Instr::ElwBcast { rows, cols, .. } => r(*rows) * r(*cols),
        Instr::Sctr { cols, .. } | Instr::Gthr { cols, .. } => {
            r(crate::isa::Dim::TileEdges) * r(*cols)
        }
        _ => 0,
    }
}

/// UEM bytes touched by a compute instruction (reads + writes).
pub fn uem_bytes(instr: &Instr, ctx: &DimCtx) -> u64 {
    let r = |d: crate::isa::Dim| d.resolve(ctx) as u64;
    match instr {
        Instr::Gemm { m, k, n, .. } => 4 * (r(*m) * r(*k) + r(*m) * r(*n)),
        Instr::Bmm { m, k, n, .. } => 4 * (r(*m) * r(*k) + r(*m) * r(*n) + r(*m) * r(*k) * r(*n) / 8),
        Instr::Gemv { rows, cols, .. } => 4 * (r(*rows) * r(*cols) + r(*rows)),
        Instr::ElwU { rows, cols, .. } => 4 * 2 * r(*rows) * r(*cols),
        Instr::ElwB { rows, cols, .. } => 4 * 3 * r(*rows) * r(*cols),
        Instr::ElwBcast { rows, cols, .. } => 4 * (2 * r(*rows) * r(*cols) + r(*rows)),
        Instr::Sctr { cols, .. } => 4 * 2 * r(crate::isa::Dim::TileEdges) * r(*cols),
        Instr::Gthr { cols, .. } => 4 * 3 * r(crate::isa::Dim::TileEdges) * r(*cols),
        // LD writes into UEM; ST reads out of it
        Instr::Ld { rows, cols, .. } | Instr::St { rows, cols, .. } => {
            4 * r(*rows) * r(*cols)
        }
        _ => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{BufId, Dim, LdTarget, WeightId};

    fn arch() -> ArchConfig {
        ArchConfig::default()
    }

    fn ctx() -> DimCtx {
        DimCtx { tile_src: 256, tile_edges: 1024, part_dst: 256, feat_in: 128, feat_out: 128 }
    }

    #[test]
    fn gemm_timing_exact_block() {
        // (32 x 128 x 128): 1 block × 128 K-cycles + 160 fill
        let i = Instr::Gemm {
            src: BufId(0), weight: WeightId(0), dst: BufId(1),
            m: Dim::Const(32), k: Dim::FeatIn, n: Dim::Const(128), accumulate: false, act: None,
        };
        assert_eq!(compute_cycles(&arch(), &i, &ctx()), 160 + 128);
    }

    #[test]
    fn gemm_timing_scales_with_blocks() {
        let i = Instr::Gemm {
            src: BufId(0), weight: WeightId(0), dst: BufId(1),
            m: Dim::Const(64), k: Dim::FeatIn, n: Dim::Const(256), accumulate: false, act: None,
        };
        assert_eq!(compute_cycles(&arch(), &i, &ctx()), 160 + 4 * 128);
    }

    #[test]
    fn bmm_slower_than_gemm() {
        let g = Instr::Gemm {
            src: BufId(0), weight: WeightId(0), dst: BufId(1),
            m: Dim::TileEdges, k: Dim::FeatIn, n: Dim::FeatOut, accumulate: false, act: None,
        };
        let b = Instr::Bmm {
            src: BufId(0), weights: WeightId(0), dst: BufId(1),
            m: Dim::TileEdges, k: Dim::FeatIn, n: Dim::FeatOut,
        };
        assert!(compute_cycles(&arch(), &b, &ctx()) > compute_cycles(&arch(), &g, &ctx()));
    }

    #[test]
    fn elw_uses_full_vu_width() {
        let i = Instr::ElwU {
            op: crate::isa::ElwUnary::Relu,
            src: BufId(0), dst: BufId(1),
            rows: Dim::Const(256), cols: Dim::Const(256),
        };
        // 65536 elems / 256 lanes = 256 cycles
        assert_eq!(compute_cycles(&arch(), &i, &ctx()), 256);
    }

    #[test]
    fn gop_walks_edges_per_core() {
        let i = Instr::Gthr {
            reduce: crate::isa::Reduce::Sum,
            src: BufId(0), dst: BufId(0x100),
            cols: Dim::FeatIn, accumulate: true,
        };
        // ceil(1024/8)=128 groups × ceil(128/32)=4 = 512 cycles
        assert_eq!(compute_cycles(&arch(), &i, &ctx()), 512);
    }

    #[test]
    fn mem_cycles_latency_plus_bandwidth() {
        let a = arch();
        // 256 B/cycle at defaults
        assert_eq!(mem_cycles(&a, 0), a.hbm_latency_cycles);
        assert_eq!(mem_cycles(&a, 256 * 100), a.hbm_latency_cycles + 100);
    }

    #[test]
    fn energy_counters_positive_for_compute() {
        let c = ctx();
        let g = Instr::Gemm {
            src: BufId(0), weight: WeightId(0), dst: BufId(1),
            m: Dim::TileSrc, k: Dim::FeatIn, n: Dim::FeatOut, accumulate: false, act: None,
        };
        assert_eq!(macs(&g, &c), 256 * 128 * 128);
        assert_eq!(vu_ops(&g, &c), 0);
        assert!(uem_bytes(&g, &c) > 0);
        let ld = Instr::Ld {
            target: LdTarget::Src, dst: BufId(0), rows: Dim::TileSrc, cols: Dim::FeatIn,
        };
        assert_eq!(uem_bytes(&ld, &c), 256 * 128 * 4);
    }
}
