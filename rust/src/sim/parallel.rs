//! Tile-parallel, batched functional execution (the serving fast path).
//!
//! The discrete-event engine behind [`super::Simulator`] interleaves
//! functional execution with cycle-accurate stream scheduling, which
//! makes it inherently sequential. This module is the complementary mode: it
//! executes a plan *functionally only*, in the canonical partition order,
//! and exploits the paper's tile-level parallelism on the host — the
//! tiles of each graph partition are sharded round-robin across a scoped
//! thread pool, each worker owning its own pooled buffer frames.
//!
//! **Determinism contract.** Outputs are bit-identical for every thread
//! count and every batch grouping:
//!
//! * a tile's buffers are a pure function of (input lane, partition
//!   frame, tile metadata) — workers never write shared state during the
//!   tile phase;
//! * the only cross-tile reduction (`GTHR` into the partition
//!   accumulators) is *deferred*: workers leave each tile's gather
//!   sources resident in their frames, and the main thread folds them in
//!   ascending tile order, partition by partition — the same float
//!   association for 1 thread or N;
//! * lanes (requests of a batch) never interact, so batch size only
//!   changes how much tile-metadata traversal is amortized, not the
//!   arithmetic per lane.
//!
//! Instruction semantics are NOT implemented here: every load/compute
//! goes through the shared dispatch core (`sim::dispatch::exec_instr`)
//! via this module's two adapters — [`TileAccess`] (worker frame +
//! read-only lane partition view) and [`PartAccess`] (dFunction
//! partition-only view) — and the gather fold is the shared
//! `dispatch::fold_tile_gathers`. The engine consumes the same core, so
//! outputs here are **bit-identical to the engine's functional output**,
//! not merely close (asserted in `rust/tests/parallel_batch.rs`).
//!
//! **Memory discipline.** [`BatchScratch`] follows the PR 2 pooling
//! rules: frames and tensors stay resident across tiles, partitions,
//! runs, and plans; [`BatchScratch::alloc_events`] counts growth events
//! and `rust/tests/parallel_batch.rs` asserts a warm batch adds zero —
//! per worker thread, via [`BatchScratch::worker_alloc_events`].
//!
//! **Layer pipelines.** [`run_pipeline`] chains multiple [`StageWl`]
//! stages (one compiled layer program each) over ONE shared tiling:
//! stage *l*'s per-lane outputs (original vertex order) become stage
//! *l+1*'s inputs via the scratch's pooled ping-pong chain buffers, so
//! warm multi-layer batches stay allocation-free and single-stage
//! pipelines are exactly [`run_batch`] (DESIGN.md §3.4).

use super::dispatch::{self, BufAccess};
use super::exec::{part_slot, unpermute_into, Env, Frame};
use super::tensor::{self, Tensor};
use super::types::Workload;
use crate::compiler::{AccKind, Program};
use crate::config::KernelPolicy;
use crate::isa::{BufId, Dim, DimCtx, Instr, StreamClass};
use crate::models::WeightStore;
use crate::tiling::{Partition, Tile, Tiling};

/// Per-request ("lane") state of a batched run: permuted input/output
/// images plus the partition frame the lane's accumulators live in.
#[derive(Default)]
struct LaneState {
    x_tiled: Vec<f32>,
    out_tiled: Vec<f32>,
    part_frame: Frame,
    allocs: u64,
}

impl LaneState {
    /// Permute the caller's input embeddings into tiled vertex order.
    fn init_input(&mut self, tiling: &Tiling, x: &[f32], feat_in: u32) -> Result<(), String> {
        let n = tiling.num_vertices as usize;
        let f = feat_in as usize;
        if x.len() != n * f {
            return Err(format!(
                "input embedding size {} != |V|*feat_in = {}",
                x.len(),
                n * f
            ));
        }
        if n * f > self.x_tiled.capacity() {
            self.allocs += 1;
        }
        self.x_tiled.resize(n * f, 0.0);
        if f > 0 {
            for (old, row) in x.chunks_exact(f).enumerate() {
                let new = tiling.perm[old] as usize;
                self.x_tiled[new * f..(new + 1) * f].copy_from_slice(row);
            }
        }
        Ok(())
    }

    fn prepare_output(&mut self, num_vertices: u32, feat_out: u32) {
        let len = num_vertices as usize * feat_out as usize;
        if len > self.out_tiled.capacity() {
            self.allocs += 1;
        }
        self.out_tiled.clear();
        self.out_tiled.resize(len, 0.0);
    }

    /// Reset the partition frame and init accumulators in place.
    fn begin_partition(&mut self, acc_meta: &[(usize, AccKind, u32)], part_dst: u32) {
        self.part_frame.clear();
        for &(slot, kind, cols) in acc_meta {
            let init = match kind {
                AccKind::Sum => 0.0,
                AccKind::Max => f32::NEG_INFINITY,
            };
            let grew = self.part_frame.slot_mut(slot).reset_filled(part_dst, cols, init);
            self.allocs += grew as u64;
        }
    }

    /// Post-fold boundary: neutralize untouched Max accumulators.
    fn fixup_max_accs(&mut self, acc_meta: &[(usize, AccKind, u32)]) {
        for &(slot, kind, _) in acc_meta {
            if kind == AccKind::Max {
                if let Some(t) = self.part_frame.get_mut(slot) {
                    for v in &mut t.data {
                        if *v == f32::NEG_INFINITY {
                            *v = 0.0;
                        }
                    }
                }
            }
        }
    }

    /// Commit the partition's output rows into the tiled output image.
    fn commit_partition(&mut self, env: &Env, part: &Partition) -> Result<(), String> {
        let t = self
            .part_frame
            .get(part_slot(env.program.output_buf))
            .ok_or("output buffer not materialized")?;
        if (t.rows, t.cols) != (part.num_dst(), env.feat_out) {
            return Err(format!(
                "output buffer shape {}x{} != partition {}x{}",
                t.rows,
                t.cols,
                part.num_dst(),
                env.feat_out
            ));
        }
        let base = part.dst_start as usize * env.feat_out as usize;
        self.out_tiled[base..base + t.data.len()].copy_from_slice(&t.data);
        Ok(())
    }

    /// Un-permute the tiled output back to original vertex order. The
    /// returned vector is caller-owned (excluded from `alloc_events`).
    fn take_output(&self, tiling: &Tiling, feat_out: u32) -> Vec<f32> {
        let mut out = Vec::new();
        unpermute_into(tiling, feat_out, &self.out_tiled, &mut out);
        out
    }

    /// Un-permute the tiled output into `dst`, reusing its capacity —
    /// the inter-layer chaining step of [`run_pipeline`]. Returns the
    /// number of pool-growth events (0 or 1).
    fn write_output_into(&self, tiling: &Tiling, feat_out: u32, dst: &mut Vec<f32>) -> u64 {
        unpermute_into(tiling, feat_out, &self.out_tiled, dst) as u64
    }

    fn alloc_events(&self) -> u64 {
        self.allocs + self.part_frame.allocs
    }
}

/// One exec thread's pooled tile frames: worker `w` of `T` owns the
/// frames of tiles `w, w+T, w+2T, …` of the current partition, laid out
/// `[tile slot][lane]`. The assignment is static so a worker's pool size
/// is a pure function of (plan, threads, lanes) — warm batches grow it
/// by zero.
#[derive(Default)]
struct WorkerScratch {
    frames: Vec<Frame>,
    allocs: u64,
}

impl WorkerScratch {
    fn alloc_events(&self) -> u64 {
        self.allocs + self.frames.iter().map(|f| f.allocs).sum::<u64>()
    }
}

/// Reusable state of the batched tile-parallel executor. Create once per
/// serving worker and pass to every [`run_batch`] call; lanes, worker
/// frames, and tensors are recycled between batches (and across plans).
#[derive(Default)]
pub struct BatchScratch {
    lanes: Vec<LaneState>,
    workers: Vec<WorkerScratch>,
    acc_meta: Vec<(usize, AccKind, u32)>,
    /// Pooled inter-layer activation images (ORIGINAL vertex order, one
    /// per lane) for [`run_pipeline`]: the stage ping-pong pair. Their
    /// growth is tracked in `allocs`, so warm multi-layer batches stay
    /// at zero.
    chain_prev: Vec<Vec<f32>>,
    chain_next: Vec<Vec<f32>>,
    /// Per-shard child scratches for sharded plans (DESIGN.md §3.8):
    /// shard *s* of a K-way plan runs its per-layer [`run_batch`] on
    /// `shard_pool[s]`. Empty for unsharded plans; grows once to K.
    shard_pool: Vec<BatchScratch>,
    allocs: u64,
}

impl BatchScratch {
    pub fn new() -> BatchScratch {
        BatchScratch::default()
    }

    /// Grow (never shrink) the shard pool to `k` children and hand the
    /// caller disjoint mutable borrows, one per shard worker thread.
    pub(crate) fn ensure_shards(&mut self, k: usize) -> &mut [BatchScratch] {
        if k > self.shard_pool.capacity() {
            self.allocs += 1;
        }
        while self.shard_pool.len() < k {
            self.shard_pool.push(BatchScratch::default());
        }
        &mut self.shard_pool[..k]
    }

    /// Pool-growth events since this scratch was created, summed over
    /// lanes and exec-thread workers (monotonic; a warm batch of the
    /// same shape adds 0).
    pub fn alloc_events(&self) -> u64 {
        self.allocs
            + self.lanes.iter().map(|l| l.alloc_events()).sum::<u64>()
            + self.workers.iter().map(|w| w.alloc_events()).sum::<u64>()
            + self.shard_pool.iter().map(|s| s.alloc_events()).sum::<u64>()
    }

    /// Per-exec-thread pool-growth events (index = worker id). Warm
    /// batches must not move any entry.
    pub fn worker_alloc_events(&self) -> Vec<u64> {
        self.workers.iter().map(|w| w.alloc_events()).collect()
    }

    /// Pre-size every pool from the plan so a warm batch of the same
    /// (plan, lanes, threads) shape does zero growth.
    fn reserve(&mut self, env: &Env, nlanes: usize, threads: usize) {
        if nlanes > self.lanes.capacity() {
            self.allocs += 1;
        }
        while self.lanes.len() < nlanes {
            self.lanes.push(LaneState::default());
        }
        let part_slots = env.program.part_bufs as usize;
        for lane in self.lanes.iter_mut().take(nlanes) {
            lane.part_frame.ensure_slots(part_slots);
        }
        if threads > self.workers.capacity() {
            self.allocs += 1;
        }
        while self.workers.len() < threads {
            self.workers.push(WorkerScratch::default());
        }
        let max_tiles = env
            .tiling
            .partitions
            .iter()
            .map(|p| p.tiles.len())
            .max()
            .unwrap_or(0);
        let frames_needed = max_tiles.div_ceil(threads) * nlanes;
        let tile_slots = env.program.tile_bufs as usize;
        for ws in self.workers.iter_mut().take(threads) {
            if frames_needed > ws.frames.capacity() {
                ws.allocs += 1;
            }
            while ws.frames.len() < frames_needed {
                ws.frames.push(Frame::default());
            }
            for f in ws.frames.iter_mut() {
                f.ensure_slots(tile_slots);
            }
        }
        if env.program.accumulators.len() > self.acc_meta.capacity() {
            self.allocs += 1;
        }
        self.acc_meta.clear();
        for &(buf, kind, cols) in &env.program.accumulators {
            let cols = match cols {
                Dim::FeatIn => env.feat_in,
                Dim::FeatOut => env.feat_out,
                Dim::Const(c) => c,
                _ => env.feat_out,
            };
            self.acc_meta.push((part_slot(buf), kind, cols));
        }
    }
}

/// Execute `wl`'s program functionally for a batch of input embeddings
/// (one lane per entry of `inputs`, original vertex order), sharding each
/// partition's tiles across `exec_threads` OS threads. Returns one output
/// embedding vector per lane, bit-identical for every `exec_threads`
/// value and batch grouping (see the module docs for the argument).
///
/// `wl.x` is ignored — inputs arrive per lane. Timing is not modeled
/// here; pair with a `functional: false` [`super::Simulator`] run (which
/// is input-independent) when latency numbers are needed.
pub fn run_batch(
    wl: &Workload,
    inputs: &[&[f32]],
    exec_threads: usize,
    scratch: &mut BatchScratch,
) -> Result<Vec<Vec<f32>>, String> {
    let env = Env::of(wl);
    let out = run_stage(&env, inputs, exec_threads.max(1), scratch, None)?;
    Ok(out.expect("run_stage without a sink returns outputs"))
}

/// One pipeline stage's immutable pieces for [`run_pipeline`]: the
/// compiled layer program plus that layer's weights and feature dims.
/// The tiling is deliberately *not* here — it is shared by every stage
/// of a pipeline and passed once.
pub struct StageWl<'a> {
    pub program: &'a Program,
    pub weights: &'a WeightStore,
    pub feat_in: u32,
    pub feat_out: u32,
    pub kernels: KernelPolicy,
}

/// Execute a multi-layer pipeline functionally for a batch of lanes:
/// every stage runs the full tile-parallel [`run_batch`] machinery over
/// the **same** shared `tiling`, and stage *l*'s per-lane output
/// (ORIGINAL vertex order) becomes stage *l+1*'s input. Hidden-stage
/// outputs live in the scratch's pooled chain buffers (warm pipelines
/// allocate nothing); only the final stage's outputs are fresh
/// caller-owned vectors. Single-stage pipelines are exactly
/// [`run_batch`], so depth 1 is bit-exact with the pre-pipeline path.
pub fn run_pipeline(
    tiling: &Tiling,
    stages: &[StageWl],
    inputs: &[&[f32]],
    exec_threads: usize,
    scratch: &mut BatchScratch,
) -> Result<Vec<Vec<f32>>, String> {
    if stages.is_empty() {
        return Err("run_pipeline: empty stage list".into());
    }
    let nlanes = inputs.len();
    if nlanes == 0 {
        return Ok(Vec::new());
    }
    let threads = exec_threads.max(1);
    // ping-pong the pooled chain buffers around the borrow on `scratch`
    let mut prev = std::mem::take(&mut scratch.chain_prev);
    let mut next = std::mem::take(&mut scratch.chain_next);
    let result = pipeline_stages(tiling, stages, inputs, threads, scratch, &mut prev, &mut next);
    scratch.chain_prev = prev;
    scratch.chain_next = next;
    result
}

/// The stage loop of [`run_pipeline`], with the chain buffers detached
/// from the scratch so a stage can read `prev` while `run_stage`
/// mutably borrows the scratch.
fn pipeline_stages(
    tiling: &Tiling,
    stages: &[StageWl],
    inputs: &[&[f32]],
    threads: usize,
    scratch: &mut BatchScratch,
    prev: &mut Vec<Vec<f32>>,
    next: &mut Vec<Vec<f32>>,
) -> Result<Vec<Vec<f32>>, String> {
    let nlanes = inputs.len();
    let last = stages.len() - 1;
    for (l, st) in stages.iter().enumerate() {
        let env = Env {
            program: st.program,
            tiling,
            weights: st.weights,
            feat_in: st.feat_in,
            feat_out: st.feat_out,
            kernels: st.kernels,
        };
        let owned: Vec<&[f32]>;
        let lane_inputs: &[&[f32]] = if l == 0 {
            inputs
        } else {
            owned = prev.iter().take(nlanes).map(|v| v.as_slice()).collect();
            &owned
        };
        if l == last {
            let out = run_stage(&env, lane_inputs, threads, scratch, None)?;
            return Ok(out.expect("run_stage without a sink returns outputs"));
        }
        run_stage(&env, lane_inputs, threads, scratch, Some(&mut *next))?;
        std::mem::swap(prev, next);
    }
    unreachable!("the final stage returns from the loop")
}

/// One stage (= one compiled layer program) of a batched run: the core
/// the public [`run_batch`] / [`run_pipeline`] entry points share. With
/// `sink: None` the per-lane outputs come back as fresh caller-owned
/// vectors; with `Some(bufs)` they are written into the pooled chain
/// buffers instead (growth tracked in the scratch's alloc counter).
fn run_stage(
    env: &Env,
    inputs: &[&[f32]],
    threads: usize,
    scratch: &mut BatchScratch,
    sink: Option<&mut Vec<Vec<f32>>>,
) -> Result<Option<Vec<Vec<f32>>>, String> {
    let nlanes = inputs.len();
    if nlanes == 0 {
        return Ok(sink.is_none().then(Vec::new));
    }
    scratch.reserve(env, nlanes, threads);
    let BatchScratch { lanes, workers, acc_meta, allocs, .. } = scratch;
    for (lane, x) in lanes.iter_mut().zip(inputs) {
        lane.init_input(env.tiling, x, env.feat_in)?;
        lane.prepare_output(env.tiling.num_vertices, env.feat_out);
    }

    let d = &env.program.d_func;
    let (sig, wait, upd) = validate_d_layout(d)?;
    let d_pre = &d[1..sig];
    let d_post = &d[wait + 1..upd];

    for part in &env.tiling.partitions {
        let pdims = DimCtx {
            tile_src: 0,
            tile_edges: 0,
            part_dst: part.num_dst(),
            feat_in: env.feat_in,
            feat_out: env.feat_out,
        };
        for lane in lanes.iter_mut().take(nlanes) {
            lane.begin_partition(acc_meta, part.num_dst());
            for instr in d_pre {
                exec_part_instr(env, part, &pdims, lane, instr)?;
            }
        }

        let tiles = &part.tiles;
        if !tiles.is_empty() {
            // ---- tile phase: round-robin shard across exec threads ----
            let lane_view: &[LaneState] = &lanes[..nlanes];
            if threads == 1 || tiles.len() == 1 {
                worker_pass(env, lane_view, part, 1, 0, &mut workers[0])?;
            } else {
                let env_ref = env;
                let results: Vec<Result<(), String>> = std::thread::scope(|s| {
                    let handles: Vec<_> = workers
                        .iter_mut()
                        .take(threads)
                        .enumerate()
                        .map(|(w, ws)| {
                            s.spawn(move || worker_pass(env_ref, lane_view, part, threads, w, ws))
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.join().unwrap_or_else(|_| Err("tile worker panicked".into())))
                        .collect()
                });
                for r in results {
                    r?;
                }
            }

            // ---- deterministic reduction: ascending tile order ----
            // (this is what makes outputs independent of the thread
            // count: the gather fold order is fixed here, not by the
            // workers' completion order)
            let stride = if threads == 1 || tiles.len() == 1 { 1 } else { threads };
            for (t_idx, t_meta) in tiles.iter().enumerate() {
                let ws = &workers[t_idx % stride];
                let base = (t_idx / stride) * nlanes;
                for (b, lane) in lanes.iter_mut().take(nlanes).enumerate() {
                    dispatch::fold_tile_gathers(
                        &env.program.e_func,
                        &ws.frames[base + b],
                        t_meta,
                        &mut lane.part_frame,
                    )?;
                }
            }
        }

        for lane in lanes.iter_mut().take(nlanes) {
            lane.fixup_max_accs(acc_meta);
            for instr in d_post {
                exec_part_instr(env, part, &pdims, lane, instr)?;
            }
            lane.commit_partition(env, part)?;
        }
    }

    match sink {
        None => Ok(Some(
            lanes
                .iter()
                .take(nlanes)
                .map(|l| l.take_output(env.tiling, env.feat_out))
                .collect(),
        )),
        Some(out) => {
            // pooled chain buffers: one image per lane, capacity reused
            if nlanes > out.capacity() {
                *allocs += 1;
            }
            if out.len() < nlanes {
                out.resize_with(nlanes, Vec::new);
            }
            for (lane, dst) in lanes.iter().take(nlanes).zip(out.iter_mut()) {
                *allocs += lane.write_output_into(env.tiling, env.feat_out, dst);
                // Reduced-precision storage: hidden-layer activation
                // images are quantized to the policy dtype at exactly
                // this chain boundary (the engine path quantizes at its
                // stash_output call), so both executors feed the next
                // stage bit-identical inputs. Final-stage outputs stay
                // f32 (the no-sink branch above).
                tensor::quantize_slice(env.kernels.dtype, dst);
            }
            Ok(None)
        }
    }
}

/// One worker's share of a partition's tile phase: tiles
/// `first, first+stride, …`, each executed for every lane into the
/// worker's own pooled frames.
fn worker_pass(
    env: &Env,
    lanes: &[LaneState],
    part: &Partition,
    stride: usize,
    first: usize,
    ws: &mut WorkerScratch,
) -> Result<(), String> {
    let nlanes = lanes.len();
    let mut t_idx = first;
    let mut slot = 0usize;
    while t_idx < part.tiles.len() {
        let t_meta = &part.tiles[t_idx];
        for (b, lane) in lanes.iter().enumerate() {
            let grew = exec_tile(env, lane, part, t_meta, &mut ws.frames[slot * nlanes + b])?;
            ws.allocs += grew;
        }
        t_idx += stride;
        slot += 1;
    }
    Ok(())
}

/// Validate the compiler's dFunction layout before slicing it into pre
/// and post phases: `FCH.PTT; <pre ops>; SIGNAL.S; WAIT; <post ops incl.
/// ST.DST>; UPD.PTT; JUMP`. A program that drifts from this shape (or
/// reorders the markers) gets a structured error naming the offending
/// instruction/positions instead of silently dropping instructions.
/// Returns the (SIGNAL.S, WAIT, UPD.PTT) positions.
fn validate_d_layout(d: &[Instr]) -> Result<(usize, usize, usize), String> {
    match d.first() {
        Some(Instr::FchPtt) => {}
        Some(other) => {
            return Err(format!(
                "dFunction layout: expected FCH.PTT at instruction 0, found {other}"
            ))
        }
        None => return Err("dFunction layout: empty function".into()),
    }
    let sig = d
        .iter()
        .position(|i| matches!(i, Instr::Signal { class: StreamClass::S }))
        .ok_or("dFunction layout: missing SIGNAL.S")?;
    let wait = d
        .iter()
        .position(|i| matches!(i, Instr::Wait { .. }))
        .ok_or("dFunction layout: missing WAIT")?;
    let upd = d
        .iter()
        .position(|i| matches!(i, Instr::UpdPtt))
        .ok_or("dFunction layout: missing UPD.PTT")?;
    if !(sig < wait && wait < upd) {
        return Err(format!(
            "dFunction layout: SIGNAL.S@{sig}, WAIT@{wait}, UPD.PTT@{upd} out of order \
             (need SIGNAL.S < WAIT < UPD.PTT)"
        ));
    }
    Ok((sig, wait, upd))
}

/// Execute one tile's sFunction + eFunction bodies for one lane through
/// the shared dispatch core, *excluding* the GTHR reductions (deferred
/// to the ordered fold). Reads the lane's partition frame and input
/// image; writes only `frame` (the [`TileAccess`] adapter hard-errors on
/// partition writes). Returns the number of pool-growth events.
fn exec_tile(
    env: &Env,
    lane: &LaneState,
    part: &Partition,
    t_meta: &Tile,
    frame: &mut Frame,
) -> Result<u64, String> {
    frame.clear();
    let dims = DimCtx {
        tile_src: t_meta.num_src(),
        tile_edges: t_meta.num_edges(),
        part_dst: part.num_dst(),
        feat_in: env.feat_in,
        feat_out: env.feat_out,
    };
    let mut a = TileAccess {
        lane_part: &lane.part_frame,
        x_tiled: &lane.x_tiled,
        frame,
        allocs: 0,
    };
    for instr in &env.program.s_func {
        match instr {
            Instr::Wait { .. } | Instr::FchTile { .. } | Instr::Signal { .. } | Instr::Jump(_) => {}
            other => dispatch::exec_instr(
                &mut a,
                env.weights,
                env.feat_in,
                Some(part),
                Some(t_meta),
                &dims,
                env.kernels,
                other,
            )?,
        }
    }
    for instr in &env.program.e_func {
        match instr {
            Instr::Wait { .. } | Instr::ChkPtt | Instr::Jump(_) => {}
            // cross-tile reduction: deferred to the ordered fold
            Instr::Gthr { .. } => {}
            other => dispatch::exec_instr(
                &mut a,
                env.weights,
                env.feat_in,
                Some(part),
                Some(t_meta),
                &dims,
                env.kernels,
                other,
            )?,
        }
    }
    Ok(a.allocs)
}

/// A parallel worker's [`BufAccess`] adapter for the tile phase: tile
/// buffers live in the worker's private frame, partition buffers (LD.DST
/// data, dFunction pre-op results) are a *read-only* view of the lane's
/// partition frame. Writing the shared partition frame from the
/// (parallel) tile phase would be a data race, so it is this adapter's
/// hard error — the compiler routes all cross-tile writes through GTHR.
pub(crate) struct TileAccess<'s> {
    pub(crate) lane_part: &'s Frame,
    pub(crate) x_tiled: &'s [f32],
    pub(crate) frame: &'s mut Frame,
    pub(crate) allocs: u64,
}

impl BufAccess for TileAccess<'_> {
    fn read(&self, buf: BufId) -> Result<&Tensor, String> {
        if buf.is_partition_frame() {
            self.lane_part
                .get(part_slot(buf))
                .ok_or_else(|| format!("partition buffer b{} unset", buf.0))
        } else {
            self.frame
                .get(buf.0 as usize)
                .ok_or_else(|| format!("tile buffer b{} unset", buf.0))
        }
    }

    fn take_dst(&mut self, buf: BufId) -> Result<(Tensor, bool), String> {
        if buf.is_partition_frame() {
            return Err(format!(
                "tile phase cannot write partition buffer b{} (only GTHR crosses tiles)",
                buf.0
            ));
        }
        Ok(self.frame.take(buf.0 as usize))
    }

    fn put_back(&mut self, buf: BufId, t: Tensor, grew: bool) -> Result<(), String> {
        if buf.is_partition_frame() {
            return Err(format!(
                "tile phase cannot write partition buffer b{} (only GTHR crosses tiles)",
                buf.0
            ));
        }
        self.allocs += grew as u64;
        self.frame.put(buf.0 as usize, t);
        Ok(())
    }

    fn input(&self) -> Result<&[f32], String> {
        Ok(self.x_tiled)
    }
}

/// The dFunction partition-only [`BufAccess`] adapter: any tile-buffer
/// access from the per-partition pre/post phases is this adapter's hard
/// error (there is no bound tile to resolve it against).
pub(crate) struct PartAccess<'s> {
    pub(crate) part_frame: &'s mut Frame,
    pub(crate) x_tiled: &'s [f32],
    pub(crate) allocs: &'s mut u64,
}

impl BufAccess for PartAccess<'_> {
    fn read(&self, buf: BufId) -> Result<&Tensor, String> {
        if !buf.is_partition_frame() {
            return Err(format!("dFunction read of tile buffer b{}", buf.0));
        }
        self.part_frame
            .get(part_slot(buf))
            .ok_or_else(|| format!("partition buffer b{} unset", buf.0))
    }

    fn take_dst(&mut self, buf: BufId) -> Result<(Tensor, bool), String> {
        if !buf.is_partition_frame() {
            return Err(format!("dFunction write to tile buffer b{}", buf.0));
        }
        Ok(self.part_frame.take(part_slot(buf)))
    }

    fn put_back(&mut self, buf: BufId, t: Tensor, grew: bool) -> Result<(), String> {
        if !buf.is_partition_frame() {
            return Err(format!("dFunction write to tile buffer b{}", buf.0));
        }
        *self.allocs += grew as u64;
        self.part_frame.put(part_slot(buf), t);
        Ok(())
    }

    fn input(&self) -> Result<&[f32], String> {
        Ok(self.x_tiled)
    }
}

/// One dFunction instruction (pre or post phase) for one lane, through
/// the shared dispatch core over the partition-only adapter. ST.DST is a
/// dispatch-level no-op — the commit happens once per partition via
/// `LaneState::commit_partition`.
fn exec_part_instr(
    env: &Env,
    part: &Partition,
    dims: &DimCtx,
    lane: &mut LaneState,
    instr: &Instr,
) -> Result<(), String> {
    let mut a = PartAccess {
        part_frame: &mut lane.part_frame,
        x_tiled: &lane.x_tiled,
        allocs: &mut lane.allocs,
    };
    dispatch::exec_instr(
        &mut a,
        env.weights,
        env.feat_in,
        Some(part),
        None,
        dims,
        env.kernels,
        instr,
    )
}
