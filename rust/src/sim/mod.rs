//! Cycle-level ZIPPER architecture simulator (paper §7, §8.1).
//!
//! Discrete-event simulation of the two-level scheduler: streams (1
//! dStream + N sStreams + N eStreams) execute SDE functions; the
//! dispatcher routes each instruction to a free unit instance (MU / VU /
//! memory controller) and the stream blocks until it completes. Signals
//! implement the paper's §5.2 inter-stream protocol. Alongside timing,
//! every instruction executes *functionally* on f32 embeddings so the
//! final output validates against the PJRT oracle.
//!
//! Module map (see DESIGN.md):
//!   * `engine` — the [`Simulator`] facade + discrete-event loop and the
//!     ISA's control/protocol semantics;
//!   * `scheduler` — stream scoreboard, SIGNAL/WAIT wakeups, issue pick;
//!   * `units` — MU/VU busy-until scoreboards + HBM routing;
//!   * `dispatch` — THE per-instruction functional-semantics core: one
//!     `match instr` shared by the engine and the batched path,
//!     parameterized over a small buffer-access trait (DESIGN.md §3.3
//!     "single dispatch core");
//!   * `exec` — the engine's run-local functional state in the reusable
//!     [`ExecScratch`] (pooled buffer frames + in-place kernels: warm
//!     requests grow the pool by zero, see DESIGN.md "Memory
//!     discipline") plus its dispatch adapter;
//!   * [`parallel`] — the tile-parallel batched functional executor:
//!     shards each partition's tiles across a scoped thread pool and
//!     folds the GTHR reductions in deterministic tile order, so outputs
//!     are bit-identical for any thread count AND bit-identical to the
//!     engine's functional output (DESIGN.md §3.3);
//!   * [`hbm`] — banked memory-controller timing (Ramulator stand-in);
//!   * [`timing`] — per-instruction cycle counts;
//!   * [`tensor`] — dense f32 tensors + functional op semantics.
//!
//! Stand-ins vs the paper (DESIGN.md §5): Ramulator is replaced by a
//! latency+bandwidth memory-controller queue; eDRAM bank conflicts are
//! folded into per-access byte accounting.

mod dispatch;
mod engine;
mod exec;
pub mod hbm;
pub mod parallel;
mod scheduler;
pub mod tensor;
pub mod timing;
mod types;
mod units;

pub use engine::Simulator;
pub use exec::ExecScratch;
pub use tensor::Tensor;
pub use types::{HaloMetrics, LayerMetrics, SimOptions, SimResult, Workload};
