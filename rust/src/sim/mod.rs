//! Cycle-level ZIPPER architecture simulator (paper §7, §8.1).
//!
//! Discrete-event simulation of the two-level scheduler: streams (1
//! dStream + N sStreams + N eStreams) execute SDE functions; the
//! dispatcher routes each instruction to a free unit instance (MU / VU /
//! memory controller) and the stream blocks until it completes. Signals
//! implement the paper's §5.2 inter-stream protocol. Alongside timing,
//! every instruction executes *functionally* on f32 embeddings so the
//! final output validates against the PJRT oracle.
//!
//! Stand-ins vs the paper (DESIGN.md §5): Ramulator is replaced by a
//! latency+bandwidth memory-controller queue; eDRAM bank conflicts are
//! folded into per-access byte accounting.

mod engine;
pub mod hbm;
pub mod tensor;
pub mod timing;

pub use engine::{SimOptions, SimResult, Simulator, Workload};
pub use tensor::Tensor;
