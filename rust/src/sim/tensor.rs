//! Dense f32 tensors + the functional semantics of every ISA instruction.
//!
//! The simulator executes programs *functionally* as well as temporally:
//! each instruction updates real embedding data so end-of-run outputs can
//! be validated against the PJRT-executed JAX artifacts (the role DGL
//! played for the paper's simulator validation, §8.1).
//!
//! **In-place convention** (the executor's zero-allocation contract, see
//! DESIGN.md "Memory discipline"): every op writes into a caller-provided
//! `&mut Tensor`, resizing it in place — capacity is preserved across
//! calls, so the executor's pooled buffer slots never re-allocate on the
//! warm path. Each shaping op returns `true` iff the destination's
//! backing allocation had to grow; the executor feeds that into its
//! allocation counter. New kernels must follow the same convention.
//!
//! **Error convention**: operand-shape mismatches that a (mis)compiled
//! program could reach through the serving path return `Err(String)`
//! carrying the offending shapes — the dispatch core prefixes the
//! instruction — instead of panicking inside a scoped worker thread
//! (which would surface as a messageless "tile worker panicked").
//! `debug_assert!` remains for pure-internal invariants the tiling and
//! compiler construction already guarantee (e.g. local edge endpoints
//! in bounds).
//!
//! The `*_inplace` variants back the dispatch core's aliased-operand
//! (`src == dst`) path: they apply the exact same scalar function to the
//! detached destination tensor, so results are bit-identical to the
//! out-of-place kernels.

use crate::config::StorageDtype;
use crate::isa::{ElwBinary, ElwUnary, Reduce, SctrDir};

/// Row-major dense matrix.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Tensor {
    pub rows: u32,
    pub cols: u32,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(rows: u32, cols: u32) -> Self {
        Tensor { rows, cols, data: vec![0.0; rows as usize * cols as usize] }
    }

    pub fn filled(rows: u32, cols: u32, v: f32) -> Self {
        Tensor { rows, cols, data: vec![v; rows as usize * cols as usize] }
    }

    pub fn from_rows(rows: u32, cols: u32, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows as usize * cols as usize);
        Tensor { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: u32) -> &[f32] {
        let c = self.cols as usize;
        &self.data[r as usize * c..(r as usize + 1) * c]
    }

    #[inline]
    pub fn row_mut(&mut self, r: u32) -> &mut [f32] {
        let c = self.cols as usize;
        &mut self.data[r as usize * c..(r as usize + 1) * c]
    }

    pub fn bytes(&self) -> u64 {
        self.data.len() as u64 * 4
    }

    /// Reshape in place WITHOUT initializing reused elements — callers
    /// must overwrite every element. Capacity is preserved; returns
    /// `true` iff the backing allocation had to grow.
    pub fn reshape(&mut self, rows: u32, cols: u32) -> bool {
        let len = rows as usize * cols as usize;
        let grew = len > self.data.capacity();
        self.data.resize(len, 0.0);
        self.rows = rows;
        self.cols = cols;
        grew
    }

    /// Reshape in place and set every element to `v` (accumulator
    /// init). Capacity is preserved; returns `true` iff the backing
    /// allocation had to grow.
    pub fn reset_filled(&mut self, rows: u32, cols: u32, v: f32) -> bool {
        let len = rows as usize * cols as usize;
        let grew = len > self.data.capacity();
        self.data.clear();
        self.data.resize(len, v);
        self.rows = rows;
        self.cols = cols;
        grew
    }
}

fn unop(op: ElwUnary) -> fn(f32) -> f32 {
    match op {
        ElwUnary::Exp => |v| v.exp(),
        ElwUnary::Relu => |v| v.max(0.0),
        ElwUnary::LeakyRelu => |v| if v >= 0.0 { v } else { 0.2 * v },
        ElwUnary::Sigmoid => |v| 1.0 / (1.0 + (-v).exp()),
        ElwUnary::Tanh => |v| v.tanh(),
        ElwUnary::Neg => |v| -v,
        ElwUnary::OneMinus => |v| 1.0 - v,
        ElwUnary::Recip => |v| 1.0 / v,
        ElwUnary::Recip0 => |v| if v == 0.0 { 0.0 } else { 1.0 / v },
    }
}

pub fn apply_unary(op: ElwUnary, x: &Tensor, out: &mut Tensor) -> bool {
    let f = unop(op);
    let grew = out.reshape(x.rows, x.cols);
    for (o, &v) in out.data.iter_mut().zip(&x.data) {
        *o = f(v);
    }
    grew
}

/// In-place unary for aliased `src == dst` instructions.
pub fn apply_unary_inplace(op: ElwUnary, t: &mut Tensor) {
    let f = unop(op);
    for v in &mut t.data {
        *v = f(*v);
    }
}

fn binary_shapes_match(a: &Tensor, b: &Tensor) -> Result<(), String> {
    if (a.rows, a.cols) != (b.rows, b.cols) {
        return Err(format!(
            "ELW operand shape mismatch: {}x{} vs {}x{}",
            a.rows, a.cols, b.rows, b.cols
        ));
    }
    Ok(())
}

pub fn apply_binary(
    op: ElwBinary,
    a: &Tensor,
    b: &Tensor,
    out: &mut Tensor,
) -> Result<bool, String> {
    binary_shapes_match(a, b)?;
    let f: fn(f32, f32) -> f32 = binop(op);
    let grew = out.reshape(a.rows, a.cols);
    for ((o, &x), &y) in out.data.iter_mut().zip(&a.data).zip(&b.data) {
        *o = f(x, y);
    }
    Ok(grew)
}

/// In-place binary with the destination aliasing the LEFT operand:
/// `a = f(a, b)`.
pub fn apply_binary_lhs_inplace(
    op: ElwBinary,
    a: &mut Tensor,
    b: &Tensor,
) -> Result<(), String> {
    binary_shapes_match(a, b)?;
    let f = binop(op);
    for (x, &y) in a.data.iter_mut().zip(&b.data) {
        *x = f(*x, y);
    }
    Ok(())
}

/// In-place binary with the destination aliasing the RIGHT operand:
/// `b = f(a, b)`.
pub fn apply_binary_rhs_inplace(
    op: ElwBinary,
    a: &Tensor,
    b: &mut Tensor,
) -> Result<(), String> {
    binary_shapes_match(a, b)?;
    let f = binop(op);
    for (&x, y) in a.data.iter().zip(b.data.iter_mut()) {
        *y = f(x, *y);
    }
    Ok(())
}

/// In-place binary with the destination aliasing BOTH operands:
/// `t = f(t, t)`.
pub fn apply_binary_self_inplace(op: ElwBinary, t: &mut Tensor) {
    let f = binop(op);
    for v in &mut t.data {
        *v = f(*v, *v);
    }
}

fn bcast_shapes_match(a: &Tensor, vec: &Tensor) -> Result<(), String> {
    if a.rows != vec.rows {
        return Err(format!(
            "broadcast row mismatch: operand {}x{} vs vector {}x{}",
            a.rows, a.cols, vec.rows, vec.cols
        ));
    }
    if vec.cols != 1 {
        return Err(format!(
            "broadcast vector must be a column, got {}x{}",
            vec.rows, vec.cols
        ));
    }
    Ok(())
}

/// Broadcast a (rows × 1) column over a (rows × cols) operand.
pub fn apply_bcast(
    op: ElwBinary,
    a: &Tensor,
    vec: &Tensor,
    out: &mut Tensor,
) -> Result<bool, String> {
    bcast_shapes_match(a, vec)?;
    let f = binop(op);
    let grew = out.reshape(a.rows, a.cols);
    let c = a.cols as usize;
    if c > 0 {
        for ((dst, src), &v) in out
            .data
            .chunks_exact_mut(c)
            .zip(a.data.chunks_exact(c))
            .zip(&vec.data)
        {
            for (d, &s) in dst.iter_mut().zip(src) {
                *d = f(s, v);
            }
        }
    }
    Ok(grew)
}

/// In-place broadcast with the destination aliasing the row operand:
/// `a[r][c] = f(a[r][c], vec[r])`.
pub fn apply_bcast_inplace(op: ElwBinary, a: &mut Tensor, vec: &Tensor) -> Result<(), String> {
    bcast_shapes_match(a, vec)?;
    let f = binop(op);
    let c = a.cols as usize;
    if c > 0 {
        for (dst, &v) in a.data.chunks_exact_mut(c).zip(&vec.data) {
            for d in dst.iter_mut() {
                *d = f(*d, v);
            }
        }
    }
    Ok(())
}

fn binop(op: ElwBinary) -> fn(f32, f32) -> f32 {
    match op {
        ElwBinary::Add => |x, y| x + y,
        ElwBinary::Sub => |x, y| x - y,
        ElwBinary::Mul => |x, y| x * y,
        ElwBinary::Div => |x, y| x / y,
        ElwBinary::Max => |x, y| x.max(y),
    }
}

// ---- lane-array elementwise kernels (KernelPolicy::simd) -------------------
//
// The scalar family above dispatches through the `unop`/`binop`
// fn-pointer tables; a pointer call per element blocks vectorization, so
// the SIMD variants monomorphize the loop body per op via the
// `with_unop!`/`with_binop!` macros below and process `[f32; LANES]`
// chunks with constant-trip inner loops. The closure bodies MUST mirror
// the fn-pointer tables exactly; the
// `simd_elementwise_is_bit_exact_with_scalar` test pins them together
// (bit-exactness is trivial: the same per-element function is applied
// in both policies, only the loop structure differs).

/// Monomorphize `$body` once per unary op, binding `$f` to an inlinable
/// closure with the same semantics as `unop($op)`.
macro_rules! with_unop {
    ($op:expr, $f:ident => $body:expr) => {
        match $op {
            ElwUnary::Exp => {
                let $f = |v: f32| v.exp();
                $body
            }
            ElwUnary::Relu => {
                let $f = |v: f32| v.max(0.0);
                $body
            }
            ElwUnary::LeakyRelu => {
                let $f = |v: f32| if v >= 0.0 { v } else { 0.2 * v };
                $body
            }
            ElwUnary::Sigmoid => {
                let $f = |v: f32| 1.0 / (1.0 + (-v).exp());
                $body
            }
            ElwUnary::Tanh => {
                let $f = |v: f32| v.tanh();
                $body
            }
            ElwUnary::Neg => {
                let $f = |v: f32| -v;
                $body
            }
            ElwUnary::OneMinus => {
                let $f = |v: f32| 1.0 - v;
                $body
            }
            ElwUnary::Recip => {
                let $f = |v: f32| 1.0 / v;
                $body
            }
            ElwUnary::Recip0 => {
                let $f = |v: f32| if v == 0.0 { 0.0 } else { 1.0 / v };
                $body
            }
        }
    };
}

/// Monomorphize `$body` once per binary op, binding `$f` to an
/// inlinable closure with the same semantics as `binop($op)`.
macro_rules! with_binop {
    ($op:expr, $f:ident => $body:expr) => {
        match $op {
            ElwBinary::Add => {
                let $f = |x: f32, y: f32| x + y;
                $body
            }
            ElwBinary::Sub => {
                let $f = |x: f32, y: f32| x - y;
                $body
            }
            ElwBinary::Mul => {
                let $f = |x: f32, y: f32| x * y;
                $body
            }
            ElwBinary::Div => {
                let $f = |x: f32, y: f32| x / y;
                $body
            }
            ElwBinary::Max => {
                let $f = |x: f32, y: f32| x.max(y);
                $body
            }
        }
    };
}

#[inline(always)]
fn lanes_map1<F: Fn(f32) -> f32>(f: F, src: &[f32], dst: &mut [f32]) {
    let head = src.len() - src.len() % LANES;
    for (d, s) in dst[..head]
        .chunks_exact_mut(LANES)
        .zip(src[..head].chunks_exact(LANES))
    {
        let mut lane = [0.0f32; LANES];
        for (l, &v) in lane.iter_mut().zip(s) {
            *l = f(v);
        }
        d.copy_from_slice(&lane);
    }
    for (d, &v) in dst[head..].iter_mut().zip(&src[head..]) {
        *d = f(v);
    }
}

#[inline(always)]
fn lanes_map1_inplace<F: Fn(f32) -> f32>(f: F, data: &mut [f32]) {
    let head = data.len() - data.len() % LANES;
    for chunk in data[..head].chunks_exact_mut(LANES) {
        let mut lane = [0.0f32; LANES];
        lane.copy_from_slice(chunk);
        for l in &mut lane {
            *l = f(*l);
        }
        chunk.copy_from_slice(&lane);
    }
    for v in &mut data[head..] {
        *v = f(*v);
    }
}

#[inline(always)]
fn lanes_map2<F: Fn(f32, f32) -> f32>(f: F, a: &[f32], b: &[f32], dst: &mut [f32]) {
    let head = a.len() - a.len() % LANES;
    for ((d, x), y) in dst[..head]
        .chunks_exact_mut(LANES)
        .zip(a[..head].chunks_exact(LANES))
        .zip(b[..head].chunks_exact(LANES))
    {
        let mut lane = [0.0f32; LANES];
        for ((l, &xv), &yv) in lane.iter_mut().zip(x).zip(y) {
            *l = f(xv, yv);
        }
        d.copy_from_slice(&lane);
    }
    for ((d, &xv), &yv) in dst[head..].iter_mut().zip(&a[head..]).zip(&b[head..]) {
        *d = f(xv, yv);
    }
}

#[inline(always)]
fn lanes_map2_lhs<F: Fn(f32, f32) -> f32>(f: F, a: &mut [f32], b: &[f32]) {
    let head = a.len() - a.len() % LANES;
    for (x, y) in a[..head]
        .chunks_exact_mut(LANES)
        .zip(b[..head].chunks_exact(LANES))
    {
        let mut lane = [0.0f32; LANES];
        for ((l, &xv), &yv) in lane.iter_mut().zip(x.iter()).zip(y) {
            *l = f(xv, yv);
        }
        x.copy_from_slice(&lane);
    }
    for (x, &yv) in a[head..].iter_mut().zip(&b[head..]) {
        *x = f(*x, yv);
    }
}

#[inline(always)]
fn lanes_map2_rhs<F: Fn(f32, f32) -> f32>(f: F, a: &[f32], b: &mut [f32]) {
    let head = a.len() - a.len() % LANES;
    for (x, y) in a[..head]
        .chunks_exact(LANES)
        .zip(b[..head].chunks_exact_mut(LANES))
    {
        let mut lane = [0.0f32; LANES];
        for ((l, &xv), &yv) in lane.iter_mut().zip(x).zip(y.iter()) {
            *l = f(xv, yv);
        }
        y.copy_from_slice(&lane);
    }
    for (&xv, y) in a[head..].iter().zip(&mut b[head..]) {
        *y = f(xv, *y);
    }
}

/// Policy-dispatched unary (see `apply_unary`).
pub fn apply_unary_with(simd: bool, op: ElwUnary, x: &Tensor, out: &mut Tensor) -> bool {
    if !simd {
        return apply_unary(op, x, out);
    }
    let grew = out.reshape(x.rows, x.cols);
    with_unop!(op, f => lanes_map1(f, &x.data, &mut out.data));
    grew
}

/// Policy-dispatched in-place unary (see `apply_unary_inplace`).
pub fn apply_unary_inplace_with(simd: bool, op: ElwUnary, t: &mut Tensor) {
    if !simd {
        return apply_unary_inplace(op, t);
    }
    with_unop!(op, f => lanes_map1_inplace(f, &mut t.data));
}

/// Policy-dispatched binary (see `apply_binary`).
pub fn apply_binary_with(
    simd: bool,
    op: ElwBinary,
    a: &Tensor,
    b: &Tensor,
    out: &mut Tensor,
) -> Result<bool, String> {
    if !simd {
        return apply_binary(op, a, b, out);
    }
    binary_shapes_match(a, b)?;
    let grew = out.reshape(a.rows, a.cols);
    with_binop!(op, f => lanes_map2(f, &a.data, &b.data, &mut out.data));
    Ok(grew)
}

/// Policy-dispatched `a = f(a, b)` (see `apply_binary_lhs_inplace`).
pub fn apply_binary_lhs_inplace_with(
    simd: bool,
    op: ElwBinary,
    a: &mut Tensor,
    b: &Tensor,
) -> Result<(), String> {
    if !simd {
        return apply_binary_lhs_inplace(op, a, b);
    }
    binary_shapes_match(a, b)?;
    with_binop!(op, f => lanes_map2_lhs(f, &mut a.data, &b.data));
    Ok(())
}

/// Policy-dispatched `b = f(a, b)` (see `apply_binary_rhs_inplace`).
pub fn apply_binary_rhs_inplace_with(
    simd: bool,
    op: ElwBinary,
    a: &Tensor,
    b: &mut Tensor,
) -> Result<(), String> {
    if !simd {
        return apply_binary_rhs_inplace(op, a, b);
    }
    binary_shapes_match(a, b)?;
    with_binop!(op, f => lanes_map2_rhs(f, &a.data, &mut b.data));
    Ok(())
}

/// Policy-dispatched `t = f(t, t)` (see `apply_binary_self_inplace`).
pub fn apply_binary_self_inplace_with(simd: bool, op: ElwBinary, t: &mut Tensor) {
    if !simd {
        return apply_binary_self_inplace(op, t);
    }
    with_binop!(op, f => lanes_map1_inplace(|v| f(v, v), &mut t.data));
}

/// Policy-dispatched broadcast (see `apply_bcast`).
pub fn apply_bcast_with(
    simd: bool,
    op: ElwBinary,
    a: &Tensor,
    vec: &Tensor,
    out: &mut Tensor,
) -> Result<bool, String> {
    if !simd {
        return apply_bcast(op, a, vec, out);
    }
    bcast_shapes_match(a, vec)?;
    let grew = out.reshape(a.rows, a.cols);
    let c = a.cols as usize;
    if c > 0 {
        with_binop!(op, f => {
            for ((dst, src), &v) in out
                .data
                .chunks_exact_mut(c)
                .zip(a.data.chunks_exact(c))
                .zip(&vec.data)
            {
                lanes_map1(|s| f(s, v), src, dst);
            }
        });
    }
    Ok(grew)
}

/// Policy-dispatched in-place broadcast (see `apply_bcast_inplace`).
pub fn apply_bcast_inplace_with(
    simd: bool,
    op: ElwBinary,
    a: &mut Tensor,
    vec: &Tensor,
) -> Result<(), String> {
    if !simd {
        return apply_bcast_inplace(op, a, vec);
    }
    bcast_shapes_match(a, vec)?;
    let c = a.cols as usize;
    if c > 0 {
        with_binop!(op, f => {
            for (row, &v) in a.data.chunks_exact_mut(c).zip(&vec.data) {
                lanes_map1_inplace(|s| f(s, v), row);
            }
        });
    }
    Ok(())
}

/// Row block of the GEMM microkernel.
const MR: usize = 4;
/// Column panel of the GEMM microkernel: 4×16 f32 accumulators fit the
/// SIMD register file (16 ymm on AVX2), so the k-loop runs register-
/// resident instead of streaming the output row through L1.
const NR: usize = 16;

/// `x (m×k) @ w (k×n)` → `out (m×n)`, in place (capacity preserved).
///
/// Hot path of the functional simulator (see `perf_hotpath`):
/// register-blocked MR×NR microkernel with the k-loop innermost over a
/// contiguous weight-panel row, amortizing each weight load over MR
/// output rows (~4× less weight-stream traffic than the row-at-a-time
/// kernel it replaced). `accumulate` folds into the store, so
/// GEMM-accumulate needs no separate zero + add passes.
pub fn matmul(
    x: &Tensor,
    w: &[f32],
    k: u32,
    n: u32,
    out: &mut Tensor,
    accumulate: bool,
) -> Result<bool, String> {
    let grew = gemm_validate(x, w, k, n, out, accumulate)?;
    matmul_block(x, w, k as usize, n as usize, out, accumulate, 0, x.rows as usize);
    Ok(grew)
}

/// Shared GEMM shape validation; reshapes `out` (non-accumulate) and
/// returns the grew flag.
fn gemm_validate(
    x: &Tensor,
    w: &[f32],
    k: u32,
    n: u32,
    out: &mut Tensor,
    accumulate: bool,
) -> Result<bool, String> {
    if x.cols != k {
        return Err(format!(
            "GEMM inner-dim mismatch: src is {}x{}, k = {k}",
            x.rows, x.cols
        ));
    }
    if (w.len() as u64) < k as u64 * n as u64 {
        return Err(format!(
            "GEMM weight matrix too small: {} elements for {k}x{n}",
            w.len()
        ));
    }
    if accumulate {
        if (out.rows, out.cols) != (x.rows, n) {
            return Err(format!(
                "GEMM accumulate destination is {}x{}, want {}x{n}",
                out.rows, out.cols, x.rows
            ));
        }
        Ok(false)
    } else {
        Ok(out.reshape(x.rows, n))
    }
}

/// Scalar reference microkernel over output rows `[r0, r1)`. Each output
/// element is one sequential ascending-k accumulation, which is the
/// bit-exactness contract every other GEMM variant in this module must
/// reproduce.
#[allow(clippy::too_many_arguments)]
fn matmul_block(
    x: &Tensor,
    w: &[f32],
    k: usize,
    n: usize,
    out: &mut Tensor,
    accumulate: bool,
    r0: usize,
    r1: usize,
) {
    let mut r = r0;
    while r < r1 {
        let mr = MR.min(r1 - r);
        let mut j0 = 0;
        while j0 < n {
            let nr = NR.min(n - j0);
            let mut acc = [[0.0f32; NR]; MR];
            if mr == MR && nr == NR {
                // full tile: constant-trip loops, register-resident acc
                for kk in 0..k {
                    let wrow: &[f32; NR] =
                        w[kk * n + j0..kk * n + j0 + NR].try_into().unwrap();
                    for (i, arow) in acc.iter_mut().enumerate() {
                        let xv = x.data[(r + i) * k + kk];
                        for (av, &wv) in arow.iter_mut().zip(wrow) {
                            *av += xv * wv;
                        }
                    }
                }
            } else {
                // ragged edge tile (m % 4 / n % 16 remainders)
                for kk in 0..k {
                    let wrow = &w[kk * n + j0..kk * n + j0 + nr];
                    for (i, arow) in acc[..mr].iter_mut().enumerate() {
                        let xv = x.data[(r + i) * k + kk];
                        for (av, &wv) in arow[..nr].iter_mut().zip(wrow) {
                            *av += xv * wv;
                        }
                    }
                }
            }
            for (i, arow) in acc[..mr].iter().enumerate() {
                let orow = &mut out.data[(r + i) * n + j0..(r + i) * n + j0 + nr];
                if accumulate {
                    for (o, &v) in orow.iter_mut().zip(&arow[..nr]) {
                        *o += v;
                    }
                } else {
                    orow.copy_from_slice(&arow[..nr]);
                }
            }
            j0 += nr;
        }
        r += mr;
    }
}

/// SIMD lane width of the vectorized kernels: `[f32; 8]` accumulators
/// (one AVX2 ymm / two NEON q registers), written so the inner loops are
/// constant-trip over lane arrays and autovectorize on stable Rust.
pub const LANES: usize = 8;

/// Lane-array microkernel over output rows `[r0, r1)`. Same MR×NR
/// blocking as `matmul_block` but the column panel is held as explicit
/// `[f32; LANES]` pairs. Per output element the accumulation is still
/// one sequential ascending-k chain, so results are bit-exact with the
/// scalar reference (asserted in tests and `perf_hotpath`).
#[allow(clippy::too_many_arguments)]
fn matmul_block_simd(
    x: &Tensor,
    w: &[f32],
    k: usize,
    n: usize,
    out: &mut Tensor,
    accumulate: bool,
    r0: usize,
    r1: usize,
) {
    let mut r = r0;
    while r < r1 {
        let mr = MR.min(r1 - r);
        let mut j0 = 0;
        while j0 < n {
            let nr = NR.min(n - j0);
            if mr == MR && nr == NR {
                // full tile: MR rows × 2 lane arrays of LANES columns
                let mut acc = [[[0.0f32; LANES]; 2]; MR];
                for kk in 0..k {
                    let wp = &w[kk * n + j0..kk * n + j0 + NR];
                    let w0: &[f32; LANES] = wp[..LANES].try_into().unwrap();
                    let w1: &[f32; LANES] = wp[LANES..].try_into().unwrap();
                    for (i, [a0, a1]) in acc.iter_mut().enumerate() {
                        let xv = x.data[(r + i) * k + kk];
                        for (av, &wv) in a0.iter_mut().zip(w0) {
                            *av += xv * wv;
                        }
                        for (av, &wv) in a1.iter_mut().zip(w1) {
                            *av += xv * wv;
                        }
                    }
                }
                for (i, [a0, a1]) in acc.iter().enumerate() {
                    let orow = &mut out.data[(r + i) * n + j0..(r + i) * n + j0 + NR];
                    let (o0, o1) = orow.split_at_mut(LANES);
                    if accumulate {
                        for (o, &v) in o0.iter_mut().zip(a0) {
                            *o += v;
                        }
                        for (o, &v) in o1.iter_mut().zip(a1) {
                            *o += v;
                        }
                    } else {
                        o0.copy_from_slice(a0);
                        o1.copy_from_slice(a1);
                    }
                }
            } else {
                // ragged edge tile: defer to the scalar path (bit-exact
                // per element, and never hot at model dims)
                matmul_block_ragged(x, w, k, n, out, accumulate, r, r + mr, j0, j0 + nr);
            }
            j0 += nr;
        }
        r += mr;
    }
}

/// Ragged-remainder helper shared by the SIMD kernel: scalar MR×NR
/// accumulation over rows `[r0, r1)` and columns `[j0, j1)`.
#[allow(clippy::too_many_arguments)]
fn matmul_block_ragged(
    x: &Tensor,
    w: &[f32],
    k: usize,
    n: usize,
    out: &mut Tensor,
    accumulate: bool,
    r0: usize,
    r1: usize,
    j0: usize,
    j1: usize,
) {
    let (mr, nr) = (r1 - r0, j1 - j0);
    let mut acc = [[0.0f32; NR]; MR];
    for kk in 0..k {
        let wrow = &w[kk * n + j0..kk * n + j1];
        for (i, arow) in acc[..mr].iter_mut().enumerate() {
            let xv = x.data[(r0 + i) * k + kk];
            for (av, &wv) in arow[..nr].iter_mut().zip(wrow) {
                *av += xv * wv;
            }
        }
    }
    for (i, arow) in acc[..mr].iter().enumerate() {
        let orow = &mut out.data[(r0 + i) * n + j0..(r0 + i) * n + j1];
        if accumulate {
            for (o, &v) in orow.iter_mut().zip(&arow[..nr]) {
                *o += v;
            }
        } else {
            orow.copy_from_slice(&arow[..nr]);
        }
    }
}

/// Policy-dispatched GEMM: `simd` selects the lane-array kernel,
/// otherwise the scalar reference. Both are bit-exact on identical
/// inputs.
pub fn matmul_with(
    x: &Tensor,
    w: &[f32],
    k: u32,
    n: u32,
    out: &mut Tensor,
    accumulate: bool,
    simd: bool,
) -> Result<bool, String> {
    if !simd {
        return matmul(x, w, k, n, out, accumulate);
    }
    let grew = gemm_validate(x, w, k, n, out, accumulate)?;
    matmul_block_simd(x, w, k as usize, n as usize, out, accumulate, 0, x.rows as usize);
    Ok(grew)
}

/// Sparsity-masked GEMM: compute only the rows whose bit is set in
/// `mask` (bit r of word r/64), zero the untouched rows of a
/// non-accumulating store, and leave untouched rows alone when
/// accumulating (a masked non-accumulate GEMM earlier in the chain has
/// already zeroed them). Touched rows are bit-exact with the unmasked
/// kernels; untouched rows are deterministic zeros. Sound only for
/// tile-phase tensors whose untouched source rows are never consumed —
/// see `tiling::Tile::src_occ` and DESIGN.md "Kernel policies".
#[allow(clippy::too_many_arguments)]
pub fn matmul_masked(
    x: &Tensor,
    w: &[f32],
    k: u32,
    n: u32,
    out: &mut Tensor,
    accumulate: bool,
    simd: bool,
    mask: &[u64],
) -> Result<bool, String> {
    let grew = gemm_validate(x, w, k, n, out, accumulate)?;
    let m = x.rows as usize;
    debug_assert!(mask.len() * 64 >= m, "occupancy mask shorter than row count");
    let (ku, nu) = (k as usize, n as usize);
    let touched = |r: usize| mask[r / 64] >> (r % 64) & 1 == 1;
    let mut r = 0;
    while r < m {
        if touched(r) {
            let mut r1 = r + 1;
            while r1 < m && touched(r1) {
                r1 += 1;
            }
            if simd {
                matmul_block_simd(x, w, ku, nu, out, accumulate, r, r1);
            } else {
                matmul_block(x, w, ku, nu, out, accumulate, r, r1);
            }
            r = r1;
        } else {
            if !accumulate {
                out.data[r * nu..(r + 1) * nu].fill(0.0);
            }
            r += 1;
        }
    }
    Ok(grew)
}

/// Per-edge typed matmul: edge r uses weight matrix `etypes[r]`
/// (`None` = every edge uses matrix 0, the untyped-graph fallback).
pub fn bmm_by_type(
    x: &Tensor,
    wset: &[f32],
    k: u32,
    n: u32,
    etypes: Option<&[u8]>,
    out: &mut Tensor,
) -> Result<bool, String> {
    bmm_by_type_with(x, wset, k, n, etypes, out, false)
}

/// One BMM output row with `[f32; LANES]` panel-resident accumulators:
/// per output element a single sequential ascending-k chain starting at
/// 0.0, exactly like the scalar `orow.fill(0.0)` + k-loop — bit-exact.
fn bmm_row_simd(xrow: &[f32], w: &[f32], n: usize, orow: &mut [f32]) {
    let mut j0 = 0;
    while j0 < n {
        let nr = LANES.min(n - j0);
        let mut acc = [0.0f32; LANES];
        if nr == LANES {
            for (kk, &xv) in xrow.iter().enumerate() {
                let wp: &[f32; LANES] =
                    w[kk * n + j0..kk * n + j0 + LANES].try_into().unwrap();
                for (a, &wv) in acc.iter_mut().zip(wp) {
                    *a += xv * wv;
                }
            }
            orow[j0..j0 + LANES].copy_from_slice(&acc);
        } else {
            for (kk, &xv) in xrow.iter().enumerate() {
                let wp = &w[kk * n + j0..kk * n + j0 + nr];
                for (a, &wv) in acc[..nr].iter_mut().zip(wp) {
                    *a += xv * wv;
                }
            }
            orow[j0..j0 + nr].copy_from_slice(&acc[..nr]);
        }
        j0 += nr;
    }
}

/// Policy-dispatched BMM (see `bmm_by_type`); `simd = false` is the
/// scalar reference path.
pub fn bmm_by_type_with(
    x: &Tensor,
    wset: &[f32],
    k: u32,
    n: u32,
    etypes: Option<&[u8]>,
    out: &mut Tensor,
    simd: bool,
) -> Result<bool, String> {
    if x.cols != k {
        return Err(format!(
            "BMM inner-dim mismatch: src is {}x{}, k = {k}",
            x.rows, x.cols
        ));
    }
    if let Some(t) = etypes {
        if t.len() != x.rows as usize {
            return Err(format!(
                "BMM edge-type count {} != {} edge rows",
                t.len(),
                x.rows
            ));
        }
    }
    let grew = out.reshape(x.rows, n);
    let (k, n) = (k as usize, n as usize);
    let mat = k * n;
    if mat == 0 {
        out.data.fill(0.0);
        return Ok(grew);
    }
    let nmat = wset.len() / mat;
    match etypes.and_then(|t| t.iter().copied().max()) {
        Some(max_ty) if (max_ty as usize) >= nmat => {
            return Err(format!(
                "BMM edge type {max_ty} out of range: weight set holds {nmat} {k}x{n} matrices"
            ));
        }
        None if etypes.is_none() && nmat == 0 => {
            return Err(format!(
                "BMM weight set too small: {} elements for one {k}x{n} matrix",
                wset.len()
            ));
        }
        _ => {}
    }
    for r in 0..x.rows as usize {
        let ty = etypes.map_or(0, |t| t[r] as usize);
        let w = &wset[ty * mat..(ty + 1) * mat];
        let xrow = &x.data[r * k..(r + 1) * k];
        let orow = &mut out.data[r * n..(r + 1) * n];
        if simd {
            bmm_row_simd(xrow, w, n, orow);
        } else {
            orow.fill(0.0);
            for (kk, &xv) in xrow.iter().enumerate() {
                let wrow = &w[kk * n..(kk + 1) * n];
                for (o, &wv) in orow.iter_mut().zip(wrow) {
                    *o += xv * wv;
                }
            }
        }
    }
    Ok(grew)
}

fn gemv_validate(x: &Tensor, w: &[f32]) -> Result<(), String> {
    if w.len() != x.cols as usize {
        return Err(format!(
            "GEMV weight length {} != src cols {} (src is {}x{})",
            w.len(),
            x.cols,
            x.rows,
            x.cols
        ));
    }
    Ok(())
}

/// GEMV: `x (rows×cols) @ w (cols×1)` → (rows×1), in place.
pub fn gemv(x: &Tensor, w: &[f32], out: &mut Tensor) -> Result<bool, String> {
    gemv_validate(x, w)?;
    let grew = out.reshape(x.rows, 1);
    let c = x.cols as usize;
    if c == 0 {
        out.data.fill(0.0);
    } else {
        for (o, xrow) in out.data.iter_mut().zip(x.data.chunks_exact(c)) {
            *o = xrow.iter().zip(w).map(|(&a, &b)| a * b).sum();
        }
    }
    Ok(grew)
}

/// Policy-dispatched GEMV. The SIMD variant vectorizes ACROSS rows —
/// `LANES` independent per-row accumulators with the k-loop outer —
/// never across k: the scalar dot is a sequential ascending-k sum, and
/// splitting it into lane partials would change the rounding sequence.
/// Each row's accumulation order is identical to scalar, so results are
/// bit-exact.
pub fn gemv_with(x: &Tensor, w: &[f32], out: &mut Tensor, simd: bool) -> Result<bool, String> {
    if !simd {
        return gemv(x, w, out);
    }
    gemv_validate(x, w)?;
    let grew = out.reshape(x.rows, 1);
    let c = x.cols as usize;
    if c == 0 {
        out.data.fill(0.0);
        return Ok(grew);
    }
    let m = x.rows as usize;
    let mut r = 0;
    while r + LANES <= m {
        let mut acc = [0.0f32; LANES];
        for (kk, &wv) in w.iter().enumerate() {
            for (l, a) in acc.iter_mut().enumerate() {
                *a += x.data[(r + l) * c + kk] * wv;
            }
        }
        out.data[r..r + LANES].copy_from_slice(&acc);
        r += LANES;
    }
    for rr in r..m {
        out.data[rr] = x.data[rr * c..(rr + 1) * c]
            .iter()
            .zip(w)
            .map(|(&a, &b)| a * b)
            .sum();
    }
    Ok(grew)
}

/// SCTR: expand vertex rows along a tile's COO edge list. `edges` holds
/// (local_src, local_dst) pairs; `dir` picks which side indexes `v`.
pub fn scatter_rows(
    v: &Tensor,
    edges: &[(u32, u32)],
    dir: SctrDir,
    cols: u32,
    out: &mut Tensor,
) -> Result<bool, String> {
    if v.cols != cols {
        return Err(format!(
            "SCTR column mismatch: vertex buffer is {}x{}, want {cols} cols",
            v.rows, v.cols
        ));
    }
    let grew = out.reshape(edges.len() as u32, cols);
    let c = cols as usize;
    if c > 0 {
        for (row, &(ls, ld)) in out.data.chunks_exact_mut(c).zip(edges) {
            let src = match dir {
                SctrDir::OutEdge => ls,
                SctrDir::InEdge => ld,
            };
            // local edge endpoints in bounds is a tiling-construction
            // invariant, not a program-reachable state
            debug_assert!(src < v.rows, "edge endpoint {src} out of tile bounds {}", v.rows);
            row.copy_from_slice(v.row(src));
        }
    }
    Ok(grew)
}

/// GTHR: reduce edge rows into the partition accumulator
/// (`acc[ld] ⊕= e[ei]` for each edge). The accumulator is written in
/// place and must already be shaped by the partition prologue.
pub fn gather_rows(
    reduce: Reduce,
    e: &Tensor,
    edges: &[(u32, u32)],
    acc: &mut Tensor,
) -> Result<(), String> {
    if e.cols != acc.cols {
        return Err(format!(
            "GTHR column mismatch: edge buffer is {}x{}, accumulator {}x{}",
            e.rows, e.cols, acc.rows, acc.cols
        ));
    }
    if (e.rows as usize) < edges.len() {
        return Err(format!(
            "GTHR edge buffer has {} rows for {} edges",
            e.rows,
            edges.len()
        ));
    }
    match reduce {
        Reduce::Sum => {
            for (ei, &(_, ld)) in edges.iter().enumerate() {
                let src = e.row(ei as u32);
                for (d, &s) in acc.row_mut(ld).iter_mut().zip(src) {
                    *d += s;
                }
            }
        }
        Reduce::Max => {
            for (ei, &(_, ld)) in edges.iter().enumerate() {
                let src = e.row(ei as u32);
                for (d, &s) in acc.row_mut(ld).iter_mut().zip(src) {
                    *d = d.max(s);
                }
            }
        }
    }
    Ok(())
}

// ---- reduced-precision storage (KernelPolicy::dtype) -----------------------
//
// Hand-rolled IEEE 754 binary16 / bfloat16 conversions (the crate is
// dependency-free; no `half` crate). Narrowing rounds to nearest, ties
// to even — the same rounding a hardware store unit performs. The
// simulator keeps the *dequantized* f32 image resident and re-narrows at
// every storage boundary, which is numerically identical to storing 16
// bits and widening at load: f16→f32 is exact, and quantization is
// idempotent (q(q(v)) == q(v), tested below).

/// Narrow an f32 to IEEE binary16 bits, round-to-nearest-even.
/// NaN payload top bits are kept (with the quiet bit forced); values
/// beyond ±65504 that round past the largest normal become ±Inf;
/// |v| < 2⁻²⁵ rounds to ±0.
pub fn f32_to_f16_bits(v: f32) -> u16 {
    let x = v.to_bits();
    let sign = ((x >> 16) & 0x8000) as u16;
    let abs = x & 0x7fff_ffff;
    if abs >= 0x7f80_0000 {
        // Inf / NaN
        return if abs > 0x7f80_0000 {
            sign | 0x7e00 | ((abs >> 13) & 0x03ff) as u16
        } else {
            sign | 0x7c00
        };
    }
    if abs >= 0x4780_0000 {
        // |v| ≥ 65536: past the largest f16 normal even before rounding
        return sign | 0x7c00;
    }
    if abs >= 0x3880_0000 {
        // normal range: rebias exponent, round 23→10 mantissa bits; a
        // mantissa carry propagates into the exponent (and to Inf for
        // values in [65520, 65536)) by construction of the encoding
        let e = ((abs >> 23) as i32 - 127 + 15) as u32;
        let m = abs & 0x007f_ffff;
        let base = (e << 10) | (m >> 13);
        let rem = m & 0x1fff;
        let round = (rem > 0x1000 || (rem == 0x1000 && base & 1 == 1)) as u32;
        return sign | (base + round) as u16;
    }
    if abs < 0x3300_0000 {
        // |v| < 2⁻²⁵: below half the smallest subnormal → ±0
        return sign;
    }
    // subnormal: target mantissa is round(|v| · 2²⁴); shifting the
    // 24-bit significand right by (126 − e) ∈ [14, 24] aligns it
    let e = (abs >> 23) as i32;
    let m = (abs & 0x007f_ffff) | 0x0080_0000;
    let shift = (126 - e) as u32;
    let base = m >> shift;
    let rem = m & ((1 << shift) - 1);
    let half = 1u32 << (shift - 1);
    let round = (rem > half || (rem == half && base & 1 == 1)) as u32;
    sign | (base + round) as u16
}

/// Widen IEEE binary16 bits to f32 (exact).
pub fn f16_bits_to_f32(b: u16) -> f32 {
    let sign = ((b as u32) & 0x8000) << 16;
    let exp = ((b >> 10) & 0x1f) as u32;
    let man = (b & 0x03ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13)
    } else if exp == 0 {
        if man == 0 {
            sign
        } else {
            // subnormal (man · 2⁻²⁴): normalize into an f32 normal
            let shift = man.leading_zeros() - 21;
            sign | ((113 - shift) << 23) | (((man << shift) & 0x03ff) << 13)
        }
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// Narrow an f32 to bfloat16 bits, round-to-nearest-even. NaNs keep
/// their top payload bits with the quiet bit forced.
pub fn f32_to_bf16_bits(v: f32) -> u16 {
    let x = v.to_bits();
    if v.is_nan() {
        return ((x >> 16) as u16) | 0x0040;
    }
    let round = (x >> 16 & 1).wrapping_add(0x7fff);
    (x.wrapping_add(round) >> 16) as u16
}

/// Widen bfloat16 bits to f32 (exact).
pub fn bf16_bits_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Round-trip a buffer through the 16-bit storage format in place
/// (no-op for f32). The resident f32 image becomes the exact
/// dequantization of the stored values. Per element the relative error
/// is bounded by the format's unit roundoff
/// (`StorageDtype::unit_roundoff`): |q(v) − v| ≤ u·|v| for finite
/// in-range v.
pub fn quantize_slice(dtype: StorageDtype, data: &mut [f32]) {
    match dtype {
        StorageDtype::F32 => {}
        StorageDtype::F16 => {
            for v in data {
                *v = f16_bits_to_f32(f32_to_f16_bits(*v));
            }
        }
        StorageDtype::Bf16 => {
            for v in data {
                *v = bf16_bits_to_f32(f32_to_bf16_bits(*v));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Scalar reference GEMM for differential-testing the blocked kernel.
    fn matmul_naive(x: &Tensor, w: &[f32], k: usize, n: usize, out: &mut Vec<f32>) {
        out.clear();
        out.resize(x.rows as usize * n, 0.0);
        for r in 0..x.rows as usize {
            for kk in 0..k {
                let xv = x.data[r * k + kk];
                for j in 0..n {
                    out[r * n + j] += xv * w[kk * n + j];
                }
            }
        }
    }

    #[test]
    fn matmul_small() {
        let x = Tensor::from_rows(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let w = vec![1.0, 0.0, 0.0, 1.0]; // identity
        let mut out = Tensor::default();
        matmul(&x, &w, 2, 2, &mut out, false).unwrap();
        assert_eq!(out.data, x.data);
        // accumulate doubles
        matmul(&x, &w, 2, 2, &mut out, true).unwrap();
        assert_eq!(out.data, vec![2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn blocked_matmul_matches_naive_on_ragged_shapes() {
        let mut rng = Rng::new(3);
        let mut out = Tensor::default();
        let shapes = [(1u32, 1usize, 1usize), (7, 13, 21), (4, 16, 16), (9, 5, 17), (64, 32, 48)];
        for (m, k, n) in shapes {
            let x = Tensor::from_rows(
                m,
                k as u32,
                (0..m as usize * k).map(|_| rng.next_f32_sym()).collect(),
            );
            let w: Vec<f32> = (0..k * n).map(|_| rng.next_f32_sym()).collect();
            let mut expect = Vec::new();
            matmul_naive(&x, &w, k, n, &mut expect);
            matmul(&x, &w, k as u32, n as u32, &mut out, false).unwrap();
            assert_eq!((out.rows, out.cols), (m, n as u32));
            for (a, b) in out.data.iter().zip(&expect) {
                assert!((a - b).abs() < 1e-4, "{m}x{k}x{n}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn in_place_ops_reuse_capacity() {
        let x = Tensor::filled(8, 8, 2.0);
        let mut out = Tensor::default();
        assert!(apply_unary(ElwUnary::Relu, &x, &mut out), "first use must grow");
        let small = Tensor::filled(4, 4, -1.0);
        assert!(
            !apply_unary(ElwUnary::Relu, &small, &mut out),
            "shrinking reuse must not grow"
        );
        assert_eq!((out.rows, out.cols), (4, 4));
        assert!(out.data.iter().all(|&v| v == 0.0));
        assert!(!out.reshape(8, 8), "regrow within capacity must not allocate");
    }

    #[test]
    fn unary_ops() {
        let x = Tensor::from_rows(1, 3, vec![-1.0, 0.0, 2.0]);
        let mut out = Tensor::default();
        apply_unary(ElwUnary::Relu, &x, &mut out);
        assert_eq!(out.data, vec![0.0, 0.0, 2.0]);
        apply_unary(ElwUnary::OneMinus, &x, &mut out);
        assert_eq!(out.data, vec![2.0, 1.0, -1.0]);
        apply_unary(ElwUnary::LeakyRelu, &x, &mut out);
        assert!((out.data[0] + 0.2).abs() < 1e-6);
    }

    #[test]
    fn bcast_divide() {
        let a = Tensor::from_rows(2, 2, vec![2.0, 4.0, 9.0, 12.0]);
        let v = Tensor::from_rows(2, 1, vec![2.0, 3.0]);
        let mut out = Tensor::default();
        apply_bcast(ElwBinary::Div, &a, &v, &mut out).unwrap();
        assert_eq!(out.data, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn bmm_selects_weights() {
        // two 1x1 "matrices": w0 = [10], w1 = [100]
        let x = Tensor::from_rows(3, 1, vec![1.0, 2.0, 3.0]);
        let wset = vec![10.0, 100.0];
        let mut out = Tensor::default();
        bmm_by_type(&x, &wset, 1, 1, Some(&[0, 1, 0]), &mut out).unwrap();
        assert_eq!(out.data, vec![10.0, 200.0, 30.0]);
        // untyped fallback: every edge uses matrix 0
        bmm_by_type(&x, &wset, 1, 1, None, &mut out).unwrap();
        assert_eq!(out.data, vec![10.0, 20.0, 30.0]);
    }

    #[test]
    fn gemv_matches_manual() {
        let x = Tensor::from_rows(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let w = vec![1.0, 0.5, 2.0];
        let mut out = Tensor::default();
        gemv(&x, &w, &mut out).unwrap();
        assert_eq!(out.data, vec![8.0, 18.5]);
    }

    #[test]
    fn scatter_then_gather_roundtrip() {
        let v = Tensor::from_rows(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let edges = [(0u32, 1u32), (2, 1), (1, 0)];
        let mut e = Tensor::default();
        scatter_rows(&v, &edges, SctrDir::OutEdge, 2, &mut e).unwrap();
        assert_eq!(e.data, vec![1.0, 2.0, 5.0, 6.0, 3.0, 4.0]);
        let mut acc = Tensor::zeros(2, 2);
        gather_rows(Reduce::Sum, &e, &edges, &mut acc).unwrap();
        // dst 0 ← edge 2 (src row 1); dst 1 ← edges 0+1 (rows 0+2)
        assert_eq!(acc.data, vec![3.0, 4.0, 6.0, 8.0]);
        let mut mx = Tensor::filled(2, 2, f32::NEG_INFINITY);
        gather_rows(Reduce::Max, &e, &edges, &mut mx).unwrap();
        assert_eq!(mx.data, vec![3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn shape_mismatches_are_errors_carrying_shapes() {
        let a = Tensor::zeros(2, 3);
        let b = Tensor::zeros(3, 2);
        let mut out = Tensor::default();
        let e = apply_binary(ElwBinary::Add, &a, &b, &mut out).unwrap_err();
        assert!(e.contains("2x3") && e.contains("3x2"), "{e}");
        let v = Tensor::zeros(2, 2); // not a column
        let e = apply_bcast(ElwBinary::Div, &a, &v, &mut out).unwrap_err();
        assert!(e.contains("column"), "{e}");
        let e = matmul(&a, &[0.0; 6], 2, 3, &mut out, false).unwrap_err();
        assert!(e.contains("inner-dim"), "{e}");
        let e = matmul(&a, &[0.0; 2], 3, 2, &mut out, false).unwrap_err();
        assert!(e.contains("too small"), "{e}");
        let e = bmm_by_type(&a, &[0.0; 6], 3, 2, Some(&[0, 1]), &mut out).unwrap_err();
        assert!(e.contains("out of range"), "{e}");
        let e = gemv(&a, &[1.0, 2.0], &mut out).unwrap_err();
        assert!(e.contains("GEMV"), "{e}");
        let e = scatter_rows(&a, &[(0, 0)], SctrDir::OutEdge, 5, &mut out).unwrap_err();
        assert!(e.contains("SCTR"), "{e}");
        let edge_buf = Tensor::zeros(1, 4);
        let mut acc = Tensor::zeros(2, 3);
        let e = gather_rows(Reduce::Sum, &edge_buf, &[(0, 0)], &mut acc).unwrap_err();
        assert!(e.contains("GTHR"), "{e}");
    }

    #[test]
    fn inplace_variants_match_out_of_place_bit_exactly() {
        let mut rng = Rng::new(9);
        let mk = |rng: &mut Rng, r: u32, c: u32| {
            Tensor::from_rows(r, c, (0..r as usize * c as usize).map(|_| rng.next_f32_sym()).collect())
        };
        let a = mk(&mut rng, 5, 7);
        let b = mk(&mut rng, 5, 7);
        let v = mk(&mut rng, 5, 1);
        let mut want = Tensor::default();
        let mut got;

        apply_unary(ElwUnary::Sigmoid, &a, &mut want);
        got = a.clone();
        apply_unary_inplace(ElwUnary::Sigmoid, &mut got);
        assert_eq!(got, want);

        apply_binary(ElwBinary::Sub, &a, &b, &mut want).unwrap();
        got = a.clone();
        apply_binary_lhs_inplace(ElwBinary::Sub, &mut got, &b).unwrap();
        assert_eq!(got, want);
        got = b.clone();
        apply_binary_rhs_inplace(ElwBinary::Sub, &a, &mut got).unwrap();
        assert_eq!(got, want);

        apply_binary(ElwBinary::Mul, &a, &a, &mut want).unwrap();
        got = a.clone();
        apply_binary_self_inplace(ElwBinary::Mul, &mut got);
        assert_eq!(got, want);

        apply_bcast(ElwBinary::Div, &a, &v, &mut want).unwrap();
        got = a.clone();
        apply_bcast_inplace(ElwBinary::Div, &mut got, &v).unwrap();
        assert_eq!(got, want);
    }

    fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what} elem {i}: {x} vs {y}");
        }
    }

    fn rand_tensor(rng: &mut Rng, r: u32, c: u32) -> Tensor {
        Tensor::from_rows(
            r,
            c,
            (0..r as usize * c as usize).map(|_| rng.next_f32_sym()).collect(),
        )
    }

    #[test]
    fn remainder_tile_gemm_matches_naive() {
        // dims not divisible by MR=4 / NR=16, exercising the ragged
        // scalar tail, including the accumulate store path
        let mut rng = Rng::new(11);
        let mut out = Tensor::default();
        let shapes =
            [(5u32, 7usize, 17usize), (3, 2, 1), (1, 4, 16), (5, 17, 3), (2, 3, 1), (1, 1, 1)];
        for (m, k, n) in shapes {
            let x = rand_tensor(&mut rng, m, k as u32);
            let w: Vec<f32> = (0..k * n).map(|_| rng.next_f32_sym()).collect();
            let mut expect = Vec::new();
            matmul_naive(&x, &w, k, n, &mut expect);
            matmul(&x, &w, k as u32, n as u32, &mut out, false).unwrap();
            assert_eq!((out.rows, out.cols), (m, n as u32), "{m}x{k}x{n}");
            // both accumulate each output element in one sequential
            // ascending-k chain → bit-exact, not merely close
            assert_bits_eq(&out.data, &expect, "ragged gemm");
            // accumulate folds a second product on top: expect + expect
            matmul(&x, &w, k as u32, n as u32, &mut out, true).unwrap();
            let doubled: Vec<f32> = expect.iter().map(|&v| v + v).collect();
            assert_bits_eq(&out.data, &doubled, "ragged gemm accumulate");
        }
    }

    #[test]
    fn simd_gemm_bit_exact_with_scalar() {
        let mut rng = Rng::new(21);
        let mut scalar = Tensor::default();
        let mut simd = Tensor::default();
        let shapes =
            [(1u32, 1usize, 1usize), (5, 7, 17), (8, 16, 32), (33, 128, 128), (9, 5, 1)];
        for (m, k, n) in shapes {
            let x = rand_tensor(&mut rng, m, k as u32);
            let w: Vec<f32> = (0..k * n).map(|_| rng.next_f32_sym()).collect();
            matmul_with(&x, &w, k as u32, n as u32, &mut scalar, false, false).unwrap();
            matmul_with(&x, &w, k as u32, n as u32, &mut simd, false, true).unwrap();
            assert_bits_eq(&simd.data, &scalar.data, "gemm");
            matmul_with(&x, &w, k as u32, n as u32, &mut scalar, true, false).unwrap();
            matmul_with(&x, &w, k as u32, n as u32, &mut simd, true, true).unwrap();
            assert_bits_eq(&simd.data, &scalar.data, "gemm accumulate");
        }
    }

    #[test]
    fn simd_gemv_and_bmm_bit_exact_with_scalar() {
        let mut rng = Rng::new(22);
        let mut scalar = Tensor::default();
        let mut simd = Tensor::default();
        for (m, k) in [(1u32, 3usize), (7, 16), (64, 128), (13, 1)] {
            let x = rand_tensor(&mut rng, m, k as u32);
            let w: Vec<f32> = (0..k).map(|_| rng.next_f32_sym()).collect();
            gemv_with(&x, &w, &mut scalar, false).unwrap();
            gemv_with(&x, &w, &mut simd, true).unwrap();
            assert_bits_eq(&simd.data, &scalar.data, "gemv");
        }
        for (m, k, n) in [(4u32, 3usize, 5usize), (9, 16, 16), (17, 8, 1)] {
            let x = rand_tensor(&mut rng, m, k as u32);
            let wset: Vec<f32> = (0..3 * k * n).map(|_| rng.next_f32_sym()).collect();
            let etypes: Vec<u8> = (0..m).map(|i| (i % 3) as u8).collect();
            for et in [None, Some(etypes.as_slice())] {
                bmm_by_type_with(&x, &wset, k as u32, n as u32, et, &mut scalar, false)
                    .unwrap();
                bmm_by_type_with(&x, &wset, k as u32, n as u32, et, &mut simd, true)
                    .unwrap();
                assert_bits_eq(&simd.data, &scalar.data, "bmm");
            }
        }
    }

    /// Satellite: NaN / ±0 / subnormal semantics. In-place vs
    /// out-of-place and SIMD vs scalar must agree bit-for-bit on
    /// special values for every op in the ISA.
    #[test]
    fn special_value_semantics_bit_exact_across_policies() {
        let specials = [
            f32::NAN,
            -f32::NAN,
            0.0,
            -0.0,
            1.0e-40,  // f32 subnormal
            -1.0e-40,
            f32::MIN_POSITIVE,
            f32::INFINITY,
            f32::NEG_INFINITY,
            1.5,
            -2.5,
        ];
        // 3 rows × 11 cols so lane chunks mix specials and remainders
        let rows: Vec<f32> = (0..3).flat_map(|_| specials).collect();
        let a = Tensor::from_rows(3, 11, rows.clone());
        let b = Tensor::from_rows(3, 11, rows.iter().rev().copied().collect());
        let v = Tensor::from_rows(3, 1, vec![f32::NAN, -0.0, 2.0]);
        let unary_ops = [
            ElwUnary::Exp,
            ElwUnary::Relu,
            ElwUnary::LeakyRelu,
            ElwUnary::Sigmoid,
            ElwUnary::Tanh,
            ElwUnary::Neg,
            ElwUnary::OneMinus,
            ElwUnary::Recip,
            ElwUnary::Recip0,
        ];
        let binary_ops = [
            ElwBinary::Add,
            ElwBinary::Sub,
            ElwBinary::Mul,
            ElwBinary::Div,
            ElwBinary::Max,
        ];
        let mut want = Tensor::default();
        let mut got = Tensor::default();
        for op in unary_ops {
            apply_unary(op, &a, &mut want);
            for simd in [false, true] {
                apply_unary_with(simd, op, &a, &mut got);
                assert_bits_eq(&got.data, &want.data, "unary");
                let mut t = a.clone();
                apply_unary_inplace_with(simd, op, &mut t);
                assert_bits_eq(&t.data, &want.data, "unary inplace");
            }
        }
        for op in binary_ops {
            apply_binary(op, &a, &b, &mut want).unwrap();
            for simd in [false, true] {
                apply_binary_with(simd, op, &a, &b, &mut got).unwrap();
                assert_bits_eq(&got.data, &want.data, "binary");
                let mut t = a.clone();
                apply_binary_lhs_inplace_with(simd, op, &mut t, &b).unwrap();
                assert_bits_eq(&t.data, &want.data, "binary lhs inplace");
                let mut t = b.clone();
                apply_binary_rhs_inplace_with(simd, op, &a, &mut t).unwrap();
                assert_bits_eq(&t.data, &want.data, "binary rhs inplace");
            }
            apply_binary(op, &a, &a, &mut want).unwrap();
            for simd in [false, true] {
                let mut t = a.clone();
                apply_binary_self_inplace_with(simd, op, &mut t);
                assert_bits_eq(&t.data, &want.data, "binary self inplace");
            }
            apply_bcast(op, &a, &v, &mut want).unwrap();
            for simd in [false, true] {
                apply_bcast_with(simd, op, &a, &v, &mut got).unwrap();
                assert_bits_eq(&got.data, &want.data, "bcast");
                let mut t = a.clone();
                apply_bcast_inplace_with(simd, op, &mut t, &v).unwrap();
                assert_bits_eq(&t.data, &want.data, "bcast inplace");
            }
        }
    }

    #[test]
    fn masked_gemm_computes_touched_rows_and_zeroes_the_rest() {
        let mut rng = Rng::new(31);
        let (m, k, n) = (70u32, 9usize, 19usize);
        let x = rand_tensor(&mut rng, m, k as u32);
        let w: Vec<f32> = (0..k * n).map(|_| rng.next_f32_sym()).collect();
        // touch rows 0..3, 10, 63..66 (crosses the u64 word boundary)
        let mut mask = vec![0u64; 2];
        for r in [0usize, 1, 2, 10, 63, 64, 65] {
            mask[r / 64] |= 1 << (r % 64);
        }
        let mut full = Tensor::default();
        matmul(&x, &w, k as u32, n as u32, &mut full, false).unwrap();
        for simd in [false, true] {
            let mut out = Tensor::filled(m, n as u32, 7.0); // stale garbage
            matmul_masked(&x, &w, k as u32, n as u32, &mut out, false, simd, &mask)
                .unwrap();
            for r in 0..m as usize {
                let got = &out.data[r * n..(r + 1) * n];
                if mask[r / 64] >> (r % 64) & 1 == 1 {
                    assert_bits_eq(got, &full.data[r * n..(r + 1) * n], "touched row");
                } else {
                    assert!(got.iter().all(|&v| v == 0.0), "untouched row {r} not zeroed");
                }
            }
            // accumulate on top: touched rows double, untouched stay 0
            matmul_masked(&x, &w, k as u32, n as u32, &mut out, true, simd, &mask)
                .unwrap();
            for r in 0..m as usize {
                let got = &out.data[r * n..(r + 1) * n];
                if mask[r / 64] >> (r % 64) & 1 == 1 {
                    let doubled: Vec<f32> =
                        full.data[r * n..(r + 1) * n].iter().map(|&v| v + v).collect();
                    assert_bits_eq(got, &doubled, "touched row accumulate");
                } else {
                    assert!(got.iter().all(|&v| v == 0.0), "untouched row {r} disturbed");
                }
            }
        }
    }

    #[test]
    fn f16_roundtrip_is_identity_on_all_finite_bit_patterns() {
        for b in 0..=u16::MAX {
            let v = f16_bits_to_f32(b);
            if v.is_nan() {
                // NaNs stay NaNs (quiet bit may be forced)
                assert!(f16_bits_to_f32(f32_to_f16_bits(v)).is_nan());
                continue;
            }
            assert_eq!(
                f32_to_f16_bits(v),
                b,
                "f16 bits {b:#06x} -> {v} failed to round-trip"
            );
        }
    }

    #[test]
    fn f16_known_values_and_rne() {
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(0.5), 0x3800);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff); // largest normal
        assert_eq!(f32_to_f16_bits(65520.0), 0x7c00); // ties-to-even → Inf
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-24)), 0x0001); // min subnormal
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-26)), 0x0000); // underflow
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // RNE: 1 + 2⁻¹¹ ties down to 1.0, 1 + 3·2⁻¹² rounds up
        assert_eq!(f32_to_f16_bits(1.0 + 2.0f32.powi(-11)), 0x3c00);
        assert_eq!(f32_to_f16_bits(1.0 + 3.0 * 2.0f32.powi(-12)), 0x3c01);
        assert_eq!(f32_to_bf16_bits(1.0), 0x3f80);
        assert_eq!(f32_to_bf16_bits(-2.0), 0xc000);
        assert_eq!(f32_to_bf16_bits(f32::INFINITY), 0x7f80);
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
    }

    #[test]
    fn quantization_error_bounded_by_unit_roundoff_and_idempotent() {
        let mut rng = Rng::new(5);
        for dtype in [StorageDtype::F16, StorageDtype::Bf16] {
            let u = dtype.unit_roundoff();
            for _ in 0..10_000 {
                let v = rng.next_f32_sym() * 100.0;
                if v.abs() < 1.0e-4 {
                    // the relative bound holds for *normal* f16 values;
                    // subnormals have a (tighter) absolute bound instead
                    continue;
                }
                let mut q = [v];
                quantize_slice(dtype, &mut q);
                assert!(
                    (q[0] - v).abs() <= u * v.abs(),
                    "{dtype:?}: |q({v}) - {v}| = {} > u·|v| = {}",
                    (q[0] - v).abs(),
                    u * v.abs()
                );
                let mut q2 = q;
                quantize_slice(dtype, &mut q2);
                assert_eq!(q2[0].to_bits(), q[0].to_bits(), "{dtype:?} not idempotent");
            }
        }
    }

    /// Documented error bound of the reduced-precision GEMM path
    /// (DESIGN.md "Kernel policies"): quantizing x and w to a storage
    /// format with unit roundoff u perturbs each output element by at
    /// most (2u + u²)·Σ_k |x_k|·|w_k| versus the f32 result (first
    /// order in u; the f32 accumulation rounding of both runs adds
    /// k·2⁻²³·Σ|xw|, folded into the 2⁻²⁰ slack term below).
    #[test]
    fn quantized_gemm_error_within_documented_bound() {
        let mut rng = Rng::new(6);
        let (m, k, n) = (12u32, 64usize, 24usize);
        let x = rand_tensor(&mut rng, m, k as u32);
        let w: Vec<f32> = (0..k * n).map(|_| rng.next_f32_sym()).collect();
        let mut exact = Tensor::default();
        matmul(&x, &w, k as u32, n as u32, &mut exact, false).unwrap();
        for dtype in [StorageDtype::F16, StorageDtype::Bf16] {
            let u = dtype.unit_roundoff();
            let mut xq = x.clone();
            quantize_slice(dtype, &mut xq.data);
            let mut wq = w.clone();
            quantize_slice(dtype, &mut wq);
            let mut got = Tensor::default();
            matmul(&xq, &wq, k as u32, n as u32, &mut got, false).unwrap();
            for r in 0..m as usize {
                for j in 0..n {
                    let mag: f32 = (0..k)
                        .map(|kk| (x.data[r * k + kk] * w[kk * n + j]).abs())
                        .sum();
                    let bound = (2.0 * u + u * u + 2.0f32.powi(-20)) * mag;
                    let err = (got.data[r * n + j] - exact.data[r * n + j]).abs();
                    assert!(
                        err <= bound,
                        "{dtype:?} ({r},{j}): err {err} > bound {bound}"
                    );
                }
            }
        }
    }
}
