//! Dense f32 tensors + the functional semantics of every ISA instruction.
//!
//! The simulator executes programs *functionally* as well as temporally:
//! each instruction updates real embedding data so end-of-run outputs can
//! be validated against the PJRT-executed JAX artifacts (the role DGL
//! played for the paper's simulator validation, §8.1).

use crate::isa::{ElwBinary, ElwUnary};

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub rows: u32,
    pub cols: u32,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(rows: u32, cols: u32) -> Self {
        Tensor { rows, cols, data: vec![0.0; rows as usize * cols as usize] }
    }

    pub fn filled(rows: u32, cols: u32, v: f32) -> Self {
        Tensor { rows, cols, data: vec![v; rows as usize * cols as usize] }
    }

    pub fn from_rows(rows: u32, cols: u32, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows as usize * cols as usize);
        Tensor { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: u32) -> &[f32] {
        let c = self.cols as usize;
        &self.data[r as usize * c..(r as usize + 1) * c]
    }

    #[inline]
    pub fn row_mut(&mut self, r: u32) -> &mut [f32] {
        let c = self.cols as usize;
        &mut self.data[r as usize * c..(r as usize + 1) * c]
    }

    pub fn bytes(&self) -> u64 {
        self.data.len() as u64 * 4
    }
}

pub fn apply_unary(op: ElwUnary, x: &Tensor) -> Tensor {
    let f: fn(f32) -> f32 = match op {
        ElwUnary::Exp => |v| v.exp(),
        ElwUnary::Relu => |v| v.max(0.0),
        ElwUnary::LeakyRelu => |v| if v >= 0.0 { v } else { 0.2 * v },
        ElwUnary::Sigmoid => |v| 1.0 / (1.0 + (-v).exp()),
        ElwUnary::Tanh => |v| v.tanh(),
        ElwUnary::Neg => |v| -v,
        ElwUnary::OneMinus => |v| 1.0 - v,
        ElwUnary::Recip => |v| 1.0 / v,
        ElwUnary::Recip0 => |v| if v == 0.0 { 0.0 } else { 1.0 / v },
    };
    Tensor {
        rows: x.rows,
        cols: x.cols,
        data: x.data.iter().map(|&v| f(v)).collect(),
    }
}

pub fn apply_binary(op: ElwBinary, a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "ELW shape mismatch");
    let f: fn(f32, f32) -> f32 = binop(op);
    Tensor {
        rows: a.rows,
        cols: a.cols,
        data: a.data.iter().zip(&b.data).map(|(&x, &y)| f(x, y)).collect(),
    }
}

/// Broadcast a (rows × 1) column over a (rows × cols) operand.
pub fn apply_bcast(op: ElwBinary, a: &Tensor, vec: &Tensor) -> Tensor {
    assert_eq!(a.rows, vec.rows, "broadcast rows mismatch");
    assert_eq!(vec.cols, 1, "broadcast vector must be a column");
    let f = binop(op);
    let mut out = Tensor::zeros(a.rows, a.cols);
    for r in 0..a.rows {
        let v = vec.data[r as usize];
        let src = a.row(r);
        let dst = out.row_mut(r);
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = f(s, v);
        }
    }
    out
}

fn binop(op: ElwBinary) -> fn(f32, f32) -> f32 {
    match op {
        ElwBinary::Add => |x, y| x + y,
        ElwBinary::Sub => |x, y| x - y,
        ElwBinary::Mul => |x, y| x * y,
        ElwBinary::Div => |x, y| x / y,
        ElwBinary::Max => |x, y| x.max(y),
    }
}

/// `x (m×k) @ w (k×n)`, optionally accumulating into `out`.
///
/// Hot path of the functional simulator (see perf benches): ikj
/// order with a 4-way unroll over k so the inner j-loop is a clean
/// multiply-add chain the compiler vectorizes (AVX2/512 with the
/// project's `target-cpu=native` rustflag).
pub fn matmul(x: &Tensor, w: &[f32], k: u32, n: u32, out: &mut Tensor, accumulate: bool) {
    assert_eq!(x.cols, k, "GEMM inner dim");
    assert_eq!((out.rows, out.cols), (x.rows, n), "GEMM out shape");
    if !accumulate {
        out.data.fill(0.0);
    }
    let (k, n) = (k as usize, n as usize);
    for r in 0..x.rows as usize {
        let xrow = &x.data[r * k..(r + 1) * k];
        let orow = &mut out.data[r * n..(r + 1) * n];
        let mut kk = 0;
        while kk + 4 <= k {
            let (x0, x1, x2, x3) = (xrow[kk], xrow[kk + 1], xrow[kk + 2], xrow[kk + 3]);
            let w0 = &w[kk * n..kk * n + n];
            let w1 = &w[(kk + 1) * n..(kk + 1) * n + n];
            let w2 = &w[(kk + 2) * n..(kk + 2) * n + n];
            let w3 = &w[(kk + 3) * n..(kk + 3) * n + n];
            for j in 0..n {
                orow[j] += x0 * w0[j] + x1 * w1[j] + x2 * w2[j] + x3 * w3[j];
            }
            kk += 4;
        }
        while kk < k {
            let xv = xrow[kk];
            let wrow = &w[kk * n..kk * n + n];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
            kk += 1;
        }
    }
}

/// Per-edge typed matmul: edge r uses weight matrix `etypes[r]`.
pub fn bmm_by_type(
    x: &Tensor,
    wset: &[f32],
    k: u32,
    n: u32,
    etypes: &[u8],
    out: &mut Tensor,
) {
    assert_eq!(x.cols, k);
    assert_eq!(etypes.len(), x.rows as usize);
    assert_eq!((out.rows, out.cols), (x.rows, n));
    let mat = (k * n) as usize;
    out.data.fill(0.0);
    for r in 0..x.rows as usize {
        let w = &wset[etypes[r] as usize * mat..(etypes[r] as usize + 1) * mat];
        let xrow = &x.data[r * k as usize..(r + 1) * k as usize];
        let orow = &mut out.data[r * n as usize..(r + 1) * n as usize];
        for (kk, &xv) in xrow.iter().enumerate() {
            let wrow = &w[kk * n as usize..(kk + 1) * n as usize];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
}

/// GEMV: `x (rows×cols) @ w (cols×1)` → (rows×1).
pub fn gemv(x: &Tensor, w: &[f32], out: &mut Tensor) {
    assert_eq!((out.rows, out.cols), (x.rows, 1));
    assert_eq!(w.len(), x.cols as usize);
    for r in 0..x.rows {
        out.data[r as usize] = x.row(r).iter().zip(w).map(|(&a, &b)| a * b).sum();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let x = Tensor::from_rows(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let w = vec![1.0, 0.0, 0.0, 1.0]; // identity
        let mut out = Tensor::zeros(2, 2);
        matmul(&x, &w, 2, 2, &mut out, false);
        assert_eq!(out.data, x.data);
        // accumulate doubles
        matmul(&x, &w, 2, 2, &mut out, true);
        assert_eq!(out.data, vec![2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn unary_ops() {
        let x = Tensor::from_rows(1, 3, vec![-1.0, 0.0, 2.0]);
        assert_eq!(apply_unary(ElwUnary::Relu, &x).data, vec![0.0, 0.0, 2.0]);
        assert_eq!(apply_unary(ElwUnary::OneMinus, &x).data, vec![2.0, 1.0, -1.0]);
        let lr = apply_unary(ElwUnary::LeakyRelu, &x).data;
        assert!((lr[0] + 0.2).abs() < 1e-6);
    }

    #[test]
    fn bcast_divide() {
        let a = Tensor::from_rows(2, 2, vec![2.0, 4.0, 9.0, 12.0]);
        let v = Tensor::from_rows(2, 1, vec![2.0, 3.0]);
        let out = apply_bcast(ElwBinary::Div, &a, &v);
        assert_eq!(out.data, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn bmm_selects_weights() {
        // two 1x1 "matrices": w0 = [10], w1 = [100]
        let x = Tensor::from_rows(3, 1, vec![1.0, 2.0, 3.0]);
        let wset = vec![10.0, 100.0];
        let mut out = Tensor::zeros(3, 1);
        bmm_by_type(&x, &wset, 1, 1, &[0, 1, 0], &mut out);
        assert_eq!(out.data, vec![10.0, 200.0, 30.0]);
    }

    #[test]
    fn gemv_matches_manual() {
        let x = Tensor::from_rows(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let w = vec![1.0, 0.5, 2.0];
        let mut out = Tensor::zeros(2, 1);
        gemv(&x, &w, &mut out);
        assert_eq!(out.data, vec![8.0, 18.5]);
    }
}
