//! Dense f32 tensors + the functional semantics of every ISA instruction.
//!
//! The simulator executes programs *functionally* as well as temporally:
//! each instruction updates real embedding data so end-of-run outputs can
//! be validated against the PJRT-executed JAX artifacts (the role DGL
//! played for the paper's simulator validation, §8.1).
//!
//! **In-place convention** (the executor's zero-allocation contract, see
//! DESIGN.md "Memory discipline"): every op writes into a caller-provided
//! `&mut Tensor`, resizing it in place — capacity is preserved across
//! calls, so the executor's pooled buffer slots never re-allocate on the
//! warm path. Each shaping op returns `true` iff the destination's
//! backing allocation had to grow; the executor feeds that into its
//! allocation counter. New kernels must follow the same convention.
//!
//! **Error convention**: operand-shape mismatches that a (mis)compiled
//! program could reach through the serving path return `Err(String)`
//! carrying the offending shapes — the dispatch core prefixes the
//! instruction — instead of panicking inside a scoped worker thread
//! (which would surface as a messageless "tile worker panicked").
//! `debug_assert!` remains for pure-internal invariants the tiling and
//! compiler construction already guarantee (e.g. local edge endpoints
//! in bounds).
//!
//! The `*_inplace` variants back the dispatch core's aliased-operand
//! (`src == dst`) path: they apply the exact same scalar function to the
//! detached destination tensor, so results are bit-identical to the
//! out-of-place kernels.

use crate::isa::{ElwBinary, ElwUnary, Reduce, SctrDir};

/// Row-major dense matrix.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Tensor {
    pub rows: u32,
    pub cols: u32,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(rows: u32, cols: u32) -> Self {
        Tensor { rows, cols, data: vec![0.0; rows as usize * cols as usize] }
    }

    pub fn filled(rows: u32, cols: u32, v: f32) -> Self {
        Tensor { rows, cols, data: vec![v; rows as usize * cols as usize] }
    }

    pub fn from_rows(rows: u32, cols: u32, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows as usize * cols as usize);
        Tensor { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: u32) -> &[f32] {
        let c = self.cols as usize;
        &self.data[r as usize * c..(r as usize + 1) * c]
    }

    #[inline]
    pub fn row_mut(&mut self, r: u32) -> &mut [f32] {
        let c = self.cols as usize;
        &mut self.data[r as usize * c..(r as usize + 1) * c]
    }

    pub fn bytes(&self) -> u64 {
        self.data.len() as u64 * 4
    }

    /// Reshape in place WITHOUT initializing reused elements — callers
    /// must overwrite every element. Capacity is preserved; returns
    /// `true` iff the backing allocation had to grow.
    pub fn reshape(&mut self, rows: u32, cols: u32) -> bool {
        let len = rows as usize * cols as usize;
        let grew = len > self.data.capacity();
        self.data.resize(len, 0.0);
        self.rows = rows;
        self.cols = cols;
        grew
    }

    /// Reshape in place and set every element to `v` (accumulator
    /// init). Capacity is preserved; returns `true` iff the backing
    /// allocation had to grow.
    pub fn reset_filled(&mut self, rows: u32, cols: u32, v: f32) -> bool {
        let len = rows as usize * cols as usize;
        let grew = len > self.data.capacity();
        self.data.clear();
        self.data.resize(len, v);
        self.rows = rows;
        self.cols = cols;
        grew
    }
}

fn unop(op: ElwUnary) -> fn(f32) -> f32 {
    match op {
        ElwUnary::Exp => |v| v.exp(),
        ElwUnary::Relu => |v| v.max(0.0),
        ElwUnary::LeakyRelu => |v| if v >= 0.0 { v } else { 0.2 * v },
        ElwUnary::Sigmoid => |v| 1.0 / (1.0 + (-v).exp()),
        ElwUnary::Tanh => |v| v.tanh(),
        ElwUnary::Neg => |v| -v,
        ElwUnary::OneMinus => |v| 1.0 - v,
        ElwUnary::Recip => |v| 1.0 / v,
        ElwUnary::Recip0 => |v| if v == 0.0 { 0.0 } else { 1.0 / v },
    }
}

pub fn apply_unary(op: ElwUnary, x: &Tensor, out: &mut Tensor) -> bool {
    let f = unop(op);
    let grew = out.reshape(x.rows, x.cols);
    for (o, &v) in out.data.iter_mut().zip(&x.data) {
        *o = f(v);
    }
    grew
}

/// In-place unary for aliased `src == dst` instructions.
pub fn apply_unary_inplace(op: ElwUnary, t: &mut Tensor) {
    let f = unop(op);
    for v in &mut t.data {
        *v = f(*v);
    }
}

fn binary_shapes_match(a: &Tensor, b: &Tensor) -> Result<(), String> {
    if (a.rows, a.cols) != (b.rows, b.cols) {
        return Err(format!(
            "ELW operand shape mismatch: {}x{} vs {}x{}",
            a.rows, a.cols, b.rows, b.cols
        ));
    }
    Ok(())
}

pub fn apply_binary(
    op: ElwBinary,
    a: &Tensor,
    b: &Tensor,
    out: &mut Tensor,
) -> Result<bool, String> {
    binary_shapes_match(a, b)?;
    let f: fn(f32, f32) -> f32 = binop(op);
    let grew = out.reshape(a.rows, a.cols);
    for ((o, &x), &y) in out.data.iter_mut().zip(&a.data).zip(&b.data) {
        *o = f(x, y);
    }
    Ok(grew)
}

/// In-place binary with the destination aliasing the LEFT operand:
/// `a = f(a, b)`.
pub fn apply_binary_lhs_inplace(
    op: ElwBinary,
    a: &mut Tensor,
    b: &Tensor,
) -> Result<(), String> {
    binary_shapes_match(a, b)?;
    let f = binop(op);
    for (x, &y) in a.data.iter_mut().zip(&b.data) {
        *x = f(*x, y);
    }
    Ok(())
}

/// In-place binary with the destination aliasing the RIGHT operand:
/// `b = f(a, b)`.
pub fn apply_binary_rhs_inplace(
    op: ElwBinary,
    a: &Tensor,
    b: &mut Tensor,
) -> Result<(), String> {
    binary_shapes_match(a, b)?;
    let f = binop(op);
    for (&x, y) in a.data.iter().zip(b.data.iter_mut()) {
        *y = f(x, *y);
    }
    Ok(())
}

/// In-place binary with the destination aliasing BOTH operands:
/// `t = f(t, t)`.
pub fn apply_binary_self_inplace(op: ElwBinary, t: &mut Tensor) {
    let f = binop(op);
    for v in &mut t.data {
        *v = f(*v, *v);
    }
}

fn bcast_shapes_match(a: &Tensor, vec: &Tensor) -> Result<(), String> {
    if a.rows != vec.rows {
        return Err(format!(
            "broadcast row mismatch: operand {}x{} vs vector {}x{}",
            a.rows, a.cols, vec.rows, vec.cols
        ));
    }
    if vec.cols != 1 {
        return Err(format!(
            "broadcast vector must be a column, got {}x{}",
            vec.rows, vec.cols
        ));
    }
    Ok(())
}

/// Broadcast a (rows × 1) column over a (rows × cols) operand.
pub fn apply_bcast(
    op: ElwBinary,
    a: &Tensor,
    vec: &Tensor,
    out: &mut Tensor,
) -> Result<bool, String> {
    bcast_shapes_match(a, vec)?;
    let f = binop(op);
    let grew = out.reshape(a.rows, a.cols);
    let c = a.cols as usize;
    if c > 0 {
        for ((dst, src), &v) in out
            .data
            .chunks_exact_mut(c)
            .zip(a.data.chunks_exact(c))
            .zip(&vec.data)
        {
            for (d, &s) in dst.iter_mut().zip(src) {
                *d = f(s, v);
            }
        }
    }
    Ok(grew)
}

/// In-place broadcast with the destination aliasing the row operand:
/// `a[r][c] = f(a[r][c], vec[r])`.
pub fn apply_bcast_inplace(op: ElwBinary, a: &mut Tensor, vec: &Tensor) -> Result<(), String> {
    bcast_shapes_match(a, vec)?;
    let f = binop(op);
    let c = a.cols as usize;
    if c > 0 {
        for (dst, &v) in a.data.chunks_exact_mut(c).zip(&vec.data) {
            for d in dst.iter_mut() {
                *d = f(*d, v);
            }
        }
    }
    Ok(())
}

fn binop(op: ElwBinary) -> fn(f32, f32) -> f32 {
    match op {
        ElwBinary::Add => |x, y| x + y,
        ElwBinary::Sub => |x, y| x - y,
        ElwBinary::Mul => |x, y| x * y,
        ElwBinary::Div => |x, y| x / y,
        ElwBinary::Max => |x, y| x.max(y),
    }
}

/// Row block of the GEMM microkernel.
const MR: usize = 4;
/// Column panel of the GEMM microkernel: 4×16 f32 accumulators fit the
/// SIMD register file (16 ymm on AVX2), so the k-loop runs register-
/// resident instead of streaming the output row through L1.
const NR: usize = 16;

/// `x (m×k) @ w (k×n)` → `out (m×n)`, in place (capacity preserved).
///
/// Hot path of the functional simulator (see `perf_hotpath`):
/// register-blocked MR×NR microkernel with the k-loop innermost over a
/// contiguous weight-panel row, amortizing each weight load over MR
/// output rows (~4× less weight-stream traffic than the row-at-a-time
/// kernel it replaced). `accumulate` folds into the store, so
/// GEMM-accumulate needs no separate zero + add passes.
pub fn matmul(
    x: &Tensor,
    w: &[f32],
    k: u32,
    n: u32,
    out: &mut Tensor,
    accumulate: bool,
) -> Result<bool, String> {
    if x.cols != k {
        return Err(format!(
            "GEMM inner-dim mismatch: src is {}x{}, k = {k}",
            x.rows, x.cols
        ));
    }
    if (w.len() as u64) < k as u64 * n as u64 {
        return Err(format!(
            "GEMM weight matrix too small: {} elements for {k}x{n}",
            w.len()
        ));
    }
    let grew = if accumulate {
        if (out.rows, out.cols) != (x.rows, n) {
            return Err(format!(
                "GEMM accumulate destination is {}x{}, want {}x{n}",
                out.rows, out.cols, x.rows
            ));
        }
        false
    } else {
        out.reshape(x.rows, n)
    };
    let m = x.rows as usize;
    let (k, n) = (k as usize, n as usize);
    let mut r = 0;
    while r < m {
        let mr = MR.min(m - r);
        let mut j0 = 0;
        while j0 < n {
            let nr = NR.min(n - j0);
            let mut acc = [[0.0f32; NR]; MR];
            if mr == MR && nr == NR {
                // full tile: constant-trip loops, register-resident acc
                for kk in 0..k {
                    let wrow: &[f32; NR] =
                        w[kk * n + j0..kk * n + j0 + NR].try_into().unwrap();
                    for (i, arow) in acc.iter_mut().enumerate() {
                        let xv = x.data[(r + i) * k + kk];
                        for (av, &wv) in arow.iter_mut().zip(wrow) {
                            *av += xv * wv;
                        }
                    }
                }
            } else {
                // ragged edge tile (m % 4 / n % 16 remainders)
                for kk in 0..k {
                    let wrow = &w[kk * n + j0..kk * n + j0 + nr];
                    for (i, arow) in acc[..mr].iter_mut().enumerate() {
                        let xv = x.data[(r + i) * k + kk];
                        for (av, &wv) in arow[..nr].iter_mut().zip(wrow) {
                            *av += xv * wv;
                        }
                    }
                }
            }
            for (i, arow) in acc[..mr].iter().enumerate() {
                let orow = &mut out.data[(r + i) * n + j0..(r + i) * n + j0 + nr];
                if accumulate {
                    for (o, &v) in orow.iter_mut().zip(&arow[..nr]) {
                        *o += v;
                    }
                } else {
                    orow.copy_from_slice(&arow[..nr]);
                }
            }
            j0 += nr;
        }
        r += mr;
    }
    Ok(grew)
}

/// Per-edge typed matmul: edge r uses weight matrix `etypes[r]`
/// (`None` = every edge uses matrix 0, the untyped-graph fallback).
pub fn bmm_by_type(
    x: &Tensor,
    wset: &[f32],
    k: u32,
    n: u32,
    etypes: Option<&[u8]>,
    out: &mut Tensor,
) -> Result<bool, String> {
    if x.cols != k {
        return Err(format!(
            "BMM inner-dim mismatch: src is {}x{}, k = {k}",
            x.rows, x.cols
        ));
    }
    if let Some(t) = etypes {
        if t.len() != x.rows as usize {
            return Err(format!(
                "BMM edge-type count {} != {} edge rows",
                t.len(),
                x.rows
            ));
        }
    }
    let grew = out.reshape(x.rows, n);
    let (k, n) = (k as usize, n as usize);
    let mat = k * n;
    if mat == 0 {
        out.data.fill(0.0);
        return Ok(grew);
    }
    let nmat = wset.len() / mat;
    match etypes.and_then(|t| t.iter().copied().max()) {
        Some(max_ty) if (max_ty as usize) >= nmat => {
            return Err(format!(
                "BMM edge type {max_ty} out of range: weight set holds {nmat} {k}x{n} matrices"
            ));
        }
        None if etypes.is_none() && nmat == 0 => {
            return Err(format!(
                "BMM weight set too small: {} elements for one {k}x{n} matrix",
                wset.len()
            ));
        }
        _ => {}
    }
    for r in 0..x.rows as usize {
        let ty = etypes.map_or(0, |t| t[r] as usize);
        let w = &wset[ty * mat..(ty + 1) * mat];
        let xrow = &x.data[r * k..(r + 1) * k];
        let orow = &mut out.data[r * n..(r + 1) * n];
        orow.fill(0.0);
        for (kk, &xv) in xrow.iter().enumerate() {
            let wrow = &w[kk * n..(kk + 1) * n];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
    Ok(grew)
}

/// GEMV: `x (rows×cols) @ w (cols×1)` → (rows×1), in place.
pub fn gemv(x: &Tensor, w: &[f32], out: &mut Tensor) -> Result<bool, String> {
    if w.len() != x.cols as usize {
        return Err(format!(
            "GEMV weight length {} != src cols {} (src is {}x{})",
            w.len(),
            x.cols,
            x.rows,
            x.cols
        ));
    }
    let grew = out.reshape(x.rows, 1);
    let c = x.cols as usize;
    if c == 0 {
        out.data.fill(0.0);
    } else {
        for (o, xrow) in out.data.iter_mut().zip(x.data.chunks_exact(c)) {
            *o = xrow.iter().zip(w).map(|(&a, &b)| a * b).sum();
        }
    }
    Ok(grew)
}

/// SCTR: expand vertex rows along a tile's COO edge list. `edges` holds
/// (local_src, local_dst) pairs; `dir` picks which side indexes `v`.
pub fn scatter_rows(
    v: &Tensor,
    edges: &[(u32, u32)],
    dir: SctrDir,
    cols: u32,
    out: &mut Tensor,
) -> Result<bool, String> {
    if v.cols != cols {
        return Err(format!(
            "SCTR column mismatch: vertex buffer is {}x{}, want {cols} cols",
            v.rows, v.cols
        ));
    }
    let grew = out.reshape(edges.len() as u32, cols);
    let c = cols as usize;
    if c > 0 {
        for (row, &(ls, ld)) in out.data.chunks_exact_mut(c).zip(edges) {
            let src = match dir {
                SctrDir::OutEdge => ls,
                SctrDir::InEdge => ld,
            };
            // local edge endpoints in bounds is a tiling-construction
            // invariant, not a program-reachable state
            debug_assert!(src < v.rows, "edge endpoint {src} out of tile bounds {}", v.rows);
            row.copy_from_slice(v.row(src));
        }
    }
    Ok(grew)
}

/// GTHR: reduce edge rows into the partition accumulator
/// (`acc[ld] ⊕= e[ei]` for each edge). The accumulator is written in
/// place and must already be shaped by the partition prologue.
pub fn gather_rows(
    reduce: Reduce,
    e: &Tensor,
    edges: &[(u32, u32)],
    acc: &mut Tensor,
) -> Result<(), String> {
    if e.cols != acc.cols {
        return Err(format!(
            "GTHR column mismatch: edge buffer is {}x{}, accumulator {}x{}",
            e.rows, e.cols, acc.rows, acc.cols
        ));
    }
    if (e.rows as usize) < edges.len() {
        return Err(format!(
            "GTHR edge buffer has {} rows for {} edges",
            e.rows,
            edges.len()
        ));
    }
    match reduce {
        Reduce::Sum => {
            for (ei, &(_, ld)) in edges.iter().enumerate() {
                let src = e.row(ei as u32);
                for (d, &s) in acc.row_mut(ld).iter_mut().zip(src) {
                    *d += s;
                }
            }
        }
        Reduce::Max => {
            for (ei, &(_, ld)) in edges.iter().enumerate() {
                let src = e.row(ei as u32);
                for (d, &s) in acc.row_mut(ld).iter_mut().zip(src) {
                    *d = d.max(s);
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Scalar reference GEMM for differential-testing the blocked kernel.
    fn matmul_naive(x: &Tensor, w: &[f32], k: usize, n: usize, out: &mut Vec<f32>) {
        out.clear();
        out.resize(x.rows as usize * n, 0.0);
        for r in 0..x.rows as usize {
            for kk in 0..k {
                let xv = x.data[r * k + kk];
                for j in 0..n {
                    out[r * n + j] += xv * w[kk * n + j];
                }
            }
        }
    }

    #[test]
    fn matmul_small() {
        let x = Tensor::from_rows(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let w = vec![1.0, 0.0, 0.0, 1.0]; // identity
        let mut out = Tensor::default();
        matmul(&x, &w, 2, 2, &mut out, false).unwrap();
        assert_eq!(out.data, x.data);
        // accumulate doubles
        matmul(&x, &w, 2, 2, &mut out, true).unwrap();
        assert_eq!(out.data, vec![2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn blocked_matmul_matches_naive_on_ragged_shapes() {
        let mut rng = Rng::new(3);
        let mut out = Tensor::default();
        let shapes = [(1u32, 1usize, 1usize), (7, 13, 21), (4, 16, 16), (9, 5, 17), (64, 32, 48)];
        for (m, k, n) in shapes {
            let x = Tensor::from_rows(
                m,
                k as u32,
                (0..m as usize * k).map(|_| rng.next_f32_sym()).collect(),
            );
            let w: Vec<f32> = (0..k * n).map(|_| rng.next_f32_sym()).collect();
            let mut expect = Vec::new();
            matmul_naive(&x, &w, k, n, &mut expect);
            matmul(&x, &w, k as u32, n as u32, &mut out, false).unwrap();
            assert_eq!((out.rows, out.cols), (m, n as u32));
            for (a, b) in out.data.iter().zip(&expect) {
                assert!((a - b).abs() < 1e-4, "{m}x{k}x{n}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn in_place_ops_reuse_capacity() {
        let x = Tensor::filled(8, 8, 2.0);
        let mut out = Tensor::default();
        assert!(apply_unary(ElwUnary::Relu, &x, &mut out), "first use must grow");
        let small = Tensor::filled(4, 4, -1.0);
        assert!(
            !apply_unary(ElwUnary::Relu, &small, &mut out),
            "shrinking reuse must not grow"
        );
        assert_eq!((out.rows, out.cols), (4, 4));
        assert!(out.data.iter().all(|&v| v == 0.0));
        assert!(!out.reshape(8, 8), "regrow within capacity must not allocate");
    }

    #[test]
    fn unary_ops() {
        let x = Tensor::from_rows(1, 3, vec![-1.0, 0.0, 2.0]);
        let mut out = Tensor::default();
        apply_unary(ElwUnary::Relu, &x, &mut out);
        assert_eq!(out.data, vec![0.0, 0.0, 2.0]);
        apply_unary(ElwUnary::OneMinus, &x, &mut out);
        assert_eq!(out.data, vec![2.0, 1.0, -1.0]);
        apply_unary(ElwUnary::LeakyRelu, &x, &mut out);
        assert!((out.data[0] + 0.2).abs() < 1e-6);
    }

    #[test]
    fn bcast_divide() {
        let a = Tensor::from_rows(2, 2, vec![2.0, 4.0, 9.0, 12.0]);
        let v = Tensor::from_rows(2, 1, vec![2.0, 3.0]);
        let mut out = Tensor::default();
        apply_bcast(ElwBinary::Div, &a, &v, &mut out).unwrap();
        assert_eq!(out.data, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn bmm_selects_weights() {
        // two 1x1 "matrices": w0 = [10], w1 = [100]
        let x = Tensor::from_rows(3, 1, vec![1.0, 2.0, 3.0]);
        let wset = vec![10.0, 100.0];
        let mut out = Tensor::default();
        bmm_by_type(&x, &wset, 1, 1, Some(&[0, 1, 0]), &mut out).unwrap();
        assert_eq!(out.data, vec![10.0, 200.0, 30.0]);
        // untyped fallback: every edge uses matrix 0
        bmm_by_type(&x, &wset, 1, 1, None, &mut out).unwrap();
        assert_eq!(out.data, vec![10.0, 20.0, 30.0]);
    }

    #[test]
    fn gemv_matches_manual() {
        let x = Tensor::from_rows(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let w = vec![1.0, 0.5, 2.0];
        let mut out = Tensor::default();
        gemv(&x, &w, &mut out).unwrap();
        assert_eq!(out.data, vec![8.0, 18.5]);
    }

    #[test]
    fn scatter_then_gather_roundtrip() {
        let v = Tensor::from_rows(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let edges = [(0u32, 1u32), (2, 1), (1, 0)];
        let mut e = Tensor::default();
        scatter_rows(&v, &edges, SctrDir::OutEdge, 2, &mut e).unwrap();
        assert_eq!(e.data, vec![1.0, 2.0, 5.0, 6.0, 3.0, 4.0]);
        let mut acc = Tensor::zeros(2, 2);
        gather_rows(Reduce::Sum, &e, &edges, &mut acc).unwrap();
        // dst 0 ← edge 2 (src row 1); dst 1 ← edges 0+1 (rows 0+2)
        assert_eq!(acc.data, vec![3.0, 4.0, 6.0, 8.0]);
        let mut mx = Tensor::filled(2, 2, f32::NEG_INFINITY);
        gather_rows(Reduce::Max, &e, &edges, &mut mx).unwrap();
        assert_eq!(mx.data, vec![3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn shape_mismatches_are_errors_carrying_shapes() {
        let a = Tensor::zeros(2, 3);
        let b = Tensor::zeros(3, 2);
        let mut out = Tensor::default();
        let e = apply_binary(ElwBinary::Add, &a, &b, &mut out).unwrap_err();
        assert!(e.contains("2x3") && e.contains("3x2"), "{e}");
        let v = Tensor::zeros(2, 2); // not a column
        let e = apply_bcast(ElwBinary::Div, &a, &v, &mut out).unwrap_err();
        assert!(e.contains("column"), "{e}");
        let e = matmul(&a, &[0.0; 6], 2, 3, &mut out, false).unwrap_err();
        assert!(e.contains("inner-dim"), "{e}");
        let e = matmul(&a, &[0.0; 2], 3, 2, &mut out, false).unwrap_err();
        assert!(e.contains("too small"), "{e}");
        let e = bmm_by_type(&a, &[0.0; 6], 3, 2, Some(&[0, 1]), &mut out).unwrap_err();
        assert!(e.contains("out of range"), "{e}");
        let e = gemv(&a, &[1.0, 2.0], &mut out).unwrap_err();
        assert!(e.contains("GEMV"), "{e}");
        let e = scatter_rows(&a, &[(0, 0)], SctrDir::OutEdge, 5, &mut out).unwrap_err();
        assert!(e.contains("SCTR"), "{e}");
        let edge_buf = Tensor::zeros(1, 4);
        let mut acc = Tensor::zeros(2, 3);
        let e = gather_rows(Reduce::Sum, &edge_buf, &[(0, 0)], &mut acc).unwrap_err();
        assert!(e.contains("GTHR"), "{e}");
    }

    #[test]
    fn inplace_variants_match_out_of_place_bit_exactly() {
        let mut rng = Rng::new(9);
        let mk = |rng: &mut Rng, r: u32, c: u32| {
            Tensor::from_rows(r, c, (0..r as usize * c as usize).map(|_| rng.next_f32_sym()).collect())
        };
        let a = mk(&mut rng, 5, 7);
        let b = mk(&mut rng, 5, 7);
        let v = mk(&mut rng, 5, 1);
        let mut want = Tensor::default();
        let mut got;

        apply_unary(ElwUnary::Sigmoid, &a, &mut want);
        got = a.clone();
        apply_unary_inplace(ElwUnary::Sigmoid, &mut got);
        assert_eq!(got, want);

        apply_binary(ElwBinary::Sub, &a, &b, &mut want).unwrap();
        got = a.clone();
        apply_binary_lhs_inplace(ElwBinary::Sub, &mut got, &b).unwrap();
        assert_eq!(got, want);
        got = b.clone();
        apply_binary_rhs_inplace(ElwBinary::Sub, &a, &mut got).unwrap();
        assert_eq!(got, want);

        apply_binary(ElwBinary::Mul, &a, &a, &mut want).unwrap();
        got = a.clone();
        apply_binary_self_inplace(ElwBinary::Mul, &mut got);
        assert_eq!(got, want);

        apply_bcast(ElwBinary::Div, &a, &v, &mut want).unwrap();
        got = a.clone();
        apply_bcast_inplace(ElwBinary::Div, &mut got, &v).unwrap();
        assert_eq!(got, want);
    }
}
