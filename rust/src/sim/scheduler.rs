//! Stream scoreboard and issue logic (paper §5.2's two-level scheduler,
//! top level): one dStream plus N sStreams and N eStreams, each a
//! program counter into its SDE function with a ready-time, a signal
//! counter, and (for s/e streams) a bound tile context.
//!
//! The scheduler picks the runnable stream with the earliest ready time;
//! SIGNAL/WAIT wakeups are implemented here so the engine's instruction
//! semantics stay free of scoreboard bookkeeping.

use crate::config::ArchConfig;
use crate::isa::{DimCtx, StreamClass};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum StreamState {
    Ready,
    /// Blocked in WAIT until enough signals arrive.
    Waiting,
    Halted,
}

/// Tile context bound to a stream between FCH.TILE and CHK.PTT, and
/// handed from sStreams to eStreams by SIGNAL.E.
#[derive(Clone, Debug)]
pub(crate) struct TileCtx {
    pub part_idx: usize,
    pub tile_idx: usize,
    pub dims: DimCtx,
    /// Functional tile-frame id (index into `ExecScratch` tile frames).
    pub frame: usize,
}

pub(crate) struct Stream {
    pub class: StreamClass,
    pub func: &'static str,
    pub pc: usize,
    pub state: StreamState,
    /// Simulation time at which the stream can issue its next instruction.
    pub ready_at: u64,
    pub signals: u32,
    /// Tile contexts handed over by SIGNAL.E (eStreams).
    pub mailbox: Vec<TileCtx>,
    /// Currently bound tile (s/e streams).
    pub tile: Option<TileCtx>,
}

impl Stream {
    fn new(class: StreamClass, func: &'static str) -> Stream {
        Stream {
            class,
            func,
            pc: 0,
            state: StreamState::Ready,
            ready_at: 0,
            signals: 0,
            mailbox: Vec::new(),
            tile: None,
        }
    }
}

/// The stream scoreboard. Stream 0 is always the dStream.
pub(crate) struct Scheduler {
    pub streams: Vec<Stream>,
}

impl Scheduler {
    pub fn new(arch: &ArchConfig) -> Scheduler {
        let mut streams = Vec::with_capacity(1 + (arch.s_streams + arch.e_streams) as usize);
        streams.push(Stream::new(StreamClass::D, "d"));
        for _ in 0..arch.s_streams {
            streams.push(Stream::new(StreamClass::S, "s"));
        }
        for _ in 0..arch.e_streams {
            streams.push(Stream::new(StreamClass::E, "e"));
        }
        Scheduler { streams }
    }

    /// Runnable stream with the earliest ready time, if any.
    pub fn pick_ready(&self) -> Option<usize> {
        let mut best: Option<(usize, u64)> = None;
        for (i, s) in self.streams.iter().enumerate() {
            if s.state != StreamState::Ready {
                continue;
            }
            if best.map_or(true, |(_, t)| s.ready_at < t) {
                best = Some((i, s.ready_at));
            }
        }
        best.map(|(i, _)| i)
    }

    pub fn d_halted(&self) -> bool {
        self.streams[0].state == StreamState::Halted
    }

    /// Advance a stream past the instruction it just executed.
    pub fn advance(&mut self, sid: usize, end: u64, pc_delta: i64) {
        let s = &mut self.streams[sid];
        s.ready_at = end;
        s.pc = (s.pc as i64 + pc_delta) as usize;
    }

    /// Credit one signal to stream `sid`, waking it if it was waiting.
    pub fn signal(&mut self, sid: usize, at: u64) {
        let s = &mut self.streams[sid];
        s.signals += 1;
        if s.state == StreamState::Waiting {
            s.state = StreamState::Ready;
            s.ready_at = s.ready_at.max(at);
        }
    }

    /// SIGNAL.S broadcast: wake every sStream for the new partition.
    pub fn signal_all_s(&mut self, at: u64) {
        for i in 0..self.streams.len() {
            if self.streams[i].class == StreamClass::S {
                self.signal(i, at);
            }
        }
    }

    /// SIGNAL.E rendezvous: hand `tile` to the least-loaded eStream.
    pub fn deliver_tile_to_e(&mut self, tile: TileCtx, at: u64) -> Result<(), String> {
        let eid = self
            .streams
            .iter()
            .enumerate()
            .filter(|(_, s)| s.class == StreamClass::E)
            .min_by_key(|(_, s)| s.mailbox.len())
            .map(|(i, _)| i)
            .ok_or("no eStreams configured")?;
        self.streams[eid].mailbox.insert(0, tile);
        self.signal(eid, at);
        Ok(())
    }

    /// Latest ready time across all streams (end-of-run cycle count).
    pub fn max_ready_at(&self) -> u64 {
        self.streams.iter().map(|s| s.ready_at).max().unwrap_or(0)
    }

    /// Debug dump for deadlock diagnostics.
    pub fn state_dump(&self) -> String {
        format!(
            "{:?}",
            self.streams
                .iter()
                .map(|s| (s.func, s.pc, s.state))
                .collect::<Vec<_>>()
        )
    }
}
