//! Functional execution: every ISA instruction also runs on real f32
//! embeddings so end-of-run outputs validate against the PJRT oracle.
//!
//! All run-local state lives in [`ExecScratch`], a reusable arena the
//! caller owns: a serving worker allocates one scratch and reuses it for
//! every request, so repeat simulations pay no per-run `HashMap`/`Vec`
//! churn. Buffer frames are flat slot vectors indexed by `BufId` (the
//! compiler assigns dense ids per frame), which also removes the hashing
//! the old engine paid on every operand access.

use super::scheduler::TileCtx;
use super::tensor::{self, Tensor};
use crate::compiler::{AccKind, Program, PART_FRAME_BASE};
use crate::isa::{BufId, Dim, DimCtx, Instr, LdTarget, Reduce, SctrDir};
use crate::models::WeightStore;
use crate::tiling::Tiling;

/// Borrow bundle of the plan pieces the executor reads.
pub(crate) struct Env<'a> {
    pub program: &'a Program,
    pub tiling: &'a Tiling,
    pub weights: &'a WeightStore,
    pub feat_in: u32,
    pub feat_out: u32,
}

impl<'a> Env<'a> {
    pub fn of(wl: &super::types::Workload<'a>) -> Env<'a> {
        Env {
            program: wl.program,
            tiling: wl.tiling,
            weights: wl.weights,
            feat_in: wl.feat_in,
            feat_out: wl.feat_out,
        }
    }
}

/// Reusable per-worker scratch for simulation runs. Create once, pass to
/// `Simulator::run_with` (or `ExecPlan::simulate_with`) for every run;
/// buffers are recycled between runs instead of reallocated.
pub struct ExecScratch {
    pub(crate) func: FuncState,
}

impl ExecScratch {
    pub fn new() -> ExecScratch {
        ExecScratch {
            func: FuncState {
                x_tiled: Vec::new(),
                out_tiled: Vec::new(),
                part_frame: Frame::new(),
                tile_frames: Vec::new(),
                next_frame: 0,
                has_input: false,
            },
        }
    }
}

impl Default for ExecScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// One buffer frame: dense `BufId` → tensor slots.
pub(crate) struct Frame {
    slots: Vec<Option<Tensor>>,
}

impl Frame {
    fn new() -> Frame {
        Frame { slots: Vec::new() }
    }

    fn clear(&mut self) {
        for s in &mut self.slots {
            *s = None;
        }
    }

    fn get(&self, i: usize) -> Option<&Tensor> {
        self.slots.get(i).and_then(|s| s.as_ref())
    }

    fn get_mut(&mut self, i: usize) -> Option<&mut Tensor> {
        self.slots.get_mut(i).and_then(|s| s.as_mut())
    }

    fn put(&mut self, i: usize, t: Tensor) {
        if self.slots.len() <= i {
            self.slots.resize_with(i + 1, || None);
        }
        self.slots[i] = Some(t);
    }
}

fn part_slot(buf: BufId) -> usize {
    (buf.0 - PART_FRAME_BASE) as usize
}

/// Functional state of one run, recycled across runs via `ExecScratch`.
pub(crate) struct FuncState {
    /// Permuted input (V × feat_in), tiled vertex order.
    pub x_tiled: Vec<f32>,
    /// Permuted output (V × feat_out), tiled vertex order.
    pub out_tiled: Vec<f32>,
    part_frame: Frame,
    tile_frames: Vec<Frame>,
    pub next_frame: usize,
    pub has_input: bool,
}

impl FuncState {
    /// Reset per-run state; retains buffer capacity from prior runs.
    pub fn begin_run(&mut self) {
        self.part_frame.clear();
        for f in &mut self.tile_frames {
            f.clear();
        }
        self.next_frame = 0;
        self.has_input = false;
    }

    /// Permute the caller's input embeddings into tiled vertex order.
    pub fn init_input(&mut self, tiling: &Tiling, x: &[f32], feat_in: u32) -> Result<(), String> {
        let n = tiling.num_vertices as usize;
        let f = feat_in as usize;
        if x.len() != n * f {
            return Err(format!(
                "input embedding size {} != |V|*feat_in = {}",
                x.len(),
                n * f
            ));
        }
        self.x_tiled.resize(n * f, 0.0);
        for old in 0..n {
            let new = tiling.perm[old] as usize;
            self.x_tiled[new * f..(new + 1) * f].copy_from_slice(&x[old * f..(old + 1) * f]);
        }
        self.has_input = true;
        Ok(())
    }

    /// Size (and zero) the tiled output image for a functional run.
    pub fn prepare_output(&mut self, num_vertices: u32, feat_out: u32) {
        let len = num_vertices as usize * feat_out as usize;
        self.out_tiled.clear();
        self.out_tiled.resize(len, 0.0);
    }

    /// Column width of a partition accumulator (learned from the Gthr
    /// that writes it).
    fn acc_cols(&self, env: &Env, buf: BufId) -> u32 {
        for i in &env.program.e_func {
            if let Instr::Gthr { dst, cols, .. } = i {
                if *dst == buf {
                    return match cols {
                        Dim::FeatIn => env.feat_in,
                        Dim::FeatOut => env.feat_out,
                        Dim::Const(c) => *c,
                        _ => env.feat_out,
                    };
                }
            }
        }
        env.feat_out
    }

    /// FCH.PTT: reset the partition frame and init accumulators.
    pub fn begin_partition(&mut self, env: &Env, dims: &DimCtx) {
        self.part_frame.clear();
        for &(buf, kind) in &env.program.accumulators {
            let cols = self.acc_cols(env, buf);
            let init = match kind {
                AccKind::Sum => 0.0,
                AccKind::Max => f32::NEG_INFINITY,
            };
            self.part_frame
                .put(part_slot(buf), Tensor::filled(dims.part_dst, cols, init));
        }
    }

    /// dStream wait boundary: neutralize untouched Max accumulators.
    pub fn fixup_max_accs(&mut self, env: &Env) {
        for &(buf, kind) in &env.program.accumulators {
            if kind == AccKind::Max {
                if let Some(t) = self.part_frame.get_mut(part_slot(buf)) {
                    for v in &mut t.data {
                        if *v == f32::NEG_INFINITY {
                            *v = 0.0;
                        }
                    }
                }
            }
        }
    }

    /// UPD.PTT: commit the partition output rows and recycle tile frames.
    pub fn commit_partition(
        &mut self,
        env: &Env,
        part: &crate::tiling::Partition,
    ) -> Result<(), String> {
        let out_buf = env.program.output_buf;
        let t = self
            .part_frame
            .get(part_slot(out_buf))
            .ok_or("output buffer not materialized")?;
        let f = env.feat_out as usize;
        for (i, d) in (part.dst_start..part.dst_end).enumerate() {
            self.out_tiled[d as usize * f..(d as usize + 1) * f].copy_from_slice(t.row(i as u32));
        }
        for fr in &mut self.tile_frames {
            fr.clear();
        }
        self.next_frame = 0;
        Ok(())
    }

    /// FCH.TILE: claim the next tile-frame id (frames are recycled at
    /// each UPD.PTT, so ids restart per partition).
    pub fn alloc_tile_frame(&mut self, functional: bool) -> usize {
        let frame = self.next_frame;
        self.next_frame += 1;
        if functional {
            while self.tile_frames.len() <= frame {
                self.tile_frames.push(Frame::new());
            }
        }
        frame
    }

    /// Un-permute the tiled output back to original vertex order.
    pub fn take_output(&self, tiling: &Tiling, feat_out: u32) -> Vec<f32> {
        let n = tiling.num_vertices as usize;
        let f = feat_out as usize;
        let mut out = vec![0.0f32; n * f];
        for new in 0..n {
            let old = tiling.inv_perm[new] as usize;
            out[old * f..(old + 1) * f].copy_from_slice(&self.out_tiled[new * f..(new + 1) * f]);
        }
        out
    }

    fn get_buf(&self, tile: Option<&TileCtx>, buf: BufId) -> Result<&Tensor, String> {
        if buf.is_partition_frame() {
            self.part_frame
                .get(part_slot(buf))
                .ok_or_else(|| format!("partition buffer b{} unset", buf.0))
        } else {
            let frame = tile.ok_or("tile buf w/o tile")?.frame;
            self.tile_frames
                .get(frame)
                .and_then(|f| f.get(buf.0 as usize))
                .ok_or_else(|| format!("tile buffer b{} unset (frame {frame})", buf.0))
        }
    }

    fn put_buf(&mut self, tile: Option<&TileCtx>, buf: BufId, t: Tensor) -> Result<(), String> {
        if buf.is_partition_frame() {
            self.part_frame.put(part_slot(buf), t);
        } else {
            let frame = tile.ok_or("tile buf w/o tile")?.frame;
            while self.tile_frames.len() <= frame {
                self.tile_frames.push(Frame::new());
            }
            self.tile_frames[frame].put(buf.0 as usize, t);
        }
        Ok(())
    }

    /// Functional semantics of LD.* (the edge list lives in the Tile
    /// struct already, so LD.EDGE is timing-only).
    pub fn exec_load(
        &mut self,
        env: &Env,
        tile: Option<&TileCtx>,
        cur_part: Option<usize>,
        instr: &Instr,
    ) -> Result<(), String> {
        let Instr::Ld { target, dst, .. } = instr else {
            return Err(format!("exec_load on non-load instr {instr}"));
        };
        match target {
            LdTarget::Edge => Ok(()),
            LdTarget::Src => {
                let tc = tile.ok_or("LD.SRC w/o tile")?;
                if !self.has_input {
                    return Err("functional run without input x".into());
                }
                let part = &env.tiling.partitions[tc.part_idx];
                let t_meta = &part.tiles[tc.tile_idx];
                let f = env.feat_in as usize;
                let mut t = Tensor::zeros(t_meta.num_src(), env.feat_in);
                for (i, &v) in t_meta.src_vertices.iter().enumerate() {
                    t.row_mut(i as u32)
                        .copy_from_slice(&self.x_tiled[v as usize * f..(v as usize + 1) * f]);
                }
                self.put_buf(tile, *dst, t)
            }
            LdTarget::Dst => {
                let p = cur_part.ok_or("LD.DST w/o partition")?;
                if !self.has_input {
                    return Err("functional run without input x".into());
                }
                let part = &env.tiling.partitions[p];
                let f = env.feat_in as usize;
                let mut t = Tensor::zeros(part.num_dst(), env.feat_in);
                for (i, v) in (part.dst_start..part.dst_end).enumerate() {
                    t.row_mut(i as u32)
                        .copy_from_slice(&self.x_tiled[v as usize * f..(v as usize + 1) * f]);
                }
                self.put_buf(tile, *dst, t)
            }
        }
    }

    /// Functional semantics of every compute instruction.
    pub fn exec_compute(
        &mut self,
        env: &Env,
        tile: Option<&TileCtx>,
        dims: &DimCtx,
        instr: &Instr,
    ) -> Result<(), String> {
        let rd = |d: Dim| d.resolve(dims);
        match instr {
            Instr::ElwU { op, src, dst, .. } => {
                let t = tensor::apply_unary(*op, self.get_buf(tile, *src)?);
                self.put_buf(tile, *dst, t)
            }
            Instr::ElwB { op, a, b, dst, .. } => {
                let t =
                    tensor::apply_binary(*op, self.get_buf(tile, *a)?, self.get_buf(tile, *b)?);
                self.put_buf(tile, *dst, t)
            }
            Instr::ElwBcast { op, a, vec, dst, .. } => {
                let t =
                    tensor::apply_bcast(*op, self.get_buf(tile, *a)?, self.get_buf(tile, *vec)?);
                self.put_buf(tile, *dst, t)
            }
            Instr::Gemv { src, weight: w, dst, .. } => {
                let x = self.get_buf(tile, *src)?;
                let mut out = Tensor::zeros(x.rows, 1);
                tensor::gemv(x, &env.weights.tensors[w.0 as usize].data, &mut out);
                self.put_buf(tile, *dst, out)
            }
            Instr::Gemm { src, weight: w, dst, k, n, accumulate, .. } => {
                let x = self.get_buf(tile, *src)?;
                let mut out = Tensor::zeros(x.rows, rd(*n));
                tensor::matmul(
                    x,
                    &env.weights.tensors[w.0 as usize].data,
                    rd(*k),
                    rd(*n),
                    &mut out,
                    false,
                );
                if *accumulate {
                    let sum = {
                        let prev = self.get_buf(tile, *dst)?;
                        tensor::apply_binary(crate::isa::ElwBinary::Add, prev, &out)
                    };
                    self.put_buf(tile, *dst, sum)
                } else {
                    self.put_buf(tile, *dst, out)
                }
            }
            Instr::Bmm { src, weights, dst, k, n, .. } => {
                let tc = tile.ok_or("BMM w/o tile")?;
                let part = &env.tiling.partitions[tc.part_idx];
                let t_meta = &part.tiles[tc.tile_idx];
                let default_types;
                let etypes: &[u8] = match &t_meta.etypes {
                    Some(t) => t.as_slice(),
                    None => {
                        default_types = vec![0u8; t_meta.edges.len()];
                        &default_types
                    }
                };
                let x = self.get_buf(tile, *src)?;
                let mut out = Tensor::zeros(x.rows, rd(*n));
                tensor::bmm_by_type(
                    x,
                    &env.weights.tensors[weights.0 as usize].data,
                    rd(*k),
                    rd(*n),
                    etypes,
                    &mut out,
                );
                self.put_buf(tile, *dst, out)
            }
            Instr::Sctr { dir, src, dst, cols } => {
                let tc = tile.ok_or("SCTR w/o tile")?;
                let part = &env.tiling.partitions[tc.part_idx];
                let t_meta = &part.tiles[tc.tile_idx];
                let v = self.get_buf(tile, *src)?;
                let mut out = Tensor::zeros(t_meta.num_edges(), rd(*cols));
                for (e, &(ls, ld)) in t_meta.edges.iter().enumerate() {
                    let row = match dir {
                        SctrDir::OutEdge => v.row(ls),
                        SctrDir::InEdge => v.row(ld),
                    };
                    out.row_mut(e as u32).copy_from_slice(row);
                }
                self.put_buf(tile, *dst, out)
            }
            Instr::Gthr { reduce, src, dst, .. } => {
                let tc = tile.ok_or("GTHR w/o tile")?;
                let part = &env.tiling.partitions[tc.part_idx];
                let t_meta = &part.tiles[tc.tile_idx];
                // disjoint-field borrows: edge data lives in a tile
                // frame, the accumulator in the partition frame — no
                // clone needed (functional-mode hot-spot)
                let e = self
                    .tile_frames
                    .get(tc.frame)
                    .and_then(|f| f.get(src.0 as usize))
                    .ok_or_else(|| format!("tile buffer b{} unset", src.0))?;
                let acc = self
                    .part_frame
                    .get_mut(part_slot(*dst))
                    .ok_or_else(|| format!("accumulator b{} unset", dst.0))?;
                for (ei, &(_, ld)) in t_meta.edges.iter().enumerate() {
                    let src_row = e.row(ei as u32);
                    let dst_row = acc.row_mut(ld);
                    match reduce {
                        Reduce::Sum => {
                            for (d, &s) in dst_row.iter_mut().zip(src_row) {
                                *d += s;
                            }
                        }
                        Reduce::Max => {
                            for (d, &s) in dst_row.iter_mut().zip(src_row) {
                                *d = d.max(s);
                            }
                        }
                    }
                }
                Ok(())
            }
            other => Err(format!("unexpected compute instr: {other}")),
        }
    }
}
