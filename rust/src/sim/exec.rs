//! Functional execution: every ISA instruction also runs on real f32
//! embeddings so end-of-run outputs validate against the PJRT oracle.
//!
//! All run-local state lives in [`ExecScratch`], a reusable arena the
//! caller owns: a serving worker allocates one scratch and reuses it for
//! every request. Buffer frames are flat slot vectors indexed by `BufId`
//! whose tensors are *pooled* — clearing a frame only marks its slots
//! dead, the backing allocations stay resident — and every compute
//! instruction borrows its destination slot and computes into it via the
//! in-place kernels in [`super::tensor`]. Combined with the `begin_run`
//! pre-sizing pass (frame/slot counts come straight from the plan), a
//! warm request does zero pool growth; [`ExecScratch::alloc_events`]
//! counts the growth events so benches can assert exactly that.
//!
//! Per-instruction functional semantics do NOT live here: they live in
//! the shared dispatch core (`sim::dispatch`), which this module feeds
//! through its [`EngineAccess`] adapter. This file owns the engine's
//! run-local *state* (frames, input/output images, accumulator metadata)
//! and the partition lifecycle hooks the engine calls.

use super::dispatch::{self, BufAccess};
use super::scheduler::TileCtx;
use super::tensor::Tensor;
use crate::compiler::{AccKind, Program, PART_FRAME_BASE};
use crate::isa::{BufId, Dim, DimCtx, Instr};
use crate::models::WeightStore;
use crate::tiling::Tiling;

/// Borrow bundle of the plan pieces the executor reads.
pub(crate) struct Env<'a> {
    pub program: &'a Program,
    pub tiling: &'a Tiling,
    pub weights: &'a WeightStore,
    pub feat_in: u32,
    pub feat_out: u32,
    pub kernels: crate::config::KernelPolicy,
}

impl<'a> Env<'a> {
    pub fn of(wl: &super::types::Workload<'a>) -> Env<'a> {
        Env {
            program: wl.program,
            tiling: wl.tiling,
            weights: wl.weights,
            feat_in: wl.feat_in,
            feat_out: wl.feat_out,
            kernels: wl.kernels,
        }
    }
}

/// Reusable per-worker scratch for simulation runs. Create once, pass to
/// `Simulator::run_with` (or `ExecPlan::simulate_with`) for every run;
/// buffers are recycled between runs instead of reallocated.
pub struct ExecScratch {
    pub(crate) func: FuncState,
    /// Pooled inter-layer activation image (ORIGINAL vertex order):
    /// layer *l* of a multi-layer pipeline stashes its output here and
    /// layer *l+1* reads it back as `x`. Capacity persists across
    /// layers, runs, and plans, so warm multi-layer requests allocate
    /// nothing (`alloc_events` counts its growth).
    pub(crate) chain: Vec<f32>,
    /// Per-shard child scratches for sharded plans (DESIGN.md §3.8):
    /// shard *s* of a K-way plan runs its engine on `shard_pool[s]`.
    /// Empty for unsharded runs; grows once to K and then persists, so
    /// warm sharded requests reuse the children like any other pool.
    pub(crate) shard_pool: Vec<ExecScratch>,
}

impl ExecScratch {
    pub fn new() -> ExecScratch {
        ExecScratch { func: FuncState::new(), chain: Vec::new(), shard_pool: Vec::new() }
    }

    /// Grow (never shrink) the shard pool to `k` children and hand the
    /// caller disjoint mutable borrows, one per shard worker thread.
    pub(crate) fn ensure_shards(&mut self, k: usize) -> &mut [ExecScratch] {
        while self.shard_pool.len() < k {
            self.shard_pool.push(ExecScratch::new());
        }
        &mut self.shard_pool[..k]
    }

    /// Un-permute the last functional run's (still-tiled, `emit_output:
    /// false`) output image into `dst`, reusing `dst`'s capacity — the
    /// inter-layer chaining step of a pipeline run.
    pub(crate) fn stash_output(&mut self, tiling: &Tiling, feat_out: u32, dst: &mut Vec<f32>) {
        let grew = unpermute_into(tiling, feat_out, &self.func.out_tiled, dst);
        self.func.allocs += grew as u64;
    }

    /// Pool-growth events since this scratch was created: +1 every time
    /// a frame, slot vector, or backing tensor allocation had to grow.
    /// Monotonic across runs; a warm request on a reused scratch should
    /// add ≈0 (the returned output embedding vector is caller-owned and
    /// deliberately excluded). `perf_hotpath` asserts the warm delta is
    /// zero for all five models. Includes shard-pool children.
    pub fn alloc_events(&self) -> u64 {
        self.func.alloc_events()
            + self.shard_pool.iter().map(|s| s.alloc_events()).sum::<u64>()
    }
}

impl Default for ExecScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// One pooled buffer slot: the tensor stays resident (capacity reuse)
/// even when the value it held is dead.
#[derive(Default)]
struct Slot {
    t: Tensor,
    /// Whether the slot currently holds a live value.
    set: bool,
}

/// One buffer frame: dense `BufId` → pooled tensor slots. Shared with
/// the tile-parallel executor in `sim::parallel`, which owns one frame
/// per in-flight (tile, lane) pair.
#[derive(Default)]
pub(crate) struct Frame {
    slots: Vec<Slot>,
    pub(crate) allocs: u64,
}

impl Frame {
    /// Invalidate every slot, keeping tensors (and capacity) pooled.
    pub(crate) fn clear(&mut self) {
        for s in &mut self.slots {
            s.set = false;
        }
    }

    pub(crate) fn ensure_slots(&mut self, n: usize) {
        if self.slots.len() < n {
            self.allocs += 1;
            self.slots.resize_with(n, Slot::default);
        }
    }

    pub(crate) fn get(&self, i: usize) -> Option<&Tensor> {
        self.slots.get(i).and_then(|s| if s.set { Some(&s.t) } else { None })
    }

    pub(crate) fn get_mut(&mut self, i: usize) -> Option<&mut Tensor> {
        self.slots
            .get_mut(i)
            .and_then(|s| if s.set { Some(&mut s.t) } else { None })
    }

    /// Mutably borrow slot `i`'s pooled tensor for an in-place rewrite,
    /// marking it live.
    pub(crate) fn slot_mut(&mut self, i: usize) -> &mut Tensor {
        self.ensure_slots(i + 1);
        let s = &mut self.slots[i];
        s.set = true;
        &mut s.t
    }

    /// Detach slot `i`'s tensor so an op can compute into it while its
    /// operands stay borrowed from the frames (slot is left unset).
    /// Returns (tensor, was_set); the caller re-attaches via `put`.
    pub(crate) fn take(&mut self, i: usize) -> (Tensor, bool) {
        self.ensure_slots(i + 1);
        let s = &mut self.slots[i];
        let was = s.set;
        s.set = false;
        (std::mem::take(&mut s.t), was)
    }

    pub(crate) fn put(&mut self, i: usize, t: Tensor) {
        self.ensure_slots(i + 1);
        let s = &mut self.slots[i];
        s.t = t;
        s.set = true;
    }
}

pub(crate) fn part_slot(buf: BufId) -> usize {
    (buf.0 - PART_FRAME_BASE) as usize
}

/// Un-permute a tiled (V × feat) image back to ORIGINAL vertex order into
/// `dst`, reusing `dst`'s capacity. THE single un-permute site shared by
/// the engine, the pipeline chain, and the batched executor's lanes.
/// Returns whether `dst`'s backing allocation had to grow.
pub(crate) fn unpermute_into(
    tiling: &Tiling,
    feat_out: u32,
    tiled: &[f32],
    dst: &mut Vec<f32>,
) -> bool {
    let n = tiling.num_vertices as usize;
    let f = feat_out as usize;
    let grew = n * f > dst.capacity();
    dst.clear();
    dst.resize(n * f, 0.0);
    for new in 0..n {
        let old = tiling.inv_perm[new] as usize;
        dst[old * f..(old + 1) * f].copy_from_slice(&tiled[new * f..(new + 1) * f]);
    }
    grew
}

/// Functional state of one run, recycled across runs via `ExecScratch`.
pub(crate) struct FuncState {
    /// Permuted input (V × feat_in), tiled vertex order.
    pub x_tiled: Vec<f32>,
    /// Permuted output (V × feat_out), tiled vertex order.
    pub out_tiled: Vec<f32>,
    part_frame: Frame,
    tile_frames: Vec<Frame>,
    pub next_frame: usize,
    pub has_input: bool,
    /// (partition-frame slot, kind, resolved cols) per program
    /// accumulator — the compiler records the column dim next to each
    /// accumulator, so this is a cheap O(accumulators) resolve at
    /// `begin_run` and `begin_partition` is scan-free.
    acc_meta: Vec<(usize, AccKind, u32)>,
    allocs: u64,
}

impl FuncState {
    fn new() -> FuncState {
        FuncState {
            x_tiled: Vec::new(),
            out_tiled: Vec::new(),
            part_frame: Frame::default(),
            tile_frames: Vec::new(),
            next_frame: 0,
            has_input: false,
            acc_meta: Vec::new(),
            allocs: 0,
        }
    }

    fn alloc_events(&self) -> u64 {
        self.allocs
            + self.part_frame.allocs
            + self.tile_frames.iter().map(|f| f.allocs).sum::<u64>()
    }

    /// Reset per-run state; retains buffer capacity from prior runs and
    /// (functional runs) pre-sizes the pool from the plan.
    pub fn begin_run(&mut self, env: &Env, functional: bool) {
        self.part_frame.clear();
        for f in &mut self.tile_frames {
            f.clear();
        }
        self.next_frame = 0;
        self.has_input = false;
        if functional {
            self.reserve(env);
        }
    }

    /// Pre-size the buffer pool from the plan's dimensions so steady
    /// state does zero Vec growth: one frame per concurrently-live tile
    /// of a partition, `tile_bufs`/`part_bufs` slots per frame. Tensor
    /// capacity inside each slot is learned on first touch and kept
    /// forever, so only the first run on a scratch allocates.
    fn reserve(&mut self, env: &Env) {
        let frames = env
            .tiling
            .partitions
            .iter()
            .map(|p| p.tiles.len())
            .max()
            .unwrap_or(0);
        if frames > self.tile_frames.capacity() {
            self.allocs += 1;
        }
        while self.tile_frames.len() < frames {
            self.tile_frames.push(Frame::default());
        }
        let tile_slots = env.program.tile_bufs as usize;
        for f in &mut self.tile_frames {
            f.ensure_slots(tile_slots);
        }
        self.part_frame.ensure_slots(env.program.part_bufs as usize);
        if env.program.accumulators.len() > self.acc_meta.capacity() {
            self.allocs += 1;
        }
        self.acc_meta.clear();
        for &(buf, kind, cols) in &env.program.accumulators {
            let cols = match cols {
                Dim::FeatIn => env.feat_in,
                Dim::FeatOut => env.feat_out,
                Dim::Const(c) => c,
                _ => env.feat_out,
            };
            self.acc_meta.push((part_slot(buf), kind, cols));
        }
    }

    /// Permute the caller's input embeddings into tiled vertex order.
    pub fn init_input(&mut self, tiling: &Tiling, x: &[f32], feat_in: u32) -> Result<(), String> {
        let n = tiling.num_vertices as usize;
        let f = feat_in as usize;
        if x.len() != n * f {
            return Err(format!(
                "input embedding size {} != |V|*feat_in = {}",
                x.len(),
                n * f
            ));
        }
        if n * f > self.x_tiled.capacity() {
            self.allocs += 1;
        }
        self.x_tiled.resize(n * f, 0.0);
        if f > 0 {
            for (old, row) in x.chunks_exact(f).enumerate() {
                let new = tiling.perm[old] as usize;
                self.x_tiled[new * f..(new + 1) * f].copy_from_slice(row);
            }
        }
        self.has_input = true;
        Ok(())
    }

    /// Size (and zero) the tiled output image for a functional run.
    pub fn prepare_output(&mut self, num_vertices: u32, feat_out: u32) {
        let len = num_vertices as usize * feat_out as usize;
        if len > self.out_tiled.capacity() {
            self.allocs += 1;
        }
        self.out_tiled.clear();
        self.out_tiled.resize(len, 0.0);
    }

    /// FCH.PTT: reset the partition frame and init accumulators in
    /// place (pooled slots, no allocation on the warm path).
    pub fn begin_partition(&mut self, dims: &DimCtx) {
        self.part_frame.clear();
        for &(slot, kind, cols) in &self.acc_meta {
            let init = match kind {
                AccKind::Sum => 0.0,
                AccKind::Max => f32::NEG_INFINITY,
            };
            let grew = self
                .part_frame
                .slot_mut(slot)
                .reset_filled(dims.part_dst, cols, init);
            self.allocs += grew as u64;
        }
    }

    /// dStream wait boundary: neutralize untouched Max accumulators.
    pub fn fixup_max_accs(&mut self) {
        for &(slot, kind, _) in &self.acc_meta {
            if kind == AccKind::Max {
                if let Some(t) = self.part_frame.get_mut(slot) {
                    for v in &mut t.data {
                        if *v == f32::NEG_INFINITY {
                            *v = 0.0;
                        }
                    }
                }
            }
        }
    }

    /// UPD.PTT: commit the partition output rows and recycle tile
    /// frames. Destination rows are contiguous in the tiled image, so
    /// the commit is a single memcpy.
    pub fn commit_partition(
        &mut self,
        env: &Env,
        part: &crate::tiling::Partition,
    ) -> Result<(), String> {
        let out_buf = env.program.output_buf;
        let t = self
            .part_frame
            .get(part_slot(out_buf))
            .ok_or("output buffer not materialized")?;
        if (t.rows, t.cols) != (part.num_dst(), env.feat_out) {
            return Err(format!(
                "output buffer shape {}x{} != partition {}x{}",
                t.rows,
                t.cols,
                part.num_dst(),
                env.feat_out
            ));
        }
        let base = part.dst_start as usize * env.feat_out as usize;
        self.out_tiled[base..base + t.data.len()].copy_from_slice(&t.data);
        for fr in &mut self.tile_frames {
            fr.clear();
        }
        self.next_frame = 0;
        Ok(())
    }

    /// FCH.TILE: claim the next tile-frame id (frames are recycled at
    /// each UPD.PTT, so ids restart per partition).
    pub fn alloc_tile_frame(&mut self, functional: bool) -> usize {
        let frame = self.next_frame;
        self.next_frame += 1;
        if functional {
            while self.tile_frames.len() <= frame {
                self.allocs += 1;
                self.tile_frames.push(Frame::default());
            }
        }
        frame
    }

    /// Un-permute the tiled output back to original vertex order. The
    /// returned vector is caller-owned (excluded from `alloc_events`).
    pub fn take_output(&self, tiling: &Tiling, feat_out: u32) -> Vec<f32> {
        let mut out = Vec::new();
        unpermute_into(tiling, feat_out, &self.out_tiled, &mut out);
        out
    }

    /// Functional semantics of one load or compute instruction, executed
    /// through the shared dispatch core (`sim::dispatch::exec_instr`)
    /// over this state's frames. GTHR is the one exception: it is
    /// deferred to [`FuncState::fold_gathers`] at the dStream wait
    /// boundary so the cross-tile float association matches the batched
    /// path bit-exactly.
    pub fn exec_instr(
        &mut self,
        env: &Env,
        tile: Option<&TileCtx>,
        cur_part: Option<usize>,
        dims: &DimCtx,
        instr: &Instr,
    ) -> Result<(), String> {
        if matches!(instr, Instr::Gthr { .. }) {
            return Ok(());
        }
        let t_meta = tile.map(|tc| &env.tiling.partitions[tc.part_idx].tiles[tc.tile_idx]);
        let part = cur_part.map(|p| &env.tiling.partitions[p]);
        let mut a = EngineAccess {
            part_frame: &mut self.part_frame,
            tile_frames: &mut self.tile_frames,
            frame: tile.map(|tc| tc.frame),
            x_tiled: &self.x_tiled,
            has_input: self.has_input,
            allocs: &mut self.allocs,
        };
        dispatch::exec_instr(
            &mut a,
            env.weights,
            env.feat_in,
            part,
            t_meta,
            dims,
            env.kernels,
            instr,
        )
    }

    /// dStream wait boundary: all tiles of the partition have retired,
    /// so fold their deferred GTHR reductions into the partition
    /// accumulators in **ascending tile order** (frame `i` belongs to
    /// tile `i` — FCH.TILE hands frames out in fetch order and they are
    /// recycled at UPD.PTT). Same fold order as `parallel::run_batch`,
    /// hence bit-identical outputs.
    pub fn fold_gathers(&mut self, env: &Env, part_idx: usize) -> Result<(), String> {
        let part = &env.tiling.partitions[part_idx];
        for (t_idx, t_meta) in part.tiles.iter().enumerate() {
            let frame = self
                .tile_frames
                .get(t_idx)
                .ok_or_else(|| format!("gather fold: tile frame {t_idx} missing"))?;
            dispatch::fold_tile_gathers(&env.program.e_func, frame, t_meta, &mut self.part_frame)?;
        }
        Ok(())
    }
}

/// The engine's [`BufAccess`] adapter: tile buffers resolve through the
/// stream's bound tile frame, partition buffers through the partition
/// frame. A missing tile binding (dStream instructions touching tile
/// buffers) is this adapter's access error.
pub(crate) struct EngineAccess<'s> {
    pub(crate) part_frame: &'s mut Frame,
    pub(crate) tile_frames: &'s mut Vec<Frame>,
    /// Bound tile's frame id (`None` off-tile, e.g. dFunction instrs).
    pub(crate) frame: Option<usize>,
    pub(crate) x_tiled: &'s [f32],
    pub(crate) has_input: bool,
    pub(crate) allocs: &'s mut u64,
}

impl BufAccess for EngineAccess<'_> {
    fn read(&self, buf: BufId) -> Result<&Tensor, String> {
        if buf.is_partition_frame() {
            self.part_frame
                .get(part_slot(buf))
                .ok_or_else(|| format!("partition buffer b{} unset", buf.0))
        } else {
            let frame = self.frame.ok_or("tile buf w/o tile")?;
            self.tile_frames
                .get(frame)
                .and_then(|f| f.get(buf.0 as usize))
                .ok_or_else(|| format!("tile buffer b{} unset (frame {frame})", buf.0))
        }
    }

    fn take_dst(&mut self, buf: BufId) -> Result<(Tensor, bool), String> {
        if buf.is_partition_frame() {
            Ok(self.part_frame.take(part_slot(buf)))
        } else {
            let frame = self.frame.ok_or("tile buf w/o tile")?;
            while self.tile_frames.len() <= frame {
                *self.allocs += 1;
                self.tile_frames.push(Frame::default());
            }
            Ok(self.tile_frames[frame].take(buf.0 as usize))
        }
    }

    fn put_back(&mut self, buf: BufId, t: Tensor, grew: bool) -> Result<(), String> {
        *self.allocs += grew as u64;
        if buf.is_partition_frame() {
            self.part_frame.put(part_slot(buf), t);
        } else {
            let frame = self.frame.ok_or("tile buf w/o tile")?;
            self.tile_frames[frame].put(buf.0 as usize, t);
        }
        Ok(())
    }

    fn input(&self) -> Result<&[f32], String> {
        if !self.has_input {
            return Err("functional run without input x".into());
        }
        Ok(self.x_tiled)
    }
}
