//! The discrete-event engine: streams, scheduler, dispatcher, units.

use super::tensor::{self, Tensor};
use super::timing;
use crate::compiler::{AccKind, Program};
use crate::config::ArchConfig;
use crate::energy::EnergyCounters;
use crate::isa::{
    BufId, Dim, DimCtx, ElwUnary, Instr, LdTarget, Reduce, SctrDir, StreamClass, UnitClass,
};
use crate::metrics::{Phase, Trace, TraceSample};
use crate::models::WeightStore;
use crate::tiling::Tiling;
use std::collections::HashMap;

/// Everything a simulation run needs.
pub struct Workload<'a> {
    pub program: &'a Program,
    pub tiling: &'a Tiling,
    pub weights: &'a WeightStore,
    pub feat_in: u32,
    pub feat_out: u32,
    /// Input embeddings in ORIGINAL vertex order, (V × feat_in) row-major.
    /// Required when `SimOptions::functional` is set.
    pub x: Option<&'a [f32]>,
}

#[derive(Clone, Copy, Debug)]
pub struct SimOptions {
    pub functional: bool,
    /// Trace window in cycles (0 = no trace).
    pub trace_window: u64,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { functional: false, trace_window: 0 }
    }
}

/// Simulation result: timing, utilization, energy events, output.
#[derive(Clone, Debug, Default)]
pub struct SimResult {
    pub cycles: u64,
    pub instructions: u64,
    pub counters: EnergyCounters,
    pub mu_busy: u64,
    pub vu_busy: u64,
    pub mem_busy: u64,
    /// Off-chip reads only (Fig 11's reduction metric).
    pub dram_read_bytes: u64,
    pub dram_write_bytes: u64,
    pub trace: Vec<TraceSample>,
    /// Output embeddings in ORIGINAL vertex order (functional runs).
    pub output: Option<Vec<f32>>,
    /// Peak resident UEM bytes observed (Fig 2-style footprint).
    pub peak_uem_bytes: u64,
}

impl SimResult {
    pub fn seconds(&self, arch: &ArchConfig) -> f64 {
        self.cycles as f64 / arch.freq_hz
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum StreamState {
    Ready,
    /// Blocked in WAIT until enough signals arrive.
    Waiting,
    Halted,
}

struct Stream {
    class: StreamClass,
    func: &'static str,
    pc: usize,
    state: StreamState,
    /// Simulation time at which the stream can issue its next instruction.
    ready_at: u64,
    signals: u32,
    /// Tile contexts handed over by SIGNAL.E (eStreams).
    mailbox: Vec<TileCtx>,
    /// Currently bound tile (s/e streams).
    tile: Option<TileCtx>,
}

#[derive(Clone, Debug)]
struct TileCtx {
    part_idx: usize,
    tile_idx: usize,
    dims: DimCtx,
    /// Functional tile frame id.
    frame: usize,
}

pub struct Simulator<'a> {
    arch: &'a ArchConfig,
    wl: &'a Workload<'a>,
    opts: SimOptions,
}

impl<'a> Simulator<'a> {
    pub fn new(arch: &'a ArchConfig, wl: &'a Workload<'a>, opts: SimOptions) -> Self {
        Simulator { arch, wl, opts }
    }

    pub fn run(&self) -> Result<SimResult, String> {
        Engine::new(self.arch, self.wl, self.opts).run()
    }
}

struct Engine<'a> {
    arch: &'a ArchConfig,
    wl: &'a Workload<'a>,
    opts: SimOptions,
    streams: Vec<Stream>,
    /// busy-until per unit instance.
    mu_free: Vec<u64>,
    vu_free: Vec<u64>,
    /// Banked HBM controller (Ramulator stand-in): row-buffer state,
    /// channel occupancy. Sparse tile loads issue one run per
    /// consecutive-vertex span, so scattered sources pay activations.
    hbm: super::hbm::Hbm,
    // partition progress
    part_cursor: usize,
    cur_part: Option<usize>,
    tile_cursor: usize,
    tiles_done: usize,
    // functional state
    x_tiled: Option<Vec<f32>>, // permuted input (V × feat_in)
    out_tiled: Vec<f32>,       // permuted output (V × feat_out)
    part_frame: HashMap<u16, Tensor>,
    tile_frames: Vec<HashMap<u16, Tensor>>,
    next_frame: usize,
    // metrics
    res: SimResult,
    trace: Option<Trace>,
}

impl<'a> Engine<'a> {
    fn new(arch: &'a ArchConfig, wl: &'a Workload<'a>, opts: SimOptions) -> Self {
        let mut streams = Vec::new();
        streams.push(Stream {
            class: StreamClass::D,
            func: "d",
            pc: 0,
            state: StreamState::Ready,
            ready_at: 0,
            signals: 0,
            mailbox: Vec::new(),
            tile: None,
        });
        for _ in 0..arch.s_streams {
            streams.push(Stream {
                class: StreamClass::S,
                func: "s",
                pc: 0,
                state: StreamState::Ready,
                ready_at: 0,
                signals: 0,
                mailbox: Vec::new(),
                tile: None,
            });
        }
        for _ in 0..arch.e_streams {
            streams.push(Stream {
                class: StreamClass::E,
                func: "e",
                pc: 0,
                state: StreamState::Ready,
                ready_at: 0,
                signals: 0,
                mailbox: Vec::new(),
                tile: None,
            });
        }
        let n = wl.tiling.num_vertices as usize;
        let x_tiled = wl.x.map(|x| {
            assert_eq!(x.len(), n * wl.feat_in as usize, "input embedding size");
            let mut t = vec![0.0f32; x.len()];
            let f = wl.feat_in as usize;
            for old in 0..n {
                let new = wl.tiling.perm[old] as usize;
                t[new * f..(new + 1) * f].copy_from_slice(&x[old * f..(old + 1) * f]);
            }
            t
        });
        let trace = (opts.trace_window > 0).then(|| {
            Trace::new(
                opts.trace_window,
                (arch.mu_count as f64 * arch.mu_macs_per_cycle() as f64 * 2.0)
                    + arch.vu_count as f64 * arch.vu_width() as f64,
                arch.hbm_bytes_per_cycle(),
            )
        });
        Engine {
            arch,
            wl,
            opts,
            streams,
            mu_free: vec![0; arch.mu_count as usize],
            vu_free: vec![0; arch.vu_count as usize],
            hbm: super::hbm::Hbm::new(super::hbm::HbmConfig {
                channels: ((arch.hbm_bytes_per_cycle() / 32.0).round() as u32).max(1),
                ctrl_latency: arch.hbm_latency_cycles / 2,
                ..Default::default()
            }),
            part_cursor: 0,
            cur_part: None,
            tile_cursor: 0,
            tiles_done: 0,
            x_tiled,
            // output image only exists in functional mode (perf: timing
            // runs on large graphs shouldn't pay an O(V·F) allocation)
            out_tiled: if opts.functional {
                vec![0.0; n * wl.feat_out as usize]
            } else {
                Vec::new()
            },
            part_frame: HashMap::new(),
            tile_frames: Vec::new(),
            next_frame: 0,
            res: SimResult::default(),
            trace,
        }
    }

    fn func_of(&self, class: StreamClass) -> &'a [Instr] {
        match class {
            StreamClass::D => &self.wl.program.d_func,
            StreamClass::S => &self.wl.program.s_func,
            StreamClass::E => &self.wl.program.e_func,
        }
    }

    fn dims_for_partition(&self, part_idx: usize) -> DimCtx {
        let p = &self.wl.tiling.partitions[part_idx];
        DimCtx {
            tile_src: 0,
            tile_edges: 0,
            part_dst: p.num_dst(),
            feat_in: self.wl.feat_in,
            feat_out: self.wl.feat_out,
        }
    }

    fn run(mut self) -> Result<SimResult, String> {
        let max_steps: u64 = 2_000_000_000;
        let mut steps: u64 = 0;
        loop {
            steps += 1;
            if steps > max_steps {
                return Err("simulation exceeded step budget".into());
            }
            // pick the runnable stream with the earliest ready time
            let mut best: Option<(usize, u64)> = None;
            for (i, s) in self.streams.iter().enumerate() {
                if s.state != StreamState::Ready {
                    continue;
                }
                if best.map_or(true, |(_, t)| s.ready_at < t) {
                    best = Some((i, s.ready_at));
                }
            }
            let Some((sid, _)) = best else {
                // no runnable stream: if the dStream halted we're done;
                // otherwise it's a deadlock (protocol bug)
                if self.streams[0].state == StreamState::Halted {
                    break;
                }
                return Err(format!(
                    "deadlock: stream states {:?}",
                    self.streams.iter().map(|s| (s.func, s.pc, s.state)).collect::<Vec<_>>()
                ));
            };
            self.step(sid)?;
            if self.streams[0].state == StreamState::Halted {
                break;
            }
        }
        // finish metrics
        self.res.cycles = self
            .streams
            .iter()
            .map(|s| s.ready_at)
            .chain(self.mu_free.iter().copied())
            .chain(self.vu_free.iter().copied())
            .max()
            .unwrap_or(0);
        self.res.counters.cycles = self.res.cycles;
        if let Some(t) = self.trace.take() {
            self.res.trace = t.finish();
        }
        if self.opts.functional {
            // un-permute output to original vertex order
            let n = self.wl.tiling.num_vertices as usize;
            let f = self.wl.feat_out as usize;
            let mut out = vec![0.0f32; n * f];
            for new in 0..n {
                let old = self.wl.tiling.inv_perm[new] as usize;
                out[old * f..(old + 1) * f]
                    .copy_from_slice(&self.out_tiled[new * f..(new + 1) * f]);
            }
            self.res.output = Some(out);
        }
        Ok(self.res)
    }

    /// Execute one instruction of stream `sid`.
    fn step(&mut self, sid: usize) -> Result<(), String> {
        let class = self.streams[sid].class;
        let func = self.func_of(class);
        let pc = self.streams[sid].pc;
        let instr = func
            .get(pc)
            .ok_or_else(|| format!("stream {sid} pc {pc} out of bounds"))?
            .clone();
        let t0 = self.streams[sid].ready_at;
        self.res.instructions += 1;

        let dims = self.stream_dims(sid);

        match instr.unit() {
            UnitClass::Sync => self.exec_sync(sid, &instr, t0)?,
            UnitClass::Mem => {
                let bytes = instr.dram_bytes(&dims);
                let start = t0;
                let end = self.issue_hbm(sid, &instr, start, bytes)?;
                self.res.mem_busy +=
                    (bytes as f64 / self.hbm.peak_bytes_per_cycle()).ceil() as u64;
                match instr {
                    Instr::Ld { target, .. } => {
                        self.res.dram_read_bytes += bytes;
                        if target == LdTarget::Edge {
                            self.res.counters.th_bytes += bytes;
                        } else {
                            self.res.counters.uem_bytes += timing::uem_bytes(&instr, &dims);
                        }
                        if self.opts.functional {
                            self.exec_load(sid, &instr)?;
                        }
                    }
                    Instr::St { .. } => {
                        self.res.dram_write_bytes += bytes;
                        self.res.counters.uem_bytes += timing::uem_bytes(&instr, &dims);
                        // functional store happens at UPD.PTT commit
                    }
                    _ => unreachable!(),
                }
                self.res.counters.hbm_bytes += bytes;
                self.record_trace(start, end, 0, bytes, Phase::Mem);
                self.advance(sid, end, 1);
            }
            UnitClass::Mu | UnitClass::Vu => {
                let dur = timing::compute_cycles(self.arch, &instr, &dims);
                let (start, end) = if instr.unit() == UnitClass::Mu {
                    let (idx, free) = min_slot(&self.mu_free);
                    let start = t0.max(free);
                    self.mu_free[idx] = start + dur;
                    self.res.mu_busy += dur;
                    (start, start + dur)
                } else {
                    let (idx, free) = min_slot(&self.vu_free);
                    let start = t0.max(free);
                    self.vu_free[idx] = start + dur;
                    self.res.vu_busy += dur;
                    (start, start + dur)
                };
                self.res.counters.macs += timing::macs(&instr, &dims);
                self.res.counters.vu_ops += timing::vu_ops(&instr, &dims);
                self.res.counters.uem_bytes += timing::uem_bytes(&instr, &dims);
                if matches!(instr, Instr::Sctr { .. } | Instr::Gthr { .. }) {
                    // edge-list reads from the tile hub
                    self.res.counters.th_bytes += dims.tile_edges as u64 * 8;
                }
                let phase = match &instr {
                    Instr::Gemm { .. } | Instr::Bmm { .. } => Phase::Gemm,
                    Instr::Sctr { .. } | Instr::Gthr { .. } => Phase::Gop,
                    _ => Phase::Elw,
                };
                self.record_trace(start, end, instr.flops(&dims), 0, phase);
                if self.opts.functional {
                    self.exec_compute(sid, &instr)?;
                }
                self.advance(sid, end, 1);
            }
        }
        Ok(())
    }

    fn stream_dims(&self, sid: usize) -> DimCtx {
        if let Some(t) = &self.streams[sid].tile {
            t.dims
        } else if let Some(p) = self.cur_part {
            self.dims_for_partition(p)
        } else {
            DimCtx { feat_in: self.wl.feat_in, feat_out: self.wl.feat_out, ..Default::default() }
        }
    }

    fn advance(&mut self, sid: usize, end: u64, pc_delta: i64) {
        let s = &mut self.streams[sid];
        s.ready_at = end;
        s.pc = (s.pc as i64 + pc_delta) as usize;
    }

    fn exec_sync(&mut self, sid: usize, instr: &Instr, t0: u64) -> Result<(), String> {
        match instr {
            Instr::FchPtt => {
                debug_assert_eq!(self.streams[sid].class, StreamClass::D);
                if self.part_cursor >= self.wl.tiling.partitions.len() {
                    self.streams[sid].state = StreamState::Halted;
                    return Ok(());
                }
                let p = self.part_cursor;
                self.part_cursor += 1;
                self.cur_part = Some(p);
                self.tile_cursor = 0;
                self.tiles_done = 0;
                // functional: reset partition frame; init accumulators
                if self.opts.functional {
                    self.part_frame.clear();
                    let dims = self.dims_for_partition(p);
                    for &(buf, kind) in &self.wl.program.accumulators {
                        let cols = self.acc_cols(buf);
                        let init = match kind {
                            AccKind::Sum => 0.0,
                            AccKind::Max => f32::NEG_INFINITY,
                        };
                        self.part_frame
                            .insert(buf.0, Tensor::filled(dims.part_dst, cols, init));
                    }
                }
                // empty partition: pre-credit the completion signal so the
                // dStream's WAIT doesn't deadlock
                if self.wl.tiling.partitions[p].tiles.is_empty() {
                    self.streams[sid].signals += 1;
                }
                self.advance(sid, t0 + 1, 1);
            }
            Instr::UpdPtt => {
                // commit the partition output (functional)
                if self.opts.functional {
                    let p = self.cur_part.ok_or("UPD.PTT without partition")?;
                    let part = &self.wl.tiling.partitions[p];
                    let out_buf = self.wl.program.output_buf;
                    let t = self
                        .part_frame
                        .get(&out_buf.0)
                        .ok_or("output buffer not materialized")?;
                    let f = self.wl.feat_out as usize;
                    for (i, d) in (part.dst_start..part.dst_end).enumerate() {
                        self.out_tiled[d as usize * f..(d as usize + 1) * f]
                            .copy_from_slice(t.row(i as u32));
                    }
                    // release tile frames of the finished partition
                    self.tile_frames.clear();
                    self.next_frame = 0;
                }
                self.advance(sid, t0 + 1, 1);
            }
            Instr::Signal { class } => {
                match class {
                    StreamClass::S => {
                        // broadcast: wake every sStream for this partition
                        let end = t0 + 1;
                        for i in 0..self.streams.len() {
                            if self.streams[i].class == StreamClass::S {
                                self.streams[i].signals += 1;
                                if self.streams[i].state == StreamState::Waiting {
                                    self.streams[i].state = StreamState::Ready;
                                    self.streams[i].ready_at =
                                        self.streams[i].ready_at.max(end);
                                }
                            }
                        }
                        self.advance(sid, end, 1);
                    }
                    StreamClass::E => {
                        // rendezvous: hand the bound tile to the least-loaded eStream
                        let tile = self.streams[sid]
                            .tile
                            .clone()
                            .ok_or("SIGNAL.E without a bound tile")?;
                        let end = t0 + 1;
                        let eid = self
                            .streams
                            .iter()
                            .enumerate()
                            .filter(|(_, s)| s.class == StreamClass::E)
                            .min_by_key(|(_, s)| s.mailbox.len())
                            .map(|(i, _)| i)
                            .ok_or("no eStreams configured")?;
                        self.streams[eid].mailbox.insert(0, tile);
                        self.streams[eid].signals += 1;
                        if self.streams[eid].state == StreamState::Waiting {
                            self.streams[eid].state = StreamState::Ready;
                            self.streams[eid].ready_at = self.streams[eid].ready_at.max(end);
                        }
                        self.advance(sid, end, 1);
                    }
                    StreamClass::D => {
                        let end = t0 + 1;
                        self.streams[0].signals += 1;
                        if self.streams[0].state == StreamState::Waiting {
                            self.streams[0].state = StreamState::Ready;
                            self.streams[0].ready_at = self.streams[0].ready_at.max(end);
                        }
                        self.advance(sid, end, 1);
                    }
                }
            }
            Instr::Wait { count } => {
                let need = count.resolve(&self.stream_dims(sid)).max(1);
                if self.streams[sid].signals >= need {
                    self.streams[sid].signals -= need;
                    // eStream: bind the tile handed over by SIGNAL.E (FIFO)
                    if self.streams[sid].class == StreamClass::E {
                        if let Some(t) = self.streams[sid].mailbox.pop() {
                            self.streams[sid].tile = Some(t);
                        }
                    }
                    // dStream resuming after all tiles: fix up max accs
                    if self.streams[sid].class == StreamClass::D && self.opts.functional {
                        for &(buf, kind) in &self.wl.program.accumulators {
                            if kind == AccKind::Max {
                                if let Some(t) = self.part_frame.get_mut(&buf.0) {
                                    for v in &mut t.data {
                                        if *v == f32::NEG_INFINITY {
                                            *v = 0.0;
                                        }
                                    }
                                }
                            }
                        }
                    }
                    self.advance(sid, t0 + 1, 1);
                } else {
                    self.streams[sid].state = StreamState::Waiting;
                    // pc unchanged: re-execute WAIT when woken
                }
            }
            Instr::FchTile { on_empty } => {
                let p = self.cur_part.ok_or("FCH.TILE without partition")?;
                let part = &self.wl.tiling.partitions[p];
                if self.tile_cursor >= part.tiles.len() {
                    // no tiles left in this partition
                    self.advance(sid, t0 + 1, *on_empty as i64);
                    return Ok(());
                }
                let ti = self.tile_cursor;
                self.tile_cursor += 1;
                let tile = &part.tiles[ti];
                let dims = DimCtx {
                    tile_src: tile.num_src(),
                    tile_edges: tile.num_edges(),
                    part_dst: part.num_dst(),
                    feat_in: self.wl.feat_in,
                    feat_out: self.wl.feat_out,
                };
                let frame = self.next_frame;
                self.next_frame += 1;
                if self.opts.functional {
                    while self.tile_frames.len() <= frame {
                        self.tile_frames.push(HashMap::new());
                    }
                }
                self.streams[sid].tile = Some(TileCtx { part_idx: p, tile_idx: ti, dims, frame });
                // UEM residency estimate: src tile + edge intermediates
                let resident = (tile.num_src() as u64 * self.wl.feat_in as u64
                    + tile.num_edges() as u64 * self.wl.feat_out as u64)
                    * 4;
                self.res.peak_uem_bytes = self.res.peak_uem_bytes.max(resident);
                self.advance(sid, t0 + 1, 1);
            }
            Instr::ChkPtt => {
                self.tiles_done += 1;
                let p = self.cur_part.ok_or("CHK.PTT without partition")?;
                let total = self.wl.tiling.partitions[p].tiles.len();
                let end = t0 + 1;
                if self.tiles_done >= total {
                    self.streams[0].signals += 1;
                    if self.streams[0].state == StreamState::Waiting {
                        self.streams[0].state = StreamState::Ready;
                        self.streams[0].ready_at = self.streams[0].ready_at.max(end);
                    }
                }
                self.streams[sid].tile = None;
                self.advance(sid, end, 1);
            }
            Instr::Jump(off) => {
                self.advance(sid, t0, *off as i64);
            }
            Instr::Halt => {
                self.streams[sid].state = StreamState::Halted;
            }
            other => return Err(format!("non-sync instruction in exec_sync: {other}")),
        }
        Ok(())
    }

    fn acc_cols(&self, buf: BufId) -> u32 {
        // find the Gthr writing this accumulator to learn its width
        for i in &self.wl.program.e_func {
            if let Instr::Gthr { dst, cols, .. } = i {
                if *dst == buf {
                    return match cols {
                        Dim::FeatIn => self.wl.feat_in,
                        Dim::FeatOut => self.wl.feat_out,
                        Dim::Const(c) => *c,
                        _ => self.wl.feat_out,
                    };
                }
            }
        }
        self.wl.feat_out
    }

    /// Route a data-transfer instruction through the banked HBM model.
    /// LD.SRC decomposes into one run per span of consecutive source
    /// vertices — regular tiles stream one contiguous block (row hits),
    /// sparse tiles pay scattered activations (the §5.3 trade-off the
    /// paper argues is worth it at embedding granularity).
    fn issue_hbm(
        &mut self,
        sid: usize,
        instr: &Instr,
        start: u64,
        bytes: u64,
    ) -> Result<u64, String> {
        const OUT_BASE: u64 = 1 << 41;
        const EDGE_BASE: u64 = 1 << 42;
        let fi = self.wl.feat_in as u64 * 4;
        let fo = self.wl.feat_out as u64 * 4;
        match instr {
            Instr::Ld { target: LdTarget::Src, .. } => {
                let tc = self.streams[sid].tile.clone().ok_or("LD.SRC w/o tile")?;
                let part = &self.wl.tiling.partitions[tc.part_idx];
                let tile = &part.tiles[tc.tile_idx];
                let mut end = start;
                let vs = &tile.src_vertices;
                let mut i = 0;
                while i < vs.len() {
                    // coalesce consecutive vertex ids into one run
                    let run_start = i;
                    while i + 1 < vs.len() && vs[i + 1] == vs[i] + 1 {
                        i += 1;
                    }
                    i += 1;
                    let addr = vs[run_start] as u64 * fi;
                    let run_bytes = (i - run_start) as u64 * fi;
                    end = end.max(self.hbm.access(start, addr, run_bytes));
                }
                Ok(end)
            }
            Instr::Ld { target: LdTarget::Dst, .. } => {
                let p = self.cur_part.ok_or("LD.DST w/o partition")?;
                let part = &self.wl.tiling.partitions[p];
                let addr = part.dst_start as u64 * fi;
                Ok(self.hbm.access(start, addr, bytes))
            }
            Instr::Ld { target: LdTarget::Edge, .. } => {
                // edge lists stream from their own region (tile hub fill)
                let tc = self.streams[sid].tile.as_ref().ok_or("LD.EDGE w/o tile")?;
                let addr = EDGE_BASE
                    + ((tc.part_idx as u64) << 28)
                    + ((tc.tile_idx as u64) << 14);
                Ok(self.hbm.access(start, addr, bytes))
            }
            Instr::St { .. } => {
                let p = self.cur_part.ok_or("ST w/o partition")?;
                let part = &self.wl.tiling.partitions[p];
                let addr = OUT_BASE + part.dst_start as u64 * fo;
                Ok(self.hbm.access(start, addr, bytes))
            }
            other => Err(format!("issue_hbm on non-mem instr {other}")),
        }
    }

    // ---- functional execution --------------------------------------------

    fn exec_load(&mut self, sid: usize, instr: &Instr) -> Result<(), String> {
        let Instr::Ld { target, dst, .. } = instr else { unreachable!() };
        match target {
            LdTarget::Edge => Ok(()), // edge list already in Tile struct
            LdTarget::Src => {
                let tile_ctx = self.streams[sid].tile.clone().ok_or("LD.SRC w/o tile")?;
                let x = self.x_tiled.as_ref().ok_or("functional run without input x")?;
                let part = &self.wl.tiling.partitions[tile_ctx.part_idx];
                let tile = &part.tiles[tile_ctx.tile_idx];
                let f = self.wl.feat_in as usize;
                let mut t = Tensor::zeros(tile.num_src(), self.wl.feat_in);
                for (i, &v) in tile.src_vertices.iter().enumerate() {
                    t.row_mut(i as u32)
                        .copy_from_slice(&x[v as usize * f..(v as usize + 1) * f]);
                }
                self.tile_frames[tile_ctx.frame].insert(dst.0, t);
                Ok(())
            }
            LdTarget::Dst => {
                let p = self.cur_part.ok_or("LD.DST w/o partition")?;
                let x = self.x_tiled.as_ref().ok_or("functional run without input x")?;
                let part = &self.wl.tiling.partitions[p];
                let f = self.wl.feat_in as usize;
                let mut t = Tensor::zeros(part.num_dst(), self.wl.feat_in);
                for (i, v) in (part.dst_start..part.dst_end).enumerate() {
                    t.row_mut(i as u32)
                        .copy_from_slice(&x[v as usize * f..(v as usize + 1) * f]);
                }
                self.part_frame.insert(dst.0, t);
                Ok(())
            }
        }
    }

    fn get_buf(&self, sid: usize, buf: BufId) -> Result<&Tensor, String> {
        if buf.is_partition_frame() {
            self.part_frame
                .get(&buf.0)
                .ok_or_else(|| format!("partition buffer b{} unset", buf.0))
        } else {
            let frame = self.streams[sid].tile.as_ref().ok_or("tile buf w/o tile")?.frame;
            self.tile_frames[frame]
                .get(&buf.0)
                .ok_or_else(|| format!("tile buffer b{} unset (frame {frame})", buf.0))
        }
    }

    fn put_buf(&mut self, sid: usize, buf: BufId, t: Tensor) -> Result<(), String> {
        if buf.is_partition_frame() {
            self.part_frame.insert(buf.0, t);
        } else {
            let frame = self.streams[sid].tile.as_ref().ok_or("tile buf w/o tile")?.frame;
            self.tile_frames[frame].insert(buf.0, t);
        }
        Ok(())
    }

    fn weight_slice(&self, id: crate::isa::WeightId) -> &[f32] {
        &self.wl.weights.tensors[id.0 as usize].data
    }

    fn exec_compute(&mut self, sid: usize, instr: &Instr) -> Result<(), String> {
        let dims = self.stream_dims(sid);
        let rd = |d: Dim| d.resolve(&dims);
        match instr {
            Instr::ElwU { op, src, dst, .. } => {
                let t = tensor::apply_unary(*op, self.get_buf(sid, *src)?);
                self.put_buf(sid, *dst, t)
            }
            Instr::ElwB { op, a, b, dst, .. } => {
                let t = tensor::apply_binary(*op, self.get_buf(sid, *a)?, self.get_buf(sid, *b)?);
                self.put_buf(sid, *dst, t)
            }
            Instr::ElwBcast { op, a, vec, dst, .. } => {
                let t = tensor::apply_bcast(*op, self.get_buf(sid, *a)?, self.get_buf(sid, *vec)?);
                self.put_buf(sid, *dst, t)
            }
            Instr::Gemv { src, weight, dst, .. } => {
                let x = self.get_buf(sid, *src)?;
                let mut out = Tensor::zeros(x.rows, 1);
                tensor::gemv(x, self.weight_slice(*weight), &mut out);
                self.put_buf(sid, *dst, out)
            }
            Instr::Gemm { src, weight, dst, k, n, accumulate, .. } => {
                let x = self.get_buf(sid, *src)?;
                let mut out = Tensor::zeros(x.rows, rd(*n));
                tensor::matmul(x, self.weight_slice(*weight), rd(*k), rd(*n), &mut out, false);
                if *accumulate {
                    let prev = self.get_buf(sid, *dst)?;
                    let sum = tensor::apply_binary(crate::isa::ElwBinary::Add, prev, &out);
                    self.put_buf(sid, *dst, sum)
                } else {
                    self.put_buf(sid, *dst, out)
                }
            }
            Instr::Bmm { src, weights, dst, k, n, .. } => {
                let tc = self.streams[sid].tile.clone().ok_or("BMM w/o tile")?;
                let part = &self.wl.tiling.partitions[tc.part_idx];
                let tile = &part.tiles[tc.tile_idx];
                let etypes = tile
                    .etypes
                    .clone()
                    .unwrap_or_else(|| vec![0; tile.edges.len()]);
                let x = self.get_buf(sid, *src)?;
                let mut out = Tensor::zeros(x.rows, rd(*n));
                tensor::bmm_by_type(x, self.weight_slice(*weights), rd(*k), rd(*n), &etypes, &mut out);
                self.put_buf(sid, *dst, out)
            }
            Instr::Sctr { dir, src, dst, cols } => {
                let tc = self.streams[sid].tile.clone().ok_or("SCTR w/o tile")?;
                let part = &self.wl.tiling.partitions[tc.part_idx];
                let tile = &part.tiles[tc.tile_idx];
                let v = self.get_buf(sid, *src)?;
                let mut out = Tensor::zeros(tile.num_edges(), rd(*cols));
                for (e, &(ls, ld)) in tile.edges.iter().enumerate() {
                    let row = match dir {
                        SctrDir::OutEdge => v.row(ls),
                        SctrDir::InEdge => v.row(ld),
                    };
                    out.row_mut(e as u32).copy_from_slice(row);
                }
                self.put_buf(sid, *dst, out)
            }
            Instr::Gthr { reduce, src, dst, .. } => {
                let tc = self.streams[sid].tile.clone().ok_or("GTHR w/o tile")?;
                let part = &self.wl.tiling.partitions[tc.part_idx];
                let tile = &part.tiles[tc.tile_idx];
                // disjoint-field borrows: edge data lives in the tile
                // frame, the accumulator in the partition frame — no
                // clone needed (perf: this was the functional-mode
                // hot-spot; see EXPERIMENTS.md §Perf)
                let e = self.tile_frames[tc.frame]
                    .get(&src.0)
                    .ok_or_else(|| format!("tile buffer b{} unset", src.0))?;
                let acc = self
                    .part_frame
                    .get_mut(&dst.0)
                    .ok_or_else(|| format!("accumulator b{} unset", dst.0))?;
                for (ei, &(_, ld)) in tile.edges.iter().enumerate() {
                    let src_row = e.row(ei as u32);
                    let dst_row = acc.row_mut(ld);
                    match reduce {
                        Reduce::Sum => {
                            for (d, &s) in dst_row.iter_mut().zip(src_row) {
                                *d += s;
                            }
                        }
                        Reduce::Max => {
                            for (d, &s) in dst_row.iter_mut().zip(src_row) {
                                *d = d.max(s);
                            }
                        }
                    }
                }
                Ok(())
            }
            other => Err(format!("unexpected compute instr: {other}")),
        }
    }

    fn record_trace(&mut self, start: u64, end: u64, flops: u64, bytes: u64, phase: Phase) {
        if let Some(t) = &mut self.trace {
            t.record(start, end, flops, bytes, phase);
        }
    }
}

fn min_slot(slots: &[u64]) -> (usize, u64) {
    slots
        .iter()
        .copied()
        .enumerate()
        .min_by_key(|&(_, t)| t)
        .expect("at least one unit instance")
}

// Silence unused warnings for ElwUnary import used only via tensor fns.
#[allow(unused)]
fn _k(_: ElwUnary) {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, OptLevel};
    use crate::graph::generators;
    use crate::models::{ModelKind, WeightStore};
    use crate::tiling::{tile, Reorder, TilingConfig, TilingMode};
    use crate::util::Rng;

    fn run_model(
        m: ModelKind,
        opt: OptLevel,
        functional: bool,
    ) -> (SimResult, crate::compiler::Program) {
        let arch = ArchConfig::default();
        let g = generators::power_law(300, 1500, 1.0, 1.0,
            if m.uses_etypes() { 3 } else { 0 }, 7);
        let tl = tile(&g, TilingConfig {
            dst_part: 64, src_part: 64,
            mode: TilingMode::Sparse, reorder: Reorder::InDegree,
        });
        let prog = compile(&m.build(), opt).unwrap();
        let (fi, fo) = if m.requires_square() { (16, 16) } else { (16, 8) };
        let ws = WeightStore::synthesize(&m.build(), fi, fo, 5);
        let mut rng = Rng::new(11);
        let x: Vec<f32> = (0..300 * fi as usize).map(|_| rng.next_f32_sym() * 0.5).collect();
        let wl = Workload {
            program: &prog,
            tiling: &tl,
            weights: &ws,
            feat_in: fi,
            feat_out: fo,
            x: functional.then_some(x.as_slice()),
        };
        let res = Simulator::new(&arch, &wl, SimOptions { functional, trace_window: 0 })
            .run()
            .unwrap_or_else(|e| panic!("{}: {e}", m.name()));
        (res, prog)
    }

    #[test]
    fn all_models_simulate_to_completion() {
        for m in ModelKind::ALL {
            let (res, _) = run_model(m, OptLevel::E2v, false);
            assert!(res.cycles > 0, "{}", m.name());
            assert!(res.instructions > 0);
            assert!(res.dram_read_bytes > 0);
        }
    }

    #[test]
    fn functional_gcn_matches_direct_computation() {
        let (res, _) = run_model(ModelKind::Gcn, OptLevel::E2v, true);
        let out = res.output.unwrap();
        // recompute directly: out = A^T·(x W) summed over in-edges
        let g = generators::power_law(300, 1500, 1.0, 1.0, 0, 7);
        let ws = WeightStore::synthesize(&crate::models::gcn(), 16, 8, 5);
        let w = &ws.tensors[0];
        let mut rng = Rng::new(11);
        let x: Vec<f32> = (0..300 * 16).map(|_| rng.next_f32_sym() * 0.5).collect();
        // h = x @ w  (E2V order); out[d] = Σ_{s∈in(d)} h[s]
        let mut h = vec![0.0f32; 300 * 8];
        for v in 0..300usize {
            for kk in 0..16usize {
                let xv = x[v * 16 + kk];
                for n in 0..8usize {
                    h[v * 8 + n] += xv * w.data[kk * 8 + n];
                }
            }
        }
        let mut expect = vec![0.0f32; 300 * 8];
        for d in 0..300u32 {
            for &s in g.in_neighbors(d) {
                for n in 0..8usize {
                    expect[d as usize * 8 + n] += h[s as usize * 8 + n];
                }
            }
        }
        for (a, b) in out.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn naive_and_e2v_agree_functionally() {
        for m in [ModelKind::Gat, ModelKind::Sage] {
            let (a, _) = run_model(m, OptLevel::None, true);
            let (b, _) = run_model(m, OptLevel::E2v, true);
            let (oa, ob) = (a.output.unwrap(), b.output.unwrap());
            let mut max_err = 0.0f32;
            for (x, y) in oa.iter().zip(&ob) {
                max_err = max_err.max((x - y).abs());
            }
            assert!(max_err < 1e-3, "{}: max err {max_err}", m.name());
        }
    }

    #[test]
    fn e2v_is_faster_for_gat() {
        let (naive, _) = run_model(ModelKind::Gat, OptLevel::None, false);
        let (opt, _) = run_model(ModelKind::Gat, OptLevel::E2v, false);
        assert!(
            opt.cycles < naive.cycles,
            "E2V {} !< naive {}",
            opt.cycles,
            naive.cycles
        );
    }

    #[test]
    fn more_streams_dont_break_correctness() {
        let mut arch = ArchConfig::default();
        arch.s_streams = 8;
        arch.e_streams = 8;
        let g = generators::power_law(200, 1000, 1.0, 1.0, 0, 3);
        let tl = tile(&g, TilingConfig {
            dst_part: 32, src_part: 32,
            mode: TilingMode::Sparse, reorder: Reorder::None,
        });
        let prog = compile(&crate::models::gcn(), OptLevel::E2v).unwrap();
        let ws = WeightStore::synthesize(&crate::models::gcn(), 8, 8, 1);
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..200 * 8).map(|_| rng.next_f32_sym()).collect();
        let wl = Workload {
            program: &prog, tiling: &tl, weights: &ws,
            feat_in: 8, feat_out: 8, x: Some(&x),
        };
        let res = Simulator::new(&arch, &wl, SimOptions { functional: true, trace_window: 0 })
            .run()
            .unwrap();
        assert!(res.output.unwrap().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn trace_produces_samples() {
        let arch = ArchConfig::default();
        let g = generators::power_law(300, 3000, 1.1, 1.1, 0, 9);
        let tl = tile(&g, TilingConfig::default());
        let prog = compile(&crate::models::gat(), OptLevel::E2v).unwrap();
        let ws = WeightStore::synthesize(&crate::models::gat(), 32, 32, 1);
        let wl = Workload {
            program: &prog, tiling: &tl, weights: &ws,
            feat_in: 32, feat_out: 32, x: None,
        };
        let res = Simulator::new(&arch, &wl, SimOptions { functional: false, trace_window: 256 })
            .run()
            .unwrap();
        assert!(!res.trace.is_empty());
        // GAT must show multiple phases
        let phases: std::collections::HashSet<&str> =
            res.trace.iter().map(|s| s.phase.tag()).collect();
        assert!(phases.len() >= 2, "phases: {phases:?}");
    }
}
