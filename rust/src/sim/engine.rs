//! The discrete-event engine: facade (`Simulator`) + event loop.
//!
//! The engine is deliberately thin: stream scoreboarding lives in
//! `sim::scheduler`, unit timing in `sim::units`, and functional
//! execution in `sim::exec`. What remains here is the ISA's control
//! semantics (the §5.2 stream protocol) and metric accounting.

use super::exec::{Env, ExecScratch};
use super::scheduler::{Scheduler, StreamState, TileCtx};
use super::timing;
use super::types::{SimOptions, SimResult, Workload};
use super::units::Units;
use crate::config::ArchConfig;
use crate::isa::{Dim, DimCtx, Instr, LdTarget, StreamClass, UnitClass};
use crate::metrics::{Phase, Trace};

/// Stable facade over the event loop: construct once per (arch,
/// workload, options) and `run` any number of times. `run_with` reuses a
/// caller-owned [`ExecScratch`] so repeat runs are allocation-light.
pub struct Simulator<'a> {
    arch: &'a ArchConfig,
    wl: &'a Workload<'a>,
    opts: SimOptions,
}

impl<'a> Simulator<'a> {
    pub fn new(arch: &'a ArchConfig, wl: &'a Workload<'a>, opts: SimOptions) -> Self {
        Simulator { arch, wl, opts }
    }

    pub fn run(&self) -> Result<SimResult, String> {
        let mut scratch = ExecScratch::new();
        self.run_with(&mut scratch)
    }

    /// Run reusing `scratch` buffers from previous runs (re-entrant
    /// serving hot path; one scratch per worker thread).
    pub fn run_with(&self, scratch: &mut ExecScratch) -> Result<SimResult, String> {
        Engine::new(self.arch, self.wl, self.opts, scratch)?.run()
    }
}

struct Engine<'a, 's> {
    arch: &'a ArchConfig,
    wl: &'a Workload<'a>,
    opts: SimOptions,
    sched: Scheduler,
    units: Units,
    // partition progress
    part_cursor: usize,
    cur_part: Option<usize>,
    tile_cursor: usize,
    tiles_done: usize,
    // functional state (recycled across runs)
    scratch: &'s mut ExecScratch,
    // metrics
    res: SimResult,
    trace: Option<Trace>,
}

impl<'a, 's> Engine<'a, 's> {
    fn new(
        arch: &'a ArchConfig,
        wl: &'a Workload<'a>,
        opts: SimOptions,
        scratch: &'s mut ExecScratch,
    ) -> Result<Self, String> {
        scratch.func.begin_run(&Env::of(wl), opts.functional);
        if let Some(x) = wl.x {
            scratch.func.init_input(wl.tiling, x, wl.feat_in)?;
        }
        if opts.functional {
            // output image only exists in functional mode (perf: timing
            // runs on large graphs shouldn't pay an O(V·F) pass)
            scratch.func.prepare_output(wl.tiling.num_vertices, wl.feat_out);
        }
        let trace = (opts.trace_window > 0).then(|| {
            Trace::new(
                opts.trace_window,
                (arch.mu_count as f64 * arch.mu_macs_per_cycle() as f64 * 2.0)
                    + arch.vu_count as f64 * arch.vu_width() as f64,
                arch.hbm_bytes_per_cycle(),
            )
        });
        Ok(Engine {
            arch,
            wl,
            opts,
            sched: Scheduler::new(arch),
            units: Units::new(arch),
            part_cursor: 0,
            cur_part: None,
            tile_cursor: 0,
            tiles_done: 0,
            scratch,
            res: SimResult::default(),
            trace,
        })
    }

    fn func_of(&self, class: StreamClass) -> &'a [Instr] {
        match class {
            StreamClass::D => &self.wl.program.d_func,
            StreamClass::S => &self.wl.program.s_func,
            StreamClass::E => &self.wl.program.e_func,
        }
    }

    fn dims_for_partition(&self, part_idx: usize) -> DimCtx {
        let p = &self.wl.tiling.partitions[part_idx];
        DimCtx {
            tile_src: 0,
            tile_edges: 0,
            part_dst: p.num_dst(),
            feat_in: self.wl.feat_in,
            feat_out: self.wl.feat_out,
        }
    }

    fn run(mut self) -> Result<SimResult, String> {
        let max_steps: u64 = 2_000_000_000;
        let mut steps: u64 = 0;
        loop {
            steps += 1;
            if steps > max_steps {
                return Err("simulation exceeded step budget".into());
            }
            // pick the runnable stream with the earliest ready time
            let Some(sid) = self.sched.pick_ready() else {
                // no runnable stream: if the dStream halted we're done;
                // otherwise it's a deadlock (protocol bug)
                if self.sched.d_halted() {
                    break;
                }
                return Err(format!("deadlock: stream states {}", self.sched.state_dump()));
            };
            self.step(sid)?;
            if self.sched.d_halted() {
                break;
            }
        }
        // finish metrics
        self.res.cycles = self.sched.max_ready_at().max(self.units.max_busy());
        self.res.counters.cycles = self.res.cycles;
        if let Some(t) = self.trace.take() {
            self.res.trace = t.finish();
        }
        if self.opts.functional && self.opts.emit_output {
            // un-permute output to original vertex order
            self.res.output = Some(self.scratch.func.take_output(self.wl.tiling, self.wl.feat_out));
        }
        // !emit_output (hidden pipeline layers): the tiled output image
        // stays pooled in the scratch for `ExecScratch::stash_output`
        Ok(self.res)
    }

    /// Execute one instruction of stream `sid`.
    fn step(&mut self, sid: usize) -> Result<(), String> {
        let class = self.sched.streams[sid].class;
        let func = self.func_of(class);
        let pc = self.sched.streams[sid].pc;
        let instr = func
            .get(pc)
            .ok_or_else(|| format!("stream {sid} pc {pc} out of bounds"))?
            .clone();
        let t0 = self.sched.streams[sid].ready_at;
        self.res.instructions += 1;

        let dims = self.stream_dims(sid);
        // Timing-only dims: under `sparse_skip` a TileSrc-row instruction
        // on a partially occupied tile is charged for the occupied
        // row-blocks only. Functional execution below always uses the
        // real `dims` — the skip changes accounting, never values.
        let tdims = self.timing_dims(sid, &dims, &instr);

        match instr.unit() {
            UnitClass::Sync => self.exec_sync(sid, &instr, t0)?,
            UnitClass::Mem => {
                let bytes = instr.dram_bytes(&tdims);
                let start = t0;
                let end = self.units.issue_transfer(
                    self.wl.tiling,
                    self.sched.streams[sid].tile.as_ref(),
                    self.cur_part,
                    self.wl.feat_in,
                    self.wl.feat_out,
                    &instr,
                    start,
                    bytes,
                )?;
                self.res.mem_busy +=
                    (bytes as f64 / self.units.hbm.peak_bytes_per_cycle()).ceil() as u64;
                match instr {
                    Instr::Ld { target, .. } => {
                        self.res.dram_read_bytes += bytes;
                        if target == LdTarget::Edge {
                            self.res.counters.th_bytes += bytes;
                        } else {
                            self.res.counters.uem_bytes += timing::uem_bytes(&instr, &tdims);
                        }
                        if self.opts.functional {
                            let env = Env::of(self.wl);
                            let tile = self.sched.streams[sid].tile.clone();
                            self.scratch.func.exec_instr(
                                &env,
                                tile.as_ref(),
                                self.cur_part,
                                &dims,
                                &instr,
                            )?;
                        }
                    }
                    Instr::St { .. } => {
                        self.res.dram_write_bytes += bytes;
                        self.res.counters.uem_bytes += timing::uem_bytes(&instr, &tdims);
                        // functional store happens at UPD.PTT commit
                    }
                    _ => unreachable!(),
                }
                self.res.counters.hbm_bytes += bytes;
                self.record_trace(start, end, 0, bytes, Phase::Mem);
                self.sched.advance(sid, end, 1);
            }
            UnitClass::Mu | UnitClass::Vu => {
                let dur = timing::compute_cycles(self.arch, &instr, &tdims);
                let (start, end) = if instr.unit() == UnitClass::Mu {
                    self.res.mu_busy += dur;
                    self.units.issue_mu(t0, dur)
                } else {
                    self.res.vu_busy += dur;
                    self.units.issue_vu(t0, dur)
                };
                self.res.counters.macs += timing::macs(&instr, &tdims);
                self.res.counters.vu_ops += timing::vu_ops(&instr, &tdims);
                self.res.counters.uem_bytes += timing::uem_bytes(&instr, &tdims);
                if matches!(instr, Instr::Sctr { .. } | Instr::Gthr { .. }) {
                    // edge-list reads from the tile hub
                    self.res.counters.th_bytes += dims.tile_edges as u64 * 8;
                }
                let phase = match &instr {
                    Instr::Gemm { .. } | Instr::Bmm { .. } => Phase::Gemm,
                    Instr::Sctr { .. } | Instr::Gthr { .. } => Phase::Gop,
                    _ => Phase::Elw,
                };
                self.record_trace(start, end, instr.flops(&tdims), 0, phase);
                if self.opts.functional {
                    // GTHR is a no-op here: its reduction is deferred to
                    // the tile-ordered fold at the dStream wait boundary
                    let env = Env::of(self.wl);
                    let tile = self.sched.streams[sid].tile.clone();
                    self.scratch
                        .func
                        .exec_instr(&env, tile.as_ref(), self.cur_part, &dims, &instr)?;
                }
                self.sched.advance(sid, end, 1);
            }
        }
        Ok(())
    }

    fn stream_dims(&self, sid: usize) -> DimCtx {
        if let Some(t) = &self.sched.streams[sid].tile {
            t.dims
        } else if let Some(p) = self.cur_part {
            self.dims_for_partition(p)
        } else {
            DimCtx { feat_in: self.wl.feat_in, feat_out: self.wl.feat_out, ..Default::default() }
        }
    }

    /// The dims an instruction is *charged* with. Under the
    /// `sparse_skip` kernel policy, instructions whose row extent is
    /// `Dim::TileSrc` (LD.SRC, the source-side GEMM/GEMV/elementwise
    /// ops) on a partially occupied tile are billed for the occupied
    /// row-blocks only (`tiling::SKIP_BLOCK` granularity) — modeling
    /// compute and DRAM traffic the masked kernels actually skip.
    /// Edge-extent ops (SCTR/GTHR/BMM) already scale with real work and
    /// are charged as-is, as is everything when the tile is dense.
    fn timing_dims(&self, sid: usize, dims: &DimCtx, instr: &Instr) -> DimCtx {
        if !self.wl.kernels.sparse_skip {
            return *dims;
        }
        let Some(tc) = &self.sched.streams[sid].tile else {
            return *dims;
        };
        let src_rows = match instr {
            Instr::Ld { target: LdTarget::Src, rows, .. }
            | Instr::Gemv { rows, .. }
            | Instr::ElwU { rows, .. }
            | Instr::ElwB { rows, .. }
            | Instr::ElwBcast { rows, .. } => matches!(rows, Dim::TileSrc),
            Instr::Gemm { m, .. } => matches!(m, Dim::TileSrc),
            _ => false,
        };
        if !src_rows {
            return *dims;
        }
        let tile = &self.wl.tiling.partitions[tc.part_idx].tiles[tc.tile_idx];
        if tile.fully_occupied() {
            return *dims;
        }
        DimCtx { tile_src: tile.occupied_block_rows(crate::tiling::SKIP_BLOCK), ..*dims }
    }

    fn exec_sync(&mut self, sid: usize, instr: &Instr, t0: u64) -> Result<(), String> {
        match instr {
            Instr::FchPtt => {
                debug_assert_eq!(self.sched.streams[sid].class, StreamClass::D);
                if self.part_cursor >= self.wl.tiling.partitions.len() {
                    self.sched.streams[sid].state = StreamState::Halted;
                    return Ok(());
                }
                let p = self.part_cursor;
                self.part_cursor += 1;
                self.cur_part = Some(p);
                self.tile_cursor = 0;
                self.tiles_done = 0;
                // functional: reset partition frame; init accumulators
                if self.opts.functional {
                    let dims = self.dims_for_partition(p);
                    self.scratch.func.begin_partition(&dims);
                }
                // empty partition: pre-credit the completion signal so the
                // dStream's WAIT doesn't deadlock
                if self.wl.tiling.partitions[p].tiles.is_empty() {
                    self.sched.streams[sid].signals += 1;
                }
                self.sched.advance(sid, t0 + 1, 1);
            }
            Instr::UpdPtt => {
                // commit the partition output (functional)
                if self.opts.functional {
                    let p = self.cur_part.ok_or("UPD.PTT without partition")?;
                    let env = Env::of(self.wl);
                    self.scratch
                        .func
                        .commit_partition(&env, &self.wl.tiling.partitions[p])?;
                }
                self.sched.advance(sid, t0 + 1, 1);
            }
            Instr::Signal { class } => {
                let end = t0 + 1;
                match class {
                    StreamClass::S => {
                        // broadcast: wake every sStream for this partition
                        self.sched.signal_all_s(end);
                    }
                    StreamClass::E => {
                        // rendezvous: hand the bound tile to the least-loaded eStream
                        let tile = self.sched.streams[sid]
                            .tile
                            .clone()
                            .ok_or("SIGNAL.E without a bound tile")?;
                        self.sched.deliver_tile_to_e(tile, end)?;
                    }
                    StreamClass::D => {
                        self.sched.signal(0, end);
                    }
                }
                self.sched.advance(sid, end, 1);
            }
            Instr::Wait { count } => {
                let need = count.resolve(&self.stream_dims(sid)).max(1);
                if self.sched.streams[sid].signals >= need {
                    self.sched.streams[sid].signals -= need;
                    // eStream: bind the tile handed over by SIGNAL.E (FIFO)
                    if self.sched.streams[sid].class == StreamClass::E {
                        if let Some(t) = self.sched.streams[sid].mailbox.pop() {
                            self.sched.streams[sid].tile = Some(t);
                        }
                    }
                    // dStream resuming after all tiles: fold the
                    // deferred GTHR reductions in ascending tile order
                    // (bit-exact with the batched path), then fix up
                    // untouched max accumulators
                    if self.sched.streams[sid].class == StreamClass::D && self.opts.functional {
                        let p = self.cur_part.ok_or("dStream WAIT without partition")?;
                        let env = Env::of(self.wl);
                        self.scratch.func.fold_gathers(&env, p)?;
                        self.scratch.func.fixup_max_accs();
                    }
                    self.sched.advance(sid, t0 + 1, 1);
                } else {
                    self.sched.streams[sid].state = StreamState::Waiting;
                    // pc unchanged: re-execute WAIT when woken
                }
            }
            Instr::FchTile { on_empty } => {
                let p = self.cur_part.ok_or("FCH.TILE without partition")?;
                let part = &self.wl.tiling.partitions[p];
                if self.tile_cursor >= part.tiles.len() {
                    // no tiles left in this partition
                    self.sched.advance(sid, t0 + 1, *on_empty as i64);
                    return Ok(());
                }
                let ti = self.tile_cursor;
                self.tile_cursor += 1;
                let tile = &part.tiles[ti];
                let dims = DimCtx {
                    tile_src: tile.num_src(),
                    tile_edges: tile.num_edges(),
                    part_dst: part.num_dst(),
                    feat_in: self.wl.feat_in,
                    feat_out: self.wl.feat_out,
                };
                // UEM residency estimate: src tile + edge intermediates
                let resident = (tile.num_src() as u64 * self.wl.feat_in as u64
                    + tile.num_edges() as u64 * self.wl.feat_out as u64)
                    * 4;
                self.res.peak_uem_bytes = self.res.peak_uem_bytes.max(resident);
                let frame = self.scratch.func.alloc_tile_frame(self.opts.functional);
                self.sched.streams[sid].tile =
                    Some(TileCtx { part_idx: p, tile_idx: ti, dims, frame });
                self.sched.advance(sid, t0 + 1, 1);
            }
            Instr::ChkPtt => {
                self.tiles_done += 1;
                let p = self.cur_part.ok_or("CHK.PTT without partition")?;
                let total = self.wl.tiling.partitions[p].tiles.len();
                let end = t0 + 1;
                if self.tiles_done >= total {
                    self.sched.signal(0, end);
                }
                self.sched.streams[sid].tile = None;
                self.sched.advance(sid, end, 1);
            }
            Instr::Jump(off) => {
                self.sched.advance(sid, t0, *off as i64);
            }
            Instr::Halt => {
                self.sched.streams[sid].state = StreamState::Halted;
            }
            other => return Err(format!("non-sync instruction in exec_sync: {other}")),
        }
        Ok(())
    }

    fn record_trace(&mut self, start: u64, end: u64, flops: u64, bytes: u64, phase: Phase) {
        if let Some(t) = &mut self.trace {
            t.record(start, end, flops, bytes, phase);
        }
    }
}

// Engine behaviour is exercised end-to-end in `rust/tests/sim_engine.rs`
// through the public facade (Workload / Simulator / ExecScratch).
