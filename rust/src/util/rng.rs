//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core.
//!
//! Used by the graph generators, workload synthesizers, and the in-tree
//! property-test helpers. Deterministic across platforms so every
//! experiment is exactly reproducible from its seed.

/// xoshiro256** by Blackman & Vigna (public domain), seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [-1, 1) — embedding initializer.
    #[inline]
    pub fn next_f32_sym(&mut self) -> f32 {
        (self.next_f64() * 2.0 - 1.0) as f32
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Standard normal via Box–Muller (one value, second discarded).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Zipf-like rank sample in [0, n): P(k) ∝ 1/(k+1)^alpha.
    /// Used by the power-law graph generators (inverse-CDF on a harmonic
    /// approximation — adequate for degree-shape matching, not exact Zipf).
    pub fn zipf(&mut self, n: u64, alpha: f64) -> u64 {
        debug_assert!(n > 0);
        if alpha <= 0.0 {
            return self.below(n);
        }
        // Inverse CDF of p(x) ∝ x^-alpha over [1, n+1).
        let u = self.next_f64();
        let one_minus = 1.0 - alpha;
        let x = if (one_minus).abs() < 1e-9 {
            // alpha == 1: CDF ∝ ln(x)
            ((n as f64 + 1.0).ln() * u).exp()
        } else {
            let top = ((n as f64 + 1.0).powf(one_minus) - 1.0) * u + 1.0;
            top.powf(1.0 / one_minus)
        };
        ((x as u64).saturating_sub(1)).min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_roughly_uniform() {
        let mut r = Rng::new(11);
        let mut counts = [0u32; 8];
        for _ in 0..80_000 {
            counts[r.below(8) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn zipf_is_skewed_and_bounded() {
        let mut r = Rng::new(13);
        let mut lo = 0u32;
        for _ in 0..10_000 {
            let k = r.zipf(1000, 1.2);
            assert!(k < 1000);
            if k < 10 {
                lo += 1;
            }
        }
        // power-law: a large fraction of mass on the first few ranks
        assert!(lo > 3_000, "low-rank mass {lo}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(23);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
