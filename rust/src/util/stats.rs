//! Small statistics helpers for benches and metric summaries.

/// Running summary: count / mean / min / max (Welford for variance).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: u64,
    pub mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Geometric mean of positive ratios — the averaging the paper uses for
/// its "93.6× on average" style claims.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let log_sum: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Fixed-bucket log₂ histogram over non-negative integer samples
/// (microseconds in the serving runtime). Bucket `i` covers
/// `[2^(i-1), 2^i)` with bucket 0 = the exact value 0, so recording is
/// O(1), the memory footprint is constant, and percentile queries never
/// allocate — the properties an always-on service needs from its latency
/// accounting (`coordinator::service::ServiceMetrics`).
///
/// Percentiles are resolved to the recorded maximum within the bucket's
/// range: exact for the top bucket, within a 2× factor elsewhere —
/// plenty for p50/p95/p99 tail reporting.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    counts: [u64; Self::BUCKETS],
    total: u64,
    max: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    /// 41 buckets: 0, then [2^0, 2^1) … [2^39, 2^40) — the last bucket
    /// tops out above 12 days in microseconds.
    pub const BUCKETS: usize = 41;

    pub fn new() -> Self {
        LogHistogram { counts: [0; Self::BUCKETS], total: 0, max: 0 }
    }

    fn bucket(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            ((64 - v.leading_zeros()) as usize).min(Self::BUCKETS - 1)
        }
    }

    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket(v)] += 1;
        self.total += 1;
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Nearest-rank percentile resolved to the containing bucket's upper
    /// edge (clamped to the recorded maximum). Returns 0 when empty.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let upper = if i == 0 {
                    0
                } else if i == Self::BUCKETS - 1 {
                    self.max // the top bucket is open-ended
                } else {
                    (1u64 << i) - 1
                };
                return upper.min(self.max);
            }
        }
        self.max
    }
}

/// Percentile over a copy of the data (nearest-rank).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[10.0, 10.0, 10.0]) - 10.0).abs() < 1e-9);
        assert!(geomean(&[]).is_nan());
    }

    #[test]
    fn log_histogram_buckets_and_percentiles() {
        let mut h = LogHistogram::new();
        assert_eq!(h.percentile(50.0), 0);
        // 90 fast samples at 100us, 10 slow at 10_000us
        for _ in 0..90 {
            h.record(100);
        }
        for _ in 0..10 {
            h.record(10_000);
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.max(), 10_000);
        // p50 lands in the [64, 128) bucket → upper edge 127
        assert_eq!(h.percentile(50.0), 127);
        // p95/p99 land in the slow bucket [8192, 16384), clamped to max
        assert_eq!(h.percentile(95.0), 10_000);
        assert_eq!(h.percentile(99.0), 10_000);
        // exact zeros stay zero
        let mut z = LogHistogram::new();
        z.record(0);
        assert_eq!(z.percentile(99.0), 0);
        // huge values clamp into the top bucket without overflow
        let mut big = LogHistogram::new();
        big.record(u64::MAX);
        assert_eq!(big.percentile(50.0), u64::MAX);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }
}
