//! Small statistics helpers for benches and metric summaries.

/// Running summary: count / mean / min / max (Welford for variance).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: u64,
    pub mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Geometric mean of positive ratios — the averaging the paper uses for
/// its "93.6× on average" style claims.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let log_sum: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Percentile over a copy of the data (nearest-rank).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[10.0, 10.0, 10.0]) - 10.0).abs() < 1e-9);
        assert!(geomean(&[]).is_nan());
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }
}
