//! Dependency-free utilities: deterministic RNG, minimal JSON, stats.
//!
//! This repo builds fully offline with no external crates at all, so
//! the usual ecosystem helpers (rand, serde_json, proptest) are
//! implemented in-tree at the size this project needs.

pub mod json;
pub mod rng;
pub mod stats;

pub use rng::Rng;

/// Ceiling division for unsigned integers.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Human-readable byte count (for logs and bench tables).
pub fn fmt_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Human-readable cycle/time count given a clock frequency.
pub fn fmt_time_at(cycles: u64, freq_hz: f64) -> String {
    let s = cycles as f64 / freq_hz;
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KB");
        assert!(fmt_bytes(3 * 1024 * 1024).starts_with("3.00 MB"));
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time_at(1_000_000_000, 1e9).contains("s"));
        assert!(fmt_time_at(1_000, 1e9).contains("us"));
    }
}
