//! Minimal JSON parser + serializer (RFC 8259 subset, UTF-8 input).
//!
//! Exists to read `artifacts/manifest.json` (written by the python AOT
//! pipeline) and to emit machine-readable bench/metric dumps without an
//! external serde dependency. Supports the full JSON value model; numbers
//! are f64 (adequate: the manifest only carries shapes and hashes).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // -- serializer --------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, depth: usize, pretty: bool) {
        let pad = |out: &mut String, d: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..d {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    e.write(out, depth + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, e)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    e.write(out, depth + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, depth);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only (no surrogate-pair handling needed
                            // for manifest content); replace others.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    let rest = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(1).unwrap().as_u64(), Some(2));
        assert_eq!(
            j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"entries":[{"file":"gcn.hlo.txt","tile":{"num_src":256}}],"format":"hlo-text"}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""é\t""#).unwrap();
        assert_eq!(j.as_str(), Some("é\t"));
    }

    #[test]
    fn deep_accessors_return_none_on_type_mismatch() {
        let j = Json::parse("[1]").unwrap();
        assert!(j.get("x").is_none());
        assert!(j.idx(0).unwrap().as_str().is_none());
        assert_eq!(j.idx(0).unwrap().as_u64(), Some(1));
        assert!(Json::parse("1.5").unwrap().as_u64().is_none());
    }
}
