//! Operator-level overlap tests (DESIGN.md §3.9): hiding the halo
//! exchange behind halo-independent tiles is a *timing-only* transform.
//! Functional outputs must stay bit-exact with both the serial sharded
//! schedule and the unsharded plan on both execution paths, while the
//! overlapped cycle count obeys the model's bounds: never slower than
//! serial, never faster than dropping the exchange outright, and every
//! post-boundary layer still pays at least the exchange latency.

use zipper::config::{ArchConfig, RunConfig};
use zipper::graph::GraphBuilder;
use zipper::models::ModelKind;
use zipper::plan::ExecPlan;
use zipper::sim::parallel::BatchScratch;
use zipper::tiling::{Reorder, TilingConfig, TilingMode};

const MODELS: [&str; 5] = ["gcn", "gat", "sage", "ggnn", "rgcn"];

fn run_cfg(model: &str, layers: u32, shards: u32, overlap: bool) -> RunConfig {
    RunConfig {
        model: model.into(),
        dataset: "CR".into(),
        scale: 16,
        feat_in: 16,
        feat_out: 16,
        layers,
        hidden: Vec::new(),
        tiling: TilingConfig {
            dst_part: 64,
            src_part: 64,
            mode: TilingMode::Sparse,
            reorder: Reorder::InDegree,
            threads: 1,
        },
        e2v: true,
        passes: Default::default(),
        functional: true,
        seed: 3,
        serving: Default::default(),
        kernels: Default::default(),
        shards,
        overlap,
    }
}

/// The acceptance matrix: overlap {off, on} × all five models × depths
/// {2, 3} × K ∈ {2, 3}, engine path plus the batched path at inner
/// thread counts {1, 4} — every combination bit-exact with the
/// unsharded plan.
#[test]
fn overlap_outputs_are_bit_exact_across_models_depths_k_and_threads() {
    let arch = ArchConfig::default();
    for model in MODELS {
        for depth in [2u32, 3] {
            let base = ExecPlan::compile(&run_cfg(model, depth, 1, false)).unwrap();
            let x = base.make_input(23);
            let want = base
                .simulate(&arch, true, Some(&x), 0)
                .unwrap()
                .output
                .unwrap();
            for k in [2u32, 3] {
                for overlap in [false, true] {
                    let tag = format!("{model} depth={depth} k={k} overlap={overlap}");
                    let plan = ExecPlan::compile(&run_cfg(model, depth, k, overlap)).unwrap();
                    let res = plan.simulate(&arch, true, Some(&x), 0).unwrap();
                    assert_eq!(res.output.as_ref(), Some(&want), "{tag}: engine path diverged");
                    for threads in [1usize, 4] {
                        let mut scratch = BatchScratch::new();
                        let outs =
                            plan.execute_batch_with(&[&x, &x], threads, &mut scratch).unwrap();
                        assert_eq!(outs[0], want, "{tag} threads={threads}: batched diverged");
                        assert_eq!(outs[1], want, "{tag} threads={threads}: lanes diverged");
                    }
                }
            }
        }
    }
}

/// The timing model's provable bounds, on a depth-3 K=2 run with a real
/// cut: serial and overlapped plans agree on every event count and on
/// the exchange cost itself; the overlapped total is bounded below by
/// serial-minus-exchange (perfect hiding) and above by serial (no
/// hiding); hidden + exposed partitions the exchange; each
/// post-boundary layer still pays at least the boundary latency; and
/// the per-layer breakdown still sums to the total.
#[test]
fn overlap_timing_obeys_model_bounds() {
    let arch = ArchConfig::default();
    let serial = ExecPlan::compile(&run_cfg("gcn", 3, 2, false))
        .unwrap()
        .simulate(&arch, false, None, 0)
        .unwrap();
    let ovl = ExecPlan::compile(&run_cfg("gcn", 3, 2, true))
        .unwrap()
        .simulate(&arch, false, None, 0)
        .unwrap();

    // same plan, same cut, same exchange model — only billing differs
    assert_eq!(serial.instructions, ovl.instructions);
    assert_eq!(serial.halo.exchanges, 2);
    assert_eq!(ovl.halo.exchanges, 2);
    assert_eq!(serial.halo.vertices, ovl.halo.vertices);
    assert_eq!(serial.halo.bytes, ovl.halo.bytes);
    assert_eq!(serial.halo.cycles, ovl.halo.cycles);
    assert!(ovl.halo.cycles > 0, "CR cut must produce a real exchange");

    // serial billing: everything on the critical path, nothing hidden
    assert_eq!(serial.halo.hidden_cycles, 0);
    assert_eq!(serial.halo.exposed_cycles, serial.halo.cycles);

    // overlap billing: hidden + exposed partitions the exchange cost
    assert_eq!(ovl.halo.hidden_cycles + ovl.halo.exposed_cycles, ovl.halo.cycles);

    // never slower than serial, never faster than a free exchange
    assert!(
        ovl.cycles <= serial.cycles,
        "overlap ({}) must not exceed serial ({})",
        ovl.cycles,
        serial.cycles
    );
    assert!(
        ovl.cycles >= serial.cycles - serial.halo.cycles,
        "overlap ({}) cannot hide more than the whole exchange ({} - {})",
        ovl.cycles,
        serial.cycles,
        serial.halo.cycles
    );
    // equivalently: the cycles saved are exactly the hidden cycles
    assert_eq!(serial.cycles - ovl.cycles, ovl.halo.hidden_cycles);

    // each post-boundary layer is billed max(E, independent) + dependent
    // >= E: the exchange latency can never disappear from a layer that
    // consumes halo activations
    let per_boundary = ovl.halo.cycles / ovl.halo.exchanges;
    for (l, layer) in ovl.layers.iter().enumerate().skip(1) {
        assert!(
            layer.cycles >= per_boundary,
            "layer {l} cycles {} below the boundary latency {per_boundary}",
            layer.cycles
        );
    }

    // the invariant every other timing test leans on survives overlap
    assert_eq!(ovl.cycles, ovl.layers.iter().map(|l| l.cycles).sum::<u64>());
    assert_eq!(
        ovl.dram_read_bytes,
        ovl.layers.iter().map(|l| l.dram_read_bytes).sum::<u64>()
    );
}

/// A star graph (every edge points at one hub) cut in two: the shard
/// that owns the hub imports every remote leaf, the other shard imports
/// nothing — its per-boundary copy list is empty and the exchange walk
/// skips it. Outputs stay bit-exact on both paths, overlap on and off.
#[test]
fn one_directional_halo_skips_the_empty_direction() {
    let arch = ArchConfig::default();
    let n = 64u32;
    let mut b = GraphBuilder::new(n);
    for v in 1..n {
        b.add_edge(v, 0).unwrap();
    }
    let graph = b.build();

    let base = ExecPlan::from_graph(ModelKind::Gcn, graph.clone(), &run_cfg("gcn", 2, 1, false))
        .unwrap();
    let x = base.make_input(29);
    let want = base
        .simulate(&arch, true, Some(&x), 0)
        .unwrap()
        .output
        .unwrap();

    for overlap in [false, true] {
        let plan =
            ExecPlan::from_graph(ModelKind::Gcn, graph.clone(), &run_cfg("gcn", 2, 2, overlap))
                .unwrap();
        let sh = plan.sharding.as_ref().unwrap();

        // exactly one direction carries copies
        let nonempty: Vec<usize> =
            (0..2).filter(|&s| !sh.halo_in[s].is_empty()).collect();
        assert_eq!(nonempty.len(), 1, "star cut must have a one-directional halo");
        let hub_shard = nonempty[0];
        assert_eq!(
            sh.halo_copies,
            sh.halo_in[hub_shard].len() as u64,
            "all copies flow toward the hub's shard"
        );
        // the hub's gather reads imported leaves → at least one
        // dependent tile there; the leaf-only shard reads no halo at
        // all → fully independent
        assert!(sh.overlap.dependent_tiles[hub_shard] >= 1);
        assert_eq!(sh.overlap.dependent_tiles[1 - hub_shard], 0);
        assert_eq!(
            sh.overlap.independent_tiles[1 - hub_shard] as usize,
            sh.overlap.independent[1 - hub_shard].len()
        );

        let res = plan.simulate(&arch, true, Some(&x), 0).unwrap();
        assert_eq!(res.output.as_ref(), Some(&want), "overlap={overlap}: engine diverged");
        assert_eq!(res.halo.exchanges, 1);
        assert_eq!(res.halo.vertices, sh.halo_copies, "only the hub direction is billed");

        let mut scratch = BatchScratch::new();
        let outs = plan.execute_batch_with(&[&x], 2, &mut scratch).unwrap();
        assert_eq!(outs[0], want, "overlap={overlap}: batched diverged");
    }
}

/// A self-loop-only graph partitions with an empty cut (every edge's
/// endpoints share a shard by construction): the boundary has zero
/// copies, so the staged exchange is skipped entirely — no exchanges
/// billed, no halo cycles, overlap a no-op — while the functional
/// result still matches the unsharded plan.
#[test]
fn empty_cut_skips_the_boundary_exchange_entirely() {
    let arch = ArchConfig::default();
    let mut b = GraphBuilder::new(32);
    for v in 0..32 {
        b.add_edge(v, v).unwrap();
    }
    let graph = b.build();
    let base = ExecPlan::from_graph(ModelKind::Gcn, graph.clone(), &run_cfg("gcn", 2, 1, false))
        .unwrap();
    let x = base.make_input(31);
    let want = base
        .simulate(&arch, true, Some(&x), 0)
        .unwrap()
        .output
        .unwrap();
    for overlap in [false, true] {
        let plan =
            ExecPlan::from_graph(ModelKind::Gcn, graph.clone(), &run_cfg("gcn", 2, 2, overlap))
                .unwrap();
        let sh = plan.sharding.as_ref().unwrap();
        assert_eq!(sh.halo_copies, 0, "edgeless graph has no cut");
        let res = plan.simulate(&arch, true, Some(&x), 0).unwrap();
        assert_eq!(res.halo.exchanges, 0, "empty copy list must skip the exchange");
        assert_eq!(res.halo.cycles, 0);
        assert_eq!(res.halo.hidden_cycles, 0);
        assert_eq!(res.halo.exposed_cycles, 0);
        assert_eq!(res.output.as_ref(), Some(&want), "overlap={overlap}: engine diverged");
        let mut scratch = BatchScratch::new();
        let outs = plan.execute_batch_with(&[&x], 1, &mut scratch).unwrap();
        assert_eq!(outs[0], want, "overlap={overlap}: batched diverged");
    }
}
